#!/usr/bin/env python3
"""Compare a fresh bench JSON against the committed baseline.

Usage: bench_compare.py BASELINE FRESH OUT

Handles both bench families by row shape: training rows carry
`steps_per_sec` (BENCH_throughput.json, gated on steps/sec) and
serving rows carry `reqs_per_sec` + `p99_ms` (BENCH_serving.json,
gated on throughput *drop* and p99 latency *rise*). The CI bench-smoke
and serving-smoke jobs run the matching bench into FRESH and call this
script with the repo's committed BASELINE. Two modes:

* **Seed mode** — the baseline has no results (the committed file is
  the unblessed placeholder, or a config is brand new). The script
  records the fresh numbers in OUT, prints how to bless them, and
  exits 0: a gate can't be armed against numbers that were never
  measured on this hardware class.

* **Gate mode** — the baseline carries results. Every baseline config
  must be present in FRESH and its steps/sec must not regress by more
  than MAX_REGRESSION (15%). Per-kernel GFLOP/s and per-collective
  MB/s deltas are recorded in OUT for inspection but do not gate (they
  are far noisier than end-to-end steps/sec on shared runners).

OUT is a JSON comparison artifact either way, and always embeds a
blessing candidate: commit OUT's `fresh` object as the repo's
BENCH_throughput.json (or copy the uploaded fresh file directly) to
re-baseline after an accepted perf change.
"""

import json
import sys

MAX_REGRESSION = 0.15


def by_config(doc):
    return {r["config"]: r for r in doc.get("results", [])}


def deltas(base_row, fresh_row, key):
    """Relative per-entry deltas for a nested {name: number} column."""
    out = {}
    for name, b in (base_row.get(key) or {}).items():
        f = (fresh_row.get(key) or {}).get(name)
        if b is None or f is None or b == 0:
            out[name] = None
        else:
            out[name] = (f - b) / b
    return out


def main():
    if len(sys.argv) != 4:
        sys.exit(f"usage: {sys.argv[0]} BASELINE FRESH OUT")
    base_path, fresh_path, out_path = sys.argv[1:4]
    with open(base_path) as fh:
        base = json.load(fh)
    with open(fresh_path) as fh:
        fresh = json.load(fh)

    base_rows, fresh_rows = by_config(base), by_config(fresh)
    comparison = {
        "bench": "throughput-comparison",
        "max_regression": MAX_REGRESSION,
        "fresh": fresh,
    }
    failures = []

    if not base_rows:
        comparison["mode"] = "seed"
        print("bench_compare: baseline has no results — seed mode.")
        print("bench_compare: to arm the regression gate, commit the fresh")
        print(f"bench_compare: results ({fresh_path}) as {base_path}.")
    else:
        comparison["mode"] = "gate"
        rows = []
        for config, b in base_rows.items():
            f = fresh_rows.get(config)
            if f is None:
                failures.append(f"{config}: present in baseline, missing from fresh run")
                continue
            if "reqs_per_sec" in b:
                # Serving row: throughput must not drop, p99 must not rise.
                rel = (f["reqs_per_sec"] - b["reqs_per_sec"]) / b["reqs_per_sec"]
                p99_rel = (
                    (f["p99_ms"] - b["p99_ms"]) / b["p99_ms"] if b.get("p99_ms") else 0.0
                )
                rows.append(
                    {
                        "config": config,
                        "baseline_reqs_per_sec": b["reqs_per_sec"],
                        "fresh_reqs_per_sec": f["reqs_per_sec"],
                        "delta": rel,
                        "baseline_p99_ms": b.get("p99_ms"),
                        "fresh_p99_ms": f.get("p99_ms"),
                        "p99_delta": p99_rel,
                    }
                )
                bad = rel < -MAX_REGRESSION or p99_rel > MAX_REGRESSION
                print(
                    f"bench_compare: {config}: {b['reqs_per_sec']:.1f} -> "
                    f"{f['reqs_per_sec']:.1f} req/s ({rel:+.1%}), "
                    f"p99 {b.get('p99_ms', 0):.2f} -> {f.get('p99_ms', 0):.2f} ms "
                    f"({p99_rel:+.1%}) {'FAIL' if bad else 'ok'}"
                )
                if rel < -MAX_REGRESSION:
                    failures.append(
                        f"{config}: req/s regressed {rel:+.1%} (limit -{MAX_REGRESSION:.0%})"
                    )
                if p99_rel > MAX_REGRESSION:
                    failures.append(
                        f"{config}: p99 latency rose {p99_rel:+.1%} "
                        f"(limit +{MAX_REGRESSION:.0%})"
                    )
                if f.get("wrong_shape", 0):
                    failures.append(f"{config}: {f['wrong_shape']} wrong-shape replies")
                continue
            rel = (f["steps_per_sec"] - b["steps_per_sec"]) / b["steps_per_sec"]
            rows.append(
                {
                    "config": config,
                    "baseline_steps_per_sec": b["steps_per_sec"],
                    "fresh_steps_per_sec": f["steps_per_sec"],
                    "delta": rel,
                    "kernel_gflops_delta": deltas(b, f, "kernel_gflops"),
                    "collective_mbps_delta": deltas(b, f, "collective_mbps"),
                }
            )
            verdict = "FAIL" if rel < -MAX_REGRESSION else "ok"
            print(
                f"bench_compare: {config}: {b['steps_per_sec']:.3f} -> "
                f"{f['steps_per_sec']:.3f} steps/sec ({rel:+.1%}) {verdict}"
            )
            if rel < -MAX_REGRESSION:
                failures.append(
                    f"{config}: steps/sec regressed {rel:+.1%} "
                    f"(limit -{MAX_REGRESSION:.0%})"
                )
        comparison["rows"] = rows

    comparison["failures"] = failures
    with open(out_path, "w") as fh:
        json.dump(comparison, fh, indent=2)
        fh.write("\n")
    print(f"bench_compare: wrote {out_path}")

    if failures:
        for f in failures:
            print(f"bench_compare: FAILURE: {f}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
