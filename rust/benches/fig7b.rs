//! Bench: regenerate **Fig. 7b** — communication overhead w.r.t. MP
//! group size on a cluster of eight machines.
//!
//! The paper's claims: larger MP group size increases (MP)
//! communication drastically, while DP exchange volume *shrinks* (fewer
//! replicated/shard-peer parameters per ring); at mp=2 the total
//! overhead is comparable to pure DP.

use splitbrain::bench::{fig7b, Fidelity};
use splitbrain::comm::CommCategory;
use splitbrain::api::SessionBuilder;
use splitbrain::runtime::RuntimeClient;

fn main() -> anyhow::Result<()> {
    let numeric = std::env::args().any(|a| a == "--numeric");
    let fidelity = if numeric {
        Fidelity::Numeric { steps: 3 }
    } else {
        Fidelity::Calibrated
    };
    let rt = RuntimeClient::load("artifacts")?;
    // Benches share the builder's defaults (the one ClusterConfig source).
    let base = SessionBuilder::new().cluster_config()?;

    println!("=== Fig. 7b: communication overhead vs MP group size, 8 machines ({fidelity:?}) ===\n");
    let (table, raw) = fig7b(&rt, fidelity, &base)?;
    println!("{}", table.render());

    // Collective-algorithm comparison: naive all-to-all vs ring vs
    // recursive halving/doubling volumes behind the same phases.
    let (algo_table, _) = splitbrain::bench::fig7b_algos(&rt, &base)?;
    println!("per-algorithm communication (analytic, 8 machines):\n{}", algo_table.render());

    // Per-category byte breakdown for the largest mp, from the trace.
    let rep = splitbrain::bench::experiments::run_config(&rt, 8, 8, fidelity, &base)?;
    println!("per-category volumes at mp=8 (busiest rank, whole run):");
    for cat in CommCategory::ALL {
        let b = rep.trace.bytes(cat);
        if b > 0 {
            println!(
                "  {cat:<14} {:>10.2} MB   {:>8.3} ms",
                b as f64 / 1e6,
                rep.trace.seconds(cat) * 1e3
            );
        }
    }

    // Paper-shape checks.
    let mp_comm = |mp: usize| raw.iter().find(|r| r.0 == mp).unwrap().2;
    let dp_comm = |mp: usize| raw.iter().find(|r| r.0 == mp).unwrap().3;
    println!("\nshape checks:");
    println!(
        "  [{}] MP comm grows drastically with group size (mp8 > 4x mp2)",
        if mp_comm(8) > 4.0 * mp_comm(2) { "ok" } else { "MISS" }
    );
    println!(
        "  [{}] DP exchange shrinks as mp grows",
        if dp_comm(8) < dp_comm(1) { "ok" } else { "MISS" }
    );
    Ok(())
}
