//! Microbenchmarks of the Layer-3 hot path: per-artifact PJRT execution
//! times, host-side staging (slice/gather/SGD), fabric collectives, and
//! the tensor<->literal boundary. This is the profile the §Perf
//! iteration log in EXPERIMENTS.md is based on.

use splitbrain::comm::collective::ring_allreduce_mean;
use splitbrain::comm::fabric::{Fabric, Tag};
use splitbrain::coordinator::{ModuloPlan, ShardBwdMode, ShardPlan};
use splitbrain::runtime::{DType, HostTensor, RuntimeClient};
use splitbrain::train::Sgd;
use splitbrain::util::{Rng, Stats, Table, Timer};

fn bench<F: FnMut()>(iters: usize, mut f: F) -> Stats {
    let mut s = Stats::new();
    f(); // warmup
    for _ in 0..iters {
        let t = Timer::start();
        f();
        s.push(t.elapsed_secs() * 1e3); // ms
    }
    s
}

fn main() -> anyhow::Result<()> {
    let rt = RuntimeClient::load("artifacts")?;
    let b = rt.manifest.batch;
    let mut rng = Rng::new(3);
    let mut table = Table::new(vec!["op", "ms/call (mean ± sd)", "notes"]);

    // --- PJRT artifacts ---
    for name in [
        "conv_fwd", "conv_bwd", "full_step", "fc0_fwd_k2", "fc0_bwd_k2",
        "fc1_fwd_k2", "fc1_bwd_k2", "head_step",
    ] {
        if rt.manifest.get(name).is_err() {
            continue;
        }
        let exe = rt.executable(name)?;
        let inputs: Vec<HostTensor> = exe
            .spec()
            .inputs
            .iter()
            .map(|s| match s.dtype {
                DType::F32 => HostTensor::f32(s.shape.clone(), rng.normal_vec(s.numel(), 0.02)),
                DType::I32 => HostTensor::i32(
                    s.shape.clone(),
                    (0..s.numel()).map(|i| (i % 10) as i32).collect(),
                ),
            })
            .collect();
        let stats = bench(5, || {
            exe.run(&inputs).unwrap();
        });
        table.row(vec![name.to_string(), stats.summary(), "PJRT".to_string()]);
    }

    // --- host-side staging ---
    let act = HostTensor::f32(vec![b, 4096], rng.normal_vec(b * 4096, 1.0));
    let s = bench(50, || {
        std::hint::black_box(act.slice_rows(0, b / 2));
    });
    table.row(vec!["slice_rows B/2 x 4096".into(), s.summary(), "host".into()]);

    let s = bench(50, || {
        std::hint::black_box(act.slice_cols(0, 2048));
    });
    table.row(vec!["slice_cols B x 2048".into(), s.summary(), "host".into()]);

    let s = bench(50, || {
        std::hint::black_box(act.as_f32().to_vec());
    });
    table.row(vec!["payload copy B x 4096".into(), s.summary(), "host->fabric".into()]);

    // --- SGD over the full parameter set ---
    let mut params = vec![HostTensor::f32(vec![6_990_666], rng.normal_vec(6_990_666, 0.1))];
    let grads = vec![HostTensor::f32(vec![6_990_666], rng.normal_vec(6_990_666, 0.01))];
    let mut opt = Sgd::new(0.05, 0.9, 0.0);
    let s = bench(10, || {
        opt.step(&mut params, &grads);
    });
    table.row(vec!["SGD 7.0M params".into(), s.summary(), "host".into()]);

    // --- fabric collectives (pure host) ---
    let plan = ModuloPlan::new(vec![0, 1], b, 4096);
    let acts = vec![act.clone(), act.clone()];
    let s = bench(20, || {
        let fab = Fabric::new(2);
        let out = plan.assemble(&fab, &acts, 0, Tag::new(1, 0, 0)).unwrap();
        std::hint::black_box(out);
    });
    table.row(vec!["modulo assemble k=2".into(), s.summary(), "fabric".into()]);

    let shard = ShardPlan::new(vec![0, 1], 512, ShardBwdMode::ReducePartials);
    let parts = vec![
        HostTensor::f32(vec![b, 512], rng.normal_vec(b * 512, 1.0)),
        HostTensor::f32(vec![b, 512], rng.normal_vec(b * 512, 1.0)),
    ];
    let s = bench(20, || {
        let fab = Fabric::new(2);
        std::hint::black_box(shard.gather_full(&fab, &parts, Tag::new(3, 0, 0)).unwrap());
    });
    table.row(vec!["shard gather k=2".into(), s.summary(), "fabric".into()]);

    let mut bufs: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(1_745_738, 0.1)).collect();
    let s = bench(5, || {
        let fab = Fabric::new(8);
        ring_allreduce_mean(&fab, &(0..8).collect::<Vec<_>>(), &mut bufs, 1).unwrap();
    });
    table.row(vec!["ring allreduce 8x6.7MB".into(), s.summary(), "fabric".into()]);

    println!("=== L3 hot-path microbenchmarks ===\n{}", table.render());
    println!("note: PJRT rows are the compute charged to the simulated workers;");
    println!("fabric/host rows are simulator overhead and must stay far below them.");
    Ok(())
}
