//! Bench: regenerate **Fig. 7a** — near-linear throughput scaling at
//! MP group size 2 across machine counts {2,4,8,16,32}.
//!
//! The paper's claim: "the throughput scaling with different numbers of
//! machines for MP group size 2 is nearly linear". We report images/sec
//! and the speedup relative to perfect linear scaling.

use splitbrain::bench::{fig7a, Fidelity};
use splitbrain::api::SessionBuilder;
use splitbrain::runtime::RuntimeClient;

fn main() -> anyhow::Result<()> {
    let numeric = std::env::args().any(|a| a == "--numeric");
    let fidelity = if numeric {
        Fidelity::Numeric { steps: 3 }
    } else {
        Fidelity::Calibrated
    };
    let rt = RuntimeClient::load("artifacts")?;
    // Benches share the builder's defaults (the one ClusterConfig source).
    let base = SessionBuilder::new().cluster_config()?;

    println!("=== Fig. 7a: throughput scaling at MP=2 ({fidelity:?}) ===\n");
    let (table, raw) = fig7a(&rt, fidelity, &base)?;
    println!("{}", table.render());

    // Linearity metric: efficiency at the largest scale.
    let per_machine_2 = raw[0].1 / raw[0].0 as f64;
    let last = raw.last().unwrap();
    let eff = (last.1 / last.0 as f64) / per_machine_2;
    println!(
        "parallel efficiency at {} machines: {:.1}% (paper: nearly linear; >85% expected)",
        last.0,
        eff * 100.0
    );
    if eff < 0.85 {
        println!("WARNING: scaling fell below the paper's nearly-linear claim");
    }
    Ok(())
}
