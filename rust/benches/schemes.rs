//! Bench: the §3.1 scheme comparison the paper argues by construction —
//! BK vs B vs B/K on an 8-machine cluster, sweeping MP group size.
//!
//! Expected shape (scheme.rs cost table):
//! * wire time:  B ≈ K× worse than B/K; BK ≈ B/K (both balanced);
//! * staging memory: BK ≈ K× worse than both per-round schemes;
//! * gradients: identical (asserted in the integration tests), so the
//!   scheme is purely a systems trade — B/K dominates, which is why
//!   SplitBrain builds on it.

use splitbrain::comm::NetModel;
use splitbrain::coordinator::{GmpTopology, McastScheme, StepSchedule};
use splitbrain::model::{partition_network, vgg11, PartitionConfig};
use splitbrain::runtime::RuntimeClient;
use splitbrain::train::MemoryReport;
use splitbrain::util::Table;

fn main() -> anyhow::Result<()> {
    let rt = RuntimeClient::load("artifacts")?;
    let net = NetModel::default();
    let b = rt.manifest.batch;

    println!("=== Krizhevsky'14 scheme comparison (8 machines, B={b}) ===\n");
    let mut t = Table::new(vec![
        "mp", "scheme", "MP comm ms/step", "modulo staging MB", "activations MB", "rounds",
    ]);
    for mp in [2usize, 4, 8] {
        let tnet = partition_network(
            &vgg11(),
            vec![32, 32, 3],
            &PartitionConfig { mp, ..Default::default() },
        )?;
        let topo = GmpTopology::new(8, mp)?;
        for scheme in [McastScheme::BK, McastScheme::B, McastScheme::BoverK] {
            let sched =
                StepSchedule::compile_full(&tnet, topo, &rt.manifest, true, scheme)?;
            let mem = MemoryReport::of_scheme(&tnet, b, scheme);
            let staging_mb =
                scheme.staging_floats(b, mp, sched.boundary_width) as f64 * 4.0 / 1e6;
            t.row(vec![
                mp.to_string(),
                scheme.to_string(),
                format!("{:.3}", sched.mp_comm_secs(&net) * 1e3),
                format!("{staging_mb:.2}"),
                format!("{:.2}", mem.activations as f64 / 1e6),
                scheme.rounds(mp).to_string(),
            ]);
        }
    }
    println!("{}", t.render());

    // Shape checks.
    let comm = |mp: usize, scheme: McastScheme| -> anyhow::Result<f64> {
        let tnet = partition_network(
            &vgg11(),
            vec![32, 32, 3],
            &PartitionConfig { mp, ..Default::default() },
        )?;
        let sched = StepSchedule::compile_full(
            &tnet,
            GmpTopology::new(8, mp)?,
            &rt.manifest,
            true,
            scheme,
        )?;
        Ok(sched.mp_comm_secs(&net))
    };
    println!("shape checks:");
    let b_over_k = comm(8, McastScheme::BoverK)?;
    let b_scheme = comm(8, McastScheme::B)?;
    let bk = comm(8, McastScheme::BK)?;
    println!(
        "  [{}] scheme B wire time >= 4x B/K at mp=8 (serialized sender)",
        if b_scheme > 4.0 * b_over_k { "ok" } else { "MISS" }
    );
    println!(
        "  [{}] scheme BK wire time within 2x of B/K (balanced, single phase)",
        if bk < 2.0 * b_over_k { "ok" } else { "MISS" }
    );
    let mem_bk = McastScheme::BK.staging_floats(b, 8, 4096);
    let mem_bok = McastScheme::BoverK.staging_floats(b, 8, 4096);
    println!(
        "  [{}] scheme BK staging >= 3x B/K at mp=8 (the paper's memory objection)",
        if mem_bk > 3 * mem_bok { "ok" } else { "MISS" }
    );
    Ok(())
}
