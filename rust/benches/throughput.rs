//! Bench: wall-clock training throughput — steps/sec for
//! {sequential, threaded, TCP multi-process} × {BSP, overlap} at N=4 —
//! the first real datapoint of the perf trajectory (`BENCH_throughput.json`).
//!
//! Every configuration trains the same (seed, shape) run on the native
//! backend, so besides throughput this bench is an acceptance gate: the
//! per-step loss bit patterns of every configuration must be identical
//! (the overlapped executor's fixed-order-reduce invariant). The CI
//! `bench-smoke` job runs it at reduced steps and fails on divergence.
//!
//! Timing comes from structured observability, not a wall clock around
//! the whole run: in-proc rows sum the `StepReport::wall_secs` of the
//! session's `StepCompleted` events, TCP rows take the critical path
//! over ranks of each rank's summed span time from the per-op tracing
//! layer's `metrics-opid<R>.json` snapshot. Construction and mesh
//! bring-up are therefore excluded everywhere, so the engines compare
//! on steady-state step cost. Every row also runs with the tracer on
//! and carries a per-phase breakdown (compute / MP comm / averaging
//! comm, plus per-phase byte totals — identical across engines by the
//! determinism contract) into the table and the JSON point.
//!
//! Besides the throughput table, every row reports per-kernel GFLOP/s
//! (the `obs::kernel_rows` analytic-FLOPs model folded against the
//! traced compute spans) and per-collective effective bandwidth in
//! MB/s (traced bytes over traced span time per [`CommCategory`]) —
//! both land in the JSON point so the CI regression gate can watch
//! kernels and collectives individually, not just end-to-end steps/sec.
//!
//! Flags: `--steps N` (default 12), `--workers N` (default 4),
//! `--mp K` (default 2), `--out PATH` (default `BENCH_throughput.json`).
//!
//! The TCP rows run one `TcpTransport` per thread inside this process
//! (the same rank driver `splitbrain worker` runs; `transport_parity`
//! covers real processes).

use std::collections::HashMap;
use std::net::TcpListener;
use std::path::PathBuf;

use splitbrain::api::{step_reports, CollectSink, SessionBuilder};
use splitbrain::comm::transport::TcpPeer;
use splitbrain::comm::CommCategory;
use splitbrain::coordinator::procdriver::{run_worker, ProcConfig, RunOutcome};
use splitbrain::coordinator::ExecEngine;
use splitbrain::obs::{kernel_rows, KernelRow, Metrics, OpKind};
use splitbrain::runtime::RuntimeClient;
use splitbrain::util::{Args, Table};

const SEED: u64 = 123;

fn builder(n: usize, mp: usize, engine: ExecEngine, overlap: bool) -> SessionBuilder {
    SessionBuilder::new()
        .workers(n)
        .mp(mp)
        .lr(0.02)
        .momentum(0.9)
        .clip_norm(1.0)
        .avg_period(4)
        .seed(SEED)
        .dataset_size(256)
        .engine(engine)
        .overlap(overlap)
}

/// One measured configuration: summed per-step wall seconds, per-step
/// mean loss bits, and the merged per-op metrics for the phase columns.
struct RunResult {
    name: &'static str,
    wall_secs: f64,
    /// Per-step cluster-mean loss bit patterns (the parity fingerprint).
    loss_bits: Vec<u64>,
    /// Merged (all ranks) per-op metrics of the traced run.
    metrics: Metrics,
}

impl RunResult {
    /// Per-rank mean seconds: (compute, MP comm, averaging comm).
    fn phase_secs(&self) -> (f64, f64, f64) {
        let m = &self.metrics;
        let ranks = m.ranks.max(1) as f64;
        let mp_us: u64 = [
            CommCategory::ModuloFwd,
            CommCategory::ModuloBwd,
            CommCategory::ShardFwd,
            CommCategory::ShardBwd,
        ]
        .iter()
        .map(|&c| m.phase_us(c))
        .sum();
        let avg_us: u64 = [CommCategory::DpAverage, CommCategory::ShardAverage]
            .iter()
            .map(|&c| m.phase_us(c))
            .sum();
        (
            m.compute_us() as f64 / 1e6 / ranks,
            mp_us as f64 / 1e6 / ranks,
            avg_us as f64 / 1e6 / ranks,
        )
    }
}

/// A rank's total traced span time in seconds — compute plus every
/// comm phase; the TCP rows' per-rank cost.
fn span_secs(m: &Metrics) -> f64 {
    let comm: u64 = CommCategory::ALL.iter().map(|&c| m.phase_us(c)).sum();
    (m.compute_us() + comm) as f64 / 1e6
}

/// The compute kinds reported as per-kernel GFLOP/s columns, in
/// step order.
const KERNEL_KINDS: [OpKind; 6] = [
    OpKind::FullStep,
    OpKind::ConvFwd,
    OpKind::FcFwd,
    OpKind::HeadStep,
    OpKind::FcBwd,
    OpKind::ConvBwdUpdate,
];

/// GFLOP/s for one kind out of a config's kernel rows; `None` when the
/// config never ran the kind (or recorded no time for it).
fn kind_gflops(rows: &[KernelRow], kind: OpKind) -> Option<f64> {
    rows.iter().find(|r| r.kind == kind).and_then(|r| r.gflops())
}

/// Effective per-rank bandwidth of one collective category in MB/s:
/// cluster-total traced bytes over cluster-summed span time (the
/// per-rank factors cancel). `None` when the category recorded no time.
fn category_mbps(m: &Metrics, c: CommCategory) -> Option<f64> {
    let us = m.phase_us(c);
    if us == 0 {
        None
    } else {
        Some(m.phase_bytes(c) as f64 / us as f64)
    }
}

/// `{:.2}` or `--` for an optional throughput figure.
fn fmt_opt(v: Option<f64>) -> String {
    match v {
        None => "--".to_string(),
        Some(x) => format!("{x:.2}"),
    }
}

/// JSON number or `null` for an optional throughput figure.
fn json_opt(v: Option<f64>) -> String {
    match v {
        None => "null".to_string(),
        Some(x) => format!("{x:.3}"),
    }
}

/// In-proc run (sequential or threaded engine) through the session
/// API: a collecting sink captures every `StepCompleted` event and the
/// row's wall time is the sum of the per-step timings; the session's
/// tracer supplies the phase breakdown.
fn run_inproc(
    rt: &RuntimeClient,
    name: &'static str,
    b: SessionBuilder,
    steps: usize,
) -> anyhow::Result<RunResult> {
    let mut session = b.steps(steps).trace(true).validate(rt)?.start()?;
    let sink = CollectSink::new();
    let events = sink.events();
    session.attach(Box::new(sink));
    session.run()?;
    let reports = step_reports(&events.borrow());
    anyhow::ensure!(reports.len() == steps, "{name}: {} step events, want {steps}", reports.len());
    let metrics = session.metrics().expect("trace(true) was set on the builder");
    Ok(RunResult {
        name,
        wall_secs: reports.iter().map(|r| r.wall_secs).sum(),
        loss_bits: reports.iter().map(|r| r.loss.to_bits()).collect(),
        metrics,
    })
}

/// In-process TCP run: one rank driver per thread over loopback
/// sockets, tracing on. Loss bits are recovered from the per-rank meta
/// dumps and averaged exactly like `StepMetrics::loss` (sum of
/// per-rank losses / n), so they are comparable bit-for-bit with the
/// in-proc engines; wall time is the critical path over ranks of each
/// rank's summed span time from its `metrics-opid<R>.json`.
fn run_tcp(name: &'static str, b: SessionBuilder, steps: usize) -> anyhow::Result<RunResult> {
    let c = b.steps(steps).cluster_config()?;
    let n = c.n_workers;
    // Reserve loopback ports (bind :0, record, release — the launcher's
    // documented, accepted race).
    let peers: Vec<TcpPeer> = {
        let listeners: Vec<TcpListener> =
            (0..n).map(|_| TcpListener::bind("127.0.0.1:0")).collect::<std::io::Result<_>>()?;
        listeners
            .iter()
            .enumerate()
            .map(|(opid, l)| {
                Ok(TcpPeer { opid, addr: l.local_addr()?.to_string() })
            })
            .collect::<std::io::Result<_>>()?
    };
    let out_dir = std::env::temp_dir().join(format!(
        "splitbrain-bench-{}-{name}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&out_dir);
    std::fs::create_dir_all(&out_dir)?;

    let outcomes: Vec<anyhow::Result<RunOutcome>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..n)
            .map(|opid| {
                let pc = ProcConfig {
                    cluster: c.clone(),
                    steps,
                    opid,
                    peers: peers.clone(),
                    artifacts: "artifacts".to_string(),
                    out_dir: Some(out_dir.clone()),
                    connect_timeout_ms: 30_000,
                    log_every: 0,
                    run_dir: None,
                    resume_step: 0,
                    trace: true,
                };
                s.spawn(move || run_worker(&pc))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .unwrap_or_else(|_| Err(anyhow::anyhow!("worker thread panicked")))
            })
            .collect()
    });
    for (opid, o) in outcomes.into_iter().enumerate() {
        match o? {
            RunOutcome::Completed => {}
            other => anyhow::bail!("tcp rank {opid} ended {other:?}, expected completion"),
        }
    }

    // step → sum of per-rank losses, rebuilt from the meta dumps.
    let mut sums: HashMap<usize, f64> = HashMap::new();
    for opid in 0..n {
        let meta = std::fs::read_to_string(out_dir.join(format!("opid{opid}.meta")))?;
        for line in meta.lines() {
            let mut it = line.split_whitespace();
            if it.next() == Some("loss") {
                let step: usize = it.next().unwrap().parse()?;
                let bits = u64::from_str_radix(it.next().unwrap(), 16)?;
                *sums.entry(step).or_insert(0.0) += f64::from_bits(bits);
            }
        }
    }
    // Timing + phase breakdown from the per-opid metrics snapshots.
    let mut wall_secs = 0.0f64;
    let mut parts = Vec::with_capacity(n);
    for opid in 0..n {
        let path = out_dir.join(format!("metrics-opid{opid}.json"));
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let m = Metrics::parse(&text)?;
        wall_secs = wall_secs.max(span_secs(&m));
        parts.push(m);
    }
    let metrics = Metrics::merge(&parts);
    let loss_bits = (1..=steps)
        .map(|s| (sums[&s] / n as f64).to_bits())
        .collect();
    let _ = std::fs::remove_dir_all(&out_dir);
    Ok(RunResult { name, wall_secs, loss_bits, metrics })
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    args.check_known(&["steps", "workers", "mp", "out", "bench", "compute-threads"])?;
    // Honor the flag like the CLI does (any value is bit-identical).
    splitbrain::runtime::set_compute_threads(args.usize_or("compute-threads", 1)?);
    let steps = args.usize_or("steps", 12)?;
    let n = args.usize_or("workers", 4)?;
    let mp = args.usize_or("mp", 2)?;
    let out_path = PathBuf::from(args.str_or("out", "BENCH_throughput.json"));
    let rt = RuntimeClient::load("artifacts")?;
    let batch = rt.manifest.batch;

    println!("=== throughput: N={n}, mp={mp}, B={batch}, {steps} steps per config ===\n");
    let results = vec![
        run_inproc(&rt, "sequential-bsp", builder(n, mp, ExecEngine::Sequential, false), steps)?,
        run_inproc(&rt, "threaded-bsp", builder(n, mp, ExecEngine::Threaded, false), steps)?,
        run_inproc(&rt, "threaded-overlap", builder(n, mp, ExecEngine::Threaded, true), steps)?,
        run_tcp("tcp-bsp", builder(n, mp, ExecEngine::Threaded, false), steps)?,
        run_tcp("tcp-overlap", builder(n, mp, ExecEngine::Threaded, true), steps)?,
    ];

    // Acceptance: every configuration's per-step losses bit-identical.
    let reference = &results[0];
    let mut bit_identical = true;
    for r in &results[1..] {
        if r.loss_bits != reference.loss_bits {
            bit_identical = false;
            eprintln!("DIVERGENCE: {} loss bits differ from {}", r.name, reference.name);
        }
    }

    let mut table = Table::new(vec![
        "config", "step-sum s", "steps/sec", "images/sec", "compute s", "mp-comm s", "avg-comm s",
    ]);
    for r in &results {
        let sps = steps as f64 / r.wall_secs;
        let (compute, mp_comm, avg_comm) = r.phase_secs();
        table.row(vec![
            r.name.to_string(),
            format!("{:.2}", r.wall_secs),
            format!("{:.3}", sps),
            format!("{:.1}", sps * (n * batch) as f64),
            format!("{compute:.2}"),
            format!("{mp_comm:.3}"),
            format!("{avg_comm:.3}"),
        ]);
    }
    println!("{}", table.render());
    println!("numerics bit-identical across all configs: {bit_identical}");

    // Per-kernel GFLOP/s and per-collective MB/s: the same transformed
    // net underlies every configuration, so one plan supplies the
    // FLOPs model for all rows.
    let plan = builder(n, mp, ExecEngine::Sequential, false).steps(steps).validate(&rt)?;
    let per_config_kernels: Vec<Vec<KernelRow>> = results
        .iter()
        .map(|r| kernel_rows(plan.transformed(), batch, &r.metrics))
        .collect::<anyhow::Result<_>>()?;

    let mut kheader: Vec<String> = vec!["config".into()];
    kheader.extend(KERNEL_KINDS.iter().map(|k| format!("{} GF/s", k.name())));
    let mut ktable = Table::new(kheader);
    for (r, krows) in results.iter().zip(&per_config_kernels) {
        let mut cells = vec![r.name.to_string()];
        cells.extend(KERNEL_KINDS.iter().map(|&k| fmt_opt(kind_gflops(krows, k))));
        ktable.row(cells);
    }
    println!("{}", ktable.render());

    let mut cheader: Vec<String> = vec!["config".into()];
    cheader.extend(CommCategory::ALL.iter().map(|c| format!("{c} MB/s")));
    let mut ctable = Table::new(cheader);
    for r in &results {
        let mut cells = vec![r.name.to_string()];
        cells.extend(CommCategory::ALL.iter().map(|&c| fmt_opt(category_mbps(&r.metrics, c))));
        ctable.row(cells);
    }
    println!("{}", ctable.render());

    // Emit the JSON trajectory point (hand-rolled: no serde offline).
    let mut json = String::from("{\n");
    json.push_str("  \"bench\": \"throughput\",\n");
    json.push_str("  \"timing_source\": \"per-step event stream + per-op metrics\",\n");
    json.push_str(&format!(
        "  \"workers\": {n},\n  \"mp\": {mp},\n  \"batch\": {batch},\n  \"steps\": {steps},\n"
    ));
    json.push_str(&format!("  \"bit_identical\": {bit_identical},\n"));
    json.push_str("  \"results\": [\n");
    for (i, (r, krows)) in results.iter().zip(&per_config_kernels).enumerate() {
        let sps = steps as f64 / r.wall_secs;
        let (compute, mp_comm, avg_comm) = r.phase_secs();
        let phase_bytes: Vec<String> = CommCategory::ALL
            .iter()
            .map(|&c| format!("\"{c}\": {}", r.metrics.phase_bytes(c)))
            .collect();
        let kernel_gflops: Vec<String> = KERNEL_KINDS
            .iter()
            .map(|&k| format!("\"{}\": {}", k.name(), json_opt(kind_gflops(krows, k))))
            .collect();
        let collective_mbps: Vec<String> = CommCategory::ALL
            .iter()
            .map(|&c| format!("\"{c}\": {}", json_opt(category_mbps(&r.metrics, c))))
            .collect();
        json.push_str(&format!(
            "    {{\"config\": \"{}\", \"wall_secs\": {:.4}, \"steps_per_sec\": {:.4}, \
             \"images_per_sec\": {:.2}, \"compute_secs_rank\": {:.4}, \
             \"mp_comm_secs_rank\": {:.4}, \"avg_comm_secs_rank\": {:.4}, \
             \"phase_bytes\": {{{}}}, \"kernel_gflops\": {{{}}}, \
             \"collective_mbps\": {{{}}}}}{}\n",
            r.name,
            r.wall_secs,
            sps,
            sps * (n * batch) as f64,
            compute,
            mp_comm,
            avg_comm,
            phase_bytes.join(", "),
            kernel_gflops.join(", "),
            collective_mbps.join(", "),
            if i + 1 < results.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write(&out_path, &json)?;
    println!("wrote {}", out_path.display());

    if !bit_identical {
        anyhow::bail!("overlap/BSP numerics diverged — the fixed-order-reduce invariant is broken");
    }
    println!("throughput bench OK");
    Ok(())
}
