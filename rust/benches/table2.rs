//! Bench: regenerate **Table 2** — CIFAR-10 throughput (images/sec)
//! over machines in {1,2,4,8,16,32} x DP/MP combinations.
//!
//! Calibrated mode (default): per-artifact compute times measured on
//! this host, comm charged by the α–β InfiniBand model; all 15 rows in
//! around a minute. `--numeric` runs full numeric training steps
//! instead (slow at 32 workers).
//!
//! Shape expectations vs the paper (absolute numbers differ — 2016 Xeon
//! vs XLA:CPU): pure DP scales ~linearly; mp=2 tracks DP closely;
//! mp=N collapses (paper: 520 vs 966 img/s at 8 machines); at 32
//! machines throughput orders as mp=1 > mp=2 > mp=4 > mp=8.

use splitbrain::bench::{table2, table2_paper, Fidelity};
use splitbrain::api::SessionBuilder;
use splitbrain::runtime::RuntimeClient;

fn main() -> anyhow::Result<()> {
    let numeric = std::env::args().any(|a| a == "--numeric");
    let fidelity = if numeric {
        Fidelity::Numeric { steps: 3 }
    } else {
        Fidelity::Calibrated
    };
    let rt = RuntimeClient::load("artifacts")?;
    // Benches share the builder's defaults (the one ClusterConfig source).
    let base = SessionBuilder::new().cluster_config()?;

    println!("=== Table 2: CIFAR-10 throughputs in combinations of DP and MP ({fidelity:?}) ===\n");
    let (table, raw) = table2(&rt, fidelity, &base)?;
    println!("{}", table.render());

    // The paper's 2016 GASPI/BSP software regime (per-phase overhead
    // dominates the wire volume — see NetModel::paper_2016 docs): this
    // is the regime where the paper's mp=8 collapse appears.
    let paper_base = SessionBuilder::new()
        .net(splitbrain::comm::NetModel::paper_2016())
        .cluster_config()?;
    println!("=== same sweep under the paper-2016 software-overhead regime ===\n");
    let (ptable, praw) = table2(&rt, fidelity, &paper_base)?;
    println!("{}", ptable.render());

    // Shape checks the paper's table implies (reported, not asserted,
    // so a slow host still produces the full table).
    let ips = |m: usize, dp: usize, mp: usize| {
        raw.iter()
            .find(|r| (r.0, r.1, r.2) == (m, dp, mp))
            .map(|r| r.3)
            .unwrap()
    };
    let pips = |m: usize, dp: usize, mp: usize| {
        praw.iter()
            .find(|r| (r.0, r.1, r.2) == (m, dp, mp))
            .map(|r| r.3)
            .unwrap()
    };
    let paper: std::collections::HashMap<_, _> = table2_paper().into_iter().collect();
    let mut checks = vec![];
    checks.push(("DP scales >= 3x from 1 to 4 machines", ips(4, 4, 1) > 3.0 * ips(1, 1, 1)));
    checks.push(("mp=2 within 15% of pure DP at 8 machines", ips(8, 4, 2) > 0.85 * ips(8, 8, 1)));
    // The collapse magnitude is attenuated on this host: our compute
    // per step is ~4x the 2016 testbed's, diluting the fixed per-phase
    // software overheads that drove the paper's 0.54x.
    checks.push(("mp=8 visibly collapses at 8 machines under paper-2016 regime (paper: 0.54x)",
        pips(8, 1, 8) < 0.85 * pips(8, 8, 1)));
    checks.push(("32-machine ordering mp1 > mp2 > mp4 > mp8 (paper-2016 regime)",
        pips(32, 32, 1) > pips(32, 16, 2)
            && pips(32, 16, 2) > pips(32, 8, 4)
            && pips(32, 8, 4) > pips(32, 8, 8)));
    println!("shape checks (paper-implied orderings):");
    let mut fails = 0;
    for (desc, ok) in checks {
        println!("  [{}] {desc}", if ok { "ok" } else { "MISS" });
        fails += (!ok) as usize;
    }

    // Side-by-side normalized comparison.
    println!("\nnormalized speedup vs 1 machine (ours | paper):");
    for (m, dp, mp) in [(2, 2, 1), (4, 4, 1), (8, 8, 1), (16, 16, 1), (32, 32, 1)] {
        println!(
            "  {m:>2} machines DP: {:.2}x | {:.2}x",
            ips(m, dp, mp) / ips(1, 1, 1),
            paper[&(m, dp, mp)] / paper[&(1, 1, 1)]
        );
    }
    if fails > 0 {
        println!("\nWARNING: {fails} shape check(s) missed on this host");
    }
    Ok(())
}
