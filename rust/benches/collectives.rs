//! Bench: collective-algorithm byte volumes and modeled wire times —
//! the data behind the Fig. 7b overhead discussion of naive vs
//! scalable group communication.
//!
//! For k ∈ {2, 4, 8} ranks and a model-shaped flat buffer, measures
//! (on the real fabric, exact byte counters) the per-rank bytes moved
//! by the naive all-to-all, ring, and recursive halving/doubling
//! allreduce, plus the ring vs naive column collectives, and checks the
//! ring allreduce achieves the bandwidth-optimal 2·(k−1)/k·V per rank —
//! i.e. it moves at most a 2·(k−1)/k fraction of V where the naive
//! exchange moves (k−1)·V.

use splitbrain::comm::collective::{
    allgather_cols, allgather_cols_algo, allreduce_mean, reduce_scatter_cols,
    reduce_scatter_cols_algo, CollectiveAlgo,
};
use splitbrain::comm::fabric::{Fabric, Tag};
use splitbrain::comm::NetModel;
use splitbrain::runtime::HostTensor;
use splitbrain::util::{Rng, Table, Timer};

/// 1 Mi floats (4 MiB). The byte *ratios* are buffer-size-invariant,
/// and the naive all-to-all at k=8 would otherwise stage
/// 8·7·28 MB ≈ 1.5 GB of the 7.0M-param model buffer in mailboxes.
const MODEL_FLOATS: usize = 1 << 20;

fn allreduce_bytes(algo: CollectiveAlgo, k: usize, floats: usize) -> (u64, f64) {
    let fabric = Fabric::new(k);
    let group: Vec<usize> = (0..k).collect();
    let mut rng = Rng::new(7);
    let mut bufs: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(floats, 0.1)).collect();
    let t = Timer::start();
    allreduce_mean(algo, &fabric, &group, &mut bufs, 1).unwrap();
    let host_secs = t.elapsed_secs();
    assert!(fabric.drained());
    let worst = (0..k).map(|r| fabric.bytes_from(r)).max().unwrap();
    std::hint::black_box(&bufs);
    (worst, host_secs)
}

fn main() -> anyhow::Result<()> {
    let net = NetModel::default();
    let v_bytes = (MODEL_FLOATS * 4) as f64;

    println!("=== Collective algorithms: allreduce of a 4 MiB model buffer ===\n");
    let mut t = Table::new(vec![
        "k", "algo", "bytes/rank MB", "x of V", "bound 2(k-1)/k", "modeled ms", "host ms",
    ]);
    let mut all_ok = true;
    for k in [2usize, 4, 8] {
        let bound = 2.0 * (k as f64 - 1.0) / k as f64;
        for algo in [CollectiveAlgo::Naive, CollectiveAlgo::Ring, CollectiveAlgo::Rhd] {
            let (worst, host_secs) = allreduce_bytes(algo, k, MODEL_FLOATS);
            let frac = worst as f64 / v_bytes;
            let modeled = match algo {
                CollectiveAlgo::Naive => net.naive_allreduce(k, v_bytes as u64),
                CollectiveAlgo::Ring => net.ring_allreduce(k, v_bytes as u64),
                CollectiveAlgo::Rhd => net.rhd_allreduce(k, v_bytes as u64),
            };
            t.row(vec![
                k.to_string(),
                algo.to_string(),
                format!("{:.2}", worst as f64 / 1e6),
                format!("{frac:.3}"),
                format!("{bound:.3}"),
                format!("{:.3}", modeled * 1e3),
                format!("{:.1}", host_secs * 1e3),
            ]);
            // The acceptance bound: ring (and rhd) move at most the
            // bandwidth-optimal 2·(k-1)/k·V per rank; naive moves
            // (k-1)·V.
            if algo != CollectiveAlgo::Naive {
                let ok = worst as f64 <= bound * v_bytes * 1.01;
                all_ok &= ok;
                if !ok {
                    println!("MISS: {algo} at k={k} moved {frac:.3}·V > {bound:.3}·V");
                }
            }
        }
    }
    println!("{}", t.render());

    println!("=== Column collectives: ring vs naive (B=32 shard exchange shapes) ===\n");
    let mut t = Table::new(vec!["k", "op", "naive B/rank", "ring B/rank", "equal"]);
    let mut rng = Rng::new(9);
    for k in [2usize, 4, 8] {
        let group: Vec<usize> = (0..k).collect();
        let part_w = 1024 / k;
        let rows = 32;
        let parts: Vec<HostTensor> = (0..k)
            .map(|_| HostTensor::f32(vec![rows, part_w], rng.normal_vec(rows * part_w, 1.0)))
            .collect();
        let f1 = Fabric::new(k);
        allgather_cols(&f1, &group, &parts, Tag::new(1, 0, 0))?;
        let f2 = Fabric::new(k);
        allgather_cols_algo(CollectiveAlgo::Ring, &f2, &group, &parts, Tag::new(1, 0, 0))?;
        t.row(vec![
            k.to_string(),
            "allgather".into(),
            f1.bytes_from(0).to_string(),
            f2.bytes_from(0).to_string(),
            (f1.bytes_from(0) == f2.bytes_from(0)).to_string(),
        ]);
        all_ok &= f1.bytes_from(0) == f2.bytes_from(0);

        let widths = vec![part_w; k];
        let fulls: Vec<HostTensor> = (0..k)
            .map(|_| HostTensor::f32(vec![rows, 1024], rng.normal_vec(rows * 1024, 1.0)))
            .collect();
        let f1 = Fabric::new(k);
        reduce_scatter_cols(&f1, &group, &fulls, &widths, Tag::new(2, 0, 0))?;
        let f2 = Fabric::new(k);
        reduce_scatter_cols_algo(
            CollectiveAlgo::Ring,
            &f2,
            &group,
            &fulls,
            &widths,
            Tag::new(2, 0, 0),
        )?;
        t.row(vec![
            k.to_string(),
            "reduce-scatter".into(),
            f1.bytes_from(0).to_string(),
            f2.bytes_from(0).to_string(),
            (f1.bytes_from(0) == f2.bytes_from(0)).to_string(),
        ]);
        all_ok &= f1.bytes_from(0) == f2.bytes_from(0);
    }
    println!("{}", t.render());
    println!("reading: the ring/rhd allreduce hits the 2·(k-1)/k·V bandwidth");
    println!("optimum the naive all-to-all misses by a factor of k/2; the column");
    println!("collectives move identical bytes either way — ring trades per-phase");
    println!("latency (k-1 serialized rounds) for single-sender congestion.");
    anyhow::ensure!(all_ok, "collective volume bound violated — see MISS lines above");
    println!("\ncollectives bench OK");
    Ok(())
}
