//! Bench: regenerate **Fig. 7c** — throughput trade-off with per-worker
//! memory usage across MP group sizes on eight machines.
//!
//! The paper's claims: pure DP is the throughput ceiling with the most
//! memory; full MP (Krizhevsky'14, mp=N) is the floor with the least;
//! GMP exposes the configurable frontier in between while beating
//! full-MP throughput.

use splitbrain::bench::{fig7c, Fidelity};
use splitbrain::api::SessionBuilder;
use splitbrain::runtime::RuntimeClient;

fn main() -> anyhow::Result<()> {
    let numeric = std::env::args().any(|a| a == "--numeric");
    let fidelity = if numeric {
        Fidelity::Numeric { steps: 3 }
    } else {
        Fidelity::Calibrated
    };
    let rt = RuntimeClient::load("artifacts")?;
    // Benches share the builder's defaults (the one ClusterConfig source).
    let base = SessionBuilder::new().cluster_config()?;

    println!("=== Fig. 7c: throughput vs memory, 8 machines ({fidelity:?}) ===\n");
    let (table, raw) = fig7c(&rt, fidelity, &base)?;
    println!("{}", table.render());

    println!("frontier (memory down => throughput down, monotone):");
    let mut ok = true;
    for w in raw.windows(2) {
        let (mp0, mem0, ips0) = w[0];
        let (mp1, mem1, ips1) = w[1];
        let mono = mem1 < mem0 && ips1 <= ips0 * 1.05;
        ok &= mono;
        println!(
            "  mp {mp0} -> {mp1}: memory {:.2} -> {:.2} MB, throughput {:.0} -> {:.0} img/s [{}]",
            mem0, mem1, ips0, ips1,
            if mono { "ok" } else { "MISS" }
        );
    }
    println!(
        "\nmemory saving at mp=8: {:.1}% (paper abstract: up to 67%)",
        (1.0 - raw[3].1 / raw[0].1) * 100.0
    );
    if !ok {
        println!("WARNING: frontier not monotone on this host");
    }
    Ok(())
}
