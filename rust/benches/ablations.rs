//! Ablation benches for the design choices DESIGN.md calls out
//! (calibrated mode — analytic comm over the measured compute):
//!
//! 1. **Network fabric**: the paper's 56 Gbps InfiniBand vs commodity
//!    10 GbE — where does the GMP sweet spot move when α/β degrade?
//! 2. **DP exchange topology** (§4: "peer-to-peer or parameter server"):
//!    ring vs full-mesh vs Halton vs parameter-server averaging cost.
//! 3. **Averaging period**: comm amortization vs staleness proxy.
//! 4. **CCR threshold**: what the Listing-1 decision would do to
//!    per-worker memory if FC2 were force-partitioned or FC1 excluded.

use splitbrain::comm::{CommGraph, NetModel};
use splitbrain::coordinator::{GmpTopology, StepSchedule};
use splitbrain::model::{partition_network, vgg11, PartitionConfig};
use splitbrain::runtime::RuntimeClient;
use splitbrain::train::MemoryReport;
use splitbrain::util::Table;

fn main() -> anyhow::Result<()> {
    let rt = RuntimeClient::load("artifacts")?;

    // --- 1. fabric ablation -------------------------------------------------
    println!("=== Ablation 1: InfiniBand vs 10 GbE (8 machines, per-step MP comm) ===\n");
    let mut t = Table::new(vec!["mp", "IB 40Gbps ms", "10GbE ms", "slowdown"]);
    for mp in [1usize, 2, 4, 8] {
        let net = partition_network(
            &vgg11(),
            vec![32, 32, 3],
            &PartitionConfig { mp, ..Default::default() },
        )?;
        let topo = GmpTopology::new(8, mp)?;
        let sched = StepSchedule::compile_opts(&net, topo, &rt.manifest, true)?;
        let ib = sched.mp_comm_secs(&NetModel::default()) * 1e3;
        let eth = sched.mp_comm_secs(&NetModel::ethernet_10g()) * 1e3;
        t.row(vec![
            mp.to_string(),
            format!("{ib:.3}"),
            format!("{eth:.3}"),
            if ib > 0.0 { format!("{:.1}x", eth / ib) } else { "-".into() },
        ]);
    }
    println!("{}", t.render());
    println!("reading: on 10 GbE the MP exchange cost grows ~4x; the paper's");
    println!("GMP knob matters even more on commodity fabrics.\n");

    // --- 2. topology ablation ----------------------------------------------
    println!("=== Ablation 2: DP parameter-exchange topology (7.0M params) ===\n");
    let bytes = 6_990_666u64 * 4;
    let mut t = Table::new(vec!["workers", "ring ms", "full-mesh ms", "halton ms", "param-server ms"]);
    let net = NetModel::default();
    for n in [2usize, 4, 8, 16, 32] {
        t.row(vec![
            n.to_string(),
            format!("{:.2}", CommGraph::Ring.exchange_time(&net, n, bytes) * 1e3),
            format!("{:.2}", CommGraph::FullMesh.exchange_time(&net, n, bytes) * 1e3),
            format!("{:.2}", CommGraph::Halton.exchange_time(&net, n, bytes) * 1e3),
            format!("{:.2}", CommGraph::ParamServer.exchange_time(&net, n, bytes) * 1e3),
        ]);
    }
    println!("{}", t.render());
    println!("reading: ring stays flat (bandwidth-optimal); the central PS and");
    println!("full mesh blow up with N — the paper's motivation for p2p graphs.\n");

    // --- 3. averaging period ------------------------------------------------
    println!("=== Ablation 3: model-averaging period (8 machines, mp=2) ===\n");
    let netm = NetModel::default();
    let vnet = partition_network(
        &vgg11(),
        vec![32, 32, 3],
        &PartitionConfig { mp: 2, ..Default::default() },
    )?;
    let topo = GmpTopology::new(8, 2)?;
    // Ring averaging: what the cluster actually runs by default.
    let sched = StepSchedule::compile_with_algo(
        &vnet,
        topo,
        &rt.manifest,
        true,
        splitbrain::coordinator::McastScheme::BoverK,
        splitbrain::comm::CollectiveAlgo::Ring,
    )?;
    let avg_ms = sched.avg_comm_secs(&netm) * 1e3;
    let mut t = Table::new(vec!["avg period", "avg ms/step", "vs period=1"]);
    for period in [1usize, 5, 10, 50, 100] {
        t.row(vec![
            period.to_string(),
            format!("{:.3}", avg_ms / period as f64),
            format!("{:.1}%", 100.0 / period as f64),
        ]);
    }
    println!("{}", t.render());
    println!("reading: the period divides the DP exchange cost linearly; the paper");
    println!("trades it against replica staleness (§2's bounded-staleness argument).\n");

    // --- 4. CCR threshold ---------------------------------------------------
    println!("=== Ablation 4: CCR threshold -> partition set and memory (mp=4) ===\n");
    let mut t = Table::new(vec!["ccr threshold", "sharded linears", "per-worker MB", "note"]);
    for (thr, note) in [
        (0.0, "everything divisible splits (FC2 kept: 10 % 4 != 0)"),
        (50.0, "default: FC0+FC1"),
        (400.0, "only FC0 clears the bar"),
        (1e9, "nothing splits = pure DP"),
    ] {
        let net = partition_network(
            &vgg11(),
            vec![32, 32, 3],
            &PartitionConfig { mp: 4, ccr_threshold: thr },
        )?;
        let mem = MemoryReport::of(&net, rt.manifest.batch);
        t.row(vec![
            format!("{thr}"),
            format!("{:?}", net.sharded_linears()),
            format!("{:.2}", mem.param_mb()),
            note.to_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}
