//! Bench: serving throughput and latency under open-loop load —
//! req/s and p50/p95/p99 for {mp=1, mp=2, mp=2 × 2 replicas}, the
//! serving trajectory point (`BENCH_serving.json`).
//!
//! Every configuration hosts the same seeded model behind the real
//! frontend (TCP framing, deadline-aware batching, bounded admission)
//! and drives it with the open-loop Poisson load generator, so the
//! measured numbers include everything a client sees: framing, queue
//! wait, batch close, the sharded forward, and the reply path. Batch
//! occupancy comes from the frontend's own log₂ histogram.
//!
//! Flags: `--requests N` (default 1000 — point it at 1000000 for the
//! full load soak), `--rate R` req/s (default 500), `--replicas N`
//! (default 2, third config only), `--deadline-ms D` (default 0),
//! `--out PATH` (default `BENCH_serving.json`).
//!
//! The CI `serving-smoke` job runs it at reduced request counts and
//! `tools/bench_compare.py` gates `reqs_per_sec` / `p99_ms` against
//! the committed baseline.

use std::path::PathBuf;

use splitbrain::api::RunManifest;
use splitbrain::coordinator::ClusterConfig;
use splitbrain::serve::{run_loadgen, LoadgenConfig, ServeConfig, ServeModel, Server};
use splitbrain::util::{Args, Table};

const SEED: u64 = 123;

fn fresh_model(mp: usize) -> anyhow::Result<ServeModel> {
    let cfg = ClusterConfig { n_workers: mp.max(1), mp, seed: SEED, ..Default::default() };
    ServeModel::from_manifest_text(&RunManifest::from_config(&cfg, 1).to_json())
}

struct BenchRow {
    config: String,
    report: splitbrain::serve::LoadgenReport,
    batches: usize,
    occupancy_json: String,
}

fn run_config(
    config: &str,
    mp: usize,
    replicas: usize,
    requests: usize,
    rate: f64,
    deadline_ms: u32,
) -> anyhow::Result<BenchRow> {
    let server = Server::start(
        fresh_model(mp)?,
        ServeConfig { replicas, ..ServeConfig::default() },
    )?;
    let report = run_loadgen(&LoadgenConfig {
        addr: server.addr().to_string(),
        rate,
        requests,
        deadline_ms,
        seed: SEED,
    })?;
    let stats = server.stats();
    let batches = stats.batches.load(std::sync::atomic::Ordering::SeqCst);
    let occupancy_json = stats.occupancy.lock().unwrap().to_json();
    server.shutdown();
    anyhow::ensure!(
        report.wrong_shape == 0,
        "{config}: {} wrong-shape replies — serving is broken, not slow",
        report.wrong_shape
    );
    Ok(BenchRow { config: config.to_string(), report, batches, occupancy_json })
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    args.check_known(&[
        "requests", "rate", "replicas", "deadline-ms", "out", "bench", "compute-threads",
    ])?;
    splitbrain::runtime::set_compute_threads(args.usize_or("compute-threads", 1)?);
    let requests = args.usize_or("requests", 1000)?;
    let rate = args.f32_or("rate", 500.0)? as f64;
    let replicas = args.usize_or("replicas", 2)?.max(1);
    let deadline_ms = args.u64_or("deadline-ms", 0)? as u32;
    let out_path = PathBuf::from(args.str_or("out", "BENCH_serving.json"));

    println!("=== serving: {requests} requests per config, {rate} req/s offered ===\n");
    let rows = vec![
        run_config("serve_mp1", 1, 1, requests, rate, deadline_ms)?,
        run_config("serve_mp2", 2, 1, requests, rate, deadline_ms)?,
        run_config(
            &format!("serve_mp2_r{replicas}"),
            2,
            replicas,
            requests,
            rate,
            deadline_ms,
        )?,
    ];

    let mut table = Table::new(vec![
        "config", "replies", "rejected", "req/s", "p50 ms", "p95 ms", "p99 ms", "batches",
        "occ avg",
    ]);
    for r in &rows {
        let rep = &r.report;
        let rejected = rep.rejected_queue + rep.rejected_deadline + rep.rejected_draining;
        let occ = if r.batches > 0 { rep.replies as f64 / r.batches as f64 } else { 0.0 };
        table.row(vec![
            r.config.clone(),
            rep.replies.to_string(),
            rejected.to_string(),
            format!("{:.1}", rep.reqs_per_sec),
            format!("{:.2}", rep.p50_ms),
            format!("{:.2}", rep.p95_ms),
            format!("{:.2}", rep.p99_ms),
            r.batches.to_string(),
            format!("{occ:.1}"),
        ]);
    }
    println!("{}", table.render());

    // Emit the JSON trajectory point (hand-rolled: no serde offline).
    // Row schema is `LoadgenReport::bench_row` — what the regression
    // gate reads — plus the frontend-side occupancy histogram.
    let mut json = String::from("{\n  \"bench\": \"serving\",\n");
    json.push_str(&format!(
        "  \"requests\": {requests},\n  \"offered_rate\": {rate},\n  \"results\": [\n"
    ));
    for (i, r) in rows.iter().enumerate() {
        let row = r.report.bench_row(&r.config);
        // Graft the occupancy histogram into the row object.
        let row = format!(
            "{}, \"batches\": {}, \"occupancy\": {}}}",
            &row[..row.len() - 1],
            r.batches,
            r.occupancy_json
        );
        json.push_str(&format!(
            "    {row}{}\n",
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write(&out_path, &json)?;
    println!("wrote {}", out_path.display());
    println!("serving bench OK");
    Ok(())
}
