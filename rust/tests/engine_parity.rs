//! Engine parity: the threaded execution engine (one scoped thread per
//! worker, blocking fabric takes, BSP barrier) must reproduce the
//! sequential reference engine **bit-for-bit** — same losses, same
//! parameters — over multi-step training runs, across topologies,
//! schemes and collective algorithms.
//!
//! Runs on the built-in native backend (no artifacts needed).

use std::sync::Arc;

use splitbrain::api::SessionBuilder;
use splitbrain::comm::CollectiveAlgo;
use splitbrain::coordinator::{Cluster, ClusterConfig, ExecEngine, McastScheme};
use splitbrain::data::{Dataset, SyntheticCifar};
use splitbrain::runtime::RuntimeClient;

/// All configs come from the typed builder (the one `ClusterConfig`
/// constructor); tests tweak the returned builder before resolving.
fn builder(n: usize, mp: usize, engine: ExecEngine, algo: CollectiveAlgo) -> SessionBuilder {
    SessionBuilder::new()
        .workers(n)
        .mp(mp)
        .lr(0.02)
        .momentum(0.9)
        .clip_norm(1.0)
        .avg_period(4)
        .seed(123)
        .dataset_size(256)
        .engine(engine)
        .collectives(algo)
}

fn cfg(n: usize, mp: usize, engine: ExecEngine, algo: CollectiveAlgo) -> ClusterConfig {
    builder(n, mp, engine, algo).cluster_config().unwrap()
}

fn dataset() -> Arc<dyn Dataset> {
    Arc::new(SyntheticCifar::new(256, 123))
}

/// Every worker's every parameter, flattened (exact f32 payloads).
fn all_params(c: &Cluster) -> Vec<Vec<f32>> {
    let mut out = Vec::new();
    for rank in 0..c.cfg.n_workers {
        let w = c.worker(rank);
        for t in w.conv_params.iter().chain(w.fc_params.iter()) {
            out.push(t.as_f32().to_vec());
        }
    }
    out
}

fn assert_parity(mut a: Cluster, mut b: Cluster, steps: usize, what: &str) {
    for step in 1..=steps {
        let ma = a.step().unwrap();
        let mb = b.step().unwrap();
        assert_eq!(
            ma.loss.to_bits(),
            mb.loss.to_bits(),
            "{what}: loss diverged at step {step}: {} vs {}",
            ma.loss,
            mb.loss
        );
    }
    let pa = all_params(&a);
    let pb = all_params(&b);
    assert_eq!(pa.len(), pb.len());
    for (i, (x, y)) in pa.iter().zip(pb.iter()).enumerate() {
        assert_eq!(x, y, "{what}: parameter tensor {i} diverged");
    }
}

/// The headline acceptance check: hybrid (n=2, mp=2) training for 10
/// steps — two averaging events included — is bit-identical between
/// engines.
#[test]
fn threaded_matches_sequential_hybrid_10_steps() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let seq = Cluster::with_dataset(
        &rt,
        cfg(2, 2, ExecEngine::Sequential, CollectiveAlgo::Ring),
        dataset(),
    )
    .unwrap();
    let thr = Cluster::with_dataset(
        &rt,
        cfg(2, 2, ExecEngine::Threaded, CollectiveAlgo::Ring),
        dataset(),
    )
    .unwrap();
    assert_parity(seq, thr, 10, "hybrid n=2 mp=2");
}

/// Pure-DP path (fused full_step per worker) with averaging.
#[test]
fn threaded_matches_sequential_pure_dp() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let ca = builder(2, 1, ExecEngine::Sequential, CollectiveAlgo::Ring)
        .avg_period(2)
        .cluster_config()
        .unwrap();
    let cb = builder(2, 1, ExecEngine::Threaded, CollectiveAlgo::Ring)
        .avg_period(2)
        .cluster_config()
        .unwrap();
    let seq = Cluster::with_dataset(&rt, ca, dataset()).unwrap();
    let thr = Cluster::with_dataset(&rt, cb, dataset()).unwrap();
    assert_parity(seq, thr, 2, "pure DP n=2");
}

/// Multi-group topology (n=4, mp=2: replicated + shard averaging) for
/// every collective algorithm.
#[test]
fn threaded_matches_sequential_all_collective_algos() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    for algo in [CollectiveAlgo::Naive, CollectiveAlgo::Ring, CollectiveAlgo::Rhd] {
        // avg_period 1: average every step, exercising both rings.
        let ca = builder(4, 2, ExecEngine::Sequential, algo)
            .avg_period(1)
            .cluster_config()
            .unwrap();
        let cb = builder(4, 2, ExecEngine::Threaded, algo)
            .avg_period(1)
            .cluster_config()
            .unwrap();
        let seq = Cluster::with_dataset(&rt, ca, dataset()).unwrap();
        let thr = Cluster::with_dataset(&rt, cb, dataset()).unwrap();
        assert_parity(seq, thr, 1, &format!("n=4 mp=2 algo={algo}"));
    }
}

/// Non-power-of-two DP averaging (3 ranks) under recursive
/// halving/doubling.
#[test]
fn threaded_matches_sequential_rhd_non_pow2() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let ca = builder(3, 1, ExecEngine::Sequential, CollectiveAlgo::Rhd)
        .avg_period(1)
        .cluster_config()
        .unwrap();
    let cb = builder(3, 1, ExecEngine::Threaded, CollectiveAlgo::Rhd)
        .avg_period(1)
        .cluster_config()
        .unwrap();
    let seq = Cluster::with_dataset(&rt, ca, dataset()).unwrap();
    let thr = Cluster::with_dataset(&rt, cb, dataset()).unwrap();
    assert_parity(seq, thr, 2, "pure DP n=3 rhd");
}

/// The BK scheme's distinct artifact set and gradient rescale survive
/// the threaded engine.
#[test]
fn threaded_matches_sequential_bk_scheme() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let ca = builder(2, 2, ExecEngine::Sequential, CollectiveAlgo::Ring)
        .scheme(McastScheme::BK)
        .cluster_config()
        .unwrap();
    let cb = builder(2, 2, ExecEngine::Threaded, CollectiveAlgo::Ring)
        .scheme(McastScheme::BK)
        .cluster_config()
        .unwrap();
    let seq = Cluster::with_dataset(&rt, ca, dataset()).unwrap();
    let thr = Cluster::with_dataset(&rt, cb, dataset()).unwrap();
    assert_parity(seq, thr, 1, "n=2 mp=2 scheme=BK");
}

/// The threaded engine drains the fabric and reproduces the schedule's
/// analytic per-rank byte volumes, exactly like the sequential one.
#[test]
fn threaded_fabric_bytes_match_schedule() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let mut c = Cluster::with_dataset(
        &rt,
        cfg(2, 2, ExecEngine::Threaded, CollectiveAlgo::Ring),
        dataset(),
    )
    .unwrap();
    c.step().unwrap(); // non-averaging step
    let (max_rank_bytes, _total) = c.last_fabric_bytes;
    assert_eq!(max_rank_bytes, c.schedule.mp_bytes_per_member());
}
