//! Wire-protocol robustness + `HostTensor` byte-codec property tests.
//!
//! What is proven here:
//! * the tensor byte codec round-trips **bit-exactly** for every dtype,
//!   empty/odd/high-rank shapes, and non-finite f32 payloads (seeded
//!   property sweep);
//! * malformed frames — truncations at every byte boundary, bad magic,
//!   version mismatches, oversized length prefixes, flipped bits — are
//!   **typed errors**, never panics, and a hostile length prefix cannot
//!   trigger an allocation beyond the enforced frame bound;
//! * the stream reader agrees with the slice decoder and treats only
//!   frame-boundary EOF as clean.

use splitbrain::comm::fabric::Tag;
use splitbrain::comm::transport::wire::{
    self, crc32, decode_frame, encode_frame, Frame, FrameKind, Message, WireError, HEADER_LEN,
    MAX_FRAME_PAYLOAD, WIRE_MAGIC, WIRE_VERSION,
};
use splitbrain::runtime::{DType, HostTensor};
use splitbrain::util::Rng;

// ---------------------------------------------------------------------------
// HostTensor byte codec: property sweep.

/// Deterministic shape generator: rank 0..=4, dims 0..=5 (so empty
/// tensors — any dim 0 — and scalars — rank 0 — both occur often).
fn random_shape(rng: &mut Rng) -> Vec<usize> {
    let rank = rng.below(5);
    (0..rank).map(|_| rng.below(6)).collect()
}

/// Interesting f32 bit patterns: normals, subnormals, NaN payloads,
/// infinities, signed zeros.
fn random_f32_bits(rng: &mut Rng) -> u32 {
    match rng.below(8) {
        0 => f32::NAN.to_bits(),
        1 => f32::INFINITY.to_bits(),
        2 => f32::NEG_INFINITY.to_bits(),
        3 => (-0.0f32).to_bits(),
        4 => 0x7fc0_0000 | (rng.next_u64() as u32 & 0x003f_ffff), // NaN payloads
        5 => rng.next_u64() as u32 & 0x007f_ffff,                 // subnormals
        _ => (rng.normal() * 1e3).to_bits(),
    }
}

#[test]
fn tensor_codec_roundtrips_bit_exactly_all_dtypes_and_shapes() {
    let mut rng = Rng::new(0x7E57_0001);
    for case in 0..500 {
        let shape = random_shape(&mut rng);
        let numel: usize = shape.iter().product();
        let t = if case % 2 == 0 {
            let data: Vec<f32> =
                (0..numel).map(|_| f32::from_bits(random_f32_bits(&mut rng))).collect();
            HostTensor::f32(shape.clone(), data)
        } else {
            let data: Vec<i32> = (0..numel).map(|_| rng.next_u64() as i32).collect();
            HostTensor::i32(shape.clone(), data)
        };
        let bytes = t.to_bytes();
        let back = HostTensor::from_bytes(&bytes)
            .unwrap_or_else(|e| panic!("case {case} shape {shape:?} failed decode: {e}"));
        assert_eq!(back.dtype, t.dtype, "case {case}");
        assert_eq!(back.shape, t.shape, "case {case}");
        match t.dtype {
            DType::F32 => {
                for (a, b) in t.as_f32().iter().zip(back.as_f32()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "case {case}: f32 bits drifted");
                }
            }
            DType::I32 => assert_eq!(t.as_i32(), back.as_i32(), "case {case}"),
        }
        // And through a whole wire frame too.
        let msg = Message::Tensor {
            epoch: case as u32,
            step: 1,
            src: 0,
            flags: 0,
            tag: Tag::new(1, case % 7, 0),
            tensor: t,
        };
        let framed = msg.encode();
        let (frame, used) = decode_frame(&framed).expect("frame decode");
        assert_eq!(used, framed.len());
        assert!(matches!(Message::decode(&frame), Ok(Message::Tensor { .. })));
    }
}

#[test]
fn tensor_codec_empty_scalar_and_odd_shapes() {
    for t in [
        HostTensor::f32(vec![], vec![42.0]),         // rank-0 scalar
        HostTensor::f32(vec![0], vec![]),            // empty
        HostTensor::f32(vec![3, 0, 5], vec![]),      // empty via inner dim
        HostTensor::f32(vec![1, 1, 1, 7], (0..7).map(|i| i as f32).collect()),
        HostTensor::i32(vec![0], vec![]),
        HostTensor::i32(vec![], vec![-7]),
    ] {
        let back = HostTensor::from_bytes(&t.to_bytes()).unwrap();
        assert_eq!(back.shape, t.shape);
        assert_eq!(back.dtype, t.dtype);
        assert_eq!(back.numel(), t.numel());
    }
}

// ---------------------------------------------------------------------------
// Frame robustness: every malformation is a typed error, never a panic.

fn sample_frames() -> Vec<Vec<u8>> {
    vec![
        Message::Hello { opid: 1, n_procs: 4, fingerprint: 0xABCD }.encode(),
        Message::Tensor {
            epoch: 0,
            step: 3,
            src: 2,
            flags: 0,
            tag: Tag::new(4, 1, 2),
            tensor: HostTensor::f32(vec![2, 3], vec![1.0; 6]),
        }
        .encode(),
        Message::Barrier { epoch: 1, step: 5, phase: 2 }.encode(),
        Message::Abort { epoch: 1, step: 5 }.encode(),
        Message::Dead { epoch: 0, opid: 3, step: 2 }.encode(),
        Message::Goodbye.encode(),
    ]
}

#[test]
fn truncation_at_every_boundary_is_typed_never_panics() {
    for bytes in sample_frames() {
        for cut in 0..bytes.len() {
            match decode_frame(&bytes[..cut]) {
                Err(WireError::Truncated { needed, got }) => {
                    assert_eq!(got, cut.min(needed), "got field must reflect the input");
                    assert!(needed > cut, "needed {needed} must exceed the {cut} available");
                }
                Err(other) => panic!("cut at {cut}: expected Truncated, got {other:?}"),
                Ok(_) => panic!("cut at {cut}: truncated frame decoded successfully"),
            }
        }
        // The stream reader mirrors the slice decoder for mid-frame EOF.
        for cut in 1..bytes.len() {
            let mut r = &bytes[..cut];
            let res = wire::read_frame(&mut r);
            assert!(res.is_err(), "stream cut at {cut} must error");
        }
        // Full frame decodes; clean EOF after it returns None.
        let mut r = &bytes[..];
        assert!(wire::read_frame(&mut r).unwrap().is_some());
        assert!(wire::read_frame(&mut r).unwrap().is_none());
    }
}

#[test]
fn bad_magic_is_typed() {
    let mut bytes = Message::Goodbye.encode();
    bytes[0] ^= 0xFF;
    match decode_frame(&bytes) {
        Err(WireError::BadMagic(m)) => assert_ne!(m, WIRE_MAGIC),
        other => panic!("expected BadMagic, got {other:?}"),
    }
}

#[test]
fn version_mismatch_is_typed() {
    let mut bytes = Message::Abort { epoch: 0, step: 1 }.encode();
    let bogus = (WIRE_VERSION + 7).to_le_bytes();
    bytes[4] = bogus[0];
    bytes[5] = bogus[1];
    match decode_frame(&bytes) {
        Err(WireError::VersionMismatch { got, want }) => {
            assert_eq!(got, WIRE_VERSION + 7);
            assert_eq!(want, WIRE_VERSION);
        }
        other => panic!("expected VersionMismatch, got {other:?}"),
    }
}

#[test]
fn oversized_length_prefix_is_rejected_before_allocation() {
    // A frame whose header promises a multi-gigabyte payload must be
    // rejected from the 12-byte header alone — decoding it from a tiny
    // buffer must not attempt any payload-sized allocation.
    let mut bytes = Vec::new();
    bytes.extend_from_slice(&WIRE_MAGIC.to_le_bytes());
    bytes.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    bytes.push(FrameKind::Tensor as u8);
    bytes.push(0);
    bytes.extend_from_slice(&u32::MAX.to_le_bytes()); // ~4 GiB payload
    match decode_frame(&bytes) {
        Err(WireError::Oversized { len, max }) => {
            assert_eq!(len, u32::MAX);
            assert_eq!(max, MAX_FRAME_PAYLOAD);
        }
        other => panic!("expected Oversized, got {other:?}"),
    }
    // Same through the stream reader.
    let mut r = &bytes[..];
    let err = wire::read_frame(&mut r).unwrap_err();
    assert!(
        matches!(err.downcast_ref::<WireError>(), Some(WireError::Oversized { .. })),
        "stream reader must reject oversized prefixes too: {err:#}"
    );
}

#[test]
fn unknown_kind_is_typed() {
    let bytes = encode_frame(FrameKind::Goodbye, &[]);
    let mut bytes = bytes;
    bytes[6] = 0xEE;
    // Kind is validated before the CRC, so this surfaces as BadKind.
    match decode_frame(&bytes) {
        Err(WireError::BadKind(0xEE)) => {}
        other => panic!("expected BadKind, got {other:?}"),
    }
}

#[test]
fn flipped_bits_fail_crc_everywhere() {
    let bytes = Message::Tensor {
        epoch: 9,
        step: 9,
        src: 1,
        flags: 0,
        tag: Tag::new(2, 0, 0),
        tensor: HostTensor::f32(vec![4], vec![1.0, 2.0, 3.0, 4.0]),
    }
    .encode();
    // Flip one bit in each payload byte position; all must be caught
    // (header corruptions surface as other typed errors first).
    for pos in HEADER_LEN..bytes.len() - 4 {
        let mut corrupt = bytes.clone();
        corrupt[pos] ^= 0x01;
        match decode_frame(&corrupt) {
            Err(WireError::BadCrc { .. }) => {}
            other => panic!("flip at {pos}: expected BadCrc, got {other:?}"),
        }
    }
}

#[test]
fn malformed_payloads_of_valid_frames_are_typed() {
    // A structurally valid frame whose payload is garbage for its kind.
    let frame = Frame { kind: FrameKind::Hello, payload: vec![1, 2, 3] };
    match Message::decode(&frame) {
        Err(WireError::BadPayload(_)) => {}
        other => panic!("expected BadPayload, got {other:?}"),
    }
    // Tensor frame whose embedded tensor header lies about its size.
    let mut payload = Vec::new();
    payload.extend_from_slice(&0u32.to_le_bytes()); // epoch
    payload.extend_from_slice(&1u64.to_le_bytes()); // step
    payload.extend_from_slice(&0u32.to_le_bytes()); // src
    payload.extend_from_slice(&0u32.to_le_bytes()); // flags
    payload.extend_from_slice(&Tag::new(1, 0, 0).0.to_le_bytes());
    payload.push(0); // dtype f32
    payload.push(2); // rank 2
    payload.extend_from_slice(&1000u32.to_le_bytes());
    payload.extend_from_slice(&1000u32.to_le_bytes()); // promises 4 MB…
    payload.extend_from_slice(&[0u8; 8]); // …delivers 8 bytes
    let frame = Frame { kind: FrameKind::Tensor, payload };
    match Message::decode(&frame) {
        Err(WireError::BadPayload(why)) => {
            assert!(why.contains("tensor"), "typed tensor error, got: {why}")
        }
        other => panic!("expected BadPayload, got {other:?}"),
    }
}

#[test]
fn crc_catches_byte_swaps_the_length_check_misses() {
    // Swapping two payload bytes keeps every length valid; only the CRC
    // can catch it.
    let bytes = Message::Hello { opid: 0, n_procs: 2, fingerprint: 7 }.encode();
    let mut swapped = bytes.clone();
    swapped.swap(HEADER_LEN, HEADER_LEN + 4);
    assert_ne!(bytes, swapped);
    assert!(matches!(decode_frame(&swapped), Err(WireError::BadCrc { .. })));
    // Sanity: the CRC itself is the standard IEEE polynomial.
    assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
}
