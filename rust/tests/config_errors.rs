//! The `ConfigError` matrix: **every** invalid configuration the
//! builder can express yields the right *typed* error — never a panic,
//! never a mid-run bail — and the checks fire before any compute.

use splitbrain::api::{ConfigError, SessionBuilder};
use splitbrain::comm::{FaultPlan, NetModel};
use splitbrain::coordinator::ExecEngine;
use splitbrain::runtime::RuntimeClient;

/// Build the full invalid-combination matrix as (description, builder,
/// variant-matcher) rows. A closure per row keeps the assertion on the
/// exact variant (and its payload), not just "some error".
fn matrix() -> Vec<(&'static str, SessionBuilder, fn(&ConfigError) -> bool)> {
    let b = SessionBuilder::new; // each row starts from defaults
    vec![
        ("zero workers", b().workers(0), |e| matches!(e, ConfigError::ZeroWorkers)),
        ("zero mp", b().mp(0), |e| matches!(e, ConfigError::ZeroMp)),
        (
            "mp does not divide workers",
            b().workers(4).mp(3),
            |e| matches!(e, ConfigError::MpNotDivisor { n_workers: 4, mp: 3 }),
        ),
        ("zero steps", b().steps(0), |e| matches!(e, ConfigError::ZeroSteps)),
        ("zero avg period", b().avg_period(0), |e| matches!(e, ConfigError::ZeroAvgPeriod)),
        ("zero dataset", b().dataset_size(0), |e| matches!(e, ConfigError::ZeroDataset)),
        (
            "zero take timeout",
            b().take_timeout_ms(0),
            |e| matches!(e, ConfigError::ZeroTakeTimeout),
        ),
        ("zero lr", b().lr(0.0), |e| matches!(e, ConfigError::InvalidLr { .. })),
        ("negative lr", b().lr(-0.1), |e| matches!(e, ConfigError::InvalidLr { .. })),
        ("NaN lr", b().lr(f32::NAN), |e| matches!(e, ConfigError::InvalidLr { .. })),
        (
            "infinite lr",
            b().lr(f32::INFINITY),
            |e| matches!(e, ConfigError::InvalidLr { .. }),
        ),
        (
            "momentum at 1",
            b().momentum(1.0),
            |e| matches!(e, ConfigError::InvalidMomentum { .. }),
        ),
        (
            "negative momentum",
            b().momentum(-0.1),
            |e| matches!(e, ConfigError::InvalidMomentum { .. }),
        ),
        (
            "NaN momentum",
            b().momentum(f32::NAN),
            |e| matches!(e, ConfigError::InvalidMomentum { .. }),
        ),
        (
            "negative clip norm",
            b().clip_norm(-1.0),
            |e| matches!(e, ConfigError::InvalidClipNorm { .. }),
        ),
        (
            "NaN clip norm",
            b().clip_norm(f32::NAN),
            |e| matches!(e, ConfigError::InvalidClipNorm { .. }),
        ),
        (
            "overlap forced on the sequential reference",
            b().engine(ExecEngine::Sequential).overlap(true),
            |e| matches!(e, ConfigError::OverlapOnSequential),
        ),
        (
            "crash rank out of range",
            b().workers(2).faults(FaultPlan::new().crash(2, 1)),
            |e| matches!(e, ConfigError::FaultRankOutOfRange { rank: 2, n_workers: 2, .. }),
        ),
        (
            "straggle rank out of range",
            b().workers(2).faults(FaultPlan::new().straggle(5, 1, 100)),
            |e| matches!(e, ConfigError::FaultRankOutOfRange { rank: 5, .. }),
        ),
        (
            "drop dst out of range",
            b().workers(2).faults(FaultPlan::new().drop_msg(0, 2, 1, 1)),
            |e| matches!(e, ConfigError::FaultRankOutOfRange { rank: 2, .. }),
        ),
        (
            "delay src out of range",
            b().workers(2).faults(FaultPlan::new().delay_msg(3, 0, 1, 1, 10)),
            |e| matches!(e, ConfigError::FaultRankOutOfRange { rank: 3, .. }),
        ),
        (
            "fault step zero (steps are 1-based)",
            b().workers(2).steps(10).faults(FaultPlan::new().crash(1, 0)),
            |e| matches!(e, ConfigError::FaultStepOutOfRange { step: 0, .. }),
        ),
        (
            "fault step past the run",
            b().workers(2).steps(10).faults(FaultPlan::new().crash(1, 11)),
            |e| matches!(e, ConfigError::FaultStepOutOfRange { step: 11, steps: 10, .. }),
        ),
        (
            "zero net alpha",
            b().net(NetModel { alpha: 0.0, ..Default::default() }),
            |e| matches!(e, ConfigError::InvalidNetModel { field: "alpha", .. }),
        ),
        (
            "negative net beta",
            b().net(NetModel { beta: -1.0, ..Default::default() }),
            |e| matches!(e, ConfigError::InvalidNetModel { field: "beta", .. }),
        ),
        (
            "NaN phase overhead",
            b().net(NetModel { phase_overhead: f64::NAN, ..Default::default() }),
            |e| matches!(e, ConfigError::InvalidNetModel { field: "phase_overhead", .. }),
        ),
    ]
}

#[test]
fn every_invalid_combination_yields_the_right_typed_error() {
    for (what, builder, is_expected) in matrix() {
        let err = builder
            .cluster_config()
            .expect_err(&format!("{what}: must be rejected"));
        assert!(is_expected(&err), "{what}: wrong variant: {err:?}");
        // Every error renders an actionable message and behaves as a
        // std error (so `?` converts it into anyhow at CLI boundaries).
        assert!(!err.to_string().is_empty(), "{what}: empty message");
        let _dyn_err: &dyn std::error::Error = &err;
    }
}

#[test]
fn validate_rejects_unsupported_mp_with_the_supported_list() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    // 3 divides 6, so the shape is fine — but no artifact set was
    // lowered for mp=3.
    let err = SessionBuilder::new().workers(6).mp(3).validate(&rt).unwrap_err();
    match err {
        ConfigError::MpUnsupported { mp: 3, supported } => {
            assert!(!supported.contains(&3));
            assert!(supported.contains(&1), "the supported list is actionable: {supported:?}");
        }
        other => panic!("wrong variant: {other:?}"),
    }
}

#[test]
fn first_failing_check_wins_deterministically() {
    // Multiple violations: the validation order is part of the
    // contract (workers before mp before trainer fields), so callers
    // can rely on stable error surfaces.
    let err = SessionBuilder::new().workers(0).mp(0).lr(-1.0).cluster_config().unwrap_err();
    assert!(matches!(err, ConfigError::ZeroWorkers), "got {err:?}");
}

#[test]
fn valid_edges_stay_valid() {
    // The legal boundary values next to every rejection above.
    let b = SessionBuilder::new;
    b().workers(1).cluster_config().unwrap();
    b().momentum(0.0).cluster_config().unwrap();
    b().clip_norm(0.0).cluster_config().unwrap(); // 0 = clipping off
    b().avg_period(1).cluster_config().unwrap();
    b().steps(1).cluster_config().unwrap();
    b().engine(ExecEngine::Sequential).overlap(false).cluster_config().unwrap();
    b().workers(2)
        .steps(10)
        .faults(FaultPlan::new().crash(1, 10)) // last step: in range
        .cluster_config()
        .unwrap();
    b().net(NetModel { phase_overhead: 0.0, ..Default::default() })
        .cluster_config()
        .unwrap();
}
