//! Kill–resume acceptance battery for durable runs.
//!
//! The contract under test: a run killed at **any** point and resumed
//! from its `--run-dir` continues **bit-identically** to the run that
//! was never interrupted — losses, parameters, optimizer momentum and
//! the event-log lineage — for both in-proc engines and for real
//! SIGKILL'd worker processes over TCP, including resuming *after* an
//! elastic shrink recovery. Event ordering under recovery is pinned for
//! both the in-memory sink and the durable log: `Recovered` precedes
//! the retried step's `StepCompleted`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::process::Command;
use std::sync::Arc;
use std::time::{Duration, Instant};

use splitbrain::api::{CollectSink, Event, Session, SessionBuilder};
use splitbrain::comm::FaultPlan;
use splitbrain::coordinator::{ExecEngine, RecoveryPolicy};
use splitbrain::data::{Dataset, SyntheticCifar};
use splitbrain::runtime::RuntimeClient;
use splitbrain::store::{replay, LogRecord};
use splitbrain::train::checkpoint;

const SEED: u64 = 123;
const DATASET: usize = 256;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_splitbrain")
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sb-resume-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn dataset() -> Arc<dyn Dataset> {
    Arc::new(SyntheticCifar::new(DATASET, SEED))
}

fn base_builder(n: usize, mp: usize, steps: usize) -> SessionBuilder {
    SessionBuilder::new()
        .workers(n)
        .mp(mp)
        .steps(steps)
        .lr(0.02)
        .momentum(0.9)
        .clip_norm(1.0)
        .avg_period(2)
        .seed(SEED)
        .dataset_size(DATASET)
}

/// Drive `s` to completion, returning per-step
/// `(loss bits, busiest-rank bytes, total bytes)`.
fn run_out(s: &mut Session) -> Vec<(u64, u64, u64)> {
    let mut steps = Vec::new();
    while !s.is_done() {
        let r = s.step().unwrap();
        steps.push((r.loss.to_bits(), r.bytes_busiest_rank, r.bytes_total));
    }
    steps
}

/// A killed-then-resumed in-proc run must be bit-identical to the
/// uninterrupted run — per engine. "Kill" here is dropping the Session
/// mid-run: every log append is fsync'd and checkpoint artifacts land
/// atomically, so an abandoned process and a dropped session leave the
/// same on-disk states behind.
#[test]
fn inproc_kill_resume_is_bit_identical_per_engine() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let steps = 8;
    for engine in [ExecEngine::Sequential, ExecEngine::Threaded] {
        // Uninterrupted reference.
        let mut reference = base_builder(4, 2, steps)
            .engine(engine)
            .dataset(dataset())
            .validate(&rt)
            .unwrap()
            .start()
            .unwrap();
        let ref_losses = run_out(&mut reference);
        assert_eq!(ref_losses.len(), steps);

        // Durable run, killed after step 5 (between the step-4
        // checkpoint and the step-6 one).
        let dir = tmp_dir(&format!("inproc-{engine}"));
        let mut victim = base_builder(4, 2, steps)
            .engine(engine)
            .run_dir(&dir)
            .dataset(dataset())
            .validate(&rt)
            .unwrap()
            .start()
            .unwrap();
        for _ in 0..5 {
            victim.step().unwrap();
        }
        drop(victim); // the kill

        // Resume: rewinds to the newest checkpoint (step 4) and replays.
        let mut resumed = SessionBuilder::resume_from(&dir)
            .unwrap()
            .dataset(dataset())
            .validate(&rt)
            .unwrap()
            .start()
            .unwrap();
        assert_eq!(resumed.steps_done(), 4, "{engine}: resume lands on the step-4 boundary");
        assert_eq!(resumed.run_dir(), Some(dir.as_path()));
        let tail = run_out(&mut resumed);
        assert_eq!(
            tail,
            ref_losses[4..],
            "{engine}: post-resume losses and byte counters must match the \
             uninterrupted run bit-for-bit"
        );
        assert!(
            resumed.cluster().full_state() == reference.cluster().full_state(),
            "{engine}: full cluster state (params + momentum) must be bit-identical \
             after resume"
        );

        // The durable lineage: both incarnations' records, the step-5
        // orphan truncated away, one Resumed marker, and per-step loss
        // bits that replay the uninterrupted run exactly.
        let rp = replay(dir.join("events.log")).unwrap();
        assert!(rp.tail.is_none(), "{engine}: finished log must replay cleanly");
        assert!(matches!(rp.records.last(), Some(LogRecord::RunCompleted(_))));
        let resumes: Vec<_> = rp
            .records
            .iter()
            .filter_map(|r| match r {
                LogRecord::Resumed { step } => Some(*step),
                _ => None,
            })
            .collect();
        assert_eq!(resumes, vec![4], "{engine}: exactly one resume, at the boundary");
        let ckpts: Vec<_> = rp
            .records
            .iter()
            .filter_map(|r| match r {
                LogRecord::Checkpoint { step, .. } => Some(*step),
                _ => None,
            })
            .collect();
        assert_eq!(ckpts, vec![2, 4, 6, 8], "{engine}: every averaging boundary persisted");
        let logged: Vec<(u64, u64, u64, u64)> = rp
            .records
            .iter()
            .filter_map(|r| match r {
                LogRecord::Step(s) => {
                    Some((s.step as u64, s.loss.to_bits(), s.bytes_busiest_rank, s.bytes_total))
                }
                _ => None,
            })
            .collect();
        let want: Vec<(u64, u64, u64, u64)> = ref_losses
            .iter()
            .enumerate()
            .map(|(i, &(loss, bb, bt))| (i as u64 + 1, loss, bb, bt))
            .collect();
        assert_eq!(logged, want, "{engine}: logged lineage must equal the uninterrupted run");
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// Resume *after* an elastic shrink: rank 3 of 4 crashes at step 3, the
/// cluster shrinks to 3 workers (mp 2 → 1), the run is killed at step 6
/// and resumed. The resumed incarnation must come back on the shrunk
/// topology with the consumed fault staying consumed, and finish
/// bit-identically to the never-killed faulted run.
#[test]
fn resume_after_shrink_recovery_is_bit_identical() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let steps = 8;
    let faulted = |dir: Option<&Path>| {
        let mut b = base_builder(4, 2, steps)
            .recovery(RecoveryPolicy::ShrinkAndContinue)
            .faults(FaultPlan::new().crash(3, 3));
        if let Some(d) = dir {
            b = b.run_dir(d);
        }
        b.dataset(dataset()).validate(&rt).unwrap().start().unwrap()
    };

    let mut reference = faulted(None);
    let ref_losses = run_out(&mut reference);

    let dir = tmp_dir("shrink");
    let mut victim = faulted(Some(&dir));
    for _ in 0..6 {
        victim.step().unwrap();
    }
    drop(victim);

    let mut resumed = SessionBuilder::resume_from(&dir)
        .unwrap()
        .dataset(dataset())
        .validate(&rt)
        .unwrap()
        .start()
        .unwrap();
    assert_eq!(resumed.steps_done(), 6, "step 6 is an averaging boundary of the shrunk run");
    let c = resumed.cluster();
    assert_eq!(c.cfg.n_workers, 3, "resume must come back on the shrunk topology");
    assert_eq!(c.cfg.mp, 1);
    assert_eq!(c.recoveries, 1);
    let tail = run_out(&mut resumed);
    assert_eq!(
        tail,
        ref_losses[6..],
        "post-resume losses on the shrunk cluster must match the uninterrupted faulted run"
    );
    assert!(
        resumed.cluster().full_state() == reference.cluster().full_state(),
        "shrunk-cluster state must be bit-identical after resume (fired fault flags, \
         survivor params, momentum)"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Event ordering under recovery, in memory and on disk: `Recovered`
/// arrives immediately before the retried step's `StepCompleted`, never
/// after it — a replay consumer must know the topology changed *before*
/// it sees the step that ran on the new topology.
#[test]
fn recovered_event_precedes_retried_step_in_sink_and_log() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let dir = tmp_dir("order");
    let mut session = base_builder(4, 2, 4)
        .recovery(RecoveryPolicy::ShrinkAndContinue)
        .faults(FaultPlan::new().crash(1, 3))
        .run_dir(&dir)
        .dataset(dataset())
        .validate(&rt)
        .unwrap()
        .start()
        .unwrap();
    let sink = CollectSink::new();
    let events = sink.events();
    session.attach(Box::new(sink));
    session.run().unwrap();
    drop(session);

    let events = events.borrow();
    let idx = events
        .iter()
        .position(|e| matches!(e, Event::Recovered(_)))
        .expect("the planned crash must surface a Recovered event");
    let recovered = match &events[idx] {
        Event::Recovered(r) => r.clone(),
        _ => unreachable!(),
    };
    assert!(recovered.n_workers < 4, "recovery shrank the cluster");
    match &events[idx + 1] {
        Event::StepCompleted(s) => assert_eq!(
            s.step, recovered.step,
            "the event right after Recovered must be the retried step itself"
        ),
        other => panic!("Recovered must be followed by the retried StepCompleted, got {other:?}"),
    }

    // Same ordering in the durable log.
    let rp = replay(dir.join("events.log")).unwrap();
    assert!(rp.tail.is_none());
    let li = rp
        .records
        .iter()
        .position(|r| matches!(r, LogRecord::Recovered(_)))
        .expect("the recovery must be in the durable log");
    match (&rp.records[li], &rp.records[li + 1]) {
        (LogRecord::Recovered(r), LogRecord::Step(s)) => {
            assert_eq!(s.step, r.step, "log: Recovered then the retried Step, adjacent")
        }
        (r, next) => panic!("log ordering broken: {r:?} followed by {next:?}"),
    }
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// Multi-process: real SIGKILL, real resume
// ---------------------------------------------------------------------

fn launch_args(dir: &Path, resume: bool) -> Vec<String> {
    let mut v: Vec<String> = [
        "launch",
        "--workers", "4",
        "--mp", "2",
        "--steps", "8",
        "--avg-period", "2",
        "--lr", "0.02",
        "--momentum", "0.9",
        "--clip-norm", "1.0",
        "--seed", "123",
        "--dataset-size", "256",
        "--take-timeout-ms", "120000",
        "--log-every", "4",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    v.push("--run-dir".into());
    v.push(dir.display().to_string());
    if resume {
        v.push("--resume".into());
    }
    v
}

/// step → loss bits from one worker process's meta dump.
fn meta_losses(dir: &Path, opid: usize) -> HashMap<usize, u64> {
    let meta = std::fs::read_to_string(dir.join(format!("opid{opid}.meta")))
        .unwrap_or_else(|e| panic!("opid {opid} meta missing: {e}"));
    let mut losses = HashMap::new();
    for line in meta.lines() {
        let mut it = line.split_whitespace();
        if it.next() == Some("loss") {
            let step: usize = it.next().unwrap().parse().unwrap();
            let bits = u64::from_str_radix(it.next().unwrap(), 16).unwrap();
            losses.insert(step, bits);
        }
    }
    losses
}

fn param_bits(dir: &Path, opid: usize) -> Vec<Vec<u32>> {
    checkpoint::load(dir.join(format!("opid{opid}.ckpt")))
        .unwrap()
        .into_iter()
        .map(|(_, t)| t.as_f32().iter().map(|v| v.to_bits()).collect())
        .collect()
}

/// SIGKILL a 4-process TCP launch mid-run, then `launch --resume` it:
/// the resumed processes must pick up from the newest complete
/// checkpoint set and land on losses and parameters bit-identical to a
/// launch that was never killed.
#[test]
fn launch_sigkill_resume_is_bit_identical() {
    let n = 4usize;
    let steps = 8usize;

    // Reference: an uninterrupted durable launch.
    let ref_dir = tmp_dir("launch-ref");
    let status = Command::new(bin())
        .args(launch_args(&ref_dir, false))
        .status()
        .expect("launching the reference run");
    assert!(status.success(), "reference launch must exit cleanly: {status:?}");

    // Victim: same launch, SIGKILL'd once every opid has persisted its
    // step-2 checkpoint artifact (6 steps of runway before completion
    // makes losing the race to a finished run implausible).
    let dir = tmp_dir("launch-kill");
    let mut launcher = Command::new(bin())
        .args(launch_args(&dir, false))
        .spawn()
        .expect("spawning the victim launch");
    let deadline = Instant::now() + Duration::from_secs(120);
    let ckpt_set = |step: usize| {
        (0..n).all(|opid| {
            dir.join("checkpoints").join(format!("step-{step}.opid-{opid}.ckpt")).is_file()
        })
    };
    while !ckpt_set(2) {
        assert!(Instant::now() < deadline, "step-2 checkpoint set never appeared");
        if let Ok(Some(s)) = launcher.try_wait() {
            panic!("victim launch exited before the step-2 checkpoints landed: {s:?}");
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    launcher.kill().ok(); // SIGKILL the launcher first: nothing reaps or retries
    let mut pids = Vec::new();
    for opid in 0..n {
        let pid = std::fs::read_to_string(dir.join(format!("opid{opid}.pid")))
            .unwrap_or_else(|e| panic!("opid {opid} pid file missing: {e}"));
        pids.push(pid.trim().to_string());
    }
    for pid in &pids {
        let _ = Command::new("kill").args(["-9", pid]).status();
    }
    launcher.wait().ok();
    std::thread::sleep(Duration::from_millis(200)); // let the SIGKILLs land
    assert!(
        !dir.join("opid0.meta").exists(),
        "the kill must interrupt the run before it writes final outputs — \
         if this fires the test lost the kill race"
    );

    // Resume in place. The launcher reports and restarts from the
    // newest step where every opid's artifact landed.
    let status = Command::new(bin())
        .args(launch_args(&dir, true))
        .status()
        .expect("relaunching with --resume");
    assert!(status.success(), "resumed launch must exit cleanly: {status:?}");

    // Bit-identical to the uninterrupted launch: every step the resumed
    // incarnation ran, and every parameter of every rank.
    for opid in 0..n {
        let got = meta_losses(&dir, opid);
        let want = meta_losses(&ref_dir, opid);
        assert_eq!(want.len(), steps);
        assert!(
            !got.is_empty() && got.len() < steps,
            "opid {opid}: resumed incarnation must run a strict, non-empty suffix \
             (ran {} of {steps} steps)",
            got.len()
        );
        assert!(got.contains_key(&steps), "opid {opid}: resumed run must reach step {steps}");
        for (step, bits) in &got {
            assert_eq!(
                bits, &want[step],
                "opid {opid}: loss bits diverged at step {step} after SIGKILL + resume"
            );
        }
        let a = param_bits(&dir, opid);
        let b = param_bits(&ref_dir, opid);
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x, y, "opid {opid}: parameter tensor {i} diverged after resume");
        }
        assert!(
            !dir.join(format!("opid{opid}.pid")).exists(),
            "opid {opid}: clean exit must remove the pid file"
        );
    }

    // The durable lineage survived the SIGKILL: the leader's log
    // replays cleanly end-to-end with exactly one Resumed marker at an
    // averaging boundary, and closes with RunCompleted.
    let rp = replay(dir.join("events.log")).unwrap();
    assert!(rp.tail.is_none(), "torn tail must have been truncated on resume: {:?}", rp.tail);
    let resumes: Vec<u64> = rp
        .records
        .iter()
        .filter_map(|r| match r {
            LogRecord::Resumed { step } => Some(*step),
            _ => None,
        })
        .collect();
    assert_eq!(resumes.len(), 1, "exactly one resume: {resumes:?}");
    assert!(resumes[0] >= 2 && resumes[0] % 2 == 0, "resumed at a boundary: {}", resumes[0]);
    assert!(matches!(rp.records.last(), Some(LogRecord::RunCompleted(_))));

    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&ref_dir).ok();
}
