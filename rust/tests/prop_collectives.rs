//! Property tests for the collective algorithm families: ring and
//! recursive-halving/doubling results must match the naive all-to-all
//! oracle, and the fabric's byte counters must match the analytic
//! expectations, on randomized group sizes — powers of two and not.
//!
//! (The offline registry has no proptest crate; these are seeded
//! randomized sweeps — every failure reproduces from the printed seed.)

use splitbrain::comm::collective::{
    allgather_cols, allgather_cols_algo, allreduce_mean, reduce_scatter_cols,
    reduce_scatter_cols_algo, ring_allreduce_mean, CollectiveAlgo,
};
use splitbrain::comm::fabric::{Fabric, Tag};
use splitbrain::runtime::HostTensor;
use splitbrain::util::Rng;

const CASES: usize = 40;

fn rand_tensor(rng: &mut Rng, shape: Vec<usize>) -> HostTensor {
    let n = shape.iter().product();
    HostTensor::f32(shape, rng.normal_vec(n, 1.0))
}

/// Ring allgather output is bit-identical to the naive oracle (pure
/// data movement, no arithmetic), per-rank byte totals match the
/// `V - w_next` forwarding volume, and only successor links carry
/// traffic.
#[test]
fn prop_ring_allgather_matches_naive() {
    for case in 0..CASES {
        let mut rng = Rng::new(10_000 + case as u64);
        let k = 2 + rng.below(7); // 2..=8, non-powers of two included
        let rows = 1 + rng.below(5);
        let widths: Vec<usize> = (0..k).map(|_| 1 + rng.below(6)).collect();
        let full: usize = widths.iter().sum();
        let group: Vec<usize> = (0..k).collect();
        let parts: Vec<HostTensor> = widths
            .iter()
            .map(|&w| rand_tensor(&mut rng, vec![rows, w]))
            .collect();

        let f_naive = Fabric::new(k);
        let naive = allgather_cols(&f_naive, &group, &parts, Tag::new(1, 0, 0)).unwrap();
        let f_ring = Fabric::new(k);
        let ring = allgather_cols_algo(
            CollectiveAlgo::Ring,
            &f_ring,
            &group,
            &parts,
            Tag::new(1, 0, 0),
        )
        .unwrap();

        for (gi, (a, b)) in naive.iter().zip(ring.iter()).enumerate() {
            assert_eq!(a.as_f32(), b.as_f32(), "case {case} member {gi}");
        }
        assert!(f_ring.drained(), "case {case}");
        for gi in 0..k {
            // Ring rank gi forwards every chunk except its successor's.
            let expect = (rows * (full - widths[(gi + 1) % k]) * 4) as u64;
            assert_eq!(f_ring.bytes_from(gi), expect, "case {case} rank {gi}");
            for dst in 0..k {
                let on_link = f_ring.bytes_on_link(gi, dst);
                if dst == (gi + 1) % k {
                    assert_eq!(on_link, expect, "case {case} link {gi}->{dst}");
                } else {
                    assert_eq!(on_link, 0, "case {case} stray traffic {gi}->{dst}");
                }
            }
        }
    }
}

/// Ring reduce-scatter matches the naive oracle numerically (summation
/// order differs, so tolerance not bit-equality) with *identical*
/// per-rank byte totals.
#[test]
fn prop_ring_reduce_scatter_matches_naive() {
    for case in 0..CASES {
        let mut rng = Rng::new(20_000 + case as u64);
        let k = 2 + rng.below(7);
        let rows = 1 + rng.below(4);
        let widths: Vec<usize> = (0..k).map(|_| 1 + rng.below(5)).collect();
        let full: usize = widths.iter().sum();
        let group: Vec<usize> = (0..k).collect();
        let fulls: Vec<HostTensor> =
            (0..k).map(|_| rand_tensor(&mut rng, vec![rows, full])).collect();

        let f_naive = Fabric::new(k);
        let naive =
            reduce_scatter_cols(&f_naive, &group, &fulls, &widths, Tag::new(2, 0, 0)).unwrap();
        let f_ring = Fabric::new(k);
        let ring = reduce_scatter_cols_algo(
            CollectiveAlgo::Ring,
            &f_ring,
            &group,
            &fulls,
            &widths,
            Tag::new(2, 0, 0),
        )
        .unwrap();

        for (gi, (a, b)) in naive.iter().zip(ring.iter()).enumerate() {
            assert_eq!(a.shape, b.shape, "case {case} member {gi}");
            let d = a.max_abs_diff(b);
            assert!(d < 1e-4, "case {case} member {gi}: diverged by {d}");
        }
        assert!(f_ring.drained(), "case {case}");
        for gi in 0..k {
            // Both algorithms push everything but the own slice.
            assert_eq!(
                f_ring.bytes_from(gi),
                f_naive.bytes_from(gi),
                "case {case} rank {gi}"
            );
            assert_eq!(f_ring.bytes_from(gi), (rows * (full - widths[gi]) * 4) as u64);
        }
    }
}

/// Ring and recursive-halving/doubling allreduce agree with the naive
/// mean on random lengths and group sizes, and never move more bytes
/// per rank than the naive all-to-all.
#[test]
fn prop_allreduce_algos_agree_with_naive_mean() {
    for case in 0..CASES {
        let mut rng = Rng::new(30_000 + case as u64);
        let n = 1 + rng.below(8); // 1..=8
        let len = 1 + rng.below(200);
        let group: Vec<usize> = (0..n).collect();
        let orig: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(len, 1.0)).collect();
        let expect: Vec<f32> = (0..len)
            .map(|i| orig.iter().map(|b| b[i]).sum::<f32>() / n as f32)
            .collect();

        let naive_bytes = ((n.saturating_sub(1)) * len * 4) as u64;
        for algo in [CollectiveAlgo::Naive, CollectiveAlgo::Ring, CollectiveAlgo::Rhd] {
            let fabric = Fabric::new(n);
            let mut bufs = orig.clone();
            allreduce_mean(algo, &fabric, &group, &mut bufs, 2).unwrap();
            for (r, b) in bufs.iter().enumerate() {
                for (got, want) in b.iter().zip(expect.iter()) {
                    assert!(
                        (got - want).abs() < 1e-4,
                        "case {case} n={n} algo={algo} rank {r}: {got} vs {want}"
                    );
                }
            }
            assert!(fabric.drained(), "case {case} algo={algo}");
            let worst = (0..n).map(|r| fabric.bytes_from(r)).max().unwrap_or(0);
            assert!(
                worst <= naive_bytes,
                "case {case} n={n} algo={algo}: {worst} > naive {naive_bytes}"
            );
        }
    }
}

/// The algorithm dispatcher (per-rank programs on threads) reproduces
/// the seed's group-view ring allreduce bit-for-bit — the property the
/// sequential/threaded engine parity rests on.
#[test]
fn prop_ring_dispatch_bit_matches_group_view() {
    for case in 0..CASES {
        let mut rng = Rng::new(40_000 + case as u64);
        let n = 2 + rng.below(7);
        let len = 1 + rng.below(300);
        let group: Vec<usize> = (0..n).collect();
        let orig: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(len, 1.0)).collect();

        let fa = Fabric::new(n);
        let mut a = orig.clone();
        ring_allreduce_mean(&fa, &group, &mut a, 6).unwrap();

        let fb = Fabric::new(n);
        let mut b = orig.clone();
        allreduce_mean(CollectiveAlgo::Ring, &fb, &group, &mut b, 6).unwrap();

        for (r, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x, y, "case {case} rank {r}");
        }
        assert_eq!(fa.total_bytes(), fb.total_bytes(), "case {case}");
        assert_eq!(fa.total_msgs(), fb.total_msgs(), "case {case}");
    }
}
