//! Fault injection + elastic recovery: the acceptance suite for the
//! deterministic failure harness.
//!
//! What is proven here:
//! * a single-worker crash injected at **any** step recovers under
//!   `RecoveryPolicy::ShrinkAndContinue` — the cluster re-plans over the
//!   survivor set (shrunk GMP groups), restores the latest checkpoint
//!   and keeps training;
//! * the same `FaultPlan` seed reproduces a faulted run
//!   **bit-identically** (losses and parameters), recovery included;
//! * peer loss is a **typed** error (`PeerLost` / `WorkerCrashed`), not
//!   an opaque timeout;
//! * dropped messages surface as presumed-dead peers through the
//!   (configurable) take timeout;
//! * straggle/delay faults move only the simulated clocks, never the
//!   numerics;
//! * recovery semantics are engine-independent (threaded == sequential,
//!   bit-for-bit).
//!
//! Runs on the built-in native backend (no artifacts needed).

use std::sync::Arc;

use splitbrain::api::SessionBuilder;
use splitbrain::comm::fault::FaultEvent;
use splitbrain::comm::{FaultPlan, PeerLost, WorkerCrashed};
use splitbrain::coordinator::{Cluster, ClusterConfig, ExecEngine, RecoveryPolicy};
use splitbrain::data::{Dataset, SyntheticCifar};
use splitbrain::runtime::RuntimeClient;

/// Base builder for the failure scenarios; tests chain a fault plan
/// (and any policy tweaks) before resolving with `cluster_config()`.
fn builder(n: usize, mp: usize) -> SessionBuilder {
    SessionBuilder::new()
        .workers(n)
        .mp(mp)
        .lr(0.02)
        .momentum(0.9)
        .clip_norm(1.0)
        .avg_period(2)
        .seed(77)
        .dataset_size(256)
        .recovery(RecoveryPolicy::ShrinkAndContinue)
}

fn cfg(n: usize, mp: usize) -> ClusterConfig {
    builder(n, mp).cluster_config().unwrap()
}

fn dataset() -> Arc<dyn Dataset> {
    Arc::new(SyntheticCifar::new(256, 77))
}

/// Every worker's every parameter, flattened (exact f32 payloads).
fn all_params(c: &Cluster) -> Vec<Vec<f32>> {
    let mut out = Vec::new();
    for rank in 0..c.cfg.n_workers {
        let w = c.worker(rank);
        for t in w.conv_params.iter().chain(w.fc_params.iter()) {
            out.push(t.as_f32().to_vec());
        }
    }
    out
}

/// Run `steps` steps, returning the exact per-step loss bit patterns.
fn run_losses(c: &mut Cluster, steps: usize) -> Vec<u64> {
    (0..steps).map(|_| c.step().unwrap().loss.to_bits()).collect()
}

/// The headline acceptance check: crash worker 1 at *every* step k of a
/// small hybrid run. Each scenario must recover onto the 3 survivors
/// (mp shrinks 2 → 1, since 2 ∤ 3) and finish training.
#[test]
fn crash_at_every_step_recovers_and_continues() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let steps = 3;
    for k in 1..=steps {
        let c = builder(4, 2).faults(FaultPlan::new().crash(1, k)).cluster_config().unwrap();
        let mut cluster = Cluster::with_dataset(&rt, c, dataset()).unwrap();
        let losses = run_losses(&mut cluster, steps);
        assert_eq!(losses.len(), steps, "crash@{k}: run must complete");
        for (i, bits) in losses.iter().enumerate() {
            assert!(
                f64::from_bits(*bits).is_finite(),
                "crash@{k}: loss at step {} not finite",
                i + 1
            );
        }
        assert_eq!(cluster.recoveries, 1, "crash@{k}");
        assert_eq!(cluster.lost_ranks, vec![1], "crash@{k}");
        assert_eq!(cluster.cfg.n_workers, 3, "crash@{k}: survivors");
        assert_eq!(cluster.cfg.mp, 1, "crash@{k}: 2 does not divide 3 survivors");
        assert_eq!(cluster.topo.n_workers, 3, "crash@{k}: topology re-planned");
        assert_eq!(cluster.schedule.topo.mp, 1, "crash@{k}: schedule recompiled");
        assert_eq!(cluster.fabric().ranks(), 3, "crash@{k}: fabric rebuilt");
        assert_eq!(cluster.steps_done(), steps, "crash@{k}");
        // The recovered cluster keeps training.
        assert!(cluster.step().unwrap().loss.is_finite(), "crash@{k}: step after run");
    }
}

/// Recovery converges: crash one of four workers early, then train on;
/// the survivor cluster's loss still falls.
#[test]
fn recovery_converges_on_survivors() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let c = builder(4, 2).faults(FaultPlan::new().crash(1, 2)).cluster_config().unwrap();
    let mut cluster = Cluster::with_dataset(&rt, c, dataset()).unwrap();
    // The step-2 crash precedes the first averaging boundary, so
    // recovery restarts the survivors from the initial model — give the
    // run enough steps to converge past that rollback.
    let report = cluster.train_steps(10).unwrap();
    assert_eq!(cluster.recoveries, 1);
    assert_eq!(cluster.cfg.n_workers, 3);
    let first = report.losses[0];
    let tail = report.tail_loss(3).unwrap();
    assert!(
        tail < first * 0.8,
        "survivor cluster must keep converging: first {first}, tail {tail} ({:?})",
        report.losses
    );
}

/// The second acceptance check: the same `FaultPlan::random` seed
/// replays bit-identically — per-step losses and every parameter —
/// recovery included.
#[test]
fn same_fault_seed_replays_bit_identically() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let steps = 3;
    // Seed 9 → crash(rank 2, step 2) + a delay rule. Chosen to contain
    // a crash (so the replay covers recovery) and no DropMsg (drops
    // resolve through the take timeout, which would slow the test; the
    // guard below fails loudly if the Rng stream ever changes — pick a
    // new seed then).
    let plan = FaultPlan::random(9, 4, steps, 2);
    assert!(
        plan.events().iter().any(|e| matches!(e, FaultEvent::Crash { .. })),
        "seed must exercise recovery: {plan:?}"
    );
    assert!(
        !plan.events().iter().any(|e| matches!(e, FaultEvent::DropMsg { .. })),
        "re-pick a drop-free seed: {plan:?}"
    );
    let mut runs = Vec::new();
    for _ in 0..2 {
        let c = builder(4, 2).faults(plan.clone()).cluster_config().unwrap();
        let mut cluster = Cluster::with_dataset(&rt, c, dataset()).unwrap();
        let losses = run_losses(&mut cluster, steps);
        runs.push((losses, all_params(&cluster), cluster.recoveries, cluster.lost_ranks.clone()));
    }
    assert_eq!(runs[0].0, runs[1].0, "per-step loss bits must replay identically");
    assert_eq!(runs[0].2, runs[1].2, "recovery count must replay identically");
    assert_eq!(runs[0].3, runs[1].3, "lost ranks must replay identically");
    assert_eq!(runs[0].1.len(), runs[1].1.len());
    for (i, (a, b)) in runs[0].1.iter().zip(runs[1].1.iter()).enumerate() {
        assert_eq!(a, b, "parameter tensor {i} diverged between replays");
    }
    assert!(runs[0].2 >= 1, "the seeded crash must actually have fired");
}

/// Cascaded failures: a second crash in the survivor incarnation
/// triggers a second shrink. (Fault ranks address the *current*
/// incarnation; consumed events never re-fire.)
#[test]
fn cascaded_crashes_shrink_twice() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let c = builder(4, 2)
        .faults(FaultPlan::new().crash(1, 2).crash(1, 3))
        .cluster_config()
        .unwrap();
    let mut cluster = Cluster::with_dataset(&rt, c, dataset()).unwrap();
    let losses = run_losses(&mut cluster, 3);
    assert_eq!(losses.len(), 3);
    assert_eq!(cluster.recoveries, 2);
    assert_eq!(cluster.lost_ranks, vec![1, 1]);
    assert_eq!(cluster.cfg.n_workers, 2);
    assert_eq!(cluster.cfg.mp, 1);
}

/// Under the default fail-fast policy a crash surfaces as a typed
/// `PeerLost`/`WorkerCrashed`, never an opaque timeout string.
#[test]
fn fail_fast_propagates_typed_peer_loss() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let c = builder(2, 2)
        .recovery(RecoveryPolicy::FailFast)
        .faults(FaultPlan::new().crash(1, 1))
        .cluster_config()
        .unwrap();
    let mut cluster = Cluster::with_dataset(&rt, c, dataset()).unwrap();
    let e = cluster.step().unwrap_err();
    let peer = e.downcast_ref::<PeerLost>().map(|p| p.rank);
    let crashed = e.downcast_ref::<WorkerCrashed>().map(|w| w.rank);
    assert!(
        peer == Some(1) || crashed == Some(1),
        "expected typed loss of rank 1, got: {e:#}"
    );
    assert_eq!(cluster.fabric().dead_ranks(), vec![1]);
    assert_eq!(cluster.recoveries, 0, "fail-fast must not recover");
}

/// A dropped message is indistinguishable from a dead sender: the
/// receiver's next miss on the dropped channel presumes the sender
/// dead (no timeout wait needed), and recovery continues on the
/// survivor.
#[test]
fn dropped_message_presumes_sender_dead_and_recovers() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    // take_timeout exercises the config plumbing too; the
    // dropped-channel fast path means the run never waits this long.
    let c = builder(2, 2)
        .take_timeout_ms(8_000)
        .faults(FaultPlan::new().drop_msg(0, 1, 1, 1)) // modulo-fwd slice
        .cluster_config()
        .unwrap();
    let mut cluster = Cluster::with_dataset(&rt, c, dataset()).unwrap();
    let m = cluster.step().unwrap();
    assert!(m.loss.is_finite());
    assert_eq!(cluster.recoveries, 1);
    assert_eq!(cluster.lost_ranks, vec![0], "the silent sender is the presumed-dead one");
    assert_eq!(cluster.cfg.n_workers, 1);
    assert_eq!(cluster.cfg.mp, 1);
}

/// Same drop scenario on the sequential engine: the non-blocking take's
/// miss on the dropped channel surfaces the same typed `PeerLost`, and
/// recovery proceeds identically.
#[test]
fn dropped_message_recovers_on_sequential_engine_too() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let c = builder(2, 2)
        .engine(ExecEngine::Sequential)
        .faults(FaultPlan::new().drop_msg(0, 1, 1, 1))
        .cluster_config()
        .unwrap();
    let mut cluster = Cluster::with_dataset(&rt, c, dataset()).unwrap();
    let m = cluster.step().unwrap();
    assert!(m.loss.is_finite());
    assert_eq!(cluster.recoveries, 1);
    assert_eq!(cluster.lost_ranks, vec![0]);
    assert_eq!(cluster.cfg.n_workers, 1);
}

/// Straggle and delay faults charge the simulated clocks (compute and
/// comm respectively) and leave the numerics bit-identical.
#[test]
fn straggle_and_delay_move_clocks_not_numerics() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let base = cfg(2, 2);
    let faulted = builder(2, 2)
        .faults(
            FaultPlan::new()
                .straggle(0, 1, 400)
                .delay_msg(0, 1, 3, 1, 150), // phase 3 = shard-fwd allgather
        )
        .cluster_config()
        .unwrap();
    let mut a = Cluster::with_dataset(&rt, base, dataset()).unwrap();
    let mut b = Cluster::with_dataset(&rt, faulted, dataset()).unwrap();
    let ma = a.step().unwrap();
    let mb = b.step().unwrap();
    assert_eq!(ma.loss.to_bits(), mb.loss.to_bits(), "faults must not touch numerics");
    assert!(mb.compute_secs >= 0.4, "straggle must inflate compute: {}", mb.compute_secs);
    let delay = mb.mp_comm_secs - ma.mp_comm_secs;
    assert!(
        (delay - 0.15).abs() < 1e-9,
        "delay must add exactly 150 simulated ms to mp-comm, added {delay}"
    );
    let pa = all_params(&a);
    let pb = all_params(&b);
    for (i, (x, y)) in pa.iter().zip(pb.iter()).enumerate() {
        assert_eq!(x, y, "tensor {i} diverged under straggle/delay");
    }
}

/// Recovery restores from the checkpoint taken at the last averaging
/// boundary, and records the restore point.
#[test]
fn recovery_restores_from_last_averaging_checkpoint() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let c = builder(2, 2) // avg_period = 2
        .faults(FaultPlan::new().crash(1, 3))
        .cluster_config()
        .unwrap();
    let mut cluster = Cluster::with_dataset(&rt, c, dataset()).unwrap();
    assert_eq!(cluster.last_checkpoint_step(), 0, "initial model is the restore point");
    let losses = run_losses(&mut cluster, 3);
    assert_eq!(losses.len(), 3);
    assert_eq!(cluster.recoveries, 1);
    assert_eq!(
        cluster.last_checkpoint_step(),
        2,
        "step-3 crash must restore from the step-2 averaging checkpoint"
    );
    assert_eq!(cluster.steps_done(), 3);
}

/// Recovery is engine-independent: the sequential and threaded engines
/// agree bit-for-bit through a crash + shrink + continue run.
#[test]
fn sequential_and_threaded_recovery_agree_bitwise() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let ct = builder(2, 2).faults(FaultPlan::new().crash(1, 2)).cluster_config().unwrap();
    let cs = builder(2, 2)
        .faults(FaultPlan::new().crash(1, 2))
        .engine(ExecEngine::Sequential)
        .cluster_config()
        .unwrap();
    let mut thr = Cluster::with_dataset(&rt, ct, dataset()).unwrap();
    let mut seq = Cluster::with_dataset(&rt, cs, dataset()).unwrap();
    let lt = run_losses(&mut thr, 3);
    let ls = run_losses(&mut seq, 3);
    assert_eq!(lt, ls, "loss bits diverged between engines across recovery");
    assert_eq!(thr.recoveries, seq.recoveries);
    assert_eq!(thr.cfg.n_workers, seq.cfg.n_workers);
    let pt = all_params(&thr);
    let ps = all_params(&seq);
    assert_eq!(pt.len(), ps.len());
    for (i, (a, b)) in pt.iter().zip(ps.iter()).enumerate() {
        assert_eq!(a, b, "parameter tensor {i} diverged between engines");
    }
}

/// With no faults scheduled, enabling the recovery policy changes
/// nothing: the fault hooks stay off the hot path.
#[test]
fn recovery_policy_is_free_without_faults() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let shrink = cfg(2, 2);
    let fail = builder(2, 2).recovery(RecoveryPolicy::FailFast).cluster_config().unwrap();
    let mut a = Cluster::with_dataset(&rt, shrink, dataset()).unwrap();
    let mut b = Cluster::with_dataset(&rt, fail, dataset()).unwrap();
    let la = run_losses(&mut a, 2);
    let lb = run_losses(&mut b, 2);
    assert_eq!(la, lb);
    assert_eq!(a.recoveries, 0);
    for (x, y) in all_params(&a).iter().zip(all_params(&b).iter()) {
        assert_eq!(x, y);
    }
}
