//! Transport parity: a 4-process TCP training run must be
//! **bit-identical** — per-rank per-step losses and every parameter —
//! to the in-proc threaded engine (which `engine_parity` already pins
//! to the sequential reference) on the same seed, and a crash-at-step-k
//! TCP run must recover via ShrinkAndContinue onto the same survivor
//! set with the same bit-exact result as the in-proc fault-injection
//! harness.
//!
//! The TCP side runs real `splitbrain worker` processes spawned by
//! `splitbrain launch` over localhost sockets (the binary under test,
//! via `CARGO_BIN_EXE_splitbrain`); each worker dumps its final
//! parameters and per-step loss bit patterns, which this test compares
//! against an in-proc cluster run with the identical configuration.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::process::Command;

use splitbrain::api::SessionBuilder;
use splitbrain::comm::FaultPlan;
use splitbrain::coordinator::{Cluster, ClusterConfig, RecoveryPolicy};
use splitbrain::runtime::RuntimeClient;
use splitbrain::train::checkpoint;

const SEED: u64 = 123;
const DATASET: usize = 256;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_splitbrain")
}

fn base_builder(n: usize, mp: usize, avg_period: usize) -> SessionBuilder {
    SessionBuilder::new()
        .workers(n)
        .mp(mp)
        .lr(0.02)
        .momentum(0.9)
        .clip_norm(1.0)
        .avg_period(avg_period)
        .seed(SEED)
        .dataset_size(DATASET)
}

fn base_cfg(n: usize, mp: usize, avg_period: usize) -> ClusterConfig {
    base_builder(n, mp, avg_period).cluster_config().unwrap()
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("splitbrain-parity-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// One worker process's dumped end state.
struct WorkerState {
    rank: usize,
    workers: usize,
    mp: usize,
    recoveries: usize,
    bytes: u64,
    /// step → loss bit pattern
    losses: HashMap<usize, u64>,
    /// The 20 local parameter tensors (conv 14 + fc 6), flattened.
    params: Vec<Vec<u32>>,
}

fn read_worker_state(dir: &Path, opid: usize) -> WorkerState {
    let meta = std::fs::read_to_string(dir.join(format!("opid{opid}.meta")))
        .unwrap_or_else(|e| panic!("opid {opid} meta missing: {e}"));
    let mut rank = usize::MAX;
    let mut workers = 0;
    let mut mp = 0;
    let mut recoveries = 0;
    let mut bytes = 0u64;
    let mut losses = HashMap::new();
    for line in meta.lines() {
        let mut it = line.split_whitespace();
        match it.next() {
            Some("rank") => rank = it.next().unwrap().parse().unwrap(),
            Some("workers") => workers = it.next().unwrap().parse().unwrap(),
            Some("mp") => mp = it.next().unwrap().parse().unwrap(),
            Some("recoveries") => recoveries = it.next().unwrap().parse().unwrap(),
            Some("bytes") => bytes = it.next().unwrap().parse().unwrap(),
            Some("loss") => {
                let step: usize = it.next().unwrap().parse().unwrap();
                let bits = u64::from_str_radix(it.next().unwrap(), 16).unwrap();
                losses.insert(step, bits);
            }
            _ => {}
        }
    }
    let ckpt = checkpoint::load(dir.join(format!("opid{opid}.ckpt"))).unwrap();
    let params = ckpt
        .into_iter()
        .map(|(_, t)| t.as_f32().iter().map(|v| v.to_bits()).collect())
        .collect();
    WorkerState { rank, workers, mp, recoveries, bytes, losses, params }
}

/// In-proc rank `r`'s parameters as bit patterns, in the same order the
/// worker process dumps them (conv 14 then fc 6).
fn inproc_params(c: &Cluster, r: usize) -> Vec<Vec<u32>> {
    let w = c.worker(r);
    w.conv_params
        .iter()
        .chain(w.fc_params.iter())
        .map(|t| t.as_f32().iter().map(|v| v.to_bits()).collect())
        .collect()
}

/// The headline acceptance check: 4 TCP processes (mp=2, two MP
/// groups, ring collectives, two averaging boundaries) are
/// bit-identical to the in-proc threaded engine over 10 steps.
#[test]
fn tcp_4proc_bit_identical_to_threaded_10_steps() {
    let (n, mp, steps, avg) = (4usize, 2usize, 10usize, 5usize);

    // --- in-proc reference (threaded engine, the default) ---
    let rt = RuntimeClient::load("artifacts").unwrap();
    let mut cluster = Cluster::new(&rt, base_cfg(n, mp, avg)).unwrap();
    let mut ref_losses: Vec<Vec<u64>> = Vec::new(); // [step][rank]
    let mut ref_total_bytes = 0u64;
    for _ in 0..steps {
        cluster.step().unwrap();
        let rounds = cluster.cfg.scheme.rounds(cluster.cfg.mp.max(1)) as f64;
        ref_losses.push(
            (0..n).map(|r| (cluster.worker(r).loss_acc / rounds).to_bits()).collect(),
        );
        ref_total_bytes += cluster.last_fabric_bytes.1;
    }

    // --- 4-process TCP run over localhost ---
    let dir = tmp_dir("smoke");
    let status = Command::new(bin())
        .args([
            "launch",
            "--workers", "4",
            "--mp", "2",
            "--steps", "10",
            "--avg-period", "5",
            "--lr", "0.02",
            "--momentum", "0.9",
            "--clip-norm", "1.0",
            "--seed", "123",
            "--dataset-size", "256",
            "--take-timeout-ms", "120000",
            "--log-every", "5",
            "--verify-replicas",
        ])
        .arg("--out-dir")
        .arg(&dir)
        .status()
        .expect("launching the 4-process run");
    assert!(status.success(), "launch must exit cleanly, got {status:?}");

    let mut tcp_total_bytes = 0u64;
    for opid in 0..n {
        let ws = read_worker_state(&dir, opid);
        assert_eq!(ws.rank, opid, "no recovery: logical rank == opid");
        assert_eq!(ws.workers, n);
        assert_eq!(ws.mp, mp);
        assert_eq!(ws.recoveries, 0);
        tcp_total_bytes += ws.bytes;
        // Per-step losses bit-identical to the threaded engine.
        assert_eq!(ws.losses.len(), steps, "opid {opid} must record every step");
        for (step, row) in ref_losses.iter().enumerate() {
            assert_eq!(
                ws.losses[&(step + 1)],
                row[opid],
                "opid {opid}: loss bits diverged at step {}",
                step + 1
            );
        }
        // Every parameter tensor bit-identical.
        let ref_params = inproc_params(&cluster, opid);
        assert_eq!(ws.params.len(), ref_params.len());
        for (i, (a, b)) in ws.params.iter().zip(ref_params.iter()).enumerate() {
            assert_eq!(a, b, "opid {opid}: parameter tensor {i} diverged over TCP");
        }
    }
    // Exact byte-counter parity: the wire moved exactly what the
    // in-proc fabric counted.
    assert_eq!(
        tcp_total_bytes, ref_total_bytes,
        "cumulative data-plane bytes must match the in-proc fabric"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Crash-at-step-k parity: rank 1 of 4 crashes at step 3 (after the
/// step-2 averaging checkpoint); both drivers must shrink onto
/// survivors {0,2,3} (mp 2 → 1), restore the same checkpoint, and land
/// on bit-identical survivor parameters and losses.
#[test]
fn tcp_crash_recovery_matches_inproc_shrink_and_continue() {
    let (n, steps, avg, crash_rank, crash_step) = (4usize, 6usize, 2usize, 1usize, 3usize);

    // --- in-proc reference (threaded engine + fault plan) ---
    let rt = RuntimeClient::load("artifacts").unwrap();
    let cfg = base_builder(n, 2, avg)
        .recovery(RecoveryPolicy::ShrinkAndContinue)
        .faults(FaultPlan::new().crash(crash_rank, crash_step))
        .cluster_config()
        .unwrap();
    let mut cluster = Cluster::new(&rt, cfg).unwrap();
    let mut ref_losses: Vec<Vec<u64>> = Vec::new(); // [step][current-rank]
    for _ in 0..steps {
        cluster.step().unwrap();
        let rounds = cluster.cfg.scheme.rounds(cluster.cfg.mp.max(1)) as f64;
        ref_losses.push(
            (0..cluster.cfg.n_workers)
                .map(|r| (cluster.worker(r).loss_acc / rounds).to_bits())
                .collect(),
        );
    }
    assert_eq!(cluster.recoveries, 1);
    assert_eq!(cluster.lost_ranks, vec![crash_rank]);
    assert_eq!(cluster.cfg.n_workers, 3);
    assert_eq!(cluster.cfg.mp, 1, "2 does not divide 3 survivors");

    // --- TCP run with the same injected crash ---
    let dir = tmp_dir("crash");
    let status = Command::new(bin())
        .args([
            "launch",
            "--workers", "4",
            "--mp", "2",
            "--steps", "6",
            "--avg-period", "2",
            "--lr", "0.02",
            "--momentum", "0.9",
            "--clip-norm", "1.0",
            "--seed", "123",
            "--dataset-size", "256",
            "--recovery", "shrink",
            "--crash", "1@3",
            "--take-timeout-ms", "120000",
            "--log-every", "2",
            "--verify-replicas",
        ])
        .arg("--out-dir")
        .arg(&dir)
        .status()
        .expect("launching the crash-recovery run");
    assert!(status.success(), "launch must treat the planned crash as expected: {status:?}");

    // The crashed process left its marker and no final state.
    let marker = std::fs::read_to_string(dir.join(format!("opid{crash_rank}.crashed"))).unwrap();
    assert!(marker.contains(&format!("step {crash_step}")), "marker: {marker}");
    assert!(!dir.join(format!("opid{crash_rank}.meta")).exists());

    // Survivor opids 0, 2, 3 → new ranks 0, 1, 2 (the in-proc
    // renumbering). opid → rank-at-step mapping for the loss trace.
    let survivors = [0usize, 2, 3];
    for (new_rank, &opid) in survivors.iter().enumerate() {
        let ws = read_worker_state(&dir, opid);
        assert_eq!(ws.rank, new_rank, "opid {opid} must renumber like the in-proc shrink");
        assert_eq!(ws.workers, 3);
        assert_eq!(ws.mp, 1);
        assert_eq!(ws.recoveries, 1);
        assert_eq!(ws.losses.len(), steps);
        for step in 1..=steps {
            // Before the crash step the process's rank was its opid;
            // from the (retried) crash step on it is the survivor rank.
            let idx = if step < crash_step { opid } else { new_rank };
            assert_eq!(
                ws.losses[&step],
                ref_losses[step - 1][idx],
                "opid {opid}: loss bits diverged at step {step}"
            );
        }
        let ref_params = inproc_params(&cluster, new_rank);
        assert_eq!(ws.params.len(), ref_params.len());
        for (i, (a, b)) in ws.params.iter().zip(ref_params.iter()).enumerate() {
            assert_eq!(
                a, b,
                "survivor opid {opid} (rank {new_rank}): parameter tensor {i} diverged"
            );
        }
        assert!(ws.bytes > 0, "survivors moved real bytes");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
