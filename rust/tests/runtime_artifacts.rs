//! Integration: execute the segment artifacts through the runtime,
//! cross-checking numerics against independent Rust-side math.
//!
//! Runs on the built-in native backend when no `artifacts/` directory
//! is present, so nothing is skipped in the offline build.

use splitbrain::runtime::{HostTensor, RuntimeClient};
use splitbrain::util::Rng;

fn runtime() -> Option<RuntimeClient> {
    match RuntimeClient::load("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP: runtime unavailable ({e:#})");
            None
        }
    }
}

/// relu(x @ w + b) computed naively in Rust.
fn fc_ref(x: &[f32], w: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = b[j];
            for l in 0..k {
                acc += x[i * k + l] * w[l * n + j];
            }
            out[i * n + j] = acc.max(0.0);
        }
    }
    out
}

#[test]
fn fc0_shard_matches_rust_reference() {
    let Some(rt) = runtime() else { return };
    let b = rt.manifest.batch;
    let mut rng = Rng::new(42);
    let x = HostTensor::f32(vec![b, 4096], rng.normal_vec(b * 4096, 0.5));
    let w = HostTensor::f32(vec![4096, 512], rng.normal_vec(4096 * 512, 0.02));
    let bias = HostTensor::f32(vec![512], rng.normal_vec(512, 0.1));

    let out = rt
        .run("fc0_fwd_k2", &[w.clone(), bias.clone(), x.clone()])
        .expect("fc0_fwd_k2");
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].shape, vec![b, 512]);

    let expect = fc_ref(x.as_f32(), w.as_f32(), bias.as_f32(), b, 4096, 512);
    let got = out[0].as_f32();
    let max_err = expect
        .iter()
        .zip(got.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-2, "max err {max_err}");
}

#[test]
fn head_loss_is_ln10_for_zero_logits() {
    let Some(rt) = runtime() else { return };
    let b = rt.manifest.batch;
    let w2 = HostTensor::zeros(vec![1024, 10]);
    let b2 = HostTensor::zeros(vec![10]);
    let mut rng = Rng::new(7);
    let h1 = HostTensor::f32(vec![b, 1024], rng.normal_vec(b * 1024, 1.0));
    let labels = HostTensor::i32(
        vec![b],
        (0..b).map(|i| (i % 10) as i32).collect(),
    );
    let out = rt.run("head_step", &[w2, b2, h1, labels]).expect("head_step");
    let loss = out[0].scalar();
    assert!(
        (loss - 10f32.ln()).abs() < 1e-4,
        "zero-logit loss should be ln(10)={}, got {loss}",
        10f32.ln()
    );
    // Gradient w.r.t. bias for zero logits: softmax(0)=0.1, so
    // gb2[c] = 0.1 - count(c)/B exactly.
    let mut counts = [0usize; 10];
    for i in 0..b {
        counts[i % 10] += 1;
    }
    let gb2 = out[2].as_f32();
    for (c, g) in gb2.iter().enumerate() {
        let expect = 0.1 - counts[c] as f32 / b as f32;
        assert!((g - expect).abs() < 1e-6, "gb2[{c}]={g}, expect {expect}");
    }
}

#[test]
fn full_step_produces_all_grads_and_finite_loss() {
    let Some(rt) = runtime() else { return };
    let spec = rt.manifest.get("full_step").expect("spec").clone();
    let mut rng = Rng::new(3);
    let inputs: Vec<HostTensor> = spec
        .inputs
        .iter()
        .map(|s| match s.dtype {
            splitbrain::runtime::DType::F32 => {
                let scale = if s.shape.len() >= 2 { 0.05 } else { 0.0 };
                HostTensor::f32(s.shape.clone(), rng.normal_vec(s.numel(), scale))
            }
            splitbrain::runtime::DType::I32 => HostTensor::i32(
                s.shape.clone(),
                (0..s.numel()).map(|i| (i % 10) as i32).collect(),
            ),
        })
        .collect();
    let out = rt.run("full_step", &inputs).expect("full_step");
    assert_eq!(out.len(), 21, "loss + 14 conv grads + 6 fc grads");
    let loss = out[0].scalar();
    assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
    // Grad shapes mirror the parameter inputs.
    for (g, p) in out[1..].iter().zip(spec.inputs.iter()) {
        assert_eq!(g.shape, p.shape, "grad of {}", p.name);
    }
}

#[test]
fn conv_fwd_then_bwd_roundtrip_shapes() {
    let Some(rt) = runtime() else { return };
    let b = rt.manifest.batch;
    let spec = rt.manifest.get("conv_fwd").expect("spec").clone();
    let mut rng = Rng::new(5);
    let mut inputs: Vec<HostTensor> = spec
        .inputs
        .iter()
        .map(|s| HostTensor::f32(s.shape.clone(), rng.normal_vec(s.numel(), 0.05)))
        .collect();
    let act = rt.run("conv_fwd", &inputs).expect("conv_fwd");
    assert_eq!(act[0].shape, vec![b, rt.manifest.feature_dim]);

    // Backward with the activation gradient = act itself (arbitrary).
    inputs.push(act[0].clone());
    let grads = rt.run("conv_bwd", &inputs).expect("conv_bwd");
    assert_eq!(grads.len(), 14);
    for (g, p) in grads.iter().zip(spec.inputs.iter()) {
        assert_eq!(g.shape, p.shape, "grad of {}", p.name);
    }
}

#[test]
fn executable_cache_instantiates_once() {
    let Some(rt) = runtime() else { return };
    let a = rt.executable("head_fwd").unwrap();
    let b = rt.executable("head_fwd").unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b));
}

#[test]
fn shape_mismatch_is_rejected() {
    let Some(rt) = runtime() else { return };
    let bad = vec![HostTensor::zeros(vec![1, 1])];
    let err = rt.run("head_step", &bad).unwrap_err().to_string();
    assert!(err.contains("expected 4 inputs"), "{err}");
}
