//! Property battery for the durable store's failure envelope:
//!
//! * **truncate-at-every-byte** — a log cut at *any* byte replays to
//!   exactly the records whose frames are complete, reports a typed
//!   torn-tail error iff the cut is mid-record, and never panics;
//! * **flip-every-byte** — single-byte corruption anywhere in a log is
//!   either detected (typed [`StoreError`]) or — only when the flip
//!   lands in a record's length field, where CRC framing can no longer
//!   bound the blast radius deterministically — at worst stops replay
//!   early; records *before* the corrupted frame always survive intact;
//! * checkpoint artifacts reject **every** single-byte flip and
//!   **every** truncation (whole-file CRC);
//! * **branch-at-every-boundary** — branching a finished durable run at
//!   each of its averaging boundaries is deterministic (two branches
//!   from the same boundary are bit-identical), including across a
//!   topology change.

use std::path::PathBuf;
use std::sync::Arc;

use splitbrain::api::SessionBuilder;
use splitbrain::api::{RecoveryInfo, RunInfo, RunSummary, StepReport};
use splitbrain::comm::CollectiveAlgo;
use splitbrain::coordinator::worker::WorkerSnapshot;
use splitbrain::coordinator::{ClusterState, ExecEngine};
use splitbrain::data::{Dataset, SyntheticCifar};
use splitbrain::runtime::{HostTensor, RuntimeClient};
use splitbrain::store::ckpt::{decode_artifact, encode_artifact};
use splitbrain::store::{replay, LogRecord};

const SEED: u64 = 123;
const DATASET: usize = 256;

fn tmp_dir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("sb-prop-store-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// A lineage with one record of every kind — same shape a real durable
/// run writes (started, steps, checkpoint, recovery, resume, summary).
fn fixture_records() -> Vec<LogRecord> {
    vec![
        LogRecord::RunStarted(RunInfo {
            n_workers: 4,
            mp: 2,
            n_groups: 2,
            batch: 32,
            steps: 4,
            lr: 0.125,
            avg_period: 2,
            engine: ExecEngine::Threaded,
            collectives: CollectiveAlgo::Ring,
            overlap: true,
            param_mb: 13.5,
            total_mb: 29.75,
        }),
        LogRecord::Step(StepReport {
            step: 1,
            loss: 2.25,
            compute_secs: 0.5,
            mp_comm_secs: 0.0625,
            dp_comm_secs: 0.0,
            wall_secs: 0.25,
            bytes_busiest_rank: 65536,
            bytes_total: 262144,
        }),
        LogRecord::Checkpoint { step: 2, file: "step-2.ckpt".into(), fingerprint: 0xdead_beef },
        LogRecord::Recovered(RecoveryInfo {
            step: 3,
            lost_ranks: vec![3],
            n_workers: 3,
            mp: 1,
            restore_step: 2,
        }),
        LogRecord::Resumed { step: 2 },
        LogRecord::RunCompleted(RunSummary {
            steps: 4,
            images_per_sec: 512.0,
            comm_fraction: 0.25,
            recoveries: 1,
            lost_ranks: vec![3],
            n_workers: 3,
            mp: 1,
            last_checkpoint_step: 4,
        }),
    ]
}

/// Replay a byte image by round-tripping it through a real file.
fn replay_bytes(dir: &std::path::Path, bytes: &[u8]) -> splitbrain::store::Replay {
    let path = dir.join("events.log");
    std::fs::write(&path, bytes).unwrap();
    replay(&path).expect("replay itself must not error on a readable file")
}

#[test]
fn log_truncated_at_every_byte_recovers_the_exact_prefix() {
    let dir = tmp_dir("truncate");
    let records = fixture_records();
    let encoded: Vec<Vec<u8>> = records.iter().map(|r| r.encode()).collect();
    let full: Vec<u8> = encoded.iter().flatten().copied().collect();
    let mut boundaries = vec![0usize];
    for r in &encoded {
        boundaries.push(boundaries.last().unwrap() + r.len());
    }

    for cut in 0..=full.len() {
        let rp = replay_bytes(&dir, &full[..cut]);
        let whole = boundaries.iter().filter(|&&b| b <= cut).count() - 1;
        assert_eq!(
            rp.records,
            records[..whole],
            "cut at byte {cut}: replay must keep exactly the {whole} complete records"
        );
        let at_boundary = boundaries.contains(&cut);
        assert_eq!(
            rp.tail.is_none(),
            at_boundary,
            "cut at byte {cut}: torn tail must be reported iff mid-record (tail: {:?})",
            rp.tail
        );
        assert_eq!(rp.valid_bytes, boundaries[whole] as u64, "cut at byte {cut}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn log_single_byte_flips_never_corrupt_the_preceding_records() {
    let dir = tmp_dir("flip");
    let records = fixture_records();
    let encoded: Vec<Vec<u8>> = records.iter().map(|r| r.encode()).collect();
    let full: Vec<u8> = encoded.iter().flatten().copied().collect();
    let mut starts = vec![0usize];
    for r in &encoded {
        starts.push(starts.last().unwrap() + r.len());
    }

    for pos in 0..full.len() {
        let mut bytes = full.clone();
        bytes[pos] ^= 0xFF;
        let rp = replay_bytes(&dir, &bytes);

        // Which record frame did the flip land in?
        let hit = starts.iter().take_while(|&&s| s <= pos).count() - 1;
        assert!(
            rp.records.len() >= hit && rp.records[..hit] == records[..hit],
            "flip at byte {pos}: the {hit} records before the corrupted frame must replay intact"
        );

        // A flip inside a frame's length field can redirect where the
        // CRC trailer is *read from*, so detection there is only
        // probabilistic (2^-32) rather than guaranteed — everywhere
        // else (magic, version, kind, payload, trailer) an 8-bit burst
        // is inside the CRC's guaranteed-detection envelope and the
        // replay MUST stop with a typed error at the corrupted frame.
        let in_len_field = (starts[hit] + 8..starts[hit] + 12).contains(&pos);
        if !in_len_field {
            assert!(
                rp.tail.is_some(),
                "flip at byte {pos} (record {hit}): corruption outside the length field \
                 must be detected"
            );
            assert_eq!(
                rp.records.len(),
                hit,
                "flip at byte {pos}: replay must stop at the corrupted frame, not resync \
                 past it"
            );
            assert_eq!(rp.valid_bytes, starts[hit] as u64, "flip at byte {pos}");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Small but fully-featured artifact (multi-worker, mixed-rank tensors,
/// momentum on one side only) for corruption sweeps.
fn fixture_artifact() -> splitbrain::store::CheckpointArtifact {
    let t = |shape: Vec<usize>, v: Vec<f32>| HostTensor::f32(shape, v);
    splitbrain::store::CheckpointArtifact {
        step: 2,
        manifest_fingerprint: 0xfeed_face,
        state: ClusterState {
            step: 2,
            n_workers: 2,
            mp: 1,
            recoveries: 0,
            lost_ranks: vec![],
            fired: vec![false, true],
            global: vec![
                ("g0".into(), t(vec![2], vec![0.5, -1.5])),
                ("g1".into(), t(vec![1, 2], vec![3.25, 4.0])),
            ],
            workers: vec![
                WorkerSnapshot {
                    rank: 0,
                    conv_params: vec![t(vec![3], vec![0.5, 0.5, 0.5])],
                    fc_params: vec![t(vec![2], vec![1.5, -2.0])],
                    conv_velocity: vec![vec![0.25, 0.5, 0.75]],
                    fc_velocity: vec![],
                },
                WorkerSnapshot {
                    rank: 1,
                    conv_params: vec![t(vec![3], vec![-0.5, 0.25, 1.0])],
                    fc_params: vec![t(vec![2], vec![2.5, 0.125])],
                    conv_velocity: vec![],
                    fc_velocity: vec![vec![0.0625, -0.125]],
                },
            ],
        },
    }
}

#[test]
fn artifact_rejects_every_single_byte_flip() {
    let bytes = encode_artifact(&fixture_artifact());
    assert!(decode_artifact(&bytes).is_ok(), "clean artifact must decode");
    for pos in 0..bytes.len() {
        let mut bad = bytes.clone();
        bad[pos] ^= 0xFF;
        assert!(
            decode_artifact(&bad).is_err(),
            "artifact with byte {pos} flipped must be rejected (whole-file CRC), \
             never loaded as training state"
        );
    }
}

#[test]
fn artifact_rejects_every_truncation() {
    let bytes = encode_artifact(&fixture_artifact());
    for keep in 0..bytes.len() {
        assert!(
            decode_artifact(&bytes[..keep]).is_err(),
            "artifact truncated to {keep} bytes must be rejected"
        );
    }
}

// ---------------------------------------------------------------------
// Branch determinism sweep
// ---------------------------------------------------------------------

fn dataset() -> Arc<dyn Dataset> {
    Arc::new(SyntheticCifar::new(DATASET, SEED))
}

fn base_builder() -> SessionBuilder {
    SessionBuilder::new()
        .workers(2)
        .mp(2)
        .steps(4)
        .lr(0.02)
        .momentum(0.9)
        .clip_norm(1.0)
        .avg_period(2)
        .seed(SEED)
        .dataset_size(DATASET)
}

/// `(losses, parameter bits)` of a full run of `b`.
fn run_to_bits(b: SessionBuilder, rt: &RuntimeClient) -> (Vec<u64>, Vec<Vec<u32>>) {
    let mut s = b.dataset(dataset()).validate(rt).unwrap().start().unwrap();
    let mut losses = Vec::new();
    while !s.is_done() {
        losses.push(s.step().unwrap().loss.to_bits());
    }
    let c = s.cluster();
    let mut params = Vec::new();
    for rank in 0..c.cfg.n_workers {
        let w = c.worker(rank);
        for t in w.conv_params.iter().chain(w.fc_params.iter()) {
            params.push(t.as_f32().iter().map(|v| v.to_bits()).collect());
        }
    }
    (losses, params)
}

/// Branching a finished durable run at *every* averaging boundary is
/// deterministic: two branches cloned from the same boundary produce
/// bit-identical losses and parameters — including a branch that also
/// changes the topology (the global model re-shards to fit).
#[test]
fn branch_at_every_boundary_is_deterministic() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let src = tmp_dir("branch-src");

    let mut session = base_builder()
        .run_dir(&src)
        .dataset(dataset())
        .validate(&rt)
        .unwrap()
        .start()
        .unwrap();
    session.run().unwrap();
    drop(session);

    // steps=4, avg_period=2 ⇒ boundaries at 2 and 4, both checkpointed.
    for boundary in [2usize, 4] {
        assert!(
            src.join("checkpoints").join(format!("step-{boundary}.ckpt")).is_file(),
            "source run must have checkpointed boundary {boundary}"
        );
        let branch = || SessionBuilder::branch_from(&src, Some(boundary)).unwrap();
        let (la, pa) = run_to_bits(branch(), &rt);
        let (lb, pb) = run_to_bits(branch(), &rt);
        assert_eq!(la, lb, "branch at boundary {boundary}: losses must be bit-identical");
        assert_eq!(pa, pb, "branch at boundary {boundary}: parameters must be bit-identical");

        // Cross-topology branch: same global model, mp=1 layout.
        let retopo = || branch().mp(1).steps(2);
        let (lc, pc) = run_to_bits(retopo(), &rt);
        let (ld, pd) = run_to_bits(retopo(), &rt);
        assert_eq!(lc, ld, "re-sharded branch at boundary {boundary} must be deterministic");
        assert_eq!(pc, pd, "re-sharded branch at boundary {boundary} must be deterministic");
        assert!(lc.iter().all(|b| f64::from_bits(*b).is_finite()));
    }

    // Different boundaries clone different model states.
    let (l2, _) = run_to_bits(SessionBuilder::branch_from(&src, Some(2)).unwrap(), &rt);
    let (l4, _) = run_to_bits(SessionBuilder::branch_from(&src, Some(4)).unwrap(), &rt);
    assert_ne!(l2, l4, "branches from different boundaries must start from different state");

    std::fs::remove_dir_all(&src).ok();
}
