//! Kernel and collective parity battery.
//!
//! Every blocked/vectorized production kernel in `runtime::native` is
//! pinned **bitwise** against the retained seed oracle
//! (`runtime::native::oracle`) across an odd-shape × thread-count
//! sweep: non-multiple-of-lane `cout`/`n`, hw ∈ {4, 8, 32},
//! cin/cout ∈ {1, 3, 16, 64}, and every `--compute-threads` in 1..=8.
//! Inputs are zero-laden (every third element exactly 0.0) so the
//! removal of the seed's `if av != 0.0` skip is exercised under the
//! exact contract that makes it bitwise neutral (finite inputs, no
//! `-0.0` bias).
//!
//! The second half pins the chunk-pipelined ring collectives against
//! the round-synchronous schedule (`subchunks = 1`, the seed): bitwise
//! identical results and identical byte counters on buffers large
//! enough that the production policy actually pipelines.

use splitbrain::comm::collective::{
    allgather_cols_rank, allgather_cols_rank_pipelined, allreduce_mean_rank,
    reduce_scatter_cols_rank, reduce_scatter_cols_rank_pipelined,
    ring_allreduce_mean, ring_allreduce_mean_rank_pipelined, subchunks_for,
    CollectiveAlgo, MAX_PIPELINE_SUBCHUNKS, PIPELINE_SUBCHUNK_ELEMS,
};
use splitbrain::comm::fabric::{Fabric, Tag};
use splitbrain::runtime::native::{self, oracle};
use splitbrain::runtime::HostTensor;

/// Deterministic zero-laden value soup: every third element is exactly
/// `0.0` (exercising the dense paths' branch removal and max-pool
/// ties), the rest spread across magnitudes and signs. Finite, never
/// `-0.0`.
fn zero_laden(seed: u32, len: usize) -> Vec<f32> {
    let mut x = seed.wrapping_mul(2654435761).wrapping_add(99991);
    (0..len)
        .map(|i| {
            x = x.wrapping_mul(1664525).wrapping_add(1013904223);
            if i % 3 == 2 {
                0.0
            } else {
                ((x >> 9) as f32 / (1 << 21) as f32) - 1.0
            }
        })
        .collect()
}

fn assert_bits(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: elem {i}: {x} vs {y}");
    }
}

#[test]
fn matmul_kernels_match_oracle_across_shapes_and_threads() {
    for &(m, k, n) in &[
        (1usize, 7usize, 1usize),
        (5, 7, 16),
        (5, 40, 21),
        (8, 300, 33),
        (3, 300, 16),
        (16, 40, 1),
    ] {
        let a = zero_laden(1, m * k);
        let b = zero_laden(2, k * n);
        let g = zero_laden(3, m * n);
        for t in 1..=8usize {
            let what = format!("m={m} k={k} n={n} t={t}");
            assert_bits(
                &native::matmul_t(&a, &b, m, k, n, t),
                &oracle::matmul_t(&a, &b, m, k, n, t),
                &format!("matmul {what}"),
            );
            // tn: out[k,n] = a[m,k]ᵀ @ g[m,n] (r=m rows reduced).
            assert_bits(
                &native::matmul_tn_t(&a, &g, m, k, n, t),
                &oracle::matmul_tn_t(&a, &g, m, k, n, t),
                &format!("matmul_tn {what}"),
            );
            // nt: out[m,k] = g[m,n] @ b[k,n]ᵀ.
            assert_bits(
                &native::matmul_nt_t(&g, &b, m, n, k, t),
                &oracle::matmul_nt_t(&g, &b, m, n, k, t),
                &format!("matmul_nt {what}"),
            );
        }
    }
}

#[test]
fn conv_and_pool_kernels_match_oracle_across_shapes_and_threads() {
    for &(hw, b) in &[(4usize, 2usize), (8, 2), (32, 1)] {
        for &cin in &[1usize, 3, 16, 64] {
            for &cout in &[1usize, 3, 16, 64] {
                // The 32×32 plane with 64×64 channels is the expensive
                // corner; the small planes sweep every thread count.
                if hw == 32 && cin.max(cout) > 16 && !(cin == 64 && cout == 64) {
                    continue;
                }
                let threads: &[usize] =
                    if hw == 32 { &[1, 2, 5, 8] } else { &[1, 2, 3, 4, 5, 6, 7, 8] };
                let x = zero_laden(10 + cin as u32, b * hw * hw * cin);
                let w = zero_laden(20 + cout as u32, 9 * cin * cout);
                let bias = zero_laden(30, cout);
                let yref = oracle::conv3x3_relu_t(&x, &w, &bias, b, hw, cin, cout, 1);
                let gy = zero_laden(40, b * hw * hw * cout);
                let (gw_ref, gb_ref, gx_ref) =
                    oracle::conv3x3_bwd_t(&x, &yref, &gy, &w, b, hw, cin, cout, 1);
                for &t in threads {
                    let what = format!("hw={hw} cin={cin} cout={cout} t={t}");
                    let y = native::conv3x3_relu_t(&x, &w, &bias, b, hw, cin, cout, t);
                    assert_bits(&y, &yref, &format!("conv fwd {what}"));
                    let (gw, gb, gx) =
                        native::conv3x3_bwd_t(&x, &y, &gy, &w, b, hw, cin, cout, t);
                    assert_bits(&gw, &gw_ref, &format!("conv bwd gw {what}"));
                    assert_bits(&gb, &gb_ref, &format!("conv bwd gb {what}"));
                    assert_bits(&gx, &gx_ref, &format!("conv bwd gx {what}"));
                    // Pool fwd/bwd over the conv output (even planes).
                    let (pref, aref) = oracle::maxpool2(&y, b, hw, cout);
                    let (p, arg) = native::maxpool2_t(&y, b, hw, cout, t);
                    assert_bits(&p, &pref, &format!("pool fwd {what}"));
                    assert_eq!(arg, aref, "pool arg {what}");
                    let pg = zero_laden(50, p.len());
                    assert_bits(
                        &native::maxpool2_bwd_t(&pg, &arg, b, hw, cout, t),
                        &oracle::maxpool2_bwd(&pg, &aref, b * hw * hw * cout),
                        &format!("pool bwd {what}"),
                    );
                }
            }
        }
    }
}

#[test]
fn bias_epilogues_match_oracle_across_threads() {
    for &(rows, cols) in &[(1usize, 1usize), (7, 21), (16, 1024)] {
        let pre = zero_laden(60, rows * cols);
        let bias = zero_laden(61, cols);
        let mut plain_ref = pre.clone();
        oracle::add_bias(&mut plain_ref, &bias, rows, cols);
        let mut relu_ref = plain_ref.clone();
        for v in relu_ref.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        for t in 1..=8usize {
            let what = format!("rows={rows} cols={cols} t={t}");
            let mut p1 = pre.clone();
            native::add_bias_t(&mut p1, &bias, rows, cols, t);
            assert_bits(&p1, &plain_ref, &format!("add_bias {what}"));
            let mut p2 = pre.clone();
            native::add_bias_relu_t(&mut p2, &bias, rows, cols, t);
            assert_bits(&p2, &relu_ref, &format!("add_bias_relu {what}"));
        }
    }
}

/// Run a per-rank collective program on a scoped thread per rank.
fn per_rank<T: Send>(
    n: usize,
    f: impl Fn(usize) -> anyhow::Result<T> + Sync,
) -> Vec<T> {
    let fref = &f;
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..n).map(|gi| s.spawn(move || fref(gi))).collect();
        handles.into_iter().map(|h| h.join().unwrap().unwrap()).collect()
    })
}

#[test]
fn pipelined_flat_allreduce_matches_seed_schedule_at_scale() {
    // Large enough that the production policy pipelines at the cap.
    let n = 4usize;
    let len = 600_000usize;
    assert_eq!(subchunks_for(len / n + len % n), MAX_PIPELINE_SUBCHUNKS);
    let group: Vec<usize> = (0..n).collect();
    let inputs: Vec<Vec<f32>> = (0..n).map(|i| zero_laden(70 + i as u32, len)).collect();
    let run = |s: Option<usize>| -> (Vec<Vec<f32>>, u64) {
        let f = Fabric::new(n);
        let outs = per_rank(n, |gi| {
            let mut b = inputs[gi].clone();
            match s {
                // The production dispatch picks the depth itself.
                None => allreduce_mean_rank(CollectiveAlgo::Ring, &f, &group, gi, &mut b, 7)?,
                Some(s) => {
                    ring_allreduce_mean_rank_pipelined(&f, &group, gi, &mut b, 7, s)?
                }
            }
            Ok(b)
        });
        assert!(f.drained());
        (outs, f.total_bytes())
    };
    let (seed_outs, seed_bytes) = run(Some(1)); // the seed's schedule
    let (prod_outs, prod_bytes) = run(None);
    for (a, b) in seed_outs.iter().zip(prod_outs.iter()) {
        assert_bits(a, b, "flat allreduce policy vs seed");
    }
    assert_eq!(seed_bytes, prod_bytes);
    // Group view (sequential engine's path) agrees too.
    let f = Fabric::new(n);
    let mut bufs = inputs.clone();
    ring_allreduce_mean(&f, &group, &mut bufs, 7).unwrap();
    for (a, b) in seed_outs.iter().zip(bufs.iter()) {
        assert_bits(a, b, "flat allreduce group view vs seed");
    }
    assert_eq!(f.total_bytes(), seed_bytes);
}

#[test]
fn pipelined_column_rings_match_seed_schedule_at_scale() {
    let group = [0usize, 1, 2];
    let k = group.len();
    let rows = 64usize;
    let widths = [2000usize, 1500, 3000];
    let full_w: usize = widths.iter().sum();
    assert!(rows * 3000 > PIPELINE_SUBCHUNK_ELEMS, "must actually pipeline");
    let parts: Vec<HostTensor> = (0..k)
        .map(|i| HostTensor::f32(vec![rows, widths[i]], zero_laden(80 + i as u32, rows * widths[i])))
        .collect();
    let fulls: Vec<HostTensor> = (0..k)
        .map(|i| HostTensor::f32(vec![rows, full_w], zero_laden(90 + i as u32, rows * full_w)))
        .collect();
    // Allgather: production policy vs explicit depth 1 (the seed).
    let run_ag = |s: Option<usize>| -> (Vec<HostTensor>, u64) {
        let f = Fabric::new(k);
        let outs = per_rank(k, |gi| match s {
            None => allgather_cols_rank(
                CollectiveAlgo::Ring,
                &f,
                &group,
                gi,
                &parts[gi],
                &widths,
                Tag::new(1, 0, 0),
            ),
            Some(s) => allgather_cols_rank_pipelined(
                &f,
                &group,
                gi,
                &parts[gi],
                &widths,
                Tag::new(1, 0, 0),
                s,
            ),
        });
        assert!(f.drained());
        (outs, f.total_bytes())
    };
    let (ag_seed, agb_seed) = run_ag(Some(1));
    let (ag_prod, agb_prod) = run_ag(None);
    for (a, b) in ag_seed.iter().zip(ag_prod.iter()) {
        assert_eq!(a.shape, b.shape);
        assert_bits(a.as_f32(), b.as_f32(), "allgather policy vs seed");
    }
    assert_eq!(agb_seed, agb_prod);
    // Reduce-scatter likewise.
    let run_rs = |s: Option<usize>| -> (Vec<HostTensor>, u64) {
        let f = Fabric::new(k);
        let outs = per_rank(k, |gi| match s {
            None => reduce_scatter_cols_rank(
                CollectiveAlgo::Ring,
                &f,
                &group,
                gi,
                &fulls[gi],
                &widths,
                Tag::new(2, 0, 0),
            ),
            Some(s) => reduce_scatter_cols_rank_pipelined(
                &f,
                &group,
                gi,
                &fulls[gi],
                &widths,
                Tag::new(2, 0, 0),
                s,
            ),
        });
        assert!(f.drained());
        (outs, f.total_bytes())
    };
    let (rs_seed, rsb_seed) = run_rs(Some(1));
    let (rs_prod, rsb_prod) = run_rs(None);
    for (a, b) in rs_seed.iter().zip(rs_prod.iter()) {
        assert_eq!(a.shape, b.shape);
        assert_bits(a.as_f32(), b.as_f32(), "reduce-scatter policy vs seed");
    }
    assert_eq!(rsb_seed, rsb_prod);
}
