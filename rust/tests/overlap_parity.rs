//! Overlapped-execution parity: the overlapped executor (eager post
//! halves + double-buffered batches) must be **bit-identical** — per-step
//! losses, every parameter, and the data-plane byte counters — to the
//! strict-BSP sequential reference, across engines and transports,
//! schemes and collective algorithms, and *through* fault injection
//! (crash-at-step-k recovery and straggle plans fired mid-overlap).
//!
//! This is the tentpole invariant of the step-program refactor: overlap
//! changes only *when* payloads are posted, never their contents, tags,
//! or the fixed group order every reduce consumes them in — arrival
//! order affects wall-clock only, never the reduction tree.
//!
//! Runs on the built-in native backend (no artifacts needed).

use std::sync::Arc;

use splitbrain::api::SessionBuilder;
use splitbrain::comm::transport::TcpPeer;
use splitbrain::comm::{CollectiveAlgo, FaultPlan};
use splitbrain::coordinator::procdriver::{run_worker, ProcConfig, RunOutcome};
use splitbrain::coordinator::{Cluster, ClusterConfig, ExecEngine, McastScheme, RecoveryPolicy};
use splitbrain::data::{Dataset, SyntheticCifar};
use splitbrain::runtime::RuntimeClient;

const SEED: u64 = 123;
const DATASET: usize = 256;

/// Configs come from the typed builder; tests chain extra setters
/// before resolving with `cluster_config()`.
fn builder(n: usize, mp: usize, engine: ExecEngine, overlap: bool) -> SessionBuilder {
    SessionBuilder::new()
        .workers(n)
        .mp(mp)
        .lr(0.02)
        .momentum(0.9)
        .clip_norm(1.0)
        .avg_period(4)
        .seed(SEED)
        .dataset_size(DATASET)
        .engine(engine)
        .collectives(CollectiveAlgo::Ring)
        .overlap(overlap)
}

fn cfg(n: usize, mp: usize, engine: ExecEngine, overlap: bool) -> ClusterConfig {
    builder(n, mp, engine, overlap).cluster_config().unwrap()
}

fn dataset() -> Arc<dyn Dataset> {
    Arc::new(SyntheticCifar::new(DATASET, SEED))
}

/// Every worker's every parameter as bit patterns.
fn all_param_bits(c: &Cluster) -> Vec<Vec<u32>> {
    let mut out = Vec::new();
    for rank in 0..c.cfg.n_workers {
        let w = c.worker(rank);
        for t in w.conv_params.iter().chain(w.fc_params.iter()) {
            out.push(t.as_f32().iter().map(|v| v.to_bits()).collect());
        }
    }
    out
}

/// Step both clusters `steps` times asserting bit-equal losses, then
/// bit-equal parameters and identical per-step byte counters.
fn assert_parity(mut a: Cluster, mut b: Cluster, steps: usize, what: &str) {
    for step in 1..=steps {
        let ma = a.step().unwrap();
        let mb = b.step().unwrap();
        assert_eq!(
            ma.loss.to_bits(),
            mb.loss.to_bits(),
            "{what}: loss diverged at step {step}: {} vs {}",
            ma.loss,
            mb.loss
        );
        assert_eq!(
            a.last_fabric_bytes, b.last_fabric_bytes,
            "{what}: byte counters diverged at step {step}"
        );
    }
    let pa = all_param_bits(&a);
    let pb = all_param_bits(&b);
    assert_eq!(pa.len(), pb.len(), "{what}: parameter tensor count");
    for (i, (x, y)) in pa.iter().zip(pb.iter()).enumerate() {
        assert_eq!(x, y, "{what}: parameter tensor {i} diverged");
    }
}

/// The headline check: overlapped threaded execution (eager posts +
/// prefetch) over two MP groups is bit-identical to the strict-BSP
/// sequential reference across 10 steps (two averaging boundaries).
#[test]
fn overlap_threaded_matches_sequential_bsp_10_steps() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let seq = Cluster::with_dataset(
        &rt,
        cfg(4, 2, ExecEngine::Sequential, false),
        dataset(),
    )
    .unwrap();
    let ovl = Cluster::with_dataset(&rt, cfg(4, 2, ExecEngine::Threaded, true), dataset())
        .unwrap();
    assert_parity(seq, ovl, 10, "n=4 mp=2 overlap vs sequential BSP");
}

/// Overlap vs BSP on the *same* threaded engine: identical numerics and
/// identical per-rank wire volumes (the hoist moves posts in time, not
/// in content).
#[test]
fn overlap_matches_bsp_threaded_and_schedule_bytes() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let mut bsp =
        Cluster::with_dataset(&rt, cfg(2, 2, ExecEngine::Threaded, false), dataset()).unwrap();
    let mut ovl =
        Cluster::with_dataset(&rt, cfg(2, 2, ExecEngine::Threaded, true), dataset()).unwrap();
    let mb = bsp.step().unwrap();
    let mo = ovl.step().unwrap();
    assert_eq!(mb.loss.to_bits(), mo.loss.to_bits());
    assert_eq!(bsp.last_fabric_bytes, ovl.last_fabric_bytes);
    // And both match the analytic schedule volume exactly.
    assert_eq!(ovl.last_fabric_bytes.0, ovl.schedule.mp_bytes_per_member());
}

/// The BK scheme (single B·K round, distinct artifacts, gradient
/// rescale) and the B scheme (serialized owner) under overlap.
#[test]
fn overlap_parity_across_schemes() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    for scheme in [McastScheme::B, McastScheme::BK] {
        let ca = builder(2, 2, ExecEngine::Sequential, false)
            .scheme(scheme)
            .cluster_config()
            .unwrap();
        let cb = builder(2, 2, ExecEngine::Threaded, true)
            .scheme(scheme)
            .cluster_config()
            .unwrap();
        let seq = Cluster::with_dataset(&rt, ca, dataset()).unwrap();
        let ovl = Cluster::with_dataset(&rt, cb, dataset()).unwrap();
        assert_parity(seq, ovl, 2, &format!("scheme={scheme} overlap"));
    }
}

/// Naive all-to-all collectives under overlap (different rendezvous
/// structure inside the shard ops).
#[test]
fn overlap_parity_naive_collectives() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let ca = builder(4, 2, ExecEngine::Sequential, false)
        .collectives(CollectiveAlgo::Naive)
        .avg_period(1)
        .cluster_config()
        .unwrap();
    let cb = builder(4, 2, ExecEngine::Threaded, true)
        .collectives(CollectiveAlgo::Naive)
        .avg_period(1)
        .cluster_config()
        .unwrap();
    let seq = Cluster::with_dataset(&rt, ca, dataset()).unwrap();
    let ovl = Cluster::with_dataset(&rt, cb, dataset()).unwrap();
    assert_parity(seq, ovl, 2, "naive collectives overlap");
}

/// Elastic recovery fired mid-overlap: rank 1 of 4 crashes at step 3
/// (after the step-2 averaging checkpoint under avg_period=2); the
/// overlapped engine must shrink onto the same survivors and land on
/// the same bits as the sequential BSP reference with the same plan.
#[test]
fn overlap_crash_recovery_matches_sequential_bsp() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let ca = builder(4, 2, ExecEngine::Sequential, false)
        .avg_period(2)
        .recovery(RecoveryPolicy::ShrinkAndContinue)
        .faults(FaultPlan::new().crash(1, 3))
        .cluster_config()
        .unwrap();
    let cb = builder(4, 2, ExecEngine::Threaded, true)
        .avg_period(2)
        .recovery(RecoveryPolicy::ShrinkAndContinue)
        .faults(FaultPlan::new().crash(1, 3))
        .cluster_config()
        .unwrap();
    let mut seq = Cluster::with_dataset(&rt, ca, dataset()).unwrap();
    let mut ovl = Cluster::with_dataset(&rt, cb, dataset()).unwrap();
    for step in 1..=6 {
        let ma = seq.step().unwrap();
        let mb = ovl.step().unwrap();
        assert_eq!(
            ma.loss.to_bits(),
            mb.loss.to_bits(),
            "loss diverged at step {step} across recovery"
        );
    }
    assert_eq!(seq.recoveries, 1);
    assert_eq!(ovl.recoveries, 1);
    assert_eq!(seq.lost_ranks, vec![1]);
    assert_eq!(ovl.lost_ranks, vec![1]);
    assert_eq!(seq.cfg.n_workers, 3);
    assert_eq!(ovl.cfg.n_workers, 3);
    let pa = all_param_bits(&seq);
    let pb = all_param_bits(&ovl);
    for (i, (x, y)) in pa.iter().zip(pb.iter()).enumerate() {
        assert_eq!(x, y, "post-recovery parameter tensor {i} diverged");
    }
}

/// Straggle faults only inflate the simulated clock — never the bits —
/// and must do so identically under overlap.
#[test]
fn overlap_straggle_is_clock_only() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let plan = FaultPlan::new().straggle(0, 2, 750);
    let ca = builder(2, 2, ExecEngine::Sequential, false)
        .faults(plan.clone())
        .cluster_config()
        .unwrap();
    let cb = builder(2, 2, ExecEngine::Threaded, true)
        .faults(plan)
        .cluster_config()
        .unwrap();
    let mut seq = Cluster::with_dataset(&rt, ca, dataset()).unwrap();
    let mut ovl = Cluster::with_dataset(&rt, cb, dataset()).unwrap();
    for step in 1..=3 {
        let ma = seq.step().unwrap();
        let mb = ovl.step().unwrap();
        assert_eq!(ma.loss.to_bits(), mb.loss.to_bits(), "step {step}");
        if step == 2 {
            // Both engines charge the injected 0.75 simulated seconds.
            assert!(ma.compute_secs >= 0.75, "sequential straggle lost: {}", ma.compute_secs);
            assert!(mb.compute_secs >= 0.75, "overlap straggle lost: {}", mb.compute_secs);
        }
    }
    let pa = all_param_bits(&seq);
    let pb = all_param_bits(&ovl);
    for (x, y) in pa.iter().zip(pb.iter()) {
        assert_eq!(x, y);
    }
}

/// TCP transport with overlap *disabled* against the in-proc threaded
/// engine with overlap *enabled*: both must match the same bits (the
/// real-process overlapped TCP path is covered by `transport_parity`,
/// whose reference is the overlap-default threaded engine). Runs the
/// rank drivers on threads over loopback sockets inside this process.
#[test]
fn tcp_bsp_toggle_bit_identical_to_overlapped_threaded() {
    let (n, mp, steps) = (2usize, 2usize, 4usize);
    let rt = RuntimeClient::load("artifacts").unwrap();

    // In-proc overlapped reference.
    let mut cluster =
        Cluster::with_dataset(&rt, cfg(n, mp, ExecEngine::Threaded, true), dataset()).unwrap();
    let mut ref_losses: Vec<Vec<u64>> = Vec::new();
    for _ in 0..steps {
        cluster.step().unwrap();
        let rounds = cluster.cfg.scheme.rounds(cluster.cfg.mp.max(1)) as f64;
        ref_losses.push(
            (0..n).map(|r| (cluster.worker(r).loss_acc / rounds).to_bits()).collect(),
        );
    }

    // In-process TCP mesh, overlap off.
    let peers: Vec<TcpPeer> = {
        let listeners: Vec<std::net::TcpListener> = (0..n)
            .map(|_| std::net::TcpListener::bind("127.0.0.1:0").unwrap())
            .collect();
        listeners
            .iter()
            .enumerate()
            .map(|(opid, l)| TcpPeer { opid, addr: l.local_addr().unwrap().to_string() })
            .collect()
    };
    let out_dir = std::env::temp_dir()
        .join(format!("splitbrain-overlap-parity-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&out_dir);
    std::fs::create_dir_all(&out_dir).unwrap();
    let tcp_cfg = cfg(n, mp, ExecEngine::Threaded, false);
    let outcomes: Vec<RunOutcome> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..n)
            .map(|opid| {
                let pc = ProcConfig {
                    cluster: tcp_cfg.clone(),
                    steps,
                    opid,
                    peers: peers.clone(),
                    artifacts: "artifacts".to_string(),
                    out_dir: Some(out_dir.clone()),
                    connect_timeout_ms: 30_000,
                    log_every: 0,
                    run_dir: None,
                    resume_step: 0,
                    trace: false,
                };
                s.spawn(move || run_worker(&pc).unwrap())
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert!(outcomes.iter().all(|o| *o == RunOutcome::Completed));

    for opid in 0..n {
        let meta =
            std::fs::read_to_string(out_dir.join(format!("opid{opid}.meta"))).unwrap();
        let mut seen = 0usize;
        for line in meta.lines() {
            let mut it = line.split_whitespace();
            if it.next() == Some("loss") {
                let step: usize = it.next().unwrap().parse().unwrap();
                let bits = u64::from_str_radix(it.next().unwrap(), 16).unwrap();
                assert_eq!(
                    bits,
                    ref_losses[step - 1][opid],
                    "opid {opid}: TCP/BSP loss bits diverged at step {step}"
                );
                seen += 1;
            }
        }
        assert_eq!(seen, steps, "opid {opid} must record every step");
        // Final parameters bitwise equal to the in-proc worker's.
        let ckpt = splitbrain::train::checkpoint::load(
            out_dir.join(format!("opid{opid}.ckpt")),
        )
        .unwrap();
        let w = cluster.worker(opid);
        let inproc: Vec<Vec<u32>> = w
            .conv_params
            .iter()
            .chain(w.fc_params.iter())
            .map(|t| t.as_f32().iter().map(|v| v.to_bits()).collect())
            .collect();
        assert_eq!(ckpt.len(), inproc.len());
        for (i, ((_, t), b)) in ckpt.iter().zip(inproc.iter()).enumerate() {
            let got: Vec<u32> = t.as_f32().iter().map(|v| v.to_bits()).collect();
            assert_eq!(&got, b, "opid {opid}: parameter tensor {i} diverged");
        }
    }
    let _ = std::fs::remove_dir_all(&out_dir);
}
