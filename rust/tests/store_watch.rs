//! The watcher battery: `LogFollower` tail semantics (torn-tail
//! re-probe, truncate-for-resume reset), the `Watcher`'s typed
//! `RunStatus` fold and liveness rules, the pinned `splitbrain watch
//! --once` snapshot over the blessed golden run dir, and the
//! end-to-end SIGKILL → `Dead` → `--resume` → `Running`-with-lineage
//! flow against a real multi-process launch.

use std::path::{Path, PathBuf};
use std::process::Command;
use std::time::{Duration, Instant, SystemTime};

use splitbrain::api::{
    Liveness, RecoveryInfo, RunInfo, RunSummary, StepReport, Watcher,
};
use splitbrain::comm::CollectiveAlgo;
use splitbrain::coordinator::ExecEngine;
use splitbrain::store::{replay, LogFollower, LogRecord, LogWriter, StoreError};

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_splitbrain")
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sb-watch-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A step record with exactly-representable floats (no rounding drift
/// in the assertions).
fn step(step: usize, loss: f64) -> LogRecord {
    LogRecord::Step(StepReport {
        step,
        loss,
        compute_secs: 0.5,
        mp_comm_secs: 0.0625,
        dp_comm_secs: 0.0,
        wall_secs: 0.25,
        bytes_busiest_rank: 1024,
        bytes_total: 4096,
    })
}

fn append_raw(path: &Path, bytes: &[u8]) {
    use std::io::Write as _;
    let mut f = std::fs::OpenOptions::new().append(true).open(path).unwrap();
    f.write_all(bytes).unwrap();
}

// ---------------------------------------------------------------- follower

#[test]
fn follower_delivers_incrementally_exactly_once() {
    let dir = tmp_dir("incremental");
    let path = dir.join("events.log");
    let mut fl = LogFollower::new(&path);
    // Before the writer creates the file: empty, not an error.
    let p = fl.poll().unwrap();
    assert!(p.records.is_empty() && !p.reset && p.corrupt.is_none());

    let mut w = LogWriter::create(&path).unwrap();
    w.append(&step(1, 2.5)).unwrap();
    let p = fl.poll().unwrap();
    assert_eq!(p.records, vec![step(1, 2.5)]);
    assert!(!p.reset);
    w.append(&step(2, 2.25)).unwrap();
    w.append(&step(3, 2.0)).unwrap();
    let p = fl.poll().unwrap();
    assert_eq!(p.records, vec![step(2, 2.25), step(3, 2.0)], "only the new records");
    // Quiescent writer: nothing re-delivered, frontier == file length.
    let p = fl.poll().unwrap();
    assert!(p.records.is_empty() && !p.reset && p.corrupt.is_none());
    assert_eq!(p.frontier, std::fs::metadata(&path).unwrap().len());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn torn_tail_is_reprobed_then_delivered_exactly_once() {
    let dir = tmp_dir("torn");
    let path = dir.join("events.log");
    let mut w = LogWriter::create(&path).unwrap();
    w.append(&step(1, 2.5)).unwrap();
    let mut fl = LogFollower::new(&path);
    assert_eq!(fl.poll().unwrap().records.len(), 1);

    // Simulate the writer caught mid-append: half of record 2's bytes.
    let bytes = step(2, 2.25).encode();
    let (head, tail) = bytes.split_at(bytes.len() / 2);
    append_raw(&path, head);
    for _ in 0..3 {
        let p = fl.poll().unwrap();
        assert!(p.records.is_empty(), "a torn tail must never be delivered");
        assert!(p.corrupt.is_none(), "a torn tail is awaited, not corruption");
        assert!(!p.reset, "a torn tail is not a rewrite");
    }
    // The writer finishes the record: delivered exactly once.
    append_raw(&path, tail);
    let p = fl.poll().unwrap();
    assert_eq!(p.records, vec![step(2, 2.25)]);
    assert!(fl.poll().unwrap().records.is_empty(), "never re-delivered");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn frontier_corruption_is_reported_and_never_skipped() {
    let dir = tmp_dir("corrupt");
    let path = dir.join("events.log");
    let mut w = LogWriter::create(&path).unwrap();
    w.append(&step(1, 2.5)).unwrap();
    w.append(&step(2, 2.25)).unwrap();
    drop(w);
    // Flip one byte in the middle of record 2.
    let rp = replay(&path).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = ((rp.offsets[1].0 + rp.offsets[1].1) / 2) as usize;
    bytes[mid] ^= 0xFF;
    std::fs::write(&path, &bytes).unwrap();

    let mut fl = LogFollower::new(&path);
    let p = fl.poll().unwrap();
    assert_eq!(p.records, vec![step(1, 2.5)], "the clean prefix still arrives");
    assert!(p.corrupt.is_some(), "the flipped byte must surface");
    let frontier = p.frontier;
    let p = fl.poll().unwrap();
    assert!(p.records.is_empty());
    assert!(p.corrupt.is_some(), "corruption is re-reported, not forgotten");
    assert_eq!(p.frontier, frontier, "the follower never advances past corruption");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn truncate_for_resume_triggers_clean_rereplay() {
    let dir = tmp_dir("reset");
    let path = dir.join("events.log");
    let mut w = LogWriter::create(&path).unwrap();
    for s in 1..=4 {
        w.append(&step(s, 3.0 - s as f64 * 0.25)).unwrap();
    }
    let mut fl = LogFollower::new(&path);
    assert_eq!(fl.poll().unwrap().records.len(), 4);
    drop(w);

    // The resume cut: keep records 1-2, then append a new incarnation
    // that regrows *past* the old frontier — length alone looks like a
    // plain append, only the rewritten bytes reveal the cut.
    let rp = replay(&path).unwrap();
    let mut w = LogWriter::open_truncated(&path, rp.offsets[1].1).unwrap();
    w.append(&LogRecord::Resumed { step: 2 }).unwrap();
    w.append(&step(3, 9.0)).unwrap();
    w.append(&step(4, 9.5)).unwrap();
    w.append(&step(5, 10.0)).unwrap();
    assert!(
        std::fs::metadata(&path).unwrap().len() > rp.valid_bytes,
        "fixture sanity: the log regrew past the follower's old frontier"
    );
    let p = fl.poll().unwrap();
    assert!(p.reset, "rewritten history must trigger a reset, not divergence");
    assert_eq!(p.records.len(), 6, "a reset re-replays the whole new history");
    assert_eq!(p.records[2], LogRecord::Resumed { step: 2 });
    assert_eq!(p.records[5], step(5, 10.0));
    drop(w);

    // A cut exactly at the follower's frontier is NOT a rewrite: the
    // follower continues seamlessly.
    let mut fl2 = LogFollower::new(&path);
    fl2.poll().unwrap();
    let rp = replay(&path).unwrap();
    let mut w2 = LogWriter::open_truncated(&path, rp.valid_bytes).unwrap();
    w2.append(&step(6, 1.0)).unwrap();
    let p = fl2.poll().unwrap();
    assert!(!p.reset);
    assert_eq!(p.records, vec![step(6, 1.0)]);
    drop(w2);

    // Shrink-only rewrite (frontier goes backwards, no regrowth).
    let rp = replay(&path).unwrap();
    drop(LogWriter::open_truncated(&path, rp.offsets[0].1).unwrap());
    let p = fl2.poll().unwrap();
    assert!(p.reset);
    assert_eq!(p.records.len(), 1);
    assert_eq!(p.records[0], step(1, 2.75));
    std::fs::remove_dir_all(&dir).ok();
}

// ----------------------------------------------------------------- watcher

/// The blessed golden log's records (mirrors `store_format`): one of
/// every kind.
fn golden_like_records() -> Vec<LogRecord> {
    vec![
        LogRecord::RunStarted(RunInfo {
            n_workers: 4,
            mp: 2,
            n_groups: 2,
            batch: 32,
            steps: 4,
            lr: 0.125,
            avg_period: 2,
            engine: ExecEngine::Threaded,
            collectives: CollectiveAlgo::Ring,
            overlap: true,
            param_mb: 13.5,
            total_mb: 29.75,
        }),
        LogRecord::Step(StepReport {
            step: 1,
            loss: 2.25,
            compute_secs: 0.5,
            mp_comm_secs: 0.0625,
            dp_comm_secs: 0.0,
            wall_secs: 0.25,
            bytes_busiest_rank: 65536,
            bytes_total: 262144,
        }),
        LogRecord::Checkpoint { step: 2, file: "step-2.ckpt".into(), fingerprint: 0x1234 },
        LogRecord::Recovered(RecoveryInfo {
            step: 3,
            lost_ranks: vec![3],
            n_workers: 3,
            mp: 1,
            restore_step: 2,
        }),
        LogRecord::Resumed { step: 2 },
        LogRecord::RunCompleted(RunSummary {
            steps: 4,
            images_per_sec: 512.0,
            comm_fraction: 0.25,
            recoveries: 1,
            lost_ranks: vec![3],
            n_workers: 3,
            mp: 1,
            last_checkpoint_step: 4,
        }),
    ]
}

#[test]
fn watcher_folds_records_into_typed_status() {
    let dir = tmp_dir("fold");
    let mut w = LogWriter::create(dir.join("events.log")).unwrap();
    for r in golden_like_records() {
        w.append(&r).unwrap();
    }
    let mut watcher = Watcher::open(&dir).unwrap();
    let delta = watcher.poll().unwrap();
    assert_eq!(delta.new_records, 6);
    assert!(!delta.reset);
    let st = watcher.status();
    assert_eq!((st.steps_done, st.steps_planned), (4, 4));
    assert_eq!(st.tail.last().unwrap().loss, 2.25);
    assert_eq!((st.bytes_busiest, st.bytes_total), (65536, 262144));
    assert_eq!((st.n_workers, st.mp), (3, 1), "membership tracks the shrink");
    assert_eq!((st.recoveries, st.lost_ranks.clone()), (1, vec![3]));
    assert_eq!(st.checkpoints, vec![(2, "step-2.ckpt".to_string())]);
    assert_eq!(st.resumes, vec![2]);
    assert!(st.summary.is_some() && st.corrupt.is_none());
    // 32 batch × 4 launch workers × 1 tail step / 0.25 s — exact.
    assert_eq!(st.images_per_sec_wall(), Some(512.0));
    assert_eq!(watcher.liveness(), Liveness::Completed, "summary trumps staleness");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn watcher_open_is_read_only_and_demands_a_run_dir() {
    let dir = tmp_dir("readonly");
    // An existing dir with neither events.log nor run.json: not a run.
    assert!(matches!(Watcher::open(&dir), Err(StoreError::NotARunDir(_))));
    assert!(matches!(Watcher::open(dir.join("nope")), Err(StoreError::NotARunDir(_))));
    // run.json alone (a created-but-never-started run) is watchable…
    std::fs::write(dir.join("run.json"), "{}").unwrap();
    let mut watcher = Watcher::open(&dir).unwrap();
    watcher.poll().unwrap();
    // …and watching must not create anything (no checkpoints/ mkdir,
    // no events.log, no sweep side effects).
    let mut entries: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .flatten()
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .collect();
    entries.sort();
    assert_eq!(entries, vec!["run.json".to_string()]);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn liveness_classification_rules() {
    let dir = tmp_dir("liveness");
    let mut w = LogWriter::create(dir.join("events.log")).unwrap();
    w.append(&step(1, 2.5)).unwrap();
    let mut watcher = Watcher::open(&dir).unwrap();
    watcher.poll().unwrap();
    let now = SystemTime::now();
    // Fresh frontier, no pid files (an in-proc run): running.
    assert_eq!(watcher.liveness_at(now), Liveness::Running);
    // Stale past the stall threshold (10 s default): stalled — the
    // workers are not *confirmed* dead. Past the dead threshold
    // (120 s): dead.
    assert_eq!(watcher.liveness_at(now + Duration::from_secs(30)), Liveness::Stalled);
    assert_eq!(watcher.liveness_at(now + Duration::from_secs(3600)), Liveness::Dead);

    if Path::new("/proc").is_dir() {
        // A pid file naming a live pid (ours): running while fresh,
        // but a pid that *looks* alive is distrusted once the frontier
        // is stale past the dead threshold — it may be recycled.
        std::fs::write(dir.join("opid0.pid"), format!("{}\n", std::process::id())).unwrap();
        assert_eq!(watcher.liveness_at(now), Liveness::Running);
        assert_eq!(watcher.liveness_at(now + Duration::from_secs(3600)), Liveness::Dead);
        // Every recorded pid confirmed gone → dead immediately, no
        // staleness wait: clean exits remove their pid files, so
        // all-dead means SIGKILL.
        std::fs::write(dir.join("opid0.pid"), "999999999\n").unwrap();
        assert_eq!(watcher.liveness_at(now), Liveness::Dead);
        std::fs::remove_file(dir.join("opid0.pid")).unwrap();
    }

    // A RunCompleted summary is terminal whatever the clock says.
    w.append(&LogRecord::RunCompleted(RunSummary {
        steps: 1,
        images_per_sec: 0.0,
        comm_fraction: 0.0,
        recoveries: 0,
        lost_ranks: vec![],
        n_workers: 2,
        mp: 1,
        last_checkpoint_step: 0,
    }))
    .unwrap();
    watcher.poll().unwrap();
    assert_eq!(watcher.liveness_at(now + Duration::from_secs(3600)), Liveness::Completed);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn watcher_survives_the_resume_cut() {
    let dir = tmp_dir("watch-reset");
    let path = dir.join("events.log");
    let mut w = LogWriter::create(&path).unwrap();
    for s in 1..=4 {
        w.append(&step(s, 2.0)).unwrap();
    }
    let mut watcher = Watcher::open(&dir).unwrap();
    watcher.poll().unwrap();
    assert_eq!(watcher.status().steps_done, 4);
    drop(w);
    // Resume cut to step 2 + a new incarnation: the status must be
    // rebuilt, not blended (steps_done would stick at 4 if stale state
    // survived a shrink to step 3).
    let rp = replay(&path).unwrap();
    let mut w = LogWriter::open_truncated(&path, rp.offsets[1].1).unwrap();
    w.append(&LogRecord::Resumed { step: 2 }).unwrap();
    w.append(&step(3, 1.5)).unwrap();
    let delta = watcher.poll().unwrap();
    assert!(delta.reset);
    let st = watcher.status();
    assert_eq!(st.steps_done, 3, "rebuilt from the rewritten history");
    assert_eq!(st.resumes, vec![2], "the lineage shows the resume");
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------- CLI snapshot

/// `splitbrain watch --once` over the blessed golden run dir prints a
/// pinned snapshot — the CLI render is part of the format contract.
#[test]
fn watch_once_pins_the_golden_run_dir_snapshot() {
    let golden = concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/golden/run_dir");
    let out = Command::new(bin()).args(["watch", golden, "--once"]).output().unwrap();
    assert!(
        out.status.success(),
        "watch --once failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let got = String::from_utf8(out.stdout).unwrap();
    let want = format!(
        "run dir: {golden}\n\
         status:  completed\n\
         config:  4 workers, mp=2 (2 groups), B=32, engine=threaded, collectives=ring, overlap=true\n\
         steps:   4/4 (100.0%)\n\
         loss:    2.2500 (step 1)\n\
         rate:    512.0 images/sec (wall)\n\
         bytes:   65536 busiest rank / 262144 total\n\
         cluster: 3 workers, mp=1, recoveries=1 (lost ranks [3])\n\
         ckpts:   1 (latest step 2)\n\
         lineage: resumed at step 2\n"
    );
    assert_eq!(got, want, "the watch --once snapshot drifted from the blessed run dir");
    // Watching is read-only: the blessed fixture must hold exactly its
    // two committed files afterwards.
    let mut entries: Vec<String> = std::fs::read_dir(golden)
        .unwrap()
        .flatten()
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .collect();
    entries.sort();
    assert_eq!(entries, vec!["events.log".to_string(), "step-2.ckpt".to_string()]);
}

// ------------------------------------------------- end-to-end kill/resume

fn launch_args(dir: &Path, resume: bool) -> Vec<String> {
    let mut v: Vec<String> = [
        "launch",
        "--workers", "4",
        "--mp", "2",
        "--steps", "6",
        "--avg-period", "2",
        "--lr", "0.02",
        "--momentum", "0.9",
        "--clip-norm", "1.0",
        "--seed", "123",
        "--dataset-size", "256",
        "--take-timeout-ms", "120000",
        "--log-every", "2",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    v.push("--run-dir".into());
    v.push(dir.display().to_string());
    if resume {
        v.push("--resume".into());
    }
    v
}

/// The acceptance flow: a SIGKILL'd `launch` is classified `Dead`;
/// after `--resume` the *same* watcher (no re-open) follows the resume
/// cut and observes the new incarnation `Running` with `Resumed`
/// lineage, then `Completed`.
#[test]
fn launch_sigkill_is_dead_then_resume_runs_with_lineage() {
    if !Path::new("/proc").is_dir() {
        eprintln!("skipping: pid-file liveness needs /proc");
        return;
    }
    let n = 4usize;
    let dir = tmp_dir("launch");
    let mut launcher = Command::new(bin()).args(launch_args(&dir, false)).spawn().unwrap();
    // Wait for every worker's step-2 checkpoint (the resume point).
    let ckpt_set = |step: usize| {
        (0..n).all(|opid| {
            dir.join("checkpoints").join(format!("step-{step}.opid-{opid}.ckpt")).is_file()
        })
    };
    let deadline = Instant::now() + Duration::from_secs(120);
    while !ckpt_set(2) {
        assert!(Instant::now() < deadline, "step-2 checkpoint set never appeared");
        if let Ok(Some(s)) = launcher.try_wait() {
            panic!("launch exited before the kill: {s:?}");
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    let mut watcher = Watcher::open(&dir).unwrap();
    watcher.poll().unwrap();
    assert_eq!(watcher.liveness(), Liveness::Running, "a live launch reads as running");
    assert!(watcher.status().resumes.is_empty());

    // SIGKILL the launcher and every worker (the pid files the workers
    // wrote are exactly what the watcher will distrust afterwards).
    launcher.kill().ok();
    for opid in 0..n {
        let pid = std::fs::read_to_string(dir.join(format!("opid{opid}.pid")))
            .unwrap_or_else(|e| panic!("opid {opid} pid file missing: {e}"));
        let _ = Command::new("kill").args(["-9", pid.trim()]).status();
    }
    launcher.wait().ok();

    // All recorded pids gone → Dead (give the kernel a moment to reap).
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        watcher.poll().unwrap();
        if watcher.liveness() == Liveness::Dead {
            break;
        }
        assert!(Instant::now() < deadline, "SIGKILL'd launch never classified dead");
        std::thread::sleep(Duration::from_millis(100));
    }

    // Resume in the background; the same watcher must observe the new
    // incarnation running with the Resumed marker in its lineage.
    let mut resumer = Command::new(bin()).args(launch_args(&dir, true)).spawn().unwrap();
    let deadline = Instant::now() + Duration::from_secs(120);
    let mut saw_running_with_lineage = false;
    let mut resumer_done = false;
    while !resumer_done {
        assert!(Instant::now() < deadline, "resumed launch never finished");
        resumer_done = matches!(resumer.try_wait(), Ok(Some(_)));
        watcher.poll().unwrap();
        if !watcher.status().resumes.is_empty() && watcher.liveness() == Liveness::Running {
            saw_running_with_lineage = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    assert!(
        saw_running_with_lineage,
        "never observed Running with Resumed lineage mid-resume (resumes={:?})",
        watcher.status().resumes
    );
    let status = resumer.wait().unwrap();
    assert!(status.success(), "resumed launch must exit cleanly: {status:?}");

    watcher.poll().unwrap();
    assert_eq!(watcher.liveness(), Liveness::Completed);
    let st = watcher.status();
    assert_eq!(st.steps_done, 6, "the resumed run finished all steps");
    assert_eq!(st.resumes.len(), 1, "exactly one Resumed marker: {:?}", st.resumes);
    assert!(st.resumes[0] >= 2 && st.resumes[0] % 2 == 0, "resumed at a boundary");
    std::fs::remove_dir_all(&dir).ok();
}
