//! Golden on-disk format pin for the durable store: the exact bytes of
//! a fixed `events.log` (one record of every kind) and a fixed
//! checkpoint artifact are blessed into `tests/golden/run_dir/` —
//! any encoding drift (field order, a widened integer, a changed CRC
//! span) fails these tests with a byte diff, because files written by
//! an older build must stay readable forever.
//!
//! To re-bless after an *intentional* format change (which must also
//! bump `LOG_VERSION` / the artifact version so old files keep
//! decoding):
//!
//! ```bash
//! SPLITBRAIN_BLESS=1 cargo test store_format -q   # rewrites the files
//! git diff rust/tests/golden/run_dir/             # review the drift!
//! ```

use splitbrain::api::{RecoveryInfo, RunInfo, RunSummary, StepReport};
use splitbrain::comm::CollectiveAlgo;
use splitbrain::coordinator::worker::WorkerSnapshot;
use splitbrain::coordinator::{ClusterState, ExecEngine};
use splitbrain::runtime::HostTensor;
use splitbrain::store::ckpt::{decode_artifact, encode_artifact, fnv1a};
use splitbrain::store::{replay, CheckpointArtifact, LogRecord};

/// FNV-1a of the blessed artifact bytes — the value a log `Checkpoint`
/// record would carry for it. Pinned so the fingerprint function itself
/// cannot drift silently.
const GOLDEN_ARTIFACT_FNV1A: u64 = 0x0f57_10e9_5b37_3bd1;

fn golden_path(name: &str) -> std::path::PathBuf {
    std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/golden/run_dir"))
        .join(name)
}

/// One record of every kind, every float exactly representable so the
/// fixture is independent of decimal-to-binary rounding.
fn golden_records() -> Vec<LogRecord> {
    vec![
        LogRecord::RunStarted(RunInfo {
            n_workers: 4,
            mp: 2,
            n_groups: 2,
            batch: 32,
            steps: 4,
            lr: 0.125,
            avg_period: 2,
            engine: ExecEngine::Threaded,
            collectives: CollectiveAlgo::Ring,
            overlap: true,
            param_mb: 13.5,
            total_mb: 29.75,
        }),
        LogRecord::Step(StepReport {
            step: 1,
            loss: 2.25,
            compute_secs: 0.5,
            mp_comm_secs: 0.0625,
            dp_comm_secs: 0.0,
            wall_secs: 0.25,
            bytes_busiest_rank: 65536,
            bytes_total: 262144,
        }),
        LogRecord::Checkpoint {
            step: 2,
            file: "step-2.ckpt".into(),
            fingerprint: 0x1234_5678_9abc_def0,
        },
        LogRecord::Recovered(RecoveryInfo {
            step: 3,
            lost_ranks: vec![3],
            n_workers: 3,
            mp: 1,
            restore_step: 2,
        }),
        LogRecord::Resumed { step: 2 },
        LogRecord::RunCompleted(RunSummary {
            steps: 4,
            images_per_sec: 512.0,
            comm_fraction: 0.25,
            recoveries: 1,
            lost_ranks: vec![3],
            n_workers: 3,
            mp: 1,
            last_checkpoint_step: 4,
        }),
    ]
}

fn golden_artifact() -> CheckpointArtifact {
    let t = |shape: Vec<usize>, v: Vec<f32>| HostTensor::f32(shape, v);
    CheckpointArtifact {
        step: 2,
        manifest_fingerprint: 0xfeed_face,
        state: ClusterState {
            step: 2,
            n_workers: 2,
            mp: 1,
            recoveries: 0,
            lost_ranks: vec![],
            fired: vec![false, true],
            global: vec![
                ("g0".into(), t(vec![2], vec![0.5, -1.5])),
                ("g1".into(), t(vec![1, 2], vec![3.25, 4.0])),
            ],
            workers: vec![
                WorkerSnapshot {
                    rank: 0,
                    conv_params: vec![t(vec![3], vec![0.5, 0.5, 0.5])],
                    fc_params: vec![t(vec![2], vec![1.5, -2.0])],
                    conv_velocity: vec![vec![0.25, 0.5, 0.75]],
                    fc_velocity: vec![],
                },
                WorkerSnapshot {
                    rank: 1,
                    conv_params: vec![t(vec![3], vec![-0.5, 0.25, 1.0])],
                    fc_params: vec![t(vec![2], vec![2.5, 0.125])],
                    conv_velocity: vec![],
                    fc_velocity: vec![vec![0.0625, -0.125]],
                },
            ],
        },
    }
}

fn check_golden(name: &str, encoded: &[u8]) {
    let path = golden_path(name);
    if std::env::var("SPLITBRAIN_BLESS").is_ok() {
        std::fs::write(&path, encoded).unwrap();
        return;
    }
    let blessed = std::fs::read(&path)
        .expect("missing golden file — run with SPLITBRAIN_BLESS=1 to create it");
    assert_eq!(
        encoded,
        &blessed[..],
        "{name}: encoding drifted from the blessed v1 bytes. Old run dirs must stay \
         readable; if the change is intentional, bump the format version, keep the v1 \
         decode path, and re-bless with SPLITBRAIN_BLESS=1."
    );
}

#[test]
fn golden_event_log_bytes() {
    let encoded: Vec<u8> = golden_records().iter().flat_map(|r| r.encode()).collect();
    check_golden("events.log", &encoded);
}

#[test]
fn golden_event_log_decodes() {
    let rp = replay(golden_path("events.log")).unwrap();
    assert!(rp.tail.is_none(), "blessed log must replay cleanly: {:?}", rp.tail);
    assert_eq!(rp.records, golden_records());
    // The blessed lineage also pins the resume-cut semantics: the cut
    // is a *prefix* — everything from the first record past step 2
    // (the step-3 recovery) is dropped, later low-step records
    // included.
    let kept = rp.records_until_step(2);
    assert_eq!(kept.len(), 3, "RunStarted + step-1 Step + step-2 Checkpoint");
    assert!(matches!(kept.last(), Some(LogRecord::Checkpoint { step: 2, .. })));
    assert_eq!(rp.cut_for_step(2), rp.offsets[3].0, "cut lands at the recovery record");
}

#[test]
fn golden_artifact_bytes() {
    let encoded = encode_artifact(&golden_artifact());
    check_golden("step-2.ckpt", &encoded);
    assert_eq!(
        fnv1a(&encoded),
        GOLDEN_ARTIFACT_FNV1A,
        "artifact fingerprint drifted — event logs name checkpoints by this value"
    );
}

#[test]
fn golden_artifact_decodes() {
    let bytes = std::fs::read(golden_path("step-2.ckpt")).unwrap();
    let art = decode_artifact(&bytes).unwrap();
    let want = golden_artifact();
    assert_eq!(art.step, want.step);
    assert_eq!(art.manifest_fingerprint, want.manifest_fingerprint);
    assert_eq!(art.state, want.state);
}
