//! End-to-end cluster integration through the segment runtime: the
//! decomposition theorem (hybrid DP x MP == monolithic SGD),
//! convergence, GMP averaging, and the analytic-vs-measured
//! communication cross-check.
//!
//! Runs on the built-in native backend; an `artifacts/` directory (from
//! `python -m compile.aot`) overrides the manifest when present.

use std::sync::Arc;

use splitbrain::api::SessionBuilder;
use splitbrain::coordinator::{Cluster, ClusterConfig};
use splitbrain::data::{BatchIter, Dataset, SyntheticCifar};
use splitbrain::runtime::{HostTensor, RuntimeClient};

// The runtime falls back to the built-in native backend when no
// artifacts directory exists, so these tests always run.
fn runtime() -> Option<RuntimeClient> {
    match RuntimeClient::load("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP: runtime unavailable ({e:#})");
            None
        }
    }
}

/// Base builder: plain SGD (momentum 0, clipping off) so the one-step
/// decomposition algebra holds exactly. Engine/collective defaults
/// (threaded + ring) — the engine_parity suite asserts they are
/// bit-identical to the sequential reference.
fn builder(n: usize, mp: usize) -> SessionBuilder {
    SessionBuilder::new()
        .workers(n)
        .mp(mp)
        .lr(0.02)
        .momentum(0.0)
        .clip_norm(0.0)
        .avg_period(4)
        .seed(99)
        .dataset_size(512)
}

fn cfg(n: usize, mp: usize) -> ClusterConfig {
    builder(n, mp).cluster_config().unwrap()
}

/// Multi-step training config. The seed ran these tests with
/// `clip_norm: 0.0`, which diverges within a handful of steps — VGG
/// without batch norm is unstable at practical learning rates, which is
/// exactly why the trainer (§4) uses global-norm clipping (see
/// `train::sgd`). The one-step decomposition tests keep plain SGD
/// (`cfg`), where the `init - lr·g` algebra must hold exactly.
fn cfg_train(n: usize, mp: usize) -> ClusterConfig {
    builder(n, mp).clip_norm(1.0).cluster_config().unwrap()
}

fn dataset() -> Arc<dyn Dataset> {
    Arc::new(SyntheticCifar::new(512, 99))
}

/// The decomposition theorem, end-to-end through PJRT (mirrors the
/// python test_hybrid_matches_monolithic, but via the Rust coordinator
/// and the AOT artifacts).
#[test]
fn hybrid_step_matches_monolithic_sgd() {
    let Some(rt) = runtime() else { return };
    let b = rt.manifest.batch;

    // --- hybrid cluster: n=2, mp=2, one step ---
    let mut hybrid = Cluster::with_dataset(&rt, cfg(2, 2), dataset()).unwrap();
    let init_conv = hybrid.worker(0).conv_params.clone();
    let init_fc_full = hybrid.reconstruct_full_fc(0);
    hybrid.step().unwrap();

    // --- reference: full_step per worker batch with identical init ---
    // The workers' batches are reproducible from the same iterator setup.
    let data = dataset();
    let mut grads_per_worker = Vec::new();
    for rank in 0..2 {
        let mut it = BatchIter::new(data.clone(), b, rank, 2, 99);
        let batch = it.next_batch();
        let mut inputs: Vec<HostTensor> = init_conv.to_vec();
        inputs.extend(init_fc_full.iter().cloned());
        inputs.push(batch.images.clone());
        inputs.push(batch.labels.clone());
        let out = rt.run("full_step", &inputs).unwrap();
        grads_per_worker.push(out);
    }

    // (1) conv params of hybrid worker i == init - lr * own-batch grads.
    let lr = 0.02f32;
    for rank in 0..2 {
        for (pi, p0) in init_conv.iter().enumerate() {
            let got = &hybrid.worker(rank).conv_params[pi];
            let g = &grads_per_worker[rank][1 + pi];
            let max_err = got
                .as_f32()
                .iter()
                .zip(p0.as_f32().iter().zip(g.as_f32().iter()))
                .map(|(got, (p, g))| (got - (p - lr * g)).abs())
                .fold(0.0f32, f32::max);
            assert!(max_err < 5e-4, "worker {rank} conv[{pi}] err {max_err}");
        }
    }

    // (2) reconstructed FC params == init - lr * mean(worker grads).
    // (The hybrid FC gradient over K modulo iterations averages the
    // group's 2B examples = the mean of the two full_step grads.)
    let fc_after = hybrid.reconstruct_full_fc(0);
    for (fi, f0) in init_fc_full.iter().enumerate() {
        let ga = grads_per_worker[0][15 + fi].as_f32();
        let gb = grads_per_worker[1][15 + fi].as_f32();
        let got = fc_after[fi].as_f32();
        let max_err = got
            .iter()
            .enumerate()
            .map(|(i, v)| (v - (f0.as_f32()[i] - lr * 0.5 * (ga[i] + gb[i]))).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 5e-4, "fc[{fi}] err {max_err}");
    }
}

#[test]
fn losses_match_between_hybrid_and_pure_dp_at_step_one() {
    let Some(rt) = runtime() else { return };
    // Same seed -> same init and same per-worker batches; the first
    // step's mean loss must agree (before any averaging divergence).
    let mut a = Cluster::with_dataset(&rt, cfg(2, 2), dataset()).unwrap();
    let mut b = Cluster::with_dataset(&rt, cfg(2, 1), dataset()).unwrap();
    let la = a.step().unwrap().loss;
    let lb = b.step().unwrap().loss;
    assert!((la - lb).abs() < 1e-4, "hybrid {la} vs dp {lb}");
}

#[test]
fn loss_decreases_on_synthetic_task() {
    let Some(rt) = runtime() else { return };
    let mut cluster = Cluster::with_dataset(&rt, cfg_train(2, 2), dataset()).unwrap();
    let report = cluster.train_steps(12).unwrap();
    let first = report.losses[0];
    let last = report.tail_loss(3).unwrap();
    assert!(
        last < first * 0.8,
        "loss should fall: first {first}, tail {last} ({:?})",
        report.losses
    );
}

#[test]
fn averaging_keeps_replicated_params_in_sync() {
    let Some(rt) = runtime() else { return };
    let mut c = Cluster::with_dataset(&rt, cfg_train(4, 2), dataset()).unwrap();
    c.train_steps(4).unwrap(); // avg_period=4 -> averaging fired at step 4
    let w0 = c.worker(0).conv_params[0].as_f32().to_vec();
    for rank in 1..4 {
        let wr = c.worker(rank).conv_params[0].as_f32();
        let max: f32 = w0
            .iter()
            .zip(wr.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(max < 1e-6, "rank {rank} diverged by {max} after averaging");
    }
}

#[test]
fn shard_averaging_syncs_same_offset_peers_only() {
    let Some(rt) = runtime() else { return };
    let mut c = Cluster::with_dataset(&rt, cfg_train(4, 2), dataset()).unwrap();
    c.train_steps(4).unwrap();
    // Ranks 0 and 2 share offset 0: identical shards after averaging.
    let a = c.worker(0).fc_params[0].as_f32().to_vec();
    let b = c.worker(2).fc_params[0].as_f32();
    let max: f32 = a.iter().zip(b.iter()).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max);
    assert!(max < 1e-6, "offset peers diverged by {max}");
    // Ranks 0 and 1 hold different partitions: must differ.
    let d = c.worker(1).fc_params[0].as_f32();
    assert_ne!(a, d);
}

#[test]
fn measured_bytes_match_schedule_analytics() {
    let Some(rt) = runtime() else { return };
    let mut c = Cluster::with_dataset(&rt, cfg(2, 2), dataset()).unwrap();
    c.step().unwrap(); // non-averaging step
    let (max_rank_bytes, _total) = c.last_fabric_bytes;
    let expect = c.schedule.mp_bytes_per_member();
    assert_eq!(
        max_rank_bytes, expect,
        "fabric measured {max_rank_bytes} B/rank, schedule predicts {expect}"
    );
}

#[test]
fn pure_dp_has_no_mp_traffic() {
    let Some(rt) = runtime() else { return };
    let mut c = Cluster::with_dataset(&rt, cfg(2, 1), dataset()).unwrap();
    let m = c.step().unwrap();
    assert_eq!(c.last_fabric_bytes.1, 0, "mp=1 must not touch the fabric");
    assert_eq!(m.mp_comm_secs, 0.0);
}

#[test]
fn evaluate_reports_sane_accuracy() {
    let Some(rt) = runtime() else { return };
    let data = dataset();
    let mut c = Cluster::with_dataset(&rt, cfg_train(2, 2), data.clone()).unwrap();
    let (loss0, acc0) = c.evaluate(&*data, 4).unwrap();
    assert!(loss0 > 0.0 && (0.0..=1.0).contains(&acc0));
    c.train_steps(12).unwrap();
    let (loss1, acc1) = c.evaluate(&*data, 4).unwrap();
    assert!(loss1 < loss0, "eval loss should improve: {loss0} -> {loss1}");
    assert!(acc1 >= acc0, "accuracy should not regress: {acc0} -> {acc1}");
}

#[test]
fn mp4_single_group_runs() {
    let Some(rt) = runtime() else { return };
    if !rt.manifest.supports_mp(4) {
        eprintln!("SKIP: no k4 artifacts");
        return;
    }
    let mut c = Cluster::with_dataset(&rt, cfg(4, 4), dataset()).unwrap();
    let m = c.step().unwrap();
    assert!(m.loss.is_finite() && m.loss > 0.0);
    assert_eq!(c.last_fabric_bytes.0, c.schedule.mp_bytes_per_member());
}

#[test]
fn segmented_mp1_baseline_matches_full_step_numerics() {
    let Some(rt) = runtime() else { return };
    // The segmented (Pallas-pipeline) mp=1 baseline used by the Table 2
    // benches must be numerically identical to the fused full_step path.
    let seg_cfg = builder(2, 1).segmented_mp1(true).cluster_config().unwrap();
    let mut a = Cluster::with_dataset(&rt, seg_cfg, dataset()).unwrap();
    let mut b = Cluster::with_dataset(&rt, cfg(2, 1), dataset()).unwrap();
    let la = a.step().unwrap().loss;
    let lb = b.step().unwrap().loss;
    assert!((la - lb).abs() < 1e-4, "segmented {la} vs fused {lb}");
    for pi in 0..14 {
        let d = a.worker(0).conv_params[pi].max_abs_diff(&b.worker(0).conv_params[pi]);
        assert!(d < 5e-5, "conv[{pi}] diverged by {d}");
    }
    for fi in 0..6 {
        let d = a.worker(0).fc_params[fi].max_abs_diff(&b.worker(0).fc_params[fi]);
        assert!(d < 5e-5, "fc[{fi}] diverged by {d}");
    }
    // And it must not touch the fabric (K=1 exchanges are local).
    assert_eq!(a.last_fabric_bytes.1, 0);
}

#[test]
fn all_three_schemes_produce_identical_updates() {
    // §3.1: BK, B and B/K are different *schedules* over the same
    // example set — after one step every parameter must agree across
    // schemes (modulo f32 reduction-order noise).
    let Some(rt) = runtime() else { return };
    use splitbrain::coordinator::McastScheme;
    let mut params: Vec<Vec<Vec<f32>>> = Vec::new();
    let mut losses = Vec::new();
    for scheme in [McastScheme::BoverK, McastScheme::B, McastScheme::BK] {
        let c = builder(2, 2).scheme(scheme).cluster_config().unwrap();
        let mut cluster = Cluster::with_dataset(&rt, c, dataset()).unwrap();
        let m = cluster.step().unwrap();
        losses.push(m.loss);
        let mut ps = Vec::new();
        for pi in 0..14 {
            ps.push(cluster.worker(0).conv_params[pi].as_f32().to_vec());
        }
        for fi in 0..6 {
            ps.push(cluster.worker(0).fc_params[fi].as_f32().to_vec());
        }
        params.push(ps);
    }
    for s in 1..3 {
        assert!(
            (losses[0] - losses[s]).abs() < 1e-4,
            "scheme {s} loss {} vs B/K {}",
            losses[s],
            losses[0]
        );
        for (ti, (a, b)) in params[0].iter().zip(params[s].iter()).enumerate() {
            let max = a
                .iter()
                .zip(b.iter())
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            assert!(max < 5e-5, "scheme {s} tensor {ti} diverged by {max}");
        }
    }
}

#[test]
fn scheme_b_and_bk_respect_schedule_bytes() {
    let Some(rt) = runtime() else { return };
    use splitbrain::coordinator::McastScheme;
    // BK: uniform volumes -> max-rank fabric bytes == schedule.
    let c = builder(2, 2).scheme(McastScheme::BK).cluster_config().unwrap();
    let mut cluster = Cluster::with_dataset(&rt, c, dataset()).unwrap();
    cluster.step().unwrap();
    assert_eq!(cluster.last_fabric_bytes.0, cluster.schedule.mp_bytes_per_member());
}

#[test]
fn checkpoint_roundtrips_across_topologies() {
    let Some(rt) = runtime() else { return };
    let path = std::env::temp_dir().join(format!("sb-ckpt-{}.bin", std::process::id()));

    // Train a 2-worker mp=2 cluster up to an averaging boundary (the
    // checkpoint snapshots worker 0's replica, which equals the global
    // model exactly at averaging steps — avg_period is 4 in cfg()).
    let mut a = Cluster::with_dataset(&rt, cfg_train(2, 2), dataset()).unwrap();
    a.train_steps(4).unwrap();
    a.save_checkpoint(&path).unwrap();
    let loss_a = a.step().unwrap().loss;

    // Restore into a fresh cluster whose iterators are at the same
    // position: the next step must match exactly.
    let mut b = Cluster::with_dataset(&rt, cfg_train(2, 2), dataset()).unwrap();
    b.train_steps(4).unwrap(); // advance iterators to the same position
    b.restore_checkpoint(&path).unwrap();
    let loss_b = b.step().unwrap().loss;
    assert!(
        (loss_a - loss_b).abs() < 1e-5,
        "restored cluster diverged: {loss_a} vs {loss_b}"
    );

    // Cross-topology restore: mp=1 cluster accepts the same checkpoint.
    let mut c = Cluster::with_dataset(&rt, cfg_train(2, 1), dataset()).unwrap();
    c.restore_checkpoint(&path).unwrap();
    let full = c.reconstruct_full_fc(0);
    let orig = {
        let mut x = Cluster::with_dataset(&rt, cfg_train(2, 2), dataset()).unwrap();
        x.restore_checkpoint(&path).unwrap();
        x.reconstruct_full_fc(0)
    };
    for (x, y) in full.iter().zip(orig.iter()) {
        assert_eq!(x.as_f32(), y.as_f32(), "cross-topology restore differs");
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn rejects_unsupported_mp() {
    let Some(rt) = runtime() else { return };
    let bad = cfg(6, 3);
    assert!(Cluster::with_dataset(&rt, bad, dataset()).is_err());
}
