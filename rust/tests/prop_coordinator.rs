//! Property-based tests over the coordinator's routing, batching and
//! state invariants (the offline registry has no proptest crate, so
//! these use seeded randomized sweeps — every failure reproduces from
//! the printed seed).

use std::collections::HashSet;

use splitbrain::comm::collective::ring_allreduce_mean;
use splitbrain::comm::fabric::{Fabric, Tag};
use splitbrain::comm::NetModel;
use splitbrain::coordinator::{GmpTopology, ModuloPlan, ShardBwdMode, ShardPlan};
use splitbrain::model::{partition_network, vgg11, Layer, PartitionConfig};
use splitbrain::runtime::HostTensor;
use splitbrain::util::Rng;

const CASES: usize = 60;

fn rand_tensor(rng: &mut Rng, shape: Vec<usize>) -> HostTensor {
    let n = shape.iter().product();
    HostTensor::f32(shape, rng.normal_vec(n, 1.0))
}

// ---------------------------------------------------------------------------
// Modulo layer properties (Fig. 4).

/// Every (member, row) of every member's local activations appears in
/// exactly one iteration's assembled batch, at the owner-mapped slot.
#[test]
fn prop_modulo_covers_each_example_exactly_once() {
    for case in 0..CASES {
        let mut rng = Rng::new(1000 + case as u64);
        let k = [1, 2, 4, 8][rng.below(4)];
        let b = k * (1 + rng.below(4)); // B multiple of K
        let w = 1 + rng.below(6);
        let plan = ModuloPlan::new((0..k).collect(), b, w);
        let acts: Vec<HostTensor> =
            (0..k).map(|_| rand_tensor(&mut rng, vec![b, w])).collect();
        let fabric = Fabric::new(k);

        let size = b / k;
        let mut seen: HashSet<(usize, usize)> = HashSet::new(); // (member, row)
        for it in 0..k {
            let assembled = plan
                .assemble(&fabric, &acts, it, Tag::new(1, it, case))
                .unwrap();
            // All members assemble the identical batch.
            for m in 1..k {
                assert_eq!(assembled[0].as_f32(), assembled[m].as_f32(), "case {case}");
            }
            // Row j*size+r must equal member j's local row it*size+r.
            for j in 0..k {
                for r in 0..size {
                    let got = assembled[0].slice_rows(j * size + r, j * size + r + 1);
                    let want = acts[j].slice_rows(it * size + r, it * size + r + 1);
                    assert_eq!(got.as_f32(), want.as_f32(), "case {case} it {it}");
                    assert!(
                        seen.insert((j, it * size + r)),
                        "case {case}: duplicate example (member {j}, row {})",
                        it * size + r
                    );
                }
            }
        }
        assert_eq!(seen.len(), k * b, "case {case}: full coverage");
        assert!(fabric.drained());
    }
}

/// Gradient mass is conserved by the bprop routing: the sum over all
/// members' reduced local gradients equals the sum over all members'
/// assembled-batch gradients.
#[test]
fn prop_modulo_bwd_conserves_gradient_mass() {
    for case in 0..CASES {
        let mut rng = Rng::new(2000 + case as u64);
        let k = [2, 4][rng.below(2)];
        let b = k * (1 + rng.below(3));
        let w = 1 + rng.below(5);
        let plan = ModuloPlan::new((0..k).collect(), b, w);
        let fabric = Fabric::new(k);
        let gbatches: Vec<HostTensor> =
            (0..k).map(|_| rand_tensor(&mut rng, vec![b, w])).collect();
        let mut g_acts: Vec<HostTensor> = (0..k).map(|_| HostTensor::zeros(vec![b, w])).collect();
        let it = rng.below(k);
        plan.scatter_reduce(&fabric, &gbatches, &mut g_acts, it, Tag::new(2, 0, 0))
            .unwrap();

        let mass_in: f64 = gbatches
            .iter()
            .map(|t| t.as_f32().iter().map(|&v| v as f64).sum::<f64>())
            .sum();
        let mass_out: f64 = g_acts
            .iter()
            .map(|t| t.as_f32().iter().map(|&v| v as f64).sum::<f64>())
            .sum();
        assert!(
            (mass_in - mass_out).abs() < 1e-3 * mass_in.abs().max(1.0),
            "case {case}: {mass_in} vs {mass_out}"
        );
        assert!(fabric.drained());
    }
}

// ---------------------------------------------------------------------------
// Shard layer properties (Fig. 5).

/// gather_full is exactly the column-concatenation of the partitions,
/// and slicing it back recovers every member's input bit-for-bit.
#[test]
fn prop_shard_gather_slice_roundtrip() {
    for case in 0..CASES {
        let mut rng = Rng::new(3000 + case as u64);
        let k = 1 + rng.below(6);
        let part = 1 + rng.below(8);
        let rows = 1 + rng.below(6);
        let plan = ShardPlan::new((0..k).collect(), part, ShardBwdMode::ReducePartials);
        let parts: Vec<HostTensor> =
            (0..k).map(|_| rand_tensor(&mut rng, vec![rows, part])).collect();
        let fabric = Fabric::new(k);
        let fulls = plan.gather_full(&fabric, &parts, Tag::new(3, 0, 0)).unwrap();
        for m in 0..k {
            assert_eq!(fulls[m].shape, vec![rows, part * k]);
            for j in 0..k {
                let sl = fulls[m].slice_cols(j * part, (j + 1) * part);
                assert_eq!(sl.as_f32(), parts[j].as_f32(), "case {case}");
            }
        }
        assert!(fabric.drained());
    }
}

/// ReducePartials: backward(sum of random partials) == columnwise sums.
#[test]
fn prop_shard_reduce_is_columnwise_sum() {
    for case in 0..CASES {
        let mut rng = Rng::new(4000 + case as u64);
        let k = 2 + rng.below(4);
        let part = 1 + rng.below(5);
        let rows = 1 + rng.below(4);
        let plan = ShardPlan::new((0..k).collect(), part, ShardBwdMode::ReducePartials);
        let fulls: Vec<HostTensor> =
            (0..k).map(|_| rand_tensor(&mut rng, vec![rows, part * k])).collect();
        let fabric = Fabric::new(k);
        let outs = plan.backward(&fabric, &fulls, Tag::new(4, 0, 0)).unwrap();
        for (m, out) in outs.iter().enumerate() {
            for r in 0..rows {
                for c in 0..part {
                    let want: f32 = fulls
                        .iter()
                        .map(|f| f.as_f32()[r * part * k + m * part + c])
                        .sum();
                    let got = out.as_f32()[r * part + c];
                    assert!((want - got).abs() < 1e-4, "case {case} m{m} r{r} c{c}");
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// GMP topology properties (Fig. 6).

/// Groups partition the ranks; shard peers partition them orthogonally;
/// the owner mapping lands inside the caller's own group.
#[test]
fn prop_topology_partitions() {
    for case in 0..CASES {
        let mut rng = Rng::new(5000 + case as u64);
        let mp = [1, 2, 4, 8][rng.below(4)];
        let groups = 1 + rng.below(5);
        let n = mp * groups;
        let topo = GmpTopology::new(n, mp).unwrap();

        let mut by_group: Vec<usize> = (0..topo.n_groups())
            .flat_map(|g| topo.members(g))
            .collect();
        by_group.sort_unstable();
        assert_eq!(by_group, (0..n).collect::<Vec<_>>(), "groups partition ranks");

        let mut by_offset: Vec<usize> = (0..mp)
            .flat_map(|o| topo.shard_peers(o))
            .collect();
        by_offset.sort_unstable();
        assert_eq!(by_offset, (0..n).collect::<Vec<_>>(), "offsets partition ranks");

        let batch = mp * (1 + rng.below(4));
        for rank in 0..n {
            for b in 0..batch {
                let owner = topo.owner_of_example(rank, b, batch);
                assert!(topo.group_of(rank).contains(&owner), "case {case}");
                // Owner sequence is the member order, size rows each.
                assert_eq!(owner, topo.group_of(rank)[b / (batch / mp)]);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Collectives.

/// Ring allreduce == naive mean for random lengths and group sizes.
#[test]
fn prop_ring_allreduce_equals_naive_mean() {
    for case in 0..CASES {
        let mut rng = Rng::new(6000 + case as u64);
        let n = 1 + rng.below(8);
        let len = 1 + rng.below(100);
        let mut bufs: Vec<Vec<f32>> =
            (0..n).map(|_| rng.normal_vec(len, 1.0)).collect();
        let expect: Vec<f32> = (0..len)
            .map(|i| bufs.iter().map(|b| b[i]).sum::<f32>() / n as f32)
            .collect();
        let fabric = Fabric::new(n);
        ring_allreduce_mean(&fabric, &(0..n).collect::<Vec<_>>(), &mut bufs, 1).unwrap();
        for b in &bufs {
            for (got, want) in b.iter().zip(expect.iter()) {
                assert!((got - want).abs() < 1e-4, "case {case}");
            }
        }
        assert!(fabric.drained(), "case {case}");
    }
}

// ---------------------------------------------------------------------------
// Partitioner properties (Listing 1).

/// For any CCR threshold and mp, the transformed net's dimensions chain
/// end-to-end and per-worker weights never exceed the local model's.
#[test]
fn prop_partition_preserves_shape_chain_and_shrinks() {
    let full_weights = 6_987_456.0;
    for case in 0..CASES {
        let mut rng = Rng::new(7000 + case as u64);
        let mp = [1, 2, 4, 8][rng.below(4)];
        let thr = [0.0, 10.0, 100.0, 500.0, 1e9][rng.below(5)];
        let t = partition_network(
            &vgg11(),
            vec![32, 32, 3],
            &PartitionConfig { mp, ccr_threshold: thr },
        )
        .unwrap();
        // Shape chain: resize through every layer ends at [10].
        let mut d = vec![32, 32, 3];
        for l in &t.layers {
            d = splitbrain::model::dims::resize(l, &d).unwrap();
        }
        assert_eq!(d, vec![10], "case {case}");
        assert!(t.weight_count() as f64 <= full_weights, "case {case}");
        // Comm layers appear iff something was sharded.
        let has_comm = t.layers.iter().any(Layer::is_comm);
        let has_shards = !t.sharded_linears().is_empty();
        assert_eq!(has_comm, has_shards, "case {case}");
    }
}

/// Analytic collective costs are monotone in group size and bytes.
#[test]
fn prop_netmodel_monotonicity() {
    let net = NetModel::default();
    for case in 0..CASES {
        let mut rng = Rng::new(8000 + case as u64);
        let k = 2 + rng.below(14);
        let bytes = 1 + rng.next_u64() % (1 << 24);
        assert!(net.allgather(k + 1, bytes) >= net.allgather(k, bytes), "case {case}");
        assert!(net.allgather(k, bytes + 1024) >= net.allgather(k, bytes));
        assert!(net.ring_allreduce(k + 1, bytes) >= 0.0);
        assert!(
            net.reduce_scatter(k, bytes * 2) >= net.reduce_scatter(k, bytes),
            "case {case}"
        );
    }
}
