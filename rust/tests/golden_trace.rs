//! Golden-trace regression: the per-step `CommTrace` integer counters
//! (bytes, messages, phase occurrences) of every `CollectiveAlgo`
//! variant are pinned against `tests/golden/comm_trace.json`.
//!
//! The counters come from the compiled `StepSchedule`'s analytic phase
//! volumes — the same numbers the fabric's measured byte counters are
//! cross-checked against in `cluster_integration` — so any silent
//! protocol drift (a changed collective round structure, a mis-counted
//! modulo volume, a reordered category) fails this test with a diff.
//!
//! To re-bless after an *intentional* protocol change:
//!
//! ```bash
//! SPLITBRAIN_BLESS=1 cargo test golden_trace -q   # rewrites the file
//! git diff rust/tests/golden/comm_trace.json      # review the drift!
//! ```

use splitbrain::comm::{CollectiveAlgo, CommTrace, NetModel};
use splitbrain::coordinator::schedule::CommPhase;
use splitbrain::coordinator::{GmpTopology, McastScheme, StepSchedule};
use splitbrain::model::{partition_network, vgg11, PartitionConfig};
use splitbrain::runtime::Manifest;

/// Synthesize a minimal manifest accepted by `compile_with_algo` (same
/// shape as the schedule unit tests): golden counters must not depend
/// on which artifact backend is installed.
fn manifest(batch: usize, ks: &[usize]) -> Manifest {
    let mut text = format!(
        "splitbrain-artifacts v1\nbatch {batch}\nmp_sizes {}\nfeature_dim 4096\nnum_classes 10\n",
        ks.iter().map(|k| k.to_string()).collect::<Vec<_>>().join(",")
    );
    let mut add = |name: &str| {
        text.push_str(&format!(
            "artifact {name} file={name}.hlo.txt\nin x float32 1\nout y float32 1\nend\n"
        ));
    };
    for name in ["conv_fwd", "conv_bwd", "full_step", "full_eval", "head_step", "head_fwd"] {
        add(name);
    }
    for &k in ks {
        if k > 1 {
            for seg in ["fc0_fwd", "fc0_bwd", "fc1_fwd", "fc1_bwd"] {
                add(&format!("{seg}_k{k}"));
            }
        }
    }
    Manifest::parse(&text, std::path::PathBuf::from("/tmp")).unwrap()
}

/// Accumulate a trace exactly the way `Cluster::train_steps` records a
/// single occurrence of the given phase list.
fn trace_of(phases: &[CommPhase]) -> CommTrace {
    let net = NetModel::default();
    let mut t = CommTrace::new();
    for p in phases {
        for _ in 0..p.times {
            t.record_uniform(p.category, &net, p.ranks, p.per_member);
        }
    }
    t
}

/// The full golden document: one per-MP-step trace and one
/// per-averaging-event trace for every (topology, algorithm) pair.
fn golden_doc() -> String {
    let m = manifest(32, &[1, 2, 4, 8]);
    let mut lines = Vec::new();
    for &(n, mp) in &[(2usize, 2usize), (4, 2), (4, 4)] {
        for algo in [CollectiveAlgo::Naive, CollectiveAlgo::Ring, CollectiveAlgo::Rhd] {
            let net = partition_network(
                &vgg11(),
                vec![32, 32, 3],
                &PartitionConfig { mp, ..Default::default() },
            )
            .unwrap();
            let topo = GmpTopology::new(n, mp).unwrap();
            let s = StepSchedule::compile_with_algo(
                &net,
                topo,
                &m,
                false,
                McastScheme::BoverK,
                algo,
            )
            .unwrap();
            lines.push(format!(
                "  \"n{n}_mp{mp}_{algo}_step\": {}",
                trace_of(&s.mp_phases).to_json()
            ));
            lines.push(format!(
                "  \"n{n}_mp{mp}_{algo}_avg\": {}",
                trace_of(&s.avg_phases).to_json()
            ));
        }
    }
    format!("{{\n{}\n}}\n", lines.join(",\n"))
}

#[test]
fn comm_trace_counters_match_committed_golden() {
    let doc = golden_doc();
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/rust/tests/golden/comm_trace.json");
    if std::env::var("SPLITBRAIN_BLESS").is_ok() {
        std::fs::write(path, &doc).unwrap();
    }
    let want = std::fs::read_to_string(path)
        .expect("missing golden file — run with SPLITBRAIN_BLESS=1 to create it");
    assert_eq!(
        doc.trim_end(),
        want.trim_end(),
        "CommTrace counters drifted from the committed golden.\n\
         If the protocol change is intentional, re-bless with \
         SPLITBRAIN_BLESS=1 and review the JSON diff.\nCurrent counters:\n{doc}"
    );
}

/// Sanity on the golden content itself: the invariants the numbers
/// encode (so a bad bless can't silently pin nonsense).
#[test]
fn golden_invariants_hold() {
    let m = manifest(32, &[1, 2, 4, 8]);
    let net = partition_network(
        &vgg11(),
        vec![32, 32, 3],
        &PartitionConfig { mp: 4, ..Default::default() },
    )
    .unwrap();
    let topo = GmpTopology::new(4, 4).unwrap();
    let compile = |algo| {
        StepSchedule::compile_with_algo(&net, topo, &m, false, McastScheme::BoverK, algo).unwrap()
    };
    let naive = trace_of(&compile(CollectiveAlgo::Naive).mp_phases);
    let ring = trace_of(&compile(CollectiveAlgo::Ring).mp_phases);
    // Shard bytes are algorithm-invariant; the *phase structure* is not
    // (ring serializes k-1 neighbor rounds where naive posts one burst).
    assert_eq!(naive.total_bytes(), ring.total_bytes());
    assert!(
        ring.phases(splitbrain::comm::CommCategory::ShardFwd)
            > naive.phases(splitbrain::comm::CommCategory::ShardFwd)
    );
    // Averaging: ring moves 2(n-1)/n·V vs naive's (n-1)·V.
    let a_naive = trace_of(&compile(CollectiveAlgo::Naive).avg_phases);
    let a_ring = trace_of(&compile(CollectiveAlgo::Ring).avg_phases);
    assert!(a_ring.total_bytes() < a_naive.total_bytes());
}
