//! Property: checkpoint save→restore mid-run is **bit-identical** to an
//! uninterrupted run.
//!
//! The checkpoint stores the global model in f32 little-endian —
//! lossless — and `ClusterConfig::momentum = 0` makes SGD stateless, so
//! restoring at an averaging boundary (where worker 0's replica *is*
//! the global model) and continuing must reproduce the uninterrupted
//! run's losses and parameters exactly, bit for bit. (With momentum on,
//! restore resets optimizer velocity by design — the cluster
//! integration suite covers that looser contract.)
//!
//! No proptest crate in the offline registry: seeded randomized sweeps,
//! every failure reproduces from the printed case id.

use std::sync::Arc;

use splitbrain::api::SessionBuilder;
use splitbrain::coordinator::{Cluster, ClusterConfig};
use splitbrain::data::{Dataset, SyntheticCifar};
use splitbrain::runtime::RuntimeClient;
use splitbrain::train::checkpoint;

const SPLIT: usize = 2; // avg_period-aligned save point
const TAIL: usize = 2; // steps after the restore

fn cfg(n: usize, mp: usize, seed: u64) -> ClusterConfig {
    SessionBuilder::new()
        .workers(n)
        .mp(mp)
        .lr(0.02)
        .momentum(0.0) // stateless SGD: restore is exact
        .clip_norm(1.0)
        .avg_period(SPLIT)
        .seed(seed)
        .dataset_size(256)
        .cluster_config()
        .unwrap()
}

fn dataset(seed: u64) -> Arc<dyn Dataset> {
    Arc::new(SyntheticCifar::new(256, seed))
}

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("sb-prop-ckpt-{}-{name}.bin", std::process::id()))
}

/// Every worker's every parameter, flattened (exact f32 payloads).
fn all_params(c: &Cluster) -> Vec<Vec<f32>> {
    let mut out = Vec::new();
    for rank in 0..c.cfg.n_workers {
        let w = c.worker(rank);
        for t in w.conv_params.iter().chain(w.fc_params.iter()) {
            out.push(t.as_f32().to_vec());
        }
    }
    out
}

#[test]
fn prop_mid_run_save_restore_is_bit_identical() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    for (case, &(n, mp)) in [(2usize, 1usize), (2, 2), (4, 2)].iter().enumerate() {
        let seed = 5000 + case as u64;
        let data = dataset(seed);
        let path = tmp(&format!("case{case}"));

        // Reference: SPLIT + TAIL steps, uninterrupted.
        let mut a = Cluster::with_dataset(&rt, cfg(n, mp, seed), data.clone()).unwrap();
        let mut ref_losses = Vec::new();
        for _ in 0..SPLIT + TAIL {
            ref_losses.push(a.step().unwrap().loss.to_bits());
        }

        // Interrupted: train to the averaging boundary, checkpoint...
        let mut b = Cluster::with_dataset(&rt, cfg(n, mp, seed), data.clone()).unwrap();
        for _ in 0..SPLIT {
            b.step().unwrap();
        }
        b.save_checkpoint(&path).unwrap();

        // The file round-trips the in-memory snapshot losslessly.
        let snap = b.snapshot_global();
        let loaded = checkpoint::load(&path).unwrap();
        assert_eq!(loaded.len(), snap.len(), "case {case}");
        for ((ln, lt), (sn, st)) in loaded.iter().zip(snap.iter()) {
            assert_eq!(ln, sn, "case {case}: tensor name order");
            assert_eq!(lt.shape, st.shape, "case {case}: {ln} shape");
            assert_eq!(lt.as_f32(), st.as_f32(), "case {case}: {ln} payload must be bit-exact");
        }

        // ...then restore into a fresh cluster whose iterators sit at
        // the same position, and finish the run. (That restore really
        // *applies* checkpoint values into fresh state is proven by
        // `prop_restore_is_topology_portable` below; here the restored
        // run must continue exactly like the uninterrupted one.)
        let mut c = Cluster::with_dataset(&rt, cfg(n, mp, seed), data.clone()).unwrap();
        for _ in 0..SPLIT {
            c.step().unwrap(); // advance data iterators identically
        }
        c.restore_checkpoint(&path).unwrap();
        let mut tail_losses = Vec::new();
        for _ in 0..TAIL {
            tail_losses.push(c.step().unwrap().loss.to_bits());
        }
        std::fs::remove_file(&path).ok();

        assert_eq!(
            tail_losses,
            ref_losses[SPLIT..].to_vec(),
            "case {case} (n={n}, mp={mp}): post-restore losses must match bit-for-bit"
        );
        let pa = all_params(&a);
        let pc = all_params(&c);
        assert_eq!(pa.len(), pc.len(), "case {case}");
        for (i, (x, y)) in pa.iter().zip(pc.iter()).enumerate() {
            assert_eq!(
                x, y,
                "case {case} (n={n}, mp={mp}): tensor {i} diverged after restore"
            );
        }
    }
}

/// The checkpoint is topology-portable bit-exactly: restoring one file
/// into clusters of different mp yields the same global model.
#[test]
fn prop_restore_is_topology_portable() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let seed = 6001;
    let data = dataset(seed);
    let path = tmp("portable");
    let mut src = Cluster::with_dataset(&rt, cfg(2, 2, seed), data.clone()).unwrap();
    for _ in 0..SPLIT {
        src.step().unwrap();
    }
    src.save_checkpoint(&path).unwrap();

    // The trained source model, in global coordinates.
    let want: Vec<Vec<f32>> = src
        .snapshot_global()
        .into_iter()
        .map(|(_, t)| t.as_f32().to_vec())
        .collect();

    for &(n, mp) in &[(2usize, 1usize), (2, 2), (4, 2)] {
        // Fresh clusters hold *untrained* parameters, so a successful
        // comparison proves restore really applied the checkpoint.
        let mut c = Cluster::with_dataset(&rt, cfg(n, mp, seed), data.clone()).unwrap();
        c.restore_checkpoint(&path).unwrap();
        let got: Vec<Vec<f32>> = c
            .snapshot_global()
            .into_iter()
            .map(|(_, t)| t.as_f32().to_vec())
            .collect();
        assert_eq!(want, got, "restored global model differs on (n={n}, mp={mp})");
    }
    std::fs::remove_file(&path).ok();
}
