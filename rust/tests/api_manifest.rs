//! Property: run manifests are **canonical and lossless** — for random
//! valid configurations, serialize → parse → serialize is
//! byte-identical, every field survives exactly (u64 seeds beyond
//! f64's mantissa included), and the fingerprint tracks content.
//!
//! No proptest crate in the offline registry: seeded randomized
//! sweeps, every failure reproduces from the printed case id.

use splitbrain::api::{RunManifest, SessionBuilder};
use splitbrain::comm::{CollectiveAlgo, FaultPlan, NetModel};
use splitbrain::coordinator::{ExecEngine, McastScheme, RecoveryPolicy};
use splitbrain::util::Rng;

/// One random *valid* builder (every generated value passes the
/// validation matrix by construction).
fn random_builder(rng: &mut Rng) -> SessionBuilder {
    let workers = 1 + rng.below(8);
    let divisors: Vec<usize> = (1..=workers).filter(|k| workers % k == 0).collect();
    let mp = divisors[rng.below(divisors.len())];
    let steps = 1 + rng.below(200);
    let engine = if rng.below(2) == 0 { ExecEngine::Sequential } else { ExecEngine::Threaded };
    let mut b = SessionBuilder::new()
        .workers(workers)
        .mp(mp)
        .steps(steps)
        .lr(0.001 + rng.uniform() * 0.2)
        .momentum(rng.uniform() * 0.99)
        .clip_norm(rng.uniform() * 2.0)
        .avg_period(1 + rng.below(20))
        .seed(rng.next_u64()) // full u64 range: exercises losslessness
        .dataset_size(1 + rng.below(4096))
        .scheme([McastScheme::BoverK, McastScheme::B, McastScheme::BK][rng.below(3)])
        .engine(engine)
        .collectives(
            [CollectiveAlgo::Naive, CollectiveAlgo::Ring, CollectiveAlgo::Rhd][rng.below(3)],
        )
        .recovery(
            [RecoveryPolicy::FailFast, RecoveryPolicy::ShrinkAndContinue][rng.below(2)],
        )
        .take_timeout_ms(1 + rng.next_u64() % 1_000_000)
        .segmented_mp1(rng.below(2) == 0)
        .net(NetModel {
            alpha: 1e-9 + rng.uniform_f64() * 1e-4,
            beta: 1.0 + rng.uniform_f64() * 1e10,
            phase_overhead: rng.uniform_f64() * 1e-2,
        });
    // Overlap: forced-on is only legal off the sequential reference.
    b = match (engine, rng.below(3)) {
        (_, 0) => b,                                      // auto
        (_, 1) => b.overlap(false),                       // forced off
        (ExecEngine::Threaded, _) => b.overlap(true),     // forced on
        (ExecEngine::Sequential, _) => b,                 // auto again
    };
    if rng.below(2) == 0 {
        b = b.faults(FaultPlan::random(rng.next_u64(), workers, steps, 1 + rng.below(4)));
    }
    b
}

#[test]
fn prop_manifest_round_trip_is_byte_identical() {
    let mut rng = Rng::new(0xA9_1FE5);
    for case in 0..100 {
        let builder = random_builder(&mut rng);
        let cfg = builder
            .cluster_config()
            .unwrap_or_else(|e| panic!("case {case}: generated config must be valid: {e}"));
        let steps = builder.current_steps();
        let manifest = RunManifest::from_config(&cfg, steps);
        let text = manifest.to_json();

        // serialize → parse → serialize: byte-identical.
        let reparsed = RunManifest::parse(&text)
            .unwrap_or_else(|e| panic!("case {case}: parse failed: {e:#}\n{text}"));
        assert_eq!(reparsed, manifest, "case {case}: manifest round-trip");
        assert_eq!(reparsed.to_json(), text, "case {case}: canonical text round-trip");

        // manifest → builder → config → manifest: identical again
        // (including the resolved overlap and the fault plan).
        let rebuilt_cfg = SessionBuilder::from_manifest(&text)
            .unwrap_or_else(|e| panic!("case {case}: from_manifest failed: {e:#}"))
            .cluster_config()
            .unwrap_or_else(|e| panic!("case {case}: reloaded config invalid: {e}"));
        let rebuilt = RunManifest::from_config(&rebuilt_cfg, steps);
        assert_eq!(rebuilt.to_json(), text, "case {case}: builder round-trip");
        assert_eq!(
            rebuilt.fingerprint(),
            manifest.fingerprint(),
            "case {case}: fingerprint must be reproducible"
        );
    }
}

#[test]
fn fingerprint_differs_when_any_field_changes() {
    let mut rng = Rng::new(0xBEEF);
    let base = random_builder(&mut rng);
    let cfg = base.cluster_config().unwrap();
    let m = RunManifest::from_config(&cfg, base.current_steps());
    let fp = m.fingerprint();

    let mut seed_changed = m.clone();
    seed_changed.seed ^= 1;
    assert_ne!(fp, seed_changed.fingerprint(), "seed must be covered");

    let mut steps_changed = m.clone();
    steps_changed.steps += 1;
    assert_ne!(fp, steps_changed.fingerprint(), "steps must be covered");

    let mut fault_changed = m.clone();
    fault_changed.faults = fault_changed.faults.clone().crash(0, 1);
    assert_ne!(
        fp,
        fault_changed.fingerprint(),
        "the fault plan must be covered (the old flag-string preimage missed it)"
    );

    let mut net_changed = m.clone();
    net_changed.net.alpha *= 2.0;
    assert_ne!(fp, net_changed.fingerprint(), "the net model must be covered");
}

#[test]
fn worker_and_leader_fingerprints_agree_through_the_file() {
    // The launch → worker path: leader resolves flags to run.json,
    // worker reloads the file; both fingerprints (what the TCP Hello
    // handshake compares) must agree.
    let leader_cfg = SessionBuilder::new()
        .workers(4)
        .mp(2)
        .steps(6)
        .seed(99)
        .faults(FaultPlan::new().crash(1, 3))
        .recovery(RecoveryPolicy::ShrinkAndContinue)
        .cluster_config()
        .unwrap();
    let leader = RunManifest::from_config(&leader_cfg, 6);

    let text = leader.to_json(); // what launch writes to run.json
    let worker_builder = SessionBuilder::from_manifest(&text).unwrap();
    let worker_cfg = worker_builder.cluster_config().unwrap();
    let worker = RunManifest::from_config(&worker_cfg, worker_builder.current_steps());

    assert_eq!(
        splitbrain::coordinator::procdriver::run_fingerprint(&worker_cfg, 6),
        splitbrain::coordinator::procdriver::run_fingerprint(&leader_cfg, 6),
        "worker's manifest fingerprint must match the leader's handshake fingerprint"
    );
    assert_eq!(worker.to_json(), text);
}

#[test]
fn hand_edited_drift_is_rejected_or_fingerprinted() {
    let cfg = SessionBuilder::new().workers(2).cluster_config().unwrap();
    let m = RunManifest::from_config(&cfg, 10);
    let text = m.to_json();

    // A typoed key must be an error, not a silent default.
    let typo = text.replace("\"avg_period\"", "\"avg_perod\"");
    assert!(RunManifest::parse(&typo).is_err());

    // A changed value parses but fingerprints differently, so the
    // handshake rejects the mesh.
    let drifted = text.replace("\"seed\": 42", "\"seed\": 43");
    let parsed = RunManifest::parse(&drifted).unwrap();
    assert_ne!(parsed.fingerprint(), m.fingerprint());
}
