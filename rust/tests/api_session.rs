//! The session lifecycle acceptance suite:
//!
//! * `Session::step` driven one-at-a-time is **bit-identical** to
//!   `Session::run` (losses, parameters, byte counters);
//! * `ConsoleSink` reproduces the historical `splitbrain train` output
//!   **byte-for-byte** from the event stream (format pinned here);
//! * a run rebuilt from its serialized manifest reproduces the
//!   flag-built run bit-identically;
//! * recovery transitions surface as structured events;
//! * checkpoint/restore through the session keeps bit-exactness.
//!
//! Runs on the built-in native backend (no artifacts needed).

use std::cell::RefCell;
use std::io::Write;
use std::rc::Rc;
use std::sync::Arc;

use splitbrain::api::{
    step_reports, CollectSink, ConsoleSink, Event, SessionBuilder, StepReport,
};
use splitbrain::comm::FaultPlan;
use splitbrain::coordinator::RecoveryPolicy;
use splitbrain::data::{Dataset, SyntheticCifar};
use splitbrain::runtime::RuntimeClient;

const SEED: u64 = 123;
const DATASET: usize = 256;

fn builder(n: usize, mp: usize, steps: usize) -> SessionBuilder {
    SessionBuilder::new()
        .workers(n)
        .mp(mp)
        .steps(steps)
        .lr(0.02)
        .momentum(0.9)
        .clip_norm(1.0)
        .avg_period(2)
        .seed(SEED)
        .dataset_size(DATASET)
}

fn dataset() -> Arc<dyn Dataset> {
    Arc::new(SyntheticCifar::new(DATASET, SEED))
}

/// Every worker's every parameter as bit patterns.
fn all_param_bits(s: &splitbrain::api::Session) -> Vec<Vec<u32>> {
    let c = s.cluster();
    let mut out = Vec::new();
    for rank in 0..c.cfg.n_workers {
        let w = c.worker(rank);
        for t in w.conv_params.iter().chain(w.fc_params.iter()) {
            out.push(t.as_f32().iter().map(|v| v.to_bits()).collect());
        }
    }
    out
}

/// A writer handle the test can read back after the sink consumed it.
#[derive(Clone, Default)]
struct SharedBuf(Rc<RefCell<Vec<u8>>>);

impl Write for SharedBuf {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0.borrow_mut().extend_from_slice(buf);
        Ok(buf.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// The headline lifecycle check: step-at-a-time == run(), bit for bit.
#[test]
fn step_by_step_is_bit_identical_to_run() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let steps = 6;

    let mut whole = builder(4, 2, steps)
        .dataset(dataset())
        .validate(&rt)
        .unwrap()
        .start()
        .unwrap();
    let sink = CollectSink::new();
    let events = sink.events();
    whole.attach(Box::new(sink));
    let report = whole.run().unwrap();
    let run_reports = step_reports(&events.borrow());

    let mut stepped = builder(4, 2, steps)
        .dataset(dataset())
        .validate(&rt)
        .unwrap()
        .start()
        .unwrap();
    let mut step_by_step: Vec<StepReport> = Vec::new();
    while !stepped.is_done() {
        step_by_step.push(stepped.step().unwrap());
    }

    assert_eq!(report.steps_done, steps);
    assert_eq!(run_reports.len(), step_by_step.len());
    for (a, b) in run_reports.iter().zip(step_by_step.iter()) {
        assert_eq!(a.step, b.step);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss diverged at step {}", a.step);
        assert_eq!(
            (a.bytes_busiest_rank, a.bytes_total),
            (b.bytes_busiest_rank, b.bytes_total),
            "byte counters diverged at step {}",
            a.step
        );
    }
    let pa = all_param_bits(&whole);
    let pb = all_param_bits(&stepped);
    assert_eq!(pa.len(), pb.len());
    for (i, (x, y)) in pa.iter().zip(pb.iter()).enumerate() {
        assert_eq!(x, y, "parameter tensor {i} diverged between run() and step()s");
    }
}

/// ConsoleSink must render the event stream exactly like the pre-API
/// CLI loop printed it — the format strings below are the historical
/// ones, verbatim.
#[test]
fn console_sink_output_is_byte_identical_to_legacy_format() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let steps = 5;
    let log_every = 2;

    let mut session = builder(2, 2, steps)
        .dataset(dataset())
        .validate(&rt)
        .unwrap()
        .start()
        .unwrap();
    let buf = SharedBuf::default();
    session.attach(Box::new(ConsoleSink::with_writer(log_every, Box::new(buf.clone()))));
    let collect = CollectSink::new();
    let events = collect.events();
    session.attach(Box::new(collect));
    session.run().unwrap();

    // Rebuild the expected text from the same events with the legacy
    // `cmd_train` format strings.
    let mut want = String::new();
    for e in events.borrow().iter() {
        match e {
            Event::RunStarted(i) => {
                want.push_str(&format!(
                    "SplitBrain: {} workers, mp={} ({} groups), B={}, lr={}, avg_period={}, engine={}, collectives={}, overlap={}\n",
                    i.n_workers, i.mp, i.n_groups, i.batch, i.lr, i.avg_period, i.engine,
                    i.collectives, i.overlap
                ));
                want.push_str(&format!(
                    "per-worker memory: {:.2} MB params, {:.2} MB total\n\n",
                    i.param_mb, i.total_mb
                ));
            }
            Event::StepCompleted(r) => {
                if r.step % log_every == 0 || r.step == steps {
                    want.push_str(&format!(
                        "step {:>4}  loss {:.4}  compute {:.1} ms  mp-comm {:.2} ms  step {:.1} ms\n",
                        r.step,
                        r.loss,
                        r.compute_secs * 1e3,
                        r.mp_comm_secs * 1e3,
                        r.step_secs() * 1e3
                    ));
                }
            }
            Event::Recovered(_) => {}
            Event::RunCompleted(s) => {
                assert_eq!(s.recoveries, 0);
                want.push_str(&format!(
                    "\nthroughput: {:.2} images/sec (simulated cluster)  comm fraction {:.1}%\n",
                    s.images_per_sec,
                    s.comm_fraction * 100.0
                ));
            }
        }
    }
    let got = String::from_utf8(buf.0.borrow().clone()).unwrap();
    assert_eq!(got, want, "ConsoleSink drifted from the legacy byte format");
    assert!(got.contains("step    2"), "log_every=2 must print step 2:\n{got}");
    assert!(!got.contains("step    3"), "step 3 is off-cadence:\n{got}");
}

/// `--manifest run.json` path: a session rebuilt from the serialized
/// manifest reproduces the flag-built run bit-identically (losses and
/// parameters), using the default dataset loader on both sides like
/// the real CLI does.
#[test]
fn manifest_rebuilt_session_reproduces_flag_built_run() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let flags = builder(2, 2, 4);
    let plan = flags.validate(&rt).unwrap();
    let json = plan.manifest().to_json();

    let mut a = plan.start().unwrap();
    let sink_a = CollectSink::new();
    let events_a = sink_a.events();
    a.attach(Box::new(sink_a));
    a.run().unwrap();

    let mut b = SessionBuilder::from_manifest(&json)
        .unwrap()
        .validate(&rt)
        .unwrap()
        .start()
        .unwrap();
    let sink_b = CollectSink::new();
    let events_b = sink_b.events();
    b.attach(Box::new(sink_b));
    b.run().unwrap();

    let ra = step_reports(&events_a.borrow());
    let rb = step_reports(&events_b.borrow());
    assert_eq!(ra.len(), rb.len());
    for (x, y) in ra.iter().zip(rb.iter()) {
        assert_eq!(
            x.loss.to_bits(),
            y.loss.to_bits(),
            "manifest-rebuilt run diverged at step {}",
            x.step
        );
    }
    for (i, (x, y)) in all_param_bits(&a).iter().zip(all_param_bits(&b).iter()).enumerate() {
        assert_eq!(x, y, "parameter tensor {i} diverged after the manifest round-trip");
    }
}

/// Elastic recovery surfaces as a structured `Recovered` event, and
/// the end-of-run summary carries the recovery trajectory.
#[test]
fn recovery_emits_structured_events() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let mut session = builder(4, 2, 4)
        .recovery(RecoveryPolicy::ShrinkAndContinue)
        .faults(FaultPlan::new().crash(1, 3))
        .dataset(dataset())
        .validate(&rt)
        .unwrap()
        .start()
        .unwrap();
    let sink = CollectSink::new();
    let events = sink.events();
    session.attach(Box::new(sink));
    let report = session.run().unwrap();

    let recoveries: Vec<_> = events
        .borrow()
        .iter()
        .filter_map(|e| match e {
            Event::Recovered(r) => Some(r.clone()),
            _ => None,
        })
        .collect();
    assert_eq!(recoveries.len(), 1, "exactly one recovery transition");
    let r = &recoveries[0];
    assert_eq!(r.step, 3, "the retried step completes on the shrunk cluster");
    assert_eq!(r.lost_ranks, vec![1]);
    assert_eq!(r.n_workers, 3);
    assert_eq!(r.mp, 1, "2 does not divide 3 survivors");
    assert_eq!(r.restore_step, 2, "restored from the step-2 averaging checkpoint");

    assert_eq!(report.recoveries, 1);
    assert_eq!(report.lost_ranks, vec![1]);
    assert_eq!(report.n_workers, 3);
    match events.borrow().last().unwrap() {
        Event::RunCompleted(s) => {
            assert_eq!(s.recoveries, 1);
            assert_eq!(s.lost_ranks, vec![1]);
        }
        other => panic!("last event must be RunCompleted, got {other:?}"),
    }
}

/// Checkpoint/restore through the session API: save at an averaging
/// boundary, restore into a fresh session at the same data position,
/// and continue bit-identically (momentum 0 ⇒ stateless SGD).
#[test]
fn session_checkpoint_restore_is_bit_exact() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let path = std::env::temp_dir().join(format!("sb-api-ckpt-{}.bin", std::process::id()));
    let stateless = || builder(2, 2, 4).momentum(0.0).dataset(dataset());

    let mut a = stateless().validate(&rt).unwrap().start().unwrap();
    let mut ref_tail = Vec::new();
    for _ in 0..2 {
        a.step().unwrap();
    }
    a.checkpoint(&path).unwrap();
    for _ in 0..2 {
        ref_tail.push(a.step().unwrap().loss.to_bits());
    }

    let mut b = stateless().validate(&rt).unwrap().start().unwrap();
    for _ in 0..2 {
        b.step().unwrap(); // advance the data iterators identically
    }
    b.restore(&path).unwrap();
    let mut tail = Vec::new();
    for _ in 0..2 {
        tail.push(b.step().unwrap().loss.to_bits());
    }
    std::fs::remove_file(&path).ok();

    assert_eq!(tail, ref_tail, "post-restore losses must match bit-for-bit");
    for (i, (x, y)) in all_param_bits(&a).iter().zip(all_param_bits(&b).iter()).enumerate() {
        assert_eq!(x, y, "parameter tensor {i} diverged after restore");
    }
}

/// The plan's pre-compute communication estimate matches what the live
/// fabric then measures on a non-averaging step.
#[test]
fn plan_comm_estimate_matches_measured_bytes() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let plan = builder(2, 2, 3).dataset(dataset()).validate(&rt).unwrap();
    let est = plan.comm();
    let mut session = plan.start().unwrap();
    let first = session.step().unwrap(); // step 1: no averaging (period 2)
    assert_eq!(
        first.bytes_busiest_rank, est.mp_bytes_per_step,
        "plan promised {} MP bytes/step, fabric measured {}",
        est.mp_bytes_per_step, first.bytes_busiest_rank
    );
}

/// Resumed-run console output: the resumed incarnation re-emits the
/// `RunStarted` header (setting `ConsoleSink`'s planned step count),
/// prints no pre-resume steps, prints the *final* step even when it is
/// off the log-every cadence, and the summary covers the whole run —
/// not just the post-resume tail.
#[test]
fn console_sink_resumed_run_prints_final_step_and_full_summary() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let steps = 6;
    let dir = std::env::temp_dir()
        .join(format!("sb-api-console-resume-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // A durable run killed after step 5: the newest complete boundary
    // is step 4, so resume replays steps 5..=6.
    let mut victim = builder(2, 2, steps)
        .run_dir(&dir)
        .dataset(dataset())
        .validate(&rt)
        .unwrap()
        .start()
        .unwrap();
    for _ in 0..5 {
        victim.step().unwrap();
    }
    drop(victim);

    let mut resumed = SessionBuilder::resume_from(&dir)
        .unwrap()
        .dataset(dataset())
        .validate(&rt)
        .unwrap()
        .start()
        .unwrap();
    assert_eq!(resumed.steps_done(), 4, "resume lands on the step-4 boundary");
    let buf = SharedBuf::default();
    // log_every=4: step 5 (first resumed) and step 6 (final, 6 % 4 != 0)
    // are both off-cadence — only the final-step rule prints anything.
    resumed.attach(Box::new(ConsoleSink::with_writer(4, Box::new(buf.clone()))));
    let collect = CollectSink::new();
    let events = collect.events();
    resumed.attach(Box::new(collect));
    resumed.run().unwrap();

    let got = String::from_utf8(buf.0.borrow().clone()).unwrap();
    assert_eq!(
        got.matches("SplitBrain:").count(),
        1,
        "exactly one header from the resumed incarnation:\n{got}"
    );
    assert!(
        !got.contains("step    4") && !got.contains("step    5"),
        "no pre-resume or off-cadence steps:\n{got}"
    );
    assert!(
        got.contains("step    6"),
        "the final step must print even off the log-every cadence:\n{got}"
    );
    assert!(got.contains("\nthroughput: "), "summary footer present:\n{got}");
    let summary_steps: Vec<usize> = events
        .borrow()
        .iter()
        .filter_map(|e| match e {
            Event::RunCompleted(s) => Some(s.steps),
            _ => None,
        })
        .collect();
    assert_eq!(summary_steps, vec![steps], "summary counts the whole run");
    std::fs::remove_dir_all(&dir).ok();
}

/// `DiskSink` latches its first write error instead of failing the
/// run — but never silently: it is readable via `error()` and the
/// shared `error_handle()` after the sink moved into a session.
#[test]
fn disk_sink_latches_write_errors_and_exposes_them() {
    use splitbrain::api::{DiskSink, EventSink, RunSummary};
    // /dev/full accepts open() and fails every write with ENOSPC — the
    // portable unwritable path on Linux CI. Elsewhere: skip.
    if !std::path::Path::new("/dev/full").exists() {
        eprintln!("skipping: /dev/full not available on this platform");
        return;
    }
    let mut sink = DiskSink::create("/dev/full").unwrap();
    let handle = sink.error_handle();
    assert!(sink.error().is_none());
    let report = StepReport {
        step: 1,
        loss: 2.5,
        compute_secs: 0.0,
        mp_comm_secs: 0.0,
        dp_comm_secs: 0.0,
        wall_secs: 0.0,
        bytes_busiest_rank: 0,
        bytes_total: 0,
    };
    sink.on_event(&Event::StepCompleted(report.clone()));
    let first = sink.error().expect("the failed append must latch an error");
    // Latched: later events neither write nor replace the error.
    sink.on_event(&Event::StepCompleted(report));
    assert_eq!(sink.error(), Some(first.clone()));
    sink.on_event(&Event::RunCompleted(RunSummary {
        steps: 1,
        images_per_sec: 0.0,
        comm_fraction: 0.0,
        recoveries: 0,
        lost_ranks: vec![],
        n_workers: 2,
        mp: 1,
        last_checkpoint_step: 0,
    }));
    assert_eq!(
        handle.borrow().clone(),
        Some(first),
        "the shared handle sees the same latched error after the run"
    );
}
