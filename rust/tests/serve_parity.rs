//! Serving acceptance battery.
//!
//! The contract under test: the serving subsystem is **the training
//! forward pass behind a socket** — served logits are bitwise
//! identical to what `Session::evaluate()` computes on the same
//! checkpoint, for every MP width and for both the in-process and the
//! TCP path — and the frontend's admission control degrades *typed*:
//! a full queue, an expired deadline and a dying replica each produce
//! an `Overloaded` reply (or a drained re-dispatch), never a wrong
//! answer and never unbounded queue growth.

use std::io::{BufReader, Write as _};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use splitbrain::api::{RunManifest, SessionBuilder};
use splitbrain::comm::transport::wire::{read_frame, Message};
use splitbrain::coordinator::ClusterConfig;
use splitbrain::data::{Dataset, SyntheticCifar};
use splitbrain::runtime::{HostTensor, RuntimeClient};
use splitbrain::serve::{
    infer_inproc, run_loadgen, LoadgenConfig, ServeConfig, ServeModel, Server,
};

const SEED: u64 = 123;

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("sb-serve-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A fresh (untrained, seeded) serving model of the given MP width —
/// the same full parameter set for every `mp`, so cross-width logits
/// comparisons are meaningful.
fn fresh_model(mp: usize) -> ServeModel {
    let cfg = ClusterConfig { n_workers: mp.max(1), mp, seed: SEED, ..Default::default() };
    let manifest = RunManifest::from_config(&cfg, 1).to_json();
    ServeModel::from_manifest_text(&manifest).unwrap()
}

/// Deterministic request payload `i` (distinct per request, [0,1]).
fn img(i: usize) -> HostTensor {
    let data: Vec<f32> =
        (0..32 * 32 * 3).map(|p| ((i * 131 + p * 7) % 256) as f32 / 255.0).collect();
    HostTensor::f32(vec![32, 32, 3], data)
}

fn bits(t: &HostTensor) -> Vec<u32> {
    t.as_f32().iter().map(|v| v.to_bits()).collect()
}

/// Replicates the native head's loss/argmax math (`head_core` +
/// `count_correct`) from per-request logits rows, in the same f32 op
/// order, so the comparison against `full_eval` is bitwise.
fn loss_and_correct(rows: &[HostTensor], labels: &[i32]) -> (f64, i64) {
    let n = rows.len();
    let mut loss = 0.0f64;
    let mut correct = 0i64;
    for (ri, t) in rows.iter().enumerate() {
        let row = t.as_f32();
        let mut mx = f32::NEG_INFINITY;
        for &v in row {
            if v > mx {
                mx = v;
            }
        }
        let mut sum = 0.0f32;
        for &v in row {
            sum += (v - mx).exp();
        }
        let lse = mx + sum.ln();
        loss -= (row[labels[ri] as usize] - lse) as f64;
        let mut best = 0usize;
        for j in 1..row.len() {
            if row[j] > row[best] {
                best = j;
            }
        }
        if best as i32 == labels[ri] {
            correct += 1;
        }
    }
    (((loss / n as f64) as f32) as f64, correct)
}

// ---------------------------------------------------------------------------
// Parity.

/// The tentpole guarantee: one model, three shardings, identical bits.
/// The request count deliberately avoids every capacity multiple so
/// the padded partial-batch path is exercised at each width.
#[test]
fn logits_bitwise_identical_across_mp_widths() {
    let images: Vec<HostTensor> = (0..11).map(img).collect();
    let reference = infer_inproc(&fresh_model(1), &images).unwrap();
    assert_eq!(reference.len(), images.len());
    for mp in [2usize, 4] {
        let logits = infer_inproc(&fresh_model(mp), &images).unwrap();
        for (i, (a, b)) in reference.iter().zip(logits.iter()).enumerate() {
            assert_eq!(a.shape, b.shape, "mp={mp} request {i} shape");
            assert_eq!(bits(a), bits(b), "mp={mp} request {i} logits diverge from mp=1");
        }
    }
}

/// Serving a trained run dir reproduces `Session::evaluate()` exactly:
/// the checkpoint the server loads and the forward it runs are the
/// training ones, so loss and accuracy derived from served logits
/// match evaluate's to the last bit.
#[test]
fn served_logits_match_session_evaluate_on_trained_checkpoint() {
    let dir = tmp_dir("parity");
    let rt = RuntimeClient::native().unwrap();
    let data: Arc<dyn Dataset> = Arc::new(SyntheticCifar::new(64, SEED));
    let mut session = SessionBuilder::new()
        .workers(2)
        .mp(2)
        .steps(4)
        .avg_period(2)
        .seed(SEED)
        .dataset_size(64)
        .run_dir(&dir)
        .validate(&rt)
        .unwrap()
        .start_with_dataset(data.clone())
        .unwrap();
    session.run().unwrap();
    let n_batches = 2;
    let batch = rt.manifest.batch;
    let (eval_loss, eval_acc) = session.evaluate(data.as_ref(), n_batches).unwrap();
    drop(session);

    let model = ServeModel::from_run_dir(&dir, None).unwrap();
    assert_eq!(model.step, 4, "server should load the final checkpoint");
    let mut total_loss = 0.0f64;
    let mut total_correct = 0i64;
    for bi in 0..n_batches {
        let idx: Vec<usize> = (0..batch).map(|i| (bi * batch + i) % data.len()).collect();
        let gathered = data.gather(&idx);
        let images: Vec<HostTensor> = gathered
            .images
            .as_f32()
            .chunks(32 * 32 * 3)
            .map(|c| HostTensor::f32(vec![32, 32, 3], c.to_vec()))
            .collect();
        let logits = infer_inproc(&model, &images).unwrap();
        let (loss, correct) = loss_and_correct(&logits, gathered.labels.as_i32());
        total_loss += loss;
        total_correct += correct;
    }
    let served_loss = total_loss / n_batches as f64;
    let served_acc = total_correct as f64 / (n_batches * batch) as f64;
    assert_eq!(
        eval_loss.to_bits(),
        served_loss.to_bits(),
        "loss from served logits diverges from evaluate(): {eval_loss} vs {served_loss}"
    );
    assert_eq!(eval_acc.to_bits(), served_acc.to_bits());
    let _ = std::fs::remove_dir_all(&dir);
}

/// The TCP path returns the same bits as the in-process path: framing,
/// batching and replica dispatch are transport, not math.
#[test]
fn tcp_replies_bitwise_match_inproc() {
    let images: Vec<HostTensor> = (0..5).map(img).collect();
    let model = fresh_model(2);
    let reference = infer_inproc(&model, &images).unwrap();

    let server = Server::start(model, ServeConfig::default()).unwrap();
    let stream = TcpStream::connect(server.addr()).unwrap();
    let mut write_half = stream.try_clone().unwrap();
    for (i, image) in images.iter().enumerate() {
        let msg = Message::Predict { id: i as u64, deadline_ms: 0, image: image.clone() };
        write_half.write_all(&msg.encode()).unwrap();
    }
    let mut reader = BufReader::new(stream);
    let mut got: Vec<Option<HostTensor>> = vec![None; images.len()];
    for _ in 0..images.len() {
        let frame = read_frame(&mut reader).unwrap().expect("server closed early");
        match Message::decode(&frame).unwrap() {
            Message::Reply { id, logits } => got[id as usize] = Some(logits),
            other => panic!("expected Reply, got {other:?}"),
        }
    }
    server.shutdown();
    for (i, (a, b)) in reference.iter().zip(got.iter()).enumerate() {
        let b = b.as_ref().expect("missing reply");
        assert_eq!(bits(a), bits(b), "request {i}: TCP logits diverge from in-proc");
    }
}

// ---------------------------------------------------------------------------
// Admission control.

/// A full admission queue produces typed `Overloaded(queue-full)`
/// rejections, and every request still gets exactly one outcome.
#[test]
fn full_queue_rejects_typed_never_grows() {
    let server = Server::start(
        fresh_model(1),
        ServeConfig {
            queue_depth: 1,
            max_batch: 1,
            max_delay_ms: 0,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let report = run_loadgen(&LoadgenConfig {
        addr: server.addr().to_string(),
        rate: 1e6, // instantaneous burst: the queue must overflow
        requests: 24,
        deadline_ms: 0,
        seed: SEED,
    })
    .unwrap();
    server.shutdown();
    assert_eq!(report.sent, 24);
    assert!(report.rejected_queue >= 1, "burst at depth 1 must overflow: {report:?}");
    assert_eq!(report.wrong_shape, 0);
    assert_eq!(
        report.replies
            + report.rejected_queue
            + report.rejected_deadline
            + report.rejected_draining,
        report.sent,
        "every request gets exactly one outcome: {report:?}"
    );
}

/// A request whose deadline expired while batching is rejected
/// *before* compute: typed `Overloaded(deadline)`, zero batches run.
#[test]
fn expired_deadline_is_dropped_before_compute() {
    let server = Server::start(
        fresh_model(1),
        ServeConfig { max_delay_ms: 150, ..ServeConfig::default() },
    )
    .unwrap();
    let stream = TcpStream::connect(server.addr()).unwrap();
    let mut write_half = stream.try_clone().unwrap();
    let msg = Message::Predict { id: 9, deadline_ms: 1, image: img(0) };
    write_half.write_all(&msg.encode()).unwrap();
    let mut reader = BufReader::new(stream);
    let frame = read_frame(&mut reader).unwrap().expect("server closed early");
    match Message::decode(&frame).unwrap() {
        Message::Overloaded { id, reason } => {
            assert_eq!(id, 9);
            assert_eq!(reason, splitbrain::serve::protocol::REASON_DEADLINE);
        }
        other => panic!("expected deadline rejection, got {other:?}"),
    }
    let stats = server.stats();
    assert_eq!(stats.rejected_deadline.load(std::sync::atomic::Ordering::SeqCst), 1);
    assert_eq!(
        stats.batches.load(std::sync::atomic::Ordering::SeqCst),
        0,
        "an expired request must never reach a replica"
    );
    server.shutdown();
}

/// Killing a replica mid-load drains its in-flight work back through
/// the surviving replica: no wrong answers, no lost requests, and the
/// frontend reports one live replica afterwards.
#[test]
fn replica_kill_mid_load_drains_without_wrong_answers() {
    let server = Server::start(
        fresh_model(1),
        ServeConfig {
            replicas: 2,
            max_batch: 8,
            kill_replica_after: Some(1),
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let report = run_loadgen(&LoadgenConfig {
        addr: server.addr().to_string(),
        rate: 2000.0,
        requests: 40,
        deadline_ms: 0,
        seed: SEED,
    })
    .unwrap();
    assert_eq!(report.wrong_shape, 0, "a dying replica must never produce a wrong answer");
    assert!(report.replies >= 1);
    assert_eq!(
        report.replies
            + report.rejected_queue
            + report.rejected_deadline
            + report.rejected_draining,
        report.sent,
        "drain must not lose requests: {report:?}"
    );
    assert_eq!(server.replicas_live(), 1, "replica 0 was killed by the fault hook");
    server.shutdown();
}

/// Regression test for the idle-connection fix: a serving MP group
/// sits idle far past the fabric take timeout, and the leader's
/// heartbeats keep the parked members from presuming it lost. Without
/// them the first idle gap would kill the replica.
#[test]
fn idle_server_survives_fabric_take_timeout() {
    let mut model = fresh_model(2);
    model.cfg.take_timeout_ms = 150;
    let server = Server::start(model, ServeConfig::default()).unwrap();
    let cfg = LoadgenConfig {
        addr: server.addr().to_string(),
        rate: 1000.0,
        requests: 8,
        deadline_ms: 0,
        seed: SEED,
    };
    let warm = run_loadgen(&cfg).unwrap();
    assert_eq!(warm.replies, 8);
    // Idle for many multiples of the take timeout.
    std::thread::sleep(Duration::from_millis(1200));
    assert_eq!(server.replicas_live(), 1, "idle must not kill a healthy replica");
    let after = run_loadgen(&cfg).unwrap();
    assert_eq!(after.replies, 8, "replica must still serve after the idle gap: {after:?}");
    assert_eq!(after.wrong_shape, 0);
    server.shutdown();
}
