//! Observability-layer integration suite: the determinism contract of
//! the per-op tracer and its exports.
//!
//! The deterministic span fields — (kind, step, round, seg, bytes) per
//! rank, in order — must be bit-identical across seeded replays and
//! across all three engines (sequential, threaded, TCP multi-process);
//! only the timing fields are wall-clock, so every comparison here
//! masks them. On top of that: Chrome-trace exports must parse with the
//! crate's own strict JSON parser, histogram bucket edges are pinned as
//! schema, a disabled tracer must leave the numerics untouched, and
//! `profile` over a fresh traced run dir must report **exactly 0%**
//! byte error against the plan's analytic volumes.
//!
//! Runs on the built-in native backend (no artifacts needed).

use splitbrain::api::{step_reports, CollectSink, SessionBuilder, Watcher};
use splitbrain::comm::transport::TcpPeer;
use splitbrain::comm::{CollectiveAlgo, CommCategory};
use splitbrain::coordinator::procdriver::{run_worker, ProcConfig, RunOutcome};
use splitbrain::coordinator::ExecEngine;
use splitbrain::obs::{profile, LogHistogram, Metrics, OpKind};
use splitbrain::runtime::RuntimeClient;
use splitbrain::util::json::Json;

const SEED: u64 = 123;
const DATASET: usize = 256;

fn builder(n: usize, mp: usize, engine: ExecEngine, overlap: bool) -> SessionBuilder {
    SessionBuilder::new()
        .workers(n)
        .mp(mp)
        .lr(0.02)
        .momentum(0.9)
        .clip_norm(1.0)
        .avg_period(4)
        .seed(SEED)
        .dataset_size(DATASET)
        .engine(engine)
        .collectives(CollectiveAlgo::Ring)
        .overlap(overlap)
}

/// A span with the wall-clock fields masked off — the deterministic
/// identity the suite compares.
type MaskedSpan = (&'static str, u32, u32, u32, u64);

/// Run an in-proc traced session and return every rank's masked span
/// sequence (rank-major, chronological within each rank).
fn masked_spans(
    rt: &RuntimeClient,
    engine: ExecEngine,
    n: usize,
    mp: usize,
    steps: usize,
) -> Vec<Vec<MaskedSpan>> {
    let mut session = builder(n, mp, engine, false)
        .steps(steps)
        .trace(true)
        .validate(rt)
        .unwrap()
        .start()
        .unwrap();
    session.run().unwrap();
    let snap = session.cluster().tracer().unwrap().snapshot();
    snap.ranks
        .iter()
        .map(|r| {
            r.spans
                .iter()
                .map(|s| (s.kind.name(), s.step, s.round, s.seg, s.bytes))
                .collect()
        })
        .collect()
}

/// Per-kind (count, bytes) pairs — the timing-masked half of a
/// [`Metrics`] document.
fn masked_ops(m: &Metrics) -> Vec<(u64, u64)> {
    OpKind::ALL.iter().map(|&k| (m.op(k).count, m.op(k).bytes)).collect()
}

#[test]
fn span_sequences_bit_identical_across_seeded_replays() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let a = masked_spans(&rt, ExecEngine::Threaded, 4, 2, 8);
    let b = masked_spans(&rt, ExecEngine::Threaded, 4, 2, 8);
    assert!(!a.is_empty() && a.iter().any(|r| !r.is_empty()), "spans were recorded");
    assert_eq!(a, b, "same seed + config must replay the same span sequence");
}

#[test]
fn span_sequences_bit_identical_across_inproc_engines() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let seq = masked_spans(&rt, ExecEngine::Sequential, 4, 2, 8);
    let thr = masked_spans(&rt, ExecEngine::Threaded, 4, 2, 8);
    assert_eq!(seq.len(), thr.len());
    for (rank, (a, b)) in seq.iter().zip(thr.iter()).enumerate() {
        assert_eq!(a, b, "rank {rank}: sequential vs threaded span sequence diverged");
    }
}

/// The TCP engine against the in-proc threaded engine: per-rank masked
/// span sequences are recovered from each worker's exported
/// `trace-opid<R>.json` (deterministic fields ride the export
/// unscathed) and the merged per-opid metrics must agree with the
/// in-proc session's metrics on every per-kind count and byte total.
#[test]
fn tcp_spans_and_metrics_match_inproc() {
    let (n, mp, steps) = (2usize, 2usize, 4usize);
    let rt = RuntimeClient::load("artifacts").unwrap();
    let inproc = masked_spans(&rt, ExecEngine::Threaded, n, mp, steps);
    let mut session = builder(n, mp, ExecEngine::Threaded, false)
        .steps(steps)
        .trace(true)
        .validate(&rt)
        .unwrap()
        .start()
        .unwrap();
    session.run().unwrap();
    let inproc_metrics = session.metrics().unwrap();

    let peers: Vec<TcpPeer> = {
        let listeners: Vec<std::net::TcpListener> = (0..n)
            .map(|_| std::net::TcpListener::bind("127.0.0.1:0").unwrap())
            .collect();
        listeners
            .iter()
            .enumerate()
            .map(|(opid, l)| TcpPeer { opid, addr: l.local_addr().unwrap().to_string() })
            .collect()
    };
    let out_dir =
        std::env::temp_dir().join(format!("splitbrain-obs-trace-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&out_dir);
    std::fs::create_dir_all(&out_dir).unwrap();
    let cfg = builder(n, mp, ExecEngine::Threaded, false).cluster_config().unwrap();
    let outcomes: Vec<RunOutcome> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..n)
            .map(|opid| {
                let pc = ProcConfig {
                    cluster: cfg.clone(),
                    steps,
                    opid,
                    peers: peers.clone(),
                    artifacts: "artifacts".to_string(),
                    out_dir: Some(out_dir.clone()),
                    connect_timeout_ms: 30_000,
                    log_every: 0,
                    run_dir: None,
                    resume_step: 0,
                    trace: true,
                };
                s.spawn(move || run_worker(&pc).unwrap())
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert!(outcomes.iter().all(|o| *o == RunOutcome::Completed));

    let mut parts = Vec::new();
    for opid in 0..n {
        // Masked spans out of the Chrome export: "X" events, in order.
        let text =
            std::fs::read_to_string(out_dir.join(format!("trace-opid{opid}.json"))).unwrap();
        let doc = Json::parse(&text).unwrap();
        let events = doc.get("traceEvents").unwrap().as_array().unwrap();
        let spans: Vec<(String, u32, u32, u32, u64)> = events
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .map(|e| {
                assert_eq!(
                    e.get("tid").unwrap().as_u64(),
                    Some(opid as u64),
                    "a worker records only its own rank"
                );
                let args = e.get("args").unwrap();
                (
                    e.get("name").unwrap().as_str().unwrap().to_string(),
                    args.get("step").unwrap().as_u64().unwrap() as u32,
                    args.get("round").unwrap().as_u64().unwrap() as u32,
                    args.get("seg").unwrap().as_u64().unwrap() as u32,
                    args.get("bytes").unwrap().as_u64().unwrap(),
                )
            })
            .collect();
        let want: Vec<(String, u32, u32, u32, u64)> = inproc[opid]
            .iter()
            .map(|&(k, step, round, seg, bytes)| (k.to_string(), step, round, seg, bytes))
            .collect();
        assert_eq!(spans, want, "rank {opid}: TCP vs in-proc span sequence diverged");

        let mtext =
            std::fs::read_to_string(out_dir.join(format!("metrics-opid{opid}.json"))).unwrap();
        parts.push(Metrics::parse(&mtext).unwrap());
    }
    let merged = Metrics::merge(&parts);
    assert_eq!(merged.ranks, n as u64, "one active rank per opid file");
    assert_eq!(merged.steps, steps as u64);
    assert_eq!(masked_ops(&merged), masked_ops(&inproc_metrics));
    assert_eq!(merged.total_bytes(), inproc_metrics.total_bytes());
    assert!(!merged.peers.is_empty(), "TCP metrics carry per-peer transport stats");
    let _ = std::fs::remove_dir_all(&out_dir);
}

/// `--trace` off: no metrics, no trace — and bit-identical numerics to
/// a traced run (instrumentation must observe, never perturb).
#[test]
fn disabled_tracer_is_inert_and_bitwise_invisible() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let run = |trace: bool| {
        let mut session = builder(2, 2, ExecEngine::Threaded, false)
            .steps(4)
            .trace(trace)
            .validate(&rt)
            .unwrap()
            .start()
            .unwrap();
        let sink = CollectSink::new();
        let events = sink.events();
        session.attach(Box::new(sink));
        session.run().unwrap();
        let loss_bits: Vec<u64> =
            step_reports(&events.borrow()).iter().map(|r| r.loss.to_bits()).collect();
        (loss_bits, session.metrics(), session.chrome_trace())
    };
    let (plain_bits, plain_metrics, plain_trace) = run(false);
    assert!(plain_metrics.is_none(), "untraced session has no metrics");
    assert!(plain_trace.is_none(), "untraced session has no trace");
    let (traced_bits, traced_metrics, traced_trace) = run(true);
    assert!(traced_metrics.is_some() && traced_trace.is_some());
    assert_eq!(plain_bits, traced_bits, "tracing changed the numerics");
}

#[test]
fn chrome_trace_export_parses_and_counts_spans() {
    let rt = RuntimeClient::load("artifacts").unwrap();
    let mut session = builder(2, 2, ExecEngine::Threaded, false)
        .steps(4)
        .trace(true)
        .validate(&rt)
        .unwrap()
        .start()
        .unwrap();
    session.run().unwrap();
    let snap = session.cluster().tracer().unwrap().snapshot();
    let text = session.chrome_trace().unwrap();
    let doc = Json::parse(&text).unwrap();
    let events = doc.get("traceEvents").unwrap().as_array().unwrap();
    let spans: Vec<&Json> =
        events.iter().filter(|e| e.get("ph").and_then(Json::as_str) == Some("X")).collect();
    assert_eq!(spans.len() as u64, snap.span_count(), "one X event per retained span");
    let metas =
        events.iter().filter(|e| e.get("ph").and_then(Json::as_str) == Some("M")).count();
    let active_ranks = snap.ranks.iter().filter(|r| !r.spans.is_empty()).count();
    assert_eq!(metas, 1 + active_ranks, "process_name + one thread_name per active rank");
    for s in &spans {
        let args = s.get("args").expect("span args");
        for key in ["step", "round", "seg", "bytes"] {
            assert!(args.get(key).and_then(Json::as_u64).is_some(), "span arg {key}");
        }
    }
}

/// The histogram bucket layout is schema ([`LogHistogram`] merges
/// bucket-by-bucket across processes, so the edges may never drift):
/// bucket 0 = zeros, bucket i = [2^(i-1), 2^i), bucket 31 open-ended.
#[test]
fn histogram_bucket_edges_are_schema() {
    assert_eq!(LogHistogram::BUCKETS, 32);
    for (v, bucket) in
        [(0u64, 0usize), (1, 1), (2, 2), (3, 2), (4, 3), (1023, 10), (1024, 11), (1 << 30, 31), (u64::MAX, 31)]
    {
        assert_eq!(LogHistogram::bucket_of(v), bucket, "bucket_of({v})");
    }
    assert_eq!(LogHistogram::lower_bound(0), 0);
    assert_eq!(LogHistogram::lower_bound(1), 1);
    assert_eq!(LogHistogram::lower_bound(11), 1024);
    let mut h = LogHistogram::new();
    for v in [0, 1, 1024, u64::MAX] {
        h.record(v);
    }
    let doc = Json::parse(&h.to_json()).unwrap();
    assert_eq!(LogHistogram::from_json(&doc).unwrap(), h, "JSON round trip");
}

/// The acceptance criterion: a seeded 4-rank traced run persists
/// `metrics.json` + `trace.json` into its run dir, the watcher reads
/// the metrics back, and `profile` folds them against the plan's
/// analytic volumes with **exactly zero** byte error on every phase
/// that moved data.
#[test]
fn profile_over_fresh_traced_run_dir_has_zero_byte_error() {
    let (n, mp, steps) = (4usize, 2usize, 8usize);
    let rt = RuntimeClient::load("artifacts").unwrap();
    let dir = std::env::temp_dir()
        .join(format!("splitbrain-obs-profile-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut session = builder(n, mp, ExecEngine::Threaded, false)
        .steps(steps)
        .run_dir(&dir)
        .trace(true)
        .validate(&rt)
        .unwrap()
        .start()
        .unwrap();
    session.run().unwrap();
    drop(session);
    assert!(dir.join("trace.json").is_file(), "run end writes trace.json");
    assert!(dir.join("metrics.json").is_file(), "boundaries + run end write metrics.json");

    // The watcher reads the same snapshot back, read-only.
    let watcher = Watcher::open(&dir).unwrap();
    let metrics = watcher.metrics().unwrap().expect("traced run dir has metrics");
    assert_eq!(metrics.ranks, n as u64);
    assert_eq!(metrics.steps, steps as u64);

    // Rebuild the plan from the run dir's own manifest (exactly what
    // `splitbrain profile <run-dir>` does) and fold.
    let manifest = std::fs::read_to_string(dir.join("run.json")).unwrap();
    let plan = SessionBuilder::from_manifest(&manifest).unwrap().validate(&rt).unwrap();
    let report = profile(plan.schedule(), &plan.cluster_config().net, &metrics);
    assert_eq!(report.ranks, n as u64);
    assert_eq!(report.steps, steps as u64);
    let mut phases_with_traffic = 0;
    for row in &report.rows {
        assert_eq!(
            row.measured_bytes, row.predicted_bytes,
            "{}: measured bytes must hit the analytic volume exactly",
            row.category
        );
        if row.predicted_bytes > 0 {
            phases_with_traffic += 1;
            assert_eq!(row.bytes_rel_err(), Some(0.0), "{}: 0% byte error", row.category);
        }
    }
    assert!(phases_with_traffic >= 2, "MP and averaging phases both moved data");

    // The deterministic portion of the rendered report is pinned;
    // timing columns are wall-clock and deliberately not.
    let rendered = report.render();
    assert!(
        rendered.contains("=== measured vs predicted comm profile (4 ranks, 8 steps) ==="),
        "header line:\n{rendered}"
    );
    for cat in CommCategory::ALL {
        assert!(rendered.contains(&cat.to_string()), "row for {cat}:\n{rendered}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
