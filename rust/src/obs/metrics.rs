//! The `metrics.json` snapshot: exact per-op-kind counters, per-phase
//! comm totals and per-peer transport histograms, serialized with a
//! fixed key order so seeded replays produce bit-identical files for
//! the deterministic fields (counts, bytes, histogram buckets) while
//! wall-clock fields (`us`, `wall_us`, take-wait histograms) stay
//! schema-stable but vary.
//!
//! One file is written per process: `metrics.json` by the in-proc
//! session, `metrics-opid{K}.json` by each TCP worker; the launcher
//! [`merge`](Metrics::merge)s the per-opid files into the canonical
//! `metrics.json` after the run. Snapshots are rewritten at every
//! averaging boundary so `splitbrain watch` can surface a live
//! per-phase breakdown.

use anyhow::{anyhow, bail, Context};

use crate::comm::CommCategory;
use crate::util::json::Json;
use crate::Result;

use super::hist::LogHistogram;
use super::tracer::{OpKind, TraceSnapshot};

/// Metrics schema version this build writes and reads.
pub const METRICS_VERSION: u64 = 1;

/// Exact aggregate for one op kind.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OpStat {
    /// Spans recorded.
    pub count: u64,
    /// Bytes posted during those spans (counted wire payload).
    pub bytes: u64,
    /// Wall µs spent (masked in determinism tests).
    pub us: u64,
}

/// One process's transport-level peer statistics (TCP runs only).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PeerStat {
    /// The observing process (stats are from its point of view).
    pub opid: u64,
    /// Counted payload bytes this process sent to peers.
    pub sent_bytes: u64,
    /// Counted messages sent.
    pub sent_msgs: u64,
    /// Counted payload bytes received from peers.
    pub recv_bytes: u64,
    /// Counted messages received.
    pub recv_msgs: u64,
    /// Sent-message payload sizes (log-bucketed, deterministic).
    pub sent_hist: LogHistogram,
    /// Received-message payload sizes (log-bucketed, deterministic).
    pub recv_hist: LogHistogram,
    /// Blocking-take wait times, µs (wall-clock: masked in tests).
    pub take_wait_us_hist: LogHistogram,
}

/// A parsed or freshly-snapshotted metrics document.
#[derive(Debug, Clone, PartialEq)]
pub struct Metrics {
    /// Ranks covered (summed across merged per-opid files).
    pub ranks: u64,
    /// Training steps completed at snapshot time.
    pub steps: u64,
    /// Spans retained in the ring(s).
    pub spans: u64,
    /// Spans dropped by ring wrap (aggregates still exact).
    pub spans_dropped: u64,
    /// Wall µs from first span start to last span end.
    pub wall_us: u64,
    /// Per-kind aggregates, [`OpKind::ALL`] order.
    pub ops: [OpStat; OpKind::COUNT],
    /// Per-process transport stats, sorted by opid (empty in-proc).
    pub peers: Vec<PeerStat>,
}

impl Metrics {
    /// Build a metrics document from a tracer snapshot. `ranks` counts
    /// the ranks that recorded anything: a TCP worker's tracer has one
    /// slot per cluster rank but records only its own, so each per-opid
    /// document covers one rank and the merged document covers `n`.
    pub fn from_snapshot(snap: &TraceSnapshot, steps: u64, peers: Vec<PeerStat>) -> Metrics {
        let mut ops = [OpStat::default(); OpKind::COUNT];
        let mut active = 0u64;
        for r in &snap.ranks {
            if r.count.iter().any(|&c| c > 0) {
                active += 1;
            }
            for i in 0..OpKind::COUNT {
                ops[i].count += r.count[i];
                ops[i].bytes += r.bytes[i];
                ops[i].us += r.us[i];
            }
        }
        let mut peers = peers;
        peers.sort_by_key(|p| p.opid);
        Metrics {
            ranks: active,
            steps,
            spans: snap.span_count(),
            spans_dropped: snap.dropped(),
            wall_us: snap.wall_us(),
            ops,
            peers,
        }
    }

    /// Aggregate stat for one op kind.
    pub fn op(&self, kind: OpKind) -> OpStat {
        self.ops[kind.index()]
    }

    /// Bytes attributed to a communication category (summing the op
    /// kinds that map to it).
    pub fn phase_bytes(&self, cat: CommCategory) -> u64 {
        OpKind::ALL
            .iter()
            .filter(|k| k.category() == Some(cat))
            .map(|k| self.ops[k.index()].bytes)
            .sum()
    }

    /// Wall µs attributed to a communication category.
    pub fn phase_us(&self, cat: CommCategory) -> u64 {
        OpKind::ALL
            .iter()
            .filter(|k| k.category() == Some(cat))
            .map(|k| self.ops[k.index()].us)
            .sum()
    }

    /// Wall µs spent in compute ops (no comm category).
    pub fn compute_us(&self) -> u64 {
        OpKind::ALL
            .iter()
            .filter(|k| k.category().is_none())
            .map(|k| self.ops[k.index()].us)
            .sum()
    }

    /// Total counted bytes across all op kinds.
    pub fn total_bytes(&self) -> u64 {
        self.ops.iter().map(|o| o.bytes).sum()
    }

    /// Fold several per-process documents (one per opid) into one:
    /// counters sum, `steps`/`wall_us` take the maximum (every process
    /// runs the same step count; epochs are per-process), peer lists
    /// concatenate sorted by opid.
    pub fn merge(parts: &[Metrics]) -> Metrics {
        let mut out = Metrics {
            ranks: 0,
            steps: 0,
            spans: 0,
            spans_dropped: 0,
            wall_us: 0,
            ops: [OpStat::default(); OpKind::COUNT],
            peers: Vec::new(),
        };
        for p in parts {
            out.ranks += p.ranks;
            out.steps = out.steps.max(p.steps);
            out.spans += p.spans;
            out.spans_dropped += p.spans_dropped;
            out.wall_us = out.wall_us.max(p.wall_us);
            for i in 0..OpKind::COUNT {
                out.ops[i].count += p.ops[i].count;
                out.ops[i].bytes += p.ops[i].bytes;
                out.ops[i].us += p.ops[i].us;
            }
            out.peers.extend(p.peers.iter().cloned());
        }
        out.peers.sort_by_key(|p| p.opid);
        out
    }

    /// Canonical JSON text: fixed key order, one top-level key per
    /// line, trailing newline. Deterministic fields are bit-identical
    /// across seeded replays; `us`/`wall_us`/take-wait histograms are
    /// wall-clock.
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(2048);
        s.push_str("{\n");
        s.push_str(&format!("  \"splitbrain_metrics\": {METRICS_VERSION},\n"));
        s.push_str(&format!("  \"ranks\": {},\n", self.ranks));
        s.push_str(&format!("  \"steps\": {},\n", self.steps));
        s.push_str(&format!("  \"spans\": {},\n", self.spans));
        s.push_str(&format!("  \"spans_dropped\": {},\n", self.spans_dropped));
        s.push_str(&format!("  \"wall_us\": {},\n", self.wall_us));
        s.push_str("  \"ops\": {");
        for (i, k) in OpKind::ALL.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            let o = self.ops[k.index()];
            s.push_str(&format!(
                "\"{}\": {{\"count\": {}, \"bytes\": {}, \"us\": {}}}",
                k.name(),
                o.count,
                o.bytes,
                o.us
            ));
        }
        s.push_str("},\n");
        s.push_str("  \"phases\": {");
        for (i, &c) in CommCategory::ALL.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!(
                "\"{c}\": {{\"bytes\": {}, \"us\": {}}}",
                self.phase_bytes(c),
                self.phase_us(c)
            ));
        }
        s.push_str("},\n");
        s.push_str("  \"peers\": {");
        for (i, p) in self.peers.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!(
                "\"{}\": {{\"sent_bytes\": {}, \"sent_msgs\": {}, \"recv_bytes\": {}, \
                 \"recv_msgs\": {}, \"sent_hist\": {}, \"recv_hist\": {}, \
                 \"take_wait_us_hist\": {}}}",
                p.opid,
                p.sent_bytes,
                p.sent_msgs,
                p.recv_bytes,
                p.recv_msgs,
                p.sent_hist.to_json(),
                p.recv_hist.to_json(),
                p.take_wait_us_hist.to_json()
            ));
        }
        s.push_str("}\n}\n");
        s
    }

    /// Parse a metrics document. Strict on schema version and the ops
    /// table; the derived `phases` object is validated for presence but
    /// recomputed from `ops` (single source of truth).
    pub fn parse(text: &str) -> Result<Metrics> {
        let doc = Json::parse(text).context("parsing metrics.json")?;
        let version = doc
            .get("splitbrain_metrics")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("metrics: missing \"splitbrain_metrics\" version"))?;
        if version != METRICS_VERSION {
            bail!("metrics: schema version {version} (this build reads {METRICS_VERSION})");
        }
        let num = |key: &str| -> Result<u64> {
            doc.get(key)
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("metrics: missing/bad \"{key}\""))
        };
        let ops_doc = doc.get("ops").ok_or_else(|| anyhow!("metrics: missing \"ops\""))?;
        let mut ops = [OpStat::default(); OpKind::COUNT];
        for k in OpKind::ALL {
            let o = ops_doc
                .get(k.name())
                .ok_or_else(|| anyhow!("metrics: ops missing \"{}\"", k.name()))?;
            let field = |key: &str| -> Result<u64> {
                o.get(key)
                    .and_then(Json::as_u64)
                    .ok_or_else(|| anyhow!("metrics: ops.{}.{key} missing/bad", k.name()))
            };
            ops[k.index()] =
                OpStat { count: field("count")?, bytes: field("bytes")?, us: field("us")? };
        }
        if doc.get("phases").is_none() {
            bail!("metrics: missing \"phases\"");
        }
        let mut peers = Vec::new();
        let peers_doc =
            doc.get("peers").ok_or_else(|| anyhow!("metrics: missing \"peers\""))?;
        for (key, p) in peers_doc
            .fields()
            .ok_or_else(|| anyhow!("metrics: \"peers\" must be an object"))?
        {
            let opid: u64 =
                key.parse().map_err(|_| anyhow!("metrics: peer key {key:?} is not an opid"))?;
            let field = |k: &str| -> Result<u64> {
                p.get(k)
                    .and_then(Json::as_u64)
                    .ok_or_else(|| anyhow!("metrics: peers.{opid}.{k} missing/bad"))
            };
            let hist = |k: &str| -> Result<LogHistogram> {
                LogHistogram::from_json(
                    p.get(k).ok_or_else(|| anyhow!("metrics: peers.{opid}.{k} missing"))?,
                )
            };
            peers.push(PeerStat {
                opid,
                sent_bytes: field("sent_bytes")?,
                sent_msgs: field("sent_msgs")?,
                recv_bytes: field("recv_bytes")?,
                recv_msgs: field("recv_msgs")?,
                sent_hist: hist("sent_hist")?,
                recv_hist: hist("recv_hist")?,
                take_wait_us_hist: hist("take_wait_us_hist")?,
            });
        }
        peers.sort_by_key(|p| p.opid);
        Ok(Metrics {
            ranks: num("ranks")?,
            steps: num("steps")?,
            spans: num("spans")?,
            spans_dropped: num("spans_dropped")?,
            wall_us: num("wall_us")?,
            ops,
            peers,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::tracer::TraceSet;

    fn sample() -> Metrics {
        let t = TraceSet::new(2);
        t.record(0, OpKind::ConvFwd, 1, 0, 0, 0, 0, 10);
        t.record(0, OpKind::PostActs, 1, 0, 0, 4096, 10, 12);
        t.record(1, OpKind::ShardGather, 1, 0, 1, 2048, 5, 40);
        let mut peer = PeerStat {
            opid: 0,
            sent_bytes: 4096,
            sent_msgs: 1,
            recv_bytes: 2048,
            recv_msgs: 1,
            sent_hist: LogHistogram::new(),
            recv_hist: LogHistogram::new(),
            take_wait_us_hist: LogHistogram::new(),
        };
        peer.sent_hist.record(4096);
        peer.recv_hist.record(2048);
        peer.take_wait_us_hist.record(35);
        Metrics::from_snapshot(&t.snapshot(), 1, vec![peer])
    }

    #[test]
    fn json_round_trips() {
        let m = sample();
        let text = m.to_json();
        let back = Metrics::parse(&text).unwrap();
        assert_eq!(back, m);
        assert_eq!(back.to_json(), text, "canonical: serialize→parse→serialize");
    }

    #[test]
    fn phases_derive_from_ops() {
        let m = sample();
        assert_eq!(m.phase_bytes(CommCategory::ModuloFwd), 4096);
        assert_eq!(m.phase_bytes(CommCategory::ShardFwd), 2048);
        assert_eq!(m.phase_us(CommCategory::ShardFwd), 35);
        assert_eq!(m.compute_us(), 10);
        assert_eq!(m.total_bytes(), 4096 + 2048);
    }

    #[test]
    fn merge_sums_counters_and_concats_peers() {
        let a = sample();
        let mut b = sample();
        b.peers[0].opid = 1;
        let m = Metrics::merge(&[a.clone(), b]);
        assert_eq!(m.ranks, 4);
        assert_eq!(m.steps, 1);
        assert_eq!(m.spans, 6);
        assert_eq!(m.op(OpKind::PostActs).bytes, 8192);
        assert_eq!(m.peers.len(), 2);
        assert_eq!((m.peers[0].opid, m.peers[1].opid), (0, 1));
        // A merged document still round-trips.
        assert_eq!(Metrics::parse(&m.to_json()).unwrap(), m);
    }

    #[test]
    fn parse_rejects_bad_version_and_missing_ops() {
        let text = sample().to_json().replace(
            "\"splitbrain_metrics\": 1",
            "\"splitbrain_metrics\": 9",
        );
        assert!(Metrics::parse(&text).is_err());
        assert!(Metrics::parse("{}").is_err());
    }
}
