//! The measured-vs-predicted cost-model report behind
//! `splitbrain profile <run-dir>`.
//!
//! Folds a run's measured [`Metrics`] against the [`StepSchedule`]'s
//! analytic per-phase communication volumes and the α–β [`NetModel`]'s
//! time predictions, per [`CommCategory`]. Byte columns compare
//! **cluster totals**: the schedule predicts what one member posts per
//! phase occurrence, every participant posts it (uniform schemes), and
//! the tracer measures exactly the transport's counted payload — so on
//! a clean run the relative error of the byte columns is exactly 0 %,
//! which is the honesty check the cost-model-driven auto-partitioner
//! (ROADMAP) searches against. Time columns compare the model's
//! critical path against the mean measured per-rank wall time and are
//! expected to differ (that difference *is* the report's value).

use crate::comm::{CommCategory, NetModel};
use crate::coordinator::schedule::StepSchedule;

use super::metrics::Metrics;
use super::tracer::OpKind;

/// One category's measured-vs-predicted comparison.
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseRow {
    /// The communication category.
    pub category: CommCategory,
    /// Phase-occurrence count over the run (steps for MP categories,
    /// averaging events for the averaging categories), derived from
    /// the measured op counts.
    pub events: u64,
    /// Cluster-total predicted bytes over the run.
    pub predicted_bytes: u64,
    /// Cluster-total measured bytes over the run.
    pub measured_bytes: u64,
    /// Modeled seconds over the run (per-rank critical path).
    pub predicted_secs: f64,
    /// Mean measured per-rank wall seconds over the run.
    pub measured_secs: f64,
}

impl PhaseRow {
    /// Relative byte error (measured vs predicted); `None` when both
    /// sides are zero.
    pub fn bytes_rel_err(&self) -> Option<f64> {
        rel_err(self.measured_bytes as f64, self.predicted_bytes as f64)
    }

    /// Relative time error; `None` when both sides are zero.
    pub fn secs_rel_err(&self) -> Option<f64> {
        rel_err(self.measured_secs, self.predicted_secs)
    }
}

fn rel_err(measured: f64, predicted: f64) -> Option<f64> {
    if predicted == 0.0 && measured == 0.0 {
        None
    } else if predicted == 0.0 {
        Some(f64::INFINITY)
    } else {
        Some((measured - predicted) / predicted)
    }
}

/// The full report: one row per category plus run-level context.
#[derive(Debug, Clone)]
pub struct ProfileReport {
    /// Per-category rows, [`CommCategory::ALL`] order.
    pub rows: Vec<PhaseRow>,
    /// Ranks the metrics cover.
    pub ranks: u64,
    /// Steps the metrics cover.
    pub steps: u64,
    /// Mean measured per-rank compute seconds.
    pub compute_secs: f64,
    /// Measured wall seconds (first span start → last span end).
    pub wall_secs: f64,
}

/// Fold measured metrics against the schedule's analytic volumes and
/// the network model's time predictions.
pub fn profile(schedule: &StepSchedule, net: &NetModel, metrics: &Metrics) -> ProfileReport {
    let ranks = metrics.ranks.max(1);
    // Occurrences: MP phases run every step; averaging phases run once
    // per averaging event. Both are read off the measured op counts
    // (every participating rank records one span per occurrence), so
    // the byte columns isolate the *volume* model, not the scheduler.
    let avg_events =
        |kind: OpKind| -> u64 { metrics.op(kind).count / ranks };
    let rows = CommCategory::ALL
        .iter()
        .map(|&cat| {
            let (phases, events): (&[_], u64) = match cat {
                CommCategory::DpAverage => {
                    (&schedule.avg_phases, avg_events(OpKind::AverageReplicated))
                }
                CommCategory::ShardAverage => {
                    (&schedule.avg_phases, avg_events(OpKind::AverageShards))
                }
                _ => (&schedule.mp_phases, metrics.steps),
            };
            let per_member_bytes: u64 = phases
                .iter()
                .filter(|p| p.category == cat)
                .map(|p| p.times * p.per_member.bytes_out)
                .sum();
            let secs_per_event: f64 = phases
                .iter()
                .filter(|p| p.category == cat)
                .map(|p| p.times as f64 * net.phase_time(p.per_member))
                .sum();
            PhaseRow {
                category: cat,
                events,
                predicted_bytes: events * per_member_bytes * metrics.ranks,
                measured_bytes: metrics.phase_bytes(cat),
                predicted_secs: events as f64 * secs_per_event,
                measured_secs: metrics.phase_us(cat) as f64 / 1e6 / ranks as f64,
            }
        })
        .collect();
    ProfileReport {
        rows,
        ranks: metrics.ranks,
        steps: metrics.steps,
        compute_secs: metrics.compute_us() as f64 / 1e6 / ranks as f64,
        wall_secs: metrics.wall_us as f64 / 1e6,
    }
}

impl ProfileReport {
    /// Render the per-phase table. Byte columns (and their error) are
    /// deterministic for seeded replays; time columns are wall-clock.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "=== measured vs predicted comm profile ({} ranks, {} steps) ===\n",
            self.ranks, self.steps
        ));
        s.push_str(&format!(
            "{:<14} {:>7} {:>14} {:>14} {:>8} {:>12} {:>12} {:>8}\n",
            "phase", "events", "pred bytes", "meas bytes", "err", "pred s", "meas s/rank", "err"
        ));
        for r in &self.rows {
            s.push_str(&format!(
                "{:<14} {:>7} {:>14} {:>14} {:>8} {:>12.6} {:>12.6} {:>8}\n",
                r.category.to_string(),
                r.events,
                r.predicted_bytes,
                r.measured_bytes,
                fmt_err(r.bytes_rel_err()),
                r.predicted_secs,
                r.measured_secs,
                fmt_err(r.secs_rel_err()),
            ));
        }
        let pred_total: u64 = self.rows.iter().map(|r| r.predicted_bytes).sum();
        let meas_total: u64 = self.rows.iter().map(|r| r.measured_bytes).sum();
        s.push_str(&format!(
            "{:<14} {:>7} {:>14} {:>14} {:>8}\n",
            "total",
            "",
            pred_total,
            meas_total,
            fmt_err(rel_err(meas_total as f64, pred_total as f64)),
        ));
        s.push_str(&format!(
            "compute: {:.6} s/rank   wall: {:.6} s\n",
            self.compute_secs, self.wall_secs
        ));
        s
    }
}

fn fmt_err(err: Option<f64>) -> String {
    match err {
        None => "--".to_string(),
        Some(e) if e.is_infinite() => "inf".to_string(),
        Some(e) => format!("{:+.1}%", e * 100.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::netmodel::PhaseVolume;
    use crate::coordinator::schedule::CommPhase;
    use crate::obs::metrics::OpStat;

    /// A hand-built schedule fragment + matching metrics: the byte
    /// columns must come out exactly equal (0 % error).
    #[test]
    fn exact_bytes_give_zero_error() {
        let rt = crate::runtime::RuntimeClient::native().unwrap();
        let net_model = crate::model::partition_network(
            &crate::model::vgg11(),
            vec![32, 32, 3],
            &crate::model::PartitionConfig { mp: 2, ..Default::default() },
        )
        .unwrap();
        let topo = crate::coordinator::GmpTopology::new(4, 2).unwrap();
        let schedule = StepSchedule::compile_with_algo(
            &net_model,
            topo,
            &rt.manifest,
            false,
            crate::coordinator::McastScheme::BoverK,
            crate::comm::CollectiveAlgo::Ring,
        )
        .unwrap();
        let steps = 4u64;
        let avg_events = 2u64;
        let ranks = 4u64;
        // Synthesize metrics whose per-category bytes equal the
        // schedule's cluster-total predictions exactly.
        let mut ops = [OpStat::default(); OpKind::COUNT];
        for cat in CommCategory::ALL {
            let (phases, events): (&[CommPhase], u64) = match cat {
                CommCategory::DpAverage | CommCategory::ShardAverage => {
                    (&schedule.avg_phases, avg_events)
                }
                _ => (&schedule.mp_phases, steps),
            };
            let bytes: u64 = phases
                .iter()
                .filter(|p| p.category == cat)
                .map(|p| p.times * p.per_member.bytes_out)
                .sum();
            // Attribute everything to one representative op kind.
            let kind = match cat {
                CommCategory::DpAverage => OpKind::AverageReplicated,
                CommCategory::ShardAverage => OpKind::AverageShards,
                CommCategory::ModuloFwd => OpKind::PostActs,
                CommCategory::ModuloBwd => OpKind::PostGrads,
                CommCategory::ShardFwd => OpKind::ShardGather,
                CommCategory::ShardBwd => OpKind::ShardBwd,
            };
            ops[kind.index()].bytes = events * bytes * ranks;
        }
        ops[OpKind::AverageReplicated.index()].count = avg_events * ranks;
        ops[OpKind::AverageShards.index()].count = avg_events * ranks;
        let metrics = Metrics {
            ranks,
            steps,
            spans: 0,
            spans_dropped: 0,
            wall_us: 0,
            ops,
            peers: vec![],
        };
        let report = profile(&schedule, &NetModel::default(), &metrics);
        for row in &report.rows {
            assert_eq!(
                row.predicted_bytes, row.measured_bytes,
                "{}: bytes must match exactly",
                row.category
            );
            let err = row.bytes_rel_err();
            assert!(err.is_none() || err == Some(0.0), "{}: {err:?}", row.category);
        }
        let rendered = report.render();
        assert!(rendered.contains("dp-average"));
        assert!(rendered.contains("+0.0%") || rendered.contains("--"), "{rendered}");
    }

    #[test]
    fn volume_mismatch_shows_up_as_error() {
        let mut m = Metrics {
            ranks: 2,
            steps: 1,
            spans: 0,
            spans_dropped: 0,
            wall_us: 0,
            ops: [OpStat::default(); OpKind::COUNT],
            peers: vec![],
        };
        m.ops[OpKind::PostActs.index()].bytes = 1000;
        let schedule = StepSchedule {
            topo: crate::coordinator::GmpTopology::new(2, 2).unwrap(),
            batch: 8,
            algo: crate::comm::CollectiveAlgo::Naive,
            boundary_width: 4,
            shard_widths: vec![4, 4],
            compute: vec![],
            mp_phases: vec![CommPhase {
                category: CommCategory::ModuloFwd,
                per_member: PhaseVolume::new(1, 400),
                times: 1,
                ranks: 2,
            }],
            avg_phases: vec![],
            replicated_params: 0,
            shard_params: 0,
        };
        let report = profile(&schedule, &NetModel::default(), &m);
        let row = report
            .rows
            .iter()
            .find(|r| r.category == CommCategory::ModuloFwd)
            .unwrap();
        assert_eq!(row.predicted_bytes, 800);
        assert_eq!(row.measured_bytes, 1000);
        assert!((row.bytes_rel_err().unwrap() - 0.25).abs() < 1e-12);
    }
}
