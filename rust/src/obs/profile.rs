//! The measured-vs-predicted cost-model report behind
//! `splitbrain profile <run-dir>`.
//!
//! Folds a run's measured [`Metrics`] against the [`StepSchedule`]'s
//! analytic per-phase communication volumes and the α–β [`NetModel`]'s
//! time predictions, per [`CommCategory`]. Byte columns compare
//! **cluster totals**: the schedule predicts what one member posts per
//! phase occurrence, every participant posts it (uniform schemes), and
//! the tracer measures exactly the transport's counted payload — so on
//! a clean run the relative error of the byte columns is exactly 0 %,
//! which is the honesty check the cost-model-driven auto-partitioner
//! (ROADMAP) searches against. Time columns compare the model's
//! critical path against the mean measured per-rank wall time and are
//! expected to differ (that difference *is* the report's value).

use anyhow::{bail, Result};

use crate::comm::{CommCategory, NetModel};
use crate::coordinator::schedule::StepSchedule;
use crate::model::{dims, Layer, TransformedNet};

use super::metrics::Metrics;
use super::tracer::OpKind;

/// One category's measured-vs-predicted comparison.
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseRow {
    /// The communication category.
    pub category: CommCategory,
    /// Phase-occurrence count over the run (steps for MP categories,
    /// averaging events for the averaging categories), derived from
    /// the measured op counts.
    pub events: u64,
    /// Cluster-total predicted bytes over the run.
    pub predicted_bytes: u64,
    /// Cluster-total measured bytes over the run.
    pub measured_bytes: u64,
    /// Modeled seconds over the run (per-rank critical path).
    pub predicted_secs: f64,
    /// Mean measured per-rank wall seconds over the run.
    pub measured_secs: f64,
}

impl PhaseRow {
    /// Relative byte error (measured vs predicted); `None` when both
    /// sides are zero.
    pub fn bytes_rel_err(&self) -> Option<f64> {
        rel_err(self.measured_bytes as f64, self.predicted_bytes as f64)
    }

    /// Relative time error; `None` when both sides are zero.
    pub fn secs_rel_err(&self) -> Option<f64> {
        rel_err(self.measured_secs, self.predicted_secs)
    }
}

fn rel_err(measured: f64, predicted: f64) -> Option<f64> {
    if predicted == 0.0 && measured == 0.0 {
        None
    } else if predicted == 0.0 {
        Some(f64::INFINITY)
    } else {
        Some((measured - predicted) / predicted)
    }
}

/// The full report: one row per category plus run-level context.
#[derive(Debug, Clone)]
pub struct ProfileReport {
    /// Per-category rows, [`CommCategory::ALL`] order.
    pub rows: Vec<PhaseRow>,
    /// Ranks the metrics cover.
    pub ranks: u64,
    /// Steps the metrics cover.
    pub steps: u64,
    /// Mean measured per-rank compute seconds.
    pub compute_secs: f64,
    /// Measured wall seconds (first span start → last span end).
    pub wall_secs: f64,
}

/// Fold measured metrics against the schedule's analytic volumes and
/// the network model's time predictions.
pub fn profile(schedule: &StepSchedule, net: &NetModel, metrics: &Metrics) -> ProfileReport {
    let ranks = metrics.ranks.max(1);
    // Occurrences: MP phases run every step; averaging phases run once
    // per averaging event. Both are read off the measured op counts
    // (every participating rank records one span per occurrence), so
    // the byte columns isolate the *volume* model, not the scheduler.
    let avg_events =
        |kind: OpKind| -> u64 { metrics.op(kind).count / ranks };
    let rows = CommCategory::ALL
        .iter()
        .map(|&cat| {
            let (phases, events): (&[_], u64) = match cat {
                CommCategory::DpAverage => {
                    (&schedule.avg_phases, avg_events(OpKind::AverageReplicated))
                }
                CommCategory::ShardAverage => {
                    (&schedule.avg_phases, avg_events(OpKind::AverageShards))
                }
                _ => (&schedule.mp_phases, metrics.steps),
            };
            let per_member_bytes: u64 = phases
                .iter()
                .filter(|p| p.category == cat)
                .map(|p| p.times * p.per_member.bytes_out)
                .sum();
            let secs_per_event: f64 = phases
                .iter()
                .filter(|p| p.category == cat)
                .map(|p| p.times as f64 * net.phase_time(p.per_member))
                .sum();
            PhaseRow {
                category: cat,
                events,
                predicted_bytes: events * per_member_bytes * metrics.ranks,
                measured_bytes: metrics.phase_bytes(cat),
                predicted_secs: events as f64 * secs_per_event,
                measured_secs: metrics.phase_us(cat) as f64 / 1e6 / ranks as f64,
            }
        })
        .collect();
    ProfileReport {
        rows,
        ranks: metrics.ranks,
        steps: metrics.steps,
        compute_secs: metrics.compute_us() as f64 / 1e6 / ranks as f64,
        wall_secs: metrics.wall_us as f64 / 1e6,
    }
}

impl ProfileReport {
    /// Render the per-phase table. Byte columns (and their error) are
    /// deterministic for seeded replays; time columns are wall-clock.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "=== measured vs predicted comm profile ({} ranks, {} steps) ===\n",
            self.ranks, self.steps
        ));
        s.push_str(&format!(
            "{:<14} {:>7} {:>14} {:>14} {:>8} {:>12} {:>12} {:>8}\n",
            "phase", "events", "pred bytes", "meas bytes", "err", "pred s", "meas s/rank", "err"
        ));
        for r in &self.rows {
            s.push_str(&format!(
                "{:<14} {:>7} {:>14} {:>14} {:>8} {:>12.6} {:>12.6} {:>8}\n",
                r.category.to_string(),
                r.events,
                r.predicted_bytes,
                r.measured_bytes,
                fmt_err(r.bytes_rel_err()),
                r.predicted_secs,
                r.measured_secs,
                fmt_err(r.secs_rel_err()),
            ));
        }
        let pred_total: u64 = self.rows.iter().map(|r| r.predicted_bytes).sum();
        let meas_total: u64 = self.rows.iter().map(|r| r.measured_bytes).sum();
        s.push_str(&format!(
            "{:<14} {:>7} {:>14} {:>14} {:>8}\n",
            "total",
            "",
            pred_total,
            meas_total,
            fmt_err(rel_err(meas_total as f64, pred_total as f64)),
        ));
        s.push_str(&format!(
            "compute: {:.6} s/rank   wall: {:.6} s\n",
            self.compute_secs, self.wall_secs
        ));
        s
    }
}

/// One compute [`OpKind`]'s measured kernel-throughput row: analytic
/// matmul/conv FLOPs folded against the traced span time, so kernel
/// regressions show up in `splitbrain profile` and not just the bench.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelRow {
    /// The compute op kind.
    pub kind: OpKind,
    /// Measured span count over the run (all ranks).
    pub count: u64,
    /// Measured microseconds over the run (summed across ranks).
    pub us: u64,
    /// Run-total analytic FLOPs for this kind: the per-rank-per-step
    /// matmul/conv FLOPs of the transformed network, times
    /// `ranks * steps`. Bias adds, ReLU, pooling, and softmax are
    /// excluded — the model counts the multiply-accumulate work the
    /// blocked kernels in `runtime::native` actually optimize.
    pub flops: u64,
}

impl KernelRow {
    /// Mean per-rank GFLOP/s (`flops / us / 1000`); `None` when no time
    /// was measured.
    pub fn gflops(&self) -> Option<f64> {
        if self.us == 0 {
            None
        } else {
            Some(self.flops as f64 / self.us as f64 / 1000.0)
        }
    }
}

/// Analytic per-kind FLOPs folded against measured span times.
///
/// The FLOPs model walks the transformed network threading the feature
/// shape with [`dims::resize`]: a `Conv{cin,cout,ksize}` on an
/// `[h,w,cin]` input contributes `2*ksize^2*cin*cout*h*w` per example,
/// a sharded `Linear` contributes `2*din*dout` (its 1/k shard) to the
/// FC-shard bucket, and an unsharded `Linear` the same to the
/// replicated-head bucket. Per rank per step, the conv front runs on
/// `batch` examples while the FC stack sees `mp * batch` examples
/// spread over the modulo rounds (every §3.1 scheme: `rounds *
/// fc_batch == mp * batch`); backward passes count 2x forward (dX and
/// dW), and the fused `full-step` path counts 3x everything. Kinds the
/// run never recorded (zero span count) emit no row.
pub fn kernel_rows(
    net: &TransformedNet,
    batch: usize,
    metrics: &Metrics,
) -> Result<Vec<KernelRow>> {
    // Per-example FLOPs: conv front / sharded-FC stack / replicated head.
    let (mut conv, mut shard, mut head) = (0u64, 0u64, 0u64);
    let mut dim = net.input_dim.clone();
    for layer in net.layers.iter().flat_map(|l| l.flatten()) {
        match layer {
            Layer::Conv { cin, cout, ksize, .. } => {
                let (h, w) = match dim.as_slice() {
                    [h, w, _] => (*h, *w),
                    other => bail!("conv on non-spatial input {other:?}"),
                };
                conv += 2 * (ksize * ksize * cin * cout * h * w) as u64;
            }
            Layer::Linear { din, dout, shard_of, .. } => {
                let f = 2 * (din * dout) as u64;
                if shard_of.is_some() {
                    shard += f;
                } else {
                    head += f;
                }
            }
            _ => {}
        }
        dim = dims::resize(layer, &dim)?;
    }
    let mp = net.mp.max(1) as u64;
    let b = batch as u64;
    // (kind, per-rank-per-step FLOPs) in reporting order.
    let per_rank_step: [(OpKind, u64); 6] = [
        (OpKind::FullStep, 3 * (conv + shard + head) * b),
        (OpKind::ConvFwd, conv * b),
        (OpKind::FcFwd, shard * mp * b),
        (OpKind::HeadStep, 3 * head * mp * b),
        (OpKind::FcBwd, 2 * shard * mp * b),
        (OpKind::ConvBwdUpdate, 2 * conv * b),
    ];
    let scale = metrics.ranks * metrics.steps;
    Ok(per_rank_step
        .iter()
        .filter_map(|&(kind, flops)| {
            let stat = metrics.op(kind);
            if stat.count == 0 || flops == 0 {
                return None;
            }
            Some(KernelRow { kind, count: stat.count, us: stat.us, flops: flops * scale })
        })
        .collect())
}

/// Render the measured kernel-throughput table produced by
/// [`kernel_rows`]. Empty input renders nothing (e.g. a comm-only
/// metrics file).
pub fn render_kernel_table(rows: &[KernelRow]) -> String {
    if rows.is_empty() {
        return String::new();
    }
    let mut s = String::new();
    s.push_str("=== measured kernel throughput (matmul/conv flops model) ===\n");
    s.push_str(&format!(
        "{:<16} {:>9} {:>14} {:>12} {:>10}\n",
        "kind", "spans", "flops", "meas s", "GFLOP/s"
    ));
    for r in rows {
        let g = match r.gflops() {
            None => "--".to_string(),
            Some(g) => format!("{g:.2}"),
        };
        s.push_str(&format!(
            "{:<16} {:>9} {:>14} {:>12.6} {:>10}\n",
            r.kind.name(),
            r.count,
            r.flops,
            r.us as f64 / 1e6,
            g,
        ));
    }
    s
}

fn fmt_err(err: Option<f64>) -> String {
    match err {
        None => "--".to_string(),
        Some(e) if e.is_infinite() => "inf".to_string(),
        Some(e) => format!("{:+.1}%", e * 100.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::netmodel::PhaseVolume;
    use crate::coordinator::schedule::CommPhase;
    use crate::obs::metrics::OpStat;

    /// A hand-built schedule fragment + matching metrics: the byte
    /// columns must come out exactly equal (0 % error).
    #[test]
    fn exact_bytes_give_zero_error() {
        let rt = crate::runtime::RuntimeClient::native().unwrap();
        let net_model = crate::model::partition_network(
            &crate::model::vgg11(),
            vec![32, 32, 3],
            &crate::model::PartitionConfig { mp: 2, ..Default::default() },
        )
        .unwrap();
        let topo = crate::coordinator::GmpTopology::new(4, 2).unwrap();
        let schedule = StepSchedule::compile_with_algo(
            &net_model,
            topo,
            &rt.manifest,
            false,
            crate::coordinator::McastScheme::BoverK,
            crate::comm::CollectiveAlgo::Ring,
        )
        .unwrap();
        let steps = 4u64;
        let avg_events = 2u64;
        let ranks = 4u64;
        // Synthesize metrics whose per-category bytes equal the
        // schedule's cluster-total predictions exactly.
        let mut ops = [OpStat::default(); OpKind::COUNT];
        for cat in CommCategory::ALL {
            let (phases, events): (&[CommPhase], u64) = match cat {
                CommCategory::DpAverage | CommCategory::ShardAverage => {
                    (&schedule.avg_phases, avg_events)
                }
                _ => (&schedule.mp_phases, steps),
            };
            let bytes: u64 = phases
                .iter()
                .filter(|p| p.category == cat)
                .map(|p| p.times * p.per_member.bytes_out)
                .sum();
            // Attribute everything to one representative op kind.
            let kind = match cat {
                CommCategory::DpAverage => OpKind::AverageReplicated,
                CommCategory::ShardAverage => OpKind::AverageShards,
                CommCategory::ModuloFwd => OpKind::PostActs,
                CommCategory::ModuloBwd => OpKind::PostGrads,
                CommCategory::ShardFwd => OpKind::ShardGather,
                CommCategory::ShardBwd => OpKind::ShardBwd,
            };
            ops[kind.index()].bytes = events * bytes * ranks;
        }
        ops[OpKind::AverageReplicated.index()].count = avg_events * ranks;
        ops[OpKind::AverageShards.index()].count = avg_events * ranks;
        let metrics = Metrics {
            ranks,
            steps,
            spans: 0,
            spans_dropped: 0,
            wall_us: 0,
            ops,
            peers: vec![],
        };
        let report = profile(&schedule, &NetModel::default(), &metrics);
        for row in &report.rows {
            assert_eq!(
                row.predicted_bytes, row.measured_bytes,
                "{}: bytes must match exactly",
                row.category
            );
            let err = row.bytes_rel_err();
            assert!(err.is_none() || err == Some(0.0), "{}: {err:?}", row.category);
        }
        let rendered = report.render();
        assert!(rendered.contains("dp-average"));
        assert!(rendered.contains("+0.0%") || rendered.contains("--"), "{rendered}");
    }

    #[test]
    fn volume_mismatch_shows_up_as_error() {
        let mut m = Metrics {
            ranks: 2,
            steps: 1,
            spans: 0,
            spans_dropped: 0,
            wall_us: 0,
            ops: [OpStat::default(); OpKind::COUNT],
            peers: vec![],
        };
        m.ops[OpKind::PostActs.index()].bytes = 1000;
        let schedule = StepSchedule {
            topo: crate::coordinator::GmpTopology::new(2, 2).unwrap(),
            batch: 8,
            algo: crate::comm::CollectiveAlgo::Naive,
            boundary_width: 4,
            shard_widths: vec![4, 4],
            compute: vec![],
            mp_phases: vec![CommPhase {
                category: CommCategory::ModuloFwd,
                per_member: PhaseVolume::new(1, 400),
                times: 1,
                ranks: 2,
            }],
            avg_phases: vec![],
            replicated_params: 0,
            shard_params: 0,
        };
        let report = profile(&schedule, &NetModel::default(), &m);
        let row = report
            .rows
            .iter()
            .find(|r| r.category == CommCategory::ModuloFwd)
            .unwrap();
        assert_eq!(row.predicted_bytes, 800);
        assert_eq!(row.measured_bytes, 1000);
        assert!((row.bytes_rel_err().unwrap() - 0.25).abs() < 1e-12);
    }

    /// Hand-built transformed net + synthetic metrics: the FLOPs model
    /// must produce exactly the analytic totals, and kinds the run
    /// never recorded must emit no row.
    #[test]
    fn kernel_rows_match_analytic_flops() {
        use crate::model::{Layer, TransformedNet};
        let net = TransformedNet {
            layers: vec![
                Layer::Conv { name: "c0".into(), cin: 1, cout: 2, ksize: 3 },
                Layer::Relu,
                Layer::Reshape { out: vec![32] },
                Layer::Modulo { dim: 32 },
                Layer::Linear { name: "fc0".into(), din: 32, dout: 8, shard_of: Some(2) },
                Layer::Shard { dim_part: 8, dim_full: 16 },
                Layer::Linear { name: "fc1".into(), din: 16, dout: 10, shard_of: None },
                Layer::LogSoftmax,
            ],
            mp: 2,
            input_dim: vec![4, 4, 1],
        };
        let mut ops = [OpStat::default(); OpKind::COUNT];
        ops[OpKind::ConvFwd.index()] = OpStat { count: 6, bytes: 0, us: 2000 };
        ops[OpKind::FcFwd.index()] = OpStat { count: 12, bytes: 0, us: 1000 };
        ops[OpKind::HeadStep.index()] = OpStat { count: 12, bytes: 0, us: 0 };
        ops[OpKind::ConvBwdUpdate.index()] = OpStat { count: 6, bytes: 0, us: 3000 };
        let metrics = Metrics {
            ranks: 2,
            steps: 3,
            spans: 0,
            spans_dropped: 0,
            wall_us: 0,
            ops,
            peers: vec![],
        };
        let rows = kernel_rows(&net, 4, &metrics).unwrap();
        // Per example: conv = 2*9*1*2*16 = 576, shard = 2*32*8 = 512,
        // head = 2*16*10 = 320; batch 4, mp 2, ranks*steps = 6.
        let kinds: Vec<OpKind> = rows.iter().map(|r| r.kind).collect();
        assert_eq!(
            kinds,
            vec![OpKind::ConvFwd, OpKind::FcFwd, OpKind::HeadStep, OpKind::ConvBwdUpdate]
        );
        assert_eq!(rows[0].flops, 576 * 4 * 6);
        assert_eq!(rows[1].flops, 512 * 2 * 4 * 6);
        assert_eq!(rows[2].flops, 3 * 320 * 2 * 4 * 6);
        assert_eq!(rows[3].flops, 2 * 576 * 4 * 6);
        let g = rows[0].gflops().unwrap();
        assert!((g - 13824.0 / 2000.0 / 1000.0).abs() < 1e-12, "{g}");
        assert_eq!(rows[2].gflops(), None);
        let table = render_kernel_table(&rows);
        assert!(table.contains("conv-fwd"), "{table}");
        assert!(table.contains("--"), "{table}");
        assert!(render_kernel_table(&[]).is_empty());
    }
}
