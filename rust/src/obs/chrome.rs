//! Chrome-trace-event export (`trace.json`), loadable in Perfetto
//! (<https://ui.perfetto.dev>) or `chrome://tracing`.
//!
//! One **process** (`pid`) per operating-system process (opid 0 for
//! in-proc runs), one **thread** (`tid`) per rank; each span becomes a
//! complete ("ph":"X") event with µs timestamps and the deterministic
//! op arguments (step, round, seg, bytes) attached. Metadata records
//! name the processes and threads so the UI shows "opid 0 / rank 2"
//! instead of bare numbers.

use anyhow::{anyhow, Context};

use crate::util::json::Json;
use crate::Result;

use super::tracer::TraceSnapshot;

/// Render one process's snapshot as a complete Chrome-trace document:
/// `{"traceEvents": [...]}`. `pid` is the operating-system process slot
/// (opid; 0 for in-proc runs); rank indices become thread ids.
pub fn chrome_trace_json(pid: u64, snap: &TraceSnapshot) -> String {
    let mut events = Vec::new();
    render_events(pid, snap, &mut events);
    wrap_events(&events)
}

/// Merge several per-process documents (parsed leniently from
/// [`chrome_trace_json`] output) into one, concatenating their
/// `traceEvents` arrays in input order.
pub fn merge_chrome_traces(parts: &[String]) -> Result<String> {
    let mut events = Vec::new();
    for (i, text) in parts.iter().enumerate() {
        let doc = Json::parse(text).with_context(|| format!("parsing trace part {i}"))?;
        let arr = doc
            .get("traceEvents")
            .and_then(Json::as_array)
            .ok_or_else(|| anyhow!("trace part {i}: missing traceEvents array"))?;
        for ev in arr {
            events.push(render_json_value(ev));
        }
    }
    Ok(wrap_events(&events))
}

fn wrap_events(events: &[String]) -> String {
    let mut s = String::with_capacity(events.iter().map(|e| e.len() + 4).sum::<usize>() + 32);
    s.push_str("{\"traceEvents\": [\n");
    for (i, ev) in events.iter().enumerate() {
        if i > 0 {
            s.push_str(",\n");
        }
        s.push_str(ev);
    }
    s.push_str("\n]}\n");
    s
}

fn render_events(pid: u64, snap: &TraceSnapshot, out: &mut Vec<String>) {
    out.push(format!(
        "{{\"name\": \"process_name\", \"ph\": \"M\", \"pid\": {pid}, \"tid\": 0, \
         \"args\": {{\"name\": \"opid {pid}\"}}}}"
    ));
    for (rank, r) in snap.ranks.iter().enumerate() {
        if r.spans.is_empty() {
            continue;
        }
        out.push(format!(
            "{{\"name\": \"thread_name\", \"ph\": \"M\", \"pid\": {pid}, \"tid\": {rank}, \
             \"args\": {{\"name\": \"rank {rank}\"}}}}"
        ));
        for s in &r.spans {
            out.push(format!(
                "{{\"name\": \"{}\", \"cat\": \"op\", \"ph\": \"X\", \"pid\": {pid}, \
                 \"tid\": {rank}, \"ts\": {}, \"dur\": {}, \"args\": {{\"step\": {}, \
                 \"round\": {}, \"seg\": {}, \"bytes\": {}}}}}",
                s.kind.name(),
                s.start_us,
                s.dur_us,
                s.step,
                s.round,
                s.seg,
                s.bytes
            ));
        }
    }
}

/// Re-serialize a parsed JSON value (compact, source key order) — used
/// when merging already-exported trace parts.
fn render_json_value(v: &Json) -> String {
    match v {
        Json::Null => "null".into(),
        Json::Bool(b) => b.to_string(),
        Json::Num(s) => s.clone(),
        Json::Str(s) => format!("\"{}\"", crate::util::json::escape_str(s)),
        Json::Arr(items) => {
            let inner: Vec<String> = items.iter().map(render_json_value).collect();
            format!("[{}]", inner.join(", "))
        }
        Json::Obj(fields) => {
            let inner: Vec<String> = fields
                .iter()
                .map(|(k, val)| {
                    format!("\"{}\": {}", crate::util::json::escape_str(k), render_json_value(val))
                })
                .collect();
            format!("{{{}}}", inner.join(", "))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::tracer::{OpKind, TraceSet};

    fn snapshot() -> TraceSnapshot {
        let t = TraceSet::new(2);
        t.record(0, OpKind::ConvFwd, 1, 0, 0, 0, 0, 10);
        t.record(1, OpKind::ShardGather, 1, 1, 0, 2048, 5, 40);
        t.snapshot()
    }

    #[test]
    fn export_is_valid_json_with_events() {
        let text = chrome_trace_json(0, &snapshot());
        let doc = Json::parse(&text).unwrap();
        let events = doc.get("traceEvents").unwrap().as_array().unwrap();
        // 1 process_name + 2 thread_name + 2 spans.
        assert_eq!(events.len(), 5);
        let span = events
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("shard-gather"))
            .unwrap();
        assert_eq!(span.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(span.get("tid").unwrap().as_u64(), Some(1));
        assert_eq!(span.get("ts").unwrap().as_u64(), Some(5));
        assert_eq!(span.get("dur").unwrap().as_u64(), Some(35));
        assert_eq!(span.get("args").unwrap().get("bytes").unwrap().as_u64(), Some(2048));
    }

    #[test]
    fn merge_concatenates_parts() {
        let a = chrome_trace_json(0, &snapshot());
        let b = chrome_trace_json(1, &snapshot());
        let merged = merge_chrome_traces(&[a, b]).unwrap();
        let doc = Json::parse(&merged).unwrap();
        let events = doc.get("traceEvents").unwrap().as_array().unwrap();
        assert_eq!(events.len(), 10);
        // Both pids present.
        let pids: std::collections::HashSet<u64> =
            events.iter().filter_map(|e| e.get("pid").and_then(Json::as_u64)).collect();
        assert_eq!(pids.len(), 2);
        // A merged document is still parseable by this merger.
        assert!(merge_chrome_traces(&[merged]).is_ok());
    }
}
