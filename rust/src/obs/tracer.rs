//! The per-rank span recorder behind `--trace`.
//!
//! One [`TraceSet`] serves a whole process: one preallocated ring of
//! [`Span`]s per rank plus exact per-op-kind aggregates. The hot path
//! (`record`) takes the rank's own uncontended mutex, writes one fixed-
//! size slot and bumps a few counters — no allocation, no formatting,
//! no syscalls. When the ring wraps, old spans are dropped from the
//! Chrome trace (counted in `dropped`) but the aggregates stay exact,
//! so `metrics.json` never lies.
//!
//! Determinism contract: the *sequence* of (kind, step, round, seg,
//! bytes) per rank is identical across seeded replays and across all
//! three engines for the same configuration — only `start_us`/`dur_us`
//! are wall-clock. The `obs_trace` suite pins this.

use std::sync::Mutex;
use std::time::Instant;

use crate::comm::CommCategory;

/// The kind of a traced step-program op. Mirrors the traced subset of
/// `coordinator::program::StepOp`: `CrashPoll` and `Barrier` are
/// deliberately absent because the engines dispatch them asymmetrically
/// (the sequential engine handles both outside the shared executor), so
/// tracing them would break cross-engine span parity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum OpKind {
    /// mp=1 fused fast path (`full_step` artifact + local SGD).
    FullStep,
    /// Conv front forward.
    ConvFwd,
    /// Modulo label post (fwd).
    PostLabels,
    /// Modulo activation post (fwd).
    PostActs,
    /// Modulo take/assemble (fwd).
    ModuloGather,
    /// Sharded FC forward segment.
    FcFwd,
    /// Shard-layer fprop allgather.
    ShardGather,
    /// Replicated head (loss + FC2 grads).
    HeadStep,
    /// Shard-layer bprop (slice or partial reduce).
    ShardBwd,
    /// Sharded FC backward segment.
    FcBwd,
    /// Modulo gradient post (bwd).
    PostGrads,
    /// Modulo gradient reduce (bwd).
    ReduceGrads,
    /// Conv front backward + optimizer updates.
    ConvBwdUpdate,
    /// DP allreduce-mean of replicated parameters.
    AverageReplicated,
    /// Inter-group allreduce-mean of FC shards.
    AverageShards,
    /// Restore-point refresh (control plane, uncounted bytes).
    CheckpointRefresh,
}

impl OpKind {
    /// Every kind, in reporting order (the `metrics.json` "ops" key
    /// order — schema-stable).
    pub const ALL: [OpKind; 16] = [
        OpKind::FullStep,
        OpKind::ConvFwd,
        OpKind::PostLabels,
        OpKind::PostActs,
        OpKind::ModuloGather,
        OpKind::FcFwd,
        OpKind::ShardGather,
        OpKind::HeadStep,
        OpKind::ShardBwd,
        OpKind::FcBwd,
        OpKind::PostGrads,
        OpKind::ReduceGrads,
        OpKind::ConvBwdUpdate,
        OpKind::AverageReplicated,
        OpKind::AverageShards,
        OpKind::CheckpointRefresh,
    ];

    /// Number of kinds (aggregate-array width).
    pub const COUNT: usize = OpKind::ALL.len();

    /// Stable kebab-case name (the `metrics.json` / Chrome-trace label).
    pub fn name(self) -> &'static str {
        match self {
            OpKind::FullStep => "full-step",
            OpKind::ConvFwd => "conv-fwd",
            OpKind::PostLabels => "post-labels",
            OpKind::PostActs => "post-acts",
            OpKind::ModuloGather => "modulo-gather",
            OpKind::FcFwd => "fc-fwd",
            OpKind::ShardGather => "shard-gather",
            OpKind::HeadStep => "head-step",
            OpKind::ShardBwd => "shard-bwd",
            OpKind::FcBwd => "fc-bwd",
            OpKind::PostGrads => "post-grads",
            OpKind::ReduceGrads => "reduce-grads",
            OpKind::ConvBwdUpdate => "conv-bwd-update",
            OpKind::AverageReplicated => "average-replicated",
            OpKind::AverageShards => "average-shards",
            OpKind::CheckpointRefresh => "checkpoint-refresh",
        }
    }

    /// Index into the aggregate arrays.
    pub fn index(self) -> usize {
        self as usize
    }

    /// The communication category the op's wire traffic (and wait time)
    /// is attributed to; `None` for pure compute ops and the zero-byte
    /// control-plane checkpoint refresh.
    pub fn category(self) -> Option<CommCategory> {
        match self {
            OpKind::PostLabels | OpKind::PostActs | OpKind::ModuloGather => {
                Some(CommCategory::ModuloFwd)
            }
            OpKind::PostGrads | OpKind::ReduceGrads => Some(CommCategory::ModuloBwd),
            OpKind::ShardGather => Some(CommCategory::ShardFwd),
            OpKind::ShardBwd => Some(CommCategory::ShardBwd),
            OpKind::AverageReplicated => Some(CommCategory::DpAverage),
            OpKind::AverageShards => Some(CommCategory::ShardAverage),
            _ => None,
        }
    }
}

/// One recorded op execution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    /// What ran.
    pub kind: OpKind,
    /// Training step (1-based, as the drivers count).
    pub step: u32,
    /// Modulo round (0 for roundless ops).
    pub round: u32,
    /// Sharded-FC segment index (0 for segmentless ops).
    pub seg: u32,
    /// Bytes this rank posted during the op (counted wire payload).
    pub bytes: u64,
    /// Start, µs since the tracer's epoch. Wall-clock: masked in tests.
    pub start_us: u64,
    /// Duration, µs. Wall-clock: masked in tests.
    pub dur_us: u64,
}

/// One rank's recording state: span ring + exact aggregates.
#[derive(Debug)]
struct RankTrace {
    /// Preallocated ring (capacity fixed at construction).
    spans: Vec<Span>,
    /// Next ring slot to overwrite once full.
    cursor: usize,
    /// Total spans ever recorded (dropped = total - spans.len()).
    total: u64,
    count: [u64; OpKind::COUNT],
    bytes: [u64; OpKind::COUNT],
    us: [u64; OpKind::COUNT],
    first_start_us: u64,
    last_end_us: u64,
}

impl RankTrace {
    fn new() -> RankTrace {
        RankTrace {
            spans: Vec::new(),
            cursor: 0,
            total: 0,
            count: [0; OpKind::COUNT],
            bytes: [0; OpKind::COUNT],
            us: [0; OpKind::COUNT],
            first_start_us: u64::MAX,
            last_end_us: 0,
        }
    }
}

/// Read-only copy of one rank's trace at snapshot time.
#[derive(Debug, Clone)]
pub struct RankSnapshot {
    /// Retained spans, oldest first.
    pub spans: Vec<Span>,
    /// Spans dropped by ring wrap (aggregates still include them).
    pub dropped: u64,
    /// Spans recorded per kind (exact, wrap-proof).
    pub count: [u64; OpKind::COUNT],
    /// Bytes posted per kind (exact).
    pub bytes: [u64; OpKind::COUNT],
    /// Wall µs spent per kind (exact).
    pub us: [u64; OpKind::COUNT],
    /// Earliest span start (µs since epoch; `u64::MAX` when empty).
    pub first_start_us: u64,
    /// Latest span end (µs since epoch; 0 when empty).
    pub last_end_us: u64,
}

/// Read-only copy of the whole trace set at snapshot time.
#[derive(Debug, Clone)]
pub struct TraceSnapshot {
    /// Per-rank snapshots, rank order.
    pub ranks: Vec<RankSnapshot>,
}

impl TraceSnapshot {
    /// Spans retained across all ranks.
    pub fn span_count(&self) -> u64 {
        self.ranks.iter().map(|r| r.spans.len() as u64).sum()
    }

    /// Spans dropped by ring wrap across all ranks.
    pub fn dropped(&self) -> u64 {
        self.ranks.iter().map(|r| r.dropped).sum()
    }

    /// Wall µs from the earliest span start to the latest span end
    /// (0 when nothing was recorded).
    pub fn wall_us(&self) -> u64 {
        let first = self.ranks.iter().map(|r| r.first_start_us).min().unwrap_or(u64::MAX);
        let last = self.ranks.iter().map(|r| r.last_end_us).max().unwrap_or(0);
        last.saturating_sub(if first == u64::MAX { last } else { first })
    }
}

/// Default per-rank span-ring capacity (spans beyond it are dropped
/// from the Chrome trace; aggregates stay exact). ~40 B per slot.
pub const DEFAULT_SPAN_CAPACITY: usize = 65_536;

/// A process's span recorder: one ring per rank, shared epoch.
///
/// In-proc engines hold one `TraceSet` covering every rank; each TCP
/// worker process holds a single-rank set for its own rank and the
/// launcher merges the exported files. Absence of a `TraceSet`
/// (`--trace` off) short-circuits instrumentation to a `None` check.
#[derive(Debug)]
pub struct TraceSet {
    epoch: Instant,
    capacity: usize,
    ranks: Vec<Mutex<RankTrace>>,
}

impl TraceSet {
    /// A trace set for `ranks` ranks with the default ring capacity.
    pub fn new(ranks: usize) -> TraceSet {
        TraceSet::with_capacity(ranks, DEFAULT_SPAN_CAPACITY)
    }

    /// A trace set with an explicit per-rank ring capacity (tests pin
    /// wrap behavior with tiny rings).
    pub fn with_capacity(ranks: usize, capacity: usize) -> TraceSet {
        TraceSet {
            epoch: Instant::now(),
            capacity: capacity.max(1),
            ranks: (0..ranks).map(|_| Mutex::new(RankTrace::new())).collect(),
        }
    }

    /// Ranks this set records.
    pub fn ranks(&self) -> usize {
        self.ranks.len()
    }

    /// µs since the tracer's epoch (span timestamps use this clock).
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Record one span for `rank`. `start_us`/`end_us` are
    /// [`now_us`](Self::now_us) readings around the op.
    #[allow(clippy::too_many_arguments)]
    pub fn record(
        &self,
        rank: usize,
        kind: OpKind,
        step: u32,
        round: u32,
        seg: u32,
        bytes: u64,
        start_us: u64,
        end_us: u64,
    ) {
        let span = Span {
            kind,
            step,
            round,
            seg,
            bytes,
            start_us,
            dur_us: end_us.saturating_sub(start_us),
        };
        let mut rt = self.ranks[rank].lock().unwrap();
        let i = kind.index();
        rt.count[i] += 1;
        rt.bytes[i] += bytes;
        rt.us[i] += span.dur_us;
        rt.first_start_us = rt.first_start_us.min(start_us);
        rt.last_end_us = rt.last_end_us.max(end_us);
        rt.total += 1;
        if rt.spans.len() < self.capacity {
            rt.spans.push(span);
        } else {
            let slot = rt.cursor;
            rt.spans[slot] = span;
            rt.cursor = (slot + 1) % self.capacity;
        }
    }

    /// Copy out the current state (spans re-ordered oldest-first across
    /// the ring seam).
    pub fn snapshot(&self) -> TraceSnapshot {
        let ranks = self
            .ranks
            .iter()
            .map(|m| {
                let rt = m.lock().unwrap();
                let mut spans = Vec::with_capacity(rt.spans.len());
                if rt.spans.len() == self.capacity && rt.cursor > 0 {
                    spans.extend_from_slice(&rt.spans[rt.cursor..]);
                    spans.extend_from_slice(&rt.spans[..rt.cursor]);
                } else {
                    spans.extend_from_slice(&rt.spans);
                }
                RankSnapshot {
                    dropped: rt.total - rt.spans.len() as u64,
                    spans,
                    count: rt.count,
                    bytes: rt.bytes,
                    us: rt.us,
                    first_start_us: rt.first_start_us,
                    last_end_us: rt.last_end_us,
                }
            })
            .collect();
        TraceSnapshot { ranks }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_are_distinct_and_named() {
        let mut names: Vec<&str> = OpKind::ALL.iter().map(|k| k.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), OpKind::COUNT);
        for (i, k) in OpKind::ALL.iter().enumerate() {
            assert_eq!(k.index(), i, "ALL order must match discriminant order");
        }
    }

    #[test]
    fn record_updates_aggregates_and_ring() {
        let t = TraceSet::with_capacity(2, 8);
        t.record(0, OpKind::ConvFwd, 1, 0, 0, 0, 10, 25);
        t.record(0, OpKind::PostActs, 1, 0, 0, 4096, 25, 30);
        t.record(1, OpKind::ConvFwd, 1, 0, 0, 0, 12, 20);
        let snap = t.snapshot();
        assert_eq!(snap.span_count(), 3);
        assert_eq!(snap.dropped(), 0);
        let r0 = &snap.ranks[0];
        assert_eq!(r0.count[OpKind::ConvFwd.index()], 1);
        assert_eq!(r0.bytes[OpKind::PostActs.index()], 4096);
        assert_eq!(r0.us[OpKind::ConvFwd.index()], 15);
        assert_eq!((r0.first_start_us, r0.last_end_us), (10, 30));
        assert_eq!(snap.wall_us(), 20);
    }

    #[test]
    fn ring_wrap_drops_spans_but_not_aggregates() {
        let t = TraceSet::with_capacity(1, 4);
        for step in 1..=10u32 {
            t.record(0, OpKind::FullStep, step, 0, 0, 0, step as u64, step as u64 + 1);
        }
        let snap = t.snapshot();
        let r = &snap.ranks[0];
        assert_eq!(r.spans.len(), 4);
        assert_eq!(r.dropped, 6);
        assert_eq!(r.count[OpKind::FullStep.index()], 10, "aggregates stay exact");
        // Oldest-first across the seam: steps 7..=10 retained in order.
        let steps: Vec<u32> = r.spans.iter().map(|s| s.step).collect();
        assert_eq!(steps, vec![7, 8, 9, 10]);
    }

    #[test]
    fn categories_partition_comm_from_compute() {
        use crate::comm::CommCategory;
        assert_eq!(OpKind::PostActs.category(), Some(CommCategory::ModuloFwd));
        assert_eq!(OpKind::ReduceGrads.category(), Some(CommCategory::ModuloBwd));
        assert_eq!(OpKind::ShardGather.category(), Some(CommCategory::ShardFwd));
        assert_eq!(OpKind::ShardBwd.category(), Some(CommCategory::ShardBwd));
        assert_eq!(OpKind::AverageReplicated.category(), Some(CommCategory::DpAverage));
        assert_eq!(OpKind::AverageShards.category(), Some(CommCategory::ShardAverage));
        for k in [OpKind::FullStep, OpKind::ConvFwd, OpKind::HeadStep, OpKind::CheckpointRefresh] {
            assert_eq!(k.category(), None, "{} is not comm", k.name());
        }
    }
}
