//! Log-bucketed histograms with **fixed bucket edges**.
//!
//! The edges are compile-time constants (powers of two), so histograms
//! recorded by different processes merge bucket-by-bucket and the merged
//! JSON is deterministic — no per-run bucket boundaries to drift. The
//! `obs_trace` suite pins the edge layout; changing it is a schema
//! change and must bump [`super::metrics::METRICS_VERSION`].

/// A log₂-bucketed histogram of `u64` samples with fixed edges.
///
/// Bucket 0 counts exact zeros. Bucket `i` (1 ≤ i < 31) counts samples
/// in `[2^(i-1), 2^i)`. The last bucket (31) is open-ended and counts
/// everything ≥ 2^30 (~1 GiB for byte samples, ~18 min for µs samples)
/// — far past anything a single message or blocking take produces.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LogHistogram {
    counts: [u64; LogHistogram::BUCKETS],
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram::new()
    }
}

impl LogHistogram {
    /// Number of buckets. Fixed: part of the metrics schema.
    pub const BUCKETS: usize = 32;

    /// An empty histogram.
    pub fn new() -> LogHistogram {
        LogHistogram { counts: [0; LogHistogram::BUCKETS] }
    }

    /// The bucket a sample falls into (see the type docs for edges).
    pub fn bucket_of(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            ((64 - v.leading_zeros()) as usize).min(LogHistogram::BUCKETS - 1)
        }
    }

    /// Inclusive lower edge of bucket `i` (0 for the zero bucket).
    pub fn lower_bound(i: usize) -> u64 {
        match i {
            0 | 1 => i as u64,
            _ => 1u64 << (i - 1),
        }
    }

    /// Record one sample.
    pub fn record(&mut self, v: u64) {
        self.counts[Self::bucket_of(v)] += 1;
    }

    /// Count in bucket `i`.
    pub fn count(&self, i: usize) -> u64 {
        self.counts[i]
    }

    /// Total samples recorded.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// True when no samples were recorded.
    pub fn is_empty(&self) -> bool {
        self.counts.iter().all(|&c| c == 0)
    }

    /// All bucket counts, in edge order.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Add another histogram's counts bucket-by-bucket (valid because
    /// the edges are fixed).
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += *b;
        }
    }

    /// The histogram as a compact JSON array of bucket counts.
    pub fn to_json(&self) -> String {
        let mut s = String::from("[");
        for (i, c) in self.counts.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&c.to_string());
        }
        s.push(']');
        s
    }

    /// Parse a histogram from the JSON array [`to_json`](Self::to_json)
    /// writes. The bucket count must match exactly.
    pub fn from_json(doc: &crate::util::json::Json) -> crate::Result<LogHistogram> {
        let items = doc
            .as_array()
            .ok_or_else(|| anyhow::anyhow!("histogram must be a JSON array"))?;
        if items.len() != LogHistogram::BUCKETS {
            anyhow::bail!(
                "histogram has {} buckets, schema expects {}",
                items.len(),
                LogHistogram::BUCKETS
            );
        }
        let mut h = LogHistogram::new();
        for (i, item) in items.iter().enumerate() {
            h.counts[i] = item
                .as_u64()
                .ok_or_else(|| anyhow::anyhow!("histogram bucket {i} is not a u64"))?;
        }
        Ok(h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges_are_pinned() {
        // Zero has its own bucket; 1 starts the log ladder.
        assert_eq!(LogHistogram::bucket_of(0), 0);
        assert_eq!(LogHistogram::bucket_of(1), 1);
        assert_eq!(LogHistogram::bucket_of(2), 2);
        assert_eq!(LogHistogram::bucket_of(3), 2);
        assert_eq!(LogHistogram::bucket_of(4), 3);
        assert_eq!(LogHistogram::bucket_of(1023), 10);
        assert_eq!(LogHistogram::bucket_of(1024), 11);
        // The open-ended last bucket swallows everything huge.
        assert_eq!(LogHistogram::bucket_of(1 << 30), LogHistogram::BUCKETS - 1);
        assert_eq!(LogHistogram::bucket_of(u64::MAX), LogHistogram::BUCKETS - 1);
        // Lower bounds invert bucket_of at the edges.
        for i in 1..LogHistogram::BUCKETS - 1 {
            let lo = LogHistogram::lower_bound(i);
            assert_eq!(LogHistogram::bucket_of(lo), i, "bucket {i} lower edge");
            if lo > 1 {
                assert_eq!(LogHistogram::bucket_of(lo - 1), i - 1);
            }
        }
    }

    #[test]
    fn record_merge_and_json_round_trip() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        for v in [0, 1, 12, 12, 4096] {
            a.record(v);
        }
        b.record(12);
        a.merge(&b);
        assert_eq!(a.total(), 6);
        assert_eq!(a.count(LogHistogram::bucket_of(12)), 3);
        let doc = crate::util::json::Json::parse(&a.to_json()).unwrap();
        let back = LogHistogram::from_json(&doc).unwrap();
        assert_eq!(back, a);
    }

    #[test]
    fn from_json_rejects_wrong_width() {
        let doc = crate::util::json::Json::parse("[1,2,3]").unwrap();
        assert!(LogHistogram::from_json(&doc).is_err());
    }
}
