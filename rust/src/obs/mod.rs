//! Per-op observability: span tracing, metrics snapshots, Chrome-trace
//! export, and the measured-vs-predicted cost-model report.
//!
//! Every engine (sequential lockstep, threaded in-proc, multi-process
//! TCP) executes the step program through the single
//! `coordinator::program::exec_op` choke point, so one instrumentation
//! site covers all three. When tracing is enabled (builder
//! [`trace`](crate::api::SessionBuilder), CLI `--trace`) each executed
//! [`StepOp`](crate::coordinator::program::StepOp) is recorded as a
//! [`Span`] in a preallocated per-rank ring buffer — no allocation on
//! the hot path, and a no-op when disabled.
//!
//! At run end (and at every averaging boundary, for live watching) the
//! spans are folded into a [`Metrics`] snapshot (`metrics.json`) and a
//! Chrome-trace document (`trace.json`, Perfetto-loadable). The
//! deterministic fields — op sequence, counts, byte totals — are
//! bit-identical across seeded replays and across all three engines;
//! timings are wall-clock but schema-stable.
//!
//! `splitbrain profile <run-dir>` then folds `metrics.json` against the
//! plan's analytic communication volumes ([`profile`]): measured comm
//! bytes must match the schedule's prediction exactly, while measured
//! times quantify the α–β network model's honesty.

pub mod chrome;
pub mod hist;
pub mod metrics;
pub mod profile;
pub mod tracer;

pub use chrome::{chrome_trace_json, merge_chrome_traces};
pub use hist::LogHistogram;
pub use metrics::{Metrics, OpStat, PeerStat, METRICS_VERSION};
pub use profile::{kernel_rows, profile, render_kernel_table, KernelRow, PhaseRow, ProfileReport};
pub use tracer::{OpKind, Span, TraceSet, TraceSnapshot};
