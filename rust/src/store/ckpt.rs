//! Fingerprinted checkpoint artifacts: one file = the complete
//! training state at an averaging boundary.
//!
//! ```text
//! magic   "SBCKA1\n" + 0
//! u16     version (1)
//! u64     step
//! u64     manifest fingerprint (FNV-1a of run.json — config identity)
//! u64     n_workers, u64 mp, u64 recoveries
//! u32 n + u64 lost_ranks[n]
//! u32 n + u8  fired[n]               (consumed fault flags)
//! u32 len + SBCKPT1 doc              (global model, 20 named tensors)
//! u32 k × worker section             (k = n_workers for whole-cluster
//!                                     artifacts; k = 1 for the launch
//!                                     engine's per-process artifacts):
//!   u64 rank
//!   u32 len + SBCKPT1 doc            (14 conv tensors)
//!   u32 len + SBCKPT1 doc            (6 fc tensors)
//!   u32 n + (u64 len + f32[len])[n]  (conv optimizer velocity)
//!   u32 n + (u64 len + f32[len])[n]  (fc optimizer velocity)
//! u32     crc32 over every preceding byte
//! ```
//!
//! The artifact carries **both** coordinate systems deliberately: the
//! per-worker sections (with optimizer momentum) make exact resume
//! bit-identical; the global section re-shards to any topology and is
//! what branching clones. Writes are atomic (tmp + rename + fsync), so
//! a kill mid-write leaves the previous boundary's artifact intact.

use std::io::Write;
use std::path::Path;

use crate::comm::transport::wire::crc32;
use crate::coordinator::cluster::ClusterState;
use crate::coordinator::worker::WorkerSnapshot;
use crate::runtime::HostTensor;
use crate::train::checkpoint;

use super::StoreError;

const MAGIC: &[u8; 8] = b"SBCKA1\n\0";
const VERSION: u16 = 1;
/// Bound on any length-prefixed section, checked before allocation.
const MAX_SECTION: u32 = 1 << 30;

/// FNV-1a over bytes — the same offset/prime as
/// [`RunManifest::fingerprint`](crate::api::RunManifest::fingerprint),
/// applied to artifact bytes so the event log can name the exact
/// checkpoint contents it witnessed.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_01b3);
    }
    h
}

/// A decoded checkpoint artifact: the cluster state plus the config
/// identity it belongs to.
#[derive(Debug, Clone)]
pub struct CheckpointArtifact {
    /// Averaging-boundary step the state captures.
    pub step: usize,
    /// FNV-1a fingerprint of the owning run's canonical manifest.
    pub manifest_fingerprint: u64,
    /// The complete training state.
    pub state: ClusterState,
}

fn enc_doc(out: &mut Vec<u8>, tensors: &[(String, HostTensor)]) {
    let doc = checkpoint::encode_named(tensors);
    out.extend_from_slice(&(doc.len() as u32).to_le_bytes());
    out.extend_from_slice(&doc);
}

fn enc_vel(out: &mut Vec<u8>, vel: &[Vec<f32>]) {
    out.extend_from_slice(&(vel.len() as u32).to_le_bytes());
    for v in vel {
        out.extend_from_slice(&(v.len() as u64).to_le_bytes());
        for &x in v {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
}

/// Encode an artifact to its on-disk byte form (CRC trailer included).
pub fn encode_artifact(art: &CheckpointArtifact) -> Vec<u8> {
    let s = &art.state;
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&(art.step as u64).to_le_bytes());
    out.extend_from_slice(&art.manifest_fingerprint.to_le_bytes());
    out.extend_from_slice(&(s.n_workers as u64).to_le_bytes());
    out.extend_from_slice(&(s.mp as u64).to_le_bytes());
    out.extend_from_slice(&(s.recoveries as u64).to_le_bytes());
    out.extend_from_slice(&(s.lost_ranks.len() as u32).to_le_bytes());
    for &r in &s.lost_ranks {
        out.extend_from_slice(&(r as u64).to_le_bytes());
    }
    out.extend_from_slice(&(s.fired.len() as u32).to_le_bytes());
    for &f in &s.fired {
        out.push(f as u8);
    }
    enc_doc(&mut out, &s.global);
    out.extend_from_slice(&(s.workers.len() as u32).to_le_bytes());
    for w in &s.workers {
        out.extend_from_slice(&(w.rank as u64).to_le_bytes());
        let conv: Vec<(String, HostTensor)> = w
            .conv_params
            .iter()
            .enumerate()
            .map(|(i, t)| (format!("conv{i}"), t.clone()))
            .collect();
        enc_doc(&mut out, &conv);
        let fc: Vec<(String, HostTensor)> = w
            .fc_params
            .iter()
            .enumerate()
            .map(|(i, t)| (format!("fc{i}"), t.clone()))
            .collect();
        enc_doc(&mut out, &fc);
        enc_vel(&mut out, &w.conv_velocity);
        enc_vel(&mut out, &w.fc_velocity);
    }
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], StoreError> {
        if self.buf.len() - self.pos < n {
            return Err(StoreError::Truncated { needed: n, got: self.buf.len() - self.pos });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u16(&mut self) -> Result<u16, StoreError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32, StoreError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, StoreError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn section(&mut self) -> Result<&'a [u8], StoreError> {
        let len = self.u32()?;
        if len > MAX_SECTION {
            return Err(StoreError::Oversized { len, max: MAX_SECTION });
        }
        self.take(len as usize)
    }
    fn doc(&mut self) -> Result<Vec<(String, HostTensor)>, StoreError> {
        let bytes = self.section()?;
        checkpoint::decode(bytes).map_err(|e| StoreError::BadPayload(format!("{e:#}")))
    }
    fn vel(&mut self) -> Result<Vec<Vec<f32>>, StoreError> {
        let n = self.u32()? as usize;
        if n > 64 {
            return Err(StoreError::BadPayload(format!("{n} velocity buffers implausible")));
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let len = self.u64()? as usize;
            if len > (MAX_SECTION as usize) / 4 {
                return Err(StoreError::BadPayload(format!("velocity length {len} implausible")));
            }
            let bytes = self.take(len * 4)?;
            out.push(
                bytes
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            );
        }
        Ok(out)
    }
}

/// Decode an artifact from its full file bytes (verifies magic,
/// version, CRC and structure; every failure is a typed error).
pub fn decode_artifact(bytes: &[u8]) -> Result<CheckpointArtifact, StoreError> {
    if bytes.len() < MAGIC.len() + 2 + 4 {
        return Err(StoreError::Truncated { needed: MAGIC.len() + 6, got: bytes.len() });
    }
    if &bytes[..8] != MAGIC {
        return Err(StoreError::BadMagic(u32::from_le_bytes(bytes[..4].try_into().unwrap())));
    }
    let carried = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
    let computed = crc32(&bytes[..bytes.len() - 4]);
    if carried != computed {
        return Err(StoreError::BadCrc { computed, carried });
    }
    let mut d = Dec { buf: &bytes[..bytes.len() - 4], pos: 8 };
    let version = d.u16()?;
    if version != VERSION {
        return Err(StoreError::VersionMismatch { got: version, want: VERSION });
    }
    let step = d.u64()? as usize;
    let manifest_fingerprint = d.u64()?;
    let n_workers = d.u64()? as usize;
    let mp = d.u64()? as usize;
    let recoveries = d.u64()? as usize;
    let n_lost = d.u32()? as usize;
    if n_lost > 4096 {
        return Err(StoreError::BadPayload(format!("{n_lost} lost ranks implausible")));
    }
    let mut lost_ranks = Vec::with_capacity(n_lost);
    for _ in 0..n_lost {
        lost_ranks.push(d.u64()? as usize);
    }
    let n_fired = d.u32()? as usize;
    if n_fired > 1 << 20 {
        return Err(StoreError::BadPayload(format!("{n_fired} fault flags implausible")));
    }
    let fired = d.take(n_fired)?.iter().map(|&b| b != 0).collect();
    let global = d.doc()?;
    let n_snaps = d.u32()? as usize;
    // Whole-cluster artifacts carry n_workers sections, the launch
    // engine's per-process artifacts exactly one; each loader validates
    // the count it needs, the codec only bounds it.
    if n_snaps > 4096 {
        return Err(StoreError::BadPayload(format!("{n_snaps} worker sections implausible")));
    }
    let mut workers = Vec::with_capacity(n_snaps);
    for _ in 0..n_snaps {
        let rank = d.u64()? as usize;
        let conv_params = d.doc()?.into_iter().map(|(_, t)| t).collect();
        let fc_params = d.doc()?.into_iter().map(|(_, t)| t).collect();
        let conv_velocity = d.vel()?;
        let fc_velocity = d.vel()?;
        workers.push(WorkerSnapshot { rank, conv_params, fc_params, conv_velocity, fc_velocity });
    }
    if d.pos != d.buf.len() {
        return Err(StoreError::BadPayload(format!(
            "{} trailing bytes after worker sections",
            d.buf.len() - d.pos
        )));
    }
    Ok(CheckpointArtifact {
        step,
        manifest_fingerprint,
        state: ClusterState {
            step,
            n_workers,
            mp,
            recoveries,
            lost_ranks,
            fired,
            global,
            workers,
        },
    })
}

/// Write an artifact atomically (tmp + rename + fsync) and return the
/// FNV-1a fingerprint of its bytes — the value the event log's
/// `Checkpoint` record carries.
pub fn save_artifact(path: impl AsRef<Path>, art: &CheckpointArtifact) -> Result<u64, StoreError> {
    let path = path.as_ref();
    let bytes = encode_artifact(art);
    let tmp = path.with_extension("ckpt.tmp");
    {
        let mut f =
            std::fs::File::create(&tmp).map_err(|e| StoreError::io(&tmp, "create", e))?;
        f.write_all(&bytes).map_err(|e| StoreError::io(&tmp, "write", e))?;
        f.sync_data().map_err(|e| StoreError::io(&tmp, "fsync", e))?;
    }
    std::fs::rename(&tmp, path).map_err(|e| StoreError::io(path, "rename", e))?;
    if let Some(parent) = path.parent() {
        // Persist the rename itself: fsync the directory entry.
        if let Ok(dir) = std::fs::File::open(parent) {
            let _ = dir.sync_data();
        }
    }
    Ok(fnv1a(&bytes))
}

/// Load and fully verify an artifact file.
pub fn load_artifact(path: impl AsRef<Path>) -> Result<CheckpointArtifact, StoreError> {
    let path = path.as_ref();
    let bytes = std::fs::read(path).map_err(|e| StoreError::io(path, "read", e))?;
    decode_artifact(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_state() -> ClusterState {
        let t = |v: Vec<f32>| HostTensor::f32(vec![v.len()], v);
        ClusterState {
            step: 4,
            n_workers: 1,
            mp: 1,
            recoveries: 1,
            lost_ranks: vec![2],
            fired: vec![true, false],
            global: vec![("g0".into(), t(vec![1.0, -2.5]))],
            workers: vec![WorkerSnapshot {
                rank: 0,
                conv_params: vec![t(vec![0.5; 3])],
                fc_params: vec![t(vec![1.5; 2])],
                conv_velocity: vec![vec![0.1, 0.2, 0.3]],
                fc_velocity: Vec::new(),
            }],
        }
    }

    #[test]
    fn roundtrip_preserves_state_bit_exactly() {
        let art = CheckpointArtifact {
            step: 4,
            manifest_fingerprint: 0xfeed_beef,
            state: tiny_state(),
        };
        let bytes = encode_artifact(&art);
        let back = decode_artifact(&bytes).unwrap();
        assert_eq!(back.step, 4);
        assert_eq!(back.manifest_fingerprint, 0xfeed_beef);
        assert_eq!(back.state.lost_ranks, vec![2]);
        assert_eq!(back.state.fired, vec![true, false]);
        assert_eq!(back.state.workers[0].conv_velocity, vec![vec![0.1, 0.2, 0.3]]);
        assert!(back.state.workers[0].fc_velocity.is_empty());
        assert_eq!(back.state.global[0].1.as_f32(), art.state.global[0].1.as_f32());
    }

    #[test]
    fn every_flipped_byte_is_detected() {
        let art = CheckpointArtifact { step: 1, manifest_fingerprint: 7, state: tiny_state() };
        let bytes = encode_artifact(&art);
        // Flip a byte in each structural region: magic, header, body, crc.
        for &at in &[0usize, 9, bytes.len() / 2, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[at] ^= 0x40;
            assert!(decode_artifact(&bad).is_err(), "flip at {at} went undetected");
        }
    }

    #[test]
    fn truncation_is_typed() {
        let art = CheckpointArtifact { step: 1, manifest_fingerprint: 7, state: tiny_state() };
        let bytes = encode_artifact(&art);
        for keep in [0, 5, 20, bytes.len() - 1] {
            let err = decode_artifact(&bytes[..keep]).unwrap_err();
            assert!(
                matches!(err, StoreError::Truncated { .. } | StoreError::BadCrc { .. }),
                "truncation at {keep} gave {err:?}"
            );
        }
    }

    #[test]
    fn atomic_save_then_load() {
        let mut path = std::env::temp_dir();
        path.push(format!("splitbrain-art-test-{}.ckpt", std::process::id()));
        let art = CheckpointArtifact { step: 2, manifest_fingerprint: 9, state: tiny_state() };
        let fp = save_artifact(&path, &art).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(fp, fnv1a(&bytes));
        let back = load_artifact(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back.step, 2);
        assert_eq!(back.state.workers.len(), 1);
    }
}
