//! The append-only event log: CRC-framed records, fsync'd appends,
//! torn-tail-tolerant replay.
//!
//! Framing mirrors the TCP transport's `wire.rs` discipline (and reuses
//! its CRC-32 tables):
//!
//! ```text
//! u32  magic     "SBEL" (0x4C45_4253, little-endian)
//! u16  version   1
//! u8   kind      record discriminant
//! u8   reserved  0
//! u32  payload_len   bounded by MAX_RECORD_PAYLOAD *before* allocation
//! [payload_len bytes]
//! u32  crc32     over every preceding byte of the record
//! ```
//!
//! [`replay`] decodes the longest valid prefix: a torn tail (partial
//! append at the moment of a kill) or a flipped byte stops the replay
//! at the last intact record and reports *why* as a typed
//! [`StoreError`] — corruption is never a panic, and never silently
//! skipped over (everything after the first bad byte is distrusted,
//! because record boundaries can no longer be established).

use std::io::{Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::api::events::{Event, RecoveryInfo, RunInfo, RunSummary, StepReport};
use crate::comm::transport::wire::crc32;
use crate::comm::CollectiveAlgo;
use crate::coordinator::ExecEngine;

use super::StoreError;

/// Log record magic: `"SBEL"` as a little-endian u32.
pub const LOG_MAGIC: u32 = u32::from_le_bytes(*b"SBEL");
/// Log format version this build reads and writes.
pub const LOG_VERSION: u16 = 1;
/// Fixed bytes before the payload.
pub const HEADER_LEN: usize = 12;
/// Payload bound, checked before any allocation. Events are tiny; the
/// only unbounded field is a lost-ranks list.
pub const MAX_RECORD_PAYLOAD: u32 = 1 << 20;

const KIND_RUN_STARTED: u8 = 1;
const KIND_STEP: u8 = 2;
const KIND_RECOVERED: u8 = 3;
const KIND_RUN_COMPLETED: u8 = 4;
const KIND_CHECKPOINT: u8 = 5;
const KIND_RESUMED: u8 = 6;

/// One durable record. The first four variants mirror the in-memory
/// [`Event`] stream one-to-one; the store adds checkpoint and resume
/// markers so a replayed log is a complete lineage of the run.
#[derive(Debug, Clone, PartialEq)]
pub enum LogRecord {
    /// Mirror of [`Event::RunStarted`].
    RunStarted(RunInfo),
    /// Mirror of [`Event::StepCompleted`].
    Step(StepReport),
    /// Mirror of [`Event::Recovered`].
    Recovered(RecoveryInfo),
    /// Mirror of [`Event::RunCompleted`].
    RunCompleted(RunSummary),
    /// A checkpoint artifact reached disk for this step.
    Checkpoint {
        /// Averaging-boundary step the artifact captures.
        step: u64,
        /// Artifact file name, relative to `checkpoints/`.
        file: String,
        /// FNV-1a fingerprint of the artifact bytes.
        fingerprint: u64,
    },
    /// A new process rehydrated the run from the step-`step` checkpoint.
    Resumed {
        /// The step execution restarted after.
        step: u64,
    },
}

impl LogRecord {
    /// Build the durable mirror of an in-memory event.
    pub fn from_event(event: &Event) -> LogRecord {
        match event {
            Event::RunStarted(i) => LogRecord::RunStarted(i.clone()),
            Event::StepCompleted(r) => LogRecord::Step(r.clone()),
            Event::Recovered(r) => LogRecord::Recovered(r.clone()),
            Event::RunCompleted(s) => LogRecord::RunCompleted(s.clone()),
        }
    }

    /// The training step this record is anchored to, if any. Resume
    /// truncation keeps the prefix with `step() <= K`.
    pub fn step(&self) -> Option<u64> {
        match self {
            LogRecord::RunStarted(_) => None,
            LogRecord::Step(r) => Some(r.step as u64),
            LogRecord::Recovered(r) => Some(r.step as u64),
            // A completed run has executed every step; anchor past any
            // checkpoint so resume truncation always drops it.
            LogRecord::RunCompleted(_) => Some(u64::MAX),
            LogRecord::Checkpoint { step, .. } => Some(*step),
            LogRecord::Resumed { step } => Some(*step),
        }
    }

    fn kind(&self) -> u8 {
        match self {
            LogRecord::RunStarted(_) => KIND_RUN_STARTED,
            LogRecord::Step(_) => KIND_STEP,
            LogRecord::Recovered(_) => KIND_RECOVERED,
            LogRecord::RunCompleted(_) => KIND_RUN_COMPLETED,
            LogRecord::Checkpoint { .. } => KIND_CHECKPOINT,
            LogRecord::Resumed { .. } => KIND_RESUMED,
        }
    }

    /// Encode as one framed record (header + payload + CRC trailer).
    pub fn encode(&self) -> Vec<u8> {
        let payload = self.encode_payload();
        debug_assert!(payload.len() <= MAX_RECORD_PAYLOAD as usize);
        let mut out = Vec::with_capacity(HEADER_LEN + payload.len() + 4);
        out.extend_from_slice(&LOG_MAGIC.to_le_bytes());
        out.extend_from_slice(&LOG_VERSION.to_le_bytes());
        out.push(self.kind());
        out.push(0);
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&payload);
        let crc = crc32(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    fn encode_payload(&self) -> Vec<u8> {
        let mut e = Enc::new();
        match self {
            LogRecord::RunStarted(i) => {
                e.u64(i.n_workers as u64);
                e.u64(i.mp as u64);
                e.u64(i.n_groups as u64);
                e.u64(i.batch as u64);
                e.u64(i.steps as u64);
                e.f32_bits(i.lr);
                e.u64(i.avg_period as u64);
                e.str(&i.engine.to_string());
                e.str(&i.collectives.to_string());
                e.u8(i.overlap as u8);
                e.f64_bits(i.param_mb);
                e.f64_bits(i.total_mb);
            }
            LogRecord::Step(r) => {
                e.u64(r.step as u64);
                e.f64_bits(r.loss);
                e.f64_bits(r.compute_secs);
                e.f64_bits(r.mp_comm_secs);
                e.f64_bits(r.dp_comm_secs);
                e.f64_bits(r.wall_secs);
                e.u64(r.bytes_busiest_rank);
                e.u64(r.bytes_total);
            }
            LogRecord::Recovered(r) => {
                e.u64(r.step as u64);
                e.u64_list(&r.lost_ranks);
                e.u64(r.n_workers as u64);
                e.u64(r.mp as u64);
                e.u64(r.restore_step as u64);
            }
            LogRecord::RunCompleted(s) => {
                e.u64(s.steps as u64);
                e.f64_bits(s.images_per_sec);
                e.f64_bits(s.comm_fraction);
                e.u64(s.recoveries as u64);
                e.u64_list(&s.lost_ranks);
                e.u64(s.n_workers as u64);
                e.u64(s.mp as u64);
                e.u64(s.last_checkpoint_step as u64);
            }
            LogRecord::Checkpoint { step, file, fingerprint } => {
                e.u64(*step);
                e.str(file);
                e.u64(*fingerprint);
            }
            LogRecord::Resumed { step } => e.u64(*step),
        }
        e.out
    }

    fn decode_payload(kind: u8, payload: &[u8]) -> Result<LogRecord, StoreError> {
        let mut d = Dec::new(payload);
        let rec = match kind {
            KIND_RUN_STARTED => LogRecord::RunStarted(RunInfo {
                n_workers: d.u64()? as usize,
                mp: d.u64()? as usize,
                n_groups: d.u64()? as usize,
                batch: d.u64()? as usize,
                steps: d.u64()? as usize,
                lr: d.f32_bits()?,
                avg_period: d.u64()? as usize,
                engine: ExecEngine::parse(&d.str()?)
                    .map_err(|e| StoreError::BadPayload(format!("{e:#}")))?,
                collectives: CollectiveAlgo::parse(&d.str()?)
                    .map_err(|e| StoreError::BadPayload(format!("{e:#}")))?,
                overlap: d.u8()? != 0,
                param_mb: d.f64_bits()?,
                total_mb: d.f64_bits()?,
            }),
            KIND_STEP => LogRecord::Step(StepReport {
                step: d.u64()? as usize,
                loss: d.f64_bits()?,
                compute_secs: d.f64_bits()?,
                mp_comm_secs: d.f64_bits()?,
                dp_comm_secs: d.f64_bits()?,
                wall_secs: d.f64_bits()?,
                bytes_busiest_rank: d.u64()?,
                bytes_total: d.u64()?,
            }),
            KIND_RECOVERED => LogRecord::Recovered(RecoveryInfo {
                step: d.u64()? as usize,
                lost_ranks: d.u64_list()?,
                n_workers: d.u64()? as usize,
                mp: d.u64()? as usize,
                restore_step: d.u64()? as usize,
            }),
            KIND_RUN_COMPLETED => LogRecord::RunCompleted(RunSummary {
                steps: d.u64()? as usize,
                images_per_sec: d.f64_bits()?,
                comm_fraction: d.f64_bits()?,
                recoveries: d.u64()? as usize,
                lost_ranks: d.u64_list()?,
                n_workers: d.u64()? as usize,
                mp: d.u64()? as usize,
                last_checkpoint_step: d.u64()? as usize,
            }),
            KIND_CHECKPOINT => LogRecord::Checkpoint {
                step: d.u64()?,
                file: d.str()?,
                fingerprint: d.u64()?,
            },
            KIND_RESUMED => LogRecord::Resumed { step: d.u64()? },
            other => return Err(StoreError::BadKind(other)),
        };
        d.finish()?;
        Ok(rec)
    }
}

/// Little-endian payload encoder.
struct Enc {
    out: Vec<u8>,
}

impl Enc {
    fn new() -> Enc {
        Enc { out: Vec::new() }
    }
    fn u8(&mut self, v: u8) {
        self.out.push(v);
    }
    fn u64(&mut self, v: u64) {
        self.out.extend_from_slice(&v.to_le_bytes());
    }
    fn f32_bits(&mut self, v: f32) {
        self.out.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    fn f64_bits(&mut self, v: f64) {
        self.out.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    fn str(&mut self, s: &str) {
        self.out.extend_from_slice(&(s.len() as u32).to_le_bytes());
        self.out.extend_from_slice(s.as_bytes());
    }
    fn u64_list(&mut self, v: &[usize]) {
        self.out.extend_from_slice(&(v.len() as u32).to_le_bytes());
        for &x in v {
            self.u64(x as u64);
        }
    }
}

/// Little-endian payload decoder with typed structural errors.
struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, pos: 0 }
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8], StoreError> {
        if self.buf.len() - self.pos < n {
            return Err(StoreError::BadPayload(format!(
                "payload ends early: wanted {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8, StoreError> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32, StoreError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, StoreError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32_bits(&mut self) -> Result<f32, StoreError> {
        Ok(f32::from_bits(self.u32()?))
    }
    fn f64_bits(&mut self) -> Result<f64, StoreError> {
        Ok(f64::from_bits(self.u64()?))
    }
    fn str(&mut self) -> Result<String, StoreError> {
        let n = self.u32()? as usize;
        if n > MAX_RECORD_PAYLOAD as usize {
            return Err(StoreError::BadPayload(format!("string length {n} implausible")));
        }
        String::from_utf8(self.take(n)?.to_vec())
            .map_err(|e| StoreError::BadPayload(format!("string not utf-8: {e}")))
    }
    fn u64_list(&mut self) -> Result<Vec<usize>, StoreError> {
        let n = self.u32()? as usize;
        if n > (MAX_RECORD_PAYLOAD as usize) / 8 {
            return Err(StoreError::BadPayload(format!("list length {n} implausible")));
        }
        (0..n).map(|_| Ok(self.u64()? as usize)).collect()
    }
    fn finish(&self) -> Result<(), StoreError> {
        if self.pos != self.buf.len() {
            return Err(StoreError::BadPayload(format!(
                "{} trailing payload bytes",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

/// Appends framed records to `events.log`, fsync'ing each one so a
/// record either survives whole or is a detectable torn tail.
pub struct LogWriter {
    file: std::fs::File,
    path: PathBuf,
}

impl LogWriter {
    /// Create (or truncate) a fresh log.
    pub fn create(path: impl AsRef<Path>) -> Result<LogWriter, StoreError> {
        let path = path.as_ref();
        let file = std::fs::File::create(path).map_err(|e| StoreError::io(path, "create", e))?;
        Ok(LogWriter { file, path: path.to_path_buf() })
    }

    /// Open an existing log for appending after `keep_bytes`, truncating
    /// everything past that offset (resume drops the distrusted tail
    /// before writing new history — appending after a torn record would
    /// hide every later record from replay).
    pub fn open_truncated(path: impl AsRef<Path>, keep_bytes: u64) -> Result<LogWriter, StoreError> {
        let path = path.as_ref();
        let file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(path)
            .map_err(|e| StoreError::io(path, "open", e))?;
        file.set_len(keep_bytes).map_err(|e| StoreError::io(path, "truncate", e))?;
        let mut file = file;
        file.seek(SeekFrom::End(0)).map_err(|e| StoreError::io(path, "seek", e))?;
        let w = LogWriter { file, path: path.to_path_buf() };
        w.sync()?;
        Ok(w)
    }

    /// Append one record and fsync it to disk.
    pub fn append(&mut self, rec: &LogRecord) -> Result<(), StoreError> {
        let bytes = rec.encode();
        self.file
            .write_all(&bytes)
            .map_err(|e| StoreError::io(&self.path, "append", e))?;
        self.sync()
    }

    fn sync(&self) -> Result<(), StoreError> {
        self.file.sync_data().map_err(|e| StoreError::io(&self.path, "fsync", e))
    }
}

/// The result of replaying a log: the longest valid record prefix, the
/// byte extent of each record, and — when the file did not end cleanly
/// at a record boundary — the typed reason replay stopped.
#[derive(Debug)]
pub struct Replay {
    /// Decoded records, in append order.
    pub records: Vec<LogRecord>,
    /// `(start, end)` byte offsets of each record in `records`.
    pub offsets: Vec<(u64, u64)>,
    /// Bytes of the valid prefix (== file length iff `tail` is `None`).
    pub valid_bytes: u64,
    /// Why replay stopped before end-of-file, if it did. A torn tail is
    /// [`StoreError::Truncated`]; a flipped byte usually surfaces as
    /// [`StoreError::BadCrc`] or [`StoreError::BadMagic`].
    pub tail: Option<StoreError>,
}

impl Replay {
    /// Byte offset up to which records anchor at steps `<= k` — the
    /// resume truncation point. Records without a step anchor
    /// (`RunStarted`) ride along with their neighbors; everything from
    /// the first record past `k` is dropped.
    pub fn cut_for_step(&self, k: u64) -> u64 {
        for (rec, &(start, _)) in self.records.iter().zip(&self.offsets) {
            if matches!(rec.step(), Some(s) if s > k) {
                return start;
            }
        }
        self.valid_bytes
    }

    /// The records kept by [`cut_for_step`](Replay::cut_for_step).
    pub fn records_until_step(&self, k: u64) -> Vec<LogRecord> {
        self.records
            .iter()
            .take_while(|rec| !matches!(rec.step(), Some(s) if s > k))
            .cloned()
            .collect()
    }
}

/// Replay a log file. Returns `Err` only when the file cannot be read
/// at all; a malformed *interior* is not an error here — it is the
/// `tail` of the longest valid prefix.
pub fn replay(path: impl AsRef<Path>) -> Result<Replay, StoreError> {
    let path = path.as_ref();
    let buf = std::fs::read(path).map_err(|e| StoreError::io(path, "read", e))?;
    let mut records = Vec::new();
    let mut offsets = Vec::new();
    let mut pos = 0usize;
    let mut tail = None;
    while pos < buf.len() {
        match decode_one(&buf[pos..]) {
            Ok((rec, consumed)) => {
                offsets.push((pos as u64, (pos + consumed) as u64));
                records.push(rec);
                pos += consumed;
            }
            Err(e) => {
                tail = Some(e);
                break;
            }
        }
    }
    Ok(Replay { records, offsets, valid_bytes: pos as u64, tail })
}

/// Decode one record from the head of `buf`; returns the record and the
/// bytes consumed. Shared with the incremental tail-follower in
/// [`super::follow`], which needs record-at-a-time decoding from an
/// arbitrary byte offset.
pub(crate) fn decode_one(buf: &[u8]) -> Result<(LogRecord, usize), StoreError> {
    if buf.len() < HEADER_LEN {
        return Err(StoreError::Truncated { needed: HEADER_LEN, got: buf.len() });
    }
    let magic = u32::from_le_bytes(buf[0..4].try_into().unwrap());
    if magic != LOG_MAGIC {
        return Err(StoreError::BadMagic(magic));
    }
    let version = u16::from_le_bytes(buf[4..6].try_into().unwrap());
    if version != LOG_VERSION {
        return Err(StoreError::VersionMismatch { got: version, want: LOG_VERSION });
    }
    let kind = buf[6];
    let len = u32::from_le_bytes(buf[8..12].try_into().unwrap());
    if len > MAX_RECORD_PAYLOAD {
        return Err(StoreError::Oversized { len, max: MAX_RECORD_PAYLOAD });
    }
    let total = HEADER_LEN + len as usize + 4;
    if buf.len() < total {
        return Err(StoreError::Truncated { needed: total, got: buf.len() });
    }
    let carried = u32::from_le_bytes(buf[total - 4..total].try_into().unwrap());
    let computed = crc32(&buf[..total - 4]);
    if carried != computed {
        return Err(StoreError::BadCrc { computed, carried });
    }
    let rec = LogRecord::decode_payload(kind, &buf[HEADER_LEN..HEADER_LEN + len as usize])?;
    Ok((rec, total))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("splitbrain-log-test-{}-{name}", std::process::id()));
        p
    }

    fn sample_records() -> Vec<LogRecord> {
        vec![
            LogRecord::Step(StepReport {
                step: 1,
                loss: 2.302,
                compute_secs: 0.5,
                mp_comm_secs: 0.01,
                dp_comm_secs: 0.0,
                wall_secs: 0.123,
                bytes_busiest_rank: 4096,
                bytes_total: 8192,
            }),
            LogRecord::Recovered(RecoveryInfo {
                step: 2,
                lost_ranks: vec![1, 3],
                n_workers: 2,
                mp: 1,
                restore_step: 0,
            }),
            LogRecord::Checkpoint { step: 2, file: "step-2.ckpt".into(), fingerprint: 0xdead },
            LogRecord::Resumed { step: 2 },
        ]
    }

    #[test]
    fn roundtrip_all_kinds() {
        let path = tmp("roundtrip");
        let mut w = LogWriter::create(&path).unwrap();
        for r in sample_records() {
            w.append(&r).unwrap();
        }
        let replayed = replay(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(replayed.tail.is_none());
        assert_eq!(replayed.records, sample_records());
        assert_eq!(replayed.valid_bytes, replayed.offsets.last().unwrap().1);
    }

    #[test]
    fn torn_tail_recovers_prefix() {
        let path = tmp("torn");
        let mut w = LogWriter::create(&path).unwrap();
        for r in sample_records() {
            w.append(&r).unwrap();
        }
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 3]).unwrap();
        let replayed = replay(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(replayed.records.len(), sample_records().len() - 1);
        assert!(matches!(replayed.tail, Some(StoreError::Truncated { .. })));
    }

    #[test]
    fn flipped_byte_is_bad_crc() {
        let path = tmp("flip");
        let mut w = LogWriter::create(&path).unwrap();
        for r in sample_records() {
            w.append(&r).unwrap();
        }
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let replayed = replay(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(replayed.tail.is_some(), "corruption must be detected");
        assert!(replayed.records.len() < sample_records().len());
    }

    #[test]
    fn cut_for_step_drops_future_records() {
        let path = tmp("cut");
        let mut w = LogWriter::create(&path).unwrap();
        let recs = sample_records();
        for r in &recs {
            w.append(r).unwrap();
        }
        let replayed = replay(&path).unwrap();
        std::fs::remove_file(&path).ok();
        // Step-1 cut keeps only the first record.
        assert_eq!(replayed.cut_for_step(1), replayed.offsets[0].1);
        assert_eq!(replayed.records_until_step(1).len(), 1);
        // Step-2 cut keeps everything.
        assert_eq!(replayed.cut_for_step(2), replayed.valid_bytes);
    }

    #[test]
    fn open_truncated_drops_tail_then_appends() {
        let path = tmp("trunc-append");
        let mut w = LogWriter::create(&path).unwrap();
        for r in sample_records() {
            w.append(&r).unwrap();
        }
        let replayed = replay(&path).unwrap();
        let cut = replayed.offsets[1].1; // keep first two records
        let mut w2 = LogWriter::open_truncated(&path, cut).unwrap();
        w2.append(&LogRecord::Resumed { step: 9 }).unwrap();
        let again = replay(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(again.tail.is_none());
        assert_eq!(again.records.len(), 3);
        assert_eq!(again.records[2], LogRecord::Resumed { step: 9 });
    }
}
