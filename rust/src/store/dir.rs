//! The `--run-dir` layout: one directory = one durable run.
//!
//! ```text
//! <run-dir>/
//!   run.json          canonical RunManifest — the run's config identity
//!   events.log        append-only CRC-framed event log
//!   opid<R>.pid       live worker PIDs (TCP launch engine only)
//!   metrics.json          --trace: merged per-op metrics snapshot
//!   trace.json            --trace: merged Chrome-trace-event file
//!   metrics-opid<R>.json  --trace, launch engine: per-process metrics
//!   trace-opid<R>.json    --trace, launch engine: per-process trace
//!   checkpoints/
//!     step-K.ckpt           in-proc engines: whole-cluster artifact
//!     step-K.opid-R.ckpt    launch engine: per-process artifact
//! ```
//!
//! [`RunDir::create`] refuses a directory that already holds a run
//! (resume instead of clobbering history) and publishes `run.json`
//! atomically — tmp + hard-link + dir fsync, so a crash can tear a
//! *tmp*, never the manifest, and concurrently spawned `launch`
//! workers race to exactly one winner. [`RunDir::open`] demands a
//! non-empty `run.json`; both entry points sweep stale `*.tmp` litter
//! left by a SIGKILL mid-write. Checkpoint discovery is name-based and *verification
//! happens at load*: [`RunDir::latest_valid_checkpoint`] walks steps
//! newest-first and skips any artifact whose CRC or fingerprint fails,
//! so a torn checkpoint write degrades to the previous boundary instead
//! of an unusable run.

use std::path::{Path, PathBuf};

use super::ckpt::{load_artifact, CheckpointArtifact};
use super::StoreError;

/// Handle to a run directory (layout above).
#[derive(Debug, Clone)]
pub struct RunDir {
    root: PathBuf,
}

impl RunDir {
    /// Create a fresh run dir: make the directories, sweep stale
    /// atomic-write leftovers, and *atomically publish* the canonical
    /// manifest (tmp + hard-link + parent-dir fsync, the
    /// [`save_artifact`](super::save_artifact) discipline — the
    /// hard-link is the no-clobber step, so concurrent creators race
    /// safely and a crash can never leave a torn `run.json`). Fails
    /// with [`StoreError::RunExists`] if the directory already holds a
    /// published manifest.
    pub fn create(root: impl AsRef<Path>, manifest_json: &str) -> Result<RunDir, StoreError> {
        let root = root.as_ref();
        std::fs::create_dir_all(root.join("checkpoints"))
            .map_err(|e| StoreError::io(root, "mkdir", e))?;
        let d = RunDir { root: root.to_path_buf() };
        d.sweep_stale_tmp();
        d.publish_manifest(manifest_json)?;
        Ok(d)
    }

    /// Open an existing run dir (must contain a non-empty `run.json`;
    /// an *empty* one is the crash signature of a torn legacy write
    /// and reads as not-a-run-dir). Sweeps stale `*.tmp` litter that a
    /// SIGKILL mid-[`save_artifact`](super::save_artifact) left behind
    /// — safe here because `open` is a writer's entry point; the
    /// read-only [`Watcher`](crate::api::Watcher) never calls it.
    pub fn open(root: impl AsRef<Path>) -> Result<RunDir, StoreError> {
        let root = root.as_ref();
        let d = RunDir { root: root.to_path_buf() };
        if !d.has_manifest() {
            return Err(StoreError::NotARunDir(root.display().to_string()));
        }
        std::fs::create_dir_all(root.join("checkpoints"))
            .map_err(|e| StoreError::io(root, "mkdir", e))?;
        d.sweep_stale_tmp();
        Ok(d)
    }

    /// Open if a manifest exists, create otherwise — the launch
    /// engine's idempotent entry point. A create race lost to a
    /// concurrently spawned worker (its hard-link published first) is
    /// a successful `open`, not an error.
    pub fn open_or_create(
        root: impl AsRef<Path>,
        manifest_json: &str,
    ) -> Result<RunDir, StoreError> {
        let r = root.as_ref();
        match Self::open(r) {
            Ok(d) => Ok(d),
            Err(StoreError::NotARunDir(_)) => match Self::create(r, manifest_json) {
                Err(StoreError::RunExists(_)) => Self::open(r),
                other => other,
            },
            Err(e) => Err(e),
        }
    }

    /// True when a published (non-empty) `run.json` is present. Zero
    /// length is the one state the legacy non-atomic writer could
    /// crash into; it is treated as absent so the dir stays creatable.
    fn has_manifest(&self) -> bool {
        std::fs::metadata(self.manifest_path()).map(|m| m.len() > 0).unwrap_or(false)
    }

    /// Atomically publish `run.json`: write a per-process tmp, fsync
    /// it, hard-link it into place (the filesystem picks exactly one
    /// winner under concurrent creators), fsync the directory entry.
    /// An existing *empty* `run.json` (torn legacy write) is healed by
    /// removal first.
    fn publish_manifest(&self, manifest_json: &str) -> Result<(), StoreError> {
        let target = self.manifest_path();
        match std::fs::metadata(&target) {
            Ok(m) if m.len() > 0 => {
                return Err(StoreError::RunExists(self.root.display().to_string()));
            }
            Ok(_) => {
                std::fs::remove_file(&target)
                    .map_err(|e| StoreError::io(&target, "unlink", e))?;
            }
            Err(_) => {}
        }
        let tmp = self.root.join(format!("run.json.tmp-{}", std::process::id()));
        {
            use std::io::Write as _;
            let mut f =
                std::fs::File::create(&tmp).map_err(|e| StoreError::io(&tmp, "create", e))?;
            f.write_all(manifest_json.as_bytes())
                .map_err(|e| StoreError::io(&tmp, "write", e))?;
            f.sync_data().map_err(|e| StoreError::io(&tmp, "fsync", e))?;
        }
        let linked = std::fs::hard_link(&tmp, &target);
        let _ = std::fs::remove_file(&tmp);
        match linked {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                return Err(StoreError::RunExists(self.root.display().to_string()));
            }
            // A racing winner published *and* already swept our tmp
            // (its `open`-side sweep): same lost race, different errno.
            Err(e) if e.kind() == std::io::ErrorKind::NotFound && self.has_manifest() => {
                return Err(StoreError::RunExists(self.root.display().to_string()));
            }
            Err(e) => return Err(StoreError::io(&target, "publish", e)),
        }
        if let Some(parent) = target.parent() {
            // Persist the link itself: fsync the directory entry.
            if let Ok(dir) = std::fs::File::open(parent) {
                let _ = dir.sync_data();
            }
        }
        Ok(())
    }

    /// Best-effort removal of stale atomic-write leftovers: a SIGKILL
    /// mid-[`save_artifact`](super::save_artifact) strands a
    /// `step-K….ckpt.tmp` in `checkpoints/` forever, and a killed
    /// create strands a `run.json.tmp-<pid>`. Only called from
    /// `create`/`open` — a process about to *own* the dir, before any
    /// of its own artifact writes start. Manifest tmps are only swept
    /// once a manifest is published, so a concurrent creator's
    /// in-flight tmp is never deleted from under it.
    fn sweep_stale_tmp(&self) {
        if let Ok(entries) = std::fs::read_dir(self.checkpoints_dir()) {
            for e in entries.flatten() {
                if e.file_name().to_str().is_some_and(|n| n.ends_with(".tmp")) {
                    let _ = std::fs::remove_file(e.path());
                }
            }
        }
        if self.has_manifest() {
            if let Ok(entries) = std::fs::read_dir(&self.root) {
                for e in entries.flatten() {
                    if e.file_name().to_str().is_some_and(|n| n.starts_with("run.json.tmp-")) {
                        let _ = std::fs::remove_file(e.path());
                    }
                }
            }
        }
    }

    /// The directory itself.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// `run.json` path.
    pub fn manifest_path(&self) -> PathBuf {
        self.root.join("run.json")
    }

    /// Read the persisted canonical manifest.
    pub fn manifest_json(&self) -> Result<String, StoreError> {
        let p = self.manifest_path();
        std::fs::read_to_string(&p).map_err(|e| StoreError::io(&p, "read", e))
    }

    /// `events.log` path.
    pub fn events_path(&self) -> PathBuf {
        self.root.join("events.log")
    }

    /// `checkpoints/` path.
    pub fn checkpoints_dir(&self) -> PathBuf {
        self.root.join("checkpoints")
    }

    /// In-proc artifact path for averaging boundary `step`.
    pub fn checkpoint_path(&self, step: usize) -> PathBuf {
        self.checkpoints_dir().join(format!("step-{step}.ckpt"))
    }

    /// Launch-engine per-process artifact path.
    pub fn worker_checkpoint_path(&self, step: usize, opid: usize) -> PathBuf {
        self.checkpoints_dir().join(format!("step-{step}.opid-{opid}.ckpt"))
    }

    /// PID file for launch-engine process `opid` (tests and the CI
    /// kill-resume smoke read these to SIGKILL the coordinator).
    pub fn pid_path(&self, opid: usize) -> PathBuf {
        self.root.join(format!("opid{opid}.pid"))
    }

    /// Merged Chrome-trace-event file (`--trace`; all ranks, one pid
    /// per launch-engine process, one tid per rank).
    pub fn trace_path(&self) -> PathBuf {
        self.root.join("trace.json")
    }

    /// Merged per-op metrics snapshot (`--trace`), rewritten at every
    /// averaging boundary and at run end.
    pub fn metrics_path(&self) -> PathBuf {
        self.root.join("metrics.json")
    }

    /// The serving frontend's status surface (`splitbrain serve
    /// --run-dir`), rewritten atomically while the server is up; the
    /// watcher reads it to render serving throughput instead of
    /// misreading an idle server as a stalled training run.
    pub fn serve_status_path(&self) -> PathBuf {
        self.root.join("serve_status.json")
    }

    /// Launch-engine per-process Chrome-trace file for `opid`; the
    /// launcher merges these into [`trace_path`](RunDir::trace_path)
    /// once every worker exits.
    pub fn worker_trace_path(&self, opid: usize) -> PathBuf {
        self.root.join(format!("trace-opid{opid}.json"))
    }

    /// Launch-engine per-process metrics snapshot for `opid`; the
    /// launcher merges these into
    /// [`metrics_path`](RunDir::metrics_path) once every worker exits.
    pub fn worker_metrics_path(&self, opid: usize) -> PathBuf {
        self.root.join(format!("metrics-opid{opid}.json"))
    }

    /// Steps with an in-proc artifact file, ascending (presence only —
    /// validity is checked at load).
    pub fn checkpoint_steps(&self) -> Vec<usize> {
        self.scan_steps(|name| {
            name.strip_prefix("step-")?.strip_suffix(".ckpt")?.parse::<usize>().ok()
        })
    }

    /// Steps where **every** opid in `0..n` has an artifact file,
    /// ascending — the launch engine may die with some ranks a boundary
    /// ahead of others; only a complete set is resumable.
    pub fn complete_worker_checkpoint_steps(&self, n: usize) -> Vec<usize> {
        let mut per_step: std::collections::BTreeMap<usize, usize> = Default::default();
        for step in self.scan_steps(|name| {
            let rest = name.strip_prefix("step-")?;
            let (step, opid) = rest.strip_suffix(".ckpt")?.split_once(".opid-")?;
            let opid: usize = opid.parse().ok()?;
            if opid >= n {
                return None;
            }
            step.parse::<usize>().ok()
        }) {
            *per_step.entry(step).or_insert(0) += 1;
        }
        per_step.into_iter().filter(|&(_, count)| count == n).map(|(s, _)| s).collect()
    }

    fn scan_steps(&self, parse: impl Fn(&str) -> Option<usize>) -> Vec<usize> {
        let mut steps = Vec::new();
        if let Ok(entries) = std::fs::read_dir(self.checkpoints_dir()) {
            for entry in entries.flatten() {
                if let Some(step) = entry.file_name().to_str().and_then(&parse) {
                    steps.push(step);
                }
            }
        }
        steps.sort_unstable();
        steps
    }

    /// Newest artifact that decodes cleanly **and** belongs to this
    /// configuration (fingerprint match). Artifacts that fail either
    /// check are skipped — a torn checkpoint write degrades the resume
    /// point by one boundary, it does not brick the run. `Ok(None)`
    /// means no boundary was ever persisted: resume restarts from
    /// step 0 (the initial model is a pure function of the seed).
    pub fn latest_valid_checkpoint(
        &self,
        want_fingerprint: u64,
    ) -> Result<Option<CheckpointArtifact>, StoreError> {
        for step in self.checkpoint_steps().into_iter().rev() {
            match load_artifact(self.checkpoint_path(step)) {
                Ok(art) if art.manifest_fingerprint == want_fingerprint => {
                    return Ok(Some(art));
                }
                Ok(_) | Err(_) => continue,
            }
        }
        Ok(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("splitbrain-dir-test-{}-{name}", std::process::id()));
        std::fs::remove_dir_all(&p).ok();
        p
    }

    #[test]
    fn create_open_refuse_clobber() {
        let root = tmp("create");
        let d = RunDir::create(&root, "{}").unwrap();
        assert_eq!(d.manifest_json().unwrap(), "{}");
        assert!(matches!(RunDir::create(&root, "{}"), Err(StoreError::RunExists(_))));
        RunDir::open(&root).unwrap();
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn open_missing_is_not_a_run_dir() {
        let root = tmp("missing");
        assert!(matches!(RunDir::open(&root), Err(StoreError::NotARunDir(_))));
    }

    #[test]
    fn torn_empty_manifest_is_healed_not_poisonous() {
        let root = tmp("torn");
        std::fs::create_dir_all(&root).unwrap();
        // The legacy non-atomic writer's crash signature: run.json
        // exists but is empty. It must neither open as a run...
        std::fs::write(root.join("run.json"), b"").unwrap();
        assert!(matches!(RunDir::open(&root), Err(StoreError::NotARunDir(_))));
        // ...nor block re-creation (open_or_create heals it).
        let d = RunDir::open_or_create(&root, "{\"v\":1}").unwrap();
        assert_eq!(d.manifest_json().unwrap(), "{\"v\":1}");
        // Once published, the manifest is durable and wins all races:
        // a second create loses, a second open_or_create opens.
        assert!(matches!(RunDir::create(&root, "{}"), Err(StoreError::RunExists(_))));
        let again = RunDir::open_or_create(&root, "{\"v\":2}").unwrap();
        assert_eq!(again.manifest_json().unwrap(), "{\"v\":1}", "lost race opens, not clobbers");
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn open_sweeps_stale_tmp_litter() {
        let root = tmp("sweep");
        let d = RunDir::create(&root, "{}").unwrap();
        // Plant the exact litter a SIGKILL mid-save_artifact leaves:
        // the tmp sits next to a real artifact it never replaced.
        std::fs::write(d.checkpoint_path(2), b"x").unwrap();
        let stale = d.checkpoints_dir().join("step-4.ckpt.tmp");
        std::fs::write(&stale, b"half-written").unwrap();
        let stale_manifest = root.join("run.json.tmp-99999");
        std::fs::write(&stale_manifest, b"half").unwrap();
        // Stale tmps are never scanned as checkpoints...
        assert_eq!(d.checkpoint_steps(), vec![2]);
        // ...and the next open (a resume) removes them.
        let d = RunDir::open(&root).unwrap();
        assert!(!stale.exists(), "stale ckpt tmp must be swept on open");
        assert!(!stale_manifest.exists(), "stale manifest tmp must be swept on open");
        assert_eq!(d.checkpoint_steps(), vec![2], "real artifacts survive the sweep");
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn step_scans_parse_and_sort() {
        let root = tmp("scan");
        let d = RunDir::create(&root, "{}").unwrap();
        for step in [10, 2, 6] {
            std::fs::write(d.checkpoint_path(step), b"x").unwrap();
        }
        std::fs::write(d.checkpoints_dir().join("garbage.txt"), b"x").unwrap();
        assert_eq!(d.checkpoint_steps(), vec![2, 6, 10]);
        // Worker artifacts: step 4 complete for n=2, step 8 missing opid 1.
        std::fs::write(d.worker_checkpoint_path(4, 0), b"x").unwrap();
        std::fs::write(d.worker_checkpoint_path(4, 1), b"x").unwrap();
        std::fs::write(d.worker_checkpoint_path(8, 0), b"x").unwrap();
        assert_eq!(d.complete_worker_checkpoint_steps(2), vec![4]);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn latest_valid_skips_broken_artifacts() {
        use crate::coordinator::cluster::ClusterState;
        use crate::store::ckpt::{save_artifact, CheckpointArtifact};
        let root = tmp("latest");
        let d = RunDir::create(&root, "{}").unwrap();
        let state = ClusterState {
            step: 2,
            n_workers: 0,
            mp: 1,
            recoveries: 0,
            lost_ranks: vec![],
            fired: vec![],
            global: vec![],
            workers: vec![],
        };
        let art = CheckpointArtifact { step: 2, manifest_fingerprint: 77, state };
        save_artifact(d.checkpoint_path(2), &art).unwrap();
        // A newer but corrupt artifact, and an even newer wrong-config one.
        std::fs::write(d.checkpoint_path(4), b"corrupt").unwrap();
        let mut other = art.clone();
        other.step = 6;
        other.manifest_fingerprint = 99;
        save_artifact(d.checkpoint_path(6), &other).unwrap();
        let got = d.latest_valid_checkpoint(77).unwrap().unwrap();
        assert_eq!(got.step, 2, "skips corrupt step 4 and wrong-config step 6");
        assert!(d.latest_valid_checkpoint(1).unwrap().is_none());
        std::fs::remove_dir_all(&root).ok();
    }
}
