//! Incremental tail-following of a run's `events.log`.
//!
//! [`replay`](super::replay) reads a log once, from the start — right
//! for resume, wrong for *watching*: a watcher polls a file that a
//! live writer is appending to (and, across a resume, truncating).
//! [`LogFollower`] is the polling half: it remembers the byte offset
//! of the last fully decoded record and, on each
//! [`poll`](LogFollower::poll), decodes only what the writer appended
//! since — with two hazards handled explicitly:
//!
//! * **Torn tail.** The writer may be mid-`append` when we read, so
//!   the frontier can end inside a record ([`StoreError::Truncated`]).
//!   That is not corruption and not terminal: the follower leaves the
//!   partial bytes unconsumed and re-probes them on the next poll,
//!   delivering the record exactly once — when it is whole.
//! * **History rewrite.** Resume truncates the log to a record
//!   boundary ([`LogWriter::open_truncated`](super::log::LogWriter::open_truncated))
//!   and appends a new incarnation, so the frontier can move
//!   *backwards* — or, worse, regrow past the follower's offset before
//!   the next poll, leaving the length alone looking monotonic. The
//!   follower detects both (length check + re-probing the CRC trailer
//!   of the last delivered record) and signals a clean re-replay from
//!   offset zero rather than decoding from the middle of unrelated
//!   bytes.
//!
//! Real corruption (a flipped byte inside a settled record) is
//! reported via [`FollowPoll::corrupt`] and the follower refuses to
//! advance past it: downstream record boundaries cannot be trusted, so
//! it re-reports on every poll until a resume rewrites the region
//! (which the reset probe then catches).

use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};

use super::log::{decode_one, LogRecord};
use super::StoreError;

/// Identity of the last record a follower delivered: where it ended
/// and the CRC-32 trailer that must still be on disk there. If those
/// four bytes change, history was rewritten under us.
#[derive(Debug, Clone, Copy)]
struct LastRecord {
    /// Byte offset one past the record's CRC trailer (== the
    /// follower's read offset).
    end: u64,
    /// The record's CRC-32 trailer value.
    crc: u32,
}

/// The result of one [`LogFollower::poll`].
#[derive(Debug)]
pub struct FollowPoll {
    /// Records decoded this poll, in log order. After a reset this is
    /// the full re-replay, not a delta.
    pub records: Vec<LogRecord>,
    /// True when the log's history was rewritten since the last poll
    /// (truncate-for-resume, or the file vanished): any state folded
    /// from earlier polls is stale and must be rebuilt from
    /// [`records`](Self::records), which restarts from the beginning
    /// of the log.
    pub reset: bool,
    /// Byte offset of the decode frontier after this poll — advances
    /// monotonically between resets, and only over fully decoded
    /// records.
    pub frontier: u64,
    /// A non-torn decode error at the frontier (flipped byte, bad
    /// magic/version). The follower does not advance past it; the same
    /// error is re-reported on every poll until the region is
    /// rewritten. A torn tail is *not* reported here — it is awaited.
    pub corrupt: Option<StoreError>,
}

/// Polls an append-only event log and decodes records incrementally.
///
/// Create one per log file with [`LogFollower::new`] (the file need
/// not exist yet) and call [`poll`](Self::poll) at whatever cadence
/// suits the caller; each poll returns the newly settled records.
/// The follower never writes, creates, or locks anything.
#[derive(Debug)]
pub struct LogFollower {
    path: PathBuf,
    /// Byte offset of the first not-yet-delivered byte. Invariant:
    /// equals `last.end` whenever `last` is `Some`.
    offset: u64,
    last: Option<LastRecord>,
}

impl LogFollower {
    /// A follower positioned at the start of `path`. The file may not
    /// exist yet — polls before the writer creates it return empty.
    pub fn new(path: impl AsRef<Path>) -> LogFollower {
        LogFollower { path: path.as_ref().to_path_buf(), offset: 0, last: None }
    }

    /// The log file this follower reads.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Current decode frontier in bytes (0 until the first record
    /// settles).
    pub fn offset(&self) -> u64 {
        self.offset
    }

    /// Read everything the writer appended (or rewrote) since the last
    /// poll.
    ///
    /// Errors only on hard I/O failures against an existing file; a
    /// missing file and every decode-level malformation are reported
    /// in-band through [`FollowPoll`].
    pub fn poll(&mut self) -> Result<FollowPoll, StoreError> {
        let len = match std::fs::metadata(&self.path) {
            Ok(m) => m.len(),
            Err(_) => {
                // The writer has not created the log yet — or the run
                // dir was removed wholesale. Not an error for a
                // follower; if records were already delivered, the
                // history they came from is gone: reset.
                let reset = self.offset > 0;
                self.offset = 0;
                self.last = None;
                return Ok(FollowPoll { records: Vec::new(), reset, frontier: 0, corrupt: None });
            }
        };
        let mut file =
            File::open(&self.path).map_err(|e| StoreError::io(&self.path, "open", e))?;

        let mut reset = false;
        if len < self.offset {
            // Frontier moved backwards: a resume cut dropped records we
            // already delivered.
            reset = true;
        } else if let Some(last) = self.last {
            // The file is at least as long as our offset — but a resume
            // cut below the offset followed by fast regrowth looks
            // exactly like an append. Cheap rewrite probe: the CRC
            // trailer of the last delivered record must still sit at
            // the same offset.
            if read_u32_at(&mut file, &self.path, last.end - 4)? != Some(last.crc) {
                reset = true;
            }
        }
        if reset {
            self.offset = 0;
            self.last = None;
        }

        file.seek(SeekFrom::Start(self.offset))
            .map_err(|e| StoreError::io(&self.path, "seek", e))?;
        let mut buf = Vec::new();
        file.read_to_end(&mut buf).map_err(|e| StoreError::io(&self.path, "read", e))?;

        let mut records = Vec::new();
        let mut pos = 0usize;
        let mut corrupt = None;
        while pos < buf.len() {
            match decode_one(&buf[pos..]) {
                Ok((rec, consumed)) => {
                    let end = pos + consumed;
                    let crc = u32::from_le_bytes(buf[end - 4..end].try_into().unwrap());
                    self.last = Some(LastRecord { end: self.offset + end as u64, crc });
                    records.push(rec);
                    pos = end;
                }
                // The writer is mid-append: leave the partial bytes
                // unconsumed and re-probe next poll.
                Err(StoreError::Truncated { .. }) => break,
                // Settled corruption: report, never skip — boundaries
                // past a bad record are meaningless.
                Err(e) => {
                    corrupt = Some(e);
                    break;
                }
            }
        }
        self.offset += pos as u64;
        Ok(FollowPoll { records, reset, frontier: self.offset, corrupt })
    }
}

/// The little-endian `u32` at byte offset `at`, or `None` if the file
/// ends before four bytes are available (the file shrank under us —
/// the caller treats that as a rewrite).
fn read_u32_at(file: &mut File, path: &Path, at: u64) -> Result<Option<u32>, StoreError> {
    file.seek(SeekFrom::Start(at)).map_err(|e| StoreError::io(path, "seek", e))?;
    let mut b = [0u8; 4];
    match file.read_exact(&mut b) {
        Ok(()) => Ok(Some(u32::from_le_bytes(b))),
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => Ok(None),
        Err(e) => Err(StoreError::io(path, "read", e)),
    }
}

#[cfg(test)]
mod tests {
    use super::super::log::LogWriter;
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("sb-follow-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn rec(step: u64) -> LogRecord {
        LogRecord::Resumed { step }
    }

    #[test]
    fn polls_before_the_file_exists_are_empty_not_errors() {
        let dir = tmp("nofile");
        let mut fl = LogFollower::new(dir.join("events.log"));
        let p = fl.poll().unwrap();
        assert!(p.records.is_empty() && !p.reset && p.corrupt.is_none());
        assert_eq!(p.frontier, 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn delivers_appends_incrementally() {
        let dir = tmp("incr");
        let path = dir.join("events.log");
        let mut w = LogWriter::create(&path).unwrap();
        let mut fl = LogFollower::new(&path);
        w.append(&rec(1)).unwrap();
        assert_eq!(fl.poll().unwrap().records, vec![rec(1)]);
        w.append(&rec(2)).unwrap();
        w.append(&rec(3)).unwrap();
        let p = fl.poll().unwrap();
        assert_eq!(p.records, vec![rec(2), rec(3)]);
        assert!(!p.reset && p.corrupt.is_none());
        assert!(fl.poll().unwrap().records.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncate_below_offset_resets_even_after_regrowth() {
        let dir = tmp("regrow");
        let path = dir.join("events.log");
        let mut w = LogWriter::create(&path).unwrap();
        for s in 1..=3 {
            w.append(&rec(s)).unwrap();
        }
        let mut fl = LogFollower::new(&path);
        assert_eq!(fl.poll().unwrap().records.len(), 3);
        drop(w);
        // Cut back to one record, then regrow *past* the old frontier:
        // length alone cannot reveal the rewrite.
        let rp = super::super::replay(&path).unwrap();
        let mut w = LogWriter::open_truncated(&path, rp.offsets[0].1).unwrap();
        for s in 10..=13 {
            w.append(&rec(s)).unwrap();
        }
        assert!(std::fs::metadata(&path).unwrap().len() > rp.valid_bytes);
        let p = fl.poll().unwrap();
        assert!(p.reset);
        assert_eq!(p.records, vec![rec(1), rec(10), rec(11), rec(12), rec(13)]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
