//! Durable, event-sourced runs: the on-disk store behind `--run-dir`.
//!
//! A run directory makes a training run survive its process:
//!
//! ```text
//! <run-dir>/
//!   run.json                     canonical RunManifest (config identity)
//!   events.log                   append-only, fsync'd, CRC-framed event log
//!   checkpoints/
//!     step-K.ckpt                full cluster state at averaging boundary K
//!     step-K.opid-R.ckpt         per-process variant (TCP launch engine)
//! ```
//!
//! The pieces compose into the chemflow-style fingerprint / rehydrate /
//! clone-for-branch contract:
//!
//! * **Fingerprint** — `run.json` is the canonical config; its FNV-1a
//!   fingerprint (the same one the TCP handshake compares) is stamped
//!   into every checkpoint artifact, so state from a different
//!   configuration can never be silently resumed.
//! * **Rehydrate** — [`Session`](crate::api::Session) resume loads the
//!   manifest, picks the newest checkpoint whose CRC and fingerprint
//!   verify, replays the event log's valid prefix, truncates any torn
//!   tail, and continues **bit-identically** to the uninterrupted run
//!   (checkpoints carry optimizer momentum per worker, not just the
//!   global model).
//! * **Branch** — [`Session::branch`](crate::api::Session::branch)
//!   clones the *global* model out of any averaging boundary into a new
//!   run under a divergent configuration (the global 20-tensor form
//!   re-shards to any topology; momentum resets, as on any restore).
//!
//! Log framing reuses the `wire.rs` discipline — magic, version, kind,
//! length-bounded payload, CRC-32 trailer — and every malformation maps
//! to a typed [`StoreError`]: a torn tail write or flipped byte yields
//! recovery to the last valid record, never a panic and never silent
//! divergence (`prop_store` sweeps every truncation boundary).
//!
//! For *live* observation, [`LogFollower`] tail-follows a log that a
//! writer is still appending to (or truncating across a resume) — the
//! read side behind `splitbrain watch` and
//! [`Watcher`](crate::api::Watcher).

pub mod ckpt;
pub mod dir;
pub mod follow;
pub mod log;

pub use ckpt::{load_artifact, save_artifact, CheckpointArtifact};
pub use dir::RunDir;
pub use follow::{FollowPoll, LogFollower};
pub use log::{replay, LogRecord, LogWriter, Replay};

/// Every way the durable store can fail, typed. I/O carries the path
/// and operation; framing errors carry the observed vs expected values
/// so a corrupted log diagnoses itself.
#[derive(Debug, Clone, PartialEq)]
pub enum StoreError {
    /// An OS-level I/O failure (open/read/write/fsync/rename).
    Io {
        /// Path the operation touched.
        path: String,
        /// The operation that failed (e.g. `"create"`, `"fsync"`).
        op: &'static str,
        /// The OS error, stringified.
        err: String,
    },
    /// The file ended mid-record (torn tail write).
    Truncated {
        /// Bytes the record needed.
        needed: usize,
        /// Bytes actually available.
        got: usize,
    },
    /// The record header does not start with the expected magic.
    BadMagic(u32),
    /// The format version is not one this build reads.
    VersionMismatch {
        /// Version found in the file.
        got: u16,
        /// Version this build writes.
        want: u16,
    },
    /// Declared payload length exceeds the format bound.
    Oversized {
        /// Declared length.
        len: u32,
        /// The bound.
        max: u32,
    },
    /// CRC-32 over the record did not match its trailer.
    BadCrc {
        /// CRC computed over the bytes read.
        computed: u32,
        /// CRC carried in the file.
        carried: u32,
    },
    /// Unknown record kind byte.
    BadKind(u8),
    /// The payload failed structural decoding (valid frame, bad body).
    BadPayload(String),
    /// A checkpoint/manifest belongs to a different configuration.
    FingerprintMismatch {
        /// Fingerprint found in the artifact.
        got: u64,
        /// Fingerprint of the configuration trying to use it.
        want: u64,
    },
    /// The directory does not look like a run dir (no `run.json`).
    NotARunDir(String),
    /// The directory already holds a run (refuse to clobber; resume
    /// instead).
    RunExists(String),
    /// Resume/branch needs a checkpoint but none decodes cleanly.
    NoCheckpoint(String),
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::Io { path, op, err } => write!(f, "store i/o: {op} {path}: {err}"),
            StoreError::Truncated { needed, got } => {
                write!(f, "truncated record: needed {needed} bytes, got {got}")
            }
            StoreError::BadMagic(m) => write!(f, "bad log magic 0x{m:08x}"),
            StoreError::VersionMismatch { got, want } => {
                write!(f, "log version {got} (this build reads {want})")
            }
            StoreError::Oversized { len, max } => {
                write!(f, "record payload {len} exceeds bound {max}")
            }
            StoreError::BadCrc { computed, carried } => {
                write!(f, "record crc mismatch: computed 0x{computed:08x}, file carries 0x{carried:08x}")
            }
            StoreError::BadKind(k) => write!(f, "unknown record kind {k}"),
            StoreError::BadPayload(why) => write!(f, "malformed record payload: {why}"),
            StoreError::FingerprintMismatch { got, want } => {
                write!(f, "config fingerprint mismatch: artifact {got:016x}, run {want:016x}")
            }
            StoreError::NotARunDir(d) => write!(f, "{d}: not a run directory (no run.json)"),
            StoreError::RunExists(d) => {
                write!(f, "{d}: already contains a run — resume it or pick a fresh directory")
            }
            StoreError::NoCheckpoint(d) => write!(f, "{d}: no decodable checkpoint artifact"),
        }
    }
}

impl std::error::Error for StoreError {}

impl StoreError {
    /// Wrap an `std::io::Error` with path + operation context.
    pub fn io(path: impl AsRef<std::path::Path>, op: &'static str, err: std::io::Error) -> StoreError {
        StoreError::Io { path: path.as_ref().display().to_string(), op, err: err.to_string() }
    }
}
