//! Deterministic synthetic CIFAR-shaped dataset.
//!
//! Class-conditional Gaussians: class c has a per-pixel mean pattern
//! drawn once from the seed, and examples are mean + noise. The task is
//! genuinely learnable (a linear probe already separates it, a CNN
//! drives loss toward zero), which is what the end-to-end example needs
//! to demonstrate a falling loss curve; and the *shapes* match CIFAR-10
//! exactly, which is all the throughput experiments depend on.

use super::batch::Dataset;
use crate::util::Rng;

const PIXELS: usize = 32 * 32 * 3;
const CLASSES: usize = 10;

/// Synthetic stand-in for CIFAR-10 (see DESIGN.md §1 substitutions).
pub struct SyntheticCifar {
    n: usize,
    /// Per-class mean images, [10][3072].
    means: Vec<Vec<f32>>,
    seed: u64,
    /// Noise scale; mean patterns are ±`signal`.
    noise: f32,
}

impl SyntheticCifar {
    /// Build a dataset of `n` examples from a seed.
    pub fn new(n: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let means = (0..CLASSES)
            .map(|_| {
                (0..PIXELS)
                    .map(|_| if rng.uniform() < 0.5 { -0.5 } else { 0.5 })
                    .collect()
            })
            .collect();
        SyntheticCifar { n, means, seed, noise: 0.3 }
    }
}

impl Dataset for SyntheticCifar {
    fn len(&self) -> usize {
        self.n
    }

    fn example(&self, i: usize) -> (Vec<f32>, i32) {
        assert!(i < self.n, "example {i} out of range {}", self.n);
        // Per-example RNG stream: stable regardless of access order.
        let mut rng = Rng::new(self.seed ^ (i as u64).wrapping_mul(0xD1B5_4A32_D192_ED03));
        let label = (rng.next_u64() % CLASSES as u64) as usize;
        let mean = &self.means[label];
        let img = mean
            .iter()
            .map(|&m| m + rng.normal() * self.noise)
            .collect();
        (img, label as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_labels() {
        let ds = SyntheticCifar::new(100, 7);
        let (img, lab) = ds.example(3);
        assert_eq!(img.len(), PIXELS);
        assert!((0..10).contains(&lab));
    }

    #[test]
    fn deterministic_per_index() {
        let ds = SyntheticCifar::new(10, 7);
        assert_eq!(ds.example(5).0, ds.example(5).0);
        assert_eq!(ds.example(5).1, ds.example(5).1);
    }

    #[test]
    fn different_indices_differ() {
        let ds = SyntheticCifar::new(10, 7);
        assert_ne!(ds.example(1).0, ds.example(2).0);
    }

    #[test]
    fn class_means_are_separable() {
        // Nearest-mean classification on clean examples must beat chance
        // by a wide margin — the dataset is learnable by construction.
        let ds = SyntheticCifar::new(200, 3);
        let mut correct = 0;
        for i in 0..200 {
            let (img, lab) = ds.example(i);
            let best = (0..CLASSES)
                .min_by(|&a, &b| {
                    let da: f32 = ds.means[a].iter().zip(&img).map(|(m, x)| (m - x).powi(2)).sum();
                    let db: f32 = ds.means[b].iter().zip(&img).map(|(m, x)| (m - x).powi(2)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == lab as usize {
                correct += 1;
            }
        }
        assert!(correct > 190, "nearest-mean accuracy {correct}/200");
    }

    #[test]
    fn label_distribution_roughly_uniform() {
        let ds = SyntheticCifar::new(2000, 11);
        let mut counts = [0usize; 10];
        for i in 0..2000 {
            counts[ds.example(i).1 as usize] += 1;
        }
        for (c, &n) in counts.iter().enumerate() {
            assert!((120..=280).contains(&n), "class {c}: {n}");
        }
    }
}
