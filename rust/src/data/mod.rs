//! Input pipeline: CIFAR-10 (the paper's dataset) plus a deterministic
//! synthetic stand-in, and the batch/DP-sharding iterators.
//!
//! The paper loads CIFAR-10 from NFS once before timing (§5.1); we load
//! the real binary format when `CIFAR10_DIR` (or `data/cifar-10-batches-bin`)
//! is present and otherwise fall back to [`synthetic`] — a
//! class-conditional Gaussian task with identical shapes, so every code
//! path and every byte count is unchanged (DESIGN.md §1).

pub mod batch;
pub mod cifar;
pub mod synthetic;

pub use batch::{Batch, BatchIter, Dataset};
pub use synthetic::SyntheticCifar;

/// Load CIFAR-10 if available, else the synthetic fallback.
/// Returns (dataset, source description).
pub fn load_default(n_synthetic: usize, seed: u64) -> (std::sync::Arc<dyn Dataset>, String) {
    for dir in [
        std::env::var("CIFAR10_DIR").unwrap_or_default(),
        "data/cifar-10-batches-bin".to_string(),
    ] {
        if !dir.is_empty() {
            if let Ok(ds) = cifar::Cifar10::load_dir(&dir) {
                let desc = format!("CIFAR-10 from {dir} ({} images)", ds.len());
                return (std::sync::Arc::new(ds), desc);
            }
        }
    }
    let ds = SyntheticCifar::new(n_synthetic, seed);
    let desc = format!("synthetic CIFAR-shaped ({n_synthetic} images, seed {seed})");
    (std::sync::Arc::new(ds), desc)
}
