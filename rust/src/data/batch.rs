//! Dataset trait + minibatch iteration + DP sharding.
//!
//! DP splits the input dataset across workers (§1): worker `w` of `n`
//! sees the examples with `index % n == w`, and each epoch is shuffled
//! with a shared seed so all workers stay aligned on epoch boundaries.

use crate::runtime::HostTensor;
use crate::util::Rng;

/// One minibatch: NHWC images + labels.
#[derive(Debug, Clone)]
pub struct Batch {
    /// `[B, 32, 32, 3]` f32 in [0, 1]-ish normalized range.
    pub images: HostTensor,
    /// `[B]` i32 class ids.
    pub labels: HostTensor,
}

impl Batch {
    /// Examples in the batch.
    pub fn len(&self) -> usize {
        self.images.shape[0]
    }

    /// True when the batch holds no examples.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// An indexable dataset of CIFAR-shaped examples. `Send + Sync` so
/// worker threads can prefetch batches (overlap's double buffering)
/// and the multi-process driver can hand one shared handle around.
pub trait Dataset: Send + Sync {
    /// Number of examples.
    fn len(&self) -> usize;
    /// True when the dataset is empty.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Image `i` as 32*32*3 f32s (NHWC row-major) + label.
    fn example(&self, i: usize) -> (Vec<f32>, i32);

    /// Debug-friendly description (trait objects appear in
    /// `#[derive(Debug)]` holders like `api::SessionBuilder`).
    fn describe(&self) -> String {
        format!("Dataset(len={})", self.len())
    }

    /// Assemble a batch from explicit indices.
    fn gather(&self, indices: &[usize]) -> Batch {
        let b = indices.len();
        let mut images = Vec::with_capacity(b * 32 * 32 * 3);
        let mut labels = Vec::with_capacity(b);
        for &i in indices {
            let (img, lab) = self.example(i);
            debug_assert_eq!(img.len(), 32 * 32 * 3);
            images.extend_from_slice(&img);
            labels.push(lab);
        }
        Batch {
            images: HostTensor::f32(vec![b, 32, 32, 3], images),
            labels: HostTensor::i32(vec![b], labels),
        }
    }
}

impl std::fmt::Debug for dyn Dataset {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.describe())
    }
}

/// Epoch-shuffled, DP-sharded batch iterator. Infinite (wraps epochs).
/// Holds the dataset by `Arc` so the cluster driver can hand one shared
/// dataset to every worker's iterator (and worker threads can prefetch).
pub struct BatchIter {
    data: std::sync::Arc<dyn Dataset>,
    batch: usize,
    worker: usize,
    n_workers: usize,
    order: Vec<usize>,
    cursor: usize,
    epoch: u64,
    seed: u64,
}

impl BatchIter {
    /// Build worker `worker`-of-`n_workers`'s iterator over `data`.
    pub fn new(
        data: std::sync::Arc<dyn Dataset>,
        batch: usize,
        worker: usize,
        n_workers: usize,
        seed: u64,
    ) -> Self {
        assert!(worker < n_workers);
        assert!(batch > 0);
        let mut it = BatchIter {
            data,
            batch,
            worker,
            n_workers,
            order: Vec::new(),
            cursor: 0,
            epoch: 0,
            seed,
        };
        it.reshuffle();
        it
    }

    fn reshuffle(&mut self) {
        // Shared-seed epoch shuffle, then this worker's stride-slice.
        let mut all: Vec<usize> = (0..self.data.len()).collect();
        let mut rng = Rng::new(self.seed ^ self.epoch.wrapping_mul(0x9E37_79B9));
        rng.shuffle(&mut all);
        self.order = all
            .into_iter()
            .skip(self.worker)
            .step_by(self.n_workers)
            .collect();
        self.cursor = 0;
    }

    /// Current epoch (increments when the shard wraps).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Next batch (always exactly `batch` examples; wraps the epoch).
    pub fn next_batch(&mut self) -> Batch {
        let mut idx = Vec::with_capacity(self.batch);
        while idx.len() < self.batch {
            if self.cursor >= self.order.len() {
                self.epoch += 1;
                self.reshuffle();
            }
            idx.push(self.order[self.cursor]);
            self.cursor += 1;
        }
        self.data.gather(&idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny in-memory dataset: image filled with the index value.
    struct Toy(usize);
    impl Dataset for Toy {
        fn len(&self) -> usize {
            self.0
        }
        fn example(&self, i: usize) -> (Vec<f32>, i32) {
            (vec![i as f32; 32 * 32 * 3], (i % 10) as i32)
        }
    }

    #[test]
    fn batch_shapes() {
        let ds: std::sync::Arc<dyn Dataset> = std::sync::Arc::new(Toy(100));
        let mut it = BatchIter::new(ds.clone(), 8, 0, 1, 1);
        let b = it.next_batch();
        assert_eq!(b.images.shape, vec![8, 32, 32, 3]);
        assert_eq!(b.labels.shape, vec![8]);
        assert_eq!(b.len(), 8);
    }

    #[test]
    fn dp_shards_are_disjoint() {
        let ds: std::sync::Arc<dyn Dataset> = std::sync::Arc::new(Toy(40));
        let mut seen = [vec![], vec![]];
        for w in 0..2 {
            let mut it = BatchIter::new(ds.clone(), 4, w, 2, 9);
            for _ in 0..5 {
                // one epoch worth for each worker (20 examples / 4)
                let b = it.next_batch();
                seen[w].extend(b.images.as_f32().iter().step_by(32 * 32 * 3).map(|&v| v as usize));
            }
        }
        let mut all: Vec<usize> = seen[0].iter().chain(seen[1].iter()).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..40).collect::<Vec<_>>(), "workers must cover the epoch disjointly");
    }

    #[test]
    fn wraps_epochs() {
        let ds: std::sync::Arc<dyn Dataset> = std::sync::Arc::new(Toy(6));
        let mut it = BatchIter::new(ds.clone(), 4, 0, 1, 3);
        assert_eq!(it.epoch(), 0);
        it.next_batch();
        it.next_batch(); // needs 8 > 6 examples -> epoch bump
        assert!(it.epoch() >= 1);
    }

    #[test]
    fn deterministic_given_seed() {
        let ds: std::sync::Arc<dyn Dataset> = std::sync::Arc::new(Toy(50));
        let a: Vec<i32> = {
            let mut it = BatchIter::new(ds.clone(), 8, 0, 1, 42);
            it.next_batch().labels.as_i32().to_vec()
        };
        let b: Vec<i32> = {
            let mut it = BatchIter::new(ds.clone(), 8, 0, 1, 42);
            it.next_batch().labels.as_i32().to_vec()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn shuffle_changes_across_epochs() {
        let ds: std::sync::Arc<dyn Dataset> = std::sync::Arc::new(Toy(16));
        let mut it = BatchIter::new(ds.clone(), 16, 0, 1, 5);
        let e0 = it.next_batch().labels.as_i32().to_vec();
        let e1 = it.next_batch().labels.as_i32().to_vec();
        assert_ne!(e0, e1, "epoch reshuffle should change order");
    }
}
