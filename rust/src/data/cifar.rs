//! CIFAR-10 binary-format loader (the paper's dataset, §5).
//!
//! Reads the canonical `cifar-10-batches-bin` layout: five training
//! files of 10,000 records, each record `1 + 3072` bytes
//! (label, then 1024 R + 1024 G + 1024 B bytes in row-major order).
//! Also understands an uncompressed `cifar-10-binary.tar` archive via a
//! minimal built-in ustar reader (gzipped archives must be gunzipped
//! first — the offline image carries no deflate implementation).
//!
//! Images are normalized to zero-mean unit-ish range ((x/255 - 0.5) * 2)
//! and transposed CHW -> HWC to match the model's NHWC layout.

use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::batch::Dataset;

const RECORD: usize = 1 + 3072;

/// In-memory CIFAR-10 (train split).
pub struct Cifar10 {
    images: Vec<f32>, // n * 3072, HWC
    labels: Vec<i32>,
}

impl Cifar10 {
    /// Load from a directory of `data_batch_*.bin` files.
    pub fn load_dir(dir: impl AsRef<Path>) -> Result<Cifar10> {
        let dir = dir.as_ref();
        let mut raw = Vec::new();
        let mut found = 0;
        for i in 1..=5 {
            let path = dir.join(format!("data_batch_{i}.bin"));
            if path.exists() {
                raw.extend(std::fs::read(&path).with_context(|| format!("{path:?}"))?);
                found += 1;
            }
        }
        if found == 0 {
            bail!("no data_batch_*.bin under {dir:?}");
        }
        Self::from_records(&raw)
    }

    /// Load from an (uncompressed) `cifar-10-binary.tar` archive via
    /// the built-in ustar reader. Gzipped archives must be decompressed
    /// first (`gunzip`) — the offline build carries no deflate
    /// implementation.
    pub fn load_tar(path: impl AsRef<Path>) -> Result<Cifar10> {
        let path = path.as_ref();
        if path.extension().is_some_and(|e| e == "gz") {
            bail!("{path:?} is gzipped — run `gunzip` first (no deflate support offline)");
        }
        let mut f = std::fs::File::open(path).with_context(|| format!("{path:?}"))?;
        let mut tar = Vec::new();
        f.read_to_end(&mut tar).with_context(|| format!("reading {path:?}"))?;
        let mut raw = Vec::new();
        for (name, data) in iter_tar(&tar)? {
            if name.contains("data_batch_") && name.ends_with(".bin") {
                raw.extend_from_slice(data);
            }
        }
        if raw.is_empty() {
            bail!("archive contains no data_batch_*.bin members");
        }
        Self::from_records(&raw)
    }

    /// Parse concatenated binary records.
    pub fn from_records(raw: &[u8]) -> Result<Cifar10> {
        if raw.is_empty() || raw.len() % RECORD != 0 {
            bail!("CIFAR payload size {} not a multiple of {RECORD}", raw.len());
        }
        let n = raw.len() / RECORD;
        let mut images = Vec::with_capacity(n * 3072);
        let mut labels = Vec::with_capacity(n);
        for rec in raw.chunks_exact(RECORD) {
            let label = rec[0];
            if label > 9 {
                bail!("label {label} out of range");
            }
            labels.push(label as i32);
            let px = &rec[1..];
            // CHW -> HWC with normalization.
            for pos in 0..1024 {
                for c in 0..3 {
                    let v = px[c * 1024 + pos] as f32;
                    images.push((v / 255.0 - 0.5) * 2.0);
                }
            }
        }
        Ok(Cifar10 { images, labels })
    }
}

impl Dataset for Cifar10 {
    fn len(&self) -> usize {
        self.labels.len()
    }

    fn example(&self, i: usize) -> (Vec<f32>, i32) {
        let img = self.images[i * 3072..(i + 1) * 3072].to_vec();
        (img, self.labels[i])
    }
}

/// Minimal ustar reader: yields (name, payload) for regular files.
fn iter_tar(tar: &[u8]) -> Result<Vec<(String, &[u8])>> {
    let mut out = Vec::new();
    let mut off = 0;
    while off + 512 <= tar.len() {
        let hdr = &tar[off..off + 512];
        if hdr.iter().all(|&b| b == 0) {
            break; // end-of-archive
        }
        let name = std::str::from_utf8(&hdr[0..100])
            .unwrap_or("")
            .trim_end_matches('\0')
            .to_string();
        let size_field = std::str::from_utf8(&hdr[124..136])
            .context("tar size field")?
            .trim_end_matches(['\0', ' '])
            .trim();
        let size = usize::from_str_radix(size_field, 8)
            .with_context(|| format!("octal size {size_field:?}"))?;
        let typeflag = hdr[156];
        let data_start = off + 512;
        if data_start + size > tar.len() {
            bail!("truncated tar member {name}");
        }
        if typeflag == b'0' || typeflag == 0 {
            out.push((name, &tar[data_start..data_start + size]));
        }
        off = data_start + size.div_ceil(512) * 512;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a fake 3-record CIFAR payload.
    fn fake_records() -> Vec<u8> {
        let mut raw = Vec::new();
        for label in [0u8, 7, 9] {
            raw.push(label);
            for c in 0..3u8 {
                raw.extend(std::iter::repeat(c * 100).take(1024));
            }
        }
        raw
    }

    #[test]
    fn parses_records() {
        let ds = Cifar10::from_records(&fake_records()).unwrap();
        assert_eq!(ds.len(), 3);
        let (img, lab) = ds.example(1);
        assert_eq!(lab, 7);
        assert_eq!(img.len(), 3072);
        // First pixel: channels R=0, G=100, B=200 normalized.
        assert!((img[0] - (-1.0)).abs() < 1e-6);
        assert!((img[1] - (100.0 / 255.0 - 0.5) * 2.0).abs() < 1e-6);
    }

    #[test]
    fn rejects_bad_sizes_and_labels() {
        assert!(Cifar10::from_records(&[0u8; 100]).is_err());
        let mut bad = fake_records();
        bad[0] = 11; // label out of range
        assert!(Cifar10::from_records(&bad).is_err());
    }

    #[test]
    fn hwc_transpose_is_correct() {
        // Pixel p channel c lives at raw[1 + c*1024 + p]; after HWC it
        // must be at img[p*3 + c].
        let mut raw = vec![0u8];
        raw.extend(std::iter::repeat(0u8).take(3072));
        raw[1 + 2 * 1024 + 5] = 255; // B channel of pixel 5
        let ds = Cifar10::from_records(&raw).unwrap();
        let (img, _) = ds.example(0);
        assert!((img[5 * 3 + 2] - 1.0).abs() < 1e-6);
        assert_eq!(img.iter().filter(|&&v| v > 0.0).count(), 1);
    }

    #[test]
    fn tar_roundtrip() {
        // Build a minimal ustar archive with one member.
        let payload = fake_records();
        let mut hdr = vec![0u8; 512];
        hdr[0..24].copy_from_slice(b"cifar/data_batch_1.bin\0\0");
        let size_oct = format!("{:011o}\0", payload.len());
        hdr[124..136].copy_from_slice(size_oct.as_bytes());
        hdr[156] = b'0';
        let mut tar = hdr;
        tar.extend_from_slice(&payload);
        tar.resize(tar.len().div_ceil(512) * 512, 0);
        tar.extend(std::iter::repeat(0u8).take(1024)); // end blocks

        let members = iter_tar(&tar).unwrap();
        assert_eq!(members.len(), 1);
        assert_eq!(members[0].0, "cifar/data_batch_1.bin");
        let ds = Cifar10::from_records(members[0].1).unwrap();
        assert_eq!(ds.len(), 3);
    }

    #[test]
    fn missing_dir_is_error() {
        assert!(Cifar10::load_dir("/nonexistent/nope").is_err());
    }
}
