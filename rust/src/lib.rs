//! # SplitBrain — hybrid data and model parallel deep learning
//!
//! A Rust + JAX + Pallas reproduction of *SplitBrain: Hybrid Data and
//! Model Parallel Deep Learning* (Lai, Kadav, Kruus; NEC Labs, 2021).
//!
//! The crate is the paper's **Layer-3 coordinator**: it owns the cluster
//! topology, the automatic layer partitioning (Listing 1), the modulo and
//! shard communication layers (Figs. 4/5), the group-MP extension
//! (Fig. 6), BSP model averaging, SGD, the threaded cluster execution
//! engine with ring / recursive-halving-doubling collectives, and the
//! benchmark harness that regenerates every table and figure of the
//! paper's evaluation.
//!
//! Compute never happens in Python at runtime: the VGG-11 forward and
//! backward *segments* (Layer 2, JAX, calling Layer-1 Pallas kernels)
//! are AOT-lowered by `python -m compile.aot` into HLO text with a
//! manifest that [`runtime`] validates every call against; in this
//! offline build the segments execute on the bit-deterministic native
//! Rust backend (`runtime::native`), which implements exactly the same
//! functions.
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`api`] | the public session API: typed builder → staged plan → session, structured event stream, serializable run manifests |
//! | [`model`] | layer DSL, VGG-11 variant (Table 1), CCR estimates, the Listing-1 partitioner |
//! | [`comm`] | pluggable transport (in-proc fabric + multi-process TCP wire fabric), naive/ring/rhd collectives, network cost model, comm tracing, deterministic fault injection |
//! | [`coordinator`] | GMP topology, modulo/shard plans, step schedule, the compiled step-program IR + one executor for every engine (with overlapped execution), model averaging, threaded + sequential cluster engines, multi-process rank driver, elastic shrink-and-continue recovery |
//! | [`runtime`] | artifact manifest + native segment executor, host tensors |
//! | [`store`] | durable event-sourced runs: append-only CRC-framed event log, fingerprinted checkpoint artifacts, the `--run-dir` layout with kill-resume and branching, a tail-follower for live observation |
//! | [`serve`] | sharded batched inference over the fabric: forward-only step programs, deadline-aware admission with typed overload rejections, replica balancing with failure drain, and the open-loop load generator |
//! | [`obs`] | per-op tracing: span ring buffers behind the shared step-program executor, `metrics.json` snapshots, Chrome-trace export, measured-vs-predicted cost-model report |
//! | [`data`] | CIFAR-10 loader + synthetic generator, batching |
//! | [`train`] | SGD, trainer loop, metrics, memory accounting |
//! | [`bench`] | mini-bench harness + paper table printers |
//! | [`util`] | RNG, stats, timers, table formatting |
//!
//! ## Quickstart
//!
//! Build a session through the typed [`api`]: validate the
//! configuration into a [`api::Plan`] (topology, predicted memory and
//! comm volumes — before any compute), then start and run it:
//!
//! ```no_run
//! use splitbrain::api::SessionBuilder;
//! use splitbrain::runtime::RuntimeClient;
//!
//! let rt = RuntimeClient::load("artifacts").unwrap();
//! let plan = SessionBuilder::new().workers(4).mp(2).steps(100).validate(&rt).unwrap();
//! println!("per-worker params: {:.2} MB", plan.memory().param_mb());
//! let mut session = plan.start().unwrap();
//! let report = session.run().unwrap();
//! println!("{} images/sec", report.train.images_per_sec());
//! ```

#![warn(missing_docs)]

pub mod api;
pub mod bench;
pub mod comm;
pub mod coordinator;
pub mod data;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod store;
pub mod train;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
