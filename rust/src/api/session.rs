//! The live training session — stage three of the
//! `SessionBuilder → Plan → Session` lifecycle.
//!
//! A [`Session`] owns the cluster state and exposes both granularities:
//! [`run`](Session::run) drives the whole planned run, and
//! [`step`](Session::step) advances exactly one training step — the two
//! are **bit-identical** (`run` is a `step` loop; the `api_session`
//! suite asserts it), so callers can interleave checkpoints,
//! evaluation, or their own control logic between steps at no numeric
//! cost. Observability flows through attached [`EventSink`]s.

use std::path::Path;

use anyhow::Result;

use crate::coordinator::Cluster;
use crate::data::Dataset;
use crate::store::{
    ckpt::fnv1a, replay, save_artifact, CheckpointArtifact, LogRecord, LogWriter, RunDir,
    StoreError,
};
use crate::train::{MemoryReport, TrainReport};
use crate::util::Timer;

use super::builder::SessionBuilder;
use super::events::{Event, EventSink, RecoveryInfo, RunInfo, RunSummary, StepReport};

/// End-of-run report: the aggregate [`TrainReport`] plus the recovery
/// trajectory.
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Aggregated per-step metrics (losses, timing stats, comm trace).
    pub train: TrainReport,
    /// Steps completed.
    pub steps_done: usize,
    /// Elastic recoveries performed.
    pub recoveries: usize,
    /// Ranks lost, in detection order.
    pub lost_ranks: Vec<usize>,
    /// Final worker count (shrinks under recovery).
    pub n_workers: usize,
    /// Final MP group size.
    pub mp: usize,
    /// Step of the last in-memory restore point.
    pub last_checkpoint_step: usize,
}

impl RunReport {
    /// The scalar roll-up emitted as [`Event::RunCompleted`].
    pub fn summary(&self) -> RunSummary {
        RunSummary {
            steps: self.steps_done,
            images_per_sec: self.train.images_per_sec(),
            comm_fraction: self.train.comm_fraction(),
            recoveries: self.recoveries,
            lost_ranks: self.lost_ranks.clone(),
            n_workers: self.n_workers,
            mp: self.mp,
            last_checkpoint_step: self.last_checkpoint_step,
        }
    }
}

/// A running training session over the in-proc cluster.
///
/// # Examples
///
/// Drive a run step-at-a-time, checkpointing mid-way — bit-identical
/// to an uninterrupted [`run`](Session::run):
///
/// ```no_run
/// use splitbrain::api::SessionBuilder;
/// use splitbrain::runtime::RuntimeClient;
///
/// let rt = RuntimeClient::load("artifacts")?;
/// let mut session = SessionBuilder::new()
///     .workers(2)
///     .mp(2)
///     .steps(20)
///     .validate(&rt)?
///     .start()?;
/// while !session.is_done() {
///     let step = session.step()?;
///     if step.step == 10 {
///         session.checkpoint("mid.ckpt")?;
///     }
/// }
/// println!("final loss {:?}", session.report().train.final_loss());
/// # anyhow::Result::<()>::Ok(())
/// ```
pub struct Session<'rt> {
    cluster: Cluster<'rt>,
    steps: usize,
    batch: usize,
    train: TrainReport,
    sinks: Vec<Box<dyn EventSink>>,
    started: bool,
    store: Option<RunStore>,
}

/// The durable side of a session: the run dir, its event log, and the
/// facts needed to stamp checkpoint artifacts at averaging boundaries.
struct RunStore {
    dir: RunDir,
    log: LogWriter,
    manifest_fingerprint: u64,
    avg_period: usize,
}

impl<'rt> Session<'rt> {
    pub(crate) fn new(cluster: Cluster<'rt>, steps: usize, batch: usize) -> Session<'rt> {
        let train = TrainReport::new(cluster.cfg.n_workers, cluster.cfg.mp, batch);
        Session { cluster, steps, batch, train, sinks: Vec::new(), started: false, store: None }
    }

    /// Make this session durable in a freshly created run dir: every
    /// event is appended (fsync'd, CRC-framed) to `events.log`, and a
    /// fingerprinted checkpoint artifact lands at every averaging
    /// boundary.
    pub(crate) fn attach_store_fresh(
        &mut self,
        dir: RunDir,
        manifest_fingerprint: u64,
        avg_period: usize,
    ) -> Result<()> {
        let log = LogWriter::create(dir.events_path())?;
        self.store = Some(RunStore { dir, log, manifest_fingerprint, avg_period });
        Ok(())
    }

    /// [`attach_store_fresh`](Session::attach_store_fresh) for a
    /// rehydrated session: truncate the event log's distrusted tail
    /// (records past the resume point, or torn/corrupt bytes), restamp
    /// the resume boundary's `Checkpoint` record if truncation dropped
    /// it (the kill can land between the artifact rename and its log
    /// record), then append the `Resumed` lineage marker.
    pub(crate) fn attach_store_resumed(
        &mut self,
        dir: RunDir,
        manifest_fingerprint: u64,
        avg_period: usize,
        resume_step: usize,
    ) -> Result<()> {
        let path = dir.events_path();
        let mut log = if path.is_file() {
            let rp = replay(&path)?;
            let kept = rp.records_until_step(resume_step as u64);
            let mut log = LogWriter::open_truncated(&path, rp.cut_for_step(resume_step as u64))?;
            let boundary_logged = kept.iter().any(
                |r| matches!(r, LogRecord::Checkpoint { step, .. } if *step == resume_step as u64),
            );
            if resume_step > 0 && !boundary_logged {
                let p = dir.checkpoint_path(resume_step);
                let bytes = std::fs::read(&p).map_err(|e| StoreError::io(&p, "read", e))?;
                log.append(&LogRecord::Checkpoint {
                    step: resume_step as u64,
                    file: format!("step-{resume_step}.ckpt"),
                    fingerprint: fnv1a(&bytes),
                })?;
            }
            log
        } else {
            LogWriter::create(&path)?
        };
        log.append(&LogRecord::Resumed { step: resume_step as u64 })?;
        self.store = Some(RunStore { dir, log, manifest_fingerprint, avg_period });
        Ok(())
    }

    /// The durable run directory, when this session persists one.
    pub fn run_dir(&self) -> Option<&Path> {
        self.store.as_ref().map(|s| s.dir.root())
    }

    /// Seed a [`SessionBuilder`] that **branches** the run persisted in
    /// `run_dir`: the builder starts from the source run's manifest with
    /// the global model of its newest valid checkpoint as the initial
    /// parameters, and `overrides` then diverges the configuration
    /// (different collectives, lr, topology, ...). The source run dir is
    /// never written; give the branch its own dir with
    /// [`SessionBuilder::run_dir`] to persist it.
    ///
    /// Branching re-shards the global model for the (possibly new)
    /// topology and restarts optimizer momentum — the same contract as
    /// [`Session::restore`]. For bit-exact continuation of the *same*
    /// configuration use [`SessionBuilder::resume_from`] instead.
    pub fn branch(
        run_dir: impl AsRef<Path>,
        overrides: impl FnOnce(SessionBuilder) -> SessionBuilder,
    ) -> Result<SessionBuilder> {
        Ok(overrides(SessionBuilder::branch_from(run_dir, None)?))
    }

    /// Attach an observer; every event goes to every sink in attach
    /// order. Attach before the first [`step`](Session::step) to see
    /// [`Event::RunStarted`].
    pub fn attach(&mut self, sink: Box<dyn EventSink>) {
        self.sinks.push(sink);
    }

    /// Deliver to every sink (infallible observers), then mirror into
    /// the run dir's event log when this session is durable. A log
    /// append failure is a real error — durability is a correctness
    /// feature here, not best-effort observability.
    fn emit(&mut self, event: &Event) -> Result<()> {
        for sink in &mut self.sinks {
            sink.on_event(event);
        }
        if let Some(store) = &mut self.store {
            store.log.append(&LogRecord::from_event(event))?;
        }
        Ok(())
    }

    /// Persist the complete training state at an averaging boundary:
    /// write the fingerprinted artifact atomically, then witness it in
    /// the event log. (Artifact first — a kill between the two is
    /// healed by the resume path restamping the `Checkpoint` record.)
    fn maybe_persist_boundary(&mut self) -> Result<()> {
        let (avg_period, manifest_fingerprint) = match &self.store {
            Some(s) => (s.avg_period, s.manifest_fingerprint),
            None => return Ok(()),
        };
        let step = self.cluster.steps_done();
        if step == 0 || step % avg_period != 0 {
            return Ok(());
        }
        let art = CheckpointArtifact {
            step,
            manifest_fingerprint,
            state: self.cluster.full_state(),
        };
        let store = self.store.as_mut().expect("store checked above");
        let fingerprint = save_artifact(store.dir.checkpoint_path(step), &art)?;
        store.log.append(&LogRecord::Checkpoint {
            step: step as u64,
            file: format!("step-{step}.ckpt"),
            fingerprint,
        })?;
        self.write_metrics_snapshot()?;
        Ok(())
    }

    /// Rewrite `metrics.json` in the run dir from the live tracer —
    /// called at every averaging boundary and again at run end, so the
    /// read-only [`Watcher`](super::Watcher) (and `splitbrain watch`)
    /// can surface a live per-phase breakdown mid-run. A no-op unless
    /// the session is both durable and traced.
    fn write_metrics_snapshot(&self) -> Result<()> {
        let (Some(store), Some(m)) = (&self.store, self.metrics()) else {
            return Ok(());
        };
        let p = store.dir.metrics_path();
        std::fs::write(&p, m.to_json()).map_err(|e| StoreError::io(&p, "write", e))?;
        Ok(())
    }

    /// Per-op metrics snapshot of the live tracer, or `None` when the
    /// session was not built with [`SessionBuilder::trace`]. In-proc
    /// engines have no TCP fabric, so the per-peer histogram list is
    /// empty; everything else (op counts, bytes, durations) is
    /// populated.
    ///
    /// [`SessionBuilder::trace`]: super::SessionBuilder::trace
    pub fn metrics(&self) -> Option<crate::obs::Metrics> {
        self.cluster.tracer().map(|t| {
            crate::obs::Metrics::from_snapshot(
                &t.snapshot(),
                self.cluster.steps_done() as u64,
                vec![],
            )
        })
    }

    /// Chrome-trace-event JSON of the live tracer (pid 0 — the in-proc
    /// engines are a single process), or `None` when the session was
    /// not built with [`SessionBuilder::trace`]. Load the string (or
    /// the run dir's `trace.json`) in Perfetto / `chrome://tracing`.
    ///
    /// [`SessionBuilder::trace`]: super::SessionBuilder::trace
    pub fn chrome_trace(&self) -> Option<String> {
        self.cluster.tracer().map(|t| crate::obs::chrome_trace_json(0, &t.snapshot()))
    }

    /// Advance exactly one training step (recovering first under
    /// shrink-and-continue, like the cluster driver) and report it.
    /// Emits [`Event::RunStarted`] before the first step's work,
    /// [`Event::Recovered`] when the step survived a re-plan, and
    /// [`Event::StepCompleted`] on the way out.
    pub fn step(&mut self) -> Result<StepReport> {
        if !self.started {
            self.started = true;
            let mem = self.cluster.memory_report();
            let info = RunInfo {
                n_workers: self.cluster.cfg.n_workers,
                mp: self.cluster.cfg.mp,
                n_groups: self.cluster.cfg.n_workers / self.cluster.cfg.mp.max(1),
                batch: self.batch,
                steps: self.steps,
                lr: self.cluster.cfg.lr,
                avg_period: self.cluster.cfg.avg_period,
                engine: self.cluster.cfg.engine,
                collectives: self.cluster.cfg.collectives,
                overlap: self.cluster.cfg.overlap,
                param_mb: mem.param_mb(),
                total_mb: mem.total_mb(),
            };
            self.emit(&Event::RunStarted(info))?;
        }
        let recoveries_before = self.cluster.recoveries;
        let lost_before = self.cluster.lost_ranks.len();
        let timer = Timer::start();
        let m = self.cluster.step()?;
        let wall_secs = timer.elapsed_secs();

        // Mirror the modeled comm phases into the trace (what the
        // pre-API callers did by hand around `Cluster::step`).
        for p in &self.cluster.schedule.mp_phases {
            for _ in 0..p.times {
                self.train.trace.record_uniform(
                    p.category,
                    &self.cluster.cfg.net,
                    p.ranks,
                    p.per_member,
                );
            }
        }
        if m.dp_comm_secs > 0.0 {
            for p in &self.cluster.schedule.avg_phases {
                self.train.trace.record_uniform(
                    p.category,
                    &self.cluster.cfg.net,
                    p.ranks,
                    p.per_member,
                );
            }
        }
        self.train.push(&m);

        if self.cluster.recoveries > recoveries_before {
            let info = RecoveryInfo {
                step: self.cluster.steps_done(),
                lost_ranks: self.cluster.lost_ranks[lost_before..].to_vec(),
                n_workers: self.cluster.cfg.n_workers,
                mp: self.cluster.cfg.mp,
                restore_step: self.cluster.last_checkpoint_step(),
            };
            self.emit(&Event::Recovered(info))?;
        }
        let (bytes_busiest_rank, bytes_total) = self.cluster.last_fabric_bytes;
        let report = StepReport {
            step: self.cluster.steps_done(),
            loss: m.loss,
            compute_secs: m.compute_secs,
            mp_comm_secs: m.mp_comm_secs,
            dp_comm_secs: m.dp_comm_secs,
            wall_secs,
            bytes_busiest_rank,
            bytes_total,
        };
        self.emit(&Event::StepCompleted(report.clone()))?;
        self.maybe_persist_boundary()?;
        Ok(report)
    }

    /// Run every remaining planned step, emit [`Event::RunCompleted`],
    /// and return the report. Bit-identical to calling
    /// [`step`](Session::step) in a loop — it *is* that loop.
    pub fn run(&mut self) -> Result<RunReport> {
        while !self.is_done() {
            self.step()?;
        }
        let report = self.report();
        self.emit(&Event::RunCompleted(report.summary()))?;
        self.write_metrics_snapshot()?;
        if let (Some(store), Some(trace)) = (&self.store, self.chrome_trace()) {
            let p = store.dir.trace_path();
            std::fs::write(&p, trace).map_err(|e| StoreError::io(&p, "write", e))?;
        }
        Ok(report)
    }

    /// True once the planned step count has completed.
    pub fn is_done(&self) -> bool {
        self.cluster.steps_done() >= self.steps
    }

    /// Steps completed so far.
    pub fn steps_done(&self) -> usize {
        self.cluster.steps_done()
    }

    /// Steps the session plans to run in total.
    pub fn steps_planned(&self) -> usize {
        self.steps
    }

    /// Snapshot the report at the current step (also what
    /// [`run`](Session::run) returns at the end).
    pub fn report(&self) -> RunReport {
        RunReport {
            train: self.train.clone(),
            steps_done: self.cluster.steps_done(),
            recoveries: self.cluster.recoveries,
            lost_ranks: self.cluster.lost_ranks.clone(),
            n_workers: self.cluster.cfg.n_workers,
            mp: self.cluster.cfg.mp,
            last_checkpoint_step: self.cluster.last_checkpoint_step(),
        }
    }

    /// Save the global model to a checkpoint file (valid at any step;
    /// see [`Cluster::save_checkpoint`]).
    pub fn checkpoint(&self, path: impl AsRef<Path>) -> Result<()> {
        self.cluster.save_checkpoint(path)
    }

    /// Restore a checkpoint into every worker (re-sharding for this
    /// topology; optimizer momentum resets, as on any restore).
    pub fn restore(&mut self, path: impl AsRef<Path>) -> Result<()> {
        self.cluster.restore_checkpoint(path)
    }

    /// Evaluate the current model on `n_batches` × batch examples;
    /// returns (mean loss, accuracy).
    pub fn evaluate(&mut self, data: &dyn Dataset, n_batches: usize) -> Result<(f64, f64)> {
        self.cluster.evaluate(data, n_batches)
    }

    /// Per-worker memory accounting of the live cluster.
    pub fn memory_report(&self) -> MemoryReport {
        self.cluster.memory_report()
    }

    /// Read access to the underlying cluster (worker parameters,
    /// topology, schedule — what the parity suites inspect).
    pub fn cluster(&self) -> &Cluster<'rt> {
        &self.cluster
    }
}
