//! The staged plan — everything knowable about a run **before any
//! compute**: resolved GMP topology, the Fig. 3 partitioned network,
//! the compiled step schedule, predicted per-worker memory (the
//! Fig. 7c accounting) and communication volumes, and the canonical
//! run manifest.

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::{Cluster, ClusterConfig, GmpTopology, StepSchedule};
use crate::data::Dataset;
use crate::model::TransformedNet;
use crate::runtime::{HostTensor, RuntimeClient};
use crate::store::{RunDir, StoreError};
use crate::train::MemoryReport;

use super::manifest::RunManifest;
use super::session::Session;

/// The builder's durability choices, carried into [`Plan::start`]:
/// where (and whether) the run persists, whether it rehydrates, and the
/// branched-in global model, if any.
pub(crate) struct StoreOptions {
    /// Durable run directory (`None` = ephemeral run).
    pub(crate) run_dir: Option<std::path::PathBuf>,
    /// Rehydrate from `run_dir` instead of starting fresh.
    pub(crate) resume: bool,
    /// Initial global model cloned from another run's checkpoint.
    pub(crate) branch_global: Option<Vec<(String, HostTensor)>>,
    /// Record per-op spans ([`SessionBuilder::trace`]).
    ///
    /// [`SessionBuilder::trace`]: super::SessionBuilder::trace
    pub(crate) trace: bool,
}

/// Predicted per-step communication of a planned run (analytic, from
/// the compiled schedule and the α–β network model — the same numbers
/// the simulated clock will charge).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CommEstimate {
    /// Bytes the busiest rank pushes per step in the MP phases.
    pub mp_bytes_per_step: u64,
    /// Bytes the busiest rank pushes at each model-averaging boundary.
    pub avg_bytes_per_boundary: u64,
    /// Modeled seconds of MP communication per step.
    pub mp_secs_per_step: f64,
    /// Modeled seconds of averaging communication per boundary.
    pub avg_secs_per_boundary: f64,
}

/// Predicted forward-only (serving) profile of a planned topology —
/// what a replica of this shape costs to host and to feed, before any
/// serving process exists.
#[derive(Debug, Clone, PartialEq)]
pub struct ServingEstimate {
    /// Per-rank inference memory: parameters + activation staging,
    /// zero gradients, zero optimizer state.
    pub memory: MemoryReport,
    /// Fraction of the training footprint a forward-only replica
    /// avoids.
    pub memory_saving: f64,
    /// Forward-only exchange bytes one member pushes per serving step.
    pub step_bytes_per_member: u64,
    /// Exchange bytes per served request (member volume over B).
    pub bytes_per_request: f64,
    /// Requests one serving step answers (k·B).
    pub requests_per_step: usize,
}

/// A validated, fully resolved run — stage two of the
/// `SessionBuilder → Plan → Session` lifecycle.
///
/// Everything here is derived without touching worker state: callers
/// can inspect (or reject) a configuration's topology, memory and
/// communication profile before committing any resources, then
/// [`start`](Plan::start) the session.
///
/// # Examples
///
/// ```
/// use splitbrain::api::SessionBuilder;
/// use splitbrain::runtime::RuntimeClient;
///
/// let rt = RuntimeClient::load("artifacts").unwrap();
/// let plan = SessionBuilder::new().workers(8).mp(4).steps(10).validate(&rt).unwrap();
/// assert_eq!(plan.topology().n_groups(), 2);
/// let est = plan.comm();
/// assert!(est.mp_bytes_per_step > 0, "mp=4 moves activations every step");
/// println!("predicted {:.2} MB params/worker", plan.memory().param_mb());
/// ```
pub struct Plan<'rt> {
    rt: &'rt RuntimeClient,
    manifest: RunManifest,
    cfg: ClusterConfig,
    steps: usize,
    topo: GmpTopology,
    transformed: TransformedNet,
    schedule: StepSchedule,
    dataset: Option<Arc<dyn Dataset>>,
    store: StoreOptions,
}

impl<'rt> Plan<'rt> {
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn new(
        rt: &'rt RuntimeClient,
        manifest: RunManifest,
        cfg: ClusterConfig,
        steps: usize,
        topo: GmpTopology,
        transformed: TransformedNet,
        schedule: StepSchedule,
        dataset: Option<Arc<dyn Dataset>>,
        store: StoreOptions,
    ) -> Plan<'rt> {
        Plan { rt, manifest, cfg, steps, topo, transformed, schedule, dataset, store }
    }

    /// The resolved DP×MP topology (Fig. 6).
    pub fn topology(&self) -> &GmpTopology {
        &self.topo
    }

    /// The Fig. 3 transformed per-worker network.
    pub fn transformed(&self) -> &TransformedNet {
        &self.transformed
    }

    /// The compiled per-step schedule (compute inventory, per-phase
    /// comm volumes, shard plan widths).
    pub fn schedule(&self) -> &StepSchedule {
        &self.schedule
    }

    /// Per-FC-boundary shard widths of the plan (each worker owns
    /// `width / mp` columns of the sharded linears).
    pub fn shard_widths(&self) -> &[usize] {
        &self.schedule.shard_widths
    }

    /// Predicted per-worker memory (the Fig. 7c accounting) for this
    /// topology and batch — available before any worker state exists.
    pub fn memory(&self) -> MemoryReport {
        MemoryReport::of_scheme(&self.transformed, self.rt.manifest.batch, self.cfg.scheme)
    }

    /// Predicted per-step communication volumes and modeled times.
    pub fn comm(&self) -> CommEstimate {
        CommEstimate {
            mp_bytes_per_step: self.schedule.mp_bytes_per_member(),
            avg_bytes_per_boundary: self.schedule.avg_bytes_per_member(),
            mp_secs_per_step: self.schedule.mp_comm_secs(&self.cfg.net),
            avg_secs_per_boundary: self.schedule.avg_comm_secs(&self.cfg.net),
        }
    }

    /// Predicted forward-only (serving) profile of this topology: the
    /// inference memory footprint (no gradients, no optimizer state —
    /// the Fig.-7c-style saving an inference replica banks on top of
    /// the shard saving) and the per-request exchange volume. Compare
    /// against the measured `serve_status.json` /
    /// `BENCH_serving.json` numbers with `splitbrain profile`.
    pub fn serving(&self) -> ServingEstimate {
        let b = self.rt.manifest.batch;
        ServingEstimate {
            memory: MemoryReport::inference_of(&self.transformed, b),
            memory_saving: MemoryReport::inference_saving(&self.transformed, b),
            step_bytes_per_member: self.schedule.infer_bytes_per_member(),
            bytes_per_request: self.schedule.infer_bytes_per_request(),
            requests_per_step: self.cfg.mp.max(1) * b,
        }
    }

    /// The canonical, serializable description of this run — write
    /// [`RunManifest::to_json`] to `run.json` and any host can
    /// reproduce the run bit-identically
    /// (`splitbrain train --manifest run.json`).
    pub fn manifest(&self) -> &RunManifest {
        &self.manifest
    }

    /// The resolved low-level [`ClusterConfig`] (for tests and benches
    /// that drive [`Cluster`] directly).
    pub fn cluster_config(&self) -> ClusterConfig {
        self.cfg.clone()
    }

    /// Training steps the session will run.
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Build the session: initialize workers, shards and the fabric on
    /// the planned dataset (the builder's injected dataset, or the
    /// default loader).
    pub fn start(self) -> Result<Session<'rt>> {
        let data = match &self.dataset {
            Some(d) => d.clone(),
            None => crate::data::load_default(self.cfg.dataset_size, self.cfg.seed).0,
        };
        self.start_with_dataset(data)
    }

    /// [`start`](Plan::start) on an explicit dataset.
    ///
    /// Durability ([`SessionBuilder::run_dir`]) and rehydration
    /// ([`SessionBuilder::resume_from`] /
    /// [`SessionBuilder::branch_from`]) resolve here:
    ///
    /// - **fresh + run dir** — create the dir, persist `run.json`,
    ///   start logging events.
    /// - **resume** — verify this plan's manifest fingerprint matches
    ///   the persisted `run.json` (a typed
    ///   [`StoreError::FingerprintMismatch`] otherwise), rebuild the
    ///   cluster bit-exactly from the newest valid checkpoint artifact
    ///   (step 0 if none), truncate the event log's distrusted tail and
    ///   stamp a `Resumed` record.
    /// - **branch** — fresh cluster, then the source checkpoint's
    ///   global model restored (re-sharded) over it.
    ///
    /// [`SessionBuilder::run_dir`]: super::SessionBuilder::run_dir
    /// [`SessionBuilder::resume_from`]: super::SessionBuilder::resume_from
    /// [`SessionBuilder::branch_from`]: super::SessionBuilder::branch_from
    pub fn start_with_dataset(self, data: Arc<dyn Dataset>) -> Result<Session<'rt>> {
        let batch = self.rt.manifest.batch;
        let current = self.manifest.fingerprint();
        if self.store.resume {
            let dirpath =
                self.store.run_dir.as_ref().expect("resume_from always sets run_dir").clone();
            let dir = RunDir::open(&dirpath)?;
            let persisted = RunManifest::parse(&dir.manifest_json()?)?.fingerprint();
            if persisted != current {
                return Err(StoreError::FingerprintMismatch { got: current, want: persisted }
                    .into());
            }
            let (mut cluster, resume_step) = match dir.latest_valid_checkpoint(persisted)? {
                Some(art) => {
                    let step = art.step;
                    (Cluster::with_dataset_state(self.rt, self.cfg.clone(), data, art.state)?, step)
                }
                None => (Cluster::with_dataset(self.rt, self.cfg.clone(), data)?, 0),
            };
            if self.store.trace {
                cluster.set_tracer(Arc::new(crate::obs::TraceSet::new(self.cfg.n_workers)));
            }
            let mut session = Session::new(cluster, self.steps, batch);
            session.attach_store_resumed(dir, persisted, self.cfg.avg_period, resume_step)?;
            return Ok(session);
        }
        let mut cluster = Cluster::with_dataset(self.rt, self.cfg.clone(), data)?;
        if self.store.trace {
            cluster.set_tracer(Arc::new(crate::obs::TraceSet::new(self.cfg.n_workers)));
        }
        if let Some(global) = &self.store.branch_global {
            cluster.restore_from_global(global)?;
        }
        let mut session = Session::new(cluster, self.steps, batch);
        if let Some(dirpath) = &self.store.run_dir {
            let dir = RunDir::create(dirpath, &self.manifest.to_json())?;
            session.attach_store_fresh(dir, current, self.cfg.avg_period)?;
        }
        Ok(session)
    }
}
