//! The typed session builder — the **one place** in the codebase where
//! a [`ClusterConfig`] is constructed and validated.
//!
//! Every caller (the CLI, the benches, the test suites, the examples)
//! goes through [`SessionBuilder`]: per-field setters, then a single
//! [`validate`](SessionBuilder::validate) that either returns a staged
//! [`Plan`] or a typed, actionable [`ConfigError`] — never a mid-run
//! panic.

use std::sync::Arc;

use crate::comm::fabric::TAKE_TIMEOUT_SECS;
use crate::comm::fault::{FaultEvent, FaultPlan};
use crate::comm::{CollectiveAlgo, NetModel};
use crate::coordinator::cluster::plan_topology;
use crate::coordinator::{ClusterConfig, ExecEngine, McastScheme, RecoveryPolicy};
use crate::data::Dataset;
use crate::runtime::{HostTensor, RuntimeClient};
use crate::store::{load_artifact, RunDir, StoreError};

use super::error::ConfigError;
use super::manifest::RunManifest;
use super::plan::Plan;

/// Default training steps when the builder (and the CLI) are not told
/// otherwise.
pub const DEFAULT_STEPS: usize = 50;
/// Default worker count (the smallest interesting cluster).
pub const DEFAULT_WORKERS: usize = 2;
/// Default CLI/report logging cadence (a presentation knob — not part
/// of the run manifest, but shared by `ConsoleSink` and the CLI).
pub const DEFAULT_LOG_EVERY: usize = 10;

/// Typed builder for a training session.
///
/// Defaults match `splitbrain train` with no flags: 2 workers, pure DP,
/// 50 steps, the paper's trainer hyper-parameters, threaded engine with
/// ring collectives, overlap resolved per engine.
///
/// # Examples
///
/// Build, validate, inspect the plan, then train:
///
/// ```no_run
/// use splitbrain::api::SessionBuilder;
/// use splitbrain::runtime::RuntimeClient;
///
/// let rt = RuntimeClient::load("artifacts")?;
/// let plan = SessionBuilder::new()
///     .workers(4)
///     .mp(2)
///     .steps(100)
///     .lr(0.02)
///     .validate(&rt)?;
/// println!(
///     "{} groups, {:.2} MB params/worker, {} MP bytes/step",
///     plan.topology().n_groups(),
///     plan.memory().param_mb(),
///     plan.comm().mp_bytes_per_step,
/// );
/// let mut session = plan.start()?;
/// let report = session.run()?;
/// println!("{} images/sec", report.train.images_per_sec());
/// # anyhow::Result::<()>::Ok(())
/// ```
///
/// Illegal combinations are typed errors, caught before any compute:
///
/// ```
/// use splitbrain::api::{ConfigError, SessionBuilder};
///
/// let err = SessionBuilder::new().workers(4).mp(3).cluster_config().unwrap_err();
/// assert!(matches!(err, ConfigError::MpNotDivisor { .. }));
/// ```
#[derive(Debug, Clone)]
pub struct SessionBuilder {
    workers: usize,
    mp: usize,
    steps: usize,
    lr: f32,
    momentum: f32,
    clip_norm: f32,
    avg_period: usize,
    seed: u64,
    dataset_size: usize,
    scheme: McastScheme,
    engine: ExecEngine,
    collectives: CollectiveAlgo,
    recovery: RecoveryPolicy,
    take_timeout_ms: u64,
    /// `None` = auto: on for engines that can overlap (threaded, TCP),
    /// off for the sequential BSP reference.
    overlap: Option<bool>,
    segmented_mp1: bool,
    net: NetModel,
    faults: FaultPlan,
    /// Dataset injected by tests; `None` loads the default
    /// (CIFAR-10 when present, synthetic otherwise).
    dataset: Option<Arc<dyn Dataset>>,
    /// Durable run directory (`None` = ephemeral run).
    run_dir: Option<std::path::PathBuf>,
    /// Rehydrate from `run_dir` instead of starting fresh (set by
    /// [`SessionBuilder::resume_from`]).
    resume: bool,
    /// Initial global model for a branched run (set by
    /// [`SessionBuilder::branch_from`]): restored — re-sharded for this
    /// topology — right after worker init.
    branch_global: Option<Vec<(String, HostTensor)>>,
    /// Record per-op spans; a host-level knob (like `run_dir`), not part
    /// of the manifest or its fingerprint.
    trace: bool,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        SessionBuilder {
            workers: DEFAULT_WORKERS,
            mp: 1,
            steps: DEFAULT_STEPS,
            lr: 0.05,
            momentum: 0.9,
            clip_norm: 1.0,
            avg_period: 10,
            seed: 42,
            dataset_size: 2048,
            scheme: McastScheme::BoverK,
            engine: ExecEngine::Threaded,
            collectives: CollectiveAlgo::Ring,
            recovery: RecoveryPolicy::FailFast,
            take_timeout_ms: TAKE_TIMEOUT_SECS * 1000,
            overlap: None,
            segmented_mp1: false,
            net: NetModel::default(),
            faults: FaultPlan::new(),
            dataset: None,
            run_dir: None,
            resume: false,
            branch_global: None,
            trace: false,
        }
    }
}

impl SessionBuilder {
    /// A builder with the default configuration (see the type docs).
    pub fn new() -> SessionBuilder {
        SessionBuilder::default()
    }

    /// Seed every field from a parsed run manifest; flags/setters may
    /// still override afterwards. See [`RunManifest`] for the schema.
    ///
    /// # Examples
    ///
    /// ```
    /// use splitbrain::api::{RunManifest, SessionBuilder};
    ///
    /// let cfg = SessionBuilder::new().workers(4).mp(2).seed(7).cluster_config().unwrap();
    /// let json = RunManifest::from_config(&cfg, 20).to_json();
    /// let rebuilt = SessionBuilder::from_manifest(&json).unwrap().cluster_config().unwrap();
    /// assert_eq!(rebuilt.seed, 7);
    /// assert_eq!(rebuilt.mp, 2);
    /// ```
    pub fn from_manifest(text: &str) -> anyhow::Result<SessionBuilder> {
        Ok(Self::from_run_manifest(&RunManifest::parse(text)?))
    }

    /// [`from_manifest`](Self::from_manifest), reading the JSON from a
    /// file (the `splitbrain train --manifest run.json` path).
    pub fn from_manifest_file(path: impl AsRef<std::path::Path>) -> anyhow::Result<SessionBuilder> {
        use anyhow::Context;
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::from_manifest(&text)
            .with_context(|| format!("loading manifest {}", path.display()))
    }

    /// Seed every field from an already-parsed [`RunManifest`].
    pub fn from_run_manifest(m: &RunManifest) -> SessionBuilder {
        SessionBuilder {
            workers: m.workers,
            mp: m.mp,
            steps: m.steps,
            lr: m.lr,
            momentum: m.momentum,
            clip_norm: m.clip_norm,
            avg_period: m.avg_period,
            seed: m.seed,
            dataset_size: m.dataset_size,
            scheme: m.scheme,
            engine: m.engine,
            collectives: m.collectives,
            recovery: m.recovery,
            take_timeout_ms: m.take_timeout_ms,
            overlap: Some(m.overlap),
            segmented_mp1: m.segmented_mp1,
            net: m.net,
            faults: m.faults.clone(),
            dataset: None,
            run_dir: None,
            resume: false,
            branch_global: None,
            trace: false,
        }
    }

    /// Rehydrate the run persisted in `dir`: seed every field from its
    /// `run.json`, and make [`validate`](Self::validate) →
    /// [`Plan::start`] resume from the newest valid checkpoint artifact
    /// with the event log's distrusted tail truncated (no artifact at
    /// all restarts from step 0 — the initial model is a pure function
    /// of the seed). The resumed run is **bit-identical** to the
    /// uninterrupted one: per-worker parameters *and* optimizer
    /// momentum come back exactly, data iterators fast-forward, and
    /// consumed fault flags stay consumed.
    ///
    /// Overriding any manifest-bearing field after this call changes
    /// the config fingerprint and `start()` fails with
    /// [`StoreError::FingerprintMismatch`] — a resumed run must be the
    /// *same* run. To continue a run's model under a different
    /// configuration, branch instead ([`Self::branch_from`]).
    pub fn resume_from(dir: impl AsRef<std::path::Path>) -> anyhow::Result<SessionBuilder> {
        let rd = RunDir::open(dir.as_ref())?;
        let mut b = Self::from_manifest(&rd.manifest_json()?)?;
        b.run_dir = Some(dir.as_ref().to_path_buf());
        b.resume = true;
        Ok(b)
    }

    /// Clone the run persisted in `dir` into a **divergent** run: seed
    /// every field from its `run.json` and take the global model of one
    /// of its checkpoints (`at_step`, or the newest valid one) as this
    /// run's initial parameters. Setters may then change anything —
    /// topology, collectives, lr — and the global model re-shards to
    /// fit; optimizer momentum restarts (the [`Session::restore`]
    /// contract). The source dir is read-only here; give the branch its
    /// own [`run_dir`](Self::run_dir) to persist it.
    ///
    /// [`Session::restore`]: super::Session::restore
    pub fn branch_from(
        dir: impl AsRef<std::path::Path>,
        at_step: Option<usize>,
    ) -> anyhow::Result<SessionBuilder> {
        let rd = RunDir::open(dir.as_ref())?;
        let manifest = RunManifest::parse(&rd.manifest_json()?)?;
        let want = manifest.fingerprint();
        let art = match at_step {
            Some(step) => {
                let art = load_artifact(rd.checkpoint_path(step))?;
                if art.manifest_fingerprint != want {
                    return Err(StoreError::FingerprintMismatch {
                        got: art.manifest_fingerprint,
                        want,
                    }
                    .into());
                }
                art
            }
            None => rd
                .latest_valid_checkpoint(want)?
                .ok_or_else(|| StoreError::NoCheckpoint(rd.root().display().to_string()))?,
        };
        let mut b = Self::from_run_manifest(&manifest);
        b.branch_global = Some(art.state.global);
        Ok(b)
    }

    /// Total workers N.
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n;
        self
    }

    /// MP group size (1 = pure DP). Must divide the worker count.
    pub fn mp(mut self, mp: usize) -> Self {
        self.mp = mp;
        self
    }

    /// Training steps the session will run.
    pub fn steps(mut self, steps: usize) -> Self {
        self.steps = steps;
        self
    }

    /// SGD learning rate (finite, positive).
    pub fn lr(mut self, lr: f32) -> Self {
        self.lr = lr;
        self
    }

    /// SGD momentum (finite, in `[0, 1)`).
    pub fn momentum(mut self, momentum: f32) -> Self {
        self.momentum = momentum;
        self
    }

    /// Global-norm gradient clip (0 = off).
    pub fn clip_norm(mut self, clip_norm: f32) -> Self {
        self.clip_norm = clip_norm;
        self
    }

    /// Model-averaging period in steps (§4's "communication batches").
    pub fn avg_period(mut self, avg_period: usize) -> Self {
        self.avg_period = avg_period;
        self
    }

    /// Master seed (parameters, data order, fault randomness).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Synthetic dataset size when CIFAR-10 is absent.
    pub fn dataset_size(mut self, n: usize) -> Self {
        self.dataset_size = n;
        self
    }

    /// §3.1 communication scheme for the modulo layer.
    pub fn scheme(mut self, scheme: McastScheme) -> Self {
        self.scheme = scheme;
        self
    }

    /// Execution engine (threaded default; sequential = BSP reference).
    pub fn engine(mut self, engine: ExecEngine) -> Self {
        self.engine = engine;
        self
    }

    /// Collective algorithm for shard exchange and model averaging.
    pub fn collectives(mut self, algo: CollectiveAlgo) -> Self {
        self.collectives = algo;
        self
    }

    /// Peer-loss policy (fail fast, or shrink and continue).
    pub fn recovery(mut self, recovery: RecoveryPolicy) -> Self {
        self.recovery = recovery;
        self
    }

    /// Blocking-take timeout in milliseconds.
    pub fn take_timeout_ms(mut self, ms: u64) -> Self {
        self.take_timeout_ms = ms;
        self
    }

    /// Force overlapped execution on or off. Unset, it resolves
    /// automatically: on for the threaded/TCP engines, off for the
    /// sequential reference. Explicitly forcing it **on** with the
    /// sequential engine is a [`ConfigError::OverlapOnSequential`].
    ///
    /// # Examples
    ///
    /// ```
    /// use splitbrain::api::{ConfigError, SessionBuilder};
    /// use splitbrain::coordinator::ExecEngine;
    ///
    /// let err = SessionBuilder::new()
    ///     .engine(ExecEngine::Sequential)
    ///     .overlap(true)
    ///     .cluster_config()
    ///     .unwrap_err();
    /// assert!(matches!(err, ConfigError::OverlapOnSequential));
    ///
    /// // Unset overlap resolves per engine: off for sequential.
    /// let cfg = SessionBuilder::new()
    ///     .engine(ExecEngine::Sequential)
    ///     .cluster_config()
    ///     .unwrap();
    /// assert!(!cfg.overlap);
    /// ```
    pub fn overlap(mut self, overlap: bool) -> Self {
        self.overlap = Some(overlap);
        self
    }

    /// Run mp=1 through the segmented pipeline (bench fidelity knob —
    /// holds per-op efficiency constant across the DP/MP comparison).
    pub fn segmented_mp1(mut self, on: bool) -> Self {
        self.segmented_mp1 = on;
        self
    }

    /// α–β network cost model for the simulated clock.
    pub fn net(mut self, net: NetModel) -> Self {
        self.net = net;
        self
    }

    /// Deterministic fault-injection scenario.
    pub fn faults(mut self, faults: FaultPlan) -> Self {
        self.faults = faults;
        self
    }

    /// Train on an explicit dataset instead of the default loader
    /// (tests inject toy data here; not part of the manifest).
    pub fn dataset(mut self, data: Arc<dyn Dataset>) -> Self {
        self.dataset = Some(data);
        self
    }

    /// Persist this run durably under `dir`: `run.json` (the canonical
    /// manifest), an append-only CRC-framed `events.log`, and a
    /// fingerprinted checkpoint artifact at every averaging boundary —
    /// the layout [`SessionBuilder::resume_from`] and
    /// [`SessionBuilder::branch_from`] rehydrate. A fresh start refuses
    /// a directory that already holds a run
    /// ([`StoreError::RunExists`](crate::store::StoreError::RunExists));
    /// resume instead.
    pub fn run_dir(mut self, dir: impl Into<std::path::PathBuf>) -> Self {
        self.run_dir = Some(dir.into());
        self
    }

    /// Record one span per executed step-program op into a preallocated
    /// per-rank ring buffer ([`crate::obs::TraceSet`]). With a
    /// [`run_dir`](Self::run_dir), the session writes `metrics.json` at
    /// every averaging boundary and `metrics.json` + `trace.json`
    /// (Chrome-trace format) at run end; without one, read the data via
    /// [`Session::metrics`](super::Session::metrics) and
    /// [`Session::chrome_trace`](super::Session::chrome_trace). Like
    /// `run_dir`, a host-level knob: not part of the run manifest or
    /// its fingerprint, so tracing a resumed run is always legal.
    pub fn trace(mut self, on: bool) -> Self {
        self.trace = on;
        self
    }

    /// The worker count the builder currently holds (the CLI uses this
    /// to scope seeded random fault plans before validation).
    pub fn current_workers(&self) -> usize {
        self.workers
    }

    /// The step count the builder currently holds.
    pub fn current_steps(&self) -> usize {
        self.steps
    }

    /// Validate every runtime-independent combination and return the
    /// resolved [`ClusterConfig`]. This — via [`validate`](Self::validate) —
    /// is the **only** constructor of `ClusterConfig` in the tree; see
    /// [`ConfigError`] for the full matrix of rejections.
    ///
    /// Most callers want [`validate`](Self::validate), which also
    /// checks the runtime's artifact support and returns a staged
    /// [`Plan`]; `cluster_config` exists for tests and benches that
    /// drive [`Cluster`](crate::coordinator::Cluster) directly.
    ///
    /// # Examples
    ///
    /// ```
    /// use splitbrain::api::SessionBuilder;
    ///
    /// let cfg = SessionBuilder::new().workers(4).mp(2).cluster_config().unwrap();
    /// assert_eq!((cfg.n_workers, cfg.mp), (4, 2));
    /// assert!(cfg.overlap, "threaded engine resolves overlap on");
    /// ```
    pub fn cluster_config(&self) -> Result<ClusterConfig, ConfigError> {
        if self.workers == 0 {
            return Err(ConfigError::ZeroWorkers);
        }
        if self.mp == 0 {
            return Err(ConfigError::ZeroMp);
        }
        if self.workers % self.mp != 0 {
            return Err(ConfigError::MpNotDivisor { n_workers: self.workers, mp: self.mp });
        }
        if self.steps == 0 {
            return Err(ConfigError::ZeroSteps);
        }
        if self.avg_period == 0 {
            return Err(ConfigError::ZeroAvgPeriod);
        }
        if self.dataset_size == 0 {
            return Err(ConfigError::ZeroDataset);
        }
        if self.take_timeout_ms == 0 {
            return Err(ConfigError::ZeroTakeTimeout);
        }
        if !self.lr.is_finite() || self.lr <= 0.0 {
            return Err(ConfigError::InvalidLr { lr: self.lr });
        }
        if !self.momentum.is_finite() || !(0.0..1.0).contains(&self.momentum) {
            return Err(ConfigError::InvalidMomentum { momentum: self.momentum });
        }
        if !self.clip_norm.is_finite() || self.clip_norm < 0.0 {
            return Err(ConfigError::InvalidClipNorm { clip_norm: self.clip_norm });
        }
        for (field, value, lo_ok) in [
            ("alpha", self.net.alpha, false),
            ("beta", self.net.beta, false),
            ("phase_overhead", self.net.phase_overhead, true),
        ] {
            if !value.is_finite() || value < 0.0 || (!lo_ok && value == 0.0) {
                return Err(ConfigError::InvalidNetModel { field, value });
            }
        }
        let overlap = match self.overlap {
            Some(true) if self.engine == ExecEngine::Sequential => {
                return Err(ConfigError::OverlapOnSequential);
            }
            Some(v) => v,
            None => self.engine != ExecEngine::Sequential,
        };
        for (event, ev) in self.faults.events().iter().enumerate() {
            let (ranks, step) = match *ev {
                FaultEvent::Crash { rank, step } => (vec![rank], step),
                FaultEvent::Straggle { rank, step, .. } => (vec![rank], step),
                FaultEvent::DropMsg { src, dst, step, .. } => (vec![src, dst], step),
                FaultEvent::DelayMsg { src, dst, step, .. } => (vec![src, dst], step),
            };
            for rank in ranks {
                if rank >= self.workers {
                    return Err(ConfigError::FaultRankOutOfRange {
                        event,
                        rank,
                        n_workers: self.workers,
                    });
                }
            }
            if step == 0 || step > self.steps {
                return Err(ConfigError::FaultStepOutOfRange { event, step, steps: self.steps });
            }
        }
        Ok(ClusterConfig {
            n_workers: self.workers,
            mp: self.mp,
            lr: self.lr,
            momentum: self.momentum,
            clip_norm: self.clip_norm,
            avg_period: self.avg_period,
            seed: self.seed,
            net: self.net,
            dataset_size: self.dataset_size,
            segmented_mp1: self.segmented_mp1,
            scheme: self.scheme,
            engine: self.engine,
            collectives: self.collectives,
            recovery: self.recovery,
            take_timeout_ms: self.take_timeout_ms,
            faults: self.faults.clone(),
            overlap,
        })
    }

    /// Validate the full configuration against the runtime and stage a
    /// [`Plan`]: the resolved GMP topology, the Fig. 3 partitioned
    /// network, the compiled step schedule, the predicted memory and
    /// communication volumes, and the canonical [`RunManifest`] —
    /// **before any compute runs**.
    ///
    /// # Examples
    ///
    /// ```
    /// use splitbrain::api::SessionBuilder;
    /// use splitbrain::runtime::RuntimeClient;
    ///
    /// let rt = RuntimeClient::load("artifacts").unwrap();
    /// let plan = SessionBuilder::new().workers(4).mp(2).steps(8).validate(&rt).unwrap();
    /// assert_eq!(plan.topology().n_groups(), 2);
    /// assert!(plan.memory().param_mb() > 0.0);
    /// assert_eq!(plan.manifest().workers, 4);
    /// ```
    pub fn validate<'rt>(&self, rt: &'rt RuntimeClient) -> Result<Plan<'rt>, ConfigError> {
        let cfg = self.cluster_config()?;
        if !rt.manifest.supports_mp(cfg.mp) {
            return Err(ConfigError::MpUnsupported {
                mp: cfg.mp,
                supported: rt.manifest.mp_sizes.clone(),
            });
        }
        let (topo, transformed, schedule) = plan_topology(rt, &cfg, cfg.n_workers, cfg.mp)
            .map_err(|e| ConfigError::Planning(format!("{e:#}")))?;
        let manifest = RunManifest::from_config(&cfg, self.steps);
        Ok(Plan::new(
            rt,
            manifest,
            cfg,
            self.steps,
            topo,
            transformed,
            schedule,
            self.dataset.clone(),
            super::plan::StoreOptions {
                run_dir: self.run_dir.clone(),
                resume: self.resume,
                branch_global: self.branch_global.clone(),
                trace: self.trace,
            },
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid_and_resolve_overlap() {
        let cfg = SessionBuilder::new().cluster_config().unwrap();
        assert_eq!(cfg.n_workers, DEFAULT_WORKERS);
        assert!(cfg.overlap, "threaded default resolves overlap on");
        let seq = SessionBuilder::new()
            .engine(ExecEngine::Sequential)
            .cluster_config()
            .unwrap();
        assert!(!seq.overlap);
    }

    #[test]
    fn fault_plan_ranges_are_validated() {
        let err = SessionBuilder::new()
            .workers(2)
            .steps(10)
            .faults(FaultPlan::new().crash(2, 3))
            .cluster_config()
            .unwrap_err();
        assert!(matches!(err, ConfigError::FaultRankOutOfRange { rank: 2, n_workers: 2, .. }));

        let err = SessionBuilder::new()
            .workers(2)
            .steps(10)
            .faults(FaultPlan::new().straggle(1, 11, 50))
            .cluster_config()
            .unwrap_err();
        assert!(matches!(err, ConfigError::FaultStepOutOfRange { step: 11, steps: 10, .. }));
    }

    #[test]
    fn builder_matches_cli_defaults() {
        // The CLI relies on the builder's defaults being exactly the
        // historical flag defaults; pin them.
        let cfg = SessionBuilder::new().cluster_config().unwrap();
        assert_eq!(cfg.mp, 1);
        assert_eq!(cfg.lr, 0.05);
        assert_eq!(cfg.momentum, 0.9);
        assert_eq!(cfg.clip_norm, 1.0);
        assert_eq!(cfg.avg_period, 10);
        assert_eq!(cfg.seed, 42);
        assert_eq!(cfg.dataset_size, 2048);
        assert_eq!(cfg.scheme, McastScheme::BoverK);
        assert_eq!(cfg.engine, ExecEngine::Threaded);
        assert_eq!(cfg.collectives, CollectiveAlgo::Ring);
        assert_eq!(cfg.recovery, RecoveryPolicy::FailFast);
        assert_eq!(cfg.take_timeout_ms, TAKE_TIMEOUT_SECS * 1000);
        assert!(!cfg.segmented_mp1);
    }
}
