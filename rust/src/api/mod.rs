//! The library-first public API: a typed builder, a staged
//! `Plan → Session → Report` lifecycle, a structured event stream, and
//! serializable run manifests.
//!
//! This is the substrate every caller plugs into — the CLI, the
//! benches, the test suites and the examples are all thin clients of
//! this module; [`ClusterConfig`](crate::coordinator::ClusterConfig)
//! construction and validation live here and nowhere else.
//!
//! ## Lifecycle
//!
//! ```text
//! SessionBuilder ──validate(&rt)──▶ Plan ──start()──▶ Session ──run()──▶ RunReport
//!      ▲    │                        │                  │
//!      │    └─ ConfigError (typed,   │ topology()       │ step() / checkpoint()
//!      │       before any compute)   │ memory()         │ restore() / evaluate()
//!      │                             │ comm()           │ attach(EventSink)
//!      └──── from_manifest(run.json) ◀ manifest()
//! ```
//!
//! * [`SessionBuilder`] — per-field setters over the full
//!   configuration surface; [`SessionBuilder::validate`] catches every
//!   illegal combination as a typed [`ConfigError`].
//! * [`Plan`] — the resolved run *before any compute*: GMP topology,
//!   shard plan, predicted memory (Fig. 7c accounting) and
//!   communication volumes, plus the canonical [`RunManifest`].
//! * [`Session`] — live training: whole-run [`Session::run`],
//!   incremental [`Session::step`] (bit-identical to `run`), and
//!   checkpoint/restore.
//! * [`EventSink`] — structured observation (per-step loss, phase
//!   timings, byte counters, recovery transitions); [`ConsoleSink`]
//!   reproduces the historical CLI output byte-for-byte.
//! * [`RunManifest`] — every resolved config serializes to a canonical
//!   `run.json`, reloadable via [`SessionBuilder::from_manifest`] and
//!   `splitbrain train --manifest run.json`; the multi-process
//!   launcher hands one manifest to every worker and the TCP handshake
//!   compares manifest fingerprints.
//! * **Durable runs** ([`crate::store`]) —
//!   [`SessionBuilder::run_dir`](builder::SessionBuilder::run_dir)
//!   persists the event stream and fingerprinted checkpoint artifacts;
//!   [`SessionBuilder::resume_from`](builder::SessionBuilder::resume_from)
//!   rehydrates a killed run bit-identically, and
//!   [`Session::branch`](session::Session::branch) /
//!   [`SessionBuilder::branch_from`](builder::SessionBuilder::branch_from)
//!   clone a run from any averaging boundary into a divergent
//!   configuration.
//! * **Watching** ([`Watcher`]) — observe any run dir from *outside*
//!   its process, read-only: tail-follow the event log into a typed
//!   [`RunStatus`] and classify liveness (running / completed /
//!   stalled / dead) — the library half of `splitbrain watch`.
//!
//! # Examples
//!
//! ```no_run
//! use splitbrain::api::{ConsoleSink, SessionBuilder};
//! use splitbrain::runtime::RuntimeClient;
//!
//! let rt = RuntimeClient::load("artifacts")?;
//! let plan = SessionBuilder::new().workers(4).mp(2).steps(100).validate(&rt)?;
//! std::fs::write("run.json", plan.manifest().to_json())?; // reproducible
//! let mut session = plan.start()?;
//! session.attach(Box::new(ConsoleSink::new(10)));
//! let report = session.run()?;
//! println!("{} images/sec", report.train.images_per_sec());
//! # anyhow::Result::<()>::Ok(())
//! ```

pub mod builder;
pub mod error;
pub mod events;
pub mod manifest;
pub mod plan;
pub mod session;
pub mod watch;

pub use builder::{SessionBuilder, DEFAULT_LOG_EVERY, DEFAULT_STEPS, DEFAULT_WORKERS};
pub use error::ConfigError;
pub use events::{
    step_reports, CollectSink, ConsoleSink, DiskSink, Event, EventSink, RecoveryInfo, RunInfo,
    RunSummary, StepReport,
};
pub use manifest::{RunManifest, MANIFEST_VERSION};
pub use plan::{CommEstimate, Plan, ServingEstimate};
pub use session::{RunReport, Session};
pub use watch::{Liveness, RunStatus, ServeStatus, WatchDelta, Watcher};
