//! Typed configuration errors — every illegal combination a
//! [`SessionBuilder`](super::SessionBuilder) can express is caught by
//! `validate()` / `cluster_config()` **before any compute runs**, as a
//! matchable [`ConfigError`] instead of a mid-run panic or an opaque
//! string. The `config_errors` integration suite asserts the full
//! matrix: every invalid combination yields the right variant.

use std::fmt;

/// Why a session configuration was rejected.
///
/// Implements [`std::error::Error`], so `?` converts it into
/// `anyhow::Error` at CLI boundaries while library callers can still
/// match on the variant.
///
/// # Examples
///
/// ```
/// use splitbrain::api::{ConfigError, SessionBuilder};
///
/// let err = SessionBuilder::new().workers(4).mp(3).cluster_config().unwrap_err();
/// assert!(matches!(err, ConfigError::MpNotDivisor { n_workers: 4, mp: 3 }));
/// ```
#[derive(Debug, Clone, PartialEq)]
pub enum ConfigError {
    /// `workers` was 0 — a cluster needs at least one rank.
    ZeroWorkers,
    /// `mp` was 0 — the MP group size is at least 1 (1 = pure DP).
    ZeroMp,
    /// `mp` does not divide `workers`, so no GMP topology exists
    /// (Fig. 6 needs `workers = groups × mp` exactly).
    MpNotDivisor {
        /// Requested worker count N.
        n_workers: usize,
        /// Requested MP group size.
        mp: usize,
    },
    /// The runtime's artifacts were not lowered for this `mp` — re-run
    /// `make artifacts` with the size included, or pick a supported one.
    MpUnsupported {
        /// Requested MP group size.
        mp: usize,
        /// Sizes the artifact manifest supports.
        supported: Vec<usize>,
    },
    /// `steps` was 0 — a run must train at least one step.
    ZeroSteps,
    /// `avg_period` was 0 — model averaging needs a positive period
    /// (every step = 1).
    ZeroAvgPeriod,
    /// `dataset_size` was 0 — the synthetic dataset needs examples.
    ZeroDataset,
    /// `take_timeout_ms` was 0 — a zero blocking-take timeout presumes
    /// every peer dead immediately.
    ZeroTakeTimeout,
    /// `lr` was not a finite positive number.
    InvalidLr {
        /// The rejected value.
        lr: f32,
    },
    /// `momentum` was outside the finite range `[0, 1)`.
    InvalidMomentum {
        /// The rejected value.
        momentum: f32,
    },
    /// `clip_norm` was negative or non-finite (0 means clipping off).
    InvalidClipNorm {
        /// The rejected value.
        clip_norm: f32,
    },
    /// `overlap(true)` combined with the sequential engine: the
    /// sequential reference is the strict-BSP baseline and never
    /// overlaps. Leave overlap unset (it resolves per engine) or use
    /// the threaded engine.
    OverlapOnSequential,
    /// A fault-plan event targets a rank outside `0..workers`.
    FaultRankOutOfRange {
        /// Index of the offending event in the plan.
        event: usize,
        /// The out-of-range rank.
        rank: usize,
        /// The configured worker count.
        n_workers: usize,
    },
    /// A fault-plan event's step is 0 or beyond the run's `steps`
    /// (steps are 1-based; an event past the end would never fire).
    FaultStepOutOfRange {
        /// Index of the offending event in the plan.
        event: usize,
        /// The out-of-range step.
        step: usize,
        /// The run's step count.
        steps: usize,
    },
    /// The net-model parameters were not finite and positive
    /// (`alpha`/`beta` > 0, `phase_overhead` ≥ 0).
    InvalidNetModel {
        /// Which parameter was rejected.
        field: &'static str,
        /// The rejected value.
        value: f64,
    },
    /// Planning failed after every per-field check passed (artifact or
    /// partitioner inconsistency) — carries the underlying message.
    Planning(String),
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::ZeroWorkers => {
                write!(f, "workers must be positive (a cluster needs at least one rank)")
            }
            ConfigError::ZeroMp => {
                write!(f, "mp must be positive (1 = pure data parallelism)")
            }
            ConfigError::MpNotDivisor { n_workers, mp } => write!(
                f,
                "mp={mp} does not divide workers={n_workers}: the GMP topology needs \
                 workers = groups x mp exactly (try mp in the divisors of {n_workers})"
            ),
            ConfigError::MpUnsupported { mp, supported } => write!(
                f,
                "artifacts were not lowered for mp={mp} (supported: {supported:?}) — \
                 re-run `make artifacts` or pick a supported group size"
            ),
            ConfigError::ZeroSteps => write!(f, "steps must be positive"),
            ConfigError::ZeroAvgPeriod => {
                write!(f, "avg-period must be positive (1 = average every step)")
            }
            ConfigError::ZeroDataset => write!(f, "dataset-size must be positive"),
            ConfigError::ZeroTakeTimeout => write!(
                f,
                "take-timeout-ms must be positive (0 would presume every peer dead instantly)"
            ),
            ConfigError::InvalidLr { lr } => {
                write!(f, "lr must be a finite positive number, got {lr}")
            }
            ConfigError::InvalidMomentum { momentum } => {
                write!(f, "momentum must be finite and in [0, 1), got {momentum}")
            }
            ConfigError::InvalidClipNorm { clip_norm } => write!(
                f,
                "clip-norm must be finite and non-negative (0 = off), got {clip_norm}"
            ),
            ConfigError::OverlapOnSequential => write!(
                f,
                "overlap=true is meaningless on the sequential engine (the strict-BSP \
                 reference): leave overlap unset or use --engine threaded"
            ),
            ConfigError::FaultRankOutOfRange { event, rank, n_workers } => write!(
                f,
                "fault plan event {event} targets rank {rank}, but the run has ranks \
                 0..{n_workers}"
            ),
            ConfigError::FaultStepOutOfRange { event, step, steps } => write!(
                f,
                "fault plan event {event} fires at step {step}, but steps are 1-based \
                 and the run trains {steps} step(s)"
            ),
            ConfigError::InvalidNetModel { field, value } => {
                write!(f, "net model {field} must be finite and positive, got {value}")
            }
            ConfigError::Planning(msg) => write!(f, "planning failed: {msg}"),
        }
    }
}

impl std::error::Error for ConfigError {}
