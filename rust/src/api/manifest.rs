//! Serializable run manifests — the canonical `run.json` description
//! of a resolved training run.
//!
//! A [`RunManifest`] captures **everything that determines the run's
//! numerics and lifecycle**: shape (workers/mp/steps), trainer
//! hyper-parameters, seed, scheme/engine/collectives/recovery choices,
//! overlap, the α–β network model and the full fault plan. It
//! deliberately excludes host-level knobs (artifact paths, log cadence,
//! connect timeouts, compute tiling) — two hosts running the same
//! manifest produce bit-identical training.
//!
//! Properties the `api_manifest` property suite pins:
//!
//! * **Canonical**: serialize → parse → serialize is byte-identical.
//! * **Lossless**: every field round-trips exactly (floats via Rust's
//!   shortest-round-trip formatting, `u64` seeds as raw tokens).
//! * **Fingerprinted**: [`RunManifest::fingerprint`] hashes the
//!   canonical text; the TCP mesh's Hello handshake compares the
//!   fingerprints of every worker pair, so processes given different
//!   manifests can never train together
//!   (see `coordinator::procdriver::run_fingerprint`).

use anyhow::{bail, Context, Result};

use crate::comm::fault::{FaultEvent, FaultPlan};
use crate::comm::{CollectiveAlgo, NetModel};
use crate::coordinator::{ClusterConfig, ExecEngine, McastScheme, RecoveryPolicy};
use crate::util::json::{escape_str, Json};

/// Manifest schema version this build writes and reads.
pub const MANIFEST_VERSION: u64 = 1;

/// A resolved run description, serializable to canonical JSON.
///
/// Build one from a validated plan ([`Plan::manifest`](super::Plan::manifest)),
/// from a resolved config ([`RunManifest::from_config`]), or by parsing
/// a `run.json` ([`RunManifest::parse`]). Reload into a builder with
/// [`SessionBuilder::from_manifest`](super::SessionBuilder::from_manifest).
///
/// # Examples
///
/// ```
/// use splitbrain::api::{RunManifest, SessionBuilder};
///
/// let cfg = SessionBuilder::new().workers(4).mp(2).cluster_config().unwrap();
/// let manifest = RunManifest::from_config(&cfg, 20);
/// let text = manifest.to_json();
/// let reparsed = RunManifest::parse(&text).unwrap();
/// assert_eq!(reparsed.to_json(), text); // canonical round-trip
/// assert_eq!(reparsed.fingerprint(), manifest.fingerprint());
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct RunManifest {
    /// Total workers N.
    pub workers: usize,
    /// MP group size.
    pub mp: usize,
    /// Training steps.
    pub steps: usize,
    /// SGD learning rate.
    pub lr: f32,
    /// SGD momentum.
    pub momentum: f32,
    /// Global-norm gradient clip (0 = off).
    pub clip_norm: f32,
    /// Model-averaging period in steps.
    pub avg_period: usize,
    /// Master seed (params, data order, fault randomness).
    pub seed: u64,
    /// Synthetic dataset size.
    pub dataset_size: usize,
    /// §3.1 communication scheme.
    pub scheme: McastScheme,
    /// Execution engine.
    pub engine: ExecEngine,
    /// Collective algorithm.
    pub collectives: CollectiveAlgo,
    /// Peer-loss policy.
    pub recovery: RecoveryPolicy,
    /// Overlapped execution (resolved; never "auto" in a manifest).
    pub overlap: bool,
    /// Run mp=1 through the segmented pipeline (bench fidelity knob).
    pub segmented_mp1: bool,
    /// Blocking-take timeout, milliseconds.
    pub take_timeout_ms: u64,
    /// α–β network cost model.
    pub net: NetModel,
    /// Deterministic fault scenario.
    pub faults: FaultPlan,
}

impl RunManifest {
    /// Capture a resolved [`ClusterConfig`] plus the step count.
    pub fn from_config(cfg: &ClusterConfig, steps: usize) -> RunManifest {
        RunManifest {
            workers: cfg.n_workers,
            mp: cfg.mp,
            steps,
            lr: cfg.lr,
            momentum: cfg.momentum,
            clip_norm: cfg.clip_norm,
            avg_period: cfg.avg_period,
            seed: cfg.seed,
            dataset_size: cfg.dataset_size,
            scheme: cfg.scheme,
            engine: cfg.engine,
            collectives: cfg.collectives,
            recovery: cfg.recovery,
            overlap: cfg.overlap,
            segmented_mp1: cfg.segmented_mp1,
            take_timeout_ms: cfg.take_timeout_ms,
            net: cfg.net,
            faults: cfg.faults.clone(),
        }
    }

    /// The manifest as a resolved [`ClusterConfig`] (everything except
    /// `steps`, which the manifest carries separately).
    pub fn to_config(&self) -> ClusterConfig {
        ClusterConfig {
            n_workers: self.workers,
            mp: self.mp,
            lr: self.lr,
            momentum: self.momentum,
            clip_norm: self.clip_norm,
            avg_period: self.avg_period,
            seed: self.seed,
            net: self.net,
            dataset_size: self.dataset_size,
            segmented_mp1: self.segmented_mp1,
            scheme: self.scheme,
            engine: self.engine,
            collectives: self.collectives,
            recovery: self.recovery,
            take_timeout_ms: self.take_timeout_ms,
            faults: self.faults.clone(),
            overlap: self.overlap,
        }
    }

    /// Canonical JSON text (fixed key order, 2-space indent, trailing
    /// newline). Serialize → [`parse`](RunManifest::parse) → serialize
    /// is byte-identical.
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(1024);
        s.push_str("{\n");
        s.push_str(&format!("  \"splitbrain_manifest\": {MANIFEST_VERSION},\n"));
        s.push_str(&format!("  \"workers\": {},\n", self.workers));
        s.push_str(&format!("  \"mp\": {},\n", self.mp));
        s.push_str(&format!("  \"steps\": {},\n", self.steps));
        s.push_str(&format!("  \"lr\": {},\n", self.lr));
        s.push_str(&format!("  \"momentum\": {},\n", self.momentum));
        s.push_str(&format!("  \"clip_norm\": {},\n", self.clip_norm));
        s.push_str(&format!("  \"avg_period\": {},\n", self.avg_period));
        s.push_str(&format!("  \"seed\": {},\n", self.seed));
        s.push_str(&format!("  \"dataset_size\": {},\n", self.dataset_size));
        s.push_str(&format!("  \"scheme\": \"{}\",\n", escape_str(&self.scheme.to_string())));
        s.push_str(&format!("  \"engine\": \"{}\",\n", self.engine));
        s.push_str(&format!("  \"collectives\": \"{}\",\n", self.collectives));
        s.push_str(&format!("  \"recovery\": \"{}\",\n", self.recovery));
        s.push_str(&format!("  \"overlap\": {},\n", self.overlap));
        s.push_str(&format!("  \"segmented_mp1\": {},\n", self.segmented_mp1));
        s.push_str(&format!("  \"take_timeout_ms\": {},\n", self.take_timeout_ms));
        s.push_str(&format!(
            "  \"net\": {{\"alpha\": {}, \"beta\": {}, \"phase_overhead\": {}}},\n",
            self.net.alpha, self.net.beta, self.net.phase_overhead
        ));
        s.push_str("  \"faults\": [");
        for (i, ev) in self.faults.events().iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            match ev {
                FaultEvent::Crash { rank, step } => {
                    s.push_str(&format!("{{\"kind\": \"crash\", \"rank\": {rank}, \"step\": {step}}}"));
                }
                FaultEvent::Straggle { rank, step, sim_ms } => {
                    s.push_str(&format!(
                        "{{\"kind\": \"straggle\", \"rank\": {rank}, \"step\": {step}, \"sim_ms\": {sim_ms}}}"
                    ));
                }
                FaultEvent::DropMsg { src, dst, phase, step } => {
                    s.push_str(&format!(
                        "{{\"kind\": \"drop\", \"src\": {src}, \"dst\": {dst}, \"phase\": {phase}, \"step\": {step}}}"
                    ));
                }
                FaultEvent::DelayMsg { src, dst, phase, step, sim_ms } => {
                    s.push_str(&format!(
                        "{{\"kind\": \"delay\", \"src\": {src}, \"dst\": {dst}, \"phase\": {phase}, \"step\": {step}, \"sim_ms\": {sim_ms}}}"
                    ));
                }
            }
        }
        s.push_str("]\n}\n");
        s
    }

    /// Parse a manifest document. Strict: unknown or missing keys,
    /// wrong types, and unsupported schema versions are errors (a typo
    /// in a hand-edited manifest must not silently fall back to a
    /// default — the same contract the CLI's unknown-flag check gives).
    pub fn parse(text: &str) -> Result<RunManifest> {
        let doc = Json::parse(text).context("parsing run manifest")?;
        let fields = doc.fields().context("run manifest must be a JSON object")?;
        const KNOWN: &[&str] = &[
            "splitbrain_manifest", "workers", "mp", "steps", "lr", "momentum", "clip_norm",
            "avg_period", "seed", "dataset_size", "scheme", "engine", "collectives",
            "recovery", "overlap", "segmented_mp1", "take_timeout_ms", "net", "faults",
        ];
        for (key, _) in fields {
            if !KNOWN.contains(&key.as_str()) {
                bail!("run manifest: unknown key {key:?}");
            }
        }
        let version = req_u64(&doc, "splitbrain_manifest")?;
        if version != MANIFEST_VERSION {
            bail!("run manifest: schema version {version} (this build reads {MANIFEST_VERSION})");
        }
        let net_doc = doc.get("net").context("run manifest: missing key \"net\"")?;
        let net_fields = net_doc.fields().context("run manifest: \"net\" must be an object")?;
        for (key, _) in net_fields {
            if !["alpha", "beta", "phase_overhead"].contains(&key.as_str()) {
                bail!("run manifest: unknown net key {key:?}");
            }
        }
        let net = NetModel {
            alpha: req_f64(net_doc, "alpha")?,
            beta: req_f64(net_doc, "beta")?,
            phase_overhead: req_f64(net_doc, "phase_overhead")?,
        };
        let faults_doc = doc.get("faults").context("run manifest: missing key \"faults\"")?;
        let mut faults = FaultPlan::new();
        for (i, ev) in faults_doc
            .as_array()
            .context("run manifest: \"faults\" must be an array")?
            .iter()
            .enumerate()
        {
            let kind = ev
                .get("kind")
                .and_then(Json::as_str)
                .with_context(|| format!("fault event {i}: missing \"kind\""))?;
            let num = |key: &str| -> Result<usize> {
                ev.get(key)
                    .and_then(Json::as_usize)
                    .with_context(|| format!("fault event {i} ({kind}): missing/bad \"{key}\""))
            };
            let num64 = |key: &str| -> Result<u64> {
                ev.get(key)
                    .and_then(Json::as_u64)
                    .with_context(|| format!("fault event {i} ({kind}): missing/bad \"{key}\""))
            };
            faults = match kind {
                "crash" => faults.crash(num("rank")?, num("step")?),
                "straggle" => faults.straggle(num("rank")?, num("step")?, num64("sim_ms")?),
                "drop" => faults.drop_msg(
                    num("src")?,
                    num("dst")?,
                    u16::try_from(num("phase")?)
                        .map_err(|_| anyhow::anyhow!("fault event {i}: phase exceeds u16"))?,
                    num("step")?,
                ),
                "delay" => faults.delay_msg(
                    num("src")?,
                    num("dst")?,
                    u16::try_from(num("phase")?)
                        .map_err(|_| anyhow::anyhow!("fault event {i}: phase exceeds u16"))?,
                    num("step")?,
                    num64("sim_ms")?,
                ),
                other => bail!("fault event {i}: unknown kind {other:?}"),
            };
        }
        Ok(RunManifest {
            workers: req_usize(&doc, "workers")?,
            mp: req_usize(&doc, "mp")?,
            steps: req_usize(&doc, "steps")?,
            lr: req_f32(&doc, "lr")?,
            momentum: req_f32(&doc, "momentum")?,
            clip_norm: req_f32(&doc, "clip_norm")?,
            avg_period: req_usize(&doc, "avg_period")?,
            seed: req_u64(&doc, "seed")?,
            dataset_size: req_usize(&doc, "dataset_size")?,
            scheme: McastScheme::parse(req_str(&doc, "scheme")?)?,
            engine: ExecEngine::parse(req_str(&doc, "engine")?)?,
            collectives: CollectiveAlgo::parse(req_str(&doc, "collectives")?)?,
            recovery: RecoveryPolicy::parse(req_str(&doc, "recovery")?)?,
            overlap: req_bool(&doc, "overlap")?,
            segmented_mp1: req_bool(&doc, "segmented_mp1")?,
            take_timeout_ms: req_u64(&doc, "take_timeout_ms")?,
            net,
            faults,
        })
    }

    /// Deterministic FNV-1a fingerprint of the canonical JSON text.
    ///
    /// This is the value the TCP transport's Hello handshake exchanges:
    /// every worker derives it from its own manifest, so a worker whose
    /// manifest differs from the leader's (stale file, re-encoded
    /// flags, wrong launch) fails mesh bring-up with a typed handshake
    /// error instead of training a subtly different run.
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in self.to_json().as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x1_0000_01b3);
        }
        h
    }
}

fn req<'a>(doc: &'a Json, key: &str) -> Result<&'a Json> {
    doc.get(key)
        .with_context(|| format!("run manifest: missing key {key:?}"))
}

fn req_usize(doc: &Json, key: &str) -> Result<usize> {
    req(doc, key)?
        .as_usize()
        .with_context(|| format!("run manifest: {key:?} must be an unsigned integer"))
}

fn req_u64(doc: &Json, key: &str) -> Result<u64> {
    req(doc, key)?
        .as_u64()
        .with_context(|| format!("run manifest: {key:?} must be an unsigned integer"))
}

fn req_f32(doc: &Json, key: &str) -> Result<f32> {
    req(doc, key)?
        .as_f32()
        .with_context(|| format!("run manifest: {key:?} must be a number"))
}

fn req_f64(doc: &Json, key: &str) -> Result<f64> {
    req(doc, key)?
        .as_f64()
        .with_context(|| format!("run manifest: {key:?} must be a number"))
}

fn req_bool(doc: &Json, key: &str) -> Result<bool> {
    req(doc, key)?
        .as_bool()
        .with_context(|| format!("run manifest: {key:?} must be a boolean"))
}

fn req_str<'a>(doc: &'a Json, key: &str) -> Result<&'a str> {
    req(doc, key)?
        .as_str()
        .with_context(|| format!("run manifest: {key:?} must be a string"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunManifest {
        let cfg = crate::api::SessionBuilder::new()
            .workers(4)
            .mp(2)
            .steps(10)
            .faults(FaultPlan::new().crash(1, 3).straggle(0, 2, 250).drop_msg(0, 1, 1, 4))
            .cluster_config()
            .unwrap();
        RunManifest::from_config(&cfg, 10)
    }

    #[test]
    fn round_trip_is_byte_identical() {
        let m = sample();
        let text = m.to_json();
        let reparsed = RunManifest::parse(&text).unwrap();
        assert_eq!(reparsed, m);
        assert_eq!(reparsed.to_json(), text);
    }

    #[test]
    fn fingerprint_tracks_content() {
        let m = sample();
        let mut other = sample();
        assert_eq!(m.fingerprint(), other.fingerprint());
        other.seed += 1;
        assert_ne!(m.fingerprint(), other.fingerprint());
    }

    #[test]
    fn unknown_and_missing_keys_are_errors() {
        let mut text = sample().to_json();
        text = text.replace("\"workers\"", "\"wrokers\"");
        assert!(RunManifest::parse(&text).is_err(), "typoed key must not fall back");

        let bad_version = sample().to_json().replace(
            "\"splitbrain_manifest\": 1",
            "\"splitbrain_manifest\": 99",
        );
        assert!(RunManifest::parse(&bad_version).is_err());
    }

    #[test]
    fn config_round_trips_through_manifest() {
        let m = sample();
        let cfg = m.to_config();
        let back = RunManifest::from_config(&cfg, m.steps);
        assert_eq!(back, m);
    }
}
