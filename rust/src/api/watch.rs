//! Watch a run from outside its process: typed status + liveness.
//!
//! A [`Watcher`] opens a run directory **read-only** (it never creates
//! files, sweeps litter, or takes locks — safe to point at a live
//! writer's dir, or at a blessed fixture), tail-follows `events.log`
//! via [`LogFollower`], and folds every replayed [`LogRecord`] into a
//! [`RunStatus`] snapshot: steps done, the loss-curve tail,
//! throughput, byte counters, membership transitions, and the
//! checkpoint / resume lineage. [`Watcher::liveness`] classifies the
//! run as [`Running`](Liveness::Running) /
//! [`Completed`](Liveness::Completed) /
//! [`Stalled`](Liveness::Stalled) / [`Dead`](Liveness::Dead) from pid
//! files plus append-frontier staleness — see
//! [`liveness_at`](Watcher::liveness_at) for the exact rules.
//!
//! This is the library half of `splitbrain watch`; the CLI is a thin
//! render loop over it.

use std::path::{Path, PathBuf};
use std::time::{Duration, SystemTime};

use super::events::{RunInfo, RunSummary, StepReport};
use crate::store::{FollowPoll, LogFollower, LogRecord, StoreError};

/// How many recent [`StepReport`]s [`RunStatus`] retains — enough for
/// a loss-curve tail and a windowed throughput estimate without
/// unbounded growth on long runs.
pub const STATUS_TAIL_LEN: usize = 32;

/// Default staleness after which a run with no confirmed-dead pids is
/// reported [`Stalled`](Liveness::Stalled).
pub const DEFAULT_STALL_AFTER: Duration = Duration::from_secs(10);

/// Default staleness after which even an apparently-alive pid is
/// distrusted (pid recycling) and the run is reported
/// [`Dead`](Liveness::Dead).
pub const DEFAULT_DEAD_AFTER: Duration = Duration::from_secs(120);

/// Liveness classification of a watched run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Liveness {
    /// The frontier is fresh and nothing says the workers are gone.
    Running,
    /// The log ends in a `RunCompleted` summary — terminal.
    Completed,
    /// No progress for at least the stall threshold, but the workers
    /// are not confirmed dead (slow step, long collective, debugger…).
    Stalled,
    /// Every recorded worker pid is confirmed gone, or the frontier
    /// has been stale past the dead threshold (an "alive" pid that old
    /// is distrusted as recycled). Resume with `--resume`.
    Dead,
}

impl std::fmt::Display for Liveness {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Liveness::Running => "running",
            Liveness::Completed => "completed",
            Liveness::Stalled => "stalled",
            Liveness::Dead => "dead",
        })
    }
}

/// Snapshot of a serving frontend's `serve_status.json` — the status
/// surface `splitbrain serve` refreshes in its run dir and `splitbrain
/// watch` renders instead of misreading a quiet (no training events)
/// server as stalled.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeStatus {
    /// MP group size of every replica.
    pub mp: usize,
    /// Replicas spawned.
    pub replicas: usize,
    /// Replicas still alive.
    pub replicas_live: usize,
    /// Predict requests accepted off sockets.
    pub received: u64,
    /// Logits replies sent.
    pub replied: u64,
    /// Typed rejections, all reasons summed.
    pub rejected: u64,
    /// Forward steps served.
    pub batches: u64,
    /// Requests dispatched and not yet replied.
    pub inflight: u64,
    /// Seconds since the frontend started.
    pub uptime_secs: f64,
    /// Replies per second of uptime.
    pub reqs_per_sec: f64,
}

impl ServeStatus {
    /// Parse the `serve_status.json` schema written by
    /// [`ServeStats::to_json`](crate::serve::ServeStats::to_json).
    pub fn parse(text: &str) -> anyhow::Result<ServeStatus> {
        use crate::util::json::Json;
        let doc = Json::parse(text)?;
        let num = |k: &str| doc.get(k).and_then(|v| v.as_u64()).unwrap_or(0);
        let f = |k: &str| doc.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
        if doc.get("serving").and_then(|v| v.as_bool()) != Some(true) {
            anyhow::bail!("not a serve_status.json document");
        }
        Ok(ServeStatus {
            mp: num("mp") as usize,
            replicas: num("replicas") as usize,
            replicas_live: num("replicas_live") as usize,
            received: num("received"),
            replied: num("replied"),
            rejected: num("rejected_queue") + num("rejected_deadline") + num("rejected_draining"),
            batches: num("batches"),
            inflight: num("inflight"),
            uptime_secs: f("uptime_secs"),
            reqs_per_sec: f("reqs_per_sec"),
        })
    }
}

/// Typed fold of a run's event log: everything a progress view needs,
/// rebuilt incrementally (or from scratch after a resume rewrites
/// history).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RunStatus {
    /// The run's configuration header, once a `RunStarted` is seen.
    /// Resumed incarnations re-emit it, so this reflects the newest.
    pub run: Option<RunInfo>,
    /// Highest step with a completed `Step` record (or claimed by the
    /// final summary).
    pub steps_done: usize,
    /// Planned total steps from the `RunStarted` header (0 until seen).
    pub steps_planned: usize,
    /// The last [`STATUS_TAIL_LEN`] step reports, oldest first — the
    /// loss-curve tail and throughput window.
    pub tail: Vec<StepReport>,
    /// Sum of per-step busiest-rank comm bytes over the whole log.
    pub bytes_busiest: u64,
    /// Sum of per-step total comm bytes over the whole log.
    pub bytes_total: u64,
    /// Current worker count (tracks `Recovered` membership changes).
    pub n_workers: usize,
    /// Current model-parallel width (tracks `Recovered` re-plans).
    pub mp: usize,
    /// Elastic recoveries observed so far.
    pub recoveries: usize,
    /// Ranks lost across all recoveries, in event order.
    pub lost_ranks: Vec<usize>,
    /// Checkpoint lineage: `(step, file)` per `Checkpoint` record.
    pub checkpoints: Vec<(u64, String)>,
    /// Resume lineage: the boundary step of every `Resumed` marker.
    pub resumes: Vec<u64>,
    /// The final summary, once a `RunCompleted` is seen.
    pub summary: Option<RunSummary>,
    /// Total records folded in (across the whole log, post-reset).
    pub records: usize,
    /// Settled corruption at the frontier, stringified — the follower
    /// refuses to decode past it (cleared if a resume rewrites it).
    pub corrupt: Option<String>,
}

impl RunStatus {
    /// Fold one log record into the snapshot.
    pub fn apply(&mut self, rec: &LogRecord) {
        self.records += 1;
        match rec {
            LogRecord::RunStarted(i) => {
                self.steps_planned = i.steps;
                self.n_workers = i.n_workers;
                self.mp = i.mp;
                self.run = Some(i.clone());
            }
            LogRecord::Step(r) => {
                self.steps_done = self.steps_done.max(r.step);
                self.bytes_busiest += r.bytes_busiest_rank;
                self.bytes_total += r.bytes_total;
                self.tail.push(r.clone());
                if self.tail.len() > STATUS_TAIL_LEN {
                    self.tail.remove(0);
                }
            }
            LogRecord::Recovered(r) => {
                self.recoveries += 1;
                self.lost_ranks.extend_from_slice(&r.lost_ranks);
                self.n_workers = r.n_workers;
                self.mp = r.mp;
            }
            LogRecord::RunCompleted(s) => {
                self.steps_done = self.steps_done.max(s.steps);
                self.recoveries = self.recoveries.max(s.recoveries);
                self.n_workers = s.n_workers;
                self.mp = s.mp;
                self.summary = Some(s.clone());
            }
            LogRecord::Checkpoint { step, file, .. } => {
                self.checkpoints.push((*step, file.clone()));
            }
            LogRecord::Resumed { step } => self.resumes.push(*step),
        }
    }

    /// Fold a whole record slice (fresh snapshot).
    pub fn from_records(records: &[LogRecord]) -> RunStatus {
        let mut st = RunStatus::default();
        for r in records {
            st.apply(r);
        }
        st
    }

    /// Wall-clock throughput over the retained tail:
    /// `batch × launch workers × tail steps / Σ wall_secs`. `None`
    /// before the header or the first step, or when wall time is zero.
    pub fn images_per_sec_wall(&self) -> Option<f64> {
        let run = self.run.as_ref()?;
        let wall: f64 = self.tail.iter().map(|r| r.wall_secs).sum();
        if wall <= 0.0 || self.tail.is_empty() {
            return None;
        }
        Some((run.batch * run.n_workers * self.tail.len()) as f64 / wall)
    }

    /// Step of the newest checkpoint record, if any.
    pub fn latest_checkpoint_step(&self) -> Option<u64> {
        self.checkpoints.last().map(|(s, _)| *s)
    }
}

/// What changed in one [`Watcher::poll`].
#[derive(Debug, Clone, Copy)]
pub struct WatchDelta {
    /// Records folded into the status this poll.
    pub new_records: usize,
    /// True when the log's history was rewritten (resume cut) and the
    /// status was rebuilt from scratch.
    pub reset: bool,
    /// Byte offset of the decode frontier after this poll.
    pub frontier: u64,
}

/// A read-only observer of one run directory. See the
/// [module docs](self) for the overall shape.
///
/// ```no_run
/// use splitbrain::api::{Liveness, Watcher};
///
/// let mut w = Watcher::open("runs/exp-1").unwrap();
/// loop {
///     w.poll().unwrap();
///     let st = w.status();
///     println!("step {}/{}", st.steps_done, st.steps_planned);
///     match w.liveness() {
///         Liveness::Completed | Liveness::Dead => break,
///         _ => std::thread::sleep(std::time::Duration::from_millis(500)),
///     }
/// }
/// ```
#[derive(Debug)]
pub struct Watcher {
    root: PathBuf,
    follower: LogFollower,
    status: RunStatus,
    stall_after: Duration,
    dead_after: Duration,
}

impl Watcher {
    /// Open `dir` for watching. Unlike
    /// [`RunDir::open`](crate::store::RunDir::open) this creates and
    /// sweeps **nothing** (a watcher must be able to observe a dir it
    /// does not own, including a blessed read-only fixture); it only
    /// requires the directory to exist and to contain an `events.log`
    /// or a `run.json`.
    pub fn open(dir: impl AsRef<Path>) -> Result<Watcher, StoreError> {
        let root = dir.as_ref();
        if !root.is_dir()
            || (!root.join("events.log").is_file() && !root.join("run.json").is_file())
        {
            return Err(StoreError::NotARunDir(root.display().to_string()));
        }
        Ok(Watcher {
            root: root.to_path_buf(),
            follower: LogFollower::new(root.join("events.log")),
            status: RunStatus::default(),
            stall_after: DEFAULT_STALL_AFTER,
            dead_after: DEFAULT_DEAD_AFTER,
        })
    }

    /// Replace the stall threshold (default [`DEFAULT_STALL_AFTER`]).
    pub fn with_stall_after(mut self, d: Duration) -> Watcher {
        self.stall_after = d;
        self
    }

    /// Replace the dead threshold (default [`DEFAULT_DEAD_AFTER`]).
    pub fn with_dead_after(mut self, d: Duration) -> Watcher {
        self.dead_after = d;
        self
    }

    /// The watched directory.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// The run's per-op metrics snapshot, when the run is traced
    /// (`--trace` / [`SessionBuilder::trace`]): the merged
    /// `metrics.json` when present, otherwise a merge of whatever
    /// launch-engine `metrics-opid<R>.json` files have landed so far
    /// (the canonical merge is only written once every worker exits).
    /// Read-only like every other watcher access. `Ok(None)` means the
    /// run is untraced or no boundary snapshot has landed yet; a
    /// per-opid file torn by a concurrent writer is skipped, not an
    /// error.
    ///
    /// [`SessionBuilder::trace`]: super::SessionBuilder::trace
    pub fn metrics(&self) -> anyhow::Result<Option<crate::obs::Metrics>> {
        let canonical = self.root.join("metrics.json");
        if canonical.is_file() {
            let text = std::fs::read_to_string(&canonical)
                .map_err(|e| StoreError::io(&canonical, "read", e))?;
            return Ok(Some(crate::obs::Metrics::parse(&text)?));
        }
        let mut paths: Vec<PathBuf> = Vec::new();
        if let Ok(entries) = std::fs::read_dir(&self.root) {
            for e in entries.flatten() {
                let name = e.file_name();
                let Some(name) = name.to_str() else { continue };
                if name.starts_with("metrics-opid") && name.ends_with(".json") {
                    paths.push(e.path());
                }
            }
        }
        paths.sort();
        let parts: Vec<crate::obs::Metrics> = paths
            .iter()
            .filter_map(|p| std::fs::read_to_string(p).ok())
            .filter_map(|text| crate::obs::Metrics::parse(&text).ok())
            .collect();
        if parts.is_empty() {
            return Ok(None);
        }
        Ok(Some(crate::obs::Metrics::merge(&parts)))
    }

    /// Current folded snapshot (poll first to refresh it).
    pub fn status(&self) -> &RunStatus {
        &self.status
    }

    /// The serving frontend's status surface, when a `splitbrain
    /// serve` is (or was) pointed at this run dir: a parse of
    /// `serve_status.json`. `None` when the file is absent or torn —
    /// the writer publishes via rename, so torn reads are transient.
    pub fn serve_status(&self) -> Option<ServeStatus> {
        let text = std::fs::read_to_string(self.root.join("serve_status.json")).ok()?;
        ServeStatus::parse(&text).ok()
    }

    /// Follow the log's frontier: fold newly settled records into the
    /// status, rebuilding it from scratch when the follower detects a
    /// history rewrite (truncate-for-resume).
    pub fn poll(&mut self) -> Result<WatchDelta, StoreError> {
        let FollowPoll { records, reset, frontier, corrupt } = self.follower.poll()?;
        if reset {
            self.status = RunStatus::default();
        }
        for rec in &records {
            self.status.apply(rec);
        }
        self.status.corrupt = corrupt.map(|e| e.to_string());
        Ok(WatchDelta { new_records: records.len(), reset, frontier })
    }

    /// [`liveness_at`](Self::liveness_at) against the current clock.
    pub fn liveness(&self) -> Liveness {
        self.liveness_at(SystemTime::now())
    }

    /// Classify the run's liveness as of `now` (injectable for
    /// deterministic tests). The rules, in order:
    ///
    /// 1. A folded `RunCompleted` summary → [`Liveness::Completed`].
    /// 2. Pid files are present (`opid<R>.pid`, multi-process launches
    ///    only) and **every** recorded pid is confirmed gone →
    ///    [`Liveness::Dead`] immediately — clean exits remove their
    ///    pid files, so all-dead means SIGKILL. A *positive* pid check
    ///    is never trusted on its own: the pid may be recycled.
    /// 3. Otherwise staleness decides. Activity = newest mtime among
    ///    `events.log`, `run.json`, `serve_status.json` (a serving
    ///    frontend appends no training events, but refreshes its
    ///    status surface — without it an idle server would misread as
    ///    stalled), and any pid files; stale ≥ the dead threshold →
    ///    [`Liveness::Dead`], ≥ the stall threshold →
    ///    [`Liveness::Stalled`], else [`Liveness::Running`].
    ///
    /// On platforms with no `/proc` (pid liveness unknowable), rule 2
    /// is skipped and staleness alone decides.
    pub fn liveness_at(&self, now: SystemTime) -> Liveness {
        if self.status.summary.is_some() {
            return Liveness::Completed;
        }
        let pids = self.pid_files();
        let checks: Vec<Option<bool>> = pids.iter().map(|(p, _)| pid_alive(*p)).collect();
        if !checks.is_empty() && checks.iter().all(|c| *c == Some(false)) {
            return Liveness::Dead;
        }
        let mut newest: Option<SystemTime> = None;
        let mut consider = |t: Option<SystemTime>| {
            if let Some(t) = t {
                newest = Some(match newest {
                    Some(n) if n >= t => n,
                    _ => t,
                });
            }
        };
        consider(mtime(&self.root.join("events.log")));
        consider(mtime(&self.root.join("run.json")));
        consider(mtime(&self.root.join("serve_status.json")));
        for (_, m) in &pids {
            consider(Some(*m));
        }
        let Some(newest) = newest else {
            // Nothing on disk to date the run by — it never got far
            // enough to matter; report it dead rather than eternally
            // running.
            return Liveness::Dead;
        };
        let stale = now.duration_since(newest).unwrap_or(Duration::ZERO);
        if stale >= self.dead_after {
            Liveness::Dead
        } else if stale >= self.stall_after {
            Liveness::Stalled
        } else {
            Liveness::Running
        }
    }

    /// `(pid, pid-file mtime)` for every `opid<R>.pid` in the dir.
    fn pid_files(&self) -> Vec<(u32, SystemTime)> {
        let mut out = Vec::new();
        if let Ok(entries) = std::fs::read_dir(&self.root) {
            for e in entries.flatten() {
                let name = e.file_name();
                let Some(name) = name.to_str() else { continue };
                let Some(num) = name.strip_prefix("opid").and_then(|r| r.strip_suffix(".pid"))
                else {
                    continue;
                };
                if num.parse::<usize>().is_err() {
                    continue;
                }
                let Ok(text) = std::fs::read_to_string(e.path()) else { continue };
                let Ok(pid) = text.trim().parse::<u32>() else { continue };
                let mtime = e
                    .metadata()
                    .ok()
                    .and_then(|m| m.modified().ok())
                    .unwrap_or(SystemTime::UNIX_EPOCH);
                out.push((pid, mtime));
            }
        }
        out
    }
}

/// Whether `pid` is currently running — `None` when unknowable (no
/// `/proc` on this platform). A `Some(true)` still does not prove the
/// *worker* is alive (pid recycling), which is why the liveness rules
/// only ever act on confirmed death.
fn pid_alive(pid: u32) -> Option<bool> {
    let proc_dir = Path::new("/proc");
    if proc_dir.is_dir() {
        Some(proc_dir.join(pid.to_string()).is_dir())
    } else {
        None
    }
}

/// Modification time of `path`, if stat-able.
fn mtime(path: &Path) -> Option<SystemTime> {
    std::fs::metadata(path).ok().and_then(|m| m.modified().ok())
}
