//! The structured event stream — observers ([`EventSink`]) receive
//! typed run/step/recovery events instead of scraping stdout.
//!
//! [`ConsoleSink`] reproduces the historical `splitbrain train` output
//! **byte-for-byte** (pinned by the `api_session` suite), so the CLI is
//! just a session with a console sink attached; [`CollectSink`] buffers
//! events for programmatic consumers (the throughput bench derives
//! steps/sec from collected [`StepReport`]s rather than wall-clocking
//! around the whole run).

use std::cell::RefCell;
use std::io::Write;
use std::path::Path;
use std::rc::Rc;

use crate::comm::CollectiveAlgo;
use crate::coordinator::ExecEngine;
use crate::store::{LogRecord, LogWriter, StoreError};

/// Static facts about a run, emitted once before the first step.
#[derive(Debug, Clone, PartialEq)]
pub struct RunInfo {
    /// Total workers N.
    pub n_workers: usize,
    /// MP group size.
    pub mp: usize,
    /// Number of MP groups (N / mp).
    pub n_groups: usize,
    /// Per-worker batch size B.
    pub batch: usize,
    /// Steps the session plans to run.
    pub steps: usize,
    /// SGD learning rate.
    pub lr: f32,
    /// Model-averaging period.
    pub avg_period: usize,
    /// Execution engine.
    pub engine: ExecEngine,
    /// Collective algorithm.
    pub collectives: CollectiveAlgo,
    /// Overlapped execution (resolved).
    pub overlap: bool,
    /// Predicted per-worker parameter megabytes.
    pub param_mb: f64,
    /// Predicted per-worker total megabytes.
    pub total_mb: f64,
}

/// One completed training step: loss, per-phase timings, and the
/// data-plane byte counters — everything `Session::step` returns and
/// every sink observes.
#[derive(Debug, Clone, PartialEq)]
pub struct StepReport {
    /// 1-based step index (== `Session::steps_done` after the step).
    pub step: usize,
    /// Cluster-mean loss.
    pub loss: f64,
    /// Simulated compute seconds (max over workers — BSP critical path).
    pub compute_secs: f64,
    /// Simulated MP-communication seconds.
    pub mp_comm_secs: f64,
    /// Simulated averaging-communication seconds (0 off boundaries).
    pub dp_comm_secs: f64,
    /// Host wall-clock seconds the step actually took.
    pub wall_secs: f64,
    /// Data-plane bytes pushed by the busiest rank this step.
    pub bytes_busiest_rank: u64,
    /// Total data-plane bytes pushed this step.
    pub bytes_total: u64,
}

impl StepReport {
    /// Simulated step seconds (compute + MP comm + averaging comm).
    pub fn step_secs(&self) -> f64 {
        self.compute_secs + self.mp_comm_secs + self.dp_comm_secs
    }
}

/// An elastic shrink-and-continue recovery transition.
#[derive(Debug, Clone, PartialEq)]
pub struct RecoveryInfo {
    /// The step whose retry completed on the shrunk cluster.
    pub step: usize,
    /// Ranks lost in this recovery (numbered in the incarnation they
    /// died in).
    pub lost_ranks: Vec<usize>,
    /// Surviving worker count after the shrink.
    pub n_workers: usize,
    /// MP group size after re-planning.
    pub mp: usize,
    /// Step of the checkpoint the survivors restored from.
    pub restore_step: usize,
}

/// End-of-run roll-up, emitted by `Session::run`.
#[derive(Debug, Clone, PartialEq)]
pub struct RunSummary {
    /// Steps completed.
    pub steps: usize,
    /// Simulated-cluster throughput.
    pub images_per_sec: f64,
    /// Fraction of simulated step time spent communicating.
    pub comm_fraction: f64,
    /// Elastic recoveries performed.
    pub recoveries: usize,
    /// All ranks lost over the run, in detection order.
    pub lost_ranks: Vec<usize>,
    /// Final worker count.
    pub n_workers: usize,
    /// Final MP group size.
    pub mp: usize,
    /// Step of the last in-memory restore point.
    pub last_checkpoint_step: usize,
}

/// One observation from a training session.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// Emitted once, before the first step's work.
    RunStarted(RunInfo),
    /// Emitted after every completed step.
    StepCompleted(StepReport),
    /// Emitted when an elastic recovery re-planned the cluster.
    Recovered(RecoveryInfo),
    /// Emitted by `Session::run` after the last step.
    RunCompleted(RunSummary),
}

/// A session observer. Attach with
/// [`Session::attach`](super::Session::attach); every event is
/// delivered to every sink, in attach order.
///
/// # Examples
///
/// A sink that tracks the best (lowest) loss seen:
///
/// ```
/// use splitbrain::api::{Event, EventSink, StepReport};
///
/// struct BestLoss(f64);
/// impl EventSink for BestLoss {
///     fn on_event(&mut self, event: &Event) {
///         if let Event::StepCompleted(step) = event {
///             self.0 = self.0.min(step.loss);
///         }
///     }
/// }
///
/// let mut sink = BestLoss(f64::INFINITY);
/// sink.on_event(&Event::StepCompleted(StepReport {
///     step: 1, loss: 2.3, compute_secs: 0.018, mp_comm_secs: 0.004,
///     dp_comm_secs: 0.0, wall_secs: 0.025, bytes_busiest_rank: 147_456,
///     bytes_total: 589_824,
/// }));
/// assert_eq!(sink.0, 2.3);
/// ```
///
/// Events are per-*step* granularity. For per-*op* granularity — one
/// span per executed step-program op, Chrome-trace export, per-phase
/// byte/time breakdowns — use the [`crate::obs`] tracing layer
/// ([`SessionBuilder::trace`](super::SessionBuilder::trace)) instead
/// of deriving it from step events.
pub trait EventSink {
    /// Observe one event.
    fn on_event(&mut self, event: &Event);
}

/// The CLI's sink: renders events exactly like the pre-API
/// `splitbrain train` loop printed them (same format strings, same
/// blank lines — the `api_session` suite pins the bytes).
pub struct ConsoleSink {
    log_every: usize,
    steps: usize,
    out: Box<dyn Write>,
}

impl ConsoleSink {
    /// Log to stdout, printing every `log_every`-th step (and the
    /// last). `log_every` is clamped to ≥ 1.
    pub fn new(log_every: usize) -> ConsoleSink {
        Self::with_writer(log_every, Box::new(std::io::stdout()))
    }

    /// Log into an arbitrary writer (tests capture the byte stream).
    pub fn with_writer(log_every: usize, out: Box<dyn Write>) -> ConsoleSink {
        ConsoleSink { log_every: log_every.max(1), steps: 0, out }
    }
}

impl EventSink for ConsoleSink {
    fn on_event(&mut self, event: &Event) {
        // Console logging is best-effort: a closed pipe must not take
        // the training run down with it.
        let _ = match event {
            Event::RunStarted(i) => {
                self.steps = i.steps;
                writeln!(
                    self.out,
                    "SplitBrain: {} workers, mp={} ({} groups), B={}, lr={}, avg_period={}, engine={}, collectives={}, overlap={}",
                    i.n_workers,
                    i.mp,
                    i.n_groups,
                    i.batch,
                    i.lr,
                    i.avg_period,
                    i.engine,
                    i.collectives,
                    i.overlap
                )
                .and_then(|()| {
                    writeln!(
                        self.out,
                        "per-worker memory: {:.2} MB params, {:.2} MB total\n",
                        i.param_mb, i.total_mb
                    )
                })
            }
            Event::StepCompleted(r) => {
                if r.step % self.log_every == 0 || r.step == self.steps {
                    writeln!(
                        self.out,
                        "step {:>4}  loss {:.4}  compute {:.1} ms  mp-comm {:.2} ms  step {:.1} ms",
                        r.step,
                        r.loss,
                        r.compute_secs * 1e3,
                        r.mp_comm_secs * 1e3,
                        r.step_secs() * 1e3
                    )
                } else {
                    Ok(())
                }
            }
            // The historical CLI reported recoveries only in the final
            // summary; staying byte-identical means staying quiet here.
            Event::Recovered(_) => Ok(()),
            Event::RunCompleted(s) => {
                let recov = if s.recoveries > 0 {
                    writeln!(
                        self.out,
                        "\nelastic recoveries: {} (ranks lost: {:?}) — now {} workers, mp={}, \
                         last restore point step {}",
                        s.recoveries, s.lost_ranks, s.n_workers, s.mp, s.last_checkpoint_step
                    )
                } else {
                    Ok(())
                };
                recov.and_then(|()| {
                    writeln!(
                        self.out,
                        "\nthroughput: {:.2} images/sec (simulated cluster)  comm fraction {:.1}%",
                        s.images_per_sec,
                        s.comm_fraction * 100.0
                    )
                })
            }
        };
    }
}

/// A sink that buffers every event for later inspection (benches and
/// tests read the stream after the run).
///
/// # Examples
///
/// ```
/// use splitbrain::api::{CollectSink, Event, EventSink};
///
/// let mut sink = CollectSink::new();
/// let events = sink.events();
/// sink.on_event(&Event::Recovered(splitbrain::api::RecoveryInfo {
///     step: 3, lost_ranks: vec![1], n_workers: 3, mp: 1, restore_step: 2,
/// }));
/// assert_eq!(events.borrow().len(), 1);
/// ```
#[derive(Default)]
pub struct CollectSink {
    events: Rc<RefCell<Vec<Event>>>,
}

impl CollectSink {
    /// An empty collector.
    pub fn new() -> CollectSink {
        CollectSink::default()
    }

    /// Shared handle to the buffered events (clone it out before
    /// moving the sink into a session).
    pub fn events(&self) -> Rc<RefCell<Vec<Event>>> {
        self.events.clone()
    }
}

impl EventSink for CollectSink {
    fn on_event(&mut self, event: &Event) {
        self.events.borrow_mut().push(event.clone());
    }
}

/// A sink that mirrors every event into an append-only, CRC-framed,
/// fsync'd on-disk log (the [`crate::store::log`] format — replayable
/// with [`crate::store::replay`]).
///
/// This is the *observer* form of durable logging: attach it to any
/// session to get a replayable event history at a path of your
/// choosing. Sessions started with a run dir
/// ([`SessionBuilder::run_dir`](super::SessionBuilder::run_dir))
/// already write `events.log` themselves — with checkpoint and resume
/// lineage records a plain sink never sees — so a `DiskSink` is for
/// logging *outside* a run dir.
///
/// [`EventSink::on_event`] is infallible by design (observability must
/// not take training down), so I/O errors are latched: the first
/// failure warns once on stderr, stops further writes, and stays
/// readable via [`error`](DiskSink::error) — or, after the sink has
/// been moved into a session, via the shared
/// [`error_handle`](DiskSink::error_handle). When the run completes
/// with a latched error, a final stderr note flags the log as
/// incomplete, so a failed sink is never *silent*.
///
/// # Examples
///
/// ```no_run
/// use splitbrain::api::DiskSink;
///
/// let sink = DiskSink::create("events.log").unwrap();
/// let errors = sink.error_handle(); // survives the attach below
/// // session.attach(Box::new(sink));
/// // ... after session.run():
/// if let Some(e) = errors.borrow().as_ref() {
///     eprintln!("event log is incomplete: {e}");
/// }
/// ```
pub struct DiskSink {
    writer: Option<LogWriter>,
    error: Rc<RefCell<Option<StoreError>>>,
}

impl DiskSink {
    /// Create (or truncate) the log at `path`.
    pub fn create(path: impl AsRef<Path>) -> Result<DiskSink, StoreError> {
        Ok(DiskSink { writer: Some(LogWriter::create(path)?), error: Rc::new(RefCell::new(None)) })
    }

    /// The first write error, if any. Once set, no further records are
    /// written (the log ends at the last durable record, which replay
    /// handles like any other clean prefix).
    pub fn error(&self) -> Option<StoreError> {
        self.error.borrow().clone()
    }

    /// Shared handle to the latched error — clone it out *before*
    /// moving the sink into [`Session::attach`](super::Session::attach)
    /// (the [`CollectSink::events`] pattern), then inspect it alongside
    /// the run summary.
    pub fn error_handle(&self) -> Rc<RefCell<Option<StoreError>>> {
        Rc::clone(&self.error)
    }
}

impl EventSink for DiskSink {
    fn on_event(&mut self, event: &Event) {
        if let Some(w) = &mut self.writer {
            if let Err(e) = w.append(&LogRecord::from_event(event)) {
                eprintln!(
                    "warning: event log sink failed ({e}); later events will not be persisted"
                );
                *self.error.borrow_mut() = Some(e);
                self.writer = None;
            }
        } else if matches!(event, Event::RunCompleted(_)) {
            if let Some(e) = self.error.borrow().as_ref() {
                eprintln!(
                    "warning: the persisted event log is incomplete — its sink failed earlier: {e}"
                );
            }
        }
    }
}

/// Extract the step reports from a collected event stream (the common
/// consumer shape: `session.run()` then analyze per-step data).
pub fn step_reports(events: &[Event]) -> Vec<StepReport> {
    events
        .iter()
        .filter_map(|e| match e {
            Event::StepCompleted(r) => Some(r.clone()),
            _ => None,
        })
        .collect()
}
