//! One generator per paper table/figure (§5, Table 1/2, Fig. 7a/b/c),
//! plus the collective-algorithm comparison backing the Fig. 7b
//! overhead discussion.

use anyhow::Result;

use crate::comm::CollectiveAlgo;
use crate::coordinator::{
    calibrated_report, Cluster, ClusterConfig, GmpTopology, McastScheme, StepSchedule,
};
use crate::model::vgg;
use crate::runtime::RuntimeClient;
use crate::train::TrainReport;
use crate::util::Table;

/// How a configuration is costed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fidelity {
    /// Full numeric training steps (real gradients). Slow at large N.
    Numeric { steps: usize },
    /// Per-artifact calibration + analytic composition (default for
    /// sweeps; identical compute/comm model, no training state).
    Calibrated,
}

/// Run one (machines, mp) configuration.
pub fn run_config(
    rt: &RuntimeClient,
    n_workers: usize,
    mp: usize,
    fidelity: Fidelity,
    cfg_base: &ClusterConfig,
) -> Result<TrainReport> {
    // Segmented mp=1 baseline: identical per-op efficiency across the
    // DP/MP comparison (see StepSchedule::compile_opts). The base
    // config comes from the caller's SessionBuilder; only the swept
    // shape is overridden here.
    let mut cfg = cfg_base.clone();
    cfg.n_workers = n_workers;
    cfg.mp = mp;
    cfg.segmented_mp1 = true;
    match fidelity {
        Fidelity::Numeric { steps } => {
            // Timing fidelity: per-worker compute must be measured
            // contention-free (the simulated clock takes max over
            // workers). The threaded engine overlaps N workers on this
            // host's cores, which would inflate compute_secs with N —
            // numerics are identical either way.
            cfg.engine = crate::coordinator::ExecEngine::Sequential;
            let mut cluster = Cluster::new(rt, cfg)?;
            cluster.train_steps(steps)
        }
        Fidelity::Calibrated => calibrated_report(rt, &cfg, 3),
    }
}

/// Table 1: layer-wise parameters of the VGG variant.
pub fn table1() -> Table {
    let rows = vgg::table1();
    let total_w: usize = rows.iter().map(|r| r.2).sum();
    // The paper's 24.83 / 75.17 split is computed over parameters
    // *including biases* (1,735,488 conv vs 5,255,178 FC of 6,990,666),
    // while the per-row counts are weights only — reproduce both.
    let conv_w: usize = rows.iter().filter(|r| r.0.starts_with("Conv")).map(|r| r.2).sum();
    let conv_p = conv_w + 1152; // + conv biases
    let fc_p = (total_w - conv_w) + 2058; // + fc biases
    let total_p = conv_p + fc_p;
    let mut t = Table::new(vec!["Layer", "I/O Dimension", "Parameters", "%"]);
    for (name, io, params) in &rows {
        let pct = if name == "Conv3" {
            format!("{:.2}", conv_p as f64 / total_p as f64 * 100.0)
        } else if name == "FC1" {
            format!("{:.2}", fc_p as f64 / total_p as f64 * 100.0)
        } else {
            String::new()
        };
        t.row(vec![name.clone(), io.clone(), params.to_string(), pct]);
    }
    t.row(vec![
        "Total".to_string(),
        String::new(),
        total_w.to_string(),
        "100.00".to_string(),
    ]);
    t
}

/// The (machines, dp, mp) rows of Table 2, in paper order.
pub fn table2_configs() -> Vec<(usize, usize, usize)> {
    vec![
        (1, 1, 1),
        (2, 2, 1),
        (2, 1, 2),
        (4, 4, 1),
        (4, 2, 2),
        (4, 1, 4),
        (8, 8, 1),
        (8, 4, 2),
        (8, 1, 8),
        (16, 16, 1),
        (16, 8, 2),
        (32, 8, 8),
        (32, 8, 4),
        (32, 16, 2),
        (32, 32, 1),
    ]
}

/// Paper Table 2 throughputs (images/sec) keyed by (machines, dp, mp),
/// used for shape comparison in EXPERIMENTS.md.
pub fn table2_paper() -> Vec<((usize, usize, usize), f64)> {
    vec![
        ((1, 1, 1), 121.99),
        ((2, 2, 1), 247.43),
        ((2, 1, 2), 235.72),
        ((4, 4, 1), 489.62),
        ((4, 2, 2), 470.1),
        ((4, 1, 4), 421.0),
        ((8, 8, 1), 965.92),
        ((8, 4, 2), 941.84),
        ((8, 1, 8), 520.0),
        ((16, 16, 1), 1946.99),
        ((16, 8, 2), 1863.5),
        ((32, 8, 8), 2062.84),
        ((32, 8, 4), 3293.68),
        ((32, 16, 2), 3695.64),
        ((32, 32, 1), 3896.27),
    ]
}

/// Table 2: throughput over machine counts and DP/MP combinations.
/// Returns (table, raw (machines, dp, mp, images/sec) rows).
pub fn table2(
    rt: &RuntimeClient,
    fidelity: Fidelity,
    base: &ClusterConfig,
) -> Result<(Table, Vec<(usize, usize, usize, f64)>)> {
    let paper: std::collections::HashMap<_, _> = table2_paper().into_iter().collect();
    let mut t = Table::new(vec![
        "Machines", "Dataset", "DP", "MP", "images/sec", "paper img/s", "speedup-vs-1", "paper-speedup",
    ]);
    let mut raw = Vec::new();
    let mut base1 = None;
    for (m, dp, mp) in table2_configs() {
        let rep = run_config(rt, m, mp, fidelity, base)?;
        let ips = rep.images_per_sec();
        if base1.is_none() {
            base1 = Some(ips);
        }
        let p = paper[&(m, dp, mp)];
        t.row(vec![
            m.to_string(),
            "CIFAR-10".to_string(),
            dp.to_string(),
            mp.to_string(),
            format!("{ips:.2}"),
            format!("{p:.2}"),
            format!("{:.2}x", ips / base1.unwrap()),
            format!("{:.2}x", p / 121.99),
        ]);
        raw.push((m, dp, mp, ips));
    }
    Ok((t, raw))
}

/// Fig. 7a: throughput scaling at mp=2 across machine counts.
pub fn fig7a(
    rt: &RuntimeClient,
    fidelity: Fidelity,
    base: &ClusterConfig,
) -> Result<(Table, Vec<(usize, f64)>)> {
    let mut t = Table::new(vec!["Machines", "MP", "images/sec", "speedup", "ideal"]);
    let mut raw = Vec::new();
    let mut first = None;
    for m in [2usize, 4, 8, 16, 32] {
        let rep = run_config(rt, m, 2, fidelity, base)?;
        let ips = rep.images_per_sec();
        if first.is_none() {
            first = Some(ips / m as f64);
        }
        let per1 = first.unwrap();
        t.row(vec![
            m.to_string(),
            "2".to_string(),
            format!("{ips:.2}"),
            format!("{:.2}x", ips / per1),
            format!("{m}.00x"),
        ]);
        raw.push((m, ips));
    }
    Ok((t, raw))
}

/// Fig. 7b: communication overhead vs MP group size on 8 machines.
pub fn fig7b(
    rt: &RuntimeClient,
    fidelity: Fidelity,
    base: &ClusterConfig,
) -> Result<(Table, Vec<(usize, f64, f64, f64)>)> {
    let mut t = Table::new(vec![
        "MP", "compute ms", "MP-comm ms", "DP-comm ms", "comm %", "images/sec",
    ]);
    let mut raw = Vec::new();
    for mp in [1usize, 2, 4, 8] {
        let rep = run_config(rt, 8, mp, fidelity, base)?;
        let comp = rep.compute.mean() * 1e3;
        let mpc = rep.mp_comm.mean() * 1e3;
        let dpc = rep.dp_comm.mean() * 1e3;
        t.row(vec![
            mp.to_string(),
            format!("{comp:.2}"),
            format!("{mpc:.3}"),
            format!("{dpc:.3}"),
            format!("{:.2}", rep.comm_fraction() * 100.0),
            format!("{:.2}", rep.images_per_sec()),
        ]);
        raw.push((mp, comp, mpc, dpc));
    }
    Ok((t, raw))
}

/// Fig. 7b companion: analytic communication comparison of the
/// collective algorithms (naive all-to-all vs ring vs recursive
/// halving/doubling) on an 8-machine cluster, per MP group size.
/// Returns (table, raw (mp, algo, mp_bytes, avg_bytes) rows).
pub fn fig7b_algos(
    rt: &RuntimeClient,
    base: &ClusterConfig,
) -> Result<(Table, Vec<(usize, CollectiveAlgo, u64, u64)>)> {
    use crate::model::{partition_network, vgg11, PartitionConfig};
    let mut t = Table::new(vec![
        "mp", "algo", "MP comm ms", "avg comm ms", "MP MB/rank", "avg MB/rank",
    ]);
    let mut raw = Vec::new();
    for mp in [1usize, 2, 4, 8] {
        let net = partition_network(
            &vgg11(),
            vec![32, 32, 3],
            &PartitionConfig { mp, ..Default::default() },
        )?;
        let topo = GmpTopology::new(8, mp)?;
        for algo in [CollectiveAlgo::Naive, CollectiveAlgo::Ring, CollectiveAlgo::Rhd] {
            let sched = StepSchedule::compile_with_algo(
                &net,
                topo,
                &rt.manifest,
                true,
                McastScheme::BoverK,
                algo,
            )?;
            let mp_bytes = sched.mp_bytes_per_member();
            let avg_bytes = sched.avg_bytes_per_member();
            t.row(vec![
                mp.to_string(),
                algo.to_string(),
                format!("{:.3}", sched.mp_comm_secs(&base.net) * 1e3),
                format!("{:.3}", sched.avg_comm_secs(&base.net) * 1e3),
                format!("{:.2}", mp_bytes as f64 / 1e6),
                format!("{:.2}", avg_bytes as f64 / 1e6),
            ]);
            raw.push((mp, algo, mp_bytes, avg_bytes));
        }
    }
    Ok((t, raw))
}

/// Fig. 7c: throughput vs per-worker parameter memory across mp.
pub fn fig7c(
    rt: &RuntimeClient,
    fidelity: Fidelity,
    base: &ClusterConfig,
) -> Result<(Table, Vec<(usize, f64, f64)>)> {
    use crate::model::{partition_network, vgg11, PartitionConfig};
    use crate::train::MemoryReport;
    let mut t = Table::new(vec![
        "MP", "param MB/worker", "memory saving %", "images/sec", "vs pure DP %",
    ]);
    let mut raw = Vec::new();
    let mut dp_ips = None;
    for mp in [1usize, 2, 4, 8] {
        let rep = run_config(rt, 8, mp, fidelity, base)?;
        let net = partition_network(
            &vgg11(),
            vec![32, 32, 3],
            &PartitionConfig { mp, ..Default::default() },
        )?;
        let mem = MemoryReport::of(&net, rt.manifest.batch);
        let ips = rep.images_per_sec();
        if dp_ips.is_none() {
            dp_ips = Some(ips);
        }
        let full_mb = MemoryReport::of(
            &partition_network(&vgg11(), vec![32, 32, 3], &PartitionConfig::default())?,
            rt.manifest.batch,
        )
        .param_mb();
        t.row(vec![
            mp.to_string(),
            format!("{:.2}", mem.param_mb()),
            format!("{:.1}", (1.0 - mem.param_mb() / full_mb) * 100.0),
            format!("{ips:.2}"),
            format!("{:.1}", ips / dp_ips.unwrap() * 100.0),
        ]);
        raw.push((mp, mem.param_mb(), ips));
    }
    Ok((t, raw))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper_total() {
        let t = table1();
        let s = t.render();
        assert!(s.contains("4194304"));
        assert!(s.contains("6987456"));
        assert!(s.contains("75.17"));
        assert!(s.contains("24.83"));
    }

    #[test]
    fn table2_paper_rows_complete() {
        assert_eq!(table2_paper().len(), table2_configs().len());
        for (cfg, _) in table2_paper() {
            assert!(table2_configs().contains(&cfg));
        }
    }

    #[test]
    fn table2_configs_consistent() {
        for (m, dp, mp) in table2_configs() {
            // The paper's Table 2 contains one anomalous row,
            // (32, DP=8, MP=8): 8*8 != 32. We reproduce the row as
            // printed (costing it as machines=32, mp=8 -> dp=4) but
            // don't pretend it's self-consistent.
            if (m, dp, mp) == (32, 8, 8) {
                continue;
            }
            assert_eq!(m, dp * mp, "({m},{dp},{mp})");
        }
    }
}
