//! Benchmark support shared by the `cargo bench` harnesses, the CLI
//! sweeps and the examples: one entry point per paper table/figure,
//! each returning both the printable table and the raw series.
//!
//! Absolute images/sec depend on this machine's XLA:CPU throughput, so
//! every harness also prints the *normalized* quantities the paper's
//! claims are about (speedup vs 1 machine, comm fractions, memory
//! ratios). See EXPERIMENTS.md for the recorded paper-vs-measured runs.

pub mod experiments;

pub use experiments::{
    fig7a, fig7b, fig7b_algos, fig7c, run_config, table1, table2, table2_configs, table2_paper,
    Fidelity,
};
