//! The shard layer L_S (§3.1, Fig. 5): restores full-width activations
//! from 1/K partitions in fprop and routes full-width gradients back to
//! partition owners in bprop.
//!
//! Two bprop modes exist because of where the shard sits:
//!
//! * [`ShardBwdMode::ReducePartials`] — the layers *above* are
//!   partitioned, so each member's full-width input gradient is a
//!   partial sum (e.g. `gx = gpre @ W_k^T` covers the full input but
//!   only this shard's contribution). Members must reduce-scatter
//!   (Fig. 5b: "gather the gradients corresponding to the local
//!   partition ... while scattering the other partitions").
//!
//! * [`ShardBwdMode::SliceReplicated`] — everything above the shard is
//!   *replicated* across the group (the CCR-rejected FC2 + softmax head
//!   of the VGG variant), so every member already holds the identical,
//!   complete gradient; the local partition is a zero-communication
//!   slice. Summing here would double-count by K.

use anyhow::Result;

use crate::comm::collective::{
    allgather_cols_algo, allgather_cols_rank, reduce_scatter_cols_algo, reduce_scatter_cols_rank,
    CollectiveAlgo,
};
use crate::comm::fabric::Tag;
use crate::comm::transport::Transport;
use crate::runtime::HostTensor;

/// How bprop recovers the local-partition gradient.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardBwdMode {
    /// Layers above are partitioned: reduce-scatter the partial sums.
    ReducePartials,
    /// Layers above are replicated: zero-communication local slice.
    SliceReplicated,
}

/// Compile-time facts of one shard layer for one MP group.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    /// Global ranks of the group, offset order.
    pub group: Vec<usize>,
    /// Partition width per member (equal shards).
    pub part_width: usize,
    /// Gradient-recovery mode for bprop.
    pub bwd_mode: ShardBwdMode,
    /// Collective algorithm moving the data (naive all-to-all or ring;
    /// total bytes are identical, the message schedule differs).
    pub algo: CollectiveAlgo,
}

impl ShardPlan {
    /// Build a plan with the naive (all-to-all) collectives.
    pub fn new(group: Vec<usize>, part_width: usize, bwd_mode: ShardBwdMode) -> ShardPlan {
        assert!(!group.is_empty());
        ShardPlan { group, part_width, bwd_mode, algo: CollectiveAlgo::Naive }
    }

    /// Select the collective algorithm (builder style).
    pub fn with_algo(mut self, algo: CollectiveAlgo) -> ShardPlan {
        self.algo = algo;
        self
    }

    /// K = group size.
    pub fn k(&self) -> usize {
        self.group.len()
    }

    /// Restored full feature width (`part_width · K`).
    pub fn full_width(&self) -> usize {
        self.part_width * self.k()
    }

    /// Wire bytes each member sends in fprop for a batch of `b` rows.
    pub fn fwd_bytes_per_member(&self, b: usize) -> u64 {
        ((self.k() - 1) * b * self.part_width * 4) as u64
    }

    /// Wire bytes each member sends in bprop.
    pub fn bwd_bytes_per_member(&self, b: usize) -> u64 {
        match self.bwd_mode {
            ShardBwdMode::ReducePartials => self.fwd_bytes_per_member(b),
            ShardBwdMode::SliceReplicated => 0,
        }
    }

    /// fprop: allgather `[B, part]` partitions into `[B, full]` per
    /// member (group order = partition order).
    pub fn gather_full(
        &self,
        fabric: &dyn Transport,
        parts: &[HostTensor],
        tag: Tag,
    ) -> Result<Vec<HostTensor>> {
        debug_assert!(parts.iter().all(|p| p.shape[1] == self.part_width));
        if self.k() == 1 {
            return Ok(parts.to_vec());
        }
        allgather_cols_algo(self.algo, fabric, &self.group, parts, tag)
    }

    /// Per-rank fprop (threaded engine): the member at group index `gi`
    /// contributes its `[B, part]` partition, blocking-takes the rest.
    pub fn gather_full_rank(
        &self,
        fabric: &dyn Transport,
        gi: usize,
        part: &HostTensor,
        tag: Tag,
    ) -> Result<HostTensor> {
        if self.k() == 1 {
            return Ok(part.clone());
        }
        let widths = vec![self.part_width; self.k()];
        allgather_cols_rank(self.algo, fabric, &self.group, gi, part, &widths, tag)
    }

    /// bprop: recover each member's `[B, part]` gradient from the
    /// members' `[B, full]` input gradients.
    pub fn backward(
        &self,
        fabric: &dyn Transport,
        full_grads: &[HostTensor],
        tag: Tag,
    ) -> Result<Vec<HostTensor>> {
        let k = self.k();
        if k == 1 {
            return Ok(full_grads.to_vec());
        }
        match self.bwd_mode {
            ShardBwdMode::ReducePartials => {
                let widths = vec![self.part_width; k];
                reduce_scatter_cols_algo(self.algo, fabric, &self.group, full_grads, &widths, tag)
            }
            ShardBwdMode::SliceReplicated => Ok(full_grads
                .iter()
                .enumerate()
                .map(|(i, g)| {
                    g.slice_cols(i * self.part_width, (i + 1) * self.part_width)
                })
                .collect()),
        }
    }

    /// Per-rank bprop (threaded engine): recover this member's
    /// `[B, part]` gradient from its `[B, full]` input gradient.
    pub fn backward_rank(
        &self,
        fabric: &dyn Transport,
        gi: usize,
        full_grad: &HostTensor,
        tag: Tag,
    ) -> Result<HostTensor> {
        let k = self.k();
        if k == 1 {
            return Ok(full_grad.clone());
        }
        match self.bwd_mode {
            ShardBwdMode::ReducePartials => {
                let widths = vec![self.part_width; k];
                reduce_scatter_cols_rank(self.algo, fabric, &self.group, gi, full_grad, &widths, tag)
            }
            ShardBwdMode::SliceReplicated => {
                Ok(full_grad.slice_cols(gi * self.part_width, (gi + 1) * self.part_width))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Fabric;

    fn part(rows: usize, w: usize, base: f32) -> HostTensor {
        HostTensor::f32(vec![rows, w], (0..rows * w).map(|i| base + i as f32).collect())
    }

    #[test]
    fn fprop_restores_full_width() {
        let plan = ShardPlan::new(vec![0, 1], 2, ShardBwdMode::ReducePartials);
        let f = Fabric::new(2);
        let parts = [part(1, 2, 0.0), part(1, 2, 10.0)];
        let full = plan.gather_full(&f, &parts, Tag::new(3, 0, 0)).unwrap();
        for fl in &full {
            assert_eq!(fl.as_f32(), &[0.0, 1.0, 10.0, 11.0]);
        }
        assert_eq!(f.bytes_from(0), plan.fwd_bytes_per_member(1));
    }

    #[test]
    fn bwd_reduce_partials_sums() {
        let plan = ShardPlan::new(vec![0, 1], 1, ShardBwdMode::ReducePartials);
        let f = Fabric::new(2);
        let fulls = [
            HostTensor::f32(vec![1, 2], vec![1.0, 2.0]),
            HostTensor::f32(vec![1, 2], vec![10.0, 20.0]),
        ];
        let outs = plan.backward(&f, &fulls, Tag::new(4, 0, 0)).unwrap();
        assert_eq!(outs[0].as_f32(), &[11.0]); // col 0 summed
        assert_eq!(outs[1].as_f32(), &[22.0]); // col 1 summed
        assert!(f.drained());
    }

    #[test]
    fn bwd_slice_replicated_no_traffic_no_double_count() {
        let plan = ShardPlan::new(vec![0, 1], 1, ShardBwdMode::SliceReplicated);
        let f = Fabric::new(2);
        // Replicated head: both members hold the identical gradient.
        let g = HostTensor::f32(vec![1, 2], vec![5.0, 7.0]);
        let outs = plan.backward(&f, &[g.clone(), g], Tag::new(4, 0, 0)).unwrap();
        assert_eq!(outs[0].as_f32(), &[5.0]);
        assert_eq!(outs[1].as_f32(), &[7.0]);
        assert_eq!(f.total_bytes(), 0);
        assert_eq!(plan.bwd_bytes_per_member(1), 0);
    }

    #[test]
    fn k1_is_identity() {
        let plan = ShardPlan::new(vec![0], 4, ShardBwdMode::ReducePartials);
        let f = Fabric::new(1);
        let p = [part(2, 4, 0.0)];
        let full = plan.gather_full(&f, &p, Tag::new(3, 0, 0)).unwrap();
        assert_eq!(full[0].as_f32(), p[0].as_f32());
        let back = plan.backward(&f, &full, Tag::new(4, 0, 0)).unwrap();
        assert_eq!(back[0].as_f32(), p[0].as_f32());
        assert_eq!(f.total_bytes(), 0);
    }

    #[test]
    fn fwd_then_bwd_roundtrip_with_true_gradient() {
        // If the consumer above is y = sum(full), its input gradient is
        // all-ones *complete* at every member only if replicated; in the
        // partitioned case each member contributes 1/k of it. Check the
        // partial path reconstructs the all-ones gradient.
        let plan = ShardPlan::new(vec![0, 1, 2], 2, ShardBwdMode::ReducePartials);
        let f = Fabric::new(3);
        let partial = HostTensor::f32(vec![1, 6], vec![1.0 / 3.0; 6]);
        let outs = plan
            .backward(&f, &[partial.clone(), partial.clone(), partial], Tag::new(4, 0, 0))
            .unwrap();
        for o in &outs {
            for v in o.as_f32() {
                assert!((v - 1.0).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn width_bookkeeping() {
        let plan = ShardPlan::new(vec![0, 1, 2, 3], 256, ShardBwdMode::ReducePartials);
        assert_eq!(plan.full_width(), 1024);
        assert_eq!(plan.fwd_bytes_per_member(32), (3 * 32 * 256 * 4) as u64);
    }
}
