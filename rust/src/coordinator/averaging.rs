//! BSP model averaging (§4): every `avg_period` batches the workers
//! exchange parameters and reduce by averaging.
//!
//! Two scopes, matching the GMP design (§3.2):
//! * replicated parameters (conv + FC2) average across **all N** workers
//!   — ordinary DP model averaging;
//! * FC shard parameters average across the **D same-offset peers**,
//!   one per MP group — "exchanging the model shard parameters for
//!   model averaging across MP groups".
//!
//! The exchange itself is an allreduce over the fabric (real data
//! movement, exact byte counts); the algorithm — naive all-to-all,
//! bandwidth-optimal ring, or recursive halving/doubling — is selected
//! by [`CollectiveAlgo`]. Group-view entry points (sequential engine)
//! and per-rank entry points (threaded engine, one call per worker
//! thread) share the same per-rank programs, so both engines produce
//! bit-identical averages.

use anyhow::Result;

use crate::comm::collective::{allreduce_mean, allreduce_mean_rank, CollectiveAlgo};
use crate::comm::transport::Transport;

use super::group::GmpTopology;
use super::worker::Worker;

/// Tag namespaces (must not collide with the per-iteration MP tags).
const TAG_REPLICATED: u16 = 1000;
const TAG_SHARD_BASE: u16 = 2000;

/// Average replicated parameters across all workers. Returns bytes
/// pushed by the busiest rank (for the trace).
pub fn average_replicated(
    fabric: &dyn Transport,
    workers: &mut [Worker],
    algo: CollectiveAlgo,
) -> Result<u64> {
    let n = workers.len();
    if n <= 1 {
        return Ok(0);
    }
    let group: Vec<usize> = (0..n).collect();
    let mut bufs: Vec<Vec<f32>> = workers.iter().map(|w| w.replicated_flat()).collect();
    let before = fabric.max_bytes_per_rank();
    allreduce_mean(algo, fabric, &group, &mut bufs, TAG_REPLICATED)?;
    let pushed = fabric.max_bytes_per_rank() - before;
    for (w, buf) in workers.iter_mut().zip(bufs.iter()) {
        w.set_replicated_flat(buf);
    }
    Ok(pushed)
}

/// Average FC shard parameters across same-offset peers (one allreduce
/// group per shard offset). Returns bytes pushed by the busiest rank.
pub fn average_shards(
    fabric: &dyn Transport,
    workers: &mut [Worker],
    topo: &GmpTopology,
    algo: CollectiveAlgo,
) -> Result<u64> {
    if topo.mp == 1 || topo.n_groups() <= 1 {
        return Ok(0);
    }
    let before = fabric.max_bytes_per_rank();
    for offset in 0..topo.mp {
        let peers = topo.shard_peers(offset);
        let mut bufs: Vec<Vec<f32>> =
            peers.iter().map(|&r| workers[r].shards_flat()).collect();
        allreduce_mean(algo, fabric, &peers, &mut bufs, TAG_SHARD_BASE + offset as u16)?;
        for (&r, buf) in peers.iter().zip(bufs.iter()) {
            workers[r].set_shards_flat(buf);
        }
    }
    Ok(fabric.max_bytes_per_rank() - before)
}

/// Per-rank replicated-parameter averaging (the step program's
/// `AverageReplicated` op): rank `rank` contributes its conv + FC2
/// replica to the all-N allreduce-mean. No-op for a single worker.
pub fn average_replicated_rank(
    fabric: &dyn Transport,
    worker: &mut Worker,
    rank: usize,
    n_workers: usize,
    algo: CollectiveAlgo,
) -> Result<()> {
    if n_workers <= 1 {
        return Ok(());
    }
    let group: Vec<usize> = (0..n_workers).collect();
    let mut buf = worker.replicated_flat();
    allreduce_mean_rank(algo, fabric, &group, rank, &mut buf, TAG_REPLICATED)?;
    worker.set_replicated_flat(&buf);
    Ok(())
}

/// Per-rank shard-parameter averaging (the step program's
/// `AverageShards` op): rank `rank` contributes its FC0/FC1 shards to
/// the allreduce-mean across its D same-offset peers. No-op when there
/// is a single group or no model parallelism.
pub fn average_shards_rank(
    fabric: &dyn Transport,
    worker: &mut Worker,
    rank: usize,
    topo: &GmpTopology,
    algo: CollectiveAlgo,
) -> Result<()> {
    if topo.mp <= 1 || topo.n_groups() <= 1 {
        return Ok(());
    }
    let offset = topo.offset(rank);
    let peers = topo.shard_peers(offset);
    let gi = topo.gid(rank);
    debug_assert_eq!(peers[gi], rank);
    let mut buf = worker.shards_flat();
    allreduce_mean_rank(algo, fabric, &peers, gi, &mut buf, TAG_SHARD_BASE + offset as u16)?;
    worker.set_shards_flat(&buf);
    Ok(())
}

/// Per-rank averaging participation: rank `rank` contributes its
/// replicated parameters to the all-N allreduce, then its FC shards to
/// the same-offset peer allreduce — the order the step program's
/// `AverageReplicated` → `AverageShards` ops run in. Every rank of the
/// cluster must call this in the same BSP superstep. Kept as the
/// embedder-facing combined form; the executor drives the two halves
/// as separate ops.
pub fn average_rank(
    fabric: &dyn Transport,
    worker: &mut Worker,
    rank: usize,
    n_workers: usize,
    topo: &GmpTopology,
    algo: CollectiveAlgo,
) -> Result<()> {
    average_replicated_rank(fabric, worker, rank, n_workers, algo)?;
    average_shards_rank(fabric, worker, rank, topo, algo)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Fabric;
    use crate::coordinator::worker::init_full_params;

    fn workers(n: usize, mp: usize) -> (Vec<Worker>, GmpTopology) {
        let topo = GmpTopology::new(n, mp).unwrap();
        let (conv, fc) = init_full_params(5);
        let ws = (0..n)
            .map(|r| Worker::new(r, &topo, &conv, &fc, 4, 4096, 0.01, 0.0, 0.0).unwrap())
            .collect();
        (ws, topo)
    }

    #[test]
    fn replicated_average_converges_to_mean() {
        for algo in [CollectiveAlgo::Naive, CollectiveAlgo::Ring, CollectiveAlgo::Rhd] {
            let (mut ws, _) = workers(4, 2);
            // Perturb each worker's conv params differently.
            for (i, w) in ws.iter_mut().enumerate() {
                w.conv_params[0].as_f32_mut()[0] = i as f32;
            }
            let fabric = Fabric::new(4);
            average_replicated(&fabric, &mut ws, algo).unwrap();
            for w in &ws {
                assert!((w.conv_params[0].as_f32()[0] - 1.5).abs() < 1e-5, "{algo}");
            }
            assert!(fabric.drained());
        }
    }

    #[test]
    fn shard_average_stays_within_offset_peers() {
        let (mut ws, topo) = workers(4, 2);
        // Offset-0 workers are ranks 0, 2; offset-1 are 1, 3.
        ws[0].fc_params[0].as_f32_mut()[0] = 10.0;
        ws[2].fc_params[0].as_f32_mut()[0] = 20.0;
        ws[1].fc_params[0].as_f32_mut()[0] = 100.0;
        ws[3].fc_params[0].as_f32_mut()[0] = 200.0;
        let fabric = Fabric::new(4);
        average_shards(&fabric, &mut ws, &topo, CollectiveAlgo::Ring).unwrap();
        assert!((ws[0].fc_params[0].as_f32()[0] - 15.0).abs() < 1e-5);
        assert!((ws[2].fc_params[0].as_f32()[0] - 15.0).abs() < 1e-5);
        assert!((ws[1].fc_params[0].as_f32()[0] - 150.0).abs() < 1e-5);
        assert!((ws[3].fc_params[0].as_f32()[0] - 150.0).abs() < 1e-5);
    }

    #[test]
    fn single_worker_is_noop() {
        let (mut ws, topo) = workers(1, 1);
        let fabric = Fabric::new(1);
        assert_eq!(average_replicated(&fabric, &mut ws, CollectiveAlgo::Ring).unwrap(), 0);
        assert_eq!(average_shards(&fabric, &mut ws, &topo, CollectiveAlgo::Ring).unwrap(), 0);
    }

    #[test]
    fn single_group_skips_shard_average() {
        let (mut ws, topo) = workers(2, 2);
        let fabric = Fabric::new(2);
        let bytes = average_shards(&fabric, &mut ws, &topo, CollectiveAlgo::Ring).unwrap();
        assert_eq!(bytes, 0);
    }

    #[test]
    fn identical_replicas_stay_identical() {
        let (mut ws, _) = workers(4, 1);
        let before = ws[0].replicated_flat();
        let fabric = Fabric::new(4);
        average_replicated(&fabric, &mut ws, CollectiveAlgo::Ring).unwrap();
        let after = ws[0].replicated_flat();
        for (a, b) in before.iter().zip(after.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn per_rank_average_matches_group_view() {
        // Threaded-style per-rank calls (on threads) must reproduce the
        // group-view result bit-for-bit.
        let algo = CollectiveAlgo::Ring;
        let perturb = |ws: &mut [Worker]| {
            for (i, w) in ws.iter_mut().enumerate() {
                w.conv_params[0].as_f32_mut()[0] = i as f32 * 3.0;
                w.fc_params[0].as_f32_mut()[0] = i as f32 * 7.0;
            }
        };
        let (mut ws_a, topo) = workers(4, 2);
        perturb(&mut ws_a);
        let (mut ws_b, _) = workers(4, 2);
        perturb(&mut ws_b);

        let fa = Fabric::new(4);
        average_replicated(&fa, &mut ws_a, algo).unwrap();
        average_shards(&fa, &mut ws_a, &topo, algo).unwrap();

        let fb = Fabric::new(4);
        std::thread::scope(|s| {
            for (rank, w) in ws_b.iter_mut().enumerate() {
                let fb = &fb;
                let topo = &topo;
                s.spawn(move || average_rank(fb, w, rank, 4, topo, algo).unwrap());
            }
        });
        for (a, b) in ws_a.iter().zip(ws_b.iter()) {
            assert_eq!(a.replicated_flat(), b.replicated_flat());
            assert_eq!(a.shards_flat(), b.shards_flat());
        }
        assert_eq!(fa.total_bytes(), fb.total_bytes());
    }
}
