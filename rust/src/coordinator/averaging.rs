//! BSP model averaging (§4): every `avg_period` batches the workers
//! exchange parameters and reduce by averaging.
//!
//! Two scopes, matching the GMP design (§3.2):
//! * replicated parameters (conv + FC2) average across **all N** workers
//!   — ordinary DP model averaging;
//! * FC shard parameters average across the **D same-offset peers**,
//!   one per MP group — "exchanging the model shard parameters for
//!   model averaging across MP groups".
//!
//! The exchange itself is a ring allreduce over the fabric (real data
//! movement, bandwidth-optimal byte counts).

use anyhow::Result;

use crate::comm::collective::ring_allreduce_mean;
use crate::comm::Fabric;

use super::group::GmpTopology;
use super::worker::Worker;

/// Tag namespaces (must not collide with the per-iteration MP tags).
const TAG_REPLICATED: u16 = 1000;
const TAG_SHARD_BASE: u16 = 2000;

/// Average replicated parameters across all workers. Returns bytes
/// pushed by the busiest rank (for the trace).
pub fn average_replicated(fabric: &mut Fabric, workers: &mut [Worker]) -> Result<u64> {
    let n = workers.len();
    if n <= 1 {
        return Ok(0);
    }
    let group: Vec<usize> = (0..n).collect();
    let mut bufs: Vec<Vec<f32>> = workers.iter().map(|w| w.replicated_flat()).collect();
    let before = fabric.max_bytes_per_rank();
    ring_allreduce_mean(fabric, &group, &mut bufs, TAG_REPLICATED)?;
    let pushed = fabric.max_bytes_per_rank() - before;
    for (w, buf) in workers.iter_mut().zip(bufs.iter()) {
        w.set_replicated_flat(buf);
    }
    Ok(pushed)
}

/// Average FC shard parameters across same-offset peers (one ring per
/// shard offset). Returns bytes pushed by the busiest rank.
pub fn average_shards(
    fabric: &mut Fabric,
    workers: &mut [Worker],
    topo: &GmpTopology,
) -> Result<u64> {
    if topo.mp == 1 || topo.n_groups() <= 1 {
        return Ok(0);
    }
    let before = fabric.max_bytes_per_rank();
    for offset in 0..topo.mp {
        let peers = topo.shard_peers(offset);
        let mut bufs: Vec<Vec<f32>> =
            peers.iter().map(|&r| workers[r].shards_flat()).collect();
        ring_allreduce_mean(fabric, &peers, &mut bufs, TAG_SHARD_BASE + offset as u16)?;
        for (&r, buf) in peers.iter().zip(bufs.iter()) {
            workers[r].set_shards_flat(buf);
        }
    }
    Ok(fabric.max_bytes_per_rank() - before)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::worker::init_full_params;

    fn workers(n: usize, mp: usize) -> (Vec<Worker>, GmpTopology) {
        let topo = GmpTopology::new(n, mp).unwrap();
        let (conv, fc) = init_full_params(5);
        let ws = (0..n)
            .map(|r| Worker::new(r, &topo, &conv, &fc, 4, 4096, 0.01, 0.0, 0.0).unwrap())
            .collect();
        (ws, topo)
    }

    #[test]
    fn replicated_average_converges_to_mean() {
        let (mut ws, _) = workers(4, 2);
        // Perturb each worker's conv params differently.
        for (i, w) in ws.iter_mut().enumerate() {
            w.conv_params[0].as_f32_mut()[0] = i as f32;
        }
        let mut fabric = Fabric::new(4);
        average_replicated(&mut fabric, &mut ws).unwrap();
        for w in &ws {
            assert!((w.conv_params[0].as_f32()[0] - 1.5).abs() < 1e-5);
        }
        assert!(fabric.drained());
    }

    #[test]
    fn shard_average_stays_within_offset_peers() {
        let (mut ws, topo) = workers(4, 2);
        // Offset-0 workers are ranks 0, 2; offset-1 are 1, 3.
        ws[0].fc_params[0].as_f32_mut()[0] = 10.0;
        ws[2].fc_params[0].as_f32_mut()[0] = 20.0;
        ws[1].fc_params[0].as_f32_mut()[0] = 100.0;
        ws[3].fc_params[0].as_f32_mut()[0] = 200.0;
        let mut fabric = Fabric::new(4);
        average_shards(&mut fabric, &mut ws, &topo).unwrap();
        assert!((ws[0].fc_params[0].as_f32()[0] - 15.0).abs() < 1e-5);
        assert!((ws[2].fc_params[0].as_f32()[0] - 15.0).abs() < 1e-5);
        assert!((ws[1].fc_params[0].as_f32()[0] - 150.0).abs() < 1e-5);
        assert!((ws[3].fc_params[0].as_f32()[0] - 150.0).abs() < 1e-5);
    }

    #[test]
    fn single_worker_is_noop() {
        let (mut ws, topo) = workers(1, 1);
        let mut fabric = Fabric::new(1);
        assert_eq!(average_replicated(&mut fabric, &mut ws).unwrap(), 0);
        assert_eq!(average_shards(&mut fabric, &mut ws, &topo).unwrap(), 0);
    }

    #[test]
    fn single_group_skips_shard_average() {
        let (mut ws, topo) = workers(2, 2);
        let mut fabric = Fabric::new(2);
        let bytes = average_shards(&mut fabric, &mut ws, &topo).unwrap();
        assert_eq!(bytes, 0);
    }

    #[test]
    fn identical_replicas_stay_identical() {
        let (mut ws, _) = workers(4, 1);
        let before = ws[0].replicated_flat();
        let mut fabric = Fabric::new(4);
        average_replicated(&mut fabric, &mut ws).unwrap();
        let after = ws[0].replicated_flat();
        for (a, b) in before.iter().zip(after.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
    }
}
