//! The compiled per-rank **step program**: one IR, one executor, every
//! engine.
//!
//! Before this module existed the per-rank training step was hand-wired
//! three times — once in the sequential cluster driver's god-view loops,
//! once in the threaded engine's per-rank functions, and once in the
//! multi-process TCP driver — which is exactly the drift hazard a
//! bit-parity contract cannot afford. Now the step is **compiled once**
//! from the partitioned network's [`StepSchedule`] (which embeds the
//! [`GmpTopology`] and the transformed net's widths) into a flat list
//! of [`StepOp`]s with explicit data dependencies, and a single
//! executor (`exec_op`) runs those ops for all three engines:
//!
//! * **Sequential** — `run_lockstep`: op-major, rank-minor. Post ops
//!   run for every rank before the matching take ops (so non-rendezvous
//!   ops need no threads and compute stays contention-free for the
//!   calibrated benches); ops whose internals interleave sends and
//!   receives per round ([`StepOp::rendezvous`] — the ring/rhd
//!   collectives) run on a local thread scope, exactly as the seed's
//!   sequential engine already ran them.
//! * **Threaded** — `engine::run_threaded_step`: each worker thread
//!   executes the whole program in order, rendezvous provided by the
//!   transport's blocking takes; the [`StepOp::Barrier`] markers map
//!   onto the engine's BSP barrier.
//! * **TCP multi-process** — `procdriver::try_step`: one rank per
//!   process executes the same program; barrier markers map onto the
//!   transport's wire barriers and [`StepOp::CheckpointRefresh`] onto
//!   the control-plane shard allgather.
//!
//! ## Ops and dependencies
//!
//! | op | reads | writes | wire |
//! |---|---|---|---|
//! | `CrashPoll` | fault plan | — | gossip (TCP) |
//! | `FullStep` | params, batch | params, loss | — |
//! | `ConvFwd` | conv params, batch | `act` | — |
//! | `PostLabels{r}` | labels | — | post |
//! | `PostActs{r}` | `act` | — | post |
//! | `ModuloGather{r}` | `act`, labels | `assembled`, `labs` | take |
//! | `InferGather{r}` | `act` | `assembled` | take (serving) |
//! | `FcFwd{s,r}` | shard params, `assembled`/`h0` | `h0l`/`h1l` | — |
//! | `ShardGather{s,r}` | `h0l`/`h1l` | `h0`/`h1` | post+take |
//! | `HeadStep{r}` | `h1`, `labs` | loss, FC2 grads, `gh1` | — |
//! | `HeadLogits{r}` | `h1` | `logits[r]` | — (serving) |
//! | `ShardBwd{1,r}` | `gh1` | `g_h1l` | — (local slice) |
//! | `FcBwd{1,r}` | `h0`, `g_h1l` | FC1 grads, `gh0` | — |
//! | `ShardBwd{0,r}` | `gh0` | `g_h0l` | post+take (reduce) |
//! | `FcBwd{0,r}` | `assembled`, `g_h0l` | FC0 grads, `gbatch` | — |
//! | `PostGrads{r}` | `gbatch` | — | post |
//! | `ReduceGrads{r}` | `gbatch` | `g_act` rows | take (fixed order) |
//! | `ConvBwdUpdate` | `g_act` | all params | — |
//! | `Barrier(_)` | — | — | engine-defined |
//! | `AverageReplicated` | replica | replica | allreduce |
//! | `AverageShards` | shards | shards | allreduce |
//! | `CheckpointRefresh` | shards | restore point | control plane |
//!
//! ## Overlapped execution (`--overlap`)
//!
//! In BSP order every post is immediately followed by its takes, so a
//! sender serializes: compute round r, post round r, wait. The overlap
//! compile mode instead **hoists the post halves**: all rounds' label
//! posts move before `ConvFwd` (labels never depend on it) and all
//! rounds' activation posts move directly after it — every payload a
//! peer will ever take this step is on the wire before the first FC
//! round begins, so peers' takes are serviced while this rank computes
//! (the in-proc mailbox parks receivers on a condvar; the TCP reader
//! threads drain sockets in the background — nothing polls).
//!
//! **Bit-identity invariant:** overlap changes only *when* payloads are
//! posted, never their contents, their tags, or the fixed group order
//! in which every reduce consumes them ([`ModuloPlan::reduce_bwd_rank`],
//! the collectives). Arrival order affects wall-clock only; the
//! reduction tree is compiled, not raced. `overlap_parity` asserts
//! this bit-for-bit across engines, transports and fault plans.

use std::sync::Barrier;

use anyhow::{anyhow, Result};

use crate::comm::collective::CollectiveAlgo;
use crate::comm::fabric::Tag;
use crate::comm::fault::WorkerCrashed;
use crate::comm::transport::Transport;
use crate::data::Batch;
use crate::obs::tracer::{OpKind, TraceSet};
use crate::runtime::{HostTensor, RuntimeClient};
use crate::util::Timer;

use super::averaging::{average_replicated_rank, average_shards_rank};
use super::group::GmpTopology;
use super::modulo::ModuloPlan;
use super::schedule::StepSchedule;
use super::scheme::{
    gather_bk_rank, gather_scheme_b_rank, post_bk_rank, post_bwd_bk_rank,
    post_bwd_scheme_b_rank, post_scheme_b_rank, reduce_bwd_bk_rank, reduce_bwd_scheme_b_rank,
    McastScheme,
};
use super::shard::{ShardBwdMode, ShardPlan};
use super::worker::Worker;

/// Where a [`StepOp::Barrier`] sits in the step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BarrierId {
    /// End of the MP phase, before model averaging (the threaded
    /// engine's std barrier; the TCP transport's MID wire barrier).
    Mid,
    /// End of the whole step (thread join in-proc; the TCP END wire
    /// barrier that keeps processes in per-step lockstep).
    End,
}

/// One op of the compiled per-rank step program (see the module-level
/// op table for data dependencies).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepOp {
    /// Fire a pending injected crash for this rank (both engines poll
    /// at the top of the MP phase; consumption order is part of the
    /// deterministic-replay contract).
    CrashPoll,
    /// mp=1 fused fast path: one `full_step` artifact call + local SGD.
    FullStep,
    /// Conv front forward: batch images → flattened activations.
    ConvFwd,
    /// Post half of the modulo label exchange for one round. Labels
    /// depend only on the input batch, so the overlapped program hoists
    /// these before [`StepOp::ConvFwd`].
    PostLabels {
        /// Modulo round.
        round: usize,
    },
    /// Post half of the modulo activation exchange for one round.
    /// Depends on [`StepOp::ConvFwd`] only — the overlapped program
    /// hoists all rounds' posts directly after it.
    PostActs {
        /// Modulo round.
        round: usize,
    },
    /// Take half of the modulo exchange: assemble this round's FC batch
    /// and labels (own slice locally, peers' slices in group order).
    ModuloGather {
        /// Modulo round.
        round: usize,
    },
    /// Forward-only (serving) take half of the modulo exchange:
    /// assemble this round's FC batch from activations alone — no
    /// labels ride the wire, a prediction request has none. Compiled
    /// only by [`StepProgram::compile_forward`]; always scheme B/K.
    InferGather {
        /// Modulo round.
        round: usize,
    },
    /// Sharded FC forward (`fc{seg}_fwd_k{K}` artifact).
    FcFwd {
        /// Sharded FC index (0 or 1).
        seg: usize,
        /// Modulo round.
        round: usize,
    },
    /// Shard-layer fprop: allgather the partition outputs to full width
    /// (naive or ring rounds — interleaved post/take, hence
    /// rendezvous).
    ShardGather {
        /// Sharded FC index (0 or 1).
        seg: usize,
        /// Modulo round.
        round: usize,
    },
    /// Replicated head: loss + FC2 grads + the full `g_h1`.
    HeadStep {
        /// Modulo round.
        round: usize,
    },
    /// Forward-only (serving) replicated head: raw logits for this
    /// round's assembled batch, no labels, no loss, no gradients. The
    /// `head_logits` artifact is bit-identical to the logit computation
    /// inside every training-side head. Compiled only by
    /// [`StepProgram::compile_forward`].
    HeadLogits {
        /// Modulo round.
        round: usize,
    },
    /// Shard-layer bprop. seg 1 sits under the replicated head: a
    /// zero-wire local slice. seg 0 reduces partials across the group
    /// (rendezvous).
    ShardBwd {
        /// Sharded FC index (0 or 1).
        seg: usize,
        /// Modulo round.
        round: usize,
    },
    /// Sharded FC backward (`fc{seg}_bwd_k{K}` artifact).
    FcBwd {
        /// Sharded FC index (0 or 1).
        seg: usize,
        /// Modulo round.
        round: usize,
    },
    /// Post half of the modulo bprop: route owner blocks of the batch
    /// gradient back to their owners. Issued eagerly (right after
    /// `FcBwd{0}` produces the gradient) in every mode.
    PostGrads {
        /// Modulo round.
        round: usize,
    },
    /// Take half of the modulo bprop: reduce the routed copies in fixed
    /// group order into this member's `g_act` rows. The fixed order is
    /// what keeps overlapped and BSP runs bit-identical regardless of
    /// arrival order.
    ReduceGrads {
        /// Modulo round.
        round: usize,
    },
    /// Conv front backward + conv/FC optimizer updates.
    ConvBwdUpdate,
    /// BSP barrier marker — interpreted by each engine's driver (std
    /// barrier / wire barrier / no-op under lockstep).
    Barrier(BarrierId),
    /// Allreduce-mean of the replicated parameters across all N ranks.
    AverageReplicated,
    /// Allreduce-mean of the FC shards across the D same-offset peers.
    AverageShards,
    /// Refresh the in-memory global restore point. In-proc drivers
    /// snapshot centrally (no hook installed → no-op here); the TCP
    /// driver installs a control-plane shard-allgather hook.
    CheckpointRefresh,
}

impl StepOp {
    /// True when the op's internals interleave sends and receives per
    /// round (ring/rhd collectives, naive all-to-all gathers), so the
    /// lockstep executor must run all ranks concurrently on a local
    /// thread scope. All other ops are either pure compute, pure posts,
    /// or takes whose payloads were posted by an earlier op.
    pub fn rendezvous(self) -> bool {
        matches!(
            self,
            StepOp::ShardGather { .. }
                | StepOp::ShardBwd { seg: 0, .. }
                | StepOp::AverageReplicated
                | StepOp::AverageShards
        )
    }

    /// True for ops that only run on averaging steps.
    pub fn averaging_only(self) -> bool {
        matches!(
            self,
            StepOp::AverageReplicated | StepOp::AverageShards | StepOp::CheckpointRefresh
        )
    }
}

/// The compiled step program (see the module docs).
#[derive(Debug, Clone)]
pub struct StepProgram {
    ops: Vec<StepOp>,
    /// Index of `Barrier(Mid)` in `ops`.
    mid: usize,
    /// Index of `Barrier(End)` in `ops`.
    end: usize,
    /// Modulo rounds per step (0 for the fused mp=1 path).
    pub rounds: usize,
    /// Whether post halves were hoisted (overlapped execution).
    pub overlap: bool,
}

impl StepProgram {
    /// Compile the per-rank step program from the compiled schedule
    /// (which embeds the topology and the transformed net's widths).
    /// `overlap` hoists the modulo post halves (see the module docs);
    /// it never changes numerics.
    pub fn compile(
        schedule: &StepSchedule,
        scheme: McastScheme,
        segmented_mp1: bool,
        overlap: bool,
    ) -> StepProgram {
        let k = schedule.topo.mp;
        let fused = k == 1 && !segmented_mp1;
        // k=1 groups have no exchange; any scheme degrades to the local
        // B/K pipeline (same rule as the execution state below).
        let eff = if k > 1 { scheme } else { McastScheme::BoverK };
        let rounds = if fused { 0 } else { eff.rounds(k) };

        let mut ops = vec![StepOp::CrashPoll];
        if fused {
            ops.push(StepOp::FullStep);
        } else {
            if overlap {
                // Labels depend only on the batch: on the wire before
                // the heaviest compute of the step even starts.
                for r in 0..rounds {
                    ops.push(StepOp::PostLabels { round: r });
                }
            }
            ops.push(StepOp::ConvFwd);
            if overlap {
                // Every round's activation slice exists the moment the
                // conv front finishes: post them all eagerly.
                for r in 0..rounds {
                    ops.push(StepOp::PostActs { round: r });
                }
            }
            for r in 0..rounds {
                if !overlap {
                    ops.push(StepOp::PostActs { round: r });
                    ops.push(StepOp::PostLabels { round: r });
                }
                ops.push(StepOp::ModuloGather { round: r });
                ops.push(StepOp::FcFwd { seg: 0, round: r });
                ops.push(StepOp::ShardGather { seg: 0, round: r });
                ops.push(StepOp::FcFwd { seg: 1, round: r });
                ops.push(StepOp::ShardGather { seg: 1, round: r });
                ops.push(StepOp::HeadStep { round: r });
                ops.push(StepOp::ShardBwd { seg: 1, round: r });
                ops.push(StepOp::FcBwd { seg: 1, round: r });
                ops.push(StepOp::ShardBwd { seg: 0, round: r });
                ops.push(StepOp::FcBwd { seg: 0, round: r });
                ops.push(StepOp::PostGrads { round: r });
                ops.push(StepOp::ReduceGrads { round: r });
            }
            ops.push(StepOp::ConvBwdUpdate);
        }
        let mid = ops.len();
        ops.push(StepOp::Barrier(BarrierId::Mid));
        ops.push(StepOp::AverageReplicated);
        if k > 1 {
            ops.push(StepOp::AverageShards);
        }
        ops.push(StepOp::CheckpointRefresh);
        let end = ops.len();
        ops.push(StepOp::Barrier(BarrierId::End));
        StepProgram { ops, mid, end, rounds, overlap }
    }

    /// Compile the **forward-only** per-rank program for serving: the
    /// training step's exact forward half (conv front → modulo
    /// activation exchange → sharded FC segments with full-width
    /// allgathers) capped with [`StepOp::HeadLogits`] instead of the
    /// loss head — no labels, no backward ops, no averaging. Always
    /// scheme B/K (k rounds of B rows each; the serving group answers
    /// k·B requests per step). Executed by the same [`exec_op`] as
    /// training, so serving logits are bit-identical to the training
    /// forward pass.
    pub fn compile_forward(schedule: &StepSchedule) -> StepProgram {
        let k = schedule.topo.mp;
        // k=1 still runs the segmented single-round pipeline (the fused
        // full_step path has no logits output to reply with).
        let rounds = McastScheme::BoverK.rounds(k);
        let mut ops = Vec::with_capacity(2 + rounds * 7);
        ops.push(StepOp::ConvFwd);
        for r in 0..rounds {
            ops.push(StepOp::PostActs { round: r });
            ops.push(StepOp::InferGather { round: r });
            ops.push(StepOp::FcFwd { seg: 0, round: r });
            ops.push(StepOp::ShardGather { seg: 0, round: r });
            ops.push(StepOp::FcFwd { seg: 1, round: r });
            ops.push(StepOp::ShardGather { seg: 1, round: r });
            ops.push(StepOp::HeadLogits { round: r });
        }
        // Barrier markers keep the mp/avg span accessors well-formed;
        // the averaging span is empty (serving never averages).
        let mid = ops.len();
        ops.push(StepOp::Barrier(BarrierId::Mid));
        let end = ops.len();
        ops.push(StepOp::Barrier(BarrierId::End));
        StepProgram { ops, mid, end, rounds, overlap: false }
    }

    /// The full op list, in execution order.
    pub fn ops(&self) -> &[StepOp] {
        &self.ops
    }

    /// Ops of the MP phase (everything before the MID barrier).
    pub fn mp_span(&self) -> &[StepOp] {
        &self.ops[..self.mid]
    }

    /// Ops of the averaging phase (between the MID and END barriers);
    /// only executed on averaging steps.
    pub fn avg_span(&self) -> &[StepOp] {
        &self.ops[self.mid + 1..self.end]
    }
}

/// Everything `exec_op` needs for one step (shared, read-only, `Sync`).
pub(crate) struct ExecCtx<'a> {
    pub rt: &'a RuntimeClient,
    pub transport: &'a dyn Transport,
    pub topo: &'a GmpTopology,
    pub schedule: &'a StepSchedule,
    pub scheme: McastScheme,
    pub algo: CollectiveAlgo,
    pub batch: usize,
    /// Whether model averaging fires at the end of this step.
    pub averaging: bool,
    /// 1-based step number, recorded on every span.
    pub step: usize,
    /// Span recorder; `None` when tracing is off (the wrapper then adds
    /// zero work to the hot path).
    pub tracer: Option<&'a TraceSet>,
}

/// Per-driver hooks for the engine-specific ops.
pub(crate) struct RankHooks<'a> {
    /// Installed by the TCP driver only: refresh the global restore
    /// point (control-plane shard allgather). In-proc drivers snapshot
    /// centrally after the step instead.
    pub ckpt_refresh: Option<&'a (dyn Fn(&Worker) -> Result<()> + Sync)>,
}

impl RankHooks<'_> {
    pub(crate) fn none() -> RankHooks<'static> {
        RankHooks { ckpt_refresh: None }
    }
}

/// Per-group compile-time facts + plans for the segmented path.
struct GroupPlans {
    /// Effective scheme (k=1 degrades to B/K).
    scheme: McastScheme,
    rounds: usize,
    /// FC-stack batch rows per round (B, or B·K for scheme BK).
    fcb: usize,
    /// Artifact-name suffix for this scheme's FC segments.
    suffix: &'static str,
    head_name: String,
    modulo: ModuloPlan,
    modulo_lab: ModuloPlan,
    shard0: ShardPlan,
    shard1: ShardPlan,
}

/// Per-rank transient state for one step of the program.
pub(crate) struct RankState {
    gid: usize,
    gi: usize,
    k: usize,
    /// `None` on the fused mp=1 path (no exchanges, no plans).
    plans: Option<GroupPlans>,
    /// Labels as `[B, 1]` f32 for the modulo exchange; `None` on the
    /// fused path (which feeds the i32 labels straight to `full_step`).
    labels_f32: Option<HostTensor>,
    act: Option<HostTensor>,
    assembled: Option<HostTensor>,
    labs: Option<HostTensor>,
    h0l: Option<HostTensor>,
    h0: Option<HostTensor>,
    h1l: Option<HostTensor>,
    h1: Option<HostTensor>,
    gh1_full: Option<HostTensor>,
    g_h1l: Option<HostTensor>,
    gh0_partial: Option<HostTensor>,
    g_h0l: Option<HostTensor>,
    gbatch_partial: Option<HostTensor>,
    /// Per-round `[B, num_classes]` logits appended by
    /// [`StepOp::HeadLogits`] (forward-only programs; empty otherwise).
    logits: Vec<HostTensor>,
}

impl RankState {
    /// Build rank `rank`'s execution state for one step of `program`.
    pub(crate) fn new(rank: usize, program: &StepProgram, batch: &Batch, ctx: &ExecCtx<'_>) -> RankState {
        let gid = ctx.topo.gid(rank);
        let gi = ctx.topo.offset(rank);
        let k = ctx.topo.mp;
        let b = ctx.batch;
        // The fused mp=1 path feeds `full_step` directly: no plans, no
        // label conversion — keep its per-step overhead at zero.
        let (plans, labels_f32) = if program.rounds == 0 {
            (None, None)
        } else {
            let members = ctx.topo.members(gid);
            let labels_f32 = HostTensor::f32(
                vec![b, 1],
                batch.labels.as_i32().iter().map(|&v| v as f32).collect(),
            );
            let boundary = ctx.schedule.boundary_width;
            let s0 = ctx.schedule.shard_widths[0];
            let s1 = ctx.schedule.shard_widths[1];
            let scheme = if k > 1 { ctx.scheme } else { McastScheme::BoverK };
            let head_name = match scheme {
                McastScheme::BK if k > 1 => format!("head_step_bk{k}"),
                _ => "head_step".to_string(),
            };
            let plans = GroupPlans {
                scheme,
                rounds: scheme.rounds(k),
                fcb: scheme.fc_batch(b, k),
                suffix: scheme.artifact_suffix(),
                head_name,
                modulo: ModuloPlan::new(members.clone(), b, boundary),
                modulo_lab: ModuloPlan::new(members.clone(), b, 1),
                shard0: ShardPlan::new(members.clone(), s0, ShardBwdMode::ReducePartials)
                    .with_algo(ctx.algo),
                shard1: ShardPlan::new(members, s1, ShardBwdMode::SliceReplicated)
                    .with_algo(ctx.algo),
            };
            (Some(plans), Some(labels_f32))
        };
        RankState {
            gid,
            gi,
            k,
            plans,
            labels_f32,
            act: None,
            assembled: None,
            labs: None,
            h0l: None,
            h0: None,
            h1l: None,
            h1: None,
            gh1_full: None,
            g_h1l: None,
            gh0_partial: None,
            g_h0l: None,
            gbatch_partial: None,
            logits: Vec::new(),
        }
    }

    fn plans(&self) -> &GroupPlans {
        self.plans.as_ref().expect("segmented program op on the fused mp=1 path")
    }

    /// Drain the per-round serving logits accumulated by
    /// [`StepOp::HeadLogits`], leaving the state ready for the next
    /// forward-only step. Round r's tensor holds the assembled batch
    /// [r·size, (r+1)·size) of every member (B/K assembly order).
    pub(crate) fn take_logits(&mut self) -> Vec<HostTensor> {
        std::mem::take(&mut self.logits)
    }
}

/// mp=1 fast path: one fused full_step call + local SGD update for one
/// worker. The single shared body of the `FullStep` op, so no engine
/// can drift from another.
pub(crate) fn full_step_worker(rt: &RuntimeClient, w: &mut Worker, batch: &Batch) -> Result<()> {
    let t = Timer::start();
    let mut inputs: Vec<HostTensor> =
        Vec::with_capacity(w.conv_params.len() + w.fc_params.len() + 2);
    inputs.extend(w.conv_params.iter().cloned());
    inputs.extend(w.fc_params.iter().cloned());
    inputs.push(batch.images.clone());
    inputs.push(batch.labels.clone());
    let out = rt.run("full_step", &inputs)?;
    w.loss_acc += out[0].scalar() as f64;
    let conv_grads = &out[1..15];
    let fc_grads = &out[15..21];
    w.update_conv(conv_grads);
    let fcg: Vec<(usize, HostTensor)> = fc_grads.iter().cloned().enumerate().collect();
    w.accumulate_fc_grads(&fcg);
    w.update_fc(1);
    w.compute_secs += t.elapsed_secs();
    Ok(())
}

/// The span identity of an op: its [`OpKind`] plus the (round, seg)
/// coordinates recorded on the span. `None` for `CrashPoll` and
/// `Barrier`, which the engines deliberately do NOT all route through
/// `exec_op` (lockstep polls crashes centrally and treats barriers as
/// no-ops), so recording them would make span counts engine-dependent.
fn op_span(op: StepOp) -> Option<(OpKind, u32, u32)> {
    match op {
        StepOp::CrashPoll | StepOp::Barrier(_) => None,
        StepOp::FullStep => Some((OpKind::FullStep, 0, 0)),
        StepOp::ConvFwd => Some((OpKind::ConvFwd, 0, 0)),
        StepOp::PostLabels { round } => Some((OpKind::PostLabels, round as u32, 0)),
        StepOp::PostActs { round } => Some((OpKind::PostActs, round as u32, 0)),
        StepOp::ModuloGather { round } => Some((OpKind::ModuloGather, round as u32, 0)),
        // Serving ops reuse the training kinds so the metrics.json /
        // trace schema stays closed (a serving InferGather is the take
        // half of a ModuloGather; HeadLogits is the head matmul).
        StepOp::InferGather { round } => Some((OpKind::ModuloGather, round as u32, 0)),
        StepOp::HeadLogits { round } => Some((OpKind::HeadStep, round as u32, 0)),
        StepOp::FcFwd { seg, round } => Some((OpKind::FcFwd, round as u32, seg as u32)),
        StepOp::ShardGather { seg, round } => Some((OpKind::ShardGather, round as u32, seg as u32)),
        StepOp::HeadStep { round } => Some((OpKind::HeadStep, round as u32, 0)),
        StepOp::ShardBwd { seg, round } => Some((OpKind::ShardBwd, round as u32, seg as u32)),
        StepOp::FcBwd { seg, round } => Some((OpKind::FcBwd, round as u32, seg as u32)),
        StepOp::PostGrads { round } => Some((OpKind::PostGrads, round as u32, 0)),
        StepOp::ReduceGrads { round } => Some((OpKind::ReduceGrads, round as u32, 0)),
        StepOp::ConvBwdUpdate => Some((OpKind::ConvBwdUpdate, 0, 0)),
        StepOp::AverageReplicated => Some((OpKind::AverageReplicated, 0, 0)),
        StepOp::AverageShards => Some((OpKind::AverageShards, 0, 0)),
        StepOp::CheckpointRefresh => Some((OpKind::CheckpointRefresh, 0, 0)),
    }
}

/// Execute one op of the program for one rank, recording a span when
/// tracing is on. Bytes are attributed by deltas of the transport's
/// per-source post counter around the op — deterministic, because the
/// counters reset only at step boundaries and each op's posts live in
/// the op itself (overlap hoists whole post *ops*, never bytes between
/// ops).
pub(crate) fn exec_op(
    op: StepOp,
    rank: usize,
    w: &mut Worker,
    batch: &Batch,
    st: &mut RankState,
    ctx: &ExecCtx<'_>,
    hooks: &RankHooks<'_>,
) -> Result<()> {
    let span = match ctx.tracer {
        // Skip averaging-only ops on non-averaging steps for safety:
        // the drivers already gate them, and a span here would break
        // cross-engine span-count identity.
        Some(_) if op.averaging_only() && !ctx.averaging => None,
        Some(_) => op_span(op),
        None => None,
    };
    let Some((kind, round, seg)) = span else {
        return exec_op_inner(op, rank, w, batch, st, ctx, hooks);
    };
    let tracer = ctx.tracer.expect("span implies tracer");
    let bytes0 = ctx.transport.bytes_from(rank);
    let start = tracer.now_us();
    let res = exec_op_inner(op, rank, w, batch, st, ctx, hooks);
    let end = tracer.now_us();
    if res.is_ok() {
        let bytes = ctx.transport.bytes_from(rank).saturating_sub(bytes0);
        tracer.record(rank, kind, ctx.step as u32, round, seg, bytes, start, end);
    }
    res
}

/// The single implementation of every op's per-rank body — all three
/// engines funnel through [`exec_op`] into here.
fn exec_op_inner(
    op: StepOp,
    rank: usize,
    w: &mut Worker,
    batch: &Batch,
    st: &mut RankState,
    ctx: &ExecCtx<'_>,
    hooks: &RankHooks<'_>,
) -> Result<()> {
    let fabric = ctx.transport;
    match op {
        StepOp::CrashPoll => {
            if fabric.poll_crash(rank) {
                // poll_crash already declared this rank dead and
                // aborted the step on the transport.
                return Err(WorkerCrashed { rank, step: fabric.current_step() }.into());
            }
            Ok(())
        }
        StepOp::Barrier(_) => Ok(()), // driver-interpreted marker
        StepOp::FullStep => full_step_worker(ctx.rt, w, batch),
        StepOp::ConvFwd => {
            let t = Timer::start();
            let mut inputs: Vec<HostTensor> = w.conv_params.to_vec();
            inputs.push(batch.images.clone());
            let act = ctx
                .rt
                .run("conv_fwd", &inputs)?
                .into_iter()
                .next()
                .ok_or_else(|| anyhow!("conv_fwd returned no output"))?;
            w.compute_secs += t.elapsed_secs();
            st.act = Some(act);
            Ok(())
        }
        StepOp::PostActs { round } => {
            let p = st.plans();
            let act = st.act.as_ref().expect("ConvFwd precedes PostActs");
            let tag = Tag::new(1, round, st.gid);
            match p.scheme {
                McastScheme::BoverK => p.modulo.post_fwd_rank(fabric, st.gi, act, round, tag),
                McastScheme::B => post_scheme_b_rank(&p.modulo, fabric, st.gi, act, round, tag),
                McastScheme::BK => post_bk_rank(&p.modulo, fabric, st.gi, act, tag),
            }
            Ok(())
        }
        StepOp::PostLabels { round } => {
            let p = st.plans();
            let labels = st.labels_f32.as_ref().expect("segmented path carries f32 labels");
            let tag = Tag::new(2, round, st.gid);
            match p.scheme {
                McastScheme::BoverK => {
                    p.modulo_lab.post_fwd_rank(fabric, st.gi, labels, round, tag)
                }
                McastScheme::B => {
                    post_scheme_b_rank(&p.modulo_lab, fabric, st.gi, labels, round, tag)
                }
                McastScheme::BK => post_bk_rank(&p.modulo_lab, fabric, st.gi, labels, tag),
            }
            Ok(())
        }
        StepOp::ModuloGather { round } => {
            let (assembled, labs) = {
                let p = st.plans();
                let act = st.act.as_ref().expect("ConvFwd precedes ModuloGather");
                let labels = st.labels_f32.as_ref().expect("segmented path carries f32 labels");
                let tag1 = Tag::new(1, round, st.gid);
                let tag2 = Tag::new(2, round, st.gid);
                match p.scheme {
                    McastScheme::BoverK => (
                        p.modulo.gather_fwd_rank(fabric, st.gi, act, round, tag1)?,
                        p.modulo_lab.gather_fwd_rank(fabric, st.gi, labels, round, tag2)?,
                    ),
                    McastScheme::B => (
                        gather_scheme_b_rank(&p.modulo, fabric, st.gi, act, round, tag1)?,
                        gather_scheme_b_rank(&p.modulo_lab, fabric, st.gi, labels, round, tag2)?,
                    ),
                    McastScheme::BK => (
                        gather_bk_rank(&p.modulo, fabric, st.gi, act, tag1)?,
                        gather_bk_rank(&p.modulo_lab, fabric, st.gi, labels, tag2)?,
                    ),
                }
            };
            st.assembled = Some(assembled);
            st.labs = Some(labs);
            Ok(())
        }
        StepOp::InferGather { round } => {
            // Serving take: activations only — no labels ride a
            // forward-only step. Same tag lane as ModuloGather's act
            // half, so the wire schedule matches training's.
            let assembled = {
                let p = st.plans();
                let act = st.act.as_ref().expect("ConvFwd precedes InferGather");
                p.modulo.gather_fwd_rank(fabric, st.gi, act, round, Tag::new(1, round, st.gid))?
            };
            st.assembled = Some(assembled);
            Ok(())
        }
        StepOp::FcFwd { seg, round: _ } => {
            let out = {
                let p = st.plans();
                let k = st.k;
                let (input, wi) = if seg == 0 {
                    (st.assembled.as_ref().expect("ModuloGather precedes FcFwd{0}"), 0)
                } else {
                    (st.h0.as_ref().expect("ShardGather{0} precedes FcFwd{1}"), 2)
                };
                let t = Timer::start();
                let out = ctx
                    .rt
                    .run(
                        &format!("fc{seg}_fwd_k{k}{}", p.suffix),
                        &[w.fc_params[wi].clone(), w.fc_params[wi + 1].clone(), input.clone()],
                    )?
                    .into_iter()
                    .next()
                    .ok_or_else(|| anyhow!("fc{seg}_fwd returned no output"))?;
                w.compute_secs += t.elapsed_secs();
                out
            };
            if seg == 0 {
                st.h0l = Some(out);
            } else {
                st.h1l = Some(out);
            }
            Ok(())
        }
        StepOp::ShardGather { seg, round } => {
            let full = {
                let p = st.plans();
                if seg == 0 {
                    let part = st.h0l.as_ref().expect("FcFwd{0} precedes ShardGather{0}");
                    p.shard0.gather_full_rank(fabric, st.gi, part, Tag::new(3, round, st.gid))?
                } else {
                    let part = st.h1l.as_ref().expect("FcFwd{1} precedes ShardGather{1}");
                    p.shard1.gather_full_rank(fabric, st.gi, part, Tag::new(4, round, st.gid))?
                }
            };
            if seg == 0 {
                st.h0 = Some(full);
            } else {
                st.h1 = Some(full);
            }
            Ok(())
        }
        StepOp::HeadStep { round: _ } => {
            let (loss, g4, g5, gh1) = {
                let p = st.plans();
                let h1 = st.h1.as_ref().expect("ShardGather{1} precedes HeadStep");
                let labs = st.labs.as_ref().expect("ModuloGather precedes HeadStep");
                let labels_i32 = HostTensor::i32(
                    vec![p.fcb],
                    labs.as_f32().iter().map(|&v| v as i32).collect(),
                );
                let t = Timer::start();
                let out = ctx.rt.run(
                    &p.head_name,
                    &[w.fc_params[4].clone(), w.fc_params[5].clone(), h1.clone(), labels_i32],
                )?;
                w.compute_secs += t.elapsed_secs();
                (out[0].scalar() as f64, out[1].clone(), out[2].clone(), out[3].clone())
            };
            w.loss_acc += loss;
            w.accumulate_fc_grads(&[(4, g4), (5, g5)]);
            st.gh1_full = Some(gh1);
            Ok(())
        }
        StepOp::HeadLogits { round: _ } => {
            let out = {
                let h1 = st.h1.as_ref().expect("ShardGather{1} precedes HeadLogits");
                let t = Timer::start();
                let out = ctx
                    .rt
                    .run(
                        "head_logits",
                        &[w.fc_params[4].clone(), w.fc_params[5].clone(), h1.clone()],
                    )?
                    .into_iter()
                    .next()
                    .ok_or_else(|| anyhow!("head_logits returned no output"))?;
                w.compute_secs += t.elapsed_secs();
                out
            };
            st.logits.push(out);
            Ok(())
        }
        StepOp::ShardBwd { seg, round } => {
            let out = {
                let p = st.plans();
                if seg == 1 {
                    // Replicated head above: zero-wire local slice.
                    let g = st.gh1_full.as_ref().expect("HeadStep precedes ShardBwd{1}");
                    p.shard1.backward_rank(fabric, st.gi, g, Tag::new(5, round, st.gid))?
                } else {
                    // Partitioned layer above: reduce the partial sums.
                    let g = st.gh0_partial.as_ref().expect("FcBwd{1} precedes ShardBwd{0}");
                    p.shard0.backward_rank(fabric, st.gi, g, Tag::new(6, round, st.gid))?
                }
            };
            if seg == 1 {
                st.g_h1l = Some(out);
            } else {
                st.g_h0l = Some(out);
            }
            Ok(())
        }
        StepOp::FcBwd { seg, round: _ } => {
            let (ga, gb, gx) = {
                let p = st.plans();
                let k = st.k;
                let (x, gy, wi) = if seg == 1 {
                    (
                        st.h0.as_ref().expect("ShardGather{0} precedes FcBwd{1}"),
                        st.g_h1l.as_ref().expect("ShardBwd{1} precedes FcBwd{1}"),
                        2,
                    )
                } else {
                    (
                        st.assembled.as_ref().expect("ModuloGather precedes FcBwd{0}"),
                        st.g_h0l.as_ref().expect("ShardBwd{0} precedes FcBwd{0}"),
                        0,
                    )
                };
                let t = Timer::start();
                let out = ctx.rt.run(
                    &format!("fc{seg}_bwd_k{k}{}", p.suffix),
                    &[
                        w.fc_params[wi].clone(),
                        w.fc_params[wi + 1].clone(),
                        x.clone(),
                        gy.clone(),
                    ],
                )?;
                w.compute_secs += t.elapsed_secs();
                (out[0].clone(), out[1].clone(), out[2].clone())
            };
            let wi = if seg == 1 { 2 } else { 0 };
            w.accumulate_fc_grads(&[(wi, ga), (wi + 1, gb)]);
            if seg == 1 {
                st.gh0_partial = Some(gx);
            } else {
                st.gbatch_partial = Some(gx);
            }
            Ok(())
        }
        StepOp::PostGrads { round } => {
            let p = st.plans();
            let g = st.gbatch_partial.as_ref().expect("FcBwd{0} precedes PostGrads");
            let tag = Tag::new(7, round, st.gid);
            match p.scheme {
                McastScheme::BoverK => p.modulo.post_bwd_rank(fabric, st.gi, g, tag),
                McastScheme::B => post_bwd_scheme_b_rank(&p.modulo, fabric, st.gi, g, round, tag),
                McastScheme::BK => post_bwd_bk_rank(&p.modulo, fabric, st.gi, g, tag),
            }
            Ok(())
        }
        StepOp::ReduceGrads { round } => {
            // Split the g_act accumulator out of the worker so the plan
            // borrow and the mutable write don't overlap.
            let mut g_act = std::mem::replace(&mut w.g_act, HostTensor::zeros(vec![0]));
            let res = {
                let p = st.plans();
                let g = st.gbatch_partial.as_ref().expect("FcBwd{0} precedes ReduceGrads");
                let tag = Tag::new(7, round, st.gid);
                match p.scheme {
                    McastScheme::BoverK => {
                        p.modulo.reduce_bwd_rank(fabric, st.gi, g, &mut g_act, round, tag)
                    }
                    McastScheme::B => reduce_bwd_scheme_b_rank(
                        &p.modulo, fabric, st.gi, g, &mut g_act, round, tag,
                    ),
                    McastScheme::BK => {
                        let r = reduce_bwd_bk_rank(&p.modulo, fabric, st.gi, g, &mut g_act, tag);
                        if r.is_ok() && st.k > 1 {
                            // LR consistency: BK's head averaged over
                            // B*K examples — rescale (scheme.rs docs).
                            g_act.scale(st.k as f32);
                        }
                        r
                    }
                }
            };
            w.g_act = g_act;
            res
        }
        StepOp::ConvBwdUpdate => {
            let rounds = st.plans().rounds;
            let t = Timer::start();
            let mut inputs: Vec<HostTensor> = w.conv_params.to_vec();
            inputs.push(batch.images.clone());
            inputs.push(w.g_act.clone());
            let grads = ctx.rt.run("conv_bwd", &inputs)?;
            w.update_conv(&grads);
            w.update_fc(rounds);
            w.compute_secs += t.elapsed_secs();
            Ok(())
        }
        StepOp::AverageReplicated => {
            average_replicated_rank(fabric, w, rank, ctx.topo.n_workers, ctx.algo)
        }
        StepOp::AverageShards => average_shards_rank(fabric, w, rank, ctx.topo, ctx.algo),
        StepOp::CheckpointRefresh => match hooks.ckpt_refresh {
            Some(refresh) => refresh(w),
            None => Ok(()),
        },
    }
}

/// Run a span of the program for one rank, in order, stopping at the
/// first error. Barrier markers are no-ops here — the caller owns them.
pub(crate) fn run_rank_span(
    ops: &[StepOp],
    rank: usize,
    w: &mut Worker,
    batch: &Batch,
    st: &mut RankState,
    ctx: &ExecCtx<'_>,
    hooks: &RankHooks<'_>,
) -> Result<()> {
    for &op in ops {
        exec_op(op, rank, w, batch, st, ctx, hooks)?;
    }
    Ok(())
}

/// Drive the whole program **op-major** over every rank on the calling
/// thread — the sequential engine. Non-rendezvous ops run rank-by-rank
/// (compute stays contention-free, which is what the calibrated benches
/// time); rendezvous ops run all ranks on a local thread scope, exactly
/// like the seed's sequential engine ran its collectives. Per-rank
/// arithmetic is `exec_op`'s, so numerics are bit-identical to the
/// threaded and TCP engines by construction.
pub(crate) fn run_lockstep(
    program: &StepProgram,
    workers: &mut [Worker],
    batches: &[Batch],
    ctx: &ExecCtx<'_>,
) -> Result<()> {
    let n = workers.len();
    let mut states: Vec<RankState> = (0..n)
        .map(|r| RankState::new(r, program, &batches[r], ctx))
        .collect();
    let hooks = RankHooks::none();
    for &op in program.ops() {
        match op {
            StepOp::Barrier(_) => {}
            StepOp::CrashPoll => {
                // Fire every rank's pending crash in rank order (the
                // fired-flag consumption order is part of the replay
                // contract), then propagate the first crashed rank.
                let mut crashed = None;
                for rank in 0..n {
                    if ctx.transport.poll_crash(rank) && crashed.is_none() {
                        crashed = Some(rank);
                    }
                }
                if let Some(rank) = crashed {
                    return Err(
                        WorkerCrashed { rank, step: ctx.transport.current_step() }.into()
                    );
                }
            }
            op if op.averaging_only() && !ctx.averaging => {}
            op if op.rendezvous() => {
                let results: Vec<Result<()>> = std::thread::scope(|s| {
                    let handles: Vec<_> = workers
                        .iter_mut()
                        .zip(states.iter_mut())
                        .zip(batches.iter())
                        .enumerate()
                        .map(|(rank, ((w, st), batch))| {
                            let hooks = &hooks;
                            s.spawn(move || exec_op(op, rank, w, batch, st, ctx, hooks))
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| {
                            h.join()
                                .unwrap_or_else(|_| Err(anyhow!("lockstep worker panicked")))
                        })
                        .collect()
                });
                for r in results {
                    r?;
                }
            }
            op => {
                for (rank, (w, st)) in workers.iter_mut().zip(states.iter_mut()).enumerate() {
                    exec_op(op, rank, w, &batches[rank], st, ctx, &hooks)?;
                }
            }
        }
    }
    Ok(())
}

/// The threaded engine's per-thread drive of the program: MP span,
/// barrier (reached on error and panic paths too, so a failing worker
/// never wedges its peers), then the averaging span. Any failure aborts
/// the step on the transport first, so peers parked on blocking takes
/// wake with a typed error instead of waiting out the take timeout.
pub(crate) fn run_rank_threaded(
    program: &StepProgram,
    rank: usize,
    w: &mut Worker,
    batch: &Batch,
    ctx: &ExecCtx<'_>,
    barrier: &Barrier,
) -> Result<()> {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    let hooks = RankHooks::none();
    let mut st = RankState::new(rank, program, batch, ctx);
    let mp = catch_unwind(AssertUnwindSafe(|| {
        run_rank_span(program.mp_span(), rank, &mut *w, batch, &mut st, ctx, &hooks)
    }))
    .unwrap_or_else(|_| Err(anyhow!("worker {rank} panicked in the MP phase")));
    if mp.is_err() {
        ctx.transport.abort_step();
    }
    barrier.wait();
    let avg = if mp.is_ok() && ctx.averaging {
        catch_unwind(AssertUnwindSafe(|| {
            run_rank_span(program.avg_span(), rank, &mut *w, batch, &mut st, ctx, &hooks)
        }))
        .unwrap_or_else(|_| Err(anyhow!("worker {rank} panicked in averaging")))
    } else {
        Ok(())
    };
    if avg.is_err() {
        ctx.transport.abort_step();
    }
    mp.and(avg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{partition_network, vgg11, PartitionConfig};
    use crate::runtime::RuntimeClient;

    fn program(n: usize, mp: usize, scheme: McastScheme, overlap: bool) -> StepProgram {
        let rt = RuntimeClient::native().unwrap();
        let net = partition_network(
            &vgg11(),
            vec![32, 32, 3],
            &PartitionConfig { mp, ..Default::default() },
        )
        .unwrap();
        let topo = GmpTopology::new(n, mp).unwrap();
        let schedule = StepSchedule::compile_with_algo(
            &net,
            topo,
            &rt.manifest,
            false,
            scheme,
            CollectiveAlgo::Ring,
        )
        .unwrap();
        StepProgram::compile(&schedule, scheme, false, overlap)
    }

    fn forward_program(n: usize, mp: usize) -> StepProgram {
        let rt = RuntimeClient::native().unwrap();
        let net = partition_network(
            &vgg11(),
            vec![32, 32, 3],
            &PartitionConfig { mp, ..Default::default() },
        )
        .unwrap();
        let topo = GmpTopology::new(n, mp).unwrap();
        let schedule = StepSchedule::compile_with_algo(
            &net,
            topo,
            &rt.manifest,
            false,
            McastScheme::BoverK,
            CollectiveAlgo::Ring,
        )
        .unwrap();
        StepProgram::compile_forward(&schedule)
    }

    #[test]
    fn forward_program_shape() {
        let p = forward_program(4, 2);
        assert_eq!(p.rounds, 2);
        // Forward-only: no labels, no backward, no averaging, no fused
        // full-step — only the forward half plus the logits head.
        for op in p.ops() {
            assert!(
                matches!(
                    op,
                    StepOp::ConvFwd
                        | StepOp::PostActs { .. }
                        | StepOp::InferGather { .. }
                        | StepOp::FcFwd { .. }
                        | StepOp::ShardGather { .. }
                        | StepOp::HeadLogits { .. }
                        | StepOp::Barrier(_)
                ),
                "unexpected op in forward program: {op:?}"
            );
        }
        let count = |f: &dyn Fn(&StepOp) -> bool| p.ops().iter().filter(|&o| f(o)).count();
        assert_eq!(count(&|o| matches!(o, StepOp::InferGather { .. })), 2);
        assert_eq!(count(&|o| matches!(o, StepOp::HeadLogits { .. })), 2);
        assert_eq!(count(&|o| matches!(o, StepOp::ShardGather { .. })), 4);
        // The averaging span is empty; both barrier markers survive so
        // the span accessors stay well-formed.
        assert!(p.avg_span().is_empty());
        assert_eq!(p.ops().last(), Some(&StepOp::Barrier(BarrierId::End)));
        // mp=1 still compiles the segmented single-round pipeline (the
        // fused path has no logits output).
        let p1 = forward_program(2, 1);
        assert_eq!(p1.rounds, 1);
        assert_eq!(count(&|o| matches!(o, StepOp::FullStep)), 0);
        assert_eq!(
            p1.ops().iter().filter(|o| matches!(o, StepOp::HeadLogits { .. })).count(),
            1
        );
    }

    #[test]
    fn fused_program_shape() {
        let p = program(4, 1, McastScheme::BoverK, false);
        assert_eq!(p.rounds, 0);
        assert_eq!(p.mp_span(), &[StepOp::CrashPoll, StepOp::FullStep]);
        // mp=1: no shard averaging op compiled.
        assert_eq!(
            p.avg_span(),
            &[StepOp::AverageReplicated, StepOp::CheckpointRefresh]
        );
        assert_eq!(p.ops().first(), Some(&StepOp::CrashPoll));
        assert_eq!(p.ops().last(), Some(&StepOp::Barrier(BarrierId::End)));
    }

    #[test]
    fn segmented_program_has_k_rounds_and_shard_average() {
        let p = program(4, 2, McastScheme::BoverK, false);
        assert_eq!(p.rounds, 2);
        let gathers = p
            .ops()
            .iter()
            .filter(|o| matches!(o, StepOp::ModuloGather { .. }))
            .count();
        assert_eq!(gathers, 2);
        assert!(p.avg_span().contains(&StepOp::AverageShards));
        // BSP order: each round's posts immediately precede its gather.
        let ops = p.mp_span();
        let gather0 = ops
            .iter()
            .position(|o| *o == StepOp::ModuloGather { round: 0 })
            .unwrap();
        assert_eq!(ops[gather0 - 2], StepOp::PostActs { round: 0 });
        assert_eq!(ops[gather0 - 1], StepOp::PostLabels { round: 0 });
    }

    #[test]
    fn overlap_hoists_posts_without_changing_takes() {
        let bsp = program(4, 2, McastScheme::BoverK, false);
        let ovl = program(4, 2, McastScheme::BoverK, true);
        // Same multiset of ops (overlap moves posts, never adds/drops).
        let count = |p: &StepProgram, f: &dyn Fn(&StepOp) -> bool| {
            p.ops().iter().filter(|&o| f(o)).count()
        };
        for f in [
            (&|o: &StepOp| matches!(o, StepOp::PostActs { .. })) as &dyn Fn(&StepOp) -> bool,
            &|o: &StepOp| matches!(o, StepOp::PostLabels { .. }),
            &|o: &StepOp| matches!(o, StepOp::ModuloGather { .. }),
            &|o: &StepOp| matches!(o, StepOp::ReduceGrads { .. }),
        ] {
            assert_eq!(count(&bsp, f), count(&ovl, f));
        }
        // Take order is untouched by the hoist.
        let takes = |p: &StepProgram| -> Vec<StepOp> {
            p.ops()
                .iter()
                .copied()
                .filter(|o| {
                    matches!(
                        o,
                        StepOp::ModuloGather { .. }
                            | StepOp::ShardGather { .. }
                            | StepOp::ReduceGrads { .. }
                    )
                })
                .collect()
        };
        assert_eq!(takes(&bsp), takes(&ovl));
        // Hoisted: every label post precedes ConvFwd; every act post
        // precedes the first gather.
        let ops = ovl.mp_span();
        let conv = ops.iter().position(|o| *o == StepOp::ConvFwd).unwrap();
        let first_gather = ops
            .iter()
            .position(|o| matches!(o, StepOp::ModuloGather { .. }))
            .unwrap();
        for (i, op) in ops.iter().enumerate() {
            match op {
                StepOp::PostLabels { .. } => assert!(i < conv, "label post after ConvFwd"),
                StepOp::PostActs { .. } => {
                    assert!(i > conv && i < first_gather, "act post not hoisted")
                }
                _ => {}
            }
        }
    }

    #[test]
    fn rendezvous_classification() {
        assert!(StepOp::ShardGather { seg: 0, round: 0 }.rendezvous());
        assert!(StepOp::ShardBwd { seg: 0, round: 0 }.rendezvous());
        assert!(!StepOp::ShardBwd { seg: 1, round: 0 }.rendezvous(), "local slice, no wire");
        assert!(StepOp::AverageReplicated.rendezvous());
        assert!(!StepOp::ModuloGather { round: 0 }.rendezvous(), "posts precede op-major takes");
        assert!(!StepOp::PostActs { round: 0 }.rendezvous());
    }

    #[test]
    fn bk_scheme_compiles_single_round() {
        let p = program(2, 2, McastScheme::BK, true);
        assert_eq!(p.rounds, 1);
        assert!(p.overlap);
    }
}
