//! The three MP communication schemes of Krizhevsky'14 as discussed in
//! §3.1 — the paper builds SplitBrain on scheme **B/K** and argues the
//! other two don't scale; we implement all three so the argument is
//! reproducible as a benchmark rather than taken on faith.
//!
//! With batch B and group size K, per modulo "round" the FC stack sees:
//!
//! | scheme | FC batch | rounds | per-step comm time | staging memory |
//! |---|---|---|---|---|
//! | `BK`     | B·K | 1 | (K-1)·B·w/β, 1 phase   | K·B·w floats (the objection) |
//! | `B`      | B   | K | K·(K-1)·B·w/β (the round's owner is the single sender — serialized link) | B·w |
//! | `BoverK` | B   | K | (K-1)·B·w/β (balanced)  | B·w |
//!
//! Total *bytes* are identical; B/K wins on wire time (balanced
//! senders), BK matches its time but pays K× memory, and B pays K× wire
//! time. All three produce *identical gradients* (asserted in the
//! integration tests), so the choice is purely a systems trade.

use std::fmt;

use anyhow::{bail, Result};

use crate::comm::fabric::Tag;
use crate::comm::transport::Transport;
use crate::runtime::HostTensor;

use super::modulo::ModuloPlan;

/// Which §3.1 scheme the modulo layer runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum McastScheme {
    /// Scheme 3 — every member broadcasts B/K examples per round
    /// (SplitBrain's default).
    #[default]
    BoverK,
    /// Scheme 2 — members take turns broadcasting their whole batch.
    B,
    /// Scheme 1 — all batches aggregated into one B·K pass.
    BK,
}

impl McastScheme {
    /// Parse a CLI token: `bk`, `b`, or `b/k`.
    pub fn parse(s: &str) -> Result<McastScheme> {
        match s.to_ascii_lowercase().as_str() {
            "b/k" | "boverk" | "bok" => Ok(McastScheme::BoverK),
            "b" => Ok(McastScheme::B),
            "bk" => Ok(McastScheme::BK),
            other => bail!("unknown scheme {other:?} (expected bk, b, or b/k)"),
        }
    }

    /// Modulo rounds per training step.
    pub fn rounds(self, k: usize) -> usize {
        match self {
            McastScheme::BK => 1,
            _ => k,
        }
    }

    /// FC-stack batch size per round.
    pub fn fc_batch(self, b: usize, k: usize) -> usize {
        match self {
            McastScheme::BK => b * k,
            _ => b,
        }
    }

    /// Artifact-name suffix for the FC segments of this scheme.
    pub fn artifact_suffix(self) -> &'static str {
        match self {
            McastScheme::BK => "bk",
            _ => "",
        }
    }

    /// Modulo staging floats per worker (the Fig. 7c memory input).
    pub fn staging_floats(self, b: usize, k: usize, width: usize) -> usize {
        match self {
            // local acts + g_act + one assembled B*K batch
            McastScheme::BK => 2 * b * width + b * k * width,
            _ => 3 * b * width,
        }
    }
}

impl fmt::Display for McastScheme {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            McastScheme::BoverK => "B/K",
            McastScheme::B => "B",
            McastScheme::BK => "BK",
        })
    }
}

/// Scheme B fprop, round k: member k broadcasts its whole batch; the
/// assembled batch at every member IS member k's batch.
pub fn assemble_scheme_b(
    plan: &ModuloPlan,
    fabric: &dyn Transport,
    acts: &[HostTensor],
    round: usize,
    tag: Tag,
) -> Result<Vec<HostTensor>> {
    let kk = plan.k();
    assert!(round < kk);
    let owner = plan.group[round];
    for &dst in &plan.group {
        if dst != owner {
            fabric.post(owner, dst, tag, acts[round].as_f32().to_vec());
        }
    }
    let mut outs = Vec::with_capacity(kk);
    for (i, &dst) in plan.group.iter().enumerate() {
        if i == round {
            outs.push(acts[round].clone());
        } else {
            let data = fabric.take(dst, owner, tag)?;
            outs.push(HostTensor::f32(vec![plan.batch, plan.width], data));
        }
    }
    Ok(outs)
}

/// Scheme B bprop, round k: every non-owner sends its full partial
/// gradient back to the round's owner, which reduces the K copies into
/// its whole activation-gradient buffer.
pub fn scatter_reduce_scheme_b(
    plan: &ModuloPlan,
    fabric: &dyn Transport,
    gbatches: &[HostTensor],
    g_acts: &mut [HostTensor],
    round: usize,
    tag: Tag,
) -> Result<()> {
    let owner = plan.group[round];
    for (i, &src) in plan.group.iter().enumerate() {
        if i != round {
            fabric.post(src, owner, tag, gbatches[i].as_f32().to_vec());
        }
    }
    let mut acc = gbatches[round].clone();
    for &src in &plan.group {
        if src != owner {
            let data = fabric.take(owner, src, tag)?;
            acc.add_assign(&HostTensor::f32(vec![plan.batch, plan.width], data));
        }
    }
    g_acts[round] = acc;
    Ok(())
}

/// Scheme BK fprop (single round): every member broadcasts its whole
/// batch; the assembled batch is the member-ordered concatenation,
/// `[B*K, width]`.
pub fn assemble_bk(
    plan: &ModuloPlan,
    fabric: &dyn Transport,
    acts: &[HostTensor],
    tag: Tag,
) -> Result<Vec<HostTensor>> {
    let kk = plan.k();
    let b = plan.batch;
    for (j, &src) in plan.group.iter().enumerate() {
        for &dst in &plan.group {
            if dst != src {
                fabric.post(src, dst, tag, acts[j].as_f32().to_vec());
            }
        }
    }
    let mut outs = Vec::with_capacity(kk);
    for (i, &dst) in plan.group.iter().enumerate() {
        let mut big = HostTensor::zeros(vec![b * kk, plan.width]);
        for (j, &src) in plan.group.iter().enumerate() {
            if j == i {
                big.set_rows(j * b, &acts[i]);
            } else {
                let data = fabric.take(dst, src, tag)?;
                big.set_rows(j * b, &HostTensor::f32(vec![b, plan.width], data));
            }
        }
        outs.push(big);
    }
    Ok(outs)
}

/// Scheme BK bprop: the `[B*K, width]` partial gradients are routed
/// back by B-row owner block and reduced; each member ends with the
/// summed gradient for its own batch in `g_acts[i]`.
pub fn scatter_reduce_bk(
    plan: &ModuloPlan,
    fabric: &dyn Transport,
    gbatches: &[HostTensor],
    g_acts: &mut [HostTensor],
    tag: Tag,
) -> Result<()> {
    let b = plan.batch;
    for (j, &src) in plan.group.iter().enumerate() {
        for (i, &dst) in plan.group.iter().enumerate() {
            if i != j {
                let block = gbatches[j].slice_rows(i * b, (i + 1) * b);
                fabric.post(src, dst, tag, block.as_f32().to_vec());
            }
        }
    }
    for (i, &dst) in plan.group.iter().enumerate() {
        let mut acc = gbatches[i].slice_rows(i * b, (i + 1) * b);
        for &src in &plan.group {
            if src != dst {
                let data = fabric.take(dst, src, tag)?;
                acc.add_assign(&HostTensor::f32(vec![b, plan.width], data));
            }
        }
        g_acts[i] = acc;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Per-rank (SPMD) forms, used by the step-program executor. Reduction
// orders mirror the group-view functions above exactly (own
// contribution first, then peers in group order), so every engine
// agrees bit-for-bit. Like the B/K plan in `modulo.rs`, each exchange
// is split into a post half (pure sends, hoistable by the overlapped
// executor) and a take half (blocking receives + fixed-order reduce);
// the BSP program runs them back to back, the overlapped one hoists
// the posts.

/// Post half of the per-rank scheme-B fprop, round `round`: the round's
/// owner broadcasts its whole batch; non-owners send nothing.
pub fn post_scheme_b_rank(
    plan: &ModuloPlan,
    fabric: &dyn Transport,
    gi: usize,
    act: &HostTensor,
    round: usize,
    tag: Tag,
) {
    let kk = plan.k();
    assert!(round < kk && gi < kk);
    if gi != round {
        return;
    }
    let owner = plan.group[round];
    for &dst in &plan.group {
        if dst != owner {
            fabric.post(owner, dst, tag, act.as_f32().to_vec());
        }
    }
}

/// Take half of the per-rank scheme-B fprop: everyone returns the
/// round owner's batch (the owner its own copy, peers via a take).
pub fn gather_scheme_b_rank(
    plan: &ModuloPlan,
    fabric: &dyn Transport,
    gi: usize,
    act: &HostTensor,
    round: usize,
    tag: Tag,
) -> Result<HostTensor> {
    let kk = plan.k();
    assert!(round < kk && gi < kk);
    if gi == round {
        return Ok(act.clone());
    }
    let owner = plan.group[round];
    let me = plan.group[gi];
    let data = fabric.take_blocking(me, owner, tag)?;
    Ok(HostTensor::f32(vec![plan.batch, plan.width], data))
}

/// Post half of the per-rank scheme-B bprop: non-owners send their full
/// partial gradient to the round's owner.
pub fn post_bwd_scheme_b_rank(
    plan: &ModuloPlan,
    fabric: &dyn Transport,
    gi: usize,
    gbatch: &HostTensor,
    round: usize,
    tag: Tag,
) {
    if gi == round {
        return;
    }
    let owner = plan.group[round];
    let me = plan.group[gi];
    fabric.post(me, owner, tag, gbatch.as_f32().to_vec());
}

/// Take half of the per-rank scheme-B bprop: the owner reduces the K
/// copies (own first, then peers in group order) into its whole
/// activation-gradient buffer; non-owners do nothing.
pub fn reduce_bwd_scheme_b_rank(
    plan: &ModuloPlan,
    fabric: &dyn Transport,
    gi: usize,
    gbatch: &HostTensor,
    g_act: &mut HostTensor,
    round: usize,
    tag: Tag,
) -> Result<()> {
    if gi != round {
        return Ok(());
    }
    let owner = plan.group[round];
    let mut acc = gbatch.clone();
    for &src in &plan.group {
        if src != owner {
            let data = fabric.take_blocking(owner, src, tag)?;
            acc.add_assign(&HostTensor::f32(vec![plan.batch, plan.width], data));
        }
    }
    *g_act = acc;
    Ok(())
}

/// Post half of the per-rank scheme-BK fprop: broadcast this member's
/// whole batch to every peer.
pub fn post_bk_rank(plan: &ModuloPlan, fabric: &dyn Transport, gi: usize, act: &HostTensor, tag: Tag) {
    let me = plan.group[gi];
    for &dst in &plan.group {
        if dst != me {
            fabric.post(me, dst, tag, act.as_f32().to_vec());
        }
    }
}

/// Take half of the per-rank scheme-BK fprop: assemble the
/// member-ordered `[B*K, width]` concatenation.
pub fn gather_bk_rank(
    plan: &ModuloPlan,
    fabric: &dyn Transport,
    gi: usize,
    act: &HostTensor,
    tag: Tag,
) -> Result<HostTensor> {
    let kk = plan.k();
    let b = plan.batch;
    let me = plan.group[gi];
    let mut big = HostTensor::zeros(vec![b * kk, plan.width]);
    for (j, &src) in plan.group.iter().enumerate() {
        if j == gi {
            big.set_rows(j * b, act);
        } else {
            let data = fabric.take_blocking(me, src, tag)?;
            big.set_rows(j * b, &HostTensor::f32(vec![b, plan.width], data));
        }
    }
    Ok(big)
}

/// Post half of the per-rank scheme-BK bprop: route each B-row owner
/// block of the `[B*K, width]` partial gradient to its owner.
pub fn post_bwd_bk_rank(
    plan: &ModuloPlan,
    fabric: &dyn Transport,
    gi: usize,
    gbatch: &HostTensor,
    tag: Tag,
) {
    let b = plan.batch;
    let me = plan.group[gi];
    for (i, &dst) in plan.group.iter().enumerate() {
        if i != gi {
            let block = gbatch.slice_rows(i * b, (i + 1) * b);
            fabric.post(me, dst, tag, block.as_f32().to_vec());
        }
    }
}

/// Take half of the per-rank scheme-BK bprop: reduce this member's
/// block (own copy first, then peers in group order).
pub fn reduce_bwd_bk_rank(
    plan: &ModuloPlan,
    fabric: &dyn Transport,
    gi: usize,
    gbatch: &HostTensor,
    g_act: &mut HostTensor,
    tag: Tag,
) -> Result<()> {
    let b = plan.batch;
    let me = plan.group[gi];
    let mut acc = gbatch.slice_rows(gi * b, (gi + 1) * b);
    for &src in &plan.group {
        if src != me {
            let data = fabric.take_blocking(me, src, tag)?;
            acc.add_assign(&HostTensor::f32(vec![b, plan.width], data));
        }
    }
    *g_act = acc;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Fabric;

    fn acts(k: usize, b: usize, w: usize) -> Vec<HostTensor> {
        (0..k)
            .map(|j| {
                HostTensor::f32(
                    vec![b, w],
                    (0..b * w).map(|i| (100 * j + i) as f32).collect(),
                )
            })
            .collect()
    }

    #[test]
    fn scheme_b_round_k_is_owner_batch() {
        let plan = ModuloPlan::new(vec![0, 1, 2], 3, 2);
        let a = acts(3, 3, 2);
        let f = Fabric::new(3);
        let out = assemble_scheme_b(&plan, &f, &a, 1, Tag::new(1, 1, 0)).unwrap();
        for o in &out {
            assert_eq!(o.as_f32(), a[1].as_f32());
        }
        // Only the owner sent: 2 peers x (3x2 floats = 24 bytes).
        assert_eq!(f.bytes_from(1), 2 * 3 * 2 * 4);
        assert_eq!(f.bytes_from(0), 0);
        assert!(f.drained());
    }

    #[test]
    fn scheme_b_bwd_reduces_at_owner_only() {
        let plan = ModuloPlan::new(vec![0, 1], 2, 1);
        let gb = vec![
            HostTensor::f32(vec![2, 1], vec![1.0, 2.0]),
            HostTensor::f32(vec![2, 1], vec![10.0, 20.0]),
        ];
        let mut g = vec![HostTensor::zeros(vec![2, 1]), HostTensor::zeros(vec![2, 1])];
        let f = Fabric::new(2);
        scatter_reduce_scheme_b(&plan, &f, &gb, &mut g, 0, Tag::new(2, 0, 0)).unwrap();
        assert_eq!(g[0].as_f32(), &[11.0, 22.0]);
        assert_eq!(g[1].as_f32(), &[0.0, 0.0]); // untouched this round
        assert!(f.drained());
    }

    #[test]
    fn bk_assembles_member_ordered_concat() {
        let plan = ModuloPlan::new(vec![0, 1], 2, 2);
        let a = acts(2, 2, 2);
        let f = Fabric::new(2);
        let out = assemble_bk(&plan, &f, &a, Tag::new(3, 0, 0)).unwrap();
        for o in &out {
            assert_eq!(o.shape, vec![4, 2]);
            assert_eq!(&o.as_f32()[..4], a[0].as_f32());
            assert_eq!(&o.as_f32()[4..], a[1].as_f32());
        }
        assert!(f.drained());
    }

    #[test]
    fn bk_bwd_routes_blocks_to_owners() {
        let plan = ModuloPlan::new(vec![0, 1], 2, 1);
        // [B*K, 1] partial gradients at both members; rows 0..2 belong
        // to member 0, rows 2..4 to member 1.
        let gb = vec![
            HostTensor::f32(vec![4, 1], vec![1.0, 2.0, 3.0, 4.0]),
            HostTensor::f32(vec![4, 1], vec![10.0, 20.0, 30.0, 40.0]),
        ];
        let mut g = vec![HostTensor::zeros(vec![2, 1]), HostTensor::zeros(vec![2, 1])];
        let f = Fabric::new(2);
        scatter_reduce_bk(&plan, &f, &gb, &mut g, Tag::new(4, 0, 0)).unwrap();
        assert_eq!(g[0].as_f32(), &[11.0, 22.0]);
        assert_eq!(g[1].as_f32(), &[33.0, 44.0]);
        assert!(f.drained());
    }

    #[test]
    fn scheme_metadata() {
        assert_eq!(McastScheme::BK.rounds(4), 1);
        assert_eq!(McastScheme::B.rounds(4), 4);
        assert_eq!(McastScheme::BoverK.rounds(4), 4);
        assert_eq!(McastScheme::BK.fc_batch(32, 4), 128);
        assert_eq!(McastScheme::B.fc_batch(32, 4), 32);
        assert_eq!(McastScheme::BK.artifact_suffix(), "bk");
        assert_eq!(McastScheme::BoverK.artifact_suffix(), "");
    }

    #[test]
    fn scheme_parsing() {
        assert_eq!(McastScheme::parse("b/k").unwrap(), McastScheme::BoverK);
        assert_eq!(McastScheme::parse("B").unwrap(), McastScheme::B);
        assert_eq!(McastScheme::parse("bk").unwrap(), McastScheme::BK);
        assert!(McastScheme::parse("zzz").is_err());
    }

    #[test]
    fn bk_staging_is_k_fold() {
        let bok = McastScheme::BoverK.staging_floats(32, 8, 4096);
        let bk = McastScheme::BK.staging_floats(32, 8, 4096);
        assert!(bk > 3 * bok, "{bk} vs {bok}");
    }
}
