//! GMP topology (§3.2, Fig. 6): N workers = D data-parallel groups of
//! mp model-parallel members each.
//!
//! Groups are contiguous rank ranges; within a group a member is
//! identified by its offset (the paper's intra-group `iProc`). The
//! Fig. 6b mapping — batch-example index -> owning worker — is
//! `remote = gid*mp + b/size` with `size = B/K`.

use anyhow::{bail, Result};

/// The cluster's DP x MP shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GmpTopology {
    /// Total workers N.
    pub n_workers: usize,
    /// MP group size K (= the paper's `mp` training parameter).
    pub mp: usize,
}

impl GmpTopology {
    /// Build a topology (N must divide by the MP group size).
    pub fn new(n_workers: usize, mp: usize) -> Result<GmpTopology> {
        if n_workers == 0 || mp == 0 {
            bail!("workers and mp must be positive");
        }
        if n_workers % mp != 0 {
            bail!("n_workers {n_workers} not divisible by mp group size {mp}");
        }
        Ok(GmpTopology { n_workers, mp })
    }

    /// Number of MP groups (= DP degree across groups).
    pub fn n_groups(&self) -> usize {
        self.n_workers / self.mp
    }

    /// Group id of a worker (Fig. 6b's `gid`).
    pub fn gid(&self, rank: usize) -> usize {
        debug_assert!(rank < self.n_workers);
        rank / self.mp
    }

    /// Intra-group offset (the paper's `iProc` within the MP group).
    pub fn offset(&self, rank: usize) -> usize {
        rank % self.mp
    }

    /// Global ranks of group `gid`, in offset order.
    pub fn members(&self, gid: usize) -> Vec<usize> {
        debug_assert!(gid < self.n_groups());
        (gid * self.mp..(gid + 1) * self.mp).collect()
    }

    /// Group members of `rank`'s own group.
    pub fn group_of(&self, rank: usize) -> Vec<usize> {
        self.members(self.gid(rank))
    }

    /// Ranks across all groups holding the same shard offset — the
    /// peers that average FC shard parameters in GMP (one per group).
    pub fn shard_peers(&self, offset: usize) -> Vec<usize> {
        debug_assert!(offset < self.mp);
        (0..self.n_groups()).map(|g| g * self.mp + offset).collect()
    }

    /// Fig. 6b: which worker owns batch-example `b` of an assembled
    /// group batch, from the perspective of `rank`'s group.
    /// `size = B/K` examples per member.
    pub fn owner_of_example(&self, rank: usize, b: usize, batch: usize) -> usize {
        let size = batch / self.mp;
        debug_assert!(b < batch);
        self.gid(rank) * self.mp + b / size
    }

    /// True when the topology degenerates to pure DP (mp = 1).
    pub fn is_pure_dp(&self) -> bool {
        self.mp == 1
    }

    /// True when it degenerates to the single-group scheme of
    /// Krizhevsky'14 (mp = N).
    pub fn is_single_group(&self) -> bool {
        self.mp == self.n_workers
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_workers_mp2_matches_fig6a() {
        // Fig. 6a: four workers form two MP groups of size two.
        let t = GmpTopology::new(4, 2).unwrap();
        assert_eq!(t.n_groups(), 2);
        assert_eq!(t.members(0), vec![0, 1]);
        assert_eq!(t.members(1), vec![2, 3]);
        assert_eq!(t.gid(2), 1);
        assert_eq!(t.offset(3), 1);
    }

    #[test]
    fn fig6b_owner_mapping() {
        // N=4, mp=2, B=8 -> size=4. For a rank in group 1, example 5
        // belongs to gid*mp + 5/4 = 2 + 1 = rank 3.
        let t = GmpTopology::new(4, 2).unwrap();
        assert_eq!(t.owner_of_example(2, 5, 8), 3);
        assert_eq!(t.owner_of_example(2, 3, 8), 2);
        // Group 0 sees ranks 0/1.
        assert_eq!(t.owner_of_example(0, 5, 8), 1);
        assert_eq!(t.owner_of_example(1, 0, 8), 0);
    }

    #[test]
    fn fig4_mapping_single_group() {
        // The K=2, B=2 walkthrough of Fig. 4: worker P0 owns b0, P1
        // owns b1 (remote = b / (B/K) = b).
        let t = GmpTopology::new(2, 2).unwrap();
        assert_eq!(t.owner_of_example(0, 0, 2), 0);
        assert_eq!(t.owner_of_example(0, 1, 2), 1);
        assert_eq!(t.owner_of_example(1, 0, 2), 0);
    }

    #[test]
    fn shard_peers_span_groups() {
        let t = GmpTopology::new(8, 2).unwrap();
        assert_eq!(t.shard_peers(0), vec![0, 2, 4, 6]);
        assert_eq!(t.shard_peers(1), vec![1, 3, 5, 7]);
    }

    #[test]
    fn degenerate_cases() {
        let dp = GmpTopology::new(4, 1).unwrap();
        assert!(dp.is_pure_dp());
        assert_eq!(dp.n_groups(), 4);
        let single = GmpTopology::new(4, 4).unwrap();
        assert!(single.is_single_group());
        assert_eq!(single.n_groups(), 1);
    }

    #[test]
    fn divisibility_enforced() {
        assert!(GmpTopology::new(6, 4).is_err());
        assert!(GmpTopology::new(0, 1).is_err());
        assert!(GmpTopology::new(4, 0).is_err());
    }

    #[test]
    fn members_and_offsets_are_consistent() {
        let t = GmpTopology::new(12, 4).unwrap();
        for rank in 0..12 {
            let g = t.gid(rank);
            let members = t.members(g);
            assert_eq!(members[t.offset(rank)], rank);
            assert_eq!(t.group_of(rank), members);
        }
    }
}
