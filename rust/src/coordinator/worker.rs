//! Per-worker state: the parameter replica/shards, optimizer, gradient
//! accumulators and the compute clock.
//!
//! Initialization follows §2's data-parallel contract: every worker
//! starts from the *same* global model — conv parameters (and the
//! replicated FC2) are identical replicas, and each worker's FC0/FC1
//! shard is the corresponding column slice of one shared He-initialized
//! full matrix.

use anyhow::{bail, Result};

use crate::model::vgg;
use crate::runtime::HostTensor;
use crate::train::Sgd;
use crate::util::Rng;

use super::group::GmpTopology;

/// Shapes of the full (unsharded) FC stack.
pub const FC_DIMS: [(usize, usize); 3] = [(4096, 1024), (1024, 1024), (1024, 10)];

/// Build the full shared model (conv 14 tensors + fc 6 tensors) from a
/// seed — identical on every call with the same seed.
pub fn init_full_params(seed: u64) -> (Vec<HostTensor>, Vec<HostTensor>) {
    let mut rng = Rng::new(seed);
    let mut conv = Vec::new();
    for (name, io, _) in vgg::table1() {
        if !name.starts_with("Conv") {
            continue;
        }
        let (cin, cout) = parse_io(&io);
        let std = (2.0 / (9 * cin) as f32).sqrt();
        conv.push(HostTensor::f32(
            vec![3, 3, cin, cout],
            rng.normal_vec(9 * cin * cout, std),
        ));
        conv.push(HostTensor::zeros(vec![cout]));
    }
    let mut fc = Vec::new();
    for (din, dout) in FC_DIMS {
        let std = (2.0 / din as f32).sqrt();
        fc.push(HostTensor::f32(vec![din, dout], rng.normal_vec(din * dout, std)));
        fc.push(HostTensor::zeros(vec![dout]));
    }
    (conv, fc)
}

fn parse_io(io: &str) -> (usize, usize) {
    let (a, b) = io.split_once('x').expect("io format");
    (a.parse().unwrap(), b.parse().unwrap())
}

/// Column-slice the full FC params into worker `offset`'s shard of `k`
/// (FC2 replicated — below the CCR threshold).
pub fn shard_fc(full: &[HostTensor], k: usize, offset: usize) -> Vec<HostTensor> {
    assert_eq!(full.len(), 6);
    let mut out = Vec::with_capacity(6);
    for fc_idx in 0..2 {
        let (w, b) = (&full[2 * fc_idx], &full[2 * fc_idx + 1]);
        let dout = w.shape[1];
        assert_eq!(dout % k, 0);
        let s = dout / k;
        out.push(w.slice_cols(offset * s, (offset + 1) * s));
        let bias = HostTensor::f32(
            vec![s],
            b.as_f32()[offset * s..(offset + 1) * s].to_vec(),
        );
        out.push(bias);
    }
    out.push(full[4].clone());
    out.push(full[5].clone());
    out
}

/// A worker's complete training state in plain owned form — the unit
/// the durable checkpoint store ([`crate::store`]) serializes. Carries
/// optimizer momentum alongside the parameters: a resumed run is
/// bit-identical to the uninterrupted one only if the velocity buffers
/// survive the round trip.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerSnapshot {
    /// Global rank in the incarnation the snapshot was taken from.
    pub rank: usize,
    /// 14 conv tensors (w,b ×7), full replica.
    pub conv_params: Vec<HostTensor>,
    /// 6 FC tensors: FC0/FC1 shards + replicated FC2.
    pub fc_params: Vec<HostTensor>,
    /// Conv optimizer velocity (empty = momentum not yet allocated).
    pub conv_velocity: Vec<Vec<f32>>,
    /// FC optimizer velocity (empty = momentum not yet allocated).
    pub fc_velocity: Vec<Vec<f32>>,
}

/// One simulated worker.
pub struct Worker {
    /// Global rank.
    pub rank: usize,
    /// 14 conv tensors (w,b x7), full replica.
    pub conv_params: Vec<HostTensor>,
    /// 6 FC tensors: FC0/FC1 shards + replicated FC2.
    pub fc_params: Vec<HostTensor>,
    /// Optimizer for the conv replica.
    pub conv_opt: Sgd,
    /// Optimizer for the FC shard set.
    pub fc_opt: Sgd,
    /// Accumulated FC gradients over the K modulo iterations.
    pub fc_grad_acc: Vec<HostTensor>,
    /// Activation-gradient accumulator [B, boundary].
    pub g_act: HostTensor,
    /// Measured compute seconds this step (PJRT + host math).
    pub compute_secs: f64,
    /// Loss sum over modulo iterations this step.
    pub loss_acc: f64,
}

impl Worker {
    /// Build rank `rank`'s initial state from the shared full model.
    pub fn new(
        rank: usize,
        topo: &GmpTopology,
        full_conv: &[HostTensor],
        full_fc: &[HostTensor],
        batch: usize,
        boundary: usize,
        lr: f32,
        momentum: f32,
        clip_norm: f32,
    ) -> Result<Worker> {
        let fc_params = shard_fc(full_fc, topo.mp, topo.offset(rank));
        let fc_grad_acc = fc_params
            .iter()
            .map(|p| HostTensor::zeros(p.shape.clone()))
            .collect();
        Ok(Worker {
            rank,
            conv_params: full_conv.to_vec(),
            fc_params,
            conv_opt: Sgd::new(lr, momentum, 0.0).with_clip(clip_norm),
            fc_opt: Sgd::new(lr, momentum, 0.0).with_clip(clip_norm),
            fc_grad_acc,
            g_act: HostTensor::zeros(vec![batch, boundary]),
            compute_secs: 0.0,
            loss_acc: 0.0,
        })
    }

    /// Capture this worker's full training state (parameters +
    /// optimizer momentum) for the durable checkpoint store.
    pub fn snapshot(&self) -> WorkerSnapshot {
        WorkerSnapshot {
            rank: self.rank,
            conv_params: self.conv_params.clone(),
            fc_params: self.fc_params.clone(),
            conv_velocity: self.conv_opt.velocity().to_vec(),
            fc_velocity: self.fc_opt.velocity().to_vec(),
        }
    }

    /// Rebuild a worker from a snapshot taken at the *same* (n, mp)
    /// topology — the exact-resume path. Parameter tensor counts and
    /// velocity lengths are validated; shapes are trusted to the
    /// artifact's CRC + config fingerprint and re-asserted by the
    /// optimizer on the next step.
    pub fn from_snapshot(
        snap: WorkerSnapshot,
        batch: usize,
        boundary: usize,
        lr: f32,
        momentum: f32,
        clip_norm: f32,
    ) -> Result<Worker> {
        if snap.conv_params.len() != 14 || snap.fc_params.len() != 6 {
            bail!(
                "worker snapshot has {} conv + {} fc tensors (expected 14 + 6)",
                snap.conv_params.len(),
                snap.fc_params.len()
            );
        }
        for (vel, params, which) in [
            (&snap.conv_velocity, &snap.conv_params, "conv"),
            (&snap.fc_velocity, &snap.fc_params, "fc"),
        ] {
            if vel.is_empty() {
                continue;
            }
            if vel.len() != params.len() {
                bail!("{which} velocity has {} buffers for {} params", vel.len(), params.len());
            }
            for (v, p) in vel.iter().zip(params.iter()) {
                if v.len() != p.numel() {
                    bail!("{which} velocity length {} vs param numel {}", v.len(), p.numel());
                }
            }
        }
        let fc_grad_acc = snap
            .fc_params
            .iter()
            .map(|p| HostTensor::zeros(p.shape.clone()))
            .collect();
        let mut conv_opt = Sgd::new(lr, momentum, 0.0).with_clip(clip_norm);
        conv_opt.set_velocity(snap.conv_velocity);
        let mut fc_opt = Sgd::new(lr, momentum, 0.0).with_clip(clip_norm);
        fc_opt.set_velocity(snap.fc_velocity);
        Ok(Worker {
            rank: snap.rank,
            conv_params: snap.conv_params,
            fc_params: snap.fc_params,
            conv_opt,
            fc_opt,
            fc_grad_acc,
            g_act: HostTensor::zeros(vec![batch, boundary]),
            compute_secs: 0.0,
            loss_acc: 0.0,
        })
    }

    /// Zero the per-step accumulators.
    pub fn begin_step(&mut self) {
        for g in &mut self.fc_grad_acc {
            g.as_f32_mut().fill(0.0);
        }
        self.g_act.as_f32_mut().fill(0.0);
        self.loss_acc = 0.0;
    }

    /// Add FC gradients from one modulo iteration.
    pub fn accumulate_fc_grads(&mut self, grads: &[(usize, HostTensor)]) {
        for (idx, g) in grads {
            self.fc_grad_acc[*idx].add_assign(g);
        }
    }

    /// Apply the 1/K compensation and run the FC optimizer step.
    pub fn update_fc(&mut self, k: usize) {
        if k > 1 {
            let inv = 1.0 / k as f32;
            for g in &mut self.fc_grad_acc {
                g.scale(inv);
            }
        }
        let grads = std::mem::take(&mut self.fc_grad_acc);
        self.fc_opt.step(&mut self.fc_params, &grads);
        self.fc_grad_acc = grads;
    }

    /// Run the conv optimizer step.
    pub fn update_conv(&mut self, grads: &[HostTensor]) {
        self.conv_opt.step(&mut self.conv_params, grads);
    }

    /// Flatten all parameters into one buffer set for averaging:
    /// (replicated = conv + fc2, shards = fc0/fc1 shard tensors).
    pub fn replicated_flat(&self) -> Vec<f32> {
        let mut out = Vec::new();
        for t in &self.conv_params {
            out.extend_from_slice(t.as_f32());
        }
        out.extend_from_slice(self.fc_params[4].as_f32());
        out.extend_from_slice(self.fc_params[5].as_f32());
        out
    }

    /// Write back a flattened replicated-parameter buffer.
    pub fn set_replicated_flat(&mut self, flat: &[f32]) {
        let mut off = 0;
        for t in &mut self.conv_params {
            let n = t.numel();
            t.as_f32_mut().copy_from_slice(&flat[off..off + n]);
            off += n;
        }
        for idx in [4, 5] {
            let n = self.fc_params[idx].numel();
            self.fc_params[idx]
                .as_f32_mut()
                .copy_from_slice(&flat[off..off + n]);
            off += n;
        }
        assert_eq!(off, flat.len());
    }

    /// Flatten the FC0/FC1 shard tensors for averaging.
    pub fn shards_flat(&self) -> Vec<f32> {
        let mut out = Vec::new();
        for idx in 0..4 {
            out.extend_from_slice(self.fc_params[idx].as_f32());
        }
        out
    }

    /// Write back a flattened shard-parameter buffer.
    pub fn set_shards_flat(&mut self, flat: &[f32]) {
        let mut off = 0;
        for idx in 0..4 {
            let n = self.fc_params[idx].numel();
            self.fc_params[idx]
                .as_f32_mut()
                .copy_from_slice(&flat[off..off + n]);
            off += n;
        }
        assert_eq!(off, flat.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_deterministic() {
        let (c1, f1) = init_full_params(7);
        let (c2, f2) = init_full_params(7);
        for (a, b) in c1.iter().zip(c2.iter()).chain(f1.iter().zip(f2.iter())) {
            assert_eq!(a.as_f32(), b.as_f32());
        }
    }

    #[test]
    fn init_shapes_match_table1() {
        let (conv, fc) = init_full_params(0);
        assert_eq!(conv.len(), 14);
        assert_eq!(conv[0].shape, vec![3, 3, 3, 64]);
        assert_eq!(conv[12].shape, vec![3, 3, 256, 256]);
        assert_eq!(fc[0].shape, vec![4096, 1024]);
        assert_eq!(fc[4].shape, vec![1024, 10]);
    }

    #[test]
    fn shards_tile_the_full_matrix() {
        let (_, fc) = init_full_params(3);
        let k = 4;
        // Reassemble column shards and compare to the original.
        let mut w0 = HostTensor::zeros(vec![4096, 1024]);
        for off in 0..k {
            let sh = shard_fc(&fc, k, off);
            w0.set_cols(off * 256, &sh[0]);
        }
        assert_eq!(w0.as_f32(), fc[0].as_f32());
    }

    #[test]
    fn fc2_is_replicated_identically() {
        let (_, fc) = init_full_params(3);
        let a = shard_fc(&fc, 2, 0);
        let b = shard_fc(&fc, 2, 1);
        assert_eq!(a[4].as_f32(), b[4].as_f32());
        assert_eq!(a[5].as_f32(), b[5].as_f32());
        assert_ne!(a[0].as_f32(), b[0].as_f32());
    }

    #[test]
    fn replicated_flat_roundtrip() {
        let topo = GmpTopology::new(2, 2).unwrap();
        let (conv, fc) = init_full_params(1);
        let mut w = Worker::new(0, &topo, &conv, &fc, 8, 4096, 0.01, 0.9, 0.0).unwrap();
        let flat = w.replicated_flat();
        let mut flat2 = flat.clone();
        for v in &mut flat2 {
            *v *= 2.0;
        }
        w.set_replicated_flat(&flat2);
        assert_eq!(w.replicated_flat(), flat2);
        // Count: conv params incl biases + fc2.
        assert_eq!(flat.len(), 1_735_488 + 10_250);
    }

    #[test]
    fn shards_flat_roundtrip() {
        let topo = GmpTopology::new(4, 2).unwrap();
        let (conv, fc) = init_full_params(1);
        let mut w = Worker::new(3, &topo, &conv, &fc, 8, 4096, 0.01, 0.9, 0.0).unwrap();
        let flat = w.shards_flat();
        assert_eq!(flat.len(), 4096 * 512 + 512 + 1024 * 512 + 512);
        w.set_shards_flat(&flat);
        assert_eq!(w.shards_flat(), flat);
    }

    #[test]
    fn begin_step_zeroes_accumulators() {
        let topo = GmpTopology::new(2, 2).unwrap();
        let (conv, fc) = init_full_params(1);
        let mut w = Worker::new(0, &topo, &conv, &fc, 4, 16, 0.01, 0.0, 0.0).unwrap();
        w.g_act.as_f32_mut()[0] = 5.0;
        w.loss_acc = 3.0;
        w.begin_step();
        assert_eq!(w.g_act.as_f32()[0], 0.0);
        assert_eq!(w.loss_acc, 0.0);
    }
}
