//! The multi-process rank driver: one training process = one rank over
//! the TCP transport.
//!
//! This is the per-process mirror of the in-proc
//! [`Cluster`](super::cluster::Cluster) driver
//! (`splitbrain worker --rank R --peers ...`, spawned by
//! `splitbrain launch`). It executes the **same compiled step program**
//! ([`super::program`]) the in-proc engines run — the program's barrier
//! markers realized as wire barriers, its `CheckpointRefresh` op as the
//! control-plane shard allgather — against a [`TcpTransport`] instead
//! of the in-proc fabric. Because the per-op arithmetic and its order
//! are one shared implementation (`program::exec_op`), a multi-process
//! run is bit-identical to the threaded and sequential engines on the
//! same seed (the `transport_parity` suite asserts it), overlapped
//! execution included (`overlap_parity`).
//!
//! ## One BSP step across processes
//!
//! ```text
//! begin_step → crash poll → MP phase → MID barrier
//!            → averaging (if due) → checkpoint refresh → END barrier
//! ```
//!
//! The END barrier keeps the processes in per-step lockstep (what the
//! thread-join gives the in-proc engines), so a failure at step k is
//! observed by every survivor at step k, never one step later. The
//! checkpoint refresh replaces the in-proc driver's local
//! `snapshot_global()`: right after averaging — when replicas provably
//! agree — the group's FC shards are exchanged on the control plane
//! (uncounted, exactly like the in-proc snapshot's local memory reads)
//! so every process holds the full global model to restore from.
//!
//! ## Failure & recovery
//!
//! An injected crash makes the process broadcast its death and exit
//! with [`CRASH_EXIT_CODE`] — to its peers it is indistinguishable from
//! a real death (the `Dead` frame races the connection reset; either
//! works). Survivors observe typed `PeerLost`/`StepAborted` errors,
//! agree on the survivor set ([`TcpTransport::recovery_sync`]), then
//! re-plan exactly like [`Cluster`](super::cluster::Cluster) does: `planner::survivor_mp`, the
//! shared `plan_topology` pipeline, `Worker::new` from the latest
//! checkpoint, data iterators rebuilt over the survivor shape and
//! advanced to the current step.

use std::path::Path;
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::api::events::{RecoveryInfo, RunInfo, RunSummary, StepReport};
use crate::comm::fabric::Tag;
use crate::comm::fault::WorkerCrashed;
use crate::comm::transport::tcp::{SyncOutcome, BARRIER_END, BARRIER_MID};
use crate::comm::transport::{TcpPeer, TcpTransport, Transport};
use crate::data::{Batch, BatchIter};
use crate::obs::{chrome_trace_json, Metrics, TraceSet};
use crate::runtime::{HostTensor, RuntimeClient};
use crate::store::{
    ckpt::fnv1a, load_artifact, replay, save_artifact, CheckpointArtifact, LogRecord, LogWriter,
    RunDir, StoreError,
};
use crate::train::{checkpoint, MemoryReport};

use super::cluster::{plan_topology, ClusterConfig, ClusterState, RecoveryPolicy};
use super::group::GmpTopology;
use super::program::{run_rank_span, ExecCtx, RankHooks, RankState, StepProgram};
use super::schedule::StepSchedule;
use super::worker::{init_full_params, Worker};

pub use crate::comm::transport::CRASH_EXIT_CODE;

/// Exit code of a worker the cluster evicted (it was presumed dead
/// while actually alive — the membership verdict excluded it).
pub const EVICTED_EXIT_CODE: i32 = 43;

/// Tag phase for the control-plane checkpoint-refresh exchange (well
/// clear of the MP phases 1–7 and the averaging bases 1000/2000+).
const TAG_CKPT: u16 = 3000;

/// Configuration of one worker process.
pub struct ProcConfig {
    /// Launch-time cluster configuration (`n_workers` = launch size).
    pub cluster: ClusterConfig,
    /// Training steps to run.
    pub steps: usize,
    /// This process's stable id (= its launch-time rank).
    pub opid: usize,
    /// The full mesh, ordered by opid.
    pub peers: Vec<TcpPeer>,
    /// Artifact directory for the runtime.
    pub artifacts: String,
    /// Where to write the end-of-run state (`opid<N>.meta` /
    /// `opid<N>.ckpt`); no files are written when `None`.
    pub out_dir: Option<std::path::PathBuf>,
    /// Mesh bring-up timeout in milliseconds.
    pub connect_timeout_ms: u64,
    /// Print a progress line every this many steps (0 = quiet).
    pub log_every: usize,
    /// Durable run directory (`--run-dir`, created by the launcher):
    /// this process writes its PID file, a per-opid checkpoint artifact
    /// at every averaging boundary, and — opid 0 only — the run's
    /// `events.log`. `None` = no persistence.
    pub run_dir: Option<std::path::PathBuf>,
    /// Resume from the step-`resume_step` per-opid artifacts instead of
    /// the seed model (0 = fresh start). Requires `run_dir`.
    pub resume_step: usize,
    /// Record per-op spans and write `metrics-opid<N>.json` /
    /// `trace-opid<N>.json` into the run dir (falling back to
    /// `out_dir`); the launcher merges them into the canonical
    /// `metrics.json` / `trace.json`.
    pub trace: bool,
}

/// This process's slice of the durable store for a `--run-dir` launch.
struct ProcStore {
    dir: RunDir,
    /// The run fingerprint stamped into every artifact.
    fingerprint: u64,
    /// The run's event log — leader (opid 0) only; a launch that loses
    /// its leader keeps training but stops extending the log.
    log: Option<LogWriter>,
}

/// How a worker process's run ended.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunOutcome {
    /// All requested steps completed.
    Completed,
    /// An injected crash fault fired on this rank at the given step;
    /// the process must exit with [`CRASH_EXIT_CODE`].
    Crashed {
        /// Step the crash fired on.
        step: usize,
    },
    /// The membership verdict excluded this process; it must exit with
    /// [`EVICTED_EXIT_CODE`].
    Evicted,
}

/// Deterministic fingerprint over the run, exchanged in the Hello
/// handshake so workers from different launches (or holding different
/// manifests) can never mesh.
///
/// The preimage is the **canonical run manifest**
/// ([`RunManifest::to_json`](crate::api::RunManifest::to_json)) — the
/// same `run.json` the launcher writes and hands to every worker — so
/// "my manifest matches the leader's" is exactly what every
/// worker-pair handshake asserts. It also covers what the old
/// flag-string preimage missed: the fault plan and the network model.
pub fn run_fingerprint(cfg: &ClusterConfig, steps: usize) -> u64 {
    crate::api::RunManifest::from_config(cfg, steps).fingerprint()
}

/// Run one worker process to completion (see the module docs). Returns
/// the outcome; the caller maps it onto an exit code.
pub fn run_worker(pc: &ProcConfig) -> Result<RunOutcome> {
    let rt = RuntimeClient::load(&pc.artifacts)?;
    let cfg = &pc.cluster;
    if pc.peers.len() != cfg.n_workers {
        bail!(
            "peer list has {} entries but the launch declares {} workers",
            pc.peers.len(),
            cfg.n_workers
        );
    }
    // Open the durable store (pid file, leader log) *before* the mesh
    // comes up: a kill-resume test must be able to find this process's
    // pid even if a peer never arrives and bring-up blocks.
    let fingerprint = run_fingerprint(cfg, pc.steps);
    let mut pstore = open_store(pc, fingerprint)?;
    let transport = TcpTransport::connect(
        pc.opid,
        &pc.peers,
        fingerprint,
        cfg.take_timeout_ms,
        Duration::from_millis(pc.connect_timeout_ms.max(1)),
        cfg.faults.clone(),
    )
    .context("bringing up the TCP mesh")?;

    let (data, _desc) = crate::data::load_default(cfg.dataset_size, cfg.seed);

    // Current-incarnation shape (shrinks on recovery).
    let mut n = cfg.n_workers;
    let mut mp = cfg.mp;
    let mut my_rank = pc.opid;
    let (mut topo, transformed, mut schedule) = plan_topology(&rt, cfg, n, mp)?;
    let mut program = schedule.compile_program(cfg.scheme, cfg.segmented_mp1, cfg.overlap);
    let batch = rt.manifest.batch;

    let (conv, fc) = init_full_params(cfg.seed);

    // The latest global checkpoint (conv 14 + full FC 6, the
    // `snapshot_global` tensor order). The initial model is a valid
    // restore point: every process derives it from the shared seed.
    let mut ckpt: Vec<HostTensor> = conv.iter().cloned().chain(fc.iter().cloned()).collect();

    let mut step_count = 0usize;
    let mut recoveries = 0usize;
    let mut worker = if pc.resume_step > 0 {
        // Kill-resume: rebuild this rank bit-exactly from its own
        // step-K artifact. Only unshrunk runs resume — after an elastic
        // shrink the opid↔rank map of the dead incarnation is gone.
        let store = pstore.as_ref().context("--resume-step requires --run-dir")?;
        let art = load_artifact(store.dir.worker_checkpoint_path(pc.resume_step, pc.opid))
            .map_err(anyhow::Error::from)
            .with_context(|| {
                format!("loading the step-{} artifact for opid {}", pc.resume_step, pc.opid)
            })?;
        if art.manifest_fingerprint != fingerprint {
            return Err(StoreError::FingerprintMismatch {
                got: fingerprint,
                want: art.manifest_fingerprint,
            }
            .into());
        }
        if art.state.n_workers != cfg.n_workers || art.state.mp != cfg.mp {
            bail!(
                "the step-{} artifact captured a shrunk incarnation ({}×mp{}, launch is {}×mp{}) — \
                 multi-process resume supports unshrunk runs only",
                pc.resume_step,
                art.state.n_workers,
                art.state.mp,
                cfg.n_workers,
                cfg.mp
            );
        }
        if art.state.global.len() != 20 {
            bail!("resume artifact global model has {} tensors (expected 20)", art.state.global.len());
        }
        let snap = art
            .state
            .workers
            .into_iter()
            .next()
            .context("resume artifact carries no worker section")?;
        if snap.rank != pc.opid {
            bail!(
                "resume artifact holds rank {} state, this process is opid {}",
                snap.rank,
                pc.opid
            );
        }
        // The previous incarnation already consumed these injected
        // faults: keep injection at-most-once across the kill.
        transport.preset_fired(&art.state.fired);
        recoveries = art.state.recoveries;
        step_count = pc.resume_step;
        ckpt = art.state.global.into_iter().map(|(_, t)| t).collect();
        Worker::from_snapshot(
            snap,
            batch,
            schedule.boundary_width.max(1),
            cfg.lr,
            cfg.momentum,
            cfg.clip_norm,
        )?
    } else {
        Worker::new(
            my_rank,
            &topo,
            &conv,
            &fc,
            batch,
            schedule.boundary_width.max(1),
            cfg.lr,
            cfg.momentum,
            cfg.clip_norm,
        )?
    };
    let mut iter = BatchIter::new(data.clone(), batch, my_rank, n, cfg.seed);
    for _ in 0..step_count {
        iter.next_batch();
    }
    let mut losses: Vec<(usize, f64)> = Vec::with_capacity(pc.steps);
    let mut bytes_sent = 0u64;
    // Per-op span recorder (`--trace`): one slot per launch-time rank
    // so this process's spans keep their true rank as the Chrome-trace
    // tid even after an elastic re-rank.
    let tracer = if pc.trace { Some(TraceSet::new(cfg.n_workers)) } else { None };
    // Overlap's double buffer: the next step's batch is fetched on a
    // scoped helper thread while the current step computes, so input
    // assembly leaves the critical path. One batch is consumed per step
    // either way, so the example sequence is mode-invariant.
    let mut pending: Option<Batch> = None;
    // The step `ckpt` currently restores to (a resume starts from its
    // artifact's boundary; a fresh run from the step-0 seed model).
    let mut ckpt_step = step_count;

    if let Some(log) = pstore.as_mut().and_then(|s| s.log.as_mut()) {
        // The leader's log mirrors the in-proc session's stream: a
        // RunStarted header first (after a `Resumed` marker on resume —
        // same lineage order the in-proc rehydration keeps).
        let mem = MemoryReport::of_scheme(&transformed, batch, cfg.scheme);
        log.append(&LogRecord::RunStarted(RunInfo {
            n_workers: cfg.n_workers,
            mp: cfg.mp,
            n_groups: cfg.n_workers / cfg.mp.max(1),
            batch,
            steps: pc.steps,
            lr: cfg.lr,
            avg_period: cfg.avg_period,
            engine: cfg.engine,
            collectives: cfg.collectives,
            overlap: cfg.overlap,
            param_mb: mem.param_mb(),
            total_mb: mem.total_mb(),
        }))?;
    }

    while step_count < pc.steps {
        let step_no = step_count + 1;
        let this_batch = match pending.take() {
            Some(b) => b,
            None => iter.next_batch(),
        };
        let prefetch_next = program.overlap && step_no < pc.steps;
        let step_timer = std::time::Instant::now();
        let (res, next) = std::thread::scope(|s| {
            let prefetch = if prefetch_next { Some(s.spawn(|| iter.next_batch())) } else { None };
            let res = try_step(
                &rt, &transport, cfg, n, mp, &topo, &schedule, &program, &mut worker,
                &this_batch, my_rank, step_no, batch, &mut ckpt, tracer.as_ref(),
            );
            // A prefetch panic must stay loud: silently degrading to a
            // synchronous fetch would desynchronize this rank's example
            // sequence from its peers'.
            let next = prefetch.map(|h| match h.join() {
                Ok(b) => b,
                Err(p) => std::panic::resume_unwind(p),
            });
            (res, next)
        });
        pending = next;
        match res {
            Ok(loss) => {
                let step_bytes = transport.bytes_from(my_rank);
                bytes_sent += step_bytes;
                transport.reset_counters();
                step_count += 1;
                losses.push((step_count, loss));
                let wall = step_timer.elapsed().as_secs_f64();
                if n > 1 && step_count % cfg.avg_period == 0 {
                    // try_step refreshed `ckpt` over the control plane.
                    ckpt_step = step_count;
                }
                if let Some(store) = pstore.as_mut() {
                    if let Some(log) = &mut store.log {
                        // The wire path measures its own sends only (no
                        // simulated clock, no cluster-wide counter), so
                        // the modeled comm fields are zero and the byte
                        // fields are the leader's view.
                        log.append(&LogRecord::Step(StepReport {
                            step: step_count,
                            loss,
                            compute_secs: worker.compute_secs,
                            mp_comm_secs: 0.0,
                            dp_comm_secs: 0.0,
                            wall_secs: wall,
                            bytes_busiest_rank: step_bytes,
                            bytes_total: step_bytes,
                        }))?;
                    }
                    if step_count % cfg.avg_period == 0 {
                        persist_boundary(
                            store, pc, &transport, step_count, n, mp, recoveries, &worker, &ckpt,
                        )?;
                    }
                }
                if step_count % cfg.avg_period == 0 {
                    // Boundary metrics snapshot so `splitbrain watch`
                    // can surface a live per-phase breakdown.
                    if let (Some(t), Some(dir)) = (&tracer, obs_dir(pc)) {
                        write_obs_snapshot(dir, pc.opid, t, &transport, step_count, false)?;
                    }
                }
                if pc.log_every > 0 && (step_count % pc.log_every == 0 || step_count == pc.steps)
                {
                    eprintln!("[rank {my_rank}/{n} opid {}] step {step_count:>4}  loss {loss:.4}", pc.opid);
                }
            }
            Err(e) => {
                if let Some(c) = e.downcast_ref::<WorkerCrashed>() {
                    // Injected crash: this process dies. Peers already
                    // saw the Dead broadcast; dropping the transport
                    // closes the sockets like a real crash would.
                    eprintln!("[rank {my_rank} opid {}] {c} — exiting", pc.opid);
                    if let Some(dir) = &pc.out_dir {
                        let _ = std::fs::write(
                            dir.join(format!("opid{}.crashed", pc.opid)),
                            format!("step {}\n", c.step),
                        );
                    }
                    // A crash exit is as final as a clean one: drop the
                    // pid file so watchers never chase a recycled pid.
                    if let Some(store) = pstore.as_ref() {
                        let _ = std::fs::remove_file(store.dir.pid_path(pc.opid));
                    }
                    return Ok(RunOutcome::Crashed { step: c.step });
                }
                // The death notice behind a step abort may still be in
                // flight on another socket: give the gossip a bounded
                // window before concluding this was not a peer loss.
                let dead =
                    transport.wait_for_dead(Duration::from_millis(cfg.take_timeout_ms.min(2_000)));
                if cfg.recovery != RecoveryPolicy::ShrinkAndContinue || dead.is_empty() {
                    return Err(e.context(format!("step {step_no} failed (fail-fast)")));
                }
                eprintln!(
                    "[rank {my_rank} opid {}] step {step_no} lost peers {dead:?}: {e:#} — recovering",
                    pc.opid
                );
                match transport.recovery_sync()? {
                    SyncOutcome::Evicted => {
                        eprintln!("[opid {}] evicted by the membership verdict", pc.opid);
                        if let Some(store) = pstore.as_ref() {
                            let _ = std::fs::remove_file(store.dir.pid_path(pc.opid));
                        }
                        return Ok(RunOutcome::Evicted);
                    }
                    SyncOutcome::Continue { survivors, my_rank: new_rank } => {
                        recoveries += 1;
                        n = survivors.len();
                        my_rank = new_rank;
                        mp = super::planner::survivor_mp(n, mp, &rt.manifest.mp_sizes)?;
                        let planned = plan_topology(&rt, cfg, n, mp)?;
                        topo = planned.0;
                        schedule = planned.2;
                        program = schedule
                            .compile_program(cfg.scheme, cfg.segmented_mp1, cfg.overlap);
                        // Any prefetched batch belongs to the lost
                        // incarnation's iterator shape: discard it.
                        pending = None;
                        let conv_t = &ckpt[..14];
                        let fc_t = &ckpt[14..20];
                        worker = Worker::new(
                            my_rank,
                            &topo,
                            conv_t,
                            fc_t,
                            batch,
                            schedule.boundary_width.max(1),
                            cfg.lr,
                            cfg.momentum,
                            cfg.clip_norm,
                        )?;
                        // Survivor iterators advance to the current
                        // position, exactly like `Cluster::recover`.
                        iter = BatchIter::new(data.clone(), batch, my_rank, n, cfg.seed);
                        for _ in 0..step_count {
                            iter.next_batch();
                        }
                        eprintln!(
                            "[opid {}] recovered: {n} survivors, mp={mp}, now rank {my_rank}",
                            pc.opid
                        );
                        // Log the transition *before* the retried step's
                        // record lands — the same ordering contract the
                        // in-proc event stream keeps. `step` names the
                        // step whose retry runs next.
                        if let Some(log) = pstore.as_mut().and_then(|s| s.log.as_mut()) {
                            log.append(&LogRecord::Recovered(RecoveryInfo {
                                step: step_count + 1,
                                lost_ranks: dead.clone(),
                                n_workers: n,
                                mp,
                                restore_step: ckpt_step,
                            }))?;
                        }
                    }
                }
            }
        }
    }

    // Final observability snapshot: full metrics plus the Chrome-trace
    // spans, merged by the launcher across opids.
    if let (Some(t), Some(dir)) = (&tracer, obs_dir(pc)) {
        write_obs_snapshot(dir, pc.opid, t, &transport, step_count, true)?;
    }
    if let Some(store) = pstore.as_mut() {
        if let Some(log) = &mut store.log {
            // Throughput and comm fractions live in the per-step
            // records (and the `metrics-opid` snapshots); the roll-up
            // here carries the shape and lineage facts.
            log.append(&LogRecord::RunCompleted(RunSummary {
                steps: step_count,
                images_per_sec: 0.0,
                comm_fraction: 0.0,
                recoveries,
                lost_ranks: Vec::new(),
                n_workers: n,
                mp,
                last_checkpoint_step: ckpt_step,
            }))?;
        }
        // A stale pid file means "killable": remove it on clean exit.
        let _ = std::fs::remove_file(store.dir.pid_path(pc.opid));
    }
    if let Some(dir) = &pc.out_dir {
        write_outputs(dir, pc.opid, my_rank, n, mp, recoveries, &losses, bytes_sent, &worker)?;
    }
    transport.shutdown();
    Ok(RunOutcome::Completed)
}

/// Where this process's observability files land: the durable run dir
/// when launched with one, else the plain output dir (the bench path).
fn obs_dir(pc: &ProcConfig) -> Option<&Path> {
    pc.run_dir.as_deref().or(pc.out_dir.as_deref())
}

/// Write this process's `metrics-opid<N>.json` (and, at run end, its
/// `trace-opid<N>.json`). The deterministic fields — op counts, byte
/// totals, sent/recv histograms — are bit-identical across seeded
/// replays; timings are wall-clock.
fn write_obs_snapshot(
    dir: &Path,
    opid: usize,
    tracer: &TraceSet,
    transport: &TcpTransport,
    steps: usize,
    with_trace: bool,
) -> Result<()> {
    let snap = tracer.snapshot();
    let metrics = Metrics::from_snapshot(&snap, steps as u64, vec![transport.obs_stats()]);
    std::fs::write(dir.join(format!("metrics-opid{opid}.json")), metrics.to_json())
        .with_context(|| format!("writing metrics-opid{opid}.json"))?;
    if with_trace {
        std::fs::write(
            dir.join(format!("trace-opid{opid}.json")),
            chrome_trace_json(opid as u64, &snap),
        )
        .with_context(|| format!("writing trace-opid{opid}.json"))?;
    }
    Ok(())
}

/// Open this process's slice of the durable store: write the pid file,
/// and (leader only) open the event log — truncated past the resume
/// point with a `Resumed` marker on resume, fresh otherwise.
fn open_store(pc: &ProcConfig, fingerprint: u64) -> Result<Option<ProcStore>> {
    let Some(root) = &pc.run_dir else { return Ok(None) };
    let dir = RunDir::open(root)?;
    std::fs::write(dir.pid_path(pc.opid), format!("{}\n", std::process::id()))
        .with_context(|| format!("writing pid file for opid {}", pc.opid))?;
    let log = if pc.opid == 0 { Some(open_leader_log(&dir, pc.resume_step)?) } else { None };
    Ok(Some(ProcStore { dir, fingerprint, log }))
}

/// Open the leader's event log for a (possibly resumed) launch: replay
/// the longest valid prefix, cut everything past the resume step (the
/// torn tail of the killed incarnation included), restamp the resume
/// boundary's `Checkpoint` record if the cut dropped it, and append the
/// `Resumed` marker — the multi-process mirror of the in-proc
/// `Session` rehydration.
fn open_leader_log(dir: &RunDir, resume_step: usize) -> Result<LogWriter> {
    let path = dir.events_path();
    if resume_step == 0 || !path.is_file() {
        return Ok(LogWriter::create(&path)?);
    }
    let rp = replay(&path)?;
    let step = resume_step as u64;
    let logged = rp
        .records_until_step(step)
        .iter()
        .any(|r| matches!(r, LogRecord::Checkpoint { step: s, .. } if *s == step));
    let mut log = LogWriter::open_truncated(&path, rp.cut_for_step(step))?;
    if !logged {
        let file = format!("step-{resume_step}.opid-0.ckpt");
        if let Ok(bytes) = std::fs::read(dir.checkpoints_dir().join(&file)) {
            log.append(&LogRecord::Checkpoint { step, file, fingerprint: fnv1a(&bytes) })?;
        }
    }
    log.append(&LogRecord::Resumed { step })?;
    Ok(log)
}

/// Persist this process's averaging-boundary restore point: a per-opid
/// checkpoint artifact (this rank's exact worker state + the refreshed
/// global model), plus — leader only — the log's `Checkpoint` record.
/// A launch is resumable at step K once **every** opid's step-K
/// artifact exists (`RunDir::complete_worker_checkpoint_steps`).
#[allow(clippy::too_many_arguments)]
fn persist_boundary(
    store: &mut ProcStore,
    pc: &ProcConfig,
    transport: &TcpTransport,
    step: usize,
    n: usize,
    mp: usize,
    recoveries: usize,
    worker: &Worker,
    ckpt: &[HostTensor],
) -> Result<()> {
    let art = CheckpointArtifact {
        step,
        manifest_fingerprint: store.fingerprint,
        state: ClusterState {
            step,
            n_workers: n,
            mp,
            recoveries,
            lost_ranks: Vec::new(),
            fired: transport.fired_flags(),
            global: checkpoint::model_names()
                .into_iter()
                .zip(ckpt.iter().cloned())
                .collect(),
            workers: vec![worker.snapshot()],
        },
    };
    let fp = save_artifact(store.dir.worker_checkpoint_path(step, pc.opid), &art)?;
    if let Some(log) = &mut store.log {
        log.append(&LogRecord::Checkpoint {
            step: step as u64,
            file: format!("step-{step}.opid-{}.ckpt", pc.opid),
            fingerprint: fp,
        })?;
    }
    Ok(())
}

/// One step attempt on the current incarnation (the per-process mirror
/// of `Cluster::try_step`): this process executes the same compiled
/// step program as the in-proc engines, with the program's barrier
/// markers realized as the transport's MID/END wire barriers and the
/// `CheckpointRefresh` op as the control-plane shard allgather. Returns
/// this rank's per-step loss.
#[allow(clippy::too_many_arguments)]
fn try_step(
    rt: &RuntimeClient,
    transport: &TcpTransport,
    cfg: &ClusterConfig,
    n: usize,
    mp: usize,
    topo: &GmpTopology,
    schedule: &StepSchedule,
    program: &StepProgram,
    worker: &mut Worker,
    batch: &Batch,
    my_rank: usize,
    step_no: usize,
    batch_size: usize,
    ckpt: &mut Vec<HostTensor>,
    tracer: Option<&TraceSet>,
) -> Result<f64> {
    transport.begin_step(step_no);
    worker.begin_step();
    worker.compute_secs = 0.0;
    let averaging_due = n > 1 && step_no % cfg.avg_period == 0;

    let ctx = ExecCtx {
        rt,
        transport,
        topo,
        schedule,
        scheme: cfg.scheme,
        algo: cfg.collectives,
        batch: batch_size,
        averaging: averaging_due,
        step: step_no,
        tracer,
    };
    let mut st = RankState::new(my_rank, program, batch, &ctx);

    // MP span (the program's CrashPoll op is its first instruction). An
    // injected crash propagates *without* an abort broadcast — the Dead
    // gossip already went out inside poll_crash; any other failure
    // aborts the step so peers wake from their takes immediately.
    if let Err(e) = run_rank_span(
        program.mp_span(),
        my_rank,
        worker,
        batch,
        &mut st,
        &ctx,
        &RankHooks::none(),
    ) {
        if !e.is::<WorkerCrashed>() {
            transport.abort_step();
        }
        return Err(e);
    }
    transport.barrier(step_no, BARRIER_MID)?;

    if averaging_due {
        // Replicas provably agree right after the averaging ops: the
        // CheckpointRefresh op refreshes the global restore point over
        // the control plane (the in-proc equivalent is a local memory
        // read, so nothing lands on the data counters).
        let refreshed: Mutex<Option<Vec<HostTensor>>> = Mutex::new(None);
        let refresh = |w: &Worker| -> Result<()> {
            *refreshed.lock().unwrap() = Some(refresh_ckpt(transport, w, my_rank, topo)?);
            Ok(())
        };
        let hooks = RankHooks { ckpt_refresh: Some(&refresh) };
        if let Err(e) =
            run_rank_span(program.avg_span(), my_rank, worker, batch, &mut st, &ctx, &hooks)
        {
            transport.abort_step();
            return Err(e);
        }
        if let Some(t) = refreshed.into_inner().unwrap() {
            *ckpt = t;
        }
    }
    // Drain check must precede the END barrier: once our END frame is
    // out, a fast peer may legitimately post step-(s+1) data into our
    // mailbox. At this point every take of step s has returned, so any
    // leftover mail is genuinely over-posted.
    if !transport.drained() {
        bail!("transport not drained after step {step_no} — schedule bug");
    }
    transport.barrier(step_no, BARRIER_END)?;
    // Keep the injected-fault clocks ticking identically to the in-proc
    // driver (fired flags must consume in the same order).
    let straggle = transport.poll_straggle(my_rank);
    if straggle > 0.0 {
        worker.compute_secs += straggle;
    }
    let rounds = cfg.scheme.rounds(mp.max(1)) as f64;
    Ok(worker.loss_acc / rounds)
}

/// Rebuild the full global model (the `snapshot_global` tensor set)
/// from this rank's replica + a control-plane allgather of its group's
/// FC shards. Only called right after averaging, when every replica
/// and every same-offset shard provably agree bit-for-bit.
fn refresh_ckpt(
    transport: &TcpTransport,
    worker: &Worker,
    rank: usize,
    topo: &GmpTopology,
) -> Result<Vec<HostTensor>> {
    let group = topo.group_of(rank);
    let k = group.len();
    let gi = topo.offset(rank);
    let mut shard_flats: Vec<Vec<f32>> = vec![Vec::new(); k];
    shard_flats[gi] = worker.shards_flat();
    if k > 1 {
        let tag = Tag::new(TAG_CKPT, 0, topo.gid(rank));
        for &dst in &group {
            if dst != rank {
                transport.post_uncounted(rank, dst, tag, shard_flats[gi].clone());
            }
        }
        for (j, &src) in group.iter().enumerate() {
            if j != gi {
                shard_flats[j] = transport.take_blocking(rank, src, tag)?;
            }
        }
    }

    // Reassemble the full FC stack from the shard flats (the layout
    // `Worker::shards_flat` packs: w0 | b0 | w1 | b1 per member).
    let (d0, s0) = (worker.fc_params[0].shape[0], worker.fc_params[0].shape[1]);
    let (d1, s1) = (worker.fc_params[2].shape[0], worker.fc_params[2].shape[1]);
    let mut full = Vec::with_capacity(6);
    for (fc_idx, (din, s)) in [(0usize, (d0, s0)), (1usize, (d1, s1))] {
        let mut w = HostTensor::zeros(vec![din, s * k]);
        let mut bias = Vec::with_capacity(s * k);
        for (j, flat) in shard_flats.iter().enumerate() {
            let w_off = if fc_idx == 0 { 0 } else { d0 * s0 + s0 };
            let b_off = w_off + din * s;
            if flat.len() < b_off + s {
                bail!("shard flat from member {j} is {} floats, need {}", flat.len(), b_off + s);
            }
            let wj = HostTensor::f32(vec![din, s], flat[w_off..w_off + din * s].to_vec());
            w.set_cols(j * s, &wj);
            bias.extend_from_slice(&flat[b_off..b_off + s]);
        }
        full.push(w);
        full.push(HostTensor::f32(vec![s * k], bias));
    }
    full.push(worker.fc_params[4].clone());
    full.push(worker.fc_params[5].clone());

    let mut out: Vec<HostTensor> = worker.conv_params.clone();
    out.extend(full);
    debug_assert_eq!(out.len(), 20);
    Ok(out)
}

/// Write this process's end-of-run state for the launcher and the
/// parity suite: `opid<N>.meta` (final rank/shape, per-step loss bit
/// patterns, byte counters) and `opid<N>.ckpt` (every local parameter
/// tensor, bit-exact). Timing lives in `metrics-opid<N>.json`
/// (`--trace`), not here.
#[allow(clippy::too_many_arguments)]
fn write_outputs(
    dir: &Path,
    opid: usize,
    my_rank: usize,
    n: usize,
    mp: usize,
    recoveries: usize,
    losses: &[(usize, f64)],
    bytes_sent: u64,
    worker: &Worker,
) -> Result<()> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating out dir {}", dir.display()))?;
    let mut meta = String::new();
    meta.push_str(&format!("opid {opid}\n"));
    meta.push_str(&format!("rank {my_rank}\n"));
    meta.push_str(&format!("workers {n}\n"));
    meta.push_str(&format!("mp {mp}\n"));
    meta.push_str(&format!("recoveries {recoveries}\n"));
    meta.push_str(&format!("bytes {bytes_sent}\n"));
    for (step, loss) in losses {
        meta.push_str(&format!("loss {step} {:016x}\n", loss.to_bits()));
    }
    std::fs::write(dir.join(format!("opid{opid}.meta")), meta)?;

    let named: Vec<(String, &HostTensor)> = worker
        .conv_params
        .iter()
        .chain(worker.fc_params.iter())
        .enumerate()
        .map(|(i, t)| (format!("p{i}"), t))
        .collect();
    checkpoint::save(dir.join(format!("opid{opid}.ckpt")), &named)?;
    Ok(())
}
