//! The multi-threaded cluster execution engine.
//!
//! The seed drove every worker sequentially on one OS thread: the
//! coordinator interleaved each BSP phase "god-view" (post everything,
//! then take everything), so throughput could not scale with workers.
//! This engine runs **each worker's whole step program on its own
//! scoped thread** — segment compute, modulo/shard exchanges and
//! averaging included — with rendezvous provided by the thread-safe
//! transport's blocking takes and one BSP barrier at the superstep
//! boundary (MP phase → averaging phase).
//!
//! Since the step-program refactor the per-rank step itself lives in
//! [`super::program`]: this module only owns the *drive* — one scoped
//! thread per worker, the barrier, and the engine's failure semantics.
//! The sequential engine drives the very same program op-major on the
//! coordinator thread (`program::run_lockstep`), which is why the two
//! cannot drift.
//!
//! ## Bit-identical numerics
//!
//! Every engine executes `program::exec_op` — the same arithmetic in
//! the same order per rank, with every reduce consuming in fixed group
//! order — and the segment runtime is deterministic, so threaded,
//! sequential and multi-process training runs agree bit-for-bit
//! (`engine_parity`, `transport_parity`, `overlap_parity` suites).
//!
//! ## Failure semantics
//!
//! A worker error (injected crash, bad artifact, schedule bug) does not
//! hang the step: the erroring thread still reaches the barrier, and it
//! aborts the step on the transport first, so peers parked on blocking
//! takes wake immediately with a typed error — [`PeerLost`] when the
//! failed rank is dead, `StepAborted` otherwise — instead of waiting
//! out the take timeout. After all threads join, a typed
//! [`WorkerCrashed`]/[`PeerLost`] error is propagated in preference to
//! the secondary teardown errors, so the cluster driver (and its
//! `RecoveryPolicy`) sees the root cause.

use std::sync::Barrier;

use anyhow::{anyhow, bail, Result};

use crate::comm::fault::{PeerLost, StepAborted, WorkerCrashed};
use crate::data::{Batch, BatchIter};

use super::program::{run_rank_threaded, ExecCtx, StepProgram};
use super::worker::Worker;

/// Which execution engine drives a training step.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecEngine {
    /// Coordinator-driven, single OS thread: the step program runs
    /// op-major (all ranks execute op i before any executes op i+1) —
    /// the strict-BSP reference the parity tests compare against, and
    /// the engine the calibrated benches time (contention-free
    /// compute).
    Sequential,
    /// One scoped thread per worker; blocking transport takes; BSP
    /// barrier between the MP phase and model averaging. The default,
    /// matching `ClusterConfig::default()`.
    #[default]
    Threaded,
}

impl ExecEngine {
    /// Parse a CLI token: `sequential`/`seq` or `threaded`/`thread`.
    pub fn parse(s: &str) -> Result<ExecEngine> {
        match s.to_ascii_lowercase().as_str() {
            "sequential" | "seq" => Ok(ExecEngine::Sequential),
            "threaded" | "thread" | "threads" => Ok(ExecEngine::Threaded),
            other => bail!("unknown engine {other:?} (expected sequential or threaded)"),
        }
    }
}

impl std::fmt::Display for ExecEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ExecEngine::Sequential => "sequential",
            ExecEngine::Threaded => "threaded",
        })
    }
}

/// Everything a worker thread needs for one step (shared, read-only).
pub(crate) struct StepCtx<'a> {
    /// The shared executor context (runtime, transport, topology,
    /// schedule, scheme, collectives, averaging flag).
    pub exec: ExecCtx<'a>,
    /// The compiled step program every thread executes.
    pub program: &'a StepProgram,
    /// BSP superstep barrier (MP phase → averaging phase), one slot per
    /// worker.
    pub barrier: &'a Barrier,
}

/// Run one training step with one scoped thread per worker, each
/// executing the compiled step program. While the workers compute, the
/// **coordinator thread** (which would otherwise idle in the join)
/// assembles the next step's batches from `iters` when provided —
/// overlap's double buffering, genuinely off the step's critical path.
/// Returns after every thread joined, with the prefetched batches.
/// A typed root-cause error ([`WorkerCrashed`] / [`PeerLost`]) is
/// propagated in preference to the secondary teardown errors of healthy
/// peers; otherwise the first error by rank order wins.
pub(crate) fn run_threaded_step(
    workers: &mut [Worker],
    batches: &[Batch],
    iters: Option<&mut [BatchIter]>,
    ctx: &StepCtx<'_>,
) -> Result<Option<Vec<Batch>>> {
    let (results, next): (Vec<Result<()>>, Option<Vec<Batch>>) = std::thread::scope(|s| {
        let handles: Vec<_> = workers
            .iter_mut()
            .zip(batches.iter())
            .enumerate()
            .map(|(rank, (w, batch))| {
                s.spawn(move || {
                    run_rank_threaded(ctx.program, rank, w, batch, &ctx.exec, ctx.barrier)
                })
            })
            .collect();
        // Prefetch concurrently with the workers' compute. Fetched
        // unconditionally (even if a worker then fails), so every
        // rank's iterator advances uniformly; elastic recovery rebuilds
        // iterators from scratch either way.
        let next: Option<Vec<Batch>> =
            iters.map(|its| its.iter_mut().map(|it| it.next_batch()).collect());
        let results = handles
            .into_iter()
            .map(|h| {
                h.join()
                    .unwrap_or_else(|_| Err(anyhow!("worker thread panicked")))
            })
            .collect();
        (results, next)
    });
    // Root-cause preference: typed fault errors, then ordinary worker
    // errors, then the secondary StepAborted teardown errors.
    let mut typed: Option<anyhow::Error> = None;
    let mut plain: Option<anyhow::Error> = None;
    let mut aborted: Option<anyhow::Error> = None;
    for r in results {
        if let Err(e) = r {
            if e.is::<WorkerCrashed>() || e.is::<PeerLost>() {
                typed.get_or_insert(e);
            } else if e.is::<StepAborted>() {
                aborted.get_or_insert(e);
            } else {
                plain.get_or_insert(e);
            }
        }
    }
    match typed.or(plain).or(aborted) {
        Some(e) => Err(e),
        None => Ok(next),
    }
}
