//! The multi-threaded cluster execution engine.
//!
//! The seed drove every worker sequentially on one OS thread: the
//! coordinator interleaved each BSP phase "god-view" (post everything,
//! then take everything), so throughput could not scale with workers.
//! This engine runs **each worker's whole step on its own scoped
//! thread** — segment compute, modulo/shard exchanges and averaging
//! included — with rendezvous provided by the thread-safe
//! [`Fabric`](crate::comm::Fabric)'s blocking takes and one BSP barrier
//! at the superstep boundary (MP phase → averaging phase), driven by
//! the coordinator schedule.
//!
//! ## Bit-identical numerics
//!
//! The per-rank programs here perform the *same arithmetic in the same
//! order* as the sequential engine's group-view loops (own contribution
//! first, then peers in group order; identical collective round
//! structure), and the segment runtime is deterministic — so threaded
//! and sequential training runs agree bit-for-bit. The
//! `engine_parity` integration test asserts exactly this over ≥10
//! steps.
//!
//! ## Failure semantics
//!
//! A worker error (injected crash, bad artifact, schedule bug) does not
//! hang the step: the erroring thread still reaches the barrier, and it
//! aborts the step on the fabric first, so peers parked on blocking
//! takes wake immediately with a typed error — [`PeerLost`] when the
//! failed rank is dead, `StepAborted` otherwise — instead of waiting
//! out the take timeout. After all threads join, a typed
//! [`WorkerCrashed`]/[`PeerLost`] error is propagated in preference to
//! the secondary teardown errors, so the cluster driver (and its
//! `RecoveryPolicy`) sees the root cause.
//!
//! Injected faults ([`FaultPlan`](crate::comm::fault::FaultPlan)) enter
//! here and in the fabric: each rank polls for a scheduled crash at the
//! top of its MP phase; message drops/delays fire inside
//! [`Transport::post`](crate::comm::transport::Transport::post); straggles are charged by the cluster driver to the
//! simulated compute clock.

use std::sync::Barrier;

use anyhow::{anyhow, bail, Result};

use crate::comm::collective::CollectiveAlgo;
use crate::comm::fabric::Tag;
use crate::comm::transport::Transport;
use crate::comm::fault::{PeerLost, StepAborted, WorkerCrashed};
use crate::data::Batch;
use crate::runtime::{HostTensor, RuntimeClient};
use crate::util::Timer;

use super::averaging::average_rank;
use super::group::GmpTopology;
use super::modulo::ModuloPlan;
use super::schedule::StepSchedule;
use super::scheme::{
    assemble_bk_rank, assemble_scheme_b_rank, scatter_reduce_bk_rank,
    scatter_reduce_scheme_b_rank, McastScheme,
};
use super::shard::{ShardBwdMode, ShardPlan};
use super::worker::Worker;

/// Which execution engine drives a training step.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecEngine {
    /// Coordinator-interleaved, single OS thread (the seed behavior;
    /// also the reference the parity test compares against).
    Sequential,
    /// One scoped thread per worker; blocking fabric takes; BSP barrier
    /// between the MP phase and model averaging. The default, matching
    /// `ClusterConfig::default()`.
    #[default]
    Threaded,
}

impl ExecEngine {
    /// Parse a CLI token: `sequential`/`seq` or `threaded`/`thread`.
    pub fn parse(s: &str) -> Result<ExecEngine> {
        match s.to_ascii_lowercase().as_str() {
            "sequential" | "seq" => Ok(ExecEngine::Sequential),
            "threaded" | "thread" | "threads" => Ok(ExecEngine::Threaded),
            other => bail!("unknown engine {other:?} (expected sequential or threaded)"),
        }
    }
}

impl std::fmt::Display for ExecEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ExecEngine::Sequential => "sequential",
            ExecEngine::Threaded => "threaded",
        })
    }
}

/// Everything a worker thread needs for one step (shared, read-only).
pub(crate) struct StepCtx<'a> {
    pub rt: &'a RuntimeClient,
    pub fabric: &'a dyn Transport,
    pub topo: &'a GmpTopology,
    pub schedule: &'a StepSchedule,
    pub scheme: McastScheme,
    pub algo: CollectiveAlgo,
    pub segmented_mp1: bool,
    pub batch: usize,
    /// Whether model averaging fires at the end of this step.
    pub averaging: bool,
    /// BSP superstep barrier (MP phase → averaging phase), one slot per
    /// worker.
    pub barrier: &'a Barrier,
}

/// Run one training step with one scoped thread per worker. Returns
/// after every thread joined. A typed root-cause error
/// ([`WorkerCrashed`] / [`PeerLost`]) is propagated in preference to
/// the secondary teardown errors of healthy peers; otherwise the first
/// error by rank order wins.
pub(crate) fn run_threaded_step(
    workers: &mut [Worker],
    batches: &[Batch],
    ctx: &StepCtx<'_>,
) -> Result<()> {
    let results: Vec<Result<()>> = std::thread::scope(|s| {
        let handles: Vec<_> = workers
            .iter_mut()
            .zip(batches.iter())
            .enumerate()
            .map(|(rank, (w, batch))| s.spawn(move || worker_step(rank, w, batch, ctx)))
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join()
                    .unwrap_or_else(|_| Err(anyhow!("worker thread panicked")))
            })
            .collect()
    });
    // Root-cause preference: typed fault errors, then ordinary worker
    // errors, then the secondary StepAborted teardown errors.
    let mut typed: Option<anyhow::Error> = None;
    let mut plain: Option<anyhow::Error> = None;
    let mut aborted: Option<anyhow::Error> = None;
    for r in results {
        if let Err(e) = r {
            if e.is::<WorkerCrashed>() || e.is::<PeerLost>() {
                typed.get_or_insert(e);
            } else if e.is::<StepAborted>() {
                aborted.get_or_insert(e);
            } else {
                plain.get_or_insert(e);
            }
        }
    }
    match typed.or(plain).or(aborted) {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// One worker's whole step: crash poll, MP phase, superstep barrier,
/// averaging. The barrier is reached on error *and panic* paths too
/// (panics are caught and converted to errors), so a failing worker
/// never wedges its peers at the barrier. Any failure aborts the step
/// on the fabric before the barrier, so peers parked on blocking takes
/// wake with a typed error instead of waiting out the take timeout.
fn worker_step(rank: usize, w: &mut Worker, batch: &Batch, ctx: &StepCtx<'_>) -> Result<()> {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    let mp = if ctx.fabric.poll_crash(rank) {
        // Injected fault: this rank dies at the top of its MP phase.
        // poll_crash already declared it dead and aborted the step.
        Err(WorkerCrashed { rank, step: ctx.fabric.current_step() }.into())
    } else {
        catch_unwind(AssertUnwindSafe(|| {
            if ctx.topo.mp == 1 && !ctx.segmented_mp1 {
                full_step_rank(&mut *w, batch, ctx)
            } else {
                group_step_rank(rank, &mut *w, batch, ctx)
            }
        }))
        .unwrap_or_else(|_| Err(anyhow!("worker {rank} panicked in the MP phase")))
    };
    if mp.is_err() {
        ctx.fabric.abort_step();
    }
    ctx.barrier.wait();
    let avg = if mp.is_ok() && ctx.averaging {
        catch_unwind(AssertUnwindSafe(|| {
            average_rank(ctx.fabric, &mut *w, rank, ctx.topo.n_workers, ctx.topo, ctx.algo)
        }))
        .unwrap_or_else(|_| Err(anyhow!("worker {rank} panicked in averaging")))
    } else {
        Ok(())
    };
    if avg.is_err() {
        ctx.fabric.abort_step();
    }
    mp.and(avg)
}

/// mp=1 fast path: one fused full_step call + local SGD update for one
/// worker. Shared by the sequential engine's `step_pure_dp` loop and
/// the threaded per-rank program, so the two can never drift apart.
pub(crate) fn full_step_worker(rt: &RuntimeClient, w: &mut Worker, batch: &Batch) -> Result<()> {
    let t = Timer::start();
    let mut inputs: Vec<HostTensor> =
        Vec::with_capacity(w.conv_params.len() + w.fc_params.len() + 2);
    inputs.extend(w.conv_params.iter().cloned());
    inputs.extend(w.fc_params.iter().cloned());
    inputs.push(batch.images.clone());
    inputs.push(batch.labels.clone());
    let out = rt.run("full_step", &inputs)?;
    w.loss_acc += out[0].scalar() as f64;
    let conv_grads = &out[1..15];
    let fc_grads = &out[15..21];
    w.update_conv(conv_grads);
    let fcg: Vec<(usize, HostTensor)> = fc_grads.iter().cloned().enumerate().collect();
    w.accumulate_fc_grads(&fcg);
    w.update_fc(1);
    w.compute_secs += t.elapsed_secs();
    Ok(())
}

pub(crate) fn full_step_rank(w: &mut Worker, batch: &Batch, ctx: &StepCtx<'_>) -> Result<()> {
    full_step_worker(ctx.rt, w, batch)
}

/// The hybrid path, per rank: Fig. 3's transformed network phase by
/// phase — the SPMD mirror of the sequential engine's `step_group`,
/// with blocking per-rank exchanges instead of god-view collectives.
pub(crate) fn group_step_rank(rank: usize, w: &mut Worker, batch: &Batch, ctx: &StepCtx<'_>) -> Result<()> {
    let gid = ctx.topo.gid(rank);
    let members = ctx.topo.members(gid);
    let gi = ctx.topo.offset(rank);
    let k = members.len();
    let b = ctx.batch;
    let fabric = ctx.fabric;
    let boundary = ctx.schedule.boundary_width;
    let s0 = ctx.schedule.shard_widths[0];
    let s1 = ctx.schedule.shard_widths[1];

    let modulo = ModuloPlan::new(members.clone(), b, boundary);
    let modulo_lab = ModuloPlan::new(members.clone(), b, 1);
    let shard0 = ShardPlan::new(members.clone(), s0, ShardBwdMode::ReducePartials)
        .with_algo(ctx.algo);
    let shard1 = ShardPlan::new(members.clone(), s1, ShardBwdMode::SliceReplicated)
        .with_algo(ctx.algo);

    // --- conv fwd ---
    let t = Timer::start();
    let mut inputs: Vec<HostTensor> = w.conv_params.to_vec();
    inputs.push(batch.images.clone());
    let act = ctx
        .rt
        .run("conv_fwd", &inputs)?
        .into_iter()
        .next()
        .expect("conv_fwd returns one output");
    w.compute_secs += t.elapsed_secs();
    let labels_f32 = HostTensor::f32(
        vec![b, 1],
        batch.labels.as_i32().iter().map(|&v| v as f32).collect(),
    );

    // --- modulo rounds through the FC stack ---
    let scheme = if k > 1 { ctx.scheme } else { McastScheme::BoverK };
    let rounds = scheme.rounds(k);
    let fcb = scheme.fc_batch(b, k);
    let suffix = scheme.artifact_suffix();
    let head_name = match scheme {
        McastScheme::BK if k > 1 => format!("head_step_bk{k}"),
        _ => "head_step".to_string(),
    };
    for it in 0..rounds {
        let tag = |phase: u16| Tag::new(phase, it, gid);

        // Modulo fprop: assemble activations + labels.
        let (assembled, labs) = match scheme {
            McastScheme::BoverK => (
                modulo.assemble_rank(fabric, gi, &act, it, tag(1))?,
                modulo_lab.assemble_rank(fabric, gi, &labels_f32, it, tag(2))?,
            ),
            McastScheme::B => (
                assemble_scheme_b_rank(&modulo, fabric, gi, &act, it, tag(1))?,
                assemble_scheme_b_rank(&modulo_lab, fabric, gi, &labels_f32, it, tag(2))?,
            ),
            McastScheme::BK => (
                assemble_bk_rank(&modulo, fabric, gi, &act, tag(1))?,
                assemble_bk_rank(&modulo_lab, fabric, gi, &labels_f32, tag(2))?,
            ),
        };

        // FC0 shard fwd + gather to full width.
        let t = Timer::start();
        let h0l = ctx
            .rt
            .run(
                &format!("fc0_fwd_k{k}{suffix}"),
                &[w.fc_params[0].clone(), w.fc_params[1].clone(), assembled.clone()],
            )?
            .into_iter()
            .next()
            .expect("fc0_fwd returns one output");
        w.compute_secs += t.elapsed_secs();
        let h0 = shard0.gather_full_rank(fabric, gi, &h0l, tag(3))?;

        // FC1 shard fwd + gather.
        let t = Timer::start();
        let h1l = ctx
            .rt
            .run(
                &format!("fc1_fwd_k{k}{suffix}"),
                &[w.fc_params[2].clone(), w.fc_params[3].clone(), h0.clone()],
            )?
            .into_iter()
            .next()
            .expect("fc1_fwd returns one output");
        w.compute_secs += t.elapsed_secs();
        let h1 = shard1.gather_full_rank(fabric, gi, &h1l, tag(4))?;

        // Replicated head: loss + gw2 + gb2 + gh1.
        let labels_i32 = HostTensor::i32(
            vec![fcb],
            labs.as_f32().iter().map(|&v| v as i32).collect(),
        );
        let t = Timer::start();
        let out = ctx.rt.run(
            &head_name,
            &[w.fc_params[4].clone(), w.fc_params[5].clone(), h1.clone(), labels_i32],
        )?;
        w.compute_secs += t.elapsed_secs();
        w.loss_acc += out[0].scalar() as f64;
        w.accumulate_fc_grads(&[(4, out[1].clone()), (5, out[2].clone())]);
        let gh1_full = out[3].clone();

        // Shard1 bwd: replicated above -> local slice, no wire.
        let g_h1l = shard1.backward_rank(fabric, gi, &gh1_full, tag(5))?;

        // FC1 shard bwd.
        let t = Timer::start();
        let out = ctx.rt.run(
            &format!("fc1_bwd_k{k}{suffix}"),
            &[
                w.fc_params[2].clone(),
                w.fc_params[3].clone(),
                h0.clone(),
                g_h1l.clone(),
            ],
        )?;
        w.compute_secs += t.elapsed_secs();
        w.accumulate_fc_grads(&[(2, out[0].clone()), (3, out[1].clone())]);
        let gh0_partial = out[2].clone();

        // Shard0 bwd: partitioned above -> reduce partials.
        let g_h0l = shard0.backward_rank(fabric, gi, &gh0_partial, tag(6))?;

        // FC0 shard bwd.
        let t = Timer::start();
        let out = ctx.rt.run(
            &format!("fc0_bwd_k{k}{suffix}"),
            &[
                w.fc_params[0].clone(),
                w.fc_params[1].clone(),
                assembled.clone(),
                g_h0l.clone(),
            ],
        )?;
        w.compute_secs += t.elapsed_secs();
        w.accumulate_fc_grads(&[(0, out[0].clone()), (1, out[1].clone())]);
        let gbatch_partial = out[2].clone();

        // Modulo bprop: route + reduce into this member's g_act.
        match scheme {
            McastScheme::BoverK => {
                modulo.scatter_reduce_rank(fabric, gi, &gbatch_partial, &mut w.g_act, it, tag(7))?
            }
            McastScheme::B => scatter_reduce_scheme_b_rank(
                &modulo, fabric, gi, &gbatch_partial, &mut w.g_act, it, tag(7),
            )?,
            McastScheme::BK => {
                scatter_reduce_bk_rank(&modulo, fabric, gi, &gbatch_partial, &mut w.g_act, tag(7))?;
                // LR consistency: BK's head averaged over B*K examples —
                // rescale exactly as the sequential engine does.
                w.g_act.scale(k as f32);
            }
        }
    }

    // --- conv bwd + optimizer updates ---
    let t = Timer::start();
    let mut inputs: Vec<HostTensor> = w.conv_params.to_vec();
    inputs.push(batch.images.clone());
    inputs.push(w.g_act.clone());
    let grads = ctx.rt.run("conv_bwd", &inputs)?;
    w.update_conv(&grads);
    w.update_fc(rounds);
    w.compute_secs += t.elapsed_secs();
    Ok(())
}
