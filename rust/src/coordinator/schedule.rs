//! The per-step execution schedule, compiled from the transformed
//! network + topology.
//!
//! Two consumers:
//! * the numeric cluster driver validates its hard-wired execution loop
//!   against this schedule (artifact inventory, widths, batch), and
//! * the calibrated simulator and the analytic benches read the
//!   per-phase communication volumes, which unit tests cross-check
//!   against the fabric's measured byte counters.

use anyhow::{bail, Result};

use crate::comm::collective::{rhd_worst_rank_volume, CollectiveAlgo};
use crate::comm::netmodel::{NetModel, PhaseVolume};
use crate::comm::trace::CommCategory;
use crate::model::{Layer, TransformedNet};
use crate::runtime::Manifest;

use super::group::GmpTopology;
use super::scheme::McastScheme;

/// One compute segment of a step: artifact name + how many times it
/// runs per step on each worker.
#[derive(Debug, Clone, PartialEq)]
pub struct ComputeCall {
    /// Artifact name.
    pub artifact: String,
    /// Calls per step per worker.
    pub calls: u64,
}

/// One communication phase per step: category + per-member volume +
/// how many times it recurs per step.
#[derive(Debug, Clone, PartialEq)]
pub struct CommPhase {
    /// What the exchange is for.
    pub category: CommCategory,
    /// Posted volume of one member per occurrence.
    pub per_member: PhaseVolume,
    /// Occurrences per step.
    pub times: u64,
    /// Participants (K for MP phases, N or D for averaging).
    pub ranks: usize,
}

/// The compiled step: everything the simulator needs to cost one
/// training step of one worker/group.
#[derive(Debug, Clone)]
pub struct StepSchedule {
    /// The DP×MP topology the schedule was compiled for.
    pub topo: GmpTopology,
    /// Per-worker batch size.
    pub batch: usize,
    /// Collective algorithm the phase volumes were modeled for.
    pub algo: CollectiveAlgo,
    /// Feature width at the modulo boundary.
    pub boundary_width: usize,
    /// Partition widths of the sharded FC layers (full widths / K).
    pub shard_widths: Vec<usize>,
    /// Compute inventory: artifact calls per step per worker.
    pub compute: Vec<ComputeCall>,
    /// MP phases, charged every step.
    pub mp_phases: Vec<CommPhase>,
    /// Averaging phases, charged every `avg_period` steps.
    pub avg_phases: Vec<CommPhase>,
    /// Replicated parameter count (conv + FC2 + biases) for averaging.
    pub replicated_params: usize,
    /// Per-shard parameter count (FC0+FC1 shards + biases).
    pub shard_params: usize,
}

impl StepSchedule {
    /// Compile the schedule from a transformed net. Checks the manifest
    /// carries every artifact the schedule needs.
    pub fn compile(
        net: &TransformedNet,
        topo: GmpTopology,
        manifest: &Manifest,
    ) -> Result<StepSchedule> {
        Self::compile_full(net, topo, manifest, false, McastScheme::BoverK)
    }

    /// Back-compat shim: `compile` with the segmented-mp1 switch.
    pub fn compile_opts(
        net: &TransformedNet,
        topo: GmpTopology,
        manifest: &Manifest,
        segmented_mp1: bool,
    ) -> Result<StepSchedule> {
        Self::compile_full(net, topo, manifest, segmented_mp1, McastScheme::BoverK)
    }

    /// Back-compat shim: [`StepSchedule::compile_with_algo`] with the
    /// naive (all-to-all) model for *both* shard and averaging phases.
    /// Note this differs from the seed, which modeled averaging as a
    /// ring allreduce: runtime consumers (cluster, planner, benches)
    /// should pass the configured algorithm through
    /// [`StepSchedule::compile_with_algo`] instead, as they now do.
    pub fn compile_full(
        net: &TransformedNet,
        topo: GmpTopology,
        manifest: &Manifest,
        segmented_mp1: bool,
        scheme: McastScheme,
    ) -> Result<StepSchedule> {
        Self::compile_with_algo(net, topo, manifest, segmented_mp1, scheme, CollectiveAlgo::Naive)
    }

    /// Full compile: `segmented_mp1` selects the per-segment
    /// (Pallas-backed) pipeline for mp=1 instead of the fused
    /// `full_step` (numerically identical, same per-op efficiency as
    /// the MP paths — used by the Table 2 benches); `scheme` selects
    /// the §3.1 communication scheme for the modulo layer; `algo`
    /// selects the collective algorithm modeled for the shard exchanges
    /// and BSP averaging (total shard bytes are algorithm-invariant,
    /// message/phase structure is not).
    pub fn compile_with_algo(
        net: &TransformedNet,
        topo: GmpTopology,
        manifest: &Manifest,
        segmented_mp1: bool,
        scheme: McastScheme,
        algo: CollectiveAlgo,
    ) -> Result<StepSchedule> {
        if net.mp != topo.mp {
            bail!("net transformed for mp={} but topology has mp={}", net.mp, topo.mp);
        }
        let batch = manifest.batch;
        let k = topo.mp;

        // --- derive structure from the transformed layers ---
        let mut boundary_width = 0usize;
        let mut shard_widths = Vec::new();
        let mut replicated_params = 0usize;
        let mut shard_params = 0usize;
        let mut first_linear_din = 0usize;
        let mut linear_douts = Vec::new();
        for l in &net.layers {
            match l {
                Layer::Modulo { dim } => boundary_width = *dim,
                Layer::Linear { shard_of: Some(_), .. } => {
                    shard_params += l.param_count();
                    if let Layer::Linear { dout, .. } = l {
                        shard_widths.push(*dout);
                    }
                }
                Layer::Conv { .. } | Layer::Linear { shard_of: None, .. } => {
                    replicated_params += l.param_count();
                }
                _ => {}
            }
            if let Layer::Linear { din, dout, .. } = l {
                if first_linear_din == 0 {
                    first_linear_din = *din;
                }
                linear_douts.push(*dout);
            }
        }

        // --- compute inventory ---
        let mut compute = Vec::new();
        if k == 1 && segmented_mp1 {
            // Segmented baseline: same pipeline, full-width "shards".
            boundary_width = first_linear_din;
            shard_widths = linear_douts[..linear_douts.len() - 1].to_vec();
            compute.push(ComputeCall { artifact: "conv_fwd".into(), calls: 1 });
            compute.push(ComputeCall { artifact: "conv_bwd".into(), calls: 1 });
            for name in ["fc0_fwd_k1", "fc0_bwd_k1", "fc1_fwd_k1", "fc1_bwd_k1"] {
                compute.push(ComputeCall { artifact: name.into(), calls: 1 });
            }
            compute.push(ComputeCall { artifact: "head_step".into(), calls: 1 });
        } else if k == 1 {
            compute.push(ComputeCall { artifact: "full_step".into(), calls: 1 });
        } else {
            if shard_widths.len() != 2 {
                bail!(
                    "schedule supports the two-sharded-FC VGG shape; found {} sharded linears",
                    shard_widths.len()
                );
            }
            let rounds = scheme.rounds(k) as u64;
            let suffix = scheme.artifact_suffix();
            compute.push(ComputeCall { artifact: "conv_fwd".into(), calls: 1 });
            compute.push(ComputeCall { artifact: "conv_bwd".into(), calls: 1 });
            for name in ["fc0_fwd", "fc0_bwd", "fc1_fwd", "fc1_bwd"] {
                compute.push(ComputeCall {
                    artifact: format!("{name}_k{k}{suffix}"),
                    calls: rounds,
                });
            }
            let head = match scheme {
                McastScheme::BK => format!("head_step_bk{k}"),
                _ => "head_step".to_string(),
            };
            compute.push(ComputeCall { artifact: head, calls: rounds });
        }
        for c in &compute {
            manifest.get(&c.artifact)?; // fail loudly on missing artifacts
        }

        // --- MP communication phases (per step), scheme-aware ---
        let mut mp_phases = Vec::new();
        if k > 1 {
            let rounds = scheme.rounds(k) as u64;
            let fcb = scheme.fc_batch(batch, k);
            // Modulo exchange: per-round busiest-sender volume differs by
            // scheme (see scheme.rs table). Labels ride along in fwd.
            let (mod_bytes, mod_msgs) = match scheme {
                // every member pushes its B/K slice to K-1 peers
                McastScheme::BoverK => {
                    let size = batch / k;
                    (((k - 1) * size * (boundary_width + 1) * 4) as u64, 2 * (k as u64 - 1))
                }
                // the round's owner pushes its whole batch to K-1 peers —
                // serialized on one sender, the scheme's flaw
                McastScheme::B => {
                    (((k - 1) * batch * (boundary_width + 1) * 4) as u64, 2 * (k as u64 - 1))
                }
                // all members push whole batches simultaneously, once
                McastScheme::BK => {
                    (((k - 1) * batch * (boundary_width + 1) * 4) as u64, 2 * (k as u64 - 1))
                }
            };
            mp_phases.push(CommPhase {
                category: CommCategory::ModuloFwd,
                per_member: PhaseVolume::new(mod_msgs, mod_bytes),
                times: rounds,
                ranks: k,
            });
            // Modulo bwd mirrors fwd volume (gradients routed back),
            // without the label bytes.
            let bwd_bytes = match scheme {
                McastScheme::BoverK => (((k - 1) * (batch / k) * boundary_width) * 4) as u64,
                _ => (((k - 1) * batch * boundary_width) * 4) as u64,
            };
            mp_phases.push(CommPhase {
                category: CommCategory::ModuloBwd,
                per_member: PhaseVolume::new(k as u64 - 1, bwd_bytes),
                times: rounds,
                ranks: k,
            });
            // Shard fwd: allgather each sharded FC's output partition
            // over the scheme's FC batch. Naive: one phase of k-1
            // partition-sized messages per round. Ring (and the rhd
            // fallback): k-1 serialized neighbor rounds of one message —
            // identical total bytes, different phase structure.
            let shard_phase = |w: usize| -> (PhaseVolume, u64) {
                match algo {
                    CollectiveAlgo::Naive => (
                        PhaseVolume::new(k as u64 - 1, ((k - 1) * fcb * w * 4) as u64),
                        rounds,
                    ),
                    CollectiveAlgo::Ring | CollectiveAlgo::Rhd => (
                        PhaseVolume::new(1, (fcb * w * 4) as u64),
                        rounds * (k as u64 - 1),
                    ),
                }
            };
            for &w in &shard_widths {
                let (per_member, times) = shard_phase(w);
                mp_phases.push(CommPhase {
                    category: CommCategory::ShardFwd,
                    per_member,
                    times,
                    ranks: k,
                });
            }
            // Shard bwd: only the *first* sharded FC's input shard layer
            // reduces partials (the one above it feeds replicated FC2 ->
            // zero-comm slice). In transformed order: the shard between
            // FC0 and FC1 reduces over FC1's bwd partials (width = FC0's
            // partition), the shard before FC2 slices.
            let (per_member, times) = shard_phase(shard_widths[0]);
            mp_phases.push(CommPhase {
                category: CommCategory::ShardBwd,
                per_member,
                times,
                ranks: k,
            });
        }

        // --- averaging phases (per averaging event) ---
        // Worst-rank allreduce volume for `bytes` over `m` ranks under
        // the selected algorithm.
        let allreduce_vol = |m: usize, bytes: u64| -> PhaseVolume {
            match algo {
                CollectiveAlgo::Naive => {
                    PhaseVolume::new(m as u64 - 1, (m as u64 - 1) * bytes)
                }
                CollectiveAlgo::Ring => PhaseVolume::new(
                    2 * (m as u64 - 1),
                    2 * (m as u64 - 1) * (bytes / m as u64),
                ),
                CollectiveAlgo::Rhd => rhd_worst_rank_volume(m, bytes),
            }
        };
        let mut avg_phases = Vec::new();
        let n = topo.n_workers;
        if n > 1 {
            // Replicated params: allreduce across all N.
            let bytes = (replicated_params * 4) as u64;
            avg_phases.push(CommPhase {
                category: CommCategory::DpAverage,
                per_member: allreduce_vol(n, bytes),
                times: 1,
                ranks: n,
            });
        }
        let d = topo.n_groups();
        if d > 1 && k > 1 {
            // Shard params: allreduce across the D same-offset peers.
            let bytes = (shard_params * 4) as u64;
            avg_phases.push(CommPhase {
                category: CommCategory::ShardAverage,
                per_member: allreduce_vol(d, bytes),
                times: 1,
                ranks: d,
            });
        }

        Ok(StepSchedule {
            topo,
            batch,
            algo,
            boundary_width,
            shard_widths,
            compute,
            mp_phases,
            avg_phases,
            replicated_params,
            shard_params,
        })
    }

    /// Compile the per-rank step program for this schedule — the op
    /// list every execution engine drives (see
    /// [`super::program::StepProgram`]). `overlap` hoists the modulo
    /// post halves for comm/compute overlap; numerics are identical
    /// either way.
    pub fn compile_program(
        &self,
        scheme: McastScheme,
        segmented_mp1: bool,
        overlap: bool,
    ) -> super::program::StepProgram {
        super::program::StepProgram::compile(self, scheme, segmented_mp1, overlap)
    }

    /// Modeled MP communication seconds per step.
    pub fn mp_comm_secs(&self, net: &NetModel) -> f64 {
        let t: f64 = self
            .mp_phases
            .iter()
            .map(|p| p.times as f64 * net.phase_time(p.per_member))
            .sum();
        t.max(0.0) // normalize -0.0 from empty phase lists
    }

    /// Modeled averaging seconds per averaging event.
    pub fn avg_comm_secs(&self, net: &NetModel) -> f64 {
        self.avg_phases
            .iter()
            .map(|p| p.times as f64 * net.phase_time(p.per_member))
            .sum()
    }

    /// Total MP bytes a single member pushes per step.
    pub fn mp_bytes_per_member(&self) -> u64 {
        self.mp_phases.iter().map(|p| p.times * p.per_member.bytes_out).sum()
    }

    /// Total averaging bytes the busiest member pushes per averaging
    /// event.
    pub fn avg_bytes_per_member(&self) -> u64 {
        self.avg_phases.iter().map(|p| p.times * p.per_member.bytes_out).sum()
    }

    /// Forward-only (serving) bytes a single member pushes per step:
    /// the modulo activation exchange *without* its label column (no
    /// label rides a forward-only step) plus the shard-allgather
    /// forward phases. Serving always runs scheme B/K; the shard term
    /// reuses the compiled phases because their per-step total is
    /// scheme-invariant (k rounds of B rows ≡ one round of B·K rows).
    /// Zero for k = 1 — a single-member group exchanges nothing.
    pub fn infer_bytes_per_member(&self) -> u64 {
        let k = self.topo.mp;
        if k <= 1 {
            return 0;
        }
        let size = (self.batch / k).max(1);
        let modulo = (k * (k - 1) * size * self.boundary_width * 4) as u64;
        let shard: u64 = self
            .mp_phases
            .iter()
            .filter(|p| p.category == CommCategory::ShardFwd)
            .map(|p| p.times * p.per_member.bytes_out)
            .sum();
        modulo + shard
    }

    /// Forward-only bytes per served request — the per-request network
    /// price of sharding. One step serves k·B requests across k
    /// members, so this is the member volume over B.
    pub fn infer_bytes_per_request(&self) -> f64 {
        self.infer_bytes_per_member() as f64 / self.batch.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{partition_network, vgg11, PartitionConfig};
    use std::path::PathBuf;

    fn manifest(batch: usize, ks: &[usize]) -> Manifest {
        // Synthesise a minimal manifest accepted by compile().
        let mut text = format!(
            "splitbrain-artifacts v1\nbatch {batch}\nmp_sizes {}\nfeature_dim 4096\nnum_classes 10\n",
            ks.iter().map(|k| k.to_string()).collect::<Vec<_>>().join(",")
        );
        let mut add = |name: &str| {
            text.push_str(&format!(
                "artifact {name} file={name}.hlo.txt\nin x float32 1\nout y float32 1\nend\n"
            ));
        };
        for name in ["conv_fwd", "conv_bwd", "full_step", "full_eval", "head_step", "head_fwd"] {
            add(name);
        }
        for &k in ks {
            if k > 1 {
                for seg in ["fc0_fwd", "fc0_bwd", "fc1_fwd", "fc1_bwd"] {
                    add(&format!("{seg}_k{k}"));
                }
            }
        }
        Manifest::parse(&text, PathBuf::from("/tmp")).unwrap()
    }

    fn schedule(n: usize, mp: usize, batch: usize) -> StepSchedule {
        let net = partition_network(
            &vgg11(),
            vec![32, 32, 3],
            &PartitionConfig { mp, ..Default::default() },
        )
        .unwrap();
        let topo = GmpTopology::new(n, mp).unwrap();
        StepSchedule::compile(&net, topo, &manifest(batch, &[1, 2, 4, 8])).unwrap()
    }

    #[test]
    fn infer_volume_is_forward_only() {
        let s = schedule(2, 2, 32);
        let total = |cat: CommCategory| -> u64 {
            s.mp_phases
                .iter()
                .filter(|p| p.category == cat)
                .map(|p| p.times * p.per_member.bytes_out)
                .sum()
        };
        // Serving volume = modulo fwd minus the label column, plus the
        // shard allgathers; no backward phases.
        let label_bytes = 2 * ((32 / 2) * 4) as u64; // rounds × size × 4
        assert_eq!(
            s.infer_bytes_per_member(),
            total(CommCategory::ModuloFwd) - label_bytes + total(CommCategory::ShardFwd)
        );
        assert!(s.infer_bytes_per_member() < s.mp_bytes_per_member());
        let per_req = s.infer_bytes_per_request();
        assert!((per_req * 32.0 - s.infer_bytes_per_member() as f64).abs() < 1e-6);
        // A single-member group exchanges nothing.
        assert_eq!(schedule(2, 1, 32).infer_bytes_per_member(), 0);
    }

    #[test]
    fn pure_dp_uses_full_step() {
        let s = schedule(4, 1, 32);
        assert_eq!(s.compute.len(), 1);
        assert_eq!(s.compute[0].artifact, "full_step");
        assert!(s.mp_phases.is_empty());
        assert_eq!(s.avg_phases.len(), 1);
    }

    #[test]
    fn mp_schedule_runs_fc_segments_k_times() {
        let s = schedule(8, 4, 32);
        let fc0 = s.compute.iter().find(|c| c.artifact == "fc0_fwd_k4").unwrap();
        assert_eq!(fc0.calls, 4);
        let head = s.compute.iter().find(|c| c.artifact == "head_step").unwrap();
        assert_eq!(head.calls, 4);
    }

    #[test]
    fn modulo_volume_matches_plan_formula() {
        use crate::coordinator::modulo::ModuloPlan;
        let s = schedule(2, 2, 32);
        let plan = ModuloPlan::new(vec![0, 1], 32, 4096);
        let phase = s
            .mp_phases
            .iter()
            .find(|p| p.category == CommCategory::ModuloFwd)
            .unwrap();
        // Schedule adds label bytes on top of the activation bytes.
        let lab = (1 * (32 / 2) * 4) as u64;
        assert_eq!(phase.per_member.bytes_out, plan.fwd_bytes_per_member() + lab);
        assert_eq!(phase.times, 2);
    }

    #[test]
    fn shard_volumes_match_plan_formula() {
        use crate::coordinator::shard::{ShardBwdMode, ShardPlan};
        let s = schedule(4, 4, 32);
        let plan = ShardPlan::new(vec![0, 1, 2, 3], 256, ShardBwdMode::ReducePartials);
        let fwd: Vec<_> = s
            .mp_phases
            .iter()
            .filter(|p| p.category == CommCategory::ShardFwd)
            .collect();
        assert_eq!(fwd.len(), 2);
        assert_eq!(fwd[0].per_member.bytes_out, plan.fwd_bytes_per_member(32));
        let bwd = s
            .mp_phases
            .iter()
            .find(|p| p.category == CommCategory::ShardBwd)
            .unwrap();
        assert_eq!(bwd.per_member.bytes_out, plan.bwd_bytes_per_member(32));
    }

    #[test]
    fn averaging_splits_replicated_vs_shard() {
        let s = schedule(8, 2, 32);
        assert_eq!(s.avg_phases.len(), 2);
        // Replicated = conv (1,735,488 incl. biases) + FC2 (10,250).
        assert_eq!(s.replicated_params, 1_735_488 + 10_250);
        // Shards: (4096*512+512) + (1024*512+512).
        assert_eq!(s.shard_params, 4096 * 512 + 512 + 1024 * 512 + 512);
    }

    #[test]
    fn single_group_has_no_shard_average() {
        let s = schedule(4, 4, 32);
        assert!(s
            .avg_phases
            .iter()
            .all(|p| p.category != CommCategory::ShardAverage));
    }

    #[test]
    fn mp_comm_grows_with_k() {
        let net = NetModel::default();
        let t2 = schedule(8, 2, 32).mp_comm_secs(&net);
        let t4 = schedule(8, 4, 32).mp_comm_secs(&net);
        let t8 = schedule(8, 8, 32).mp_comm_secs(&net);
        assert!(t2 < t4 && t4 < t8, "{t2} {t4} {t8}");
    }

    #[test]
    fn dp_averaging_shrinks_with_mp() {
        // Fig. 7b: "the communication for DP is reduced for fewer
        // parameters to exchange" — replicated volume is constant, but
        // the shard-average volume (per peer set) shrinks with K.
        let net = NetModel::default();
        let s2 = schedule(8, 2, 32);
        let s4 = schedule(8, 4, 32);
        assert!(s4.shard_params < s2.shard_params);
        assert!(s4.avg_comm_secs(&net) < s2.avg_comm_secs(&net));
    }

    #[test]
    fn algo_preserves_shard_bytes_and_shrinks_avg() {
        let net = partition_network(
            &vgg11(),
            vec![32, 32, 3],
            &PartitionConfig { mp: 4, ..Default::default() },
        )
        .unwrap();
        let topo = GmpTopology::new(8, 4).unwrap();
        let m = manifest(32, &[1, 2, 4, 8]);
        let compile = |algo| {
            StepSchedule::compile_with_algo(&net, topo, &m, false, McastScheme::BoverK, algo)
                .unwrap()
        };
        let naive = compile(CollectiveAlgo::Naive);
        let ring = compile(CollectiveAlgo::Ring);
        let rhd = compile(CollectiveAlgo::Rhd);
        // Shard-exchange totals are algorithm-invariant.
        assert_eq!(naive.mp_bytes_per_member(), ring.mp_bytes_per_member());
        assert_eq!(naive.mp_bytes_per_member(), rhd.mp_bytes_per_member());
        // Averaging: ring/rhd move 2·(n-1)/n·V vs naive's (n-1)·V.
        assert!(ring.avg_bytes_per_member() < naive.avg_bytes_per_member());
        let diff = ring.avg_bytes_per_member().abs_diff(rhd.avg_bytes_per_member());
        assert!(
            diff <= naive.avg_bytes_per_member() / 100,
            "ring {} vs rhd {}",
            ring.avg_bytes_per_member(),
            rhd.avg_bytes_per_member()
        );
    }

    #[test]
    fn missing_artifact_is_loud() {
        let net = partition_network(
            &vgg11(),
            vec![32, 32, 3],
            &PartitionConfig { mp: 2, ..Default::default() },
        )
        .unwrap();
        let topo = GmpTopology::new(2, 2).unwrap();
        let m = manifest(32, &[1]); // no k2 artifacts
        assert!(StepSchedule::compile(&net, topo, &m).is_err());
    }
}
