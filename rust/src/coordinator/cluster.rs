//! The cluster driver: SplitBrain's training loop over the simulated
//! cluster.
//!
//! ## Simulation model (DESIGN.md §1)
//!
//! Workers are deterministic state machines. *Numerics are real*: every
//! segment runs through the runtime, every exchange moves real bytes
//! through the fabric, so loss curves and gradients are exactly what an
//! N-machine deployment would compute. *Time is simulated*: each
//! worker's compute seconds are measured around its own segment/host
//! calls, communication seconds come from the α–β model over the
//! schedule's per-phase volumes, and one step costs
//! `max_w(compute_w) + Σ comm phases` on the simulated clock — the BSP
//! critical path. This avoids the distortion of oversubscribing N
//! workers' compute onto one machine's cores and is exactly the
//! quantity Table 2 reports per machine count.
//!
//! ## Engines
//!
//! Both engines execute the **same compiled step program**
//! ([`super::program`]): [`ExecEngine::Threaded`] (default) runs every
//! worker's whole program on its own scoped thread over the
//! thread-safe fabric, with overlapped execution
//! (`ClusterConfig::overlap`, default on) hoisting the program's post
//! halves so exchange overlaps compute; `Sequential` drives the BSP
//! program op-major on the coordinator thread — the strict-BSP
//! reference. The engines are bit-identical (`engine_parity`,
//! `overlap_parity` tests); only host wall-clock differs. Caveat for
//! *measured* compute: the threaded engine oversubscribes this host's
//! cores when N exceeds them, so per-worker `compute_secs` picks up
//! contention — the numeric-fidelity benches therefore measure on the
//! sequential engine (see `bench::run_config`), which times each
//! worker contention-free.
//!
//! ## Modes
//!
//! * [`Cluster`] — full numeric fidelity (training, losses, tests).
//! * [`calibrated_report`] — compute times calibrated once per artifact,
//!   then steps are costed analytically: used by the Table 2 / Fig. 7
//!   sweeps where 32-worker numeric execution would melt the wall clock
//!   without changing the reported shape.
//!
//! ## Failure & recovery
//!
//! Peer loss surfaces from the fabric as a typed `PeerLost` (crash, or
//! a blocking take timing out and presuming its sender dead). Under
//! [`RecoveryPolicy::ShrinkAndContinue`] the cluster then re-plans over
//! the survivor set — shrunk GMP topology (`planner::survivor_mp`),
//! re-partitioned network, recompiled schedule — restores weights from
//! the latest in-memory global checkpoint (refreshed at every averaging
//! boundary) and retries the step. Deterministic failure scenarios are
//! injected via `ClusterConfig::faults` (see `comm::fault`); a run with
//! a fixed (seed, plan) pair replays bit-identically, recovery
//! included. See `docs/ARCHITECTURE.md` §Failure semantics & recovery.

use anyhow::{bail, Result};

use crate::comm::collective::CollectiveAlgo;
use crate::comm::fabric::{Fabric, TAKE_TIMEOUT_SECS};
use crate::comm::fault::FaultPlan;
use crate::comm::NetModel;
use crate::data::{Batch, BatchIter, Dataset};
use crate::model::{partition_network, PartitionConfig, TransformedNet, vgg11};
use crate::runtime::{HostTensor, RuntimeClient};
use crate::train::{MemoryReport, StepMetrics, TrainReport};
use crate::util::Timer;

use super::engine::{run_threaded_step, ExecEngine, StepCtx};
use super::group::GmpTopology;
use super::program::{ExecCtx, StepProgram};
use super::schedule::StepSchedule;
use super::scheme::McastScheme;
use super::worker::{init_full_params, Worker, WorkerSnapshot};

/// What the cluster does when a peer is lost mid-run (crash, or a
/// fabric take timing out and presuming its sender dead).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RecoveryPolicy {
    /// Propagate the typed `PeerLost`/`WorkerCrashed` error to the
    /// caller and leave the cluster as-is (the seed behavior, minus the
    /// opaque timeout message). The default.
    #[default]
    FailFast,
    /// Elastic recovery: re-plan over the survivor set (shrunk GMP
    /// topology via `planner::survivor_mp` + schedule recompile),
    /// restore weights from the latest global checkpoint, and retry the
    /// step. Training continues on the survivors.
    ShrinkAndContinue,
}

impl RecoveryPolicy {
    /// Parse a CLI token: `fail-fast`/`failfast` or
    /// `shrink`/`shrink-and-continue`.
    pub fn parse(s: &str) -> Result<RecoveryPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "fail-fast" | "failfast" => Ok(RecoveryPolicy::FailFast),
            "shrink" | "shrink-and-continue" => Ok(RecoveryPolicy::ShrinkAndContinue),
            other => bail!("unknown recovery policy {other:?} (expected fail-fast or shrink)"),
        }
    }
}

impl std::fmt::Display for RecoveryPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            RecoveryPolicy::FailFast => "fail-fast",
            RecoveryPolicy::ShrinkAndContinue => "shrink-and-continue",
        })
    }
}

/// Training-run configuration (§4's trainer parameters).
///
/// Construct via [`crate::api::SessionBuilder`] — the builder is the
/// one place that validates every field combination (typed
/// [`ConfigError`](crate::api::ConfigError)s instead of mid-run
/// failures) and resolves defaults; nothing else in the tree builds
/// this struct by literal.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Total workers N.
    pub n_workers: usize,
    /// MP group size (the paper's `mp`; 1 = pure DP).
    pub mp: usize,
    /// SGD learning rate.
    pub lr: f32,
    /// SGD momentum.
    pub momentum: f32,
    /// Global-norm gradient clip (0 = off).
    pub clip_norm: f32,
    /// Model-averaging period in batches ("communication batches", §4).
    pub avg_period: usize,
    /// Master seed (params, data order).
    pub seed: u64,
    /// Network cost model.
    pub net: NetModel,
    /// Synthetic dataset size when CIFAR-10 is absent.
    pub dataset_size: usize,
    /// Run mp=1 through the same segmented (Pallas-backed) pipeline as
    /// the MP paths instead of the fused `full_step` fast path. The
    /// benches set this so Table 2's DP-vs-MP comparison holds per-op
    /// efficiency constant; numerics are identical either way.
    pub segmented_mp1: bool,
    /// §3.1 communication scheme for the modulo layer (default B/K,
    /// SplitBrain's; B and BK are the Krizhevsky'14 baselines).
    pub scheme: McastScheme,
    /// Execution engine: one thread per worker (default) or the
    /// coordinator-interleaved sequential reference. Numerics are
    /// bit-identical between the two (asserted by the parity test).
    pub engine: ExecEngine,
    /// Collective algorithm for the shard exchanges and BSP model
    /// averaging (default ring; naive all-to-all and recursive
    /// halving/doubling are selectable for the Fig. 7b comparison).
    pub collectives: CollectiveAlgo,
    /// What to do on peer loss: fail fast (default) or shrink to the
    /// survivor set and continue.
    pub recovery: RecoveryPolicy,
    /// Blocking-take timeout, milliseconds (threaded engine). Past it a
    /// silent sender is presumed dead and the take returns a typed
    /// `PeerLost`. Defaults to [`TAKE_TIMEOUT_SECS`]; fault-injection
    /// tests shrink it so drop scenarios resolve in milliseconds.
    pub take_timeout_ms: u64,
    /// Deterministic fault-injection scenario (empty = no faults).
    pub faults: FaultPlan,
    /// Overlapped execution (default on): the step program's post
    /// halves are hoisted so gradient/activation exchange overlaps
    /// backward/forward compute, and the input batch is double-buffered
    /// (prefetched concurrently with worker compute). Numerics are
    /// **bit-identical** either way
    /// (every reduce consumes in fixed rank order — arrival order
    /// affects wall-clock only); the sequential reference engine always
    /// runs strict BSP regardless of this flag.
    pub overlap: bool,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            n_workers: 1,
            mp: 1,
            lr: 0.05,
            momentum: 0.9,
            clip_norm: 1.0,
            avg_period: 10,
            seed: 42,
            net: NetModel::default(),
            dataset_size: 2048,
            segmented_mp1: false,
            scheme: McastScheme::BoverK,
            engine: ExecEngine::Threaded,
            collectives: CollectiveAlgo::Ring,
            recovery: RecoveryPolicy::FailFast,
            take_timeout_ms: TAKE_TIMEOUT_SECS * 1000,
            faults: FaultPlan::new(),
            overlap: true,
        }
    }
}

/// Complete training state of a cluster incarnation at a step boundary
/// — the payload of the durable checkpoint store ([`crate::store`]).
///
/// Two coordinate systems coexist deliberately: `workers` holds every
/// rank's exact state (parameters *and* optimizer momentum) for
/// bit-identical resume at the same topology, while `global` holds the
/// 20-tensor global model that re-shards to any (n, mp) — the branch
/// path, and the same form the elastic recovery restore point uses.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterState {
    /// Steps completed when the state was captured.
    pub step: usize,
    /// Worker count of this incarnation (shrinks under recovery).
    pub n_workers: usize,
    /// MP group size of this incarnation.
    pub mp: usize,
    /// Elastic recoveries performed so far.
    pub recoveries: usize,
    /// Ranks lost so far, in detection order.
    pub lost_ranks: Vec<usize>,
    /// Consumed fault-event flags (at-most-once injection survives the
    /// round trip, so a resumed run cannot re-fire a spent fault).
    pub fired: Vec<bool>,
    /// The global model as named tensors (checkpoint order).
    pub global: Vec<(String, HostTensor)>,
    /// Per-rank exact state, rank order.
    pub workers: Vec<WorkerSnapshot>,
}

/// The numeric-fidelity cluster.
pub struct Cluster<'rt> {
    rt: &'rt RuntimeClient,
    /// The configuration the cluster was built with.
    pub cfg: ClusterConfig,
    /// DP×MP topology.
    pub topo: GmpTopology,
    /// Compiled per-step schedule (compute inventory + comm volumes).
    pub schedule: StepSchedule,
    /// The compiled per-rank step program both engines execute (the
    /// threaded engine runs the overlapped variant when
    /// `cfg.overlap`; the sequential engine always the BSP one).
    pub program: StepProgram,
    /// The Fig. 3 transformed per-worker network.
    pub transformed: TransformedNet,
    workers: Vec<Worker>,
    iters: Vec<BatchIter>,
    fabric: Fabric,
    step_count: usize,
    batch: usize,
    /// Batches prefetched by the coordinator thread while the worker
    /// threads computed the previous step (overlap's double
    /// buffering); `None` falls back to a synchronous fetch at step
    /// start. The final step of a run prefetches one batch set that is
    /// never consumed — the cluster cannot know a step is the last;
    /// the cost is one cheap synthetic batch per rank.
    prefetched: Option<Vec<Batch>>,
    /// The dataset, kept so elastic recovery can rebuild the survivor
    /// iterators.
    data: std::sync::Arc<dyn Dataset>,
    /// Latest in-memory global checkpoint (named tensors, global-model
    /// coordinates) and the step it was taken at. Refreshed at every
    /// averaging boundary, when replicas provably agree.
    ckpt: Vec<(String, HostTensor)>,
    ckpt_step: usize,
    /// Fabric counters of the last completed step (before reset):
    /// (max bytes pushed by one rank, total bytes) — used by tests to
    /// cross-check the analytic schedule volumes against reality.
    pub last_fabric_bytes: (u64, u64),
    /// How many elastic recoveries this cluster has performed.
    pub recoveries: usize,
    /// Ranks lost so far, in detection order. Ranks are re-numbered
    /// contiguously after each shrink, so entries are relative to the
    /// incarnation they died in.
    pub lost_ranks: Vec<usize>,
    /// Per-op span recorder (`--trace`); `None` keeps the hot path
    /// instrumentation-free.
    tracer: Option<std::sync::Arc<crate::obs::TraceSet>>,
}

/// The plan pipeline shared by cluster construction and elastic
/// recovery: validate artifact support, build the (n, mp) GMP topology,
/// partition the network and compile the step schedule.
pub(crate) fn plan_topology(
    rt: &RuntimeClient,
    cfg: &ClusterConfig,
    n: usize,
    mp: usize,
) -> Result<(GmpTopology, TransformedNet, StepSchedule)> {
    if !rt.manifest.supports_mp(mp) {
        bail!(
            "artifacts were not lowered for mp={mp} (manifest mp_sizes {:?}) — re-run `make artifacts`",
            rt.manifest.mp_sizes
        );
    }
    let topo = GmpTopology::new(n, mp)?;
    let transformed = partition_network(
        &vgg11(),
        vec![32, 32, 3],
        &PartitionConfig { mp, ..Default::default() },
    )?;
    let schedule = StepSchedule::compile_with_algo(
        &transformed,
        topo,
        &rt.manifest,
        cfg.segmented_mp1,
        cfg.scheme,
        cfg.collectives,
    )?;
    Ok((topo, transformed, schedule))
}

impl<'rt> Cluster<'rt> {
    /// Build the cluster: partition the VGG variant for `cfg.mp`,
    /// compile the schedule, initialize identical replicas/shards.
    pub fn new(rt: &'rt RuntimeClient, cfg: ClusterConfig) -> Result<Cluster<'rt>> {
        Self::with_dataset(rt, cfg.clone(), crate::data::load_default(cfg.dataset_size, cfg.seed).0)
    }

    /// Build with an explicit dataset (tests inject toy data here).
    pub fn with_dataset(
        rt: &'rt RuntimeClient,
        cfg: ClusterConfig,
        data: std::sync::Arc<dyn Dataset>,
    ) -> Result<Cluster<'rt>> {
        let (topo, transformed, schedule) = plan_topology(rt, &cfg, cfg.n_workers, cfg.mp)?;
        let program = schedule.compile_program(
            cfg.scheme,
            cfg.segmented_mp1,
            cfg.overlap && cfg.engine == ExecEngine::Threaded,
        );
        let batch = rt.manifest.batch;

        let (conv, fc) = init_full_params(cfg.seed);
        let mut workers = Vec::with_capacity(cfg.n_workers);
        for rank in 0..cfg.n_workers {
            workers.push(Worker::new(
                rank,
                &topo,
                &conv,
                &fc,
                batch,
                schedule.boundary_width.max(1),
                cfg.lr,
                cfg.momentum,
                cfg.clip_norm,
            )?);
        }
        let iters = (0..cfg.n_workers)
            .map(|rank| BatchIter::new(data.clone(), batch, rank, cfg.n_workers, cfg.seed))
            .collect();
        let fabric = Fabric::new(cfg.n_workers)
            .with_timeout_ms(cfg.take_timeout_ms)
            .with_faults(cfg.faults.clone());
        let mut cluster = Cluster {
            rt,
            cfg,
            topo,
            schedule,
            program,
            transformed,
            workers,
            iters,
            fabric,
            step_count: 0,
            batch,
            prefetched: None,
            data,
            ckpt: Vec::new(),
            ckpt_step: 0,
            last_fabric_bytes: (0, 0),
            recoveries: 0,
            lost_ranks: Vec::new(),
            tracer: None,
        };
        // The initial model is a valid global checkpoint (all replicas
        // identical by construction) — recovery before the first
        // averaging boundary restarts from it.
        cluster.ckpt = cluster.snapshot_global();
        Ok(cluster)
    }

    /// Rebuild a cluster from a captured [`ClusterState`] — the exact
    /// kill-resume path. The state's own (n, mp) override the config's
    /// (a run that shrank before the kill resumes shrunk); data
    /// iterators are rebuilt and advanced `state.step` batches, exactly
    /// like elastic recovery does, so the next step consumes the same
    /// global batch indices the uninterrupted run would.
    pub fn with_dataset_state(
        rt: &'rt RuntimeClient,
        cfg: ClusterConfig,
        data: std::sync::Arc<dyn Dataset>,
        state: ClusterState,
    ) -> Result<Cluster<'rt>> {
        let mut cfg = cfg;
        cfg.n_workers = state.n_workers;
        cfg.mp = state.mp;
        if state.workers.len() != cfg.n_workers {
            bail!(
                "cluster state has {} worker snapshots for n_workers={}",
                state.workers.len(),
                cfg.n_workers
            );
        }
        let (topo, transformed, schedule) = plan_topology(rt, &cfg, cfg.n_workers, cfg.mp)?;
        let program = schedule.compile_program(
            cfg.scheme,
            cfg.segmented_mp1,
            cfg.overlap && cfg.engine == ExecEngine::Threaded,
        );
        let batch = rt.manifest.batch;
        let mut workers = Vec::with_capacity(cfg.n_workers);
        for (rank, snap) in state.workers.into_iter().enumerate() {
            if snap.rank != rank {
                bail!("cluster state worker order broken: rank {} at position {rank}", snap.rank);
            }
            workers.push(Worker::from_snapshot(
                snap,
                batch,
                schedule.boundary_width.max(1),
                cfg.lr,
                cfg.momentum,
                cfg.clip_norm,
            )?);
        }
        let iters = (0..cfg.n_workers)
            .map(|rank| {
                let mut it =
                    BatchIter::new(data.clone(), batch, rank, cfg.n_workers, cfg.seed);
                for _ in 0..state.step {
                    it.next_batch();
                }
                it
            })
            .collect();
        let fabric = Fabric::new(cfg.n_workers)
            .with_timeout_ms(cfg.take_timeout_ms)
            .with_faults(cfg.faults.clone())
            .with_fired(state.fired);
        Ok(Cluster {
            rt,
            cfg,
            topo,
            schedule,
            program,
            transformed,
            workers,
            iters,
            fabric,
            step_count: state.step,
            batch,
            prefetched: None,
            data,
            ckpt: state.global,
            ckpt_step: state.step,
            last_fabric_bytes: (0, 0),
            recoveries: state.recoveries,
            lost_ranks: state.lost_ranks,
            tracer: None,
        })
    }

    /// Capture the complete training state (see [`ClusterState`]).
    /// Meaningful at any step; the durable store calls it at averaging
    /// boundaries, where replicas provably agree.
    pub fn full_state(&self) -> ClusterState {
        ClusterState {
            step: self.step_count,
            n_workers: self.cfg.n_workers,
            mp: self.cfg.mp,
            recoveries: self.recoveries,
            lost_ranks: self.lost_ranks.clone(),
            fired: self.fabric.fired_flags(),
            global: self.snapshot_global(),
            workers: self.workers.iter().map(Worker::snapshot).collect(),
        }
    }

    /// Per-worker memory accounting (Fig. 7c).
    pub fn memory_report(&self) -> MemoryReport {
        MemoryReport::of(&self.transformed, self.batch)
    }

    /// Run `steps` training steps, returning the aggregated report.
    pub fn train_steps(&mut self, steps: usize) -> Result<TrainReport> {
        let mut report = TrainReport::new(self.cfg.n_workers, self.cfg.mp, self.batch);
        for _ in 0..steps {
            let m = self.step()?;
            // Mirror the modeled phases into the trace for Fig. 7b.
            for p in &self.schedule.mp_phases {
                for _ in 0..p.times {
                    report.trace.record_uniform(p.category, &self.cfg.net, p.ranks, p.per_member);
                }
            }
            if self.just_averaged() {
                for p in &self.schedule.avg_phases {
                    report.trace.record_uniform(p.category, &self.cfg.net, p.ranks, p.per_member);
                }
            }
            report.push(&m);
        }
        Ok(report)
    }

    fn just_averaged(&self) -> bool {
        self.cfg.n_workers > 1 && self.step_count % self.cfg.avg_period == 0
    }

    /// One BSP training step across all groups, on the configured
    /// engine. Both engines produce bit-identical numerics; the
    /// threaded engine overlaps the workers' wall-clock compute.
    ///
    /// On peer loss (typed `PeerLost`/`WorkerCrashed` from the fabric
    /// or an injected fault), behavior follows `cfg.recovery`:
    /// [`RecoveryPolicy::FailFast`] propagates the error;
    /// [`RecoveryPolicy::ShrinkAndContinue`] re-plans over the survivor
    /// set, restores the latest checkpoint and retries the step, so a
    /// successful return always means one completed training step.
    pub fn step(&mut self) -> Result<StepMetrics> {
        loop {
            match self.try_step() {
                Ok(m) => return Ok(m),
                Err(e) => {
                    let dead = self.fabric.dead_ranks();
                    if self.cfg.recovery != RecoveryPolicy::ShrinkAndContinue || dead.is_empty()
                    {
                        // Not a peer loss (or fail-fast): propagate.
                        return Err(e);
                    }
                    self.recover(&dead)
                        .map_err(|re| re.context(format!("recovering from: {e:#}")))?;
                }
            }
        }
    }

    /// One step attempt on the current incarnation (no recovery). Both
    /// engines execute the same compiled step program — the sequential
    /// engine drives it op-major on this thread (`program::run_lockstep`),
    /// the threaded engine runs it whole on one thread per worker.
    fn try_step(&mut self) -> Result<StepMetrics> {
        let step_no = self.step_count + 1;
        self.fabric.begin_step(step_no);
        for w in &mut self.workers {
            w.begin_step();
            w.compute_secs = 0.0;
        }
        // Double buffering: consume the batches the worker threads
        // prefetched during the previous step, falling back to a
        // synchronous fetch (first step, sequential engine, overlap
        // off). Either path consumes exactly one batch per rank per
        // step, so the example sequence is mode-invariant.
        let batches: Vec<Batch> = match self.prefetched.take() {
            Some(b) => b,
            None => self.iters.iter_mut().map(|it| it.next_batch()).collect(),
        };
        // Averaging every avg_period steps (counting from step 1).
        let averaging_due =
            self.cfg.n_workers > 1 && (self.step_count + 1) % self.cfg.avg_period == 0;

        let ctx = ExecCtx {
            rt: self.rt,
            transport: &self.fabric,
            topo: &self.topo,
            schedule: &self.schedule,
            scheme: self.cfg.scheme,
            algo: self.cfg.collectives,
            batch: self.batch,
            averaging: averaging_due,
            step: step_no,
            tracer: self.tracer.as_deref(),
        };
        match self.cfg.engine {
            ExecEngine::Sequential => {
                super::program::run_lockstep(&self.program, &mut self.workers, &batches, &ctx)?;
            }
            ExecEngine::Threaded => {
                let barrier = std::sync::Barrier::new(self.cfg.n_workers);
                let sctx = StepCtx { exec: ctx, program: &self.program, barrier: &barrier };
                let iters = if self.program.overlap { Some(&mut self.iters[..]) } else { None };
                self.prefetched =
                    run_threaded_step(&mut self.workers, &batches, iters, &sctx)?;
            }
        }
        self.step_count += 1;

        // Injected straggles inflate the rank's simulated compute
        // clock; injected delays are charged to the MP comm clock.
        for rank in 0..self.cfg.n_workers {
            let s = self.fabric.poll_straggle(rank);
            if s > 0.0 {
                self.workers[rank].compute_secs += s;
            }
        }
        let injected_delay = self.fabric.injected_delay_secs();

        let mut dp_comm = 0.0;
        if averaging_due {
            dp_comm = self.schedule.avg_comm_secs(&self.cfg.net);
        }
        if !self.fabric.drained() {
            bail!("fabric not drained after step {} — schedule bug", self.step_count);
        }
        self.last_fabric_bytes = (self.fabric.max_bytes_per_rank(), self.fabric.total_bytes());
        self.fabric.reset_counters();
        if averaging_due {
            // Replicas provably agree right after averaging: refresh the
            // in-memory checkpoint the recovery path restores from.
            self.ckpt = self.snapshot_global();
            self.ckpt_step = self.step_count;
        }

        let compute = self
            .workers
            .iter()
            .map(|w| w.compute_secs)
            .fold(0.0, f64::max);
        let rounds = self.cfg.scheme.rounds(self.cfg.mp.max(1)) as f64;
        let loss = self.workers.iter().map(|w| w.loss_acc / rounds).sum::<f64>()
            / self.workers.len() as f64;
        Ok(StepMetrics {
            compute_secs: compute,
            mp_comm_secs: self.schedule.mp_comm_secs(&self.cfg.net) + injected_delay,
            dp_comm_secs: dp_comm,
            loss,
        })
    }

    /// Elastic recovery: shrink to the survivor set, re-plan, restore
    /// the latest checkpoint, rebuild iterators and fabric. The next
    /// `try_step` runs on the recovered cluster.
    ///
    /// Steps between the restore point and the failure are **not
    /// replayed**: the step counter and data iterators keep advancing
    /// while the model reverts to the last averaging boundary — the
    /// standard elastic-training trade (lost work is bounded by
    /// `avg_period`), chosen over rewinding so `steps_done()` and the
    /// callers' step loops stay monotonic.
    fn recover(&mut self, dead: &[usize]) -> Result<()> {
        let survivors: Vec<usize> =
            (0..self.cfg.n_workers).filter(|r| !dead.contains(r)).collect();
        if survivors.is_empty() {
            bail!("unrecoverable: all {} workers lost", self.cfg.n_workers);
        }
        let n = survivors.len();
        let mp = super::planner::survivor_mp(n, self.cfg.mp, &self.rt.manifest.mp_sizes)?;

        // Re-plan: shrunk GMP topology, re-partition, recompiled
        // schedule — the same `plan_topology` pipeline the constructor
        // runs (so recovered and freshly built clusters can't drift).
        let (topo, transformed, schedule) = plan_topology(self.rt, &self.cfg, n, mp)?;
        self.lost_ranks.extend(dead.iter().copied());
        self.recoveries += 1;
        self.cfg.n_workers = n;
        self.cfg.mp = mp;
        self.topo = topo;
        self.transformed = transformed;
        self.program = schedule.compile_program(
            self.cfg.scheme,
            self.cfg.segmented_mp1,
            self.cfg.overlap && self.cfg.engine == ExecEngine::Threaded,
        );
        self.schedule = schedule;
        // Prefetched batches belong to the lost incarnation's iterators.
        self.prefetched = None;

        // Restore survivor workers from the latest global checkpoint
        // (re-sharded for the new mp; optimizer momentum resets, as on
        // any checkpoint restore).
        let tensors: Vec<HostTensor> = self.ckpt.iter().map(|(_, t)| t.clone()).collect();
        let conv = &tensors[..14];
        let fc = &tensors[14..20];
        let mut workers = Vec::with_capacity(n);
        for rank in 0..n {
            workers.push(Worker::new(
                rank,
                &self.topo,
                conv,
                fc,
                self.batch,
                self.schedule.boundary_width.max(1),
                self.cfg.lr,
                self.cfg.momentum,
                self.cfg.clip_norm,
            )?);
        }
        self.workers = workers;

        // Survivor data iterators, advanced to the current position so
        // the retried step consumes the same global batch index a
        // from-scratch n-worker run would at this step.
        self.iters = (0..n)
            .map(|rank| {
                let mut it =
                    BatchIter::new(self.data.clone(), self.batch, rank, n, self.cfg.seed);
                for _ in 0..self.step_count {
                    it.next_batch();
                }
                it
            })
            .collect();

        // Fresh fabric over the survivors. Consumed fault events stay
        // consumed (at-most-once), keeping replays deterministic.
        let fired = self.fabric.fired_flags();
        self.fabric = Fabric::new(n)
            .with_timeout_ms(self.cfg.take_timeout_ms)
            .with_faults(self.cfg.faults.clone())
            .with_fired(fired);
        Ok(())
    }

    /// Evaluate the current model on `n_batches` x batch examples:
    /// reconstructs the full FC params of group 0 host-side (untimed)
    /// and runs the fused full_eval. Returns (mean loss, accuracy).
    pub fn evaluate(&mut self, data: &dyn Dataset, n_batches: usize) -> Result<(f64, f64)> {
        let full_fc = self.reconstruct_full_fc(0);
        let conv = self.workers[0].conv_params.clone();
        let mut total_loss = 0.0;
        let mut correct = 0i64;
        let mut seen = 0usize;
        for bi in 0..n_batches {
            let idx: Vec<usize> =
                (0..self.batch).map(|i| (bi * self.batch + i) % data.len()).collect();
            let batch = data.gather(&idx);
            let mut inputs: Vec<HostTensor> = conv.to_vec();
            inputs.extend(full_fc.iter().cloned());
            inputs.push(batch.images.clone());
            inputs.push(batch.labels.clone());
            let out = self.rt.run("full_eval", &inputs)?;
            total_loss += out[0].scalar() as f64;
            correct += out[1].scalar() as i64;
            seen += self.batch;
        }
        Ok((total_loss / n_batches as f64, correct as f64 / seen as f64))
    }

    /// Allgather (host-side, untimed) group `gid`'s FC shards into the
    /// full FC parameter set.
    pub fn reconstruct_full_fc(&self, gid: usize) -> Vec<HostTensor> {
        let members = self.topo.members(gid);
        let k = members.len();
        let mut out = Vec::with_capacity(6);
        for fc_idx in 0..2 {
            let sw = self.workers[members[0]].fc_params[2 * fc_idx].shape.clone();
            let (din, s) = (sw[0], sw[1]);
            let mut w = HostTensor::zeros(vec![din, s * k]);
            let mut bvec = Vec::with_capacity(s * k);
            for (gi, &r) in members.iter().enumerate() {
                w.set_cols(gi * s, &self.workers[r].fc_params[2 * fc_idx]);
                bvec.extend_from_slice(self.workers[r].fc_params[2 * fc_idx + 1].as_f32());
            }
            out.push(w);
            out.push(HostTensor::f32(vec![s * k], bvec));
        }
        out.push(self.workers[members[0]].fc_params[4].clone());
        out.push(self.workers[members[0]].fc_params[5].clone());
        out
    }

    /// Read-only worker access (tests).
    pub fn worker(&self, rank: usize) -> &Worker {
        &self.workers[rank]
    }

    /// Snapshot the global model (worker 0's conv replica + group 0's
    /// reconstructed full FC stack) as named tensors in checkpoint
    /// order. Valid at any point: replicas agree after averaging;
    /// between averagings this snapshots worker 0's replica, like the
    /// paper's leader would.
    pub fn snapshot_global(&self) -> Vec<(String, HostTensor)> {
        use crate::train::checkpoint;
        let mut tensors: Vec<HostTensor> = self.workers[0].conv_params.clone();
        tensors.extend(self.reconstruct_full_fc(0));
        checkpoint::model_names().into_iter().zip(tensors).collect()
    }

    /// Save the global model snapshot ([`Cluster::snapshot_global`]) to
    /// a checkpoint file.
    pub fn save_checkpoint(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        crate::train::checkpoint::save_named(path, &self.snapshot_global())
    }

    /// Restore a checkpoint into every worker (re-sharding the FC stack
    /// for this cluster's mp) and reset optimizer momentum.
    pub fn restore_checkpoint(&mut self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let loaded = crate::train::checkpoint::load(path)?;
        self.restore_from_global(&loaded)
    }

    /// Restore from an in-memory global-model snapshot (named tensors
    /// in checkpoint order — the shape [`Cluster::snapshot_global`]
    /// produces and the durable store's branch path loads). Re-shards
    /// for this cluster's (n, mp); optimizer momentum resets, as on any
    /// restore.
    pub fn restore_from_global(&mut self, loaded: &[(String, HostTensor)]) -> Result<()> {
        use crate::train::checkpoint;
        let names = checkpoint::model_names();
        if loaded.len() != names.len() {
            bail!("checkpoint has {} tensors, expected {}", loaded.len(), names.len());
        }
        for ((name, _), expect) in loaded.iter().zip(names.iter()) {
            if name != expect {
                bail!("checkpoint tensor order mismatch: {name} vs {expect}");
            }
        }
        let tensors: Vec<HostTensor> = loaded.iter().map(|(_, t)| t.clone()).collect();
        let conv = &tensors[..14];
        let fc = &tensors[14..20];
        for rank in 0..self.cfg.n_workers {
            let w = &mut self.workers[rank];
            for (p, t) in w.conv_params.iter_mut().zip(conv.iter()) {
                if p.shape != t.shape {
                    bail!("conv shape mismatch in checkpoint: {:?} vs {:?}", p.shape, t.shape);
                }
                p.as_f32_mut().copy_from_slice(t.as_f32());
            }
            let shard = super::worker::shard_fc(fc, self.topo.mp, self.topo.offset(rank));
            for (p, t) in w.fc_params.iter_mut().zip(shard.iter()) {
                p.as_f32_mut().copy_from_slice(t.as_f32());
            }
            w.conv_opt.reset();
            w.fc_opt.reset();
        }
        // A freshly restored model is globally consistent: make it the
        // recovery restore point too.
        self.ckpt = self.snapshot_global();
        self.ckpt_step = self.step_count;
        Ok(())
    }

    /// Number of training steps completed so far.
    pub fn steps_done(&self) -> usize {
        self.step_count
    }

    /// Step the latest in-memory checkpoint (the recovery restore
    /// point) was taken at — 0 until the first averaging boundary.
    pub fn last_checkpoint_step(&self) -> usize {
        self.ckpt_step
    }

    /// The fabric (tests inspect dead ranks and counters).
    pub fn fabric(&self) -> &Fabric {
        &self.fabric
    }

    /// Install a per-op span recorder: every subsequent step records
    /// one span per executed [`StepOp`](super::program::StepOp).
    pub fn set_tracer(&mut self, tracer: std::sync::Arc<crate::obs::TraceSet>) {
        self.tracer = Some(tracer);
    }

    /// The installed span recorder, if tracing is on.
    pub fn tracer(&self) -> Option<&std::sync::Arc<crate::obs::TraceSet>> {
        self.tracer.as_ref()
    }
}

/// Calibrated throughput estimation for large sweeps: times each
/// artifact the schedule needs (plus the host-side SGD) once, then costs
/// `steps` analytically. No training state is built.
pub fn calibrated_report(
    rt: &RuntimeClient,
    cfg: &ClusterConfig,
    calib_runs: usize,
) -> Result<TrainReport> {
    let topo = GmpTopology::new(cfg.n_workers, cfg.mp)?;
    let transformed = partition_network(
        &vgg11(),
        vec![32, 32, 3],
        &PartitionConfig { mp: cfg.mp, ..Default::default() },
    )?;
    let schedule = StepSchedule::compile_with_algo(
        &transformed,
        topo,
        &rt.manifest,
        false,
        McastScheme::BoverK,
        cfg.collectives,
    )?;

    // --- calibrate artifact times (process-wide cache in the runtime) ---
    let mut compute_secs = 0.0;
    for call in &schedule.compute {
        let per_call = rt.calibrated_secs(&call.artifact, calib_runs)?;
        compute_secs += per_call * call.calls as f64;
    }
    // Host-side SGD cost over the per-worker parameter count.
    let params = transformed.param_count();
    let mut p = vec![0.5f32; params];
    let g = vec![0.1f32; params];
    let mut v = vec![0.0f32; params];
    let t = Timer::start();
    for i in 0..params {
        v[i] = 0.9 * v[i] + g[i];
        p[i] -= 0.05 * v[i];
    }
    compute_secs += t.elapsed_secs();
    std::hint::black_box(&p);

    // --- compose the report ---
    let mut report = TrainReport::new(cfg.n_workers, cfg.mp, rt.manifest.batch);
    let mp_comm = schedule.mp_comm_secs(&cfg.net);
    let avg_comm = schedule.avg_comm_secs(&cfg.net) / cfg.avg_period as f64;
    let steps = 10; // representative sample; all steps identical by construction
    for _ in 0..steps {
        report.push(&StepMetrics {
            compute_secs,
            mp_comm_secs: mp_comm,
            dp_comm_secs: avg_comm,
            loss: f64::NAN,
        });
        for ph in &schedule.mp_phases {
            for _ in 0..ph.times {
                report.trace.record_uniform(ph.category, &cfg.net, ph.ranks, ph.per_member);
            }
        }
    }
    for ph in &schedule.avg_phases {
        report.trace.record_uniform(ph.category, &cfg.net, ph.ranks, ph.per_member);
    }
    Ok(report)
}
