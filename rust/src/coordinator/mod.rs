//! The SplitBrain coordinator — the paper's Layer-3 contribution.
//!
//! - [`group`] — GMP topology: N workers = D groups x mp members (Fig. 6)
//! - [`modulo`] — the modulo layer L_M: B/K example scheduling (Fig. 4)
//! - [`shard`] — the shard layer L_S: partition gather/reduce (Fig. 5)
//! - [`schedule`] — the compiled per-step plan + analytic comm volumes
//! - [`averaging`] — BSP model averaging (replicated across N, shards across groups)
//! - [`worker`] — per-worker parameter/optimizer/accumulator state
//! - [`program`] — the compiled per-rank step-program IR + the single
//!   executor all three engines (sequential, threaded, TCP) drive
//! - [`engine`] — the threaded (one thread per worker) drive of the
//!   step program
//! - [`cluster`] — the numeric simulator + calibrated throughput mode,
//!   with elastic shrink-and-continue recovery on peer loss
//! - [`procdriver`] — the multi-process rank driver (`splitbrain
//!   worker`): the same compiled step program over the TCP transport
//! - [`planner`] — feasible-configuration search under a memory budget,
//!   plus survivor re-planning for elastic recovery

pub mod averaging;
pub mod cluster;
pub mod engine;
pub mod group;
pub mod modulo;
pub mod planner;
pub mod procdriver;
pub mod program;
pub mod schedule;
pub mod scheme;
pub mod shard;
pub mod worker;

pub use cluster::{calibrated_report, Cluster, ClusterConfig, ClusterState, RecoveryPolicy};
pub use engine::ExecEngine;
pub use group::GmpTopology;
pub use modulo::ModuloPlan;
pub use planner::{best, plan, CostModel, PlanOption, PlanRequest};
pub use program::{BarrierId, StepOp, StepProgram};
pub use schedule::StepSchedule;
pub use scheme::McastScheme;
pub use shard::{ShardBwdMode, ShardPlan};
