//! Configuration planner — the paper's stated future work (§7:
//! "investigate the design space of fine-grained model partitioning
//! given a resource budget").
//!
//! Given a worker memory budget, the cluster size and a network model,
//! the planner enumerates every feasible (mp, scheme) configuration,
//! costs a step with the analytic schedule + a compute model calibrated
//! from the PJRT artifacts, and returns the feasible frontier sorted by
//! predicted throughput. This turns Fig. 7c's manual sweet-spot hunt
//! into a query.

use anyhow::Result;

use crate::comm::NetModel;
use crate::model::{partition_network, vgg11, PartitionConfig};
use crate::runtime::RuntimeClient;
use crate::train::MemoryReport;

use super::group::GmpTopology;
use super::schedule::StepSchedule;
use super::scheme::McastScheme;

/// What the planner optimizes under.
#[derive(Debug, Clone)]
pub struct PlanRequest {
    /// Cluster size N.
    pub n_workers: usize,
    /// Per-worker memory budget, bytes (params+grads+opt+activations).
    pub memory_budget: usize,
    /// Network model of the fabric.
    pub net: NetModel,
    /// Model-averaging period (amortizes DP exchange).
    pub avg_period: usize,
    /// Measured (or estimated) per-step compute seconds for mp=1 and
    /// the per-round FC compute seconds — from [`CostModel::calibrate`].
    pub cost: CostModel,
}

/// Per-segment compute costs (seconds per call).
#[derive(Debug, Clone, Default)]
pub struct CostModel {
    /// Seconds per conv_fwd call.
    pub conv_fwd: f64,
    /// Seconds per conv_bwd call.
    pub conv_bwd: f64,
    /// FC pipeline per round per member at shard width 1024/k, indexed
    /// by k (missing entries are interpolated as 1/k of full).
    pub fc_round: Vec<(usize, f64)>,
}

impl CostModel {
    /// Measure the artifact costs once via PJRT (same approach as the
    /// calibrated simulator).
    pub fn calibrate(rt: &RuntimeClient, mp_sizes: &[usize]) -> Result<CostModel> {
        let conv_fwd = rt.calibrated_secs("conv_fwd", 2)?;
        let conv_bwd = rt.calibrated_secs("conv_bwd", 2)?;
        let mut fc_round = Vec::new();
        for &k in mp_sizes {
            let mut total = 0.0;
            for seg in ["fc0_fwd", "fc0_bwd", "fc1_fwd", "fc1_bwd"] {
                total += rt.calibrated_secs(&format!("{seg}_k{k}"), 2)?;
            }
            total += rt.calibrated_secs("head_step", 2)?;
            fc_round.push((k, total));
        }
        Ok(CostModel { conv_fwd, conv_bwd, fc_round })
    }

    fn fc_round_secs(&self, k: usize) -> f64 {
        self.fc_round
            .iter()
            .find(|(kk, _)| *kk == k)
            .map(|(_, t)| *t)
            .unwrap_or_else(|| {
                // crude fallback: full-width cost scaled by 1/k
                self.fc_round.first().map(|(_, t)| t / k as f64).unwrap_or(0.0)
            })
    }
}

/// One feasible configuration with its predicted cost.
#[derive(Debug, Clone)]
pub struct PlanOption {
    /// MP group size.
    pub mp: usize,
    /// Modulo communication scheme.
    pub scheme: McastScheme,
    /// Predicted per-worker memory footprint.
    pub memory_bytes: usize,
    /// Predicted step seconds.
    pub step_secs: f64,
    /// Predicted cluster throughput.
    pub images_per_sec: f64,
    /// Predicted comm share of the step.
    pub comm_fraction: f64,
    /// True when the memory budget is met.
    pub feasible: bool,
}

/// Enumerate and cost every (mp, scheme) combination the artifacts
/// support; sorted best-first among feasible, then infeasible.
pub fn plan(rt: &RuntimeClient, req: &PlanRequest) -> Result<Vec<PlanOption>> {
    let batch = rt.manifest.batch;
    let mut out = Vec::new();
    for &mp in rt.manifest.mp_sizes.iter() {
        if req.n_workers % mp != 0 {
            continue;
        }
        let schemes: &[McastScheme] = if mp == 1 {
            &[McastScheme::BoverK]
        } else {
            &[McastScheme::BoverK, McastScheme::B, McastScheme::BK]
        };
        for &scheme in schemes {
            let net = partition_network(
                &vgg11(),
                vec![32, 32, 3],
                &PartitionConfig { mp, ..Default::default() },
            )?;
            let topo = GmpTopology::new(req.n_workers, mp)?;
            // Cost with the runtime's default collectives (ring): the
            // planner predicts the cluster as configured, and ring is
            // what `ClusterConfig::default()` runs (and what the seed's
            // averaging analytics assumed).
            let sched = StepSchedule::compile_with_algo(
                &net,
                topo,
                &rt.manifest,
                true,
                scheme,
                crate::comm::CollectiveAlgo::Ring,
            )?;
            let mem = MemoryReport::of_scheme(&net, batch, scheme);
            let rounds = scheme.rounds(mp) as f64;
            // BK rounds process k*B examples: its fc segments cost ~k x
            // the per-round figure.
            let fc_scale = if scheme == McastScheme::BK { mp as f64 } else { 1.0 };
            let compute = req.cost.conv_fwd
                + req.cost.conv_bwd
                + rounds * fc_scale * req.cost.fc_round_secs(mp);
            let comm = sched.mp_comm_secs(&req.net)
                + sched.avg_comm_secs(&req.net) / req.avg_period as f64;
            let step = compute + comm;
            out.push(PlanOption {
                mp,
                scheme,
                memory_bytes: mem.total(),
                step_secs: step,
                images_per_sec: (req.n_workers * batch) as f64 / step,
                comm_fraction: comm / step,
                feasible: mem.total() <= req.memory_budget,
            });
        }
    }
    out.sort_by(|a, b| {
        b.feasible
            .cmp(&a.feasible)
            .then(b.images_per_sec.partial_cmp(&a.images_per_sec).unwrap())
    });
    Ok(out)
}

/// The planner's answer: best feasible option, if any.
pub fn best(options: &[PlanOption]) -> Option<&PlanOption> {
    options.iter().find(|o| o.feasible)
}

/// Elastic re-planning after peer loss: the largest artifact-supported
/// MP group size that (a) divides the survivor count and (b) does not
/// exceed the pre-failure `old_mp` (growing the groups would inflate
/// per-round traffic mid-run and need artifacts the run was not
/// validated for). With `1` in `mp_sizes` — always true for generated
/// artifact sets — a survivor set of any size re-plans to at least pure
/// DP.
pub fn survivor_mp(n_survivors: usize, old_mp: usize, mp_sizes: &[usize]) -> Result<usize> {
    if n_survivors == 0 {
        anyhow::bail!("no survivors to re-plan over");
    }
    mp_sizes
        .iter()
        .copied()
        .filter(|&k| k >= 1 && k <= old_mp && n_survivors % k == 0)
        .max()
        .ok_or_else(|| {
            anyhow::anyhow!(
                "no supported mp size (of {mp_sizes:?}) divides {n_survivors} survivors \
                 under the old group size {old_mp}"
            )
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_cost() -> CostModel {
        CostModel {
            conv_fwd: 0.2,
            conv_bwd: 0.6,
            fc_round: vec![(1, 0.08), (2, 0.05), (4, 0.03), (8, 0.02)],
        }
    }

    fn toy_manifest() -> crate::runtime::Manifest {
        let text = "splitbrain-artifacts v1\nbatch 32\nmp_sizes 1,2,4,8\nfeature_dim 4096\nnum_classes 10\nartifact full_step file=x\nin a float32 1\nout b float32 1\nend\n";
        crate::runtime::Manifest::parse(text, std::path::PathBuf::from("/tmp")).unwrap()
    }

    /// plan() without PJRT: exercise the cost composition directly.
    fn plan_with(req: &PlanRequest, mp_sizes: &[usize]) -> Vec<PlanOption> {
        let manifest = toy_manifest();
        let batch = manifest.batch;
        let mut out = Vec::new();
        for &mp in mp_sizes {
            if req.n_workers % mp != 0 {
                continue;
            }
            let schemes: &[McastScheme] = if mp == 1 {
                &[McastScheme::BoverK]
            } else {
                &[McastScheme::BoverK, McastScheme::B, McastScheme::BK]
            };
            for &scheme in schemes {
                let net = partition_network(
                    &vgg11(),
                    vec![32, 32, 3],
                    &PartitionConfig { mp, ..Default::default() },
                )
                .unwrap();
                let mem = MemoryReport::of_scheme(&net, batch, scheme);
                let rounds = scheme.rounds(mp) as f64;
                let fc_scale = if scheme == McastScheme::BK { mp as f64 } else { 1.0 };
                let compute = req.cost.conv_fwd
                    + req.cost.conv_bwd
                    + rounds * fc_scale * req.cost.fc_round_secs(mp);
                out.push(PlanOption {
                    mp,
                    scheme,
                    memory_bytes: mem.total(),
                    step_secs: compute,
                    images_per_sec: (req.n_workers * batch) as f64 / compute,
                    comm_fraction: 0.0,
                    feasible: mem.total() <= req.memory_budget,
                });
            }
        }
        out.sort_by(|a, b| {
            b.feasible
                .cmp(&a.feasible)
                .then(b.images_per_sec.partial_cmp(&a.images_per_sec).unwrap())
        });
        out
    }

    fn req(budget_mb: usize) -> PlanRequest {
        PlanRequest {
            n_workers: 8,
            memory_budget: budget_mb * 1024 * 1024,
            net: NetModel::default(),
            avg_period: 10,
            cost: toy_cost(),
        }
    }

    #[test]
    fn unlimited_budget_prefers_pure_dp() {
        let options = plan_with(&req(10_000), &[1, 2, 4, 8]);
        let top = best(&options).unwrap();
        assert_eq!(top.mp, 1, "{top:?}");
    }

    #[test]
    fn tight_budget_forces_mp() {
        // mp=1 needs ~80 MB (params x3 + staging); a 60 MB budget
        // should push the best feasible choice to mp >= 2.
        let options = plan_with(&req(60), &[1, 2, 4, 8]);
        let top = best(&options).unwrap();
        assert!(top.mp >= 2, "{top:?}");
        assert!(top.feasible);
    }

    #[test]
    fn impossible_budget_has_no_feasible_option() {
        let options = plan_with(&req(1), &[1, 2, 4, 8]);
        assert!(best(&options).is_none());
        assert!(!options.is_empty());
    }

    #[test]
    fn feasible_options_sort_before_infeasible() {
        let options = plan_with(&req(60), &[1, 2, 4, 8]);
        let first_infeasible = options.iter().position(|o| !o.feasible);
        if let Some(idx) = first_infeasible {
            assert!(options[idx..].iter().all(|o| !o.feasible));
        }
    }

    #[test]
    fn survivor_mp_picks_largest_compatible_group() {
        let sizes = [1usize, 2, 4, 8];
        // 3 survivors of an mp=2 cluster: only pure DP divides 3.
        assert_eq!(survivor_mp(3, 2, &sizes).unwrap(), 1);
        // 2 survivors of an mp=2 cluster: the group shape survives.
        assert_eq!(survivor_mp(2, 2, &sizes).unwrap(), 2);
        // 6 survivors of an mp=4 cluster: 4 ∤ 6, shrink to 2.
        assert_eq!(survivor_mp(6, 4, &sizes).unwrap(), 2);
        // Never grows the groups past the pre-failure size.
        assert_eq!(survivor_mp(8, 2, &sizes).unwrap(), 2);
        // No survivors is an error.
        assert!(survivor_mp(0, 2, &sizes).is_err());
        // Pathological manifest without mp=1 can be unsatisfiable.
        assert!(survivor_mp(3, 2, &[2, 4]).is_err());
    }

    #[test]
    fn bk_memory_exceeds_bok_at_same_mp() {
        let options = plan_with(&req(10_000), &[4]);
        let bok = options
            .iter()
            .find(|o| o.mp == 4 && o.scheme == McastScheme::BoverK)
            .unwrap();
        let bk = options
            .iter()
            .find(|o| o.mp == 4 && o.scheme == McastScheme::BK)
            .unwrap();
        assert!(bk.memory_bytes > bok.memory_bytes);
    }
}
