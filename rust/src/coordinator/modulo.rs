//! The modulo layer L_M (§3.1, Fig. 4): stateful scheduling of the B/K
//! example broadcast across the K modulo iterations.
//!
//! fprop (iteration k): every group member contributes rows
//! `[k·size, (k+1)·size)` of its local activations; the assembled batch
//! places member j's contribution at rows `[j·size, (j+1)·size)`
//! (owner mapping of Fig. 6b). Local rows are copied, remote rows are
//! gathered over the fabric while the local slice is scattered —
//! "broadcast by scattering ... gathered back simultaneously".
//!
//! bprop (iteration k): the assembled-batch gradient is routed back:
//! rows owned by member j are sent to j, which *reduces* (sums) the
//! copies from all members — the partial-gradient semantics of the
//! partitioned FC0 below (Fig. 4b) — and accumulates the result into
//! rows `[k·size, (k+1)·size)` of its local activation gradient.

use anyhow::Result;

use crate::comm::fabric::Tag;
use crate::comm::transport::Transport;
use crate::runtime::HostTensor;

/// Compile-time facts of a modulo exchange for one MP group.
#[derive(Debug, Clone)]
pub struct ModuloPlan {
    /// Global ranks of the group, offset order.
    pub group: Vec<usize>,
    /// Local batch size B (the FC stack always sees B examples).
    pub batch: usize,
    /// Feature width at the DP/MP boundary (4096 for the VGG variant).
    pub width: usize,
}

impl ModuloPlan {
    /// Build the plan for one MP group (`batch` must divide by K).
    pub fn new(group: Vec<usize>, batch: usize, width: usize) -> ModuloPlan {
        assert!(!group.is_empty());
        assert_eq!(batch % group.len(), 0, "B must be a multiple of K");
        ModuloPlan { group, batch, width }
    }

    /// K = group size.
    pub fn k(&self) -> usize {
        self.group.len()
    }

    /// size = B/K examples contributed per member per iteration.
    pub fn size(&self) -> usize {
        self.batch / self.k()
    }

    /// Wire bytes each member sends in one fprop iteration:
    /// its B/K slice to each of the K-1 peers.
    pub fn fwd_bytes_per_member(&self) -> u64 {
        ((self.k() - 1) * self.size() * self.width * 4) as u64
    }

    /// bprop volume is symmetric: K-1 foreign row-blocks pushed back.
    pub fn bwd_bytes_per_member(&self) -> u64 {
        self.fwd_bytes_per_member()
    }

    /// fprop of iteration `k`: assemble every member's full batch.
    /// `acts[j]` is member j's local `[B, width]` activations; returns
    /// the `[B, width]` assembled batch per member.
    pub fn assemble(
        &self,
        fabric: &dyn Transport,
        acts: &[HostTensor],
        k: usize,
        tag: Tag,
    ) -> Result<Vec<HostTensor>> {
        let kk = self.k();
        let size = self.size();
        assert!(k < kk);
        assert_eq!(acts.len(), kk);

        // Scatter: member j pushes its slice [k*size, (k+1)*size) to all.
        for (j, &src) in self.group.iter().enumerate() {
            let slice = acts[j].slice_rows(k * size, (k + 1) * size);
            for &dst in &self.group {
                if dst != src {
                    fabric.post(src, dst, tag, slice.as_f32().to_vec());
                }
            }
        }
        // Gather + local copy: assembled rows [j*size, (j+1)*size) come
        // from member j (the Fig. 6b owner mapping).
        let mut outs = Vec::with_capacity(kk);
        for (i, &dst) in self.group.iter().enumerate() {
            let mut batch = HostTensor::zeros(vec![self.batch, self.width]);
            for (j, &src) in self.group.iter().enumerate() {
                if j == i {
                    let local = acts[i].slice_rows(k * size, (k + 1) * size);
                    batch.set_rows(j * size, &local);
                } else {
                    let data = fabric.take(dst, src, tag)?;
                    batch.set_rows(
                        j * size,
                        &HostTensor::f32(vec![size, self.width], data),
                    );
                }
            }
            outs.push(batch);
        }
        Ok(outs)
    }

    /// bprop of iteration `k`: route the assembled-batch gradients back
    /// to their owners, summing contributions from all members, and
    /// accumulate into each member's local gradient buffer at rows
    /// `[k·size, (k+1)·size)`.
    ///
    /// `gbatches[j]` is member j's `[B, width]` partial gradient of the
    /// assembled batch; `g_acts[j]` is member j's `[B, width]` local
    /// activation-gradient accumulator.
    pub fn scatter_reduce(
        &self,
        fabric: &dyn Transport,
        gbatches: &[HostTensor],
        g_acts: &mut [HostTensor],
        k: usize,
        tag: Tag,
    ) -> Result<()> {
        let kk = self.k();
        let size = self.size();
        assert_eq!(gbatches.len(), kk);
        assert_eq!(g_acts.len(), kk);

        // Scatter: member j sends the rows owned by member i (!= j).
        for (j, &src) in self.group.iter().enumerate() {
            for (i, &dst) in self.group.iter().enumerate() {
                if i != j {
                    let rows = gbatches[j].slice_rows(i * size, (i + 1) * size);
                    fabric.post(src, dst, tag, rows.as_f32().to_vec());
                }
            }
        }
        // Reduce: member i sums its own rows + K-1 gathered copies, then
        // accumulates into its local slice for this iteration.
        for (i, &dst) in self.group.iter().enumerate() {
            let mut acc = gbatches[i].slice_rows(i * size, (i + 1) * size);
            for &src in &self.group {
                if src != dst {
                    let data = fabric.take(dst, src, tag)?;
                    acc.add_assign(&HostTensor::f32(vec![size, self.width], data));
                }
            }
            // g_act rows for iteration k are exactly this member's
            // contribution rows — write (they start zeroed per step).
            let base = k * size;
            for r in 0..size {
                let dst_lo = (base + r) * self.width;
                let src_lo = r * self.width;
                let acc_row = &acc.as_f32()[src_lo..src_lo + self.width];
                g_acts[i].as_f32_mut()[dst_lo..dst_lo + self.width]
                    .copy_from_slice(acc_row);
            }
        }
        Ok(())
    }

    // -- per-rank (SPMD) forms, used by the step-program executor ------------
    //
    // Each exchange is split into a *post* half (pure sends — safe to
    // issue as soon as the data exists, which is what the overlapped
    // executor exploits) and a *take* half (blocking receives + the
    // fixed-order assembly/reduction). The BSP program runs the halves
    // back to back; the overlapped program hoists the post halves.

    /// Post half of the per-rank fprop of iteration `k`: push this
    /// member's `[k·size, (k+1)·size)` slice of `act` to every peer.
    /// Side-effect only — the overlapped executor issues this for every
    /// iteration as soon as the activations exist.
    pub fn post_fwd_rank(&self, fabric: &dyn Transport, gi: usize, act: &HostTensor, k: usize, tag: Tag) {
        let kk = self.k();
        let size = self.size();
        assert!(k < kk && gi < kk);
        let me = self.group[gi];
        let local = act.slice_rows(k * size, (k + 1) * size);
        for &dst in &self.group {
            if dst != me {
                fabric.post(me, dst, tag, local.as_f32().to_vec());
            }
        }
    }

    /// Take half of the per-rank fprop of iteration `k`: assemble the
    /// `[B, width]` batch (own slice copied locally, peers' slices via
    /// blocking takes, rows placed by the Fig. 6b owner mapping). Data
    /// placement is identical to [`ModuloPlan::assemble`].
    pub fn gather_fwd_rank(
        &self,
        fabric: &dyn Transport,
        gi: usize,
        act: &HostTensor,
        k: usize,
        tag: Tag,
    ) -> Result<HostTensor> {
        let kk = self.k();
        let size = self.size();
        assert!(k < kk && gi < kk);
        let me = self.group[gi];
        let local = act.slice_rows(k * size, (k + 1) * size);
        let mut batch = HostTensor::zeros(vec![self.batch, self.width]);
        for (j, &src) in self.group.iter().enumerate() {
            if j == gi {
                batch.set_rows(j * size, &local);
            } else {
                let data = fabric.take_blocking(me, src, tag)?;
                batch.set_rows(j * size, &HostTensor::f32(vec![size, self.width], data));
            }
        }
        Ok(batch)
    }

    /// Post half of the per-rank bprop: route the rows of `gbatch`
    /// owned by each peer back to that peer. Side-effect only.
    pub fn post_bwd_rank(&self, fabric: &dyn Transport, gi: usize, gbatch: &HostTensor, tag: Tag) {
        let size = self.size();
        assert!(gi < self.k());
        let me = self.group[gi];
        for (i, &dst) in self.group.iter().enumerate() {
            if i != gi {
                let rows = gbatch.slice_rows(i * size, (i + 1) * size);
                fabric.post(me, dst, tag, rows.as_f32().to_vec());
            }
        }
    }

    /// Take half of the per-rank bprop of iteration `k`: reduce the
    /// copies destined for this member (own rows + peers in group order
    /// — the fixed rank order that keeps all engines bit-identical) and
    /// write rows `[k·size, (k+1)·size)` of `g_act`.
    pub fn reduce_bwd_rank(
        &self,
        fabric: &dyn Transport,
        gi: usize,
        gbatch: &HostTensor,
        g_act: &mut HostTensor,
        k: usize,
        tag: Tag,
    ) -> Result<()> {
        let kk = self.k();
        let size = self.size();
        assert!(k < kk && gi < kk);
        let me = self.group[gi];
        let mut acc = gbatch.slice_rows(gi * size, (gi + 1) * size);
        for &src in &self.group {
            if src != me {
                let data = fabric.take_blocking(me, src, tag)?;
                acc.add_assign(&HostTensor::f32(vec![size, self.width], data));
            }
        }
        let base = k * size;
        for r in 0..size {
            let dst_lo = (base + r) * self.width;
            let src_lo = r * self.width;
            let acc_row = &acc.as_f32()[src_lo..src_lo + self.width];
            g_act.as_f32_mut()[dst_lo..dst_lo + self.width].copy_from_slice(acc_row);
        }
        Ok(())
    }

}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Fabric;

    fn acts(k: usize, b: usize, w: usize) -> Vec<HostTensor> {
        // member j, row r, col c = 100*j + r + 0.01*c
        (0..k)
            .map(|j| {
                let data: Vec<f32> = (0..b * w)
                    .map(|i| 100.0 * j as f32 + (i / w) as f32 + 0.01 * (i % w) as f32)
                    .collect();
                HostTensor::f32(vec![b, w], data)
            })
            .collect()
    }

    #[test]
    fn assemble_places_rows_by_owner() {
        let plan = ModuloPlan::new(vec![0, 1], 4, 3);
        let f = Fabric::new(2);
        let a = acts(2, 4, 3);
        // Iteration 0: rows 0..2 of each member.
        let out = plan.assemble(&f, &a, 0, Tag::new(1, 0, 0)).unwrap();
        for o in &out {
            // rows 0..2 from member 0 (rows 0..2 of its act),
            // rows 2..4 from member 1.
            assert_eq!(o.as_f32()[0], 0.0); // member 0 row 0 col 0
            assert_eq!(o.as_f32()[2 * 3], 100.0); // member 1 row 0
        }
        assert!(f.drained());
    }

    #[test]
    fn assemble_iteration_1_uses_second_slice() {
        let plan = ModuloPlan::new(vec![0, 1], 4, 3);
        let f = Fabric::new(2);
        let a = acts(2, 4, 3);
        let out = plan.assemble(&f, &a, 1, Tag::new(1, 1, 0)).unwrap();
        // Member 0's contribution is now its rows 2..4.
        assert_eq!(out[0].as_f32()[0], 2.0);
        assert_eq!(out[1].as_f32()[2 * 3], 102.0);
    }

    #[test]
    fn fwd_bytes_formula_matches_fabric() {
        let plan = ModuloPlan::new(vec![0, 1, 2, 3], 8, 16);
        let f = Fabric::new(4);
        let a = acts(4, 8, 16);
        plan.assemble(&f, &a, 0, Tag::new(1, 0, 0)).unwrap();
        assert_eq!(f.bytes_from(0), plan.fwd_bytes_per_member());
    }

    #[test]
    fn scatter_reduce_sums_partials() {
        let plan = ModuloPlan::new(vec![0, 1], 2, 2);
        let f = Fabric::new(2);
        // Both members produce all-ones partial gradients over the
        // assembled batch -> each owner's rows sum to 2.
        let gb = vec![
            HostTensor::f32(vec![2, 2], vec![1.0; 4]),
            HostTensor::f32(vec![2, 2], vec![1.0; 4]),
        ];
        let mut g_acts = vec![HostTensor::zeros(vec![2, 2]), HostTensor::zeros(vec![2, 2])];
        plan.scatter_reduce(&f, &gb, &mut g_acts, 0, Tag::new(2, 0, 0)).unwrap();
        // Iteration 0 wrote rows 0..1 (size=1) of each member's g_act.
        assert_eq!(g_acts[0].as_f32(), &[2.0, 2.0, 0.0, 0.0]);
        assert_eq!(g_acts[1].as_f32(), &[2.0, 2.0, 0.0, 0.0]);
        assert!(f.drained());
    }

    #[test]
    fn scatter_reduce_routes_to_owner() {
        let plan = ModuloPlan::new(vec![0, 1], 2, 1);
        let f = Fabric::new(2);
        // Member 0's gradient: rows [10, 20]; member 1's: [1, 2].
        // Owner of row 0 = member 0 -> gets 10+1; owner row 1 = member 1
        // -> gets 20+2.
        let gb = vec![
            HostTensor::f32(vec![2, 1], vec![10.0, 20.0]),
            HostTensor::f32(vec![2, 1], vec![1.0, 2.0]),
        ];
        let mut g = vec![HostTensor::zeros(vec![2, 1]), HostTensor::zeros(vec![2, 1])];
        plan.scatter_reduce(&f, &gb, &mut g, 1, Tag::new(2, 1, 0)).unwrap();
        // Iteration 1 writes row 1 of each local buffer.
        assert_eq!(g[0].as_f32(), &[0.0, 11.0]);
        assert_eq!(g[1].as_f32(), &[0.0, 22.0]);
    }

    #[test]
    fn k1_group_has_no_traffic() {
        let plan = ModuloPlan::new(vec![0], 4, 2);
        let f = Fabric::new(1);
        let a = acts(1, 4, 2);
        let out = plan.assemble(&f, &a, 0, Tag::new(1, 0, 0)).unwrap();
        // K=1: assembled batch = the full local batch (size = B).
        assert_eq!(out[0].as_f32(), a[0].as_f32());
        assert_eq!(f.total_bytes(), 0);
    }

    #[test]
    fn split_post_then_gather_supports_op_major_serial_drive() {
        // The lockstep executor runs the post halves of every rank
        // before any take half, serially, with no thread scope — the
        // result must match the god-view assembly bit-for-bit.
        let plan = ModuloPlan::new(vec![0, 1], 4, 3);
        let f = Fabric::new(2);
        let a = acts(2, 4, 3);
        for gi in 0..2 {
            plan.post_fwd_rank(&f, gi, &a[gi], 0, Tag::new(1, 0, 0));
        }
        let got: Vec<HostTensor> = (0..2)
            .map(|gi| plan.gather_fwd_rank(&f, gi, &a[gi], 0, Tag::new(1, 0, 0)).unwrap())
            .collect();
        let f2 = Fabric::new(2);
        let want = plan.assemble(&f2, &a, 0, Tag::new(1, 0, 0)).unwrap();
        for (g, w) in got.iter().zip(want.iter()) {
            assert_eq!(g.as_f32(), w.as_f32());
        }
        assert!(f.drained());
        assert_eq!(f.total_bytes(), f2.total_bytes());
    }

    #[test]
    fn split_bwd_post_then_reduce_matches_combined() {
        let plan = ModuloPlan::new(vec![0, 1], 2, 2);
        let gb = vec![
            HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]),
            HostTensor::f32(vec![2, 2], vec![10.0, 20.0, 30.0, 40.0]),
        ];
        let f = Fabric::new(2);
        let mut split = vec![HostTensor::zeros(vec![2, 2]), HostTensor::zeros(vec![2, 2])];
        for gi in 0..2 {
            plan.post_bwd_rank(&f, gi, &gb[gi], Tag::new(7, 0, 0));
        }
        for gi in 0..2 {
            plan.reduce_bwd_rank(&f, gi, &gb[gi], &mut split[gi], 0, Tag::new(7, 0, 0)).unwrap();
        }
        let f2 = Fabric::new(2);
        let mut combined = vec![HostTensor::zeros(vec![2, 2]), HostTensor::zeros(vec![2, 2])];
        plan.scatter_reduce(&f2, &gb, &mut combined, 0, Tag::new(7, 0, 0)).unwrap();
        for (a, b) in split.iter().zip(combined.iter()) {
            assert_eq!(a.as_f32(), b.as_f32());
        }
        assert!(f.drained());
    }

    #[test]
    fn roundtrip_fwd_bwd_identity() {
        // If the "FC stack" is the identity (gbatch = batch), then after
        // K iterations every member's g_act equals K times... no: each
        // row of the local act appears in exactly one iteration's
        // assembled batch, and the reduce sums the K identical copies.
        let plan = ModuloPlan::new(vec![0, 1], 4, 3);
        let k = plan.k();
        let a = acts(2, 4, 3);
        let mut g_acts = vec![HostTensor::zeros(vec![4, 3]), HostTensor::zeros(vec![4, 3])];
        let f = Fabric::new(2);
        for it in 0..k {
            let assembled = plan.assemble(&f, &a, it, Tag::new(1, it, 0)).unwrap();
            plan.scatter_reduce(&f, &assembled, &mut g_acts, it, Tag::new(2, it, 0))
                .unwrap();
        }
        // Every member's reduced gradient = K * its own activations.
        for (ga, aa) in g_acts.iter().zip(a.iter()) {
            let mut scaled = aa.clone();
            scaled.scale(k as f32);
            assert!(ga.max_abs_diff(&scaled) < 1e-5);
        }
        assert!(f.drained());
    }
}
