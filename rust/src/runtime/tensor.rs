//! Host-side tensors: the coordinator's working representation.
//!
//! Parameters, activations and gradients live on the host as flat `f32`
//! (or `i32`) buffers with explicit shapes; segment executions consume
//! and produce them directly (the native backend operates on the flat
//! buffers, so the segment boundary is zero-copy).

use anyhow::{bail, Result};

/// Element type of a [`HostTensor`]. The SplitBrain model is f32
/// throughout; labels are i32.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    /// 32-bit IEEE float (parameters, activations, gradients).
    F32,
    /// 32-bit signed integer (labels, counts).
    I32,
}

impl DType {
    /// Parse a manifest dtype token (`float32`/`f32`, `int32`/`i32`).
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" | "f32" => Ok(DType::F32),
            "int32" | "i32" => Ok(DType::I32),
            other => bail!("unsupported dtype {other:?}"),
        }
    }

    /// Bytes per element.
    pub fn size_bytes(self) -> usize {
        4
    }
}

/// A dense host tensor. `data` holds f32 values for F32 and bit-cast
/// i32 values for I32 (kept in one enum-free struct so staging buffers
/// can be pooled).
#[derive(Debug, Clone)]
pub struct HostTensor {
    /// Element type.
    pub dtype: DType,
    /// Row-major shape (empty = scalar).
    pub shape: Vec<usize>,
    f32_data: Vec<f32>,
    i32_data: Vec<i32>,
}

impl HostTensor {
    /// New f32 tensor from shape + data.
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} != data len {}",
            data.len()
        );
        HostTensor { dtype: DType::F32, shape, f32_data: data, i32_data: Vec::new() }
    }

    /// New i32 tensor from shape + data.
    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { dtype: DType::I32, shape, f32_data: Vec::new(), i32_data: data }
    }

    /// All-zeros f32 tensor.
    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        HostTensor::f32(shape, vec![0.0; n])
    }

    /// Element count.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Total byte size of the payload.
    pub fn size_bytes(&self) -> usize {
        self.numel() * self.dtype.size_bytes()
    }

    /// Borrow the flat f32 payload.
    pub fn as_f32(&self) -> &[f32] {
        debug_assert_eq!(self.dtype, DType::F32);
        &self.f32_data
    }

    /// Mutably borrow the flat f32 payload.
    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        debug_assert_eq!(self.dtype, DType::F32);
        &mut self.f32_data
    }

    /// Borrow the flat i32 payload.
    pub fn as_i32(&self) -> &[i32] {
        debug_assert_eq!(self.dtype, DType::I32);
        &self.i32_data
    }

    /// Scalar value of a 0-d / 1-element f32 tensor.
    pub fn scalar(&self) -> f32 {
        assert_eq!(self.numel(), 1, "scalar() on shape {:?}", self.shape);
        match self.dtype {
            DType::F32 => self.f32_data[0],
            DType::I32 => self.i32_data[0] as f32,
        }
    }

    /// Row-slice [lo, hi) along axis 0 (batch axis) — used by the modulo
    /// layer to extract B/K example blocks.
    pub fn slice_rows(&self, lo: usize, hi: usize) -> HostTensor {
        assert!(self.dtype == DType::F32, "slice_rows on f32 only");
        assert!(!self.shape.is_empty() && lo <= hi && hi <= self.shape[0]);
        let row: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = hi - lo;
        HostTensor::f32(shape, self.f32_data[lo * row..hi * row].to_vec())
    }

    /// Overwrite rows [lo, lo+src.rows) with `src` (modulo-layer gather).
    pub fn set_rows(&mut self, lo: usize, src: &HostTensor) {
        assert_eq!(self.dtype, DType::F32);
        assert_eq!(&self.shape[1..], &src.shape[1..], "row shapes differ");
        let row: usize = self.shape[1..].iter().product();
        let n = src.shape[0];
        assert!(lo + n <= self.shape[0]);
        self.f32_data[lo * row..(lo + n) * row].copy_from_slice(&src.f32_data);
    }

    /// Column-slice [lo, hi) along the last axis of a 2-D tensor — used
    /// by shard layers to split full-width activations/gradients.
    pub fn slice_cols(&self, lo: usize, hi: usize) -> HostTensor {
        assert_eq!(self.dtype, DType::F32);
        assert_eq!(self.shape.len(), 2, "slice_cols on 2-D only");
        let (rows, cols) = (self.shape[0], self.shape[1]);
        assert!(lo <= hi && hi <= cols);
        let mut out = Vec::with_capacity(rows * (hi - lo));
        for r in 0..rows {
            out.extend_from_slice(&self.f32_data[r * cols + lo..r * cols + hi]);
        }
        HostTensor::f32(vec![rows, hi - lo], out)
    }

    /// Write `src` into columns [lo, lo+src.cols) of a 2-D tensor —
    /// the shard-layer allgather destination.
    pub fn set_cols(&mut self, lo: usize, src: &HostTensor) {
        assert_eq!(self.dtype, DType::F32);
        assert_eq!(self.shape.len(), 2);
        assert_eq!(src.shape.len(), 2);
        assert_eq!(self.shape[0], src.shape[0]);
        let (rows, cols, scols) = (self.shape[0], self.shape[1], src.shape[1]);
        assert!(lo + scols <= cols);
        for r in 0..rows {
            self.f32_data[r * cols + lo..r * cols + lo + scols]
                .copy_from_slice(&src.f32_data[r * scols..(r + 1) * scols]);
        }
    }

    /// In-place elementwise add (gradient reduction).
    pub fn add_assign(&mut self, other: &HostTensor) {
        assert_eq!(self.shape, other.shape);
        assert_eq!(self.dtype, DType::F32);
        for (a, b) in self.f32_data.iter_mut().zip(other.f32_data.iter()) {
            *a += *b;
        }
    }

    /// In-place scale (gradient /K compensation, averaging).
    pub fn scale(&mut self, s: f32) {
        for v in self.f32_data.iter_mut() {
            *v *= s;
        }
    }

    /// Max |a - b| — test helper.
    pub fn max_abs_diff(&self, other: &HostTensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.f32_data
            .iter()
            .zip(other.f32_data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t2x3() -> HostTensor {
        HostTensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.])
    }

    #[test]
    fn numel_and_bytes() {
        let t = t2x3();
        assert_eq!(t.numel(), 6);
        assert_eq!(t.size_bytes(), 24);
    }

    #[test]
    fn slice_rows_extracts_block() {
        let t = t2x3();
        let r = t.slice_rows(1, 2);
        assert_eq!(r.shape, vec![1, 3]);
        assert_eq!(r.as_f32(), &[4., 5., 6.]);
    }

    #[test]
    fn set_rows_writes_block() {
        let mut t = HostTensor::zeros(vec![3, 2]);
        t.set_rows(1, &HostTensor::f32(vec![1, 2], vec![7., 8.]));
        assert_eq!(t.as_f32(), &[0., 0., 7., 8., 0., 0.]);
    }

    #[test]
    fn slice_cols_extracts_partition() {
        let t = t2x3();
        let c = t.slice_cols(1, 3);
        assert_eq!(c.shape, vec![2, 2]);
        assert_eq!(c.as_f32(), &[2., 3., 5., 6.]);
    }

    #[test]
    fn set_cols_roundtrip() {
        let t = t2x3();
        let mut out = HostTensor::zeros(vec![2, 3]);
        out.set_cols(0, &t.slice_cols(0, 1));
        out.set_cols(1, &t.slice_cols(1, 3));
        assert_eq!(out.as_f32(), t.as_f32());
    }

    #[test]
    fn add_assign_and_scale() {
        let mut a = t2x3();
        a.add_assign(&t2x3());
        a.scale(0.5);
        assert_eq!(a.as_f32(), t2x3().as_f32());
    }

    #[test]
    fn scalar_extraction() {
        let t = HostTensor::f32(vec![], vec![3.5]);
        assert_eq!(t.scalar(), 3.5);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        HostTensor::f32(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn max_abs_diff_works() {
        let a = t2x3();
        let mut b = t2x3();
        b.as_f32_mut()[4] += 0.25;
        assert_eq!(a.max_abs_diff(&b), 0.25);
    }
}
