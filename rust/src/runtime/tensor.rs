//! Host-side tensors: the coordinator's working representation.
//!
//! Parameters, activations and gradients live on the host as flat `f32`
//! (or `i32`) buffers with explicit shapes; segment executions consume
//! and produce them directly (the native backend operates on the flat
//! buffers, so the segment boundary is zero-copy).

use anyhow::{bail, Result};

/// Maximum tensor rank the wire encoding accepts (the model never
/// exceeds 4; 8 leaves headroom while keeping hostile headers cheap to
/// reject).
pub const MAX_WIRE_NDIM: usize = 8;

/// Element type of a [`HostTensor`]. The SplitBrain model is f32
/// throughout; labels are i32.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    /// 32-bit IEEE float (parameters, activations, gradients).
    F32,
    /// 32-bit signed integer (labels, counts).
    I32,
}

impl DType {
    /// Parse a manifest dtype token (`float32`/`f32`, `int32`/`i32`).
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "float32" | "f32" => Ok(DType::F32),
            "int32" | "i32" => Ok(DType::I32),
            other => bail!("unsupported dtype {other:?}"),
        }
    }

    /// Bytes per element.
    pub fn size_bytes(self) -> usize {
        4
    }
}

/// A dense host tensor. `data` holds f32 values for F32 and bit-cast
/// i32 values for I32 (kept in one enum-free struct so staging buffers
/// can be pooled).
#[derive(Debug, Clone)]
pub struct HostTensor {
    /// Element type.
    pub dtype: DType,
    /// Row-major shape (empty = scalar).
    pub shape: Vec<usize>,
    f32_data: Vec<f32>,
    i32_data: Vec<i32>,
}

impl PartialEq for HostTensor {
    /// **Bit-exact** equality: same dtype, same shape, same payload bit
    /// patterns. Two NaNs with identical bits compare equal — this is
    /// the identity the parity suites assert, deliberately not IEEE
    /// `==` semantics.
    fn eq(&self, other: &HostTensor) -> bool {
        self.dtype == other.dtype
            && self.shape == other.shape
            && match self.dtype {
                DType::F32 => self
                    .f32_data
                    .iter()
                    .zip(other.f32_data.iter())
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                DType::I32 => self.i32_data == other.i32_data,
            }
    }
}

impl HostTensor {
    /// New f32 tensor from shape + data.
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} != data len {}",
            data.len()
        );
        HostTensor { dtype: DType::F32, shape, f32_data: data, i32_data: Vec::new() }
    }

    /// New i32 tensor from shape + data.
    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { dtype: DType::I32, shape, f32_data: Vec::new(), i32_data: data }
    }

    /// All-zeros f32 tensor.
    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        HostTensor::f32(shape, vec![0.0; n])
    }

    /// Element count.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Total byte size of the payload.
    pub fn size_bytes(&self) -> usize {
        self.numel() * self.dtype.size_bytes()
    }

    /// Borrow the flat f32 payload.
    pub fn as_f32(&self) -> &[f32] {
        debug_assert_eq!(self.dtype, DType::F32);
        &self.f32_data
    }

    /// Consume the tensor, returning its flat f32 payload without a
    /// copy (the receive hot path of the TCP transport).
    pub fn into_f32(self) -> Vec<f32> {
        debug_assert_eq!(self.dtype, DType::F32);
        self.f32_data
    }

    /// Mutably borrow the flat f32 payload.
    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        debug_assert_eq!(self.dtype, DType::F32);
        &mut self.f32_data
    }

    /// Borrow the flat i32 payload.
    pub fn as_i32(&self) -> &[i32] {
        debug_assert_eq!(self.dtype, DType::I32);
        &self.i32_data
    }

    /// Scalar value of a 0-d / 1-element f32 tensor.
    pub fn scalar(&self) -> f32 {
        assert_eq!(self.numel(), 1, "scalar() on shape {:?}", self.shape);
        match self.dtype {
            DType::F32 => self.f32_data[0],
            DType::I32 => self.i32_data[0] as f32,
        }
    }

    /// Row-slice [lo, hi) along axis 0 (batch axis) — used by the modulo
    /// layer to extract B/K example blocks.
    pub fn slice_rows(&self, lo: usize, hi: usize) -> HostTensor {
        assert!(self.dtype == DType::F32, "slice_rows on f32 only");
        assert!(!self.shape.is_empty() && lo <= hi && hi <= self.shape[0]);
        let row: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = hi - lo;
        HostTensor::f32(shape, self.f32_data[lo * row..hi * row].to_vec())
    }

    /// Overwrite rows [lo, lo+src.rows) with `src` (modulo-layer gather).
    pub fn set_rows(&mut self, lo: usize, src: &HostTensor) {
        assert_eq!(self.dtype, DType::F32);
        assert_eq!(&self.shape[1..], &src.shape[1..], "row shapes differ");
        let row: usize = self.shape[1..].iter().product();
        let n = src.shape[0];
        assert!(lo + n <= self.shape[0]);
        self.f32_data[lo * row..(lo + n) * row].copy_from_slice(&src.f32_data);
    }

    /// Column-slice [lo, hi) along the last axis of a 2-D tensor — used
    /// by shard layers to split full-width activations/gradients.
    pub fn slice_cols(&self, lo: usize, hi: usize) -> HostTensor {
        assert_eq!(self.dtype, DType::F32);
        assert_eq!(self.shape.len(), 2, "slice_cols on 2-D only");
        let (rows, cols) = (self.shape[0], self.shape[1]);
        assert!(lo <= hi && hi <= cols);
        let mut out = Vec::with_capacity(rows * (hi - lo));
        for r in 0..rows {
            out.extend_from_slice(&self.f32_data[r * cols + lo..r * cols + hi]);
        }
        HostTensor::f32(vec![rows, hi - lo], out)
    }

    /// Write `src` into columns [lo, lo+src.cols) of a 2-D tensor —
    /// the shard-layer allgather destination.
    pub fn set_cols(&mut self, lo: usize, src: &HostTensor) {
        assert_eq!(self.dtype, DType::F32);
        assert_eq!(self.shape.len(), 2);
        assert_eq!(src.shape.len(), 2);
        assert_eq!(self.shape[0], src.shape[0]);
        let (rows, cols, scols) = (self.shape[0], self.shape[1], src.shape[1]);
        assert!(lo + scols <= cols);
        for r in 0..rows {
            self.f32_data[r * cols + lo..r * cols + lo + scols]
                .copy_from_slice(&src.f32_data[r * scols..(r + 1) * scols]);
        }
    }

    /// In-place elementwise add (gradient reduction).
    pub fn add_assign(&mut self, other: &HostTensor) {
        assert_eq!(self.shape, other.shape);
        assert_eq!(self.dtype, DType::F32);
        for (a, b) in self.f32_data.iter_mut().zip(other.f32_data.iter()) {
            *a += *b;
        }
    }

    /// In-place scale (gradient /K compensation, averaging).
    pub fn scale(&mut self, s: f32) {
        for v in self.f32_data.iter_mut() {
            *v *= s;
        }
    }

    /// Serialize to the self-describing little-endian byte layout the
    /// wire protocol frames tensors with:
    ///
    /// ```text
    /// u8  dtype        (0 = f32, 1 = i32)
    /// u8  ndim         (≤ MAX_WIRE_NDIM)
    /// u32 dims[ndim]
    /// u32 data[numel]  (f32 bit patterns / i32 two's complement)
    /// ```
    ///
    /// The payload is the raw bit pattern — NaNs, infinities and
    /// negative zeros survive a round-trip bit-exactly.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(2 + 4 * self.shape.len() + self.size_bytes());
        out.push(match self.dtype {
            DType::F32 => 0u8,
            DType::I32 => 1u8,
        });
        debug_assert!(self.shape.len() <= MAX_WIRE_NDIM, "shape rank exceeds wire limit");
        out.push(self.shape.len() as u8);
        for &d in &self.shape {
            debug_assert!(d <= u32::MAX as usize, "dim exceeds wire limit");
            out.extend_from_slice(&(d as u32).to_le_bytes());
        }
        match self.dtype {
            DType::F32 => {
                for &v in &self.f32_data {
                    out.extend_from_slice(&v.to_bits().to_le_bytes());
                }
            }
            DType::I32 => {
                for &v in &self.i32_data {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
        out
    }

    /// Decode the [`HostTensor::to_bytes`] layout. Every failure is a
    /// typed error, never a panic, and no allocation happens before the
    /// declared sizes are validated against the actual byte count — a
    /// hostile length field cannot trigger an unbounded allocation.
    pub fn from_bytes(buf: &[u8]) -> Result<HostTensor> {
        if buf.len() < 2 {
            bail!("tensor header truncated: {} bytes", buf.len());
        }
        let dtype = match buf[0] {
            0 => DType::F32,
            1 => DType::I32,
            other => bail!("unknown wire dtype {other}"),
        };
        let ndim = buf[1] as usize;
        if ndim > MAX_WIRE_NDIM {
            bail!("implausible tensor rank {ndim} (max {MAX_WIRE_NDIM})");
        }
        let dims_end = 2 + 4 * ndim;
        if buf.len() < dims_end {
            bail!("tensor dims truncated: {} bytes for rank {ndim}", buf.len());
        }
        let mut shape = Vec::with_capacity(ndim);
        let mut numel: usize = 1;
        for i in 0..ndim {
            let d = u32::from_le_bytes(buf[2 + 4 * i..6 + 4 * i].try_into().unwrap()) as usize;
            numel = numel
                .checked_mul(d)
                .ok_or_else(|| anyhow::anyhow!("tensor shape overflows: {shape:?} x {d}"))?;
            shape.push(d);
        }
        let data = &buf[dims_end..];
        let need = numel
            .checked_mul(4)
            .ok_or_else(|| anyhow::anyhow!("tensor byte size overflows: {shape:?}"))?;
        if data.len() != need {
            bail!(
                "tensor payload length mismatch: shape {shape:?} needs {need} bytes, got {}",
                data.len()
            );
        }
        Ok(match dtype {
            DType::F32 => HostTensor::f32(
                shape,
                data.chunks_exact(4)
                    .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
                    .collect(),
            ),
            DType::I32 => HostTensor::i32(
                shape,
                data.chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            ),
        })
    }

    /// Max |a - b| — test helper.
    pub fn max_abs_diff(&self, other: &HostTensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.f32_data
            .iter()
            .zip(other.f32_data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t2x3() -> HostTensor {
        HostTensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.])
    }

    #[test]
    fn numel_and_bytes() {
        let t = t2x3();
        assert_eq!(t.numel(), 6);
        assert_eq!(t.size_bytes(), 24);
    }

    #[test]
    fn slice_rows_extracts_block() {
        let t = t2x3();
        let r = t.slice_rows(1, 2);
        assert_eq!(r.shape, vec![1, 3]);
        assert_eq!(r.as_f32(), &[4., 5., 6.]);
    }

    #[test]
    fn set_rows_writes_block() {
        let mut t = HostTensor::zeros(vec![3, 2]);
        t.set_rows(1, &HostTensor::f32(vec![1, 2], vec![7., 8.]));
        assert_eq!(t.as_f32(), &[0., 0., 7., 8., 0., 0.]);
    }

    #[test]
    fn slice_cols_extracts_partition() {
        let t = t2x3();
        let c = t.slice_cols(1, 3);
        assert_eq!(c.shape, vec![2, 2]);
        assert_eq!(c.as_f32(), &[2., 3., 5., 6.]);
    }

    #[test]
    fn set_cols_roundtrip() {
        let t = t2x3();
        let mut out = HostTensor::zeros(vec![2, 3]);
        out.set_cols(0, &t.slice_cols(0, 1));
        out.set_cols(1, &t.slice_cols(1, 3));
        assert_eq!(out.as_f32(), t.as_f32());
    }

    #[test]
    fn add_assign_and_scale() {
        let mut a = t2x3();
        a.add_assign(&t2x3());
        a.scale(0.5);
        assert_eq!(a.as_f32(), t2x3().as_f32());
    }

    #[test]
    fn scalar_extraction() {
        let t = HostTensor::f32(vec![], vec![3.5]);
        assert_eq!(t.scalar(), 3.5);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        HostTensor::f32(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn byte_roundtrip_f32_and_i32() {
        let t = t2x3();
        let back = HostTensor::from_bytes(&t.to_bytes()).unwrap();
        assert_eq!(back.shape, t.shape);
        assert_eq!(back.as_f32(), t.as_f32());
        let i = HostTensor::i32(vec![4], vec![-1, 0, i32::MAX, i32::MIN]);
        let back = HostTensor::from_bytes(&i.to_bytes()).unwrap();
        assert_eq!(back.dtype, DType::I32);
        assert_eq!(back.as_i32(), i.as_i32());
    }

    #[test]
    fn byte_roundtrip_preserves_non_finite_bits() {
        let t = HostTensor::f32(
            vec![5],
            vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.0, f32::from_bits(0x7fc0_dead)],
        );
        let back = HostTensor::from_bytes(&t.to_bytes()).unwrap();
        for (a, b) in t.as_f32().iter().zip(back.as_f32()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn byte_decode_rejects_malformed() {
        assert!(HostTensor::from_bytes(&[]).is_err());
        assert!(HostTensor::from_bytes(&[9, 0]).is_err(), "unknown dtype");
        assert!(HostTensor::from_bytes(&[0, 200]).is_err(), "implausible rank");
        // Shape promises more data than present: typed error, no alloc.
        let mut b = vec![0u8, 1];
        b.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(HostTensor::from_bytes(&b).is_err());
        // Overflowing shape product.
        let mut b = vec![0u8, 4];
        for _ in 0..4 {
            b.extend_from_slice(&u32::MAX.to_le_bytes());
        }
        assert!(HostTensor::from_bytes(&b).is_err());
        // Element count fits usize but the byte size overflows it:
        // typed error, no debug-overflow panic (2^31 × 2^31 × 4 = 2^64).
        let mut b = vec![0u8, 2];
        b.extend_from_slice(&0x8000_0000u32.to_le_bytes());
        b.extend_from_slice(&0x8000_0000u32.to_le_bytes());
        assert!(HostTensor::from_bytes(&b).is_err());
    }

    #[test]
    fn max_abs_diff_works() {
        let a = t2x3();
        let mut b = t2x3();
        b.as_f32_mut()[4] += 0.25;
        assert_eq!(a.max_abs_diff(&b), 0.25);
    }
}
