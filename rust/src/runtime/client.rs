//! PJRT CPU client + executable cache.
//!
//! One client is shared by the whole simulated cluster: on the CPU
//! backend PJRT executions are serialized by the simulator anyway (each
//! worker's segment time is measured individually and composed on the
//! simulated clock — see `coordinator::cluster`), and sharing means each
//! artifact is compiled exactly once per process.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::artifacts::{ArtifactSpec, Manifest};
use super::tensor::HostTensor;

/// A compiled artifact, ready to execute.
pub struct Executable {
    spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    /// Cumulative (calls, seconds) for profiling.
    profile: RefCell<(u64, f64)>,
}

impl Executable {
    /// Execute with shape-checked host tensors; returns the unwrapped
    /// output tuple as host tensors.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.check_inputs(inputs)?;
        let start = Instant::now();
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.spec.name))?;
        let mut lit = result[0][0]
            .to_literal_sync()
            .context("device -> host transfer")?;
        // aot.py lowers with return_tuple=True: decompose the tuple.
        let parts = lit.decompose_tuple().context("decompose output tuple")?;
        let mut outs = Vec::with_capacity(parts.len());
        for (i, p) in parts.iter().enumerate() {
            let t = HostTensor::from_literal(p)
                .with_context(|| format!("output {i} of {}", self.spec.name))?;
            outs.push(t);
        }
        if outs.len() != self.spec.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.spec.name,
                self.spec.outputs.len(),
                outs.len()
            );
        }
        let dt = start.elapsed().as_secs_f64();
        let mut prof = self.profile.borrow_mut();
        prof.0 += 1;
        prof.1 += dt;
        Ok(outs)
    }

    fn check_inputs(&self, inputs: &[HostTensor]) -> Result<()> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs ({:?}), got {}",
                self.spec.name,
                self.spec.inputs.len(),
                self.spec.inputs.iter().map(|s| s.name.as_str()).collect::<Vec<_>>(),
                inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(self.spec.inputs.iter()).enumerate() {
            if t.shape != s.shape || t.dtype != s.dtype {
                bail!(
                    "{} input {i} ({}): expected {:?} {:?}, got {:?} {:?}",
                    self.spec.name,
                    s.name,
                    s.dtype,
                    s.shape,
                    t.dtype,
                    t.shape
                );
            }
        }
        Ok(())
    }

    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// (calls, cumulative seconds) since load.
    pub fn profile(&self) -> (u64, f64) {
        *self.profile.borrow()
    }
}

/// The runtime: PJRT CPU client, manifest, and lazily compiled
/// executables keyed by artifact name.
pub struct RuntimeClient {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
    calib: RefCell<HashMap<String, f64>>,
}

impl RuntimeClient {
    /// Load the manifest from `dir` and connect the PJRT CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<RuntimeClient> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(RuntimeClient {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            calib: RefCell::new(HashMap::new()),
        })
    }

    /// Platform string, e.g. "cpu" (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Get (compiling on first use) the executable for `name`.
    pub fn executable(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.get(name)?.clone();
        let path = spec.file.to_str().context("artifact path utf-8")?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA-compiling {name}"))?;
        let e = Rc::new(Executable { spec, exe, profile: RefCell::new((0, 0.0)) });
        self.cache.borrow_mut().insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Convenience: run artifact `name` on `inputs`.
    pub fn run(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.executable(name)?.run(inputs)
    }

    /// Calibrated per-call seconds for an artifact: measured once per
    /// process (dummy inputs, 1 warmup + `runs` timed), then cached —
    /// the calibrated simulator and the planner share these numbers.
    pub fn calibrated_secs(&self, name: &str, runs: usize) -> Result<f64> {
        if let Some(&t) = self.calib.borrow().get(name) {
            return Ok(t);
        }
        use super::tensor::DType;
        use crate::util::Rng;
        let exe = self.executable(name)?;
        let mut rng = Rng::new(0xCA11B);
        let inputs: Vec<HostTensor> = exe
            .spec()
            .inputs
            .iter()
            .map(|s| match s.dtype {
                DType::F32 => HostTensor::f32(s.shape.clone(), rng.normal_vec(s.numel(), 0.02)),
                DType::I32 => HostTensor::i32(
                    s.shape.clone(),
                    (0..s.numel()).map(|i| (i % 10) as i32).collect(),
                ),
            })
            .collect();
        exe.run(&inputs)?; // warmup
        // Min over runs: robust to transient host contention (the
        // quantity modeled is the artifact's intrinsic cost).
        let mut per = f64::INFINITY;
        for _ in 0..runs.max(1) {
            let start = Instant::now();
            exe.run(&inputs)?;
            per = per.min(start.elapsed().as_secs_f64());
        }
        self.calib.borrow_mut().insert(name.to_string(), per);
        Ok(per)
    }

    /// Profiling snapshot: (artifact, calls, cumulative secs), sorted by
    /// cumulative time descending. Drives the §Perf analysis.
    pub fn profile_report(&self) -> Vec<(String, u64, f64)> {
        let mut rows: Vec<(String, u64, f64)> = self
            .cache
            .borrow()
            .iter()
            .map(|(k, e)| {
                let (calls, secs) = e.profile();
                (k.clone(), calls, secs)
            })
            .collect();
        rows.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
        rows
    }
}
