//! Runtime client: artifact manifest + segment executor + profiling.
//!
//! The original seed executed AOT-lowered HLO text through a PJRT CPU
//! client (`xla` crate). That backend needs an XLA runtime the offline
//! build environment does not provide, so execution is served by the
//! [`super::native`] reference backend — a pure-Rust, bit-deterministic
//! implementation of the exact same segment functions. The manifest
//! contract is unchanged: when an `artifacts/` directory produced by
//! `python -m compile.aot` is present its manifest is loaded and every
//! call is validated against it; otherwise the built-in native manifest
//! (batch 8, mp ∈ {1,2,4,8}) is used.
//!
//! ## Thread safety
//!
//! [`RuntimeClient`] is `Send + Sync` and designed for concurrent use
//! by the threaded cluster engine: segment execution is pure (no shared
//! mutable state), and the executable cache, calibration cache and
//! profiling counters sit behind `Mutex`es. Cloning the `Arc`-backed
//! [`Executable`] handles out of the cache is cheap.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Result};

use super::artifacts::{ArtifactSpec, Manifest};
use super::native;
use super::tensor::HostTensor;

/// A callable artifact handle: spec validation + execution + profiling.
pub struct Executable {
    spec: ArtifactSpec,
    /// Cumulative (calls, seconds) for profiling.
    profile: Mutex<(u64, f64)>,
}

impl Executable {
    /// Execute with shape-checked host tensors; returns the output
    /// tuple as host tensors.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.check_inputs(inputs)?;
        let start = Instant::now();
        let outs = native::execute(&self.spec.name, inputs)?;
        if outs.len() != self.spec.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.spec.name,
                self.spec.outputs.len(),
                outs.len()
            );
        }
        let dt = start.elapsed().as_secs_f64();
        let mut prof = self.profile.lock().unwrap();
        prof.0 += 1;
        prof.1 += dt;
        Ok(outs)
    }

    fn check_inputs(&self, inputs: &[HostTensor]) -> Result<()> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs ({:?}), got {}",
                self.spec.name,
                self.spec.inputs.len(),
                self.spec.inputs.iter().map(|s| s.name.as_str()).collect::<Vec<_>>(),
                inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(self.spec.inputs.iter()).enumerate() {
            if t.shape != s.shape || t.dtype != s.dtype {
                bail!(
                    "{} input {i} ({}): expected {:?} {:?}, got {:?} {:?}",
                    self.spec.name,
                    s.name,
                    s.dtype,
                    s.shape,
                    t.dtype,
                    t.shape
                );
            }
        }
        Ok(())
    }

    /// The artifact's I/O signature.
    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// (calls, cumulative seconds) since load.
    pub fn profile(&self) -> (u64, f64) {
        *self.profile.lock().unwrap()
    }
}

/// The runtime: manifest plus executable/calibration caches. `Sync`, so
/// one client serves every worker thread of the simulated cluster.
pub struct RuntimeClient {
    /// The artifact inventory calls are validated against.
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
    calib: Mutex<HashMap<String, f64>>,
}

impl RuntimeClient {
    /// Load the manifest from `dir` when present, else fall back to the
    /// built-in native manifest. Either way, segments execute on the
    /// native backend.
    pub fn load(dir: impl AsRef<Path>) -> Result<RuntimeClient> {
        let manifest = if dir.as_ref().join("manifest.txt").exists() {
            Manifest::load(dir)?
        } else {
            native::native_manifest()?
        };
        Ok(RuntimeClient {
            manifest,
            cache: Mutex::new(HashMap::new()),
            calib: Mutex::new(HashMap::new()),
        })
    }

    /// Build a client on the built-in native manifest directly.
    pub fn native() -> Result<RuntimeClient> {
        Ok(RuntimeClient {
            manifest: native::native_manifest()?,
            cache: Mutex::new(HashMap::new()),
            calib: Mutex::new(HashMap::new()),
        })
    }

    /// Backend platform string (diagnostics).
    pub fn platform(&self) -> String {
        "native-cpu".to_string()
    }

    /// Get (instantiating on first use) the executable for `name`.
    /// The lock spans lookup-and-insert so concurrent worker threads
    /// share one instance (and its profiling counters).
    pub fn executable(&self, name: &str) -> Result<Arc<Executable>> {
        let mut cache = self.cache.lock().unwrap();
        if let Some(e) = cache.get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.get(name)?.clone();
        let e = Arc::new(Executable { spec, profile: Mutex::new((0, 0.0)) });
        cache.insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Convenience: run artifact `name` on `inputs`.
    pub fn run(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.executable(name)?.run(inputs)
    }

    /// Calibrated per-call seconds for an artifact: measured once per
    /// process (dummy inputs, 1 warmup + `runs` timed), then cached —
    /// the calibrated simulator and the planner share these numbers.
    pub fn calibrated_secs(&self, name: &str, runs: usize) -> Result<f64> {
        // Hold the calibration lock for the whole measurement:
        // serializing calibration keeps the timings contention-free and
        // prevents concurrent callers from each paying the warmup.
        let mut calib = self.calib.lock().unwrap();
        if let Some(&t) = calib.get(name) {
            return Ok(t);
        }
        use super::tensor::DType;
        use crate::util::Rng;
        let exe = self.executable(name)?;
        let mut rng = Rng::new(0xCA11B);
        let inputs: Vec<HostTensor> = exe
            .spec()
            .inputs
            .iter()
            .map(|s| match s.dtype {
                DType::F32 => HostTensor::f32(s.shape.clone(), rng.normal_vec(s.numel(), 0.02)),
                DType::I32 => HostTensor::i32(
                    s.shape.clone(),
                    (0..s.numel()).map(|i| (i % 10) as i32).collect(),
                ),
            })
            .collect();
        exe.run(&inputs)?; // warmup
        // Min over runs: robust to transient host contention (the
        // quantity modeled is the artifact's intrinsic cost).
        let mut per = f64::INFINITY;
        for _ in 0..runs.max(1) {
            let start = Instant::now();
            exe.run(&inputs)?;
            per = per.min(start.elapsed().as_secs_f64());
        }
        calib.insert(name.to_string(), per);
        Ok(per)
    }

    /// Profiling snapshot: (artifact, calls, cumulative secs), sorted by
    /// cumulative time descending. Drives the §Perf analysis.
    pub fn profile_report(&self) -> Vec<(String, u64, f64)> {
        let mut rows: Vec<(String, u64, f64)> = self
            .cache
            .lock()
            .unwrap()
            .iter()
            .map(|(k, e)| {
                let (calls, secs) = e.profile();
                (k.clone(), calls, secs)
            })
            .collect();
        rows.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
        rows
    }
}
