//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime.
//!
//! `artifacts/manifest.txt` is a plain-text inventory:
//!
//! ```text
//! splitbrain-artifacts v1
//! batch 32
//! mp_sizes 1,2,4,8
//! ...
//! artifact conv_fwd file=conv_fwd.hlo.txt sha256=...
//! in cw0 float32 3,3,3,64
//! ...
//! out act float32 32,4096
//! end
//! ```
//!
//! The Rust side validates every execution call against these
//! signatures, so a stale artifacts/ directory fails loudly instead of
//! feeding wrong-shaped literals into PJRT.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::tensor::DType;

/// Name + dtype + shape of one artifact input or output.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    /// Manifest name of the input/output.
    pub name: String,
    /// Element type.
    pub dtype: DType,
    /// Row-major shape (empty = scalar).
    pub shape: Vec<usize>,
}

impl TensorSpec {
    /// Element count.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(tokens: &[&str]) -> Result<TensorSpec> {
        if tokens.len() != 3 {
            bail!("bad tensor spec: {tokens:?}");
        }
        let dtype = DType::parse(tokens[1])?;
        let shape = if tokens[2] == "scalar" {
            vec![]
        } else {
            tokens[2]
                .split(',')
                .map(|d| d.parse::<usize>().context("bad dim"))
                .collect::<Result<Vec<_>>>()?
        };
        Ok(TensorSpec { name: tokens[0].to_string(), dtype, shape })
    }
}

/// One AOT-lowered segment: file plus full I/O signature.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Artifact name (the runtime's call key).
    pub name: String,
    /// Lowered HLO text file (unused by the native backend).
    pub file: PathBuf,
    /// Content digest recorded at lowering time.
    pub sha256: String,
    /// Input signature, in call order.
    pub inputs: Vec<TensorSpec>,
    /// Output signature, in tuple order.
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest: header fields + artifact table.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Batch size the segments were lowered for.
    pub batch: usize,
    /// MP group sizes with shard segments available.
    pub mp_sizes: Vec<usize>,
    /// Flattened conv-front feature width.
    pub feature_dim: usize,
    /// Classifier output classes.
    pub num_classes: usize,
    /// Artifact table, keyed by name.
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Parse `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (separated out for unit testing).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let mut batch = 0usize;
        let mut mp_sizes = Vec::new();
        let mut feature_dim = 0usize;
        let mut num_classes = 0usize;
        let mut artifacts = BTreeMap::new();
        let mut cur: Option<ArtifactSpec> = None;

        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let tok: Vec<&str> = line.split_whitespace().collect();
            let ctx = || format!("manifest line {}: {line:?}", lineno + 1);
            match tok[0] {
                "splitbrain-artifacts" => {
                    if tok.get(1) != Some(&"v1") {
                        bail!("unsupported manifest version: {line}");
                    }
                }
                "batch" => batch = tok[1].parse().with_context(ctx)?,
                "mp_sizes" => {
                    mp_sizes = tok[1]
                        .split(',')
                        .map(|s| s.parse::<usize>().context("mp size"))
                        .collect::<Result<Vec<_>>>()?
                }
                "feature_dim" => feature_dim = tok[1].parse().with_context(ctx)?,
                "num_classes" => num_classes = tok[1].parse().with_context(ctx)?,
                "pallas_conv" => {}
                "artifact" => {
                    if cur.is_some() {
                        bail!("nested artifact at line {}", lineno + 1);
                    }
                    let name = tok[1].to_string();
                    let mut file = String::new();
                    let mut sha256 = String::new();
                    for kv in &tok[2..] {
                        match kv.split_once('=') {
                            Some(("file", v)) => file = v.to_string(),
                            Some(("sha256", v)) => sha256 = v.to_string(),
                            _ => bail!("bad artifact attribute {kv:?}"),
                        }
                    }
                    if file.is_empty() {
                        bail!("artifact {name} missing file=");
                    }
                    cur = Some(ArtifactSpec {
                        name,
                        file: dir.join(file),
                        sha256,
                        inputs: Vec::new(),
                        outputs: Vec::new(),
                    });
                }
                "in" => cur
                    .as_mut()
                    .with_context(ctx)?
                    .inputs
                    .push(TensorSpec::parse(&tok[1..]).with_context(ctx)?),
                "out" => cur
                    .as_mut()
                    .with_context(ctx)?
                    .outputs
                    .push(TensorSpec::parse(&tok[1..]).with_context(ctx)?),
                "end" => {
                    let a = cur.take().with_context(ctx)?;
                    artifacts.insert(a.name.clone(), a);
                }
                other => bail!("unknown manifest keyword {other:?} at line {}", lineno + 1),
            }
        }
        if cur.is_some() {
            bail!("manifest ended inside an artifact block");
        }
        if batch == 0 || artifacts.is_empty() {
            bail!("manifest missing batch size or artifacts");
        }
        Ok(Manifest { dir, batch, mp_sizes, feature_dim, num_classes, artifacts })
    }

    /// Look up an artifact by name.
    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).with_context(|| {
            format!(
                "artifact {name:?} not in manifest (have: {:?}) — re-run `make artifacts`",
                self.artifacts.keys().collect::<Vec<_>>()
            )
        })
    }

    /// True if shard segments for MP group size `k` were lowered.
    pub fn supports_mp(&self, k: usize) -> bool {
        k == 1 || self.artifacts.contains_key(&format!("fc0_fwd_k{k}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
splitbrain-artifacts v1
batch 8
mp_sizes 1,2
feature_dim 4096
num_classes 10
artifact conv_fwd file=conv_fwd.hlo.txt sha256=abcd
in cw0 float32 3,3,3,64
in x float32 8,32,32,3
out act float32 8,4096
end
artifact head_step file=head_step.hlo.txt
in fw2 float32 1024,10
in labels int32 8
out loss float32 scalar
end
";

    #[test]
    fn parses_header_and_artifacts() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.batch, 8);
        assert_eq!(m.mp_sizes, vec![1, 2]);
        assert_eq!(m.artifacts.len(), 2);
        let a = m.get("conv_fwd").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[1].shape, vec![8, 32, 32, 3]);
        assert_eq!(a.sha256, "abcd");
    }

    #[test]
    fn scalar_shape_is_empty() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let h = m.get("head_step").unwrap();
        assert_eq!(h.outputs[0].shape, Vec::<usize>::new());
        assert_eq!(h.outputs[0].numel(), 1);
    }

    #[test]
    fn i32_dtype_parsed() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.get("head_step").unwrap().inputs[1].dtype, DType::I32);
    }

    #[test]
    fn supports_mp_checks_artifacts() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert!(m.supports_mp(1));
        assert!(!m.supports_mp(2)); // no fc0_fwd_k2 in SAMPLE
    }

    #[test]
    fn unknown_artifact_error_mentions_make() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let err = m.get("nope").unwrap_err().to_string();
        assert!(err.contains("make artifacts"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse("bogus line", PathBuf::new()).is_err());
        assert!(Manifest::parse("splitbrain-artifacts v2", PathBuf::new()).is_err());
    }

    #[test]
    fn rejects_unterminated_artifact() {
        let bad = "splitbrain-artifacts v1\nbatch 8\nartifact x file=x.hlo\nin a float32 1";
        assert!(Manifest::parse(bad, PathBuf::new()).is_err());
    }
}
