//! PJRT runtime: loads the AOT-compiled HLO artifacts and executes them
//! from the Rust hot path. Python never runs here.
//!
//! The interchange format is HLO *text* (not serialized protos): jax
//! ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see python/compile/aot.py and
//! /opt/xla-example/README.md).
//!
//! - [`tensor`] — host-side f32/i32 tensors and Literal conversion
//! - [`artifacts`] — manifest parser (artifact names, files, signatures)
//! - [`client`] — PJRT CPU client + compiled-executable cache

pub mod artifacts;
pub mod client;
pub mod tensor;

pub use artifacts::{ArtifactSpec, Manifest, TensorSpec};
pub use client::{Executable, RuntimeClient};
pub use tensor::{DType, HostTensor};
