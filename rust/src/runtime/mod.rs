//! Segment runtime: loads the artifact manifest and executes the
//! SplitBrain segments from the Rust hot path.
//!
//! The AOT pipeline (`python/compile/aot.py`) lowers each segment to
//! HLO text for a PJRT backend; the offline build environment provides
//! no XLA runtime, so execution is served by [`native`] — a pure-Rust,
//! bit-deterministic implementation of exactly the same segment
//! functions, validated by the same numeric integration tests. The
//! manifest remains the contract: artifact names, input order and I/O
//! signatures are identical to the lowered set, so swapping a PJRT
//! executor back in is a [`client`]-local change.
//!
//! - [`tensor`] — host-side f32/i32 tensors
//! - [`artifacts`] — manifest parser (artifact names, files, signatures)
//! - [`native`] — the pure-Rust segment executor
//! - [`client`] — executable cache, validation, calibration, profiling

pub mod artifacts;
pub mod client;
pub mod native;
pub mod tensor;

pub use artifacts::{ArtifactSpec, Manifest, TensorSpec};
pub use client::{Executable, RuntimeClient};
pub use native::{compute_threads, set_compute_threads};
pub use tensor::{DType, HostTensor};
