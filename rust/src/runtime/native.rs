//! Native reference backend: executes every SplitBrain segment artifact
//! in pure Rust, bit-reproducibly, with no external runtime.
//!
//! The AOT pipeline (`python/compile/aot.py`) lowers the Layer-2 JAX
//! segments to HLO text for a PJRT backend. This offline build has no
//! XLA runtime, so the [`super::client::RuntimeClient`] falls back to
//! this module: a hand-written forward/backward of the exact same
//! segment functions (`python/compile/model.py`), validated by the same
//! integration tests that used to validate the artifacts (e.g. the
//! decomposition theorem and the zero-logit `ln 10` head check).
//!
//! Determinism is a contract here, not an accident: every reduction
//! loops in a fixed order, so two executions of a segment on the same
//! inputs return bit-identical outputs — the property the engine-parity
//! test (sequential vs threaded cluster) is built on. All functions are
//! pure and callable concurrently from worker threads.
//!
//! ## Deterministic compute tiling (`--compute-threads N`)
//!
//! The hot kernels (the three matmul forms, `conv3x3_relu`,
//! `conv3x3_bwd`) optionally split their work across `N` scoped threads
//! ([`set_compute_threads`]; default 1 = the seed's single-threaded
//! loops). The split is a **fixed row-block partition** — block `b` of
//! `t` covers rows `[rows·b/t, rows·(b+1)/t)` — chosen so that every
//! output element is owned by exactly one thread and its floating-point
//! accumulation sequence is *unchanged* from the single-threaded loop.
//! Outputs are therefore bitwise identical for every thread count (the
//! `tiled_*` unit tests pin this), which keeps the engine-parity and
//! transport-parity contracts intact no matter how ranks are
//! configured. `conv3x3_bwd` splits over *input channels* instead (its
//! outputs `gw`/`gx` are reductions over output positions, but each
//! `(ci, ·)` element's position-order sum is preserved within a block);
//! `gb` is accumulated by the first block only.
//!
//! ## Blocked, vectorizable microkernels
//!
//! Within a row block every hot kernel is cache-blocked around a
//! fixed-width f32 microkernel the autovectorizer reliably lowers to
//! SIMD — [`MM_NR`] = 16 output lanes (one 64-byte cache line) with a
//! variable-width scalar tail for non-multiple-of-lane widths, and
//! [`MM_KB`]-sized reduction panels so the streamed operand stays
//! L1-resident across the row loop. The one invariant every variant
//! preserves is the **per-output-element accumulation order**: each
//! output element still receives exactly the seed's sequence of adds,
//! ascending in the reduction index, with register partial sums stored
//! back and reloaded *between* panels (exact — no reassociation). The
//! dense paths also drop the seed's per-element `if av != 0.0` skip:
//! the skipped terms are `av·b = ±0.0`, and an accumulator that starts
//! at a non-negative-zero value can never *be* `-0.0` (round-to-nearest
//! only yields `-0.0` from `-0.0 + -0.0`), so adding them is bitwise
//! neutral for the finite, non-`-0.0`-bias workload this backend runs.
//! The seed's scalar kernels are retained verbatim in [`oracle`] and
//! the `kernel_parity` suite pins bitwise equality against them across
//! an odd-shape × thread-count sweep.
//!
//! Layer architecture (Table 1 / `python/compile/model.py`):
//! 7× [conv3x3 SAME + bias + relu], max-pool 2×2 after convs 1, 3, 6
//! (32→16→8→4), flatten to 4096, then FC0/FC1 (relu) and the FC2 +
//! log-softmax head.

use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::{bail, Result};

use super::artifacts::Manifest;
use super::tensor::HostTensor;

/// Runtime-global compute-tiling thread count (see the module docs).
static COMPUTE_THREADS: AtomicUsize = AtomicUsize::new(1);

/// Set the deterministic compute-tiling thread count
/// (`--compute-threads`). 1 — the default — keeps the seed's
/// single-threaded kernels; any value produces bitwise-identical
/// outputs (fixed row-block split, per-element accumulation order
/// unchanged). Values are clamped to ≥ 1.
pub fn set_compute_threads(n: usize) {
    COMPUTE_THREADS.store(n.max(1), Ordering::Relaxed);
}

/// The current compute-tiling thread count.
pub fn compute_threads() -> usize {
    COMPUTE_THREADS.load(Ordering::Relaxed).max(1)
}

/// Fixed row-block bounds: block `b` of `t` over `rows` rows is
/// `[rows·b/t, rows·(b+1)/t)` — a pure function of `(rows, t)`, so the
/// work split never depends on scheduling.
fn block_bounds(rows: usize, t: usize) -> Vec<(usize, usize)> {
    (0..t).map(|b| (rows * b / t, rows * (b + 1) / t)).collect()
}

/// Run `f(lo, hi, chunk)` over disjoint row blocks of `out` (row width
/// `w` elements) on up to `t` scoped threads; serial when one block
/// suffices. `chunk` is the output slice for rows `[lo, hi)`.
fn par_row_blocks(
    out: &mut [f32],
    rows: usize,
    w: usize,
    t: usize,
    f: impl Fn(usize, usize, &mut [f32]) + Sync,
) {
    let t = t.min(rows).max(1);
    if t == 1 {
        f(0, rows, out);
        return;
    }
    let bounds = block_bounds(rows, t);
    std::thread::scope(|s| {
        let mut rest = out;
        for &(lo, hi) in &bounds {
            // mem::take detaches the remainder from `rest` so the split
            // halves inherit the full outer lifetime (the chunks must
            // outlive this loop iteration to enter the scoped threads).
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut((hi - lo) * w);
            rest = tail;
            let fref = &f;
            s.spawn(move || fref(lo, hi, chunk));
        }
    });
}

/// Conv stack channel progression (Table 1).
const CONV_CHANNELS: [(usize, usize); 7] =
    [(3, 64), (64, 64), (64, 128), (128, 128), (128, 256), (256, 256), (256, 256)];
/// Max-pool follows these conv indices (32 → 16 → 8 → 4).
const POOL_AFTER: [bool; 7] = [false, true, false, true, false, false, true];
/// Input spatial size of each conv layer.
const SPATIAL: [usize; 7] = [32, 32, 16, 16, 8, 8, 8];
/// Flattened conv-front feature width (4·4·256).
const FEATURE_DIM: usize = 4096;
/// Full widths of the FC stack.
const FC_DIMS: [(usize, usize); 3] = [(4096, 1024), (1024, 1024), (1024, 10)];
/// Number of classes.
const NUM_CLASSES: usize = 10;

/// Batch size the native manifest is "lowered" for. Small enough that
/// full numeric integration tests stay minutes-not-hours on one host.
pub const NATIVE_BATCH: usize = 8;
/// MP group sizes the native manifest supports.
pub const NATIVE_MP_SIZES: [usize; 4] = [1, 2, 4, 8];

// ---------------------------------------------------------------------------
// Manifest.

/// Build the manifest describing the native backend's artifact set —
/// the same inventory `aot.py --batch 8 --mp-sizes 1,2,4,8` would emit.
pub fn native_manifest() -> Result<Manifest> {
    let b = NATIVE_BATCH;
    let mut s = format!(
        "splitbrain-artifacts v1\nbatch {b}\nmp_sizes {}\nfeature_dim {FEATURE_DIM}\nnum_classes {NUM_CLASSES}\n",
        NATIVE_MP_SIZES.map(|k| k.to_string()).join(",")
    );
    let conv_io = |s: &mut String, prefix: &str| {
        for (i, (cin, cout)) in CONV_CHANNELS.iter().enumerate() {
            s.push_str(&format!("{prefix} {}cw{i} float32 3,3,{cin},{cout}\n", if prefix == "out" { "g" } else { "" }));
            s.push_str(&format!("{prefix} {}cb{i} float32 {cout}\n", if prefix == "out" { "g" } else { "" }));
        }
    };
    let fc_io = |s: &mut String, prefix: &str, k: usize| {
        for (i, (din, dout)) in FC_DIMS.iter().enumerate() {
            let dout = if i < 2 { dout / k } else { *dout };
            s.push_str(&format!("{prefix} {}fw{i} float32 {din},{dout}\n", if prefix == "out" { "g" } else { "" }));
            s.push_str(&format!("{prefix} {}fb{i} float32 {dout}\n", if prefix == "out" { "g" } else { "" }));
        }
    };

    // conv_fwd / conv_bwd
    s.push_str("artifact conv_fwd file=<native> sha256=native\n");
    conv_io(&mut s, "in");
    s.push_str(&format!("in x float32 {b},32,32,3\nout act float32 {b},{FEATURE_DIM}\nend\n"));
    s.push_str("artifact conv_bwd file=<native> sha256=native\n");
    conv_io(&mut s, "in");
    s.push_str(&format!("in x float32 {b},32,32,3\nin g_act float32 {b},{FEATURE_DIM}\n"));
    conv_io(&mut s, "out");
    s.push_str("end\n");

    // full_step / full_eval
    for name in ["full_step", "full_eval"] {
        s.push_str(&format!("artifact {name} file=<native> sha256=native\n"));
        conv_io(&mut s, "in");
        fc_io(&mut s, "in", 1);
        s.push_str(&format!("in x float32 {b},32,32,3\nin labels int32 {b}\n"));
        if name == "full_step" {
            s.push_str("out loss float32 scalar\n");
            conv_io(&mut s, "out");
            fc_io(&mut s, "out", 1);
        } else {
            s.push_str("out loss float32 scalar\nout correct int32 scalar\n");
        }
        s.push_str("end\n");
    }

    // head_step / head_fwd (+ BK variants of head_step)
    let head = |s: &mut String, name: &str, rows: usize, step: bool| {
        s.push_str(&format!("artifact {name} file=<native> sha256=native\n"));
        s.push_str(&format!(
            "in fw2 float32 1024,{NUM_CLASSES}\nin fb2 float32 {NUM_CLASSES}\nin h1 float32 {rows},1024\nin labels int32 {rows}\n"
        ));
        if step {
            s.push_str(&format!(
                "out loss float32 scalar\nout gfw2 float32 1024,{NUM_CLASSES}\nout gfb2 float32 {NUM_CLASSES}\nout gh1 float32 {rows},1024\n"
            ));
        } else {
            s.push_str("out loss float32 scalar\nout correct int32 scalar\n");
        }
        s.push_str("end\n");
    };
    head(&mut s, "head_step", b, true);
    head(&mut s, "head_fwd", b, false);

    // head_logits: the serving head — raw logits only, no labels, no
    // loss. Same matmul + bias as `head_core`, so the forward-only
    // serving program is bit-identical to the training-side heads.
    s.push_str("artifact head_logits file=<native> sha256=native\n");
    s.push_str(&format!(
        "in fw2 float32 1024,{NUM_CLASSES}\nin fb2 float32 {NUM_CLASSES}\nin h1 float32 {b},1024\nout logits float32 {b},{NUM_CLASSES}\nend\n"
    ));

    // FC shard segments per group size (and BK variants for k > 1).
    let fc_seg = |s: &mut String, idx: usize, k: usize, rows: usize, suffix: &str| {
        let (din, full) = FC_DIMS[idx];
        let sw = full / k;
        s.push_str(&format!("artifact fc{idx}_fwd_k{k}{suffix} file=<native> sha256=native\n"));
        s.push_str(&format!(
            "in fw{idx} float32 {din},{sw}\nin fb{idx} float32 {sw}\nin x float32 {rows},{din}\nout h float32 {rows},{sw}\nend\n"
        ));
        s.push_str(&format!("artifact fc{idx}_bwd_k{k}{suffix} file=<native> sha256=native\n"));
        s.push_str(&format!(
            "in fw{idx} float32 {din},{sw}\nin fb{idx} float32 {sw}\nin x float32 {rows},{din}\nin gy float32 {rows},{sw}\nout gfw{idx} float32 {din},{sw}\nout gfb{idx} float32 {sw}\nout gx float32 {rows},{din}\nend\n"
        ));
    };
    for &k in &NATIVE_MP_SIZES {
        fc_seg(&mut s, 0, k, b, "");
        fc_seg(&mut s, 1, k, b, "");
        if k > 1 {
            fc_seg(&mut s, 0, k, b * k, "bk");
            fc_seg(&mut s, 1, k, b * k, "bk");
            head(&mut s, &format!("head_step_bk{k}"), b * k, true);
        }
    }

    Manifest::parse(&s, std::path::PathBuf::from("<native>"))
}

// ---------------------------------------------------------------------------
// Dispatch.

/// Execute artifact `name` on shape-checked inputs. Pure and
/// thread-safe; deterministic (fixed reduction order) so repeated calls
/// are bit-identical.
pub fn execute(name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
    match name {
        "conv_fwd" => {
            let act = conv_front_fwd(&inputs[..14], &inputs[14]);
            Ok(vec![act])
        }
        "conv_bwd" => conv_front_bwd(&inputs[..14], &inputs[14], &inputs[15]),
        "full_step" => full_step(&inputs[..14], &inputs[14..20], &inputs[20], &inputs[21]),
        "full_eval" => full_eval(&inputs[..14], &inputs[14..20], &inputs[20], &inputs[21]),
        "head_fwd" => head_fwd(&inputs[0], &inputs[1], &inputs[2], &inputs[3]),
        "head_logits" => head_logits(&inputs[0], &inputs[1], &inputs[2]),
        n if n == "head_step" || n.starts_with("head_step_bk") => {
            head_step(&inputs[0], &inputs[1], &inputs[2], &inputs[3])
        }
        n if n.starts_with("fc0_fwd") || n.starts_with("fc1_fwd") => {
            Ok(vec![fc_fwd(&inputs[0], &inputs[1], &inputs[2])])
        }
        n if n.starts_with("fc0_bwd") || n.starts_with("fc1_bwd") => {
            Ok(fc_bwd(&inputs[0], &inputs[1], &inputs[2], &inputs[3]))
        }
        other => bail!("native backend: unknown artifact {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// FC primitives. Row-major throughout. Each kernel is cache-blocked
// around a fixed-width microkernel (see the module docs); the
// per-output-element accumulation order is the seed's, ascending in
// the reduction index.

/// Output lanes per microkernel: 16 f32 = one 64-byte cache line. The
/// unrolled fixed-width accumulator block is what the autovectorizer
/// lowers to SIMD; widths that are not a multiple of this get a
/// variable-width scalar tail with the identical accumulation order.
pub const MM_NR: usize = 16;
/// Reduction-panel depth: [`MM_NR`]·[`MM_KB`] f32 of the streamed
/// operand (≈ 16 KiB) stay L1-resident across the row loop. Register
/// partial sums are stored back to the output and reloaded between
/// panels — exact, so blocking never reassociates the sum.
pub const MM_KB: usize = 256;
/// Independent accumulator chains in the dot-product kernel
/// ([`matmul_nt_t`]): 8 concurrent output columns hide FMA latency
/// where lane-splitting the dot itself would reorder the reduction.
pub const MM_IB: usize = 8;
/// Dot-product j-panel width: [`MM_IB`]·[`MM_JB`] f32 of `w` (≈ 16 KiB)
/// stay L1-resident across the row loop of [`matmul_nt_t`].
pub const MM_JB: usize = 512;

/// `out[m,n] = a[m,k] @ b[k,n]`.
fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    matmul_t(a, b, m, k, n, compute_threads())
}

/// [`matmul`] with an explicit tile count. Each output row is owned by
/// exactly one thread, and within a row every element accumulates over
/// `l` ascending (k-panels store/reload exact partials), so the result
/// is bitwise identical to the seed loop for every `t`.
pub fn matmul_t(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, t: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    par_row_blocks(&mut out, m, n, t, |lo, hi, chunk| {
        let mut jb = 0;
        while jb < n {
            let jw = MM_NR.min(n - jb);
            let mut lb = 0;
            while lb < k {
                let lhi = (lb + MM_KB).min(k);
                for i in lo..hi {
                    let arow = &a[i * k..(i + 1) * k];
                    let obase = (i - lo) * n + jb;
                    let orow = &mut chunk[obase..obase + jw];
                    let mut acc = [0.0f32; MM_NR];
                    acc[..jw].copy_from_slice(orow);
                    if jw == MM_NR {
                        for l in lb..lhi {
                            let av = arow[l];
                            let brow = &b[l * n + jb..][..MM_NR];
                            for u in 0..MM_NR {
                                acc[u] += av * brow[u];
                            }
                        }
                    } else {
                        for l in lb..lhi {
                            let av = arow[l];
                            let brow = &b[l * n + jb..][..jw];
                            for u in 0..jw {
                                acc[u] += av * brow[u];
                            }
                        }
                    }
                    orow.copy_from_slice(&acc[..jw]);
                }
                lb = lhi;
            }
            jb += jw;
        }
    });
    out
}

/// `out[m,n] = a[r,m]ᵀ @ g[r,n]` (weight gradients).
fn matmul_tn(a: &[f32], g: &[f32], r: usize, m: usize, n: usize) -> Vec<f32> {
    matmul_tn_t(a, g, r, m, n, compute_threads())
}

/// [`matmul_tn`] with an explicit tile count. The seed iterated
/// ri-outer over the whole output; here each output element still
/// accumulates over `ri` ascending (r-panels store/reload exact
/// partials), so the result is bitwise identical to the seed at every
/// `t` (pinned by `tiled_matmul_tn_matches_seed_order`).
pub fn matmul_tn_t(a: &[f32], g: &[f32], r: usize, m: usize, n: usize, t: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    par_row_blocks(&mut out, m, n, t, |lo, hi, chunk| {
        let mut jb = 0;
        while jb < n {
            let jw = MM_NR.min(n - jb);
            let mut rb = 0;
            while rb < r {
                let rhi = (rb + MM_KB).min(r);
                for i in lo..hi {
                    let obase = (i - lo) * n + jb;
                    let orow = &mut chunk[obase..obase + jw];
                    let mut acc = [0.0f32; MM_NR];
                    acc[..jw].copy_from_slice(orow);
                    if jw == MM_NR {
                        for ri in rb..rhi {
                            let av = a[ri * m + i];
                            let grow = &g[ri * n + jb..][..MM_NR];
                            for u in 0..MM_NR {
                                acc[u] += av * grow[u];
                            }
                        }
                    } else {
                        for ri in rb..rhi {
                            let av = a[ri * m + i];
                            let grow = &g[ri * n + jb..][..jw];
                            for u in 0..jw {
                                acc[u] += av * grow[u];
                            }
                        }
                    }
                    orow.copy_from_slice(&acc[..jw]);
                }
                rb = rhi;
            }
            jb += jw;
        }
    });
    out
}

/// `out[r,m] = g[r,n] @ w[m,n]ᵀ` (input gradients).
fn matmul_nt(g: &[f32], w: &[f32], r: usize, n: usize, m: usize) -> Vec<f32> {
    matmul_nt_t(g, w, r, n, m, compute_threads())
}

/// [`matmul_nt`] with an explicit tile count. Each output element is a
/// single dot product over `j` ascending — a chain that cannot be
/// lane-split without reordering the reduction — so the microkernel
/// instead runs [`MM_IB`] *independent* chains (adjacent output
/// columns) concurrently, with j-panels storing/reloading exact
/// partials. Bitwise identical to the seed for every `t`.
pub fn matmul_nt_t(g: &[f32], w: &[f32], r: usize, n: usize, m: usize, t: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; r * m];
    par_row_blocks(&mut out, r, m, t, |lo, hi, chunk| {
        let mut ib = 0;
        while ib < m {
            let iw = MM_IB.min(m - ib);
            let mut jb = 0;
            while jb < n {
                let jhi = (jb + MM_JB).min(n);
                for ri in lo..hi {
                    let grow = &g[ri * n..(ri + 1) * n];
                    let obase = (ri - lo) * m + ib;
                    let orow = &mut chunk[obase..obase + iw];
                    let mut acc = [0.0f32; MM_IB];
                    acc[..iw].copy_from_slice(orow);
                    if iw == MM_IB {
                        for j in jb..jhi {
                            let gv = grow[j];
                            for u in 0..MM_IB {
                                acc[u] += gv * w[(ib + u) * n + j];
                            }
                        }
                    } else {
                        for j in jb..jhi {
                            let gv = grow[j];
                            for u in 0..iw {
                                acc[u] += gv * w[(ib + u) * n + j];
                            }
                        }
                    }
                    orow.copy_from_slice(&acc[..iw]);
                }
                jb = jhi;
            }
            ib += iw;
        }
    });
    out
}

/// `pre[r, j] += bias[j]`, row-threaded (rows are independent and each
/// element gets exactly one add — bitwise identical for every `t`).
pub fn add_bias_t(pre: &mut [f32], bias: &[f32], rows: usize, cols: usize, t: usize) {
    par_row_blocks(pre, rows, cols, t, |_lo, _hi, chunk| {
        for row in chunk.chunks_exact_mut(cols) {
            for j in 0..cols {
                row[j] += bias[j];
            }
        }
    });
}

/// Fused `relu(pre + bias)` epilogue, row-threaded. Elementwise
/// identical to [`add_bias_t`] followed by the seed's
/// `if *v < 0.0 { *v = 0.0 }` relu sweep.
pub fn add_bias_relu_t(pre: &mut [f32], bias: &[f32], rows: usize, cols: usize, t: usize) {
    par_row_blocks(pre, rows, cols, t, |_lo, _hi, chunk| {
        for row in chunk.chunks_exact_mut(cols) {
            for j in 0..cols {
                let v = row[j] + bias[j];
                row[j] = if v < 0.0 { 0.0 } else { v };
            }
        }
    });
}

fn add_bias(pre: &mut [f32], bias: &[f32], rows: usize, cols: usize) {
    add_bias_t(pre, bias, rows, cols, compute_threads());
}

/// `relu(x @ w + b)` — the `fc_fwd` segment (`model.py::fc_fwd`).
fn fc_fwd(w: &HostTensor, bias: &HostTensor, x: &HostTensor) -> HostTensor {
    let (din, dout) = (w.shape[0], w.shape[1]);
    let rows = x.shape[0];
    let mut pre = matmul(x.as_f32(), w.as_f32(), rows, din, dout);
    add_bias_relu_t(&mut pre, bias.as_f32(), rows, dout, compute_threads());
    HostTensor::f32(vec![rows, dout], pre)
}

/// Manual VJP of `fc_fwd` (`model.py::fc_bwd`): returns
/// `(gw, gb, gx_partial)`; `gx_partial` is this shard's partial
/// gradient over the full-width input.
fn fc_bwd(w: &HostTensor, bias: &HostTensor, x: &HostTensor, gy: &HostTensor) -> Vec<HostTensor> {
    let (din, dout) = (w.shape[0], w.shape[1]);
    let rows = x.shape[0];
    let mut pre = matmul(x.as_f32(), w.as_f32(), rows, din, dout);
    add_bias(&mut pre, bias.as_f32(), rows, dout);
    // gpre = gy · 1[pre > 0]
    let gyv = gy.as_f32();
    let mut gpre = vec![0.0f32; rows * dout];
    for i in 0..rows * dout {
        if pre[i] > 0.0 {
            gpre[i] = gyv[i];
        }
    }
    let gw = matmul_tn(x.as_f32(), &gpre, rows, din, dout);
    let mut gb = vec![0.0f32; dout];
    for ri in 0..rows {
        for j in 0..dout {
            gb[j] += gpre[ri * dout + j];
        }
    }
    let gx = matmul_nt(&gpre, w.as_f32(), rows, dout, din);
    vec![
        HostTensor::f32(vec![din, dout], gw),
        HostTensor::f32(vec![dout], gb),
        HostTensor::f32(vec![rows, din], gx),
    ]
}

// ---------------------------------------------------------------------------
// Softmax head.

/// Shared head math: logits, per-row log-softmax, mean NLL, and the
/// softmax−onehot logit gradient (already divided by the row count).
fn head_core(
    w2: &HostTensor,
    b2: &HostTensor,
    h1: &HostTensor,
    labels: &HostTensor,
) -> (f32, Vec<f32>, Vec<f32>) {
    let rows = h1.shape[0];
    let nc = w2.shape[1];
    let mut logits = matmul(h1.as_f32(), w2.as_f32(), rows, w2.shape[0], nc);
    add_bias(&mut logits, b2.as_f32(), rows, nc);
    let labs = labels.as_i32();
    let mut loss = 0.0f64;
    let mut glogits = vec![0.0f32; rows * nc];
    for ri in 0..rows {
        let row = &logits[ri * nc..(ri + 1) * nc];
        let mut mx = f32::NEG_INFINITY;
        for &v in row {
            if v > mx {
                mx = v;
            }
        }
        let mut sum = 0.0f32;
        for &v in row {
            sum += (v - mx).exp();
        }
        let lse = mx + sum.ln();
        let lab = labs[ri] as usize;
        loss -= (row[lab] - lse) as f64;
        let grow = &mut glogits[ri * nc..(ri + 1) * nc];
        for j in 0..nc {
            let p = (row[j] - lse).exp();
            grow[j] = (p - if j == lab { 1.0 } else { 0.0 }) / rows as f32;
        }
    }
    ((loss / rows as f64) as f32, logits, glogits)
}

/// The fused replicated head (`model.py::head_step`): returns
/// `(loss, gw2, gb2, gh1_full)`.
fn head_step(
    w2: &HostTensor,
    b2: &HostTensor,
    h1: &HostTensor,
    labels: &HostTensor,
) -> Result<Vec<HostTensor>> {
    let rows = h1.shape[0];
    let (din, nc) = (w2.shape[0], w2.shape[1]);
    let (loss, _logits, glogits) = head_core(w2, b2, h1, labels);
    let gw2 = matmul_tn(h1.as_f32(), &glogits, rows, din, nc);
    let mut gb2 = vec![0.0f32; nc];
    for ri in 0..rows {
        for j in 0..nc {
            gb2[j] += glogits[ri * nc + j];
        }
    }
    let gh1 = matmul_nt(&glogits, w2.as_f32(), rows, nc, din);
    Ok(vec![
        HostTensor::f32(vec![], vec![loss]),
        HostTensor::f32(vec![din, nc], gw2),
        HostTensor::f32(vec![nc], gb2),
        HostTensor::f32(vec![rows, din], gh1),
    ])
}

/// Validation head (`model.py::head_fwd`): `(loss, #correct)`.
fn head_fwd(
    w2: &HostTensor,
    b2: &HostTensor,
    h1: &HostTensor,
    labels: &HostTensor,
) -> Result<Vec<HostTensor>> {
    let rows = h1.shape[0];
    let nc = w2.shape[1];
    let (loss, logits, _) = head_core(w2, b2, h1, labels);
    let correct = count_correct(&logits, labels.as_i32(), rows, nc);
    Ok(vec![
        HostTensor::f32(vec![], vec![loss]),
        HostTensor::i32(vec![], vec![correct]),
    ])
}

/// Serving head: raw logits (`h1 @ w2 + b2`), no labels, no loss. The
/// logit computation is [`head_core`]'s first two lines verbatim, so
/// the forward-only serving program's replies are bit-identical to the
/// logits every training-side head computes internally.
fn head_logits(w2: &HostTensor, b2: &HostTensor, h1: &HostTensor) -> Result<Vec<HostTensor>> {
    let rows = h1.shape[0];
    let nc = w2.shape[1];
    let mut logits = matmul(h1.as_f32(), w2.as_f32(), rows, w2.shape[0], nc);
    add_bias(&mut logits, b2.as_f32(), rows, nc);
    Ok(vec![HostTensor::f32(vec![rows, nc], logits)])
}

/// `argmax(logits, axis=-1) == label` count; first maximum wins on
/// ties, matching `jnp.argmax`.
fn count_correct(logits: &[f32], labs: &[i32], rows: usize, nc: usize) -> i32 {
    let mut correct = 0i32;
    for ri in 0..rows {
        let row = &logits[ri * nc..(ri + 1) * nc];
        let mut best = 0usize;
        for j in 1..nc {
            if row[j] > row[best] {
                best = j;
            }
        }
        if best as i32 == labs[ri] {
            correct += 1;
        }
    }
    correct
}

// ---------------------------------------------------------------------------
// Conv front.

/// conv3x3 SAME + bias + relu, NHWC, HWIO weights.
fn conv3x3_relu(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    b: usize,
    hw: usize,
    cin: usize,
    cout: usize,
) -> Vec<f32> {
    conv3x3_relu_t(x, w, bias, b, hw, cin, cout, compute_threads())
}

/// [`conv3x3_relu`] with an explicit tile count: output rows
/// `(bi, oy)` are independent, so any fixed row-block split is bitwise
/// identical to the single-threaded loop.
///
/// Per output element the accumulation order is the seed's — bias
/// first, then `(ky, kx, ci)` ascending with the SAME-padding skips —
/// restricted to a [`MM_NR`]-wide `cout` lane block held in registers
/// across the whole receptive field (the dense `ci` loop drops the
/// seed's `if av != 0.0` skip; see the module docs for why that is
/// bitwise neutral). The relu epilogue applies the seed's
/// `if v < 0.0 { 0.0 }` to the register block before the single store.
#[allow(clippy::too_many_arguments)]
pub fn conv3x3_relu_t(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    b: usize,
    hw: usize,
    cin: usize,
    cout: usize,
    t: usize,
) -> Vec<f32> {
    let rows = b * hw; // one row = one (bi, oy) scanline of the output
    let mut out = vec![0.0f32; b * hw * hw * cout];
    par_row_blocks(&mut out, rows, hw * cout, t, |lo, hi, chunk| {
        for row in lo..hi {
            let (bi, oy) = (row / hw, row % hw);
            let mut cb = 0;
            while cb < cout {
                let cw = MM_NR.min(cout - cb);
                for ox in 0..hw {
                    let mut acc = [0.0f32; MM_NR];
                    acc[..cw].copy_from_slice(&bias[cb..cb + cw]);
                    for ky in 0..3usize {
                        let iy = oy + ky;
                        if iy == 0 || iy > hw {
                            continue;
                        }
                        let iy = iy - 1;
                        for kx in 0..3usize {
                            let ix = ox + kx;
                            if ix == 0 || ix > hw {
                                continue;
                            }
                            let ix = ix - 1;
                            let xrow = &x[((bi * hw + iy) * hw + ix) * cin..][..cin];
                            let wbase = (ky * 3 + kx) * cin * cout + cb;
                            if cw == MM_NR {
                                for (ci, &av) in xrow.iter().enumerate() {
                                    let wrow = &w[wbase + ci * cout..][..MM_NR];
                                    for u in 0..MM_NR {
                                        acc[u] += av * wrow[u];
                                    }
                                }
                            } else {
                                for (ci, &av) in xrow.iter().enumerate() {
                                    let wrow = &w[wbase + ci * cout..][..cw];
                                    for u in 0..cw {
                                        acc[u] += av * wrow[u];
                                    }
                                }
                            }
                        }
                    }
                    let obase = ((row - lo) * hw + ox) * cout + cb;
                    let orow = &mut chunk[obase..obase + cw];
                    for u in 0..cw {
                        orow[u] = if acc[u] < 0.0 { 0.0 } else { acc[u] };
                    }
                }
                cb += cw;
            }
        }
    });
    out
}

/// Max-pool 2×2 stride 2; returns pooled values plus the flat input
/// index of each window's (first) maximum for the backward pass.
fn maxpool2(x: &[f32], b: usize, hw: usize, c: usize) -> (Vec<f32>, Vec<u32>) {
    maxpool2_t(x, b, hw, c, compute_threads())
}

/// One block of output scanlines `[lo, hi)` of the 2×2 max-pool. The
/// window scan order (`dy`, `dx` ascending, strict `>` so the first
/// maximum wins, matching `jnp.argmax`) is the seed's; `arg` indices
/// stay absolute into `x`.
fn maxpool2_rows(
    x: &[f32],
    hw: usize,
    c: usize,
    lo: usize,
    hi: usize,
    out: &mut [f32],
    arg: &mut [u32],
) {
    let ohw = hw / 2;
    for row in lo..hi {
        let (bi, oy) = (row / ohw, row % ohw);
        for ox in 0..ohw {
            let obase = ((row - lo) * ohw + ox) * c;
            for ci in 0..c {
                let mut best = f32::NEG_INFINITY;
                let mut besti = 0u32;
                for dy in 0..2usize {
                    for dx in 0..2usize {
                        let idx = ((bi * hw + 2 * oy + dy) * hw + 2 * ox + dx) * c + ci;
                        if x[idx] > best {
                            best = x[idx];
                            besti = idx as u32;
                        }
                    }
                }
                out[obase + ci] = best;
                arg[obase + ci] = besti;
            }
        }
    }
}

/// [`maxpool2`] with an explicit tile count: output scanlines
/// `(bi, oy)` are independent, so any fixed row-block split of the
/// `out`/`arg` pair is bitwise identical to the single-threaded loop.
pub fn maxpool2_t(x: &[f32], b: usize, hw: usize, c: usize, t: usize) -> (Vec<f32>, Vec<u32>) {
    let ohw = hw / 2;
    let rows = b * ohw; // one row = one (bi, oy) scanline of the output
    let w = ohw * c;
    let mut out = vec![0.0f32; rows * w];
    let mut arg = vec![0u32; rows * w];
    let t = t.min(rows).max(1);
    if t == 1 {
        maxpool2_rows(x, hw, c, 0, rows, &mut out, &mut arg);
    } else {
        let bounds = block_bounds(rows, t);
        std::thread::scope(|s| {
            let mut orest = &mut out[..];
            let mut arest = &mut arg[..];
            for &(lo, hi) in &bounds {
                let (ochunk, otail) = std::mem::take(&mut orest).split_at_mut((hi - lo) * w);
                let (achunk, atail) = std::mem::take(&mut arest).split_at_mut((hi - lo) * w);
                orest = otail;
                arest = atail;
                s.spawn(move || maxpool2_rows(x, hw, c, lo, hi, ochunk, achunk));
            }
        });
    }
    (out, arg)
}

/// Route pooled gradients back to their argmax positions. `hw` is the
/// *input* spatial size (the pooled output is `hw/2 × hw/2`).
fn maxpool2_bwd(g: &[f32], arg: &[u32], b: usize, hw: usize, c: usize) -> Vec<f32> {
    maxpool2_bwd_t(g, arg, b, hw, c, compute_threads())
}

/// [`maxpool2_bwd`] with an explicit tile count. Output scanline `r`
/// of the pool owns exactly the two input scanlines `2r, 2r+1` — a
/// contiguous `2·hw·c` slice of `gx` — and pool windows are disjoint,
/// so every `gx` element receives at most one add: any fixed row-block
/// split over those slices is bitwise identical to the seed's scatter.
pub fn maxpool2_bwd_t(
    g: &[f32],
    arg: &[u32],
    b: usize,
    hw: usize,
    c: usize,
    t: usize,
) -> Vec<f32> {
    let ohw = hw / 2;
    let rows = b * ohw; // one row = one (bi, oy) scanline of the *output*
    let w = 2 * hw * c; // gx elements owned by that scanline
    let mut gx = vec![0.0f32; b * hw * hw * c];
    par_row_blocks(&mut gx, rows, w, t, |lo, hi, chunk| {
        let base = lo * w;
        for i in lo * ohw * c..hi * ohw * c {
            chunk[arg[i] as usize - base] += g[i];
        }
    });
    gx
}

/// Backward of one conv3x3+relu layer. `y` is the post-relu output
/// (its positivity is the relu mask), `gy` the gradient w.r.t. `y`.
fn conv3x3_bwd(
    x: &[f32],
    y: &[f32],
    gy: &[f32],
    w: &[f32],
    b: usize,
    hw: usize,
    cin: usize,
    cout: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    conv3x3_bwd_t(x, y, gy, w, b, hw, cin, cout, compute_threads())
}

/// [`conv3x3_bwd`] with an explicit tile count. `gw` and `gx` reduce
/// over output positions, so the split is over **input channels**: each
/// `(ci, ·)` output element is owned by exactly one thread and keeps
/// the seed's position-order accumulation, so the result is bitwise
/// identical at every `t`. The tiny `gb` is accumulated by the first
/// block only. The stitch step is pure copies (exclusive ownership —
/// no floating-point reorder).
#[allow(clippy::too_many_arguments)]
pub fn conv3x3_bwd_t(
    x: &[f32],
    y: &[f32],
    gy: &[f32],
    w: &[f32],
    b: usize,
    hw: usize,
    cin: usize,
    cout: usize,
    t: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let t = t.min(cin).max(1);
    if t == 1 {
        return conv3x3_bwd_ci(x, y, gy, w, b, hw, cin, cout, 0, cin);
    }
    let bounds = block_bounds(cin, t);
    let parts: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = std::thread::scope(|s| {
        let handles: Vec<_> = bounds
            .iter()
            .map(|&(clo, chi)| {
                s.spawn(move || conv3x3_bwd_ci(x, y, gy, w, b, hw, cin, cout, clo, chi))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("conv bwd tile thread panicked"))
            .collect()
    });
    let mut gw = vec![0.0f32; 9 * cin * cout];
    let mut gb = vec![0.0f32; cout];
    let mut gx = vec![0.0f32; b * hw * hw * cin];
    for (&(clo, chi), (gw_p, gb_p, gx_p)) in bounds.iter().zip(parts) {
        let wci = chi - clo;
        for kk in 0..9 {
            gw[kk * cin * cout + clo * cout..kk * cin * cout + chi * cout]
                .copy_from_slice(&gw_p[kk * wci * cout..(kk + 1) * wci * cout]);
        }
        for pos in 0..b * hw * hw {
            gx[pos * cin + clo..pos * cin + chi]
                .copy_from_slice(&gx_p[pos * wci..(pos + 1) * wci]);
        }
        if clo == 0 {
            gb.copy_from_slice(&gb_p);
        }
    }
    (gw, gb, gx)
}

/// One input-channel block `[clo, chi)` of the conv backward. Private
/// block-local layouts: `gw_p[9][chi-clo][cout]`, `gx_p[pos][chi-clo]`.
/// With `(clo, chi) = (0, cin)` the layouts coincide with the global
/// ones and the loop is, element for element, the seed's backward.
#[allow(clippy::too_many_arguments)]
fn conv3x3_bwd_ci(
    x: &[f32],
    y: &[f32],
    gy: &[f32],
    w: &[f32],
    b: usize,
    hw: usize,
    cin: usize,
    cout: usize,
    clo: usize,
    chi: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let wci = chi - clo;
    let mut gw = vec![0.0f32; 9 * wci * cout];
    let mut gb = vec![0.0f32; cout];
    let mut gx = vec![0.0f32; b * hw * hw * wci];
    let mut gprevec = vec![0.0f32; cout];
    for bi in 0..b {
        for oy in 0..hw {
            for ox in 0..hw {
                let obase = ((bi * hw + oy) * hw + ox) * cout;
                let mut any = false;
                for co in 0..cout {
                    let g = if y[obase + co] > 0.0 { gy[obase + co] } else { 0.0 };
                    gprevec[co] = g;
                    any |= g != 0.0;
                }
                if !any {
                    continue;
                }
                if clo == 0 {
                    for co in 0..cout {
                        gb[co] += gprevec[co];
                    }
                }
                for ky in 0..3usize {
                    let iy = oy + ky;
                    if iy == 0 || iy > hw {
                        continue;
                    }
                    let iy = iy - 1;
                    for kx in 0..3usize {
                        let ix = ox + kx;
                        if ix == 0 || ix > hw {
                            continue;
                        }
                        let ix = ix - 1;
                        let pos = (bi * hw + iy) * hw + ix;
                        let xbase = pos * cin;
                        let wbase = (ky * 3 + kx) * cin * cout;
                        let gwbase = (ky * 3 + kx) * wci * cout;
                        let gxrow = &mut gx[pos * wci..(pos + 1) * wci];
                        for ci in clo..chi {
                            let av = x[xbase + ci];
                            let wrow = &w[wbase + ci * cout..][..cout];
                            let gwrow = &mut gw[gwbase + (ci - clo) * cout..][..cout];
                            // The seed fused these two loops; fission
                            // keeps every element's `co`-order sum
                            // intact while letting the saxpy update
                            // vectorize (the dot stays a scalar chain —
                            // splitting it would reorder the sum).
                            for co in 0..cout {
                                gwrow[co] += av * gprevec[co];
                            }
                            let mut acc = 0.0f32;
                            for co in 0..cout {
                                acc += wrow[co] * gprevec[co];
                            }
                            gxrow[ci - clo] += acc;
                        }
                    }
                }
            }
        }
    }
    (gw, gb, gx)
}

/// The `conv_fwd` segment: conv front activations, flattened `[B, 4096]`.
fn conv_front_fwd(params: &[HostTensor], x: &HostTensor) -> HostTensor {
    let b = x.shape[0];
    let mut cur = x.as_f32().to_vec();
    for (i, &(cin, cout)) in CONV_CHANNELS.iter().enumerate() {
        let hw = SPATIAL[i];
        let out = conv3x3_relu(&cur, params[2 * i].as_f32(), params[2 * i + 1].as_f32(), b, hw, cin, cout);
        cur = if POOL_AFTER[i] { maxpool2(&out, b, hw, cout).0 } else { out };
    }
    // NHWC [B,4,4,256] is row-major contiguous == the flattened view.
    HostTensor::f32(vec![b, FEATURE_DIM], cur)
}

/// Per-layer residuals of a conv-front forward pass, kept for backward.
/// Each activation buffer is stored exactly once: layer i's input is
/// the network input (i = 0), the previous layer's pooled buffer, or —
/// when no pool intervenes — the previous layer's post-relu output.
struct ConvTrace {
    /// Network input, NHWC flat.
    x: Vec<f32>,
    /// Post-relu output of each conv layer (pre-pool).
    outputs: Vec<Vec<f32>>,
    /// Post-pool buffer where a pool follows the layer (the last one is
    /// taken as `act`, so entry 6 is `None`).
    pooled: Vec<Option<Vec<f32>>>,
    /// Pool argmax indices where a pool follows the layer.
    args: Vec<Option<Vec<u32>>>,
    /// Final flattened activations, `B * FEATURE_DIM`.
    act: Vec<f32>,
}

impl ConvTrace {
    /// Layer i's input buffer.
    fn input_of(&self, i: usize) -> &[f32] {
        if i == 0 {
            &self.x
        } else {
            match &self.pooled[i - 1] {
                Some(p) => p,
                None => &self.outputs[i - 1],
            }
        }
    }
}

/// Forward pass keeping residuals — bit-identical activations to
/// [`conv_front_fwd`] (same primitives in the same order).
fn conv_front_traced(params: &[HostTensor], x: &HostTensor) -> ConvTrace {
    let b = x.shape[0];
    let xv = x.as_f32().to_vec();
    let mut outputs: Vec<Vec<f32>> = Vec::with_capacity(7);
    let mut pooled: Vec<Option<Vec<f32>>> = Vec::with_capacity(7);
    let mut args: Vec<Option<Vec<u32>>> = Vec::with_capacity(7);
    for (i, &(cin, cout)) in CONV_CHANNELS.iter().enumerate() {
        let hw = SPATIAL[i];
        let input: &[f32] = if i == 0 {
            &xv
        } else {
            match &pooled[i - 1] {
                Some(p) => p,
                None => &outputs[i - 1],
            }
        };
        let out = conv3x3_relu(input, params[2 * i].as_f32(), params[2 * i + 1].as_f32(), b, hw, cin, cout);
        if POOL_AFTER[i] {
            let (p, a) = maxpool2(&out, b, hw, cout);
            pooled.push(Some(p));
            args.push(Some(a));
        } else {
            pooled.push(None);
            args.push(None);
        }
        outputs.push(out);
    }
    let act = pooled[6].take().expect("the last conv layer pools");
    ConvTrace { x: xv, outputs, pooled, args, act }
}

/// Backward walk over a traced forward; returns the 14 conv gradients.
fn conv_backward(params: &[HostTensor], trace: &ConvTrace, g_act: &[f32], b: usize) -> Vec<HostTensor> {
    let mut grads: Vec<Option<(Vec<f32>, Vec<f32>)>> = vec![None; 7];
    let mut g = g_act.to_vec();
    for i in (0..7).rev() {
        let (cin, cout) = CONV_CHANNELS[i];
        let hw = SPATIAL[i];
        if let Some(arg) = &trace.args[i] {
            g = maxpool2_bwd(&g, arg, b, hw, cout);
        }
        let (gw, gb, gx) = conv3x3_bwd(
            trace.input_of(i),
            &trace.outputs[i],
            &g,
            params[2 * i].as_f32(),
            b,
            hw,
            cin,
            cout,
        );
        grads[i] = Some((gw, gb));
        g = gx;
    }
    let mut out = Vec::with_capacity(14);
    for (i, &(cin, cout)) in CONV_CHANNELS.iter().enumerate() {
        let (gw, gb) = grads[i].take().expect("all layers visited");
        out.push(HostTensor::f32(vec![3, 3, cin, cout], gw));
        out.push(HostTensor::f32(vec![cout], gb));
    }
    out
}

/// The `conv_bwd` segment: rematerializes the forward (as the AOT
/// artifact does via `jax.vjp`), then walks the stack backwards.
fn conv_front_bwd(
    params: &[HostTensor],
    x: &HostTensor,
    g_act: &HostTensor,
) -> Result<Vec<HostTensor>> {
    let trace = conv_front_traced(params, x);
    Ok(conv_backward(params, &trace, g_act.as_f32(), x.shape[0]))
}

// ---------------------------------------------------------------------------
// Fused pure-DP step and evaluation.

/// Forward through the FC stack; returns `(act, h0, h1)`.
fn fc_stack_fwd(
    conv: &[HostTensor],
    fc: &[HostTensor],
    x: &HostTensor,
) -> (HostTensor, HostTensor, HostTensor) {
    let act = conv_front_fwd(conv, x);
    let h0 = fc_fwd(&fc[0], &fc[1], &act);
    let h1 = fc_fwd(&fc[2], &fc[3], &h0);
    (act, h0, h1)
}

/// The `full_step` segment (`model.py::full_step`): fused loss + all
/// gradients of the monolithic local model. The conv forward runs once
/// (traced) and its residuals feed the backward directly.
fn full_step(
    conv: &[HostTensor],
    fc: &[HostTensor],
    x: &HostTensor,
    labels: &HostTensor,
) -> Result<Vec<HostTensor>> {
    let rows = x.shape[0];
    let trace = conv_front_traced(conv, x);
    let act = HostTensor::f32(vec![rows, FEATURE_DIM], trace.act.clone());
    let h0 = fc_fwd(&fc[0], &fc[1], &act);
    let h1 = fc_fwd(&fc[2], &fc[3], &h0);
    // Head loss + grads: exactly the head_step segment, so the fused
    // path can never drift from the decomposed one.
    let mut head = head_step(&fc[4], &fc[5], &h1, labels)?;
    let gh1_t = head.pop().expect("gh1");
    let gb2_t = head.pop().expect("gb2");
    let gw2_t = head.pop().expect("gw2");
    let loss_t = head.pop().expect("loss");

    // FC1 (mask on the post-relu h1).
    let relu_mask = |h: &HostTensor, g: &[f32]| -> Vec<f32> {
        let hv = h.as_f32();
        g.iter().enumerate().map(|(i, &v)| if hv[i] > 0.0 { v } else { 0.0 }).collect()
    };
    let gpre1 = relu_mask(&h1, gh1_t.as_f32());
    let gw1 = matmul_tn(h0.as_f32(), &gpre1, rows, 1024, 1024);
    let mut gb1 = vec![0.0f32; 1024];
    for ri in 0..rows {
        for j in 0..1024 {
            gb1[j] += gpre1[ri * 1024 + j];
        }
    }
    let gh0 = matmul_nt(&gpre1, fc[2].as_f32(), rows, 1024, 1024);

    // FC0.
    let gpre0 = relu_mask(&h0, &gh0);
    let gw0 = matmul_tn(act.as_f32(), &gpre0, rows, FEATURE_DIM, 1024);
    let mut gb0 = vec![0.0f32; 1024];
    for ri in 0..rows {
        for j in 0..1024 {
            gb0[j] += gpre0[ri * 1024 + j];
        }
    }
    let g_act = matmul_nt(&gpre0, fc[0].as_f32(), rows, 1024, FEATURE_DIM);

    let conv_grads = conv_backward(conv, &trace, &g_act, rows);
    let mut out = Vec::with_capacity(21);
    out.push(loss_t);
    out.extend(conv_grads);
    out.push(HostTensor::f32(vec![FEATURE_DIM, 1024], gw0));
    out.push(HostTensor::f32(vec![1024], gb0));
    out.push(HostTensor::f32(vec![1024, 1024], gw1));
    out.push(HostTensor::f32(vec![1024], gb1));
    out.push(gw2_t);
    out.push(gb2_t);
    Ok(out)
}

/// The `full_eval` segment: `(loss, #correct)` on the full local model.
fn full_eval(
    conv: &[HostTensor],
    fc: &[HostTensor],
    x: &HostTensor,
    labels: &HostTensor,
) -> Result<Vec<HostTensor>> {
    let rows = x.shape[0];
    let (_act, _h0, h1) = fc_stack_fwd(conv, fc, x);
    let nc = fc[4].shape[1];
    let (loss, logits, _) = head_core(&fc[4], &fc[5], &h1, labels);
    let correct = count_correct(&logits, labels.as_i32(), rows, nc);
    Ok(vec![
        HostTensor::f32(vec![], vec![loss]),
        HostTensor::i32(vec![], vec![correct]),
    ])
}

// ---------------------------------------------------------------------------
// Seed oracles.

/// The seed's scalar correctness-first kernels, retained **verbatim**
/// as bitwise oracles for the blocked/vectorized kernels above.
///
/// The `kernel_parity` integration suite (and the unit tests below)
/// pin every production kernel bitwise against these across an
/// odd-shape × thread-count sweep — which is why they live in a normal
/// `pub` module rather than under `#[cfg(test)]`: integration tests
/// compile the library without the `test` cfg. They are not called on
/// any hot path.
///
/// Parity caveat (the one deliberate difference): the production dense
/// paths add the `av == 0.0` terms these oracles skip. Those terms are
/// `±0.0` and bitwise-neutral **provided** inputs are finite and the
/// conv bias contains no `-0.0` (an accumulator seeded at a
/// non-negative-zero value can never become `-0.0`); both hold for
/// everything this backend executes, and the parity suite exercises
/// zero-laden inputs to prove the skip removal under exactly that
/// contract.
pub mod oracle {
    use super::{block_bounds, par_row_blocks};

    /// Seed `out[m,n] = a[m,k] @ b[k,n]` (branchy zero-skip loop).
    pub fn matmul_t(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, t: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        par_row_blocks(&mut out, m, n, t, |lo, hi, chunk| {
            for i in lo..hi {
                let orow = &mut chunk[(i - lo) * n..(i - lo + 1) * n];
                for l in 0..k {
                    let av = a[i * k + l];
                    if av != 0.0 {
                        let brow = &b[l * n..(l + 1) * n];
                        for j in 0..n {
                            orow[j] += av * brow[j];
                        }
                    }
                }
            }
        });
        out
    }

    /// Seed `out[m,n] = a[r,m]ᵀ @ g[r,n]`.
    pub fn matmul_tn_t(a: &[f32], g: &[f32], r: usize, m: usize, n: usize, t: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        par_row_blocks(&mut out, m, n, t, |lo, hi, chunk| {
            for ri in 0..r {
                let grow = &g[ri * n..(ri + 1) * n];
                for i in lo..hi {
                    let av = a[ri * m + i];
                    if av != 0.0 {
                        let orow = &mut chunk[(i - lo) * n..(i - lo + 1) * n];
                        for j in 0..n {
                            orow[j] += av * grow[j];
                        }
                    }
                }
            }
        });
        out
    }

    /// Seed `out[r,m] = g[r,n] @ w[m,n]ᵀ` (one scalar dot per element).
    pub fn matmul_nt_t(g: &[f32], w: &[f32], r: usize, n: usize, m: usize, t: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; r * m];
        par_row_blocks(&mut out, r, m, t, |lo, hi, chunk| {
            for ri in lo..hi {
                let grow = &g[ri * n..(ri + 1) * n];
                let orow = &mut chunk[(ri - lo) * m..(ri - lo + 1) * m];
                for i in 0..m {
                    let wrow = &w[i * n..(i + 1) * n];
                    let mut acc = 0.0f32;
                    for j in 0..n {
                        acc += grow[j] * wrow[j];
                    }
                    orow[i] = acc;
                }
            }
        });
        out
    }

    /// Seed conv3x3 SAME + bias + relu (full-`cout` rows, zero-skip).
    #[allow(clippy::too_many_arguments)]
    pub fn conv3x3_relu_t(
        x: &[f32],
        w: &[f32],
        bias: &[f32],
        b: usize,
        hw: usize,
        cin: usize,
        cout: usize,
        t: usize,
    ) -> Vec<f32> {
        let rows = b * hw;
        let mut out = vec![0.0f32; b * hw * hw * cout];
        par_row_blocks(&mut out, rows, hw * cout, t, |lo, hi, chunk| {
            for row in lo..hi {
                let (bi, oy) = (row / hw, row % hw);
                for ox in 0..hw {
                    let obase = ((row - lo) * hw + ox) * cout;
                    let orow = &mut chunk[obase..obase + cout];
                    orow.copy_from_slice(bias);
                    for ky in 0..3usize {
                        let iy = oy + ky;
                        if iy == 0 || iy > hw {
                            continue;
                        }
                        let iy = iy - 1;
                        for kx in 0..3usize {
                            let ix = ox + kx;
                            if ix == 0 || ix > hw {
                                continue;
                            }
                            let ix = ix - 1;
                            let xrow = &x[((bi * hw + iy) * hw + ix) * cin..][..cin];
                            let wbase = (ky * 3 + kx) * cin * cout;
                            for (ci, &av) in xrow.iter().enumerate() {
                                if av != 0.0 {
                                    let wrow = &w[wbase + ci * cout..][..cout];
                                    for co in 0..cout {
                                        orow[co] += av * wrow[co];
                                    }
                                }
                            }
                        }
                    }
                    for v in orow.iter_mut() {
                        if *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                }
            }
        });
        out
    }

    /// Seed conv3x3 backward, input-channel split (fused inner loop).
    #[allow(clippy::too_many_arguments)]
    pub fn conv3x3_bwd_t(
        x: &[f32],
        y: &[f32],
        gy: &[f32],
        w: &[f32],
        b: usize,
        hw: usize,
        cin: usize,
        cout: usize,
        t: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let t = t.min(cin).max(1);
        if t == 1 {
            return conv3x3_bwd_ci(x, y, gy, w, b, hw, cin, cout, 0, cin);
        }
        let bounds = block_bounds(cin, t);
        let parts: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = std::thread::scope(|s| {
            let handles: Vec<_> = bounds
                .iter()
                .map(|&(clo, chi)| {
                    s.spawn(move || conv3x3_bwd_ci(x, y, gy, w, b, hw, cin, cout, clo, chi))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("conv bwd oracle tile thread panicked"))
                .collect()
        });
        let mut gw = vec![0.0f32; 9 * cin * cout];
        let mut gb = vec![0.0f32; cout];
        let mut gx = vec![0.0f32; b * hw * hw * cin];
        for (&(clo, chi), (gw_p, gb_p, gx_p)) in bounds.iter().zip(parts) {
            let wci = chi - clo;
            for kk in 0..9 {
                gw[kk * cin * cout + clo * cout..kk * cin * cout + chi * cout]
                    .copy_from_slice(&gw_p[kk * wci * cout..(kk + 1) * wci * cout]);
            }
            for pos in 0..b * hw * hw {
                gx[pos * cin + clo..pos * cin + chi]
                    .copy_from_slice(&gx_p[pos * wci..(pos + 1) * wci]);
            }
            if clo == 0 {
                gb.copy_from_slice(&gb_p);
            }
        }
        (gw, gb, gx)
    }

    #[allow(clippy::too_many_arguments)]
    fn conv3x3_bwd_ci(
        x: &[f32],
        y: &[f32],
        gy: &[f32],
        w: &[f32],
        b: usize,
        hw: usize,
        cin: usize,
        cout: usize,
        clo: usize,
        chi: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let wci = chi - clo;
        let mut gw = vec![0.0f32; 9 * wci * cout];
        let mut gb = vec![0.0f32; cout];
        let mut gx = vec![0.0f32; b * hw * hw * wci];
        let mut gprevec = vec![0.0f32; cout];
        for bi in 0..b {
            for oy in 0..hw {
                for ox in 0..hw {
                    let obase = ((bi * hw + oy) * hw + ox) * cout;
                    let mut any = false;
                    for co in 0..cout {
                        let g = if y[obase + co] > 0.0 { gy[obase + co] } else { 0.0 };
                        gprevec[co] = g;
                        any |= g != 0.0;
                    }
                    if !any {
                        continue;
                    }
                    if clo == 0 {
                        for co in 0..cout {
                            gb[co] += gprevec[co];
                        }
                    }
                    for ky in 0..3usize {
                        let iy = oy + ky;
                        if iy == 0 || iy > hw {
                            continue;
                        }
                        let iy = iy - 1;
                        for kx in 0..3usize {
                            let ix = ox + kx;
                            if ix == 0 || ix > hw {
                                continue;
                            }
                            let ix = ix - 1;
                            let pos = (bi * hw + iy) * hw + ix;
                            let xbase = pos * cin;
                            let wbase = (ky * 3 + kx) * cin * cout;
                            let gwbase = (ky * 3 + kx) * wci * cout;
                            let gxrow = &mut gx[pos * wci..(pos + 1) * wci];
                            for ci in clo..chi {
                                let av = x[xbase + ci];
                                let wrow = &w[wbase + ci * cout..][..cout];
                                let gwrow = &mut gw[gwbase + (ci - clo) * cout..][..cout];
                                let mut acc = 0.0f32;
                                for co in 0..cout {
                                    let g = gprevec[co];
                                    gwrow[co] += av * g;
                                    acc += wrow[co] * g;
                                }
                                gxrow[ci - clo] += acc;
                            }
                        }
                    }
                }
            }
        }
        (gw, gb, gx)
    }

    /// Seed single-threaded 2×2 max-pool.
    pub fn maxpool2(x: &[f32], b: usize, hw: usize, c: usize) -> (Vec<f32>, Vec<u32>) {
        let ohw = hw / 2;
        let mut out = vec![0.0f32; b * ohw * ohw * c];
        let mut arg = vec![0u32; b * ohw * ohw * c];
        for bi in 0..b {
            for oy in 0..ohw {
                for ox in 0..ohw {
                    let obase = ((bi * ohw + oy) * ohw + ox) * c;
                    for ci in 0..c {
                        let mut best = f32::NEG_INFINITY;
                        let mut besti = 0u32;
                        for dy in 0..2usize {
                            for dx in 0..2usize {
                                let idx =
                                    ((bi * hw + 2 * oy + dy) * hw + 2 * ox + dx) * c + ci;
                                if x[idx] > best {
                                    best = x[idx];
                                    besti = idx as u32;
                                }
                            }
                        }
                        out[obase + ci] = best;
                        arg[obase + ci] = besti;
                    }
                }
            }
        }
        (out, arg)
    }

    /// Seed single-threaded max-pool gradient scatter.
    pub fn maxpool2_bwd(g: &[f32], arg: &[u32], input_len: usize) -> Vec<f32> {
        let mut gx = vec![0.0f32; input_len];
        for (i, &a) in arg.iter().enumerate() {
            gx[a as usize] += g[i];
        }
        gx
    }

    /// Seed single-threaded bias add.
    pub fn add_bias(pre: &mut [f32], bias: &[f32], rows: usize, cols: usize) {
        for ri in 0..rows {
            let row = &mut pre[ri * cols..(ri + 1) * cols];
            for j in 0..cols {
                row[j] += bias[j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn manifest_parses_and_covers_schedule_needs() {
        let m = native_manifest().unwrap();
        assert_eq!(m.batch, NATIVE_BATCH);
        assert!(m.supports_mp(1) && m.supports_mp(2) && m.supports_mp(4) && m.supports_mp(8));
        for name in ["conv_fwd", "conv_bwd", "full_step", "full_eval", "head_step", "head_fwd"] {
            assert!(m.get(name).is_ok(), "{name}");
        }
        assert!(m.get("fc0_fwd_k2bk").is_ok());
        assert!(m.get("head_step_bk4").is_ok());
        // full_step signature: 22 in, 21 out.
        let fs = m.get("full_step").unwrap();
        assert_eq!(fs.inputs.len(), 22);
        assert_eq!(fs.outputs.len(), 21);
    }

    #[test]
    fn matmul_small_identity() {
        // [2,3] @ [3,2]
        let a = [1., 2., 3., 4., 5., 6.];
        let b = [7., 8., 9., 10., 11., 12.];
        let c = matmul(&a, &b, 2, 3, 2);
        assert_eq!(c, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn fc_bwd_matches_finite_difference() {
        let mut rng = Rng::new(1);
        let (rows, din, dout) = (3, 5, 4);
        let w = HostTensor::f32(vec![din, dout], rng.normal_vec(din * dout, 0.5));
        let b = HostTensor::f32(vec![dout], rng.normal_vec(dout, 0.1));
        let x = HostTensor::f32(vec![rows, din], rng.normal_vec(rows * din, 1.0));
        let gy = HostTensor::f32(vec![rows, dout], rng.normal_vec(rows * dout, 1.0));
        let outs = fc_bwd(&w, &b, &x, &gy);
        // Scalar objective L = sum(gy * fc_fwd(x)); check dL/dw numerically.
        let f = |wv: &HostTensor| -> f64 {
            let y = fc_fwd(wv, &b, &x);
            y.as_f32().iter().zip(gy.as_f32()).map(|(a, g)| (a * g) as f64).sum()
        };
        let eps = 1e-3;
        for idx in [0usize, 7, din * dout - 1] {
            let mut wp = w.clone();
            wp.as_f32_mut()[idx] += eps;
            let mut wm = w.clone();
            wm.as_f32_mut()[idx] -= eps;
            let num = (f(&wp) - f(&wm)) / (2.0 * eps as f64);
            let ana = outs[0].as_f32()[idx] as f64;
            assert!((num - ana).abs() < 1e-2, "dw[{idx}]: {num} vs {ana}");
        }
    }

    #[test]
    fn head_zero_logits_gives_ln10() {
        let w2 = HostTensor::zeros(vec![1024, 10]);
        let b2 = HostTensor::zeros(vec![10]);
        let mut rng = Rng::new(2);
        let h1 = HostTensor::f32(vec![4, 1024], rng.normal_vec(4 * 1024, 1.0));
        let labels = HostTensor::i32(vec![4], vec![0, 1, 2, 3]);
        let out = head_step(&w2, &b2, &h1, &labels).unwrap();
        assert!((out[0].scalar() - 10f32.ln()).abs() < 1e-5);
        // gb2 = softmax(0) − mean onehot = 0.1 − count/B.
        for (c, g) in out[2].as_f32().iter().enumerate() {
            let expect = 0.1 - if c < 4 { 0.25 } else { 0.0 };
            assert!((g - expect).abs() < 1e-6, "gb2[{c}]={g}");
        }
    }

    #[test]
    fn conv_grads_match_finite_difference() {
        // A tiny 1-layer version of the conv machinery (exercised through
        // the public 7-layer entry points would be slow; here we check
        // the primitive itself).
        let mut rng = Rng::new(3);
        let (b, hw, cin, cout) = (1usize, 4usize, 2usize, 3usize);
        let x: Vec<f32> = rng.normal_vec(b * hw * hw * cin, 1.0);
        let w: Vec<f32> = rng.normal_vec(9 * cin * cout, 0.5);
        let bias: Vec<f32> = rng.normal_vec(cout, 0.1);
        let gy: Vec<f32> = rng.normal_vec(b * hw * hw * cout, 1.0);
        let y = conv3x3_relu(&x, &w, &bias, b, hw, cin, cout);
        let (gw, _gb, gx) = conv3x3_bwd(&x, &y, &gy, &w, b, hw, cin, cout);
        let f = |xv: &[f32], wv: &[f32]| -> f64 {
            conv3x3_relu(xv, wv, &bias, b, hw, cin, cout)
                .iter()
                .zip(gy.iter())
                .map(|(a, g)| (a * g) as f64)
                .sum()
        };
        let eps = 1e-3;
        for idx in [0usize, 5, 9 * cin * cout - 1] {
            let mut wp = w.clone();
            wp[idx] += eps;
            let mut wm = w.clone();
            wm[idx] -= eps;
            let num = (f(&x, &wp) - f(&x, &wm)) / (2.0 * eps as f64);
            assert!((num - gw[idx] as f64).abs() < 1e-2, "gw[{idx}]");
        }
        for idx in [0usize, 13] {
            let mut xp = x.clone();
            xp[idx] += eps;
            let mut xm = x.clone();
            xm[idx] -= eps;
            let num = (f(&xp, &w) - f(&xm, &w)) / (2.0 * eps as f64);
            assert!((num - gx[idx] as f64).abs() < 1e-2, "gx[{idx}]");
        }
    }

    #[test]
    fn maxpool_routes_gradient_to_argmax() {
        // 2x2 input, 1 channel: max at index 3.
        let x = [1.0f32, 2.0, 3.0, 9.0];
        let (y, arg) = maxpool2(&x, 1, 2, 1);
        assert_eq!(y, vec![9.0]);
        assert_eq!(arg, vec![3]);
        let gx = maxpool2_bwd(&[5.0], &arg, 1, 2, 1);
        assert_eq!(gx, vec![0.0, 0.0, 0.0, 5.0]);
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn tiled_matmuls_bitwise_match_single_thread() {
        let mut rng = Rng::new(11);
        let (m, k, n) = (7, 13, 9); // odd sizes: uneven blocks
        let a = rng.normal_vec(m * k, 1.0);
        let b = rng.normal_vec(k * n, 1.0);
        let g = rng.normal_vec(m * n, 1.0);
        for t in [2usize, 3, 5, 16] {
            assert_eq!(bits(&matmul_t(&a, &b, m, k, n, 1)), bits(&matmul_t(&a, &b, m, k, n, t)), "matmul t={t}");
            assert_eq!(
                bits(&matmul_tn_t(&a, &g, m, k, n, 1)),
                bits(&matmul_tn_t(&a, &g, m, k, n, t)),
                "matmul_tn t={t}"
            );
            assert_eq!(
                bits(&matmul_nt_t(&g, &b, m, n, k, 1)),
                bits(&matmul_nt_t(&g, &b, m, n, k, t)),
                "matmul_nt t={t}"
            );
        }
    }

    #[test]
    fn tiled_matmul_tn_matches_seed_order() {
        // The seed's ri-outer loop, verbatim: the i-outer restructure in
        // matmul_tn_t must reproduce it bit-for-bit (the per-element
        // accumulation order over ri is unchanged).
        fn matmul_tn_seed(a: &[f32], g: &[f32], r: usize, m: usize, n: usize) -> Vec<f32> {
            let mut out = vec![0.0f32; m * n];
            for ri in 0..r {
                let grow = &g[ri * n..(ri + 1) * n];
                for i in 0..m {
                    let av = a[ri * m + i];
                    if av != 0.0 {
                        let orow = &mut out[i * n..(i + 1) * n];
                        for j in 0..n {
                            orow[j] += av * grow[j];
                        }
                    }
                }
            }
            out
        }
        let mut rng = Rng::new(12);
        let (r, m, n) = (10, 6, 5);
        let a = rng.normal_vec(r * m, 1.0);
        let g = rng.normal_vec(r * n, 1.0);
        let seed = matmul_tn_seed(&a, &g, r, m, n);
        assert_eq!(bits(&seed), bits(&matmul_tn_t(&a, &g, r, m, n, 1)));
        assert_eq!(bits(&seed), bits(&matmul_tn_t(&a, &g, r, m, n, 4)));
    }

    #[test]
    fn tiled_conv_kernels_bitwise_match_single_thread() {
        let mut rng = Rng::new(13);
        let (b, hw, cin, cout) = (2usize, 6usize, 4usize, 5usize);
        let x = rng.normal_vec(b * hw * hw * cin, 1.0);
        let w = rng.normal_vec(9 * cin * cout, 0.5);
        let bias = rng.normal_vec(cout, 0.1);
        let y1 = conv3x3_relu_t(&x, &w, &bias, b, hw, cin, cout, 1);
        for t in [2usize, 3, 7] {
            let yt = conv3x3_relu_t(&x, &w, &bias, b, hw, cin, cout, t);
            assert_eq!(bits(&y1), bits(&yt), "conv3x3_relu t={t}");
        }
        let gy = rng.normal_vec(b * hw * hw * cout, 1.0);
        let (gw1, gb1, gx1) = conv3x3_bwd_t(&x, &y1, &gy, &w, b, hw, cin, cout, 1);
        for t in [2usize, 3, 4, 9] {
            let (gwt, gbt, gxt) = conv3x3_bwd_t(&x, &y1, &gy, &w, b, hw, cin, cout, t);
            assert_eq!(bits(&gw1), bits(&gwt), "conv3x3_bwd gw t={t}");
            assert_eq!(bits(&gb1), bits(&gbt), "conv3x3_bwd gb t={t}");
            assert_eq!(bits(&gx1), bits(&gxt), "conv3x3_bwd gx t={t}");
        }
    }

    /// Fast in-crate slice of the kernel_parity contract: blocked
    /// kernels vs the seed oracles, bitwise, on zero-laden inputs
    /// (exercising exactly the `if av != 0.0` skip the blocked dense
    /// paths removed).
    #[test]
    fn blocked_kernels_match_oracles_on_zero_laden_inputs() {
        let mut rng = Rng::new(21);
        let zero_laden = |rng: &mut Rng, len: usize| -> Vec<f32> {
            let mut v = rng.normal_vec(len, 1.0);
            for (i, x) in v.iter_mut().enumerate() {
                if i % 3 == 0 {
                    *x = 0.0;
                }
            }
            v
        };
        let (m, k, n) = (5, 40, 21); // n straddles one full lane block + a tail
        let a = zero_laden(&mut rng, m * k);
        let b = zero_laden(&mut rng, k * n);
        let g = zero_laden(&mut rng, m * n);
        for t in [1usize, 3] {
            assert_eq!(
                bits(&matmul_t(&a, &b, m, k, n, t)),
                bits(&oracle::matmul_t(&a, &b, m, k, n, t)),
                "matmul t={t}"
            );
            assert_eq!(
                bits(&matmul_tn_t(&a, &g, m, k, n, t)),
                bits(&oracle::matmul_tn_t(&a, &g, m, k, n, t)),
                "matmul_tn t={t}"
            );
            assert_eq!(
                bits(&matmul_nt_t(&g, &b, m, n, k, t)),
                bits(&oracle::matmul_nt_t(&g, &b, m, n, k, t)),
                "matmul_nt t={t}"
            );
        }
        let (cb, hw, cin, cout) = (1usize, 4usize, 3usize, 19usize);
        let x = zero_laden(&mut rng, cb * hw * hw * cin);
        let w = zero_laden(&mut rng, 9 * cin * cout);
        let bias = rng.normal_vec(cout, 0.1);
        for t in [1usize, 2] {
            let got = conv3x3_relu_t(&x, &w, &bias, cb, hw, cin, cout, t);
            let want = oracle::conv3x3_relu_t(&x, &w, &bias, cb, hw, cin, cout, t);
            assert_eq!(bits(&got), bits(&want), "conv3x3_relu t={t}");
            let gy = zero_laden(&mut rng, cb * hw * hw * cout);
            let (gw1, gb1, gx1) = conv3x3_bwd_t(&x, &got, &gy, &w, cb, hw, cin, cout, t);
            let (gw2, gb2, gx2) = oracle::conv3x3_bwd_t(&x, &want, &gy, &w, cb, hw, cin, cout, t);
            assert_eq!(bits(&gw1), bits(&gw2), "conv bwd gw t={t}");
            assert_eq!(bits(&gb1), bits(&gb2), "conv bwd gb t={t}");
            assert_eq!(bits(&gx1), bits(&gx2), "conv bwd gx t={t}");
        }
        // Pool fwd/bwd and the threaded epilogues.
        let (pout, parg) = maxpool2_t(&x, cb, hw, cin, 3);
        let (oout, oarg) = oracle::maxpool2(&x, cb, hw, cin);
        assert_eq!(bits(&pout), bits(&oout));
        assert_eq!(parg, oarg);
        let pg = zero_laden(&mut rng, pout.len());
        assert_eq!(
            bits(&maxpool2_bwd_t(&pg, &parg, cb, hw, cin, 3)),
            bits(&oracle::maxpool2_bwd(&pg, &oarg, cb * hw * hw * cin))
        );
        let pre = zero_laden(&mut rng, m * n);
        let bias2 = rng.normal_vec(n, 0.1);
        let mut p1 = pre.clone();
        let mut p2 = pre.clone();
        add_bias_t(&mut p1, &bias2, m, n, 4);
        oracle::add_bias(&mut p2, &bias2, m, n);
        assert_eq!(bits(&p1), bits(&p2), "add_bias");
        let mut p3 = pre.clone();
        add_bias_relu_t(&mut p3, &bias2, m, n, 2);
        for v in p2.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        assert_eq!(bits(&p3), bits(&p2), "add_bias_relu");
    }

    #[test]
    fn block_bounds_partition_exactly() {
        for (rows, t) in [(7usize, 3usize), (8, 4), (5, 5), (10, 1)] {
            let b = block_bounds(rows, t);
            assert_eq!(b[0].0, 0);
            assert_eq!(b[t - 1].1, rows);
            for i in 1..t {
                assert_eq!(b[i - 1].1, b[i].0, "contiguous");
            }
        }
    }

    #[test]
    fn execute_is_deterministic() {
        let mut rng = Rng::new(4);
        let w = HostTensor::f32(vec![4096, 512], rng.normal_vec(4096 * 512, 0.02));
        let b = HostTensor::f32(vec![512], rng.normal_vec(512, 0.1));
        let x = HostTensor::f32(vec![2, 4096], rng.normal_vec(2 * 4096, 0.5));
        let a = execute("fc0_fwd_k2", &[w.clone(), b.clone(), x.clone()]).unwrap();
        let c = execute("fc0_fwd_k2", &[w, b, x]).unwrap();
        assert_eq!(a[0].as_f32(), c[0].as_f32());
    }
}
