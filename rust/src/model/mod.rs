//! The CNN layer DSL and SplitBrain's automatic network transformation.
//!
//! Mirrors the paper's §3: programmers build a CNN from convolutional,
//! FC and functional layers exactly as a *local* model; [`partition`]
//! implements Listing 1, splitting CCR-worthy FC layers 1/K and
//! inserting the [`Layer::Modulo`] / [`Layer::Shard`] communication
//! layers that the coordinator later schedules.
//!
//! - [`layer`] — the layer vocabulary (SEQ, CONV, LINEAR, ... MODULO, SHARD)
//! - [`dims`] — feature-dimension inference (`resize()` in the paper)
//! - [`ccr`] — computation-to-communication ratio estimates
//! - [`partition`] — Listing 1 + the transform of Fig. 3
//! - [`vgg`] — the VGG-11 CIFAR variant of Table 1

pub mod ccr;
pub mod dims;
pub mod layer;
pub mod partition;
pub mod spec;
pub mod vgg;

pub use dims::Dim;
pub use layer::Layer;
pub use partition::{partition_network, PartitionConfig, TransformedNet};
pub use spec::{parse as parse_spec, ModelSpec};
pub use vgg::vgg11;
