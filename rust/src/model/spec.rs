//! Text model specs — the counterpart of the paper's Torch/Lua frontend
//! (§4: "we provide Torch-like CNN construction through Lua bindings").
//!
//! A spec is a line-based description; the partitioner then transforms
//! it exactly like a hand-built network:
//!
//! ```text
//! # VGG-11 CIFAR variant
//! input 32 32 3
//! conv Conv0 3 64
//! relu
//! conv Conv1 64 64
//! relu
//! pool 2
//! ...
//! reshape 4096
//! linear FC0 4096 1024
//! relu
//! dropout 0.5
//! linear FC2 1024 10
//! logsoftmax
//! ```
//!
//! Keywords: `input H W C`, `conv NAME CIN COUT [KSIZE=3]`,
//! `pool WINDOW`, `pad AMOUNT`, `relu`, `dropout P`,
//! `reshape D0 [D1 ...]`, `linear NAME DIN DOUT`, `logsoftmax`.
//! `#` starts a comment. Shapes are validated at parse time so a typo
//! fails with the offending line, not deep inside the runtime.

use anyhow::{bail, Context, Result};

use super::dims::{self, Dim};
use super::layer::Layer;

/// A parsed spec: the network plus its input shape.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// The parsed network.
    pub net: Layer,
    /// Per-example input shape.
    pub input_dim: Dim,
}

/// Parse a spec from text.
pub fn parse(text: &str) -> Result<ModelSpec> {
    let mut input_dim: Option<Dim> = None;
    let mut layers: Vec<Layer> = Vec::new();
    let mut dim: Option<Dim> = None;

    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let tok: Vec<&str> = line.split_whitespace().collect();
        let ctx = || format!("spec line {}: {raw:?}", lineno + 1);
        let usize_at = |i: usize| -> Result<usize> {
            tok.get(i)
                .with_context(ctx)?
                .parse::<usize>()
                .with_context(ctx)
        };
        let layer = match tok[0] {
            "input" => {
                if input_dim.is_some() {
                    bail!("{}: duplicate input line", ctx());
                }
                if tok.len() < 2 {
                    bail!("{}: input needs at least one dim", ctx());
                }
                let d: Dim = (1..tok.len())
                    .map(usize_at)
                    .collect::<Result<Vec<_>>>()?;
                input_dim = Some(d.clone());
                dim = Some(d);
                continue;
            }
            "conv" => {
                let name = tok.get(1).with_context(ctx)?.to_string();
                let cin = usize_at(2)?;
                let cout = usize_at(3)?;
                let ksize = if tok.len() > 4 { usize_at(4)? } else { 3 };
                Layer::Conv { name, cin, cout, ksize }
            }
            "pool" => Layer::Pool { window: usize_at(1)? },
            "pad" => Layer::Pad { amount: usize_at(1)? },
            "relu" => Layer::Relu,
            "dropout" => Layer::Dropout {
                p: tok.get(1).with_context(ctx)?.parse::<f32>().with_context(ctx)?,
            },
            "reshape" => Layer::Reshape {
                out: (1..tok.len()).map(usize_at).collect::<Result<Vec<_>>>()?,
            },
            "linear" => Layer::Linear {
                name: tok.get(1).with_context(ctx)?.to_string(),
                din: usize_at(2)?,
                dout: usize_at(3)?,
                shard_of: None,
            },
            "logsoftmax" => Layer::LogSoftmax,
            other => bail!("{}: unknown keyword {other:?}", ctx()),
        };
        // Shape-check as we go (resize fails with the exact line).
        let d = dim.as_ref().with_context(|| format!("{}: layer before `input`", ctx()))?;
        dim = Some(dims::resize(&layer, d).with_context(ctx)?);
        layers.push(layer);
    }

    let input_dim = input_dim.context("spec missing `input H W C` line")?;
    if layers.is_empty() {
        bail!("spec has no layers");
    }
    Ok(ModelSpec { net: Layer::Seq(layers), input_dim })
}

/// The VGG-11 variant as a spec string (round-trip fixture + example).
pub const VGG11_SPEC: &str = "\
# VGG-11 CIFAR variant (Table 1 of the SplitBrain paper)
input 32 32 3
conv Conv0 3 64
relu
conv Conv1 64 64
relu
pool 2
conv Conv2 64 128
relu
conv Conv3 128 128
relu
pool 2
conv Conv4 128 256
relu
conv Conv5 256 256
relu
conv Conv6 256 256
relu
pool 2
reshape 4096
linear FC0 4096 1024
relu
linear FC1 1024 1024
relu
linear FC2 1024 10
logsoftmax
";

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::vgg::vgg11;

    #[test]
    fn vgg_spec_roundtrips_to_builder() {
        let spec = parse(VGG11_SPEC).unwrap();
        assert_eq!(spec.input_dim, vec![32, 32, 3]);
        assert_eq!(spec.net, vgg11());
    }

    #[test]
    fn partitioner_accepts_spec_output() {
        use crate::model::{partition_network, PartitionConfig};
        let spec = parse(VGG11_SPEC).unwrap();
        let t = partition_network(
            &spec.net,
            spec.input_dim,
            &PartitionConfig { mp: 4, ..Default::default() },
        )
        .unwrap();
        assert_eq!(t.sharded_linears(), vec!["FC0", "FC1"]);
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let spec = parse("# c\n\ninput 8\nlinear L 8 4  # trailing\nlogsoftmax\n").unwrap();
        assert_eq!(spec.net.flatten().len(), 2);
    }

    #[test]
    fn custom_kernel_size() {
        let spec = parse("input 8 8 4\nconv C 4 8 5\n").unwrap();
        match spec.net.flatten()[0] {
            Layer::Conv { ksize, .. } => assert_eq!(*ksize, 5),
            _ => unreachable!(),
        }
    }

    #[test]
    fn shape_errors_carry_line_numbers() {
        // Linear din mismatches the running shape.
        let err = parse("input 10\nlinear L 99 5\n").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 2"), "{msg}");
    }

    #[test]
    fn missing_input_rejected() {
        assert!(parse("linear L 4 2\n").is_err());
        assert!(parse("# only comments\n").is_err());
        assert!(parse("input 4\n").is_err()); // no layers
    }

    #[test]
    fn unknown_keyword_rejected() {
        let err = parse("input 4\nfoobar 1 2\n").unwrap_err().to_string();
        assert!(err.contains("foobar"));
    }

    #[test]
    fn duplicate_input_rejected() {
        assert!(parse("input 4\ninput 5\nlinear L 5 2\n").is_err());
    }
}
