//! Computation-to-communication ratio (CCR) estimates.
//!
//! Listing 1 (line 25/31) partitions an FC layer only when its CCR
//! exceeds a threshold: model parallelism adds per-example exchange, so
//! the layer must carry enough arithmetic to amortize it.
//!
//! We estimate, per assembled batch of B examples:
//!   flops(linear)  = 6·B·din·dout      (fwd matmul + the two bwd matmuls)
//!   comm_bytes     = 8·B·(din + dout)  (shard fprop allgather of the
//!                    dout outputs + bprop reduce of the din-wide input
//!                    gradients, 4 bytes each, both directions)
//!   ccr            = flops / comm_bytes = 0.75·din·dout/(din+dout)
//!
//! B cancels, so the decision is topology-only — matching the paper,
//! where the partitioning happens before training starts. For the VGG
//! variant: FC0 ≈ 614, FC1 ≈ 384, FC2 ≈ 7.4 — the default threshold of
//! 50 partitions FC0/FC1 and replicates the tiny FC2 head.

use super::layer::Layer;

/// Default CCR threshold (the `CCR()` call of Listing 1).
pub const DEFAULT_CCR_THRESHOLD: f64 = 50.0;

/// Forward+backward flops of a layer per example.
pub fn flops_per_example(layer: &Layer, spatial: Option<(usize, usize)>) -> f64 {
    match layer {
        Layer::Linear { din, dout, .. } => 6.0 * (*din as f64) * (*dout as f64),
        Layer::Conv { cin, cout, ksize, .. } => {
            // fwd + input-grad + weight-grad conv passes, SAME padding.
            let (h, w) = spatial.expect("conv flops need spatial dims");
            6.0 * (h * w * ksize * ksize * cin * cout) as f64
        }
        _ => 0.0,
    }
}

/// Shard-layer exchange volume per example if `layer` were partitioned
/// (bytes, both directions, f32).
pub fn shard_comm_bytes_per_example(layer: &Layer) -> f64 {
    match layer {
        Layer::Linear { din, dout, .. } => 8.0 * (*din as f64 + *dout as f64),
        _ => 0.0,
    }
}

/// The `layer.ccr()` of Listing 1. Zero for non-linear layers (never
/// partitioned on CCR grounds).
pub fn ccr(layer: &Layer) -> f64 {
    let comm = shard_comm_bytes_per_example(layer);
    if comm == 0.0 {
        return 0.0;
    }
    flops_per_example(layer, None) / comm
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lin(din: usize, dout: usize) -> Layer {
        Layer::Linear { name: "l".into(), din, dout, shard_of: None }
    }

    #[test]
    fn vgg_fc_ccr_ordering() {
        let fc0 = ccr(&lin(4096, 1024));
        let fc1 = ccr(&lin(1024, 1024));
        let fc2 = ccr(&lin(1024, 10));
        assert!(fc0 > fc1 && fc1 > fc2, "{fc0} {fc1} {fc2}");
        // The default threshold splits exactly {FC0, FC1}.
        assert!(fc0 > DEFAULT_CCR_THRESHOLD);
        assert!(fc1 > DEFAULT_CCR_THRESHOLD);
        assert!(fc2 < DEFAULT_CCR_THRESHOLD);
    }

    #[test]
    fn ccr_formula() {
        // 0.75·din·dout/(din+dout)
        let c = ccr(&lin(4096, 1024));
        assert!((c - 0.75 * 4096.0 * 1024.0 / 5120.0).abs() < 1e-9);
    }

    #[test]
    fn non_linear_layers_have_zero_ccr() {
        assert_eq!(ccr(&Layer::Relu), 0.0);
        assert_eq!(ccr(&Layer::Pool { window: 2 }), 0.0);
    }

    #[test]
    fn conv_flops_scale_with_spatial() {
        let c = Layer::Conv { name: "c".into(), cin: 64, cout: 64, ksize: 3 };
        let f32x32 = flops_per_example(&c, Some((32, 32)));
        let f16x16 = flops_per_example(&c, Some((16, 16)));
        assert!((f32x32 / f16x16 - 4.0).abs() < 1e-9);
    }
}
