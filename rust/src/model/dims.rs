//! Feature-dimension inference — the `resize()` of Listing 1.
//!
//! A [`Dim`] is the per-example feature shape (batch excluded):
//! `[H, W, C]` in the conv region, `[features]` after the flatten.
//! The partitioner threads two of these through the network: `dim`
//! (with the previous layer partitioned) and `dim_full` (without).

use anyhow::{bail, Result};

use super::layer::Layer;

/// Per-example feature shape, NHWC order without N.
pub type Dim = Vec<usize>;

/// Total element count of a feature shape.
pub fn numel(d: &Dim) -> usize {
    d.iter().product()
}

/// Output shape of `layer` on input shape `d` — the paper's
/// `layer.resize(dim)`. Fails on rank/shape mismatches so the
/// partitioner surfaces malformed networks early.
pub fn resize(layer: &Layer, d: &Dim) -> Result<Dim> {
    match layer {
        Layer::Seq(_) => bail!("resize() on a Seq container"),
        Layer::Reshape { out } => {
            if numel(d) != out.iter().product::<usize>() {
                bail!("Reshape{out:?} on input {d:?}: element count differs");
            }
            Ok(out.clone())
        }
        Layer::Pad { amount } => match d.as_slice() {
            [h, w, c] => Ok(vec![h + 2 * amount, w + 2 * amount, *c]),
            _ => bail!("Pad on non-spatial input {d:?}"),
        },
        Layer::Conv { cin, cout, name, .. } => match d.as_slice() {
            [h, w, c] if c == cin => Ok(vec![*h, *w, *cout]),
            _ => bail!("{name}: Conv expects [H,W,{cin}], got {d:?}"),
        },
        Layer::Pool { window } => match d.as_slice() {
            [h, w, c] if h % window == 0 && w % window == 0 => {
                Ok(vec![h / window, w / window, *c])
            }
            _ => bail!("Pool{window} on {d:?}: not divisible"),
        },
        Layer::Dropout { .. } | Layer::Relu => Ok(d.clone()), // one-to-one
        Layer::Linear { name, din, dout, .. } => match d.as_slice() {
            [f] if f == din => Ok(vec![*dout]),
            _ => bail!("{name}: Linear expects [{din}], got {d:?}"),
        },
        Layer::LogSoftmax => Ok(d.clone()),
        Layer::Modulo { .. } => Ok(d.clone()),
        Layer::Shard { dim_part, dim_full } => match d.as_slice() {
            [f] if f == dim_part => Ok(vec![*dim_full]),
            _ => bail!("Shard expects [{dim_part}], got {d:?}"),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_keeps_spatial_same_padding() {
        let c = Layer::Conv { name: "c".into(), cin: 3, cout: 64, ksize: 3 };
        assert_eq!(resize(&c, &vec![32, 32, 3]).unwrap(), vec![32, 32, 64]);
    }

    #[test]
    fn conv_rejects_wrong_channels() {
        let c = Layer::Conv { name: "c".into(), cin: 3, cout: 64, ksize: 3 };
        assert!(resize(&c, &vec![32, 32, 4]).is_err());
    }

    #[test]
    fn pool_halves() {
        let p = Layer::Pool { window: 2 };
        assert_eq!(resize(&p, &vec![32, 32, 64]).unwrap(), vec![16, 16, 64]);
        assert!(resize(&p, &vec![5, 5, 1]).is_err());
    }

    #[test]
    fn reshape_checks_numel() {
        let r = Layer::Reshape { out: vec![4096] };
        assert_eq!(resize(&r, &vec![4, 4, 256]).unwrap(), vec![4096]);
        assert!(resize(&r, &vec![4, 4, 128]).is_err());
    }

    #[test]
    fn linear_maps_features() {
        let l = Layer::Linear { name: "f".into(), din: 4096, dout: 1024, shard_of: None };
        assert_eq!(resize(&l, &vec![4096]).unwrap(), vec![1024]);
        assert!(resize(&l, &vec![100]).is_err());
    }

    #[test]
    fn one_to_one_layers_pass_through() {
        assert_eq!(resize(&Layer::Relu, &vec![512]).unwrap(), vec![512]);
        assert_eq!(
            resize(&Layer::Dropout { p: 0.5 }, &vec![16, 16, 64]).unwrap(),
            vec![16, 16, 64]
        );
    }

    #[test]
    fn shard_restores_full_width() {
        let s = Layer::Shard { dim_part: 512, dim_full: 1024 };
        assert_eq!(resize(&s, &vec![512]).unwrap(), vec![1024]);
        assert!(resize(&s, &vec![100]).is_err());
    }

    #[test]
    fn pad_grows_spatial() {
        let p = Layer::Pad { amount: 1 };
        assert_eq!(resize(&p, &vec![32, 32, 3]).unwrap(), vec![34, 34, 3]);
    }
}
