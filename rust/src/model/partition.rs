//! Listing 1: automatic layer partitioning and network transformation.
//!
//! Walks the sequential model tracking `dim` (feature shape *with* the
//! previous layer partitioned) and `dim_full` (*without*), splitting
//! CCR-worthy LINEAR layers into 1/K column shards and inserting the
//! `Modulo` / `Shard` communication layers exactly where the paper's
//! pseudocode does (Fig. 3's transform is the `k > 1` output for VGG).

use anyhow::{bail, Context, Result};

use super::ccr;
use super::dims::{self, Dim};
use super::layer::Layer;

/// Knobs of the transform (the trainer config of §4).
#[derive(Debug, Clone)]
pub struct PartitionConfig {
    /// MP group size K (`mp` in the paper; 1 = pure DP).
    pub mp: usize,
    /// CCR threshold — the `CCR()` call of Listing 1.
    pub ccr_threshold: f64,
}

impl Default for PartitionConfig {
    fn default() -> Self {
        PartitionConfig { mp: 1, ccr_threshold: ccr::DEFAULT_CCR_THRESHOLD }
    }
}

/// The transformed data+model-parallel network.
#[derive(Debug, Clone)]
pub struct TransformedNet {
    /// Flat layer list with Modulo/Shard inserted and Linears sharded.
    pub layers: Vec<Layer>,
    /// The group size the transform was built for.
    pub mp: usize,
    /// Input feature shape.
    pub input_dim: Dim,
}

impl TransformedNet {
    /// Per-worker weight-count (Table 1 convention, weights only).
    pub fn weight_count(&self) -> usize {
        self.layers.iter().map(Layer::weight_count).sum()
    }

    /// Per-worker parameter count including biases.
    pub fn param_count(&self) -> usize {
        self.layers.iter().map(Layer::param_count).sum()
    }

    /// Names of the linear layers that were sharded.
    pub fn sharded_linears(&self) -> Vec<&str> {
        self.layers
            .iter()
            .filter_map(|l| match l {
                Layer::Linear { name, shard_of: Some(_), .. } => Some(name.as_str()),
                _ => None,
            })
            .collect()
    }

    /// Pretty multi-line rendering (Fig. 3 style).
    pub fn render(&self) -> String {
        let mut s = String::new();
        for l in &self.layers {
            s.push_str(&format!("  {l}\n"));
        }
        s
    }
}

/// `partition()` of Listing 1, applied to a whole network.
///
/// `input_dim` is the per-example input shape (e.g. `[32, 32, 3]`).
pub fn partition_network(
    net: &Layer,
    input_dim: Dim,
    cfg: &PartitionConfig,
) -> Result<TransformedNet> {
    if cfg.mp == 0 {
        bail!("mp group size must be >= 1");
    }
    let mut out = Vec::new();
    let mut dim = input_dim.clone();
    let mut dim_full = input_dim.clone();
    walk(net, &mut dim, &mut dim_full, &mut out, cfg)
        .context("partitioning network")?;
    if dim != dim_full {
        bail!("network ends with partitioned output {dim:?} != {dim_full:?} — missing LogSoftmax/Shard?");
    }
    Ok(TransformedNet { layers: out, mp: cfg.mp, input_dim })
}

/// The recursive body — a line-by-line port of Listing 1.
fn walk(
    layer: &Layer,
    dim: &mut Dim,
    dim_full: &mut Dim,
    net: &mut Vec<Layer>,
    cfg: &PartitionConfig,
) -> Result<()> {
    let k = cfg.mp;
    match layer {
        // case SEQ: recurse in order (lines 9-12).
        Layer::Seq(layers) => {
            for l in layers {
                walk(l, dim, dim_full, net, cfg)?;
            }
            Ok(())
        }

        // case RESHAPE | PAD | CONV | POOLING: excluded from
        // partitioning; partitioned input unsupported (lines 13-18).
        Layer::Reshape { .. } | Layer::Pad { .. } | Layer::Conv { .. } | Layer::Pool { .. } => {
            if dim != dim_full {
                bail!("{layer}: partitioned input unsupported");
            }
            let d = dims::resize(layer, dim)?;
            *dim = d.clone();
            *dim_full = d;
            net.push(layer.clone());
            Ok(())
        }

        // case DROPOUT | RELU: one-to-one, adapt to the partitioned
        // width, pass dim/dim_full down intact (lines 19-21).
        Layer::Dropout { .. } | Layer::Relu => {
            net.push(layer.clone());
            Ok(())
        }

        // case LINEAR (lines 22-35).
        Layer::Linear { name, din, dout, shard_of } => {
            if shard_of.is_some() {
                bail!("{name}: already-sharded linear in source network");
            }
            let divisible = dout % k == 0;
            let worthy = k > 1 && ccr::ccr(layer) > cfg.ccr_threshold && divisible;
            let mut placed = layer.clone();

            if dim == dim_full {
                // First FC at the DP/MP boundary: full input available
                // locally. If partitioning, a MODULO layer schedules the
                // B/K broadcast (lines 24-28).
                if dim.as_slice() != [*din] {
                    bail!("{name}: expects [{din}], got {dim:?}");
                }
                if worthy {
                    net.push(Layer::Modulo { dim: *din });
                    placed = layer.shard_linear(k);
                }
            } else {
                // Partitioned input: a SHARD layer restores the full
                // width first (lines 29-33).
                let part = match dim.as_slice() {
                    [p] => *p,
                    _ => bail!("{name}: partitioned input {dim:?} not 1-D"),
                };
                net.push(Layer::Shard { dim_part: part, dim_full: din_of(dim_full)? });
                *dim = dim_full.clone();
                if worthy {
                    placed = layer.shard_linear(k);
                }
            }

            // dim <- (possibly partitioned) out_dim; dimF <- full out_dim
            // (lines 23/34).
            *dim = dims::resize(&placed, dim)?;
            *dim_full = vec![*dout];
            net.push(placed);
            Ok(())
        }

        // case LOG_SOFTMAX: restore full input so the same output error
        // is evaluated as a complete local model (lines 36-38).
        Layer::LogSoftmax => {
            if dim != dim_full {
                let part = match dim.as_slice() {
                    [p] => *p,
                    _ => bail!("LogSoftmax: partitioned input {dim:?} not 1-D"),
                };
                net.push(Layer::Shard { dim_part: part, dim_full: din_of(dim_full)? });
                *dim = dim_full.clone();
            }
            net.push(Layer::LogSoftmax);
            Ok(())
        }

        Layer::Modulo { .. } | Layer::Shard { .. } => {
            bail!("communication layer {layer} in source network")
        }
    }
}

fn din_of(dim_full: &Dim) -> Result<usize> {
    match dim_full.as_slice() {
        [f] => Ok(*f),
        other => bail!("expected 1-D full dim, got {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::vgg::vgg11;

    fn transform(mp: usize) -> TransformedNet {
        partition_network(&vgg11(), vec![32, 32, 3], &PartitionConfig {
            mp,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn mp1_is_identity() {
        let t = transform(1);
        assert!(t.layers.iter().all(|l| !l.is_comm()));
        assert_eq!(t.sharded_linears().len(), 0);
        assert_eq!(t.weight_count(), 6_987_456); // Table 1 total
    }

    #[test]
    fn mp2_matches_fig3() {
        let t = transform(2);
        // One modulo at the boundary, shard after FC0, after FC1 — and
        // none before LogSoftmax (FC2 replicated keeps full width).
        let modulos: Vec<_> = t.layers.iter().filter(|l| matches!(l, Layer::Modulo { .. })).collect();
        let shards: Vec<_> = t.layers.iter().filter(|l| matches!(l, Layer::Shard { .. })).collect();
        assert_eq!(modulos.len(), 1);
        assert_eq!(shards.len(), 2);
        assert_eq!(t.sharded_linears(), vec!["FC0", "FC1"]);
    }

    #[test]
    fn modulo_sits_before_first_shard_fc() {
        let t = transform(2);
        let idx_mod = t.layers.iter().position(|l| matches!(l, Layer::Modulo { .. })).unwrap();
        match &t.layers[idx_mod + 1] {
            Layer::Linear { name, dout, shard_of, .. } => {
                assert_eq!(name, "FC0");
                assert_eq!(*dout, 512);
                assert_eq!(*shard_of, Some(2));
            }
            other => panic!("expected sharded FC0 after modulo, got {other}"),
        }
        assert!(matches!(t.layers[idx_mod], Layer::Modulo { dim: 4096 }));
    }

    #[test]
    fn shard_widths_restore_full_input() {
        let t = transform(4);
        let shards: Vec<(usize, usize)> = t
            .layers
            .iter()
            .filter_map(|l| match l {
                Layer::Shard { dim_part, dim_full } => Some((*dim_part, *dim_full)),
                _ => None,
            })
            .collect();
        assert_eq!(shards, vec![(256, 1024), (256, 1024)]);
    }

    #[test]
    fn fc2_replicated_by_ccr() {
        for k in [2, 4, 8] {
            let t = transform(k);
            let fc2 = t
                .layers
                .iter()
                .find(|l| matches!(l, Layer::Linear { name, .. } if name == "FC2"))
                .unwrap();
            assert!(
                matches!(fc2, Layer::Linear { shard_of: None, dout: 10, .. }),
                "FC2 must stay replicated at k={k}"
            );
        }
    }

    #[test]
    fn memory_savings_track_k() {
        // Fig. 7c's x-axis: per-worker weights shrink with mp.
        let w1 = transform(1).weight_count() as f64;
        let w2 = transform(2).weight_count() as f64;
        let w8 = transform(8).weight_count() as f64;
        assert!(w2 < w1 && w8 < w2);
        // FC0+FC1 = 5,242,880 weights get divided by K.
        let expect8 = 6_987_456.0 - 5_242_880.0 * (1.0 - 1.0 / 8.0);
        assert!((w8 - expect8).abs() < 1.0, "{w8} vs {expect8}");
    }

    #[test]
    fn paper_memory_savings_claim_67_percent() {
        // Abstract: "saving up to 67% of memory consumption". With K=8,
        // weights drop from 6.99M to 2.40M — a 65.7% saving; K=16 (not
        // benchmarked in Table 2's 8-machine row) gives 70%.
        let w1 = transform(1).weight_count() as f64;
        let w8 = transform(8).weight_count() as f64;
        let saving = 1.0 - w8 / w1;
        assert!(saving > 0.60 && saving < 0.70, "saving {saving}");
    }

    #[test]
    fn high_threshold_disables_mp() {
        let t = partition_network(
            &vgg11(),
            vec![32, 32, 3],
            &PartitionConfig { mp: 4, ccr_threshold: 1e12 },
        )
        .unwrap();
        assert_eq!(t.sharded_linears().len(), 0);
        assert!(t.layers.iter().all(|l| !l.is_comm()));
    }

    #[test]
    fn rejects_comm_layer_in_source() {
        let bad = Layer::Seq(vec![Layer::Modulo { dim: 10 }]);
        assert!(partition_network(&bad, vec![10], &Default::default()).is_err());
    }

    #[test]
    fn rejects_conv_after_partitioned_fc() {
        // A (malformed) net with a conv after a sharded linear must be
        // rejected with the paper's "partitioned input unsupported".
        let bad = Layer::Seq(vec![
            Layer::Linear { name: "L".into(), din: 4096, dout: 1024, shard_of: None },
            Layer::Reshape { out: vec![4, 4, 64] },
            Layer::Conv { name: "C".into(), cin: 64, cout: 64, ksize: 3 },
            Layer::LogSoftmax,
        ]);
        let err = partition_network(
            &bad,
            vec![4096],
            &PartitionConfig { mp: 2, ..Default::default() },
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("partitioned input unsupported"));
    }

    #[test]
    fn non_divisible_dout_stays_replicated() {
        // dout=10 with k=4: not divisible -> replicated even with CCR 0.
        let net = Layer::Seq(vec![
            Layer::Linear { name: "L".into(), din: 4096, dout: 10, shard_of: None },
            Layer::LogSoftmax,
        ]);
        let t = partition_network(
            &net,
            vec![4096],
            &PartitionConfig { mp: 4, ccr_threshold: 0.0 },
        )
        .unwrap();
        assert_eq!(t.sharded_linears().len(), 0);
    }
}
