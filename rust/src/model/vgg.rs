//! The VGG variant of the paper's evaluation (§5.1, Table 1):
//! 7 convolutional + 3 FC trainable layers, 6,987,456 weights
//! (~7M; the paper quotes 7.5M including optimizer bookkeeping),
//! with the FC stack holding 75.17% of them.

use super::layer::Layer;

/// Construct the VGG-11 CIFAR variant exactly as a *local* model — the
/// programmer-facing form before `partition_network` transforms it.
pub fn vgg11() -> Layer {
    let conv = |name: &str, cin: usize, cout: usize| Layer::Conv {
        name: name.into(),
        cin,
        cout,
        ksize: 3,
    };
    let fc = |name: &str, din: usize, dout: usize| Layer::Linear {
        name: name.into(),
        din,
        dout,
        shard_of: None,
    };
    Layer::Seq(vec![
        conv("Conv0", 3, 64),
        Layer::Relu,
        conv("Conv1", 64, 64),
        Layer::Relu,
        Layer::Pool { window: 2 }, // 32 -> 16
        conv("Conv2", 64, 128),
        Layer::Relu,
        conv("Conv3", 128, 128),
        Layer::Relu,
        Layer::Pool { window: 2 }, // 16 -> 8
        conv("Conv4", 128, 256),
        Layer::Relu,
        conv("Conv5", 256, 256),
        Layer::Relu,
        conv("Conv6", 256, 256),
        Layer::Relu,
        Layer::Pool { window: 2 }, // 8 -> 4
        Layer::Reshape { out: vec![4096] },
        fc("FC0", 4096, 1024),
        Layer::Relu,
        fc("FC1", 1024, 1024),
        Layer::Relu,
        fc("FC2", 1024, 10),
        Layer::LogSoftmax,
    ])
}

/// Table 1 rows: (layer, I/O channel or feature dims, weight count).
pub fn table1() -> Vec<(String, String, usize)> {
    vgg11()
        .flatten()
        .iter()
        .filter_map(|l| match l {
            Layer::Conv { name, cin, cout, .. } => {
                Some((name.clone(), format!("{cin}x{cout}"), l.weight_count()))
            }
            Layer::Linear { name, din, dout, .. } => {
                Some((name.clone(), format!("{din}x{dout}"), l.weight_count()))
            }
            _ => None,
        })
        .collect()
}

/// Weight fraction held by the FC stack (paper: 75.17%).
pub fn fc_weight_fraction() -> f64 {
    let rows = table1();
    let total: usize = rows.iter().map(|r| r.2).sum();
    let fc: usize = rows.iter().filter(|r| r.0.starts_with("FC")).map(|r| r.2).sum();
    fc as f64 / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_counts_match_paper() {
        let rows = table1();
        let expected = [
            ("Conv0", 1728),
            ("Conv1", 36864),
            ("Conv2", 73728),
            ("Conv3", 147456),
            ("Conv4", 294912),
            ("Conv5", 589824),
            ("Conv6", 589824),
            ("FC0", 4194304),
            ("FC1", 1048576),
            ("FC2", 10240),
        ];
        assert_eq!(rows.len(), expected.len());
        for ((name, _, count), (ename, ecount)) in rows.iter().zip(expected.iter()) {
            assert_eq!(name, ename);
            assert_eq!(count, ecount, "{name}");
        }
    }

    #[test]
    fn fc_fraction_is_75_17_percent() {
        let f = fc_weight_fraction() * 100.0;
        assert!((f - 75.17).abs() < 0.05, "{f}");
    }

    #[test]
    fn shapes_infer_end_to_end() {
        use crate::model::dims::resize;
        let mut d = vec![32, 32, 3];
        for l in vgg11().flatten() {
            d = resize(l, &d).unwrap();
        }
        assert_eq!(d, vec![10]);
    }

    #[test]
    fn ten_trainable_layers() {
        assert_eq!(table1().len(), 10);
    }
}
